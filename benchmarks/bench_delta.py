"""Dynamic-R delta-occupancy rows (DESIGN.md §13): query cost vs the
fraction of the logical set living in the un-merged delta shard.

The §13 design bet is that queries degrade GRACEFULLY before
compaction: the delta is swept exactly by a small dense program
appended to `_commit_verify`, so cost grows with |delta| only — no
index rebuilds, no candidate-table churn. These rows measure a full
exact-sweep join at 0% / 12.5% / 50% delta occupancy plus a
post-compact row (delta folded into the pinned R), at a fixed smoke n
regardless of REPRO_BENCH_SCALE (the ratio, not the scale, is the
point).

Rows: ``delta/occ-<pct>`` -> us/query; the derived column carries the
slowdown vs the 0%-delta baseline — the BENCH_<n> acceptance number.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, save_json

N, DIM, NQ = 6000, 32, 256
EPS = 0.5
WARM, REPS = 2, 5
FRACS = (0.0, 0.125, 0.5)


def _unit(rng, n):
    x = rng.normal(size=(n, DIM)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def run() -> list:
    from repro.core.engine import JoinEngine

    rng = np.random.default_rng(0)
    eng = JoinEngine(_unit(rng, N), "cosine", backend="jnp")
    Q = _unit(rng, NQ)

    def med_us_per_query() -> float:
        def one():
            t0 = time.perf_counter()
            eng.filtered_join(Q, EPS)
            return time.perf_counter() - t0
        for _ in range(WARM):
            one()
        return float(np.median([one() for _ in range(REPS)])) / NQ * 1e6

    rows, base = [], None
    for frac in FRACS:
        need = int(N * frac) - eng.n_delta
        if need > 0:
            eng.insert(_unit(rng, need))
        us = med_us_per_query()
        base = us if base is None else base
        name = f"delta/occ-{100 * frac:g}%"
        emit(name, us, f"slowdown_vs_0%={us / base:.2f}x")
        rows.append({"name": name, "us_per_query": us,
                     "slowdown_vs_0": us / base,
                     "n_r": eng.nr, "n_delta": eng.n_delta})

    stats = eng.compact()
    us = med_us_per_query()
    emit("delta/post-compact", us,
         f"slowdown_vs_0%={us / base:.2f}x n_r={stats['n_r']}")
    rows.append({"name": "delta/post-compact", "us_per_query": us,
                 "slowdown_vs_0": us / base,
                 "n_r": eng.nr, "n_delta": eng.n_delta})
    save_json("delta_occupancy", rows)
    return rows
