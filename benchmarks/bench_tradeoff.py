"""Figure 3: speed-quality trade-off curves — vary the key parameter of
each approximate method (and tau/XDT-mode for XJoin; Xling-enhanced
variants of LSH/KmeansTree/IVFPQ use mean-XDT tau=0 as in the paper).
Every enhanced variant is one `JoinPlan` (DESIGN.md §9): the base's
`candidates()` routes positives through the engine's device verification.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, get_filter, save_json, true_counts
from repro.core import JoinPlan, make_join

DATASET = "glove"
EPS = 0.45


def _measure(fn, truth):
    t0 = time.perf_counter()
    counts = np.asarray(fn())
    dt = time.perf_counter() - t0
    rec = float(np.minimum(counts, truth).sum() / max(truth.sum(), 1))
    return dt, rec


def run(dataset=DATASET) -> list:
    filt, R, S, spec = get_filter(dataset)
    truth = true_counts(R, S, EPS, spec.metric)
    naive = make_join("naive", R, spec.metric, backend="jnp")
    naive.query_counts(S[:32], EPS)
    rows = []

    def record(method, param, fn):
        dt, rec = _measure(fn, truth)
        rows.append({"method": method, "param": param, "time_s": dt,
                     "recall": rec})
        emit(f"tradeoff/{method}/{param}", dt * 1e6 / len(S),
             f"recall={rec:.4f}")

    def enhanced(base, *, tau=0, xdt="mean"):
        # every variant shares the naive join's engine (same R resident
        # once); non-naive bases verify through their own candidates()
        return (JoinPlan(R, spec.metric).filter(filt, tau=tau, xdt=xdt)
                .search(base).on(backend="jnp", engine=naive.engine)
                .build())

    # XJoin: vary (xdt_mode, tau)
    for mode, tau in (("mean", 0), ("mean", 5), ("fpr", 0), ("fpr", 5),
                      ("fpr", 50)):
        xj = enhanced(naive, tau=tau, xdt=mode)
        record("xjoin", f"{mode}-tau{tau}", lambda xj=xj: xj.run(S, EPS).counts)

    # LSH and LSH-Xling: vary n_probes
    for n_p in (1, 2, 4, 8):
        lsh = make_join("lsh", R, spec.metric, k=14, l=10, n_probes=n_p, W=2.5)
        record("lsh", f"np{n_p}", lambda j=lsh: j.query_counts(S, EPS))
        enh = enhanced(lsh)
        record("lsh-xling", f"np{n_p}", lambda e=enh: e.run(S, EPS).counts)

    # KmeansTree and enhanced: vary rho
    for rho in (0.01, 0.02, 0.05, 0.1):
        km = make_join("kmeanstree", R, spec.metric, branching=3, rho=rho)
        record("kmeanstree", f"rho{rho}", lambda j=km: j.query_counts(S, EPS))
        enh = enhanced(km)
        record("kmeanstree-xling", f"rho{rho}", lambda e=enh: e.run(S, EPS).counts)

    # IVFPQ and enhanced: vary n_probe
    for n_p in (4, 16, 48):
        ivf = make_join("ivfpq", R, spec.metric, C=128, n_probe=n_p,
                        n_candidates=1000)
        record("ivfpq", f"np{n_p}", lambda j=ivf: j.query_counts(S, EPS))
        enh = enhanced(ivf)
        record("ivfpq-xling", f"np{n_p}", lambda e=enh: e.run(S, EPS).counts)

    save_json("fig3_tradeoff", rows)
    return rows


if __name__ == "__main__":
    run()
