"""XJoin probe-placement rows (DESIGN.md §11): host- vs device-probe,
replicated vs ring.

Smoke-scale end-to-end streams of the engine's approximate-verification
pipeline, timing the SAME workload with the index probe on host (the
legacy route: verdict readback -> NumPy/jit probe -> candidate upload)
and on device (`probe="device"`: compact -> probe -> verify fused into
mesh programs, positives never leaving the device). Every query is
probed (filter "none") so the rows isolate probing cost — the filtered
end-to-end picture lives in bench_e2e (fig2). Small 64-query batches
are the serving-shaped regime where per-batch host glue matters.

Rows: ``xjoin/<verify>-<probe>-<topology>`` -> us/query over the
streamed batches (best of REPS interleaved passes); the device rows' derived
column carries the speedup vs their host counterpart — the BENCH_<n>
acceptance number. Runs at a fixed smoke n regardless of
REPRO_BENCH_SCALE (the comparison, not the scale, is the point).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, get_data, save_json

DATASET = "glove"
N = 6000
EPS = 0.45
BATCH, NBATCH, DEPTH = 64, 30, 2
WARM, REPS = 2, 5

PARAMS = {
    "lsh": dict(k=14, l=10, n_probes=4, W=2.5),
    "ivfpq": dict(C=64, n_probe=8, n_candidates=400),
}


def _paired_stream_ms(plans: dict, batches) -> dict:
    """{name: best wall-clock ms} of one full streamed pass per plan.

    The two placements are timed in INTERLEAVED rounds (host pass, device
    pass, host pass, ...) so machine drift on a shared box cancels instead
    of biasing whichever ran second, and the row takes the BEST round:
    scheduler interference only ever adds time, so the one-sided noise
    makes min the faithful cost of each pipeline (the bench_ring
    methodology)."""
    def one(plan):
        t0 = time.perf_counter()
        list(plan.stream(batches, EPS, depth=DEPTH))
        return time.perf_counter() - t0

    samples: dict = {name: [] for name in plans}
    for _ in range(WARM + REPS):
        for name, plan in plans.items():
            samples[name].append(one(plan))
    return {name: float(np.min(ts[WARM:])) * 1e3
            for name, ts in samples.items()}


def run() -> list:
    import jax

    from repro.core import JoinPlan
    from repro.launch.mesh import make_join_mesh

    R, S, spec = get_data(DATASET, N)
    batches = [S[i * BATCH:(i + 1) * BATCH] for i in range(NBATCH)]
    batches = [b for b in batches if len(b)]
    nq = sum(len(b) for b in batches)

    r_shards = 2 if len(jax.devices()) >= 2 else 1
    topologies = {
        "replicated": dict(),
        # degenerate r=1 on single-device hosts still exercises the full
        # ring program path (ppermute ring, per-shard probe tables)
        f"ring{r_shards}": dict(mesh=make_join_mesh(r=r_shards),
                                topology="ring"),
    }

    rows = []
    for topo, on_extra in topologies.items():
        engine = None
        for verify, params in PARAMS.items():
            plans = {}
            for probe in ("host", "device"):
                plan = (JoinPlan(R, spec.metric).filter("none")
                        .search("naive").verify(verify, **params)
                        .on(backend="jnp", probe=probe,
                            **(dict(engine=engine) if engine else on_extra))
                        .build())
                engine = plan.engine       # share R + verifier indices
                plans[probe] = plan
            ms = _paired_stream_ms(plans, batches)
            speedup = ms["host"] / max(ms["device"], 1e-9)
            for probe in ("host", "device"):
                derived = (f"speedup_vs_host={speedup:.3f}"
                           if probe == "device" else
                           f"total_ms={ms[probe]:.1f}")
                emit(f"xjoin/{verify}-{probe}-{topo}",
                     ms[probe] * 1e3 / nq, derived)
                rows.append({"verify": verify, "probe": probe,
                             "topology": topo, "total_ms": ms[probe],
                             "us_per_query": ms[probe] * 1e3 / nq,
                             "speedup_vs_host": (speedup if probe ==
                                                 "device" else None)})
    save_json("xjoin_probe_placement", rows)
    return rows


if __name__ == "__main__":
    run()
