"""Auto-planner rows (DESIGN.md §16): planned vs exhaustive grid vs
static defaults, on a uniform and a deliberately skewed workload.

For each workload the suite streams the SAME serving-shaped batches
through (a) the configuration `JoinPlan.auto()` picks, (b) every
configuration in a small exhaustive grid over verify backend x probe
placement (including the skew-aware re-bucketed LSH variant), and
(c) the three static recall-table defaults the planner replaces
(exact / lsh-device / ivfpq-device — `TenantClass.resolved_verify`).
All plans run unfiltered so the rows isolate execution-config cost,
and every plan is timed in interleaved rounds with the row taking the
best round (the bench_probe/bench_ring methodology: scheduler noise is
one-sided, so min is the faithful cost).

Each grid config is also scored for recall against the exact ground
truth, and configs below the planner's recall floor are excluded from
the "best grid" reference (on the skewed workload plain LSH overflows
its bucket caps and silently drops ~20% of memberships — beating an
infeasible config is not a win, and the planner itself rejects it).

Rows: ``planner/<workload>-planned`` (derived: the chosen config and
its ratio vs the best RECALL-FEASIBLE grid config and the worst static
default — the BENCH_<n> acceptance numbers: planned >= 0.95x best-grid
everywhere, planned strictly faster than the worst default on the
skewed workload) and ``planner/<workload>-grid-<config>`` for every
grid entry, feasible or not, with its measured recall.  The
skewed workload plants a dense cluster (one fifth of R within a tight
ball) so the LSH occupancy histogram trips the re-bucketing trigger.
Runs at a fixed smoke n regardless of REPRO_BENCH_SCALE.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, get_data, save_json

N = 4000
EPS = 0.45
BATCH, NBATCH = 64, 20
WARM, REPS = 2, 5
RECALL = 0.85

LSH = dict(k=14, l=10, n_probes=4, W=2.5)
IVFPQ = dict(C=64, n_probe=8, n_candidates=400)

#: the static recall-table resolutions the planner replaces
#: (TenantClass.resolved_verify: 1.0 -> exact, >= 0.95 -> ivfpq, else lsh)
DEFAULTS = ("exact", "lsh-device", "ivfpq-device")


def _skewed_workload(seed: int = 0):
    """Synthetic skewed set: 1/5 of R packed into one tight cluster (a
    single LSH bucket's worth of mass), the rest uniform on the sphere;
    queries drawn near R rows.  The cluster is what trips the planner's
    re-bucketing trigger."""
    rng = np.random.default_rng(seed)
    d = 32
    n_hot = N // 5
    bg = rng.normal(size=(N - n_hot, d))
    center = rng.normal(size=(1, d))
    hot = center + 0.05 * rng.normal(size=(n_hot, d))
    R = np.concatenate([bg, hot]).astype(np.float32)
    R /= np.linalg.norm(R, axis=1, keepdims=True)
    S = R[rng.choice(N, BATCH * NBATCH, replace=True)]
    S = S + 0.02 * rng.normal(size=S.shape).astype(np.float32)
    S /= np.linalg.norm(S, axis=1, keepdims=True)
    return R.astype(np.float32), S.astype(np.float32)


def _grid(R, metric):
    """{config key: built plan} over the exhaustive verify x probe grid
    (replicated topology — the ring rows live in bench_ring), sharing
    one engine so R uploads once."""
    from repro.core import JoinPlan

    def plan(verify, params, probe, engine):
        p = (JoinPlan(R, metric).filter("none").search("naive")
             .verify(verify, **params).on(backend="jnp"))
        if probe is not None:
            p = p.on(probe=probe)
        if engine is not None:
            p = p.on(engine=engine)
        return p.build()

    plans = {}
    plans["exact"] = plan("exact", {}, None, None)
    engine = plans["exact"].engine
    for probe in ("device", "host"):
        plans[f"lsh-{probe}"] = plan("lsh", LSH, probe, engine)
        plans[f"ivfpq-{probe}"] = plan("ivfpq", IVFPQ, probe, engine)
    plans["lsh+rebucket-device"] = plan(
        "lsh", dict(LSH, rebucket_hot=4.0), "device", engine)
    return plans


def _recalls(plans: dict, batches, eps: float) -> dict:
    """{config: verified-pair recall vs the exact plan's ground truth}.
    Approximate verifies never emit false positives (candidates are
    verified exactly), so total-count ratio IS recall."""
    totals = {name: sum(int(np.sum(res.counts))
                        for res in plan.stream(batches, eps, depth=2))
              for name, plan in plans.items()}
    truth = max(totals["exact"], 1)
    return {name: t / truth for name, t in totals.items()}


def _chosen_key(explain: dict) -> str:
    """Map a planner choice onto this suite's grid keys."""
    ch = explain["chosen"]
    if ch["verify"] == "exact":
        return "exact"
    return f"{ch['verify']}-{ch['probe']}"


def _paired_stream_ms(plans: dict, batches, eps: float) -> dict:
    """{name: best wall-clock ms} of one full streamed pass per plan,
    interleaved rounds, best-of-REPS (see module docstring)."""
    def one(plan):
        t0 = time.perf_counter()
        list(plan.stream(batches, eps, depth=2))
        return time.perf_counter() - t0

    samples: dict = {name: [] for name in plans}
    for _ in range(WARM + REPS):
        for name, plan in plans.items():
            samples[name].append(one(plan))
    return {name: float(np.min(ts[WARM:])) * 1e3
            for name, ts in samples.items()}


def run() -> list:
    from repro.core import JoinPlan

    Rg, Sg, spec = get_data("glove", N)
    Rs, Ss = _skewed_workload()
    workloads = {
        "uniform": (Rg, Sg[: BATCH * NBATCH], spec.metric, EPS),
        "skewed": (Rs, Ss, "cosine", 0.3),
    }

    rows = []
    for wl, (R, S, metric, eps) in workloads.items():
        batches = [S[i * BATCH:(i + 1) * BATCH] for i in range(NBATCH)]
        batches = [b for b in batches if len(b)]
        nq = sum(len(b) for b in batches)

        planned = JoinPlan(R, metric).filter("none").auto(
            eps, S[:256], recall=RECALL, seed=0)
        key = _chosen_key(planned.explain())
        grid = _grid(R, metric)
        recall = _recalls(grid, batches, eps)
        ms = _paired_stream_ms(dict(grid, planned=planned), batches, eps)

        grid_ms = {k: v for k, v in ms.items() if k != "planned"}
        feasible = {k: v for k, v in grid_ms.items()
                    if recall[k] >= RECALL}
        best_key = min(feasible, key=feasible.get)
        worst_default = max(DEFAULTS, key=lambda k: grid_ms[k])
        vs_best = ms["planned"] / max(feasible[best_key], 1e-9)
        vs_worst = ms["planned"] / max(grid_ms[worst_default], 1e-9)
        emit(f"planner/{wl}-planned", ms["planned"] * 1e3 / nq,
             f"chosen={key} vs_best={vs_best:.3f}"
             f" vs_worst_default={vs_worst:.3f}({worst_default})")
        for cfg in sorted(grid_ms):
            tag = [f"recall={recall[cfg]:.3f}"]
            if cfg not in feasible:
                tag.append("infeasible")
            if cfg == best_key:
                tag.append("grid_best")
            if cfg == worst_default:
                tag.append("worst_default")
            emit(f"planner/{wl}-grid-{cfg}", grid_ms[cfg] * 1e3 / nq,
                 ",".join(tag))
        rows.append({"workload": wl, "chosen": key, "eps": eps,
                     "planned_us": ms["planned"] * 1e3 / nq,
                     "grid_us": {k: v * 1e3 / nq
                                 for k, v in grid_ms.items()},
                     "recall": {k: round(v, 4)
                                for k, v in recall.items()},
                     "best": best_key, "worst_default": worst_default,
                     "vs_best": vs_best, "vs_worst_default": vs_worst,
                     "explain": planned.explain()})
    save_json("planner", rows)
    return rows


if __name__ == "__main__":
    run()
