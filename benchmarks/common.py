"""Shared benchmark machinery: scaled datasets, cached fitted filters,
timing, CSV emission (format: name,us_per_call,derived)."""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import XlingConfig, XlingFilter           # noqa: E402
from repro.data import load_dataset                       # noqa: E402
from repro.utils import cache_path                        # noqa: E402

SCALE = os.environ.get("REPRO_BENCH_SCALE", "small")
N = {"small": 6000, "medium": 20000, "full": 150000}[SCALE]
EPOCHS = {"small": 12, "medium": 20, "full": 60}[SCALE]
OUT_DIR = os.environ.get("REPRO_BENCH_OUT", "experiments/bench")


def emit(name: str, us_per_call: float, derived) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def save_json(name: str, payload) -> None:
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)


def timed_call(fn, *args, warmup: int = 0, **kw):
    for _ in range(warmup):
        fn(*args, **kw)
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0)


def get_data(name: str, n: int | None = None, sample: int = 1):
    return load_dataset(name, n=n or N, seed=0, sample=sample)


def get_filter(dataset: str, *, estimator: str = "nn", n: int | None = None,
               m: int = 100, epochs: int | None = None, strategy: str = "atcs",
               seed: int = 0) -> tuple[XlingFilter, np.ndarray, np.ndarray, object]:
    """Fitted Xling filter with a disk cache (shared across benchmarks)."""
    n = n or N
    epochs = epochs or EPOCHS
    R, S, spec = get_data(dataset, n)
    key = ("xfilter-v2", dataset, estimator, n, m, epochs, strategy, seed)
    path = cache_path(*key)
    cfg = XlingConfig(estimator=estimator, metric=spec.metric, m=m,
                      epochs=epochs, strategy=strategy, seed=seed,
                      backend="jnp")
    if os.path.exists(path):
        filt = XlingFilter.load(path, cfg)
    else:
        filt = XlingFilter(cfg).fit(R, cache_key=("bench", dataset, n))
        filt.save(path)
    return filt, R, S, spec


def true_counts(R, S, eps, metric):
    from repro.kernels import ops
    key = ("bench-true", len(R), len(S), round(float(eps), 6), metric,
           float(R[0, 0]), float(S[0, 0]))
    path = cache_path(*key)
    if os.path.exists(path):
        with np.load(path) as z:
            return z["t"]
    t = np.asarray(ops.range_count(S, R, float(eps), metric=metric,
                                   backend="jnp"))
    np.savez_compressed(path, t=t)
    return t
