"""Ring sweep schedule rows (DESIGN.md §15): overlapped vs serial.

`RingSharded(overlap=True)` issues the next query block's `ppermute`
BEFORE the current histogram step and combines partial counts with a
ring reduce-scatter, so the hop transfers while the MXU sweeps.  These
rows pin the schedule's cost envelope on the CPU container: at ``r=1``
the overlapped program compiles to zero collectives, so it must be no
slower than serial; at ``r>=2`` the overlapped schedule should win (on
CPU the win is the removed `[r, q, m]` buffer + full-buffer `psum` +
`take`; on TPU/GPU the transfer itself also hides —
`launch/xla_flags.py`).

Each (r_shards) cell runs in a SUBPROCESS with
``--xla_force_host_platform_device_count=<r>`` (XLA reads the flag once
at backend init, so the parent process cannot host the multi-device
mesh itself).  The child pre-stages padded device inputs once and times
the COMPILED sweep program (`hist_program`) with `block_until_ready` —
the schedule is the thing under test, and the engine entry point's
per-call host glue (padding, `device_put`, readback; measured by the
xjoin suite) would bury the tens-of-microseconds schedule delta.  The
two schedules are timed in INTERLEAVED rounds so machine drift cancels
instead of biasing whichever ran second, and the child asserts their
counts bit-identical before timing.

  ``ring/range_count-{overlap|serial}-r{r}`` -> us/query, with the
  overlap rows' derived column carrying ``speedup_vs_serial`` — the
  BENCH_<n> acceptance number (>= ~1.0 at r=1, > 1.0 at r>=2).

Runs at a fixed smoke shape regardless of REPRO_BENCH_SCALE (the
schedule comparison, not the scale, is the point): R sized to one
block_r tile per shard at r=2 — the communication-visible regime the
overlap targets (on a pod the per-device shard is exactly the "small
enough to hop every step" size; a compute-saturated shard hides ANY
schedule equally well and measures nothing).
"""
from __future__ import annotations

import os
import subprocess
import sys

from benchmarks.common import emit, save_json

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
R_SHARD_COUNTS = (1, 2)
NR, NQ, D, M = 1024, 512, 32, 16
WARM, ROUNDS = 5, 50

#: child script: stage padded device inputs once per schedule, then time
#: the compiled hist_program in interleaved rounds; prints
#: ``RING_ROW,<schedule>,<ms>`` lines (BEST of the timing rounds —
#: scheduler interference on a shared host only ever adds time, so the
#: one-sided noise makes min the faithful cost of the compiled schedule)
_CHILD = """
import os
from repro.launch.xla_flags import apply_xla_flags, host_device_count_flag
apply_xla_flags(host_device_count_flag({r}))
import time
import numpy as np
import jax.numpy as jnp
import repro.core.engine as em
from repro.core.engine import JoinEngine
from repro.core.topology import RingSharded
from repro.launch.mesh import make_join_mesh

rng = np.random.default_rng(0)
def unit(n):
    x = rng.normal(size=(n, {d})).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)
R, Q = unit({nr}), unit({nq})
eps = np.linspace(0.3, 1.2, {m}).astype(np.float32)
mesh = make_join_mesh(data=1, r={r})
runs, base = {{}}, None
for schedule, overlap in (("overlap", True), ("serial", False)):
    eng = JoinEngine(R, "cosine", backend="jnp", mesh=mesh,
                     topology=RingSharded(overlap=overlap))
    got = np.asarray(eng.range_count_hist(Q, eps))
    if base is None:
        base = got
    else:
        np.testing.assert_array_equal(got, base)
    prog = em._hist_program(eng.mesh, eng.data_axis, eng.backend,
                            eng.metric, eng.block_q, eng.block_r,
                            eng.eps_chunk, eng.nr, eng.topology)
    qdev = eng._put_q(eng._pad_q(Q))
    epdev = jnp.asarray(eng._pad_eps(eps))
    runs[schedule] = (prog, qdev, eng._Rdev, epdev, eng._nrv_dev)
samples = {{k: [] for k in runs}}
for rep in range({warm} + {rounds}):
    for schedule, (prog, *args) in runs.items():
        t0 = time.perf_counter()
        prog(*args).block_until_ready()
        samples[schedule].append(time.perf_counter() - t0)
for schedule, ts in samples.items():
    ms = float(np.min(np.array(ts[{warm}:]))) * 1e3
    print(f"RING_ROW,{{schedule}},{{ms:.4f}}", flush=True)
"""


def _child_rows(r: int) -> dict[str, float]:
    """{schedule: total_ms} from one forced-`r`-device subprocess."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    code = _CHILD.format(r=r, nr=NR, nq=NQ, d=D, m=M, warm=WARM,
                         rounds=ROUNDS)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         cwd=REPO_ROOT, capture_output=True, text=True,
                         timeout=900)
    if out.returncode != 0:
        raise RuntimeError(f"bench_ring child (r={r}) failed:\n"
                           + out.stderr[-3000:])
    rows: dict[str, float] = {}
    for line in out.stdout.splitlines():
        parts = line.split(",")
        if parts[0] == "RING_ROW":
            rows[parts[1]] = float(parts[2])
    if set(rows) != {"overlap", "serial"}:
        raise RuntimeError(f"bench_ring child (r={r}) emitted {set(rows)}:\n"
                           + out.stdout[-2000:])
    return rows


def run() -> list:
    rows = []
    for r in R_SHARD_COUNTS:
        ms = _child_rows(r)
        speedup = ms["serial"] / max(ms["overlap"], 1e-9)
        for schedule in ("overlap", "serial"):
            derived = (f"speedup_vs_serial={speedup:.3f}"
                       if schedule == "overlap" else
                       f"total_ms={ms[schedule]:.2f}")
            emit(f"ring/range_count-{schedule}-r{r}",
                 ms[schedule] * 1e3 / NQ, derived)
            rows.append({"schedule": schedule, "r_shards": r,
                         "total_ms": ms[schedule],
                         "us_per_query": ms[schedule] * 1e3 / NQ,
                         "speedup_vs_serial": (speedup if schedule ==
                                               "overlap" else None)})
    save_json("ring_schedule", rows)
    return rows


if __name__ == "__main__":
    run()
