"""Figure 2: end-to-end join time + recall for all methods.

Methods: Naive (exact, ground truth), Grid/SuperEGO-like (exact), LSH,
KmeansTree, Naive-LSBF, IVFPQ, and XJoin (paper config: FPR XDT, tau=50)
— plus the beyond-paper engine verification backends (DESIGN.md §5):
xjoin-lsh / xjoin-ivfpq replace the exact verification sweep with an
approximate probe + on-device candidate verification, so their recall
column measures the verification backend against the exact oracle.

All filtered rows compose through the declarative `JoinPlan` API
(DESIGN.md §9); each plan's serialized `describe()` is recorded next to
its timing row.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, get_filter, save_json, true_counts
from repro.core import JoinPlan, make_join
from repro.core.joins.lsbf import LSBF

DATASETS = ("glove", "sift", "gist")
EPS = 0.45
# the filter-vs-search cost ratio that drives the paper's speedups needs a
# non-trivial |R| (estimator cost is O(1)/query, search is O(|R|d)): run the
# end-to-end figure at >= 20k points regardless of the bench scale.
N_E2E = 20000


def run(datasets=DATASETS) -> list:
    from benchmarks.common import N
    n = max(N, N_E2E)
    rows = []
    plans: dict[str, JoinPlan] = {}
    for ds in datasets:
        filt, R, S, spec = get_filter(ds, n=n)
        truth = true_counts(R, S, EPS, spec.metric)
        total_pairs = float(truth.sum())

        def recall(counts):
            if total_pairs == 0:
                return 1.0
            return float(np.minimum(counts, truth).sum() / total_pairs)

        methods = {}
        # the naive base and every filtered plan share one device-resident
        # engine; pass a data mesh via .on(mesh=make_data_mesh()) to shard
        # the query axis across devices — same counts, distributed sweep
        naive = make_join("naive", R, spec.metric, backend="jnp")
        engine = naive.engine
        naive.query_counts(S[:64], EPS)  # warm the jit
        methods["naive"] = lambda: naive.query_counts(S, EPS)
        grid = make_join("grid", R, spec.metric)
        methods["grid(superego)"] = lambda: grid.query_counts(S, EPS)
        lsh = make_join("lsh", R, spec.metric, k=14, l=10, n_probes=4,
                        W=2.5 if spec.kind == "text" else 2.0)
        methods["lsh"] = lambda: lsh.query_counts(S, EPS)
        km = make_join("kmeanstree", R, spec.metric, branching=3, rho=0.02)
        methods["kmeanstree"] = lambda: km.query_counts(S, EPS)
        ivf = make_join("ivfpq", R, spec.metric, C=128, n_probe=16,
                        n_candidates=1000)
        methods["ivfpq"] = lambda: ivf.query_counts(S, EPS)
        lsbf_plan = (JoinPlan(R, spec.metric)
                     .filter(LSBF(R, spec.metric, k=18, l=10,
                                  W=2.5 if spec.kind == "text" else 2.0))
                     .search(naive).on(engine=engine, backend="jnp").build())
        plans["naive-lsbf"] = lsbf_plan
        methods["naive-lsbf"] = lambda: lsbf_plan.run(S, EPS).counts
        xplan = (JoinPlan(R, spec.metric)
                 .filter(filt, tau=50, xdt="fpr", fpr_tolerance=0.05)
                 .search(naive).on(engine=engine, backend="jnp").build())
        # fused filter->compact->verify path: exact sweep on the shared engine
        assert xplan.describe()["verify"]["resolved"] == "exact"
        plans["xjoin"] = xplan
        xplan.run(S[:64], EPS)  # warm
        methods["xjoin"] = lambda: xplan.run(S, EPS).counts
        # engine verification backends (DESIGN.md §5): same filter, the
        # exact sweep swapped for approximate probe + device verification
        for vb in ("lsh", "ivfpq"):
            xp_v = (JoinPlan(R, spec.metric)
                    .filter(filt, tau=50, xdt="fpr", fpr_tolerance=0.05)
                    .search(naive).verify(vb)
                    .on(engine=engine, backend="jnp").build())
            xp_v.run(S[:64], EPS)  # warm (the verifier index built at .build())
            plans[f"xjoin-{vb}"] = xp_v
            methods[f"xjoin-{vb}"] = (
                lambda xp_=xp_v: xp_.run(S, EPS).counts)

        for name, fn in methods.items():
            fn()   # warm: jit shapes for the FULL query set
            t0 = time.perf_counter()
            counts = fn()
            dt = time.perf_counter() - t0
            rec = recall(np.asarray(counts))
            rows.append({"dataset": ds, "method": name, "time_s": dt,
                         "recall": rec,
                         "speedup_vs_naive": None,
                         "plan": (plans[name].describe()
                                  if name in plans else None)})
            emit(f"e2e/{ds}/{name}", dt * 1e6 / max(len(S), 1),
                 f"recall={rec:.4f};t={dt:.3f}s")
        base = next(r for r in rows if r["dataset"] == ds and r["method"] == "naive")
        for r in rows:
            if r["dataset"] == ds:
                r["speedup_vs_naive"] = base["time_s"] / max(r["time_s"], 1e-9)
    save_json("fig2_end_to_end", rows)
    return rows


if __name__ == "__main__":
    run()
