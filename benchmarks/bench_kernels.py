"""Kernel micro-benchmarks: the fused range_count + estimator-MLP paths,
plus the ADC-rank formulations (DESIGN.md §15).

On this CPU container we time the XLA:CPU jnp path (production fast path
off-TPU) and validate the Pallas kernel in interpret mode; the TPU roofline
numbers for the same shapes come from the dry-run (§Roofline).

`kernel/adc_rank` (the fused-formulation jnp path: shared per-segment
LUT accumulate + top_k) is timed against `kernel/adc_chain` (the old
transpose + take_along_axis + sum + top_k chain it replaced in
`core/probe._ivfpq_block`) on the same inputs — the BENCH_<n>
acceptance pair.  `kernel/range_count` additionally emits a
``block_r=1024`` row: the per-eps masked accumulate shrank the kernel's
largest temporary from the [Bq, Br, eps_chunk] bool broadcast (256 x
512 x 8 = 1 MB at the old maximum tile) to one [Bq, Br] bool per eps
step, which is what lets the R tile double."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, save_json
from repro.kernels import ops, ref
from repro.launch.roofline import HBM_BW, PEAK_FLOPS


def run() -> list:
    rng = np.random.default_rng(0)
    rows = []
    for (nq, nr, d, m) in ((1024, 8192, 300, 100), (2048, 16384, 128, 100)):
        q = rng.normal(size=(nq, d)).astype(np.float32)
        q /= np.linalg.norm(q, axis=1, keepdims=True)
        r = rng.normal(size=(nr, d)).astype(np.float32)
        r /= np.linalg.norm(r, axis=1, keepdims=True)
        eps = np.linspace(0.3, 1.2, m).astype(np.float32)
        out, _ = None, None
        # warm + time the jnp path
        ops.range_count_hist(q, r, eps, metric="cosine", backend="jnp")
        t0 = time.perf_counter()
        out = ops.range_count_hist(q, r, eps, metric="cosine", backend="jnp")
        np.asarray(out)
        dt = time.perf_counter() - t0
        flops = 2.0 * nq * nr * d + nq * nr * m
        # validate pallas (interpret) on a slice
        got = np.asarray(ops.range_count_hist(q[:64], r[:512], eps,
                                              metric="cosine",
                                              backend="pallas", block_q=32,
                                              block_r=128, eps_chunk=4))
        want = np.asarray(ref.range_count_hist(q[:64], r[:512], eps, "cosine"))
        assert (got == want).all()
        tpu_compute_s = flops / PEAK_FLOPS
        rows.append({"kernel": "range_count_hist", "nq": nq, "nr": nr,
                     "d": d, "m": m, "cpu_s": dt, "flops": flops,
                     "cpu_gflops": flops / dt / 1e9,
                     "tpu_roofline_s": tpu_compute_s})
        emit(f"kernel/range_count/{nq}x{nr}x{d}", dt * 1e6,
             f"gflops={flops/dt/1e9:.1f}")

    # the widened R tile (DESIGN.md §15): per-eps masked accumulate ->
    # block_r=1024 is a legal tile; validate it bit-exact in interpret
    # mode (the note lines record the working-set change; '#' lines are
    # ignored by run.py's parse_rows)
    got = np.asarray(ops.range_count_hist(q[:64], r[:1024], eps,
                                          metric="cosine",
                                          backend="pallas", block_q=32,
                                          block_r=1024, eps_chunk=4))
    want = np.asarray(ref.range_count_hist(q[:64], r[:1024], eps, "cosine"))
    assert (got == want).all()
    print("# note: range_count eps working set: [256,512,8] bool broadcast "
          "(1.0 MB, capped block_r at 512) -> one [256,1024] bool per eps "
          "step (0.25 MB at block_r=1024)")
    print("# note: block_r 512 -> 1024 verified bit-exact (interpret) above")

    # estimator MLP
    widths = (512, 512, 256, 128)
    dims = (301,) + widths + (1,)
    params = [(rng.normal(size=(a, b)).astype(np.float32) * 0.05,
               np.zeros((1, b), np.float32))
              for a, b in zip(dims[:-1], dims[1:])]
    x = rng.normal(size=(8192, 301)).astype(np.float32)
    ops.mlp_forward(params, x, backend="jnp")
    t0 = time.perf_counter()
    np.asarray(ops.mlp_forward(params, x, backend="jnp"))
    dt = time.perf_counter() - t0
    flops = 2 * 8192 * sum(a * b for a, b in zip(dims[:-1], dims[1:]))
    got = np.asarray(ops.mlp_forward(params, x[:128], backend="pallas",
                                     block_n=64))
    want = np.asarray(ref.mlp_forward(params, x[:128]))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    rows.append({"kernel": "fused_mlp", "n": 8192, "cpu_s": dt,
                 "flops": flops, "cpu_gflops": flops / dt / 1e9})
    emit("kernel/fused_mlp/8192", dt * 1e6, f"gflops={flops/dt/1e9:.1f}")

    # ADC ranking: fused formulation (jnp path of kernels/adc_rank.py,
    # what _ivfpq_block now runs) vs the old transpose+take_along_axis+
    # top_k chain it replaced — same inputs, both jit'd, median of REPS
    import jax

    b, dim, m_seg, n_codes, C, n_cand = 256, 128, 8, 4096, 400, 200
    qv = rng.normal(size=(b, dim)).astype(np.float32)
    codebooks = rng.normal(size=(m_seg, 256, dim // m_seg)).astype(np.float32)
    codes = rng.integers(0, 256, size=(n_codes, m_seg)).astype(np.uint8)
    cand = rng.integers(-1, n_codes, size=(b, C)).astype(np.int32)
    variants = {
        "adc_rank": jax.jit(lambda *a: ops.adc_rank(*a, n_cand=n_cand,
                                                    backend="jnp")),
        "adc_chain": jax.jit(lambda *a: ops.adc_rank(*a, n_cand=n_cand,
                                                     backend="ref")),
    }
    reps, times = 7, {}
    for name, fn in variants.items():
        np.asarray(fn(qv, codebooks, cand, codes))      # warm/compile
        samples = []
        for _ in range(reps):
            t0 = time.perf_counter()
            np.asarray(fn(qv, codebooks, cand, codes))
            samples.append(time.perf_counter() - t0)
        times[name] = float(np.median(samples))
    ids = {name: np.asarray(fn(qv, codebooks, cand, codes))
           for name, fn in variants.items()}
    for row in range(b):                                # same sets, always
        assert set(ids["adc_rank"][row]) == set(ids["adc_chain"][row])
    speedup = times["adc_chain"] / times["adc_rank"]
    for name in variants:
        derived = (f"speedup_vs_chain={speedup:.3f}" if name == "adc_rank"
                   else f"b={b},C={C},n_cand={n_cand}")
        emit(f"kernel/{name}/{b}x{C}x{n_cand}", times[name] * 1e6, derived)
        rows.append({"kernel": name, "b": b, "C": C, "n_cand": n_cand,
                     "cpu_s": times[name],
                     "speedup_vs_chain": (speedup if name == "adc_rank"
                                          else None)})
    save_json("kernels", rows)
    return rows


if __name__ == "__main__":
    run()
