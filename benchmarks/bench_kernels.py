"""Kernel micro-benchmarks: the fused range_count + estimator-MLP paths.

On this CPU container we time the XLA:CPU jnp path (production fast path
off-TPU) and validate the Pallas kernel in interpret mode; the TPU roofline
numbers for the same shapes come from the dry-run (§Roofline)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, save_json
from repro.kernels import ops, ref
from repro.launch.roofline import HBM_BW, PEAK_FLOPS


def run() -> list:
    rng = np.random.default_rng(0)
    rows = []
    for (nq, nr, d, m) in ((1024, 8192, 300, 100), (2048, 16384, 128, 100)):
        q = rng.normal(size=(nq, d)).astype(np.float32)
        q /= np.linalg.norm(q, axis=1, keepdims=True)
        r = rng.normal(size=(nr, d)).astype(np.float32)
        r /= np.linalg.norm(r, axis=1, keepdims=True)
        eps = np.linspace(0.3, 1.2, m).astype(np.float32)
        out, _ = None, None
        # warm + time the jnp path
        ops.range_count_hist(q, r, eps, metric="cosine", backend="jnp")
        t0 = time.perf_counter()
        out = ops.range_count_hist(q, r, eps, metric="cosine", backend="jnp")
        np.asarray(out)
        dt = time.perf_counter() - t0
        flops = 2.0 * nq * nr * d + nq * nr * m
        # validate pallas (interpret) on a slice
        got = np.asarray(ops.range_count_hist(q[:64], r[:512], eps,
                                              metric="cosine",
                                              backend="pallas", block_q=32,
                                              block_r=128, eps_chunk=4))
        want = np.asarray(ref.range_count_hist(q[:64], r[:512], eps, "cosine"))
        assert (got == want).all()
        tpu_compute_s = flops / PEAK_FLOPS
        rows.append({"kernel": "range_count_hist", "nq": nq, "nr": nr,
                     "d": d, "m": m, "cpu_s": dt, "flops": flops,
                     "cpu_gflops": flops / dt / 1e9,
                     "tpu_roofline_s": tpu_compute_s})
        emit(f"kernel/range_count/{nq}x{nr}x{d}", dt * 1e6,
             f"gflops={flops/dt/1e9:.1f}")

    # estimator MLP
    widths = (512, 512, 256, 128)
    dims = (301,) + widths + (1,)
    params = [(rng.normal(size=(a, b)).astype(np.float32) * 0.05,
               np.zeros((1, b), np.float32))
              for a, b in zip(dims[:-1], dims[1:])]
    x = rng.normal(size=(8192, 301)).astype(np.float32)
    ops.mlp_forward(params, x, backend="jnp")
    t0 = time.perf_counter()
    np.asarray(ops.mlp_forward(params, x, backend="jnp"))
    dt = time.perf_counter() - t0
    flops = 2 * 8192 * sum(a * b for a, b in zip(dims[:-1], dims[1:]))
    got = np.asarray(ops.mlp_forward(params, x[:128], backend="pallas",
                                     block_n=64))
    want = np.asarray(ref.mlp_forward(params, x[:128]))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    rows.append({"kernel": "fused_mlp", "n": 8192, "cpu_s": dt,
                 "flops": flops, "cpu_gflops": flops / dt / 1e9})
    emit("kernel/fused_mlp/8192", dt * 1e6, f"gflops={flops/dt/1e9:.1f}")
    save_json("kernels", rows)
    return rows


if __name__ == "__main__":
    run()
