"""Serving gateway rows (DESIGN.md §14): coalesced micro-batched
throughput vs the single-stream serving baseline, at equal recall.

The §14 design bet is that many small tenant requests cost the engine
almost nothing extra when coalesced: every engine batch is padded to a
power-of-two bucket, so 16-row requests served ONE AT A TIME each pay a
full minimum-bucket sweep, while the gateway packs whole requests into
one bucket and scatters the counts back per request — bit-identical to
running each request alone, which is what makes the comparison
equal-recall by construction (it is verified on every rep).

Rows (fixed smoke n regardless of REPRO_BENCH_SCALE — the ratio is the
point): ``serve/single-stream`` (one `plan.run` per request),
``serve/gateway-coalesced`` (same requests, same route, coalesced),
``serve/gateway-cache-hot`` (the whole workload resubmitted: every row
answered from the eps-aware result cache). Derived columns carry the
speedup vs single-stream — the BENCH_<n> acceptance number is
coalesced >= 1x.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, save_json

N, DIM = 6000, 32
N_REQ, REQ_ROWS = 24, 16     # 24 requests x 16 rows per measured rep
EPS = 0.5
WARM, REPS = 1, 3


def _unit(rng, n):
    x = rng.normal(size=(n, DIM)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def run() -> list:
    from repro.core import JoinPlan
    from repro.serve import Gateway, TenantClass

    rng = np.random.default_rng(0)
    R = _unit(rng, N)
    # distinct request sets per rep so the gateway's cold pass is
    # genuinely cold (no within-measurement cache hits)
    reqsets = [[_unit(rng, REQ_ROWS) for _ in range(N_REQ)]
               for _ in range(WARM + REPS)]
    nq = N_REQ * REQ_ROWS

    plan = (JoinPlan(R, "cosine").search("naive").verify("exact")
            .on(backend="jnp").build())
    gw = Gateway(R, [TenantClass("t", eps=EPS)], backend="jnp")

    def time_single(reqs) -> float:
        t0 = time.perf_counter()
        out = [np.asarray(plan.run(q, EPS).counts) for q in reqs]
        return time.perf_counter() - t0, out

    def time_gateway(reqs) -> float:
        t0 = time.perf_counter()
        tickets = [gw.submit("t", q) for q in reqs]
        gw.flush()
        return time.perf_counter() - t0, [t.counts for t in tickets]

    single_us, gw_us = [], []
    for i, reqs in enumerate(reqsets):
        t_s, want = time_single(reqs)
        t_g, got = time_gateway(reqs)
        for w, g in zip(want, got):       # equal recall, every rep
            np.testing.assert_array_equal(g, w)
        if i >= WARM:
            single_us.append(t_s / nq * 1e6)
            gw_us.append(t_g / nq * 1e6)

    base = float(np.median(single_us))
    coal = float(np.median(gw_us))
    rep = gw.report()["tenants"]["t"]["metrics"]

    # cache-hot: the last rep's workload verbatim — every row hits
    t0 = time.perf_counter()
    for q in reqsets[-1]:
        gw.join("t", q)
    hot = (time.perf_counter() - t0) / nq * 1e6
    hits = gw.report()["tenants"]["t"]["metrics"]["cache_hit_queries"]

    rows = []
    emit("serve/single-stream", base, "speedup_vs_single=1.00x")
    rows.append({"name": "serve/single-stream", "us_per_query": base,
                 "speedup_vs_single": 1.0, "n_requests": N_REQ,
                 "rows_per_request": REQ_ROWS})
    emit("serve/gateway-coalesced", coal,
         f"speedup_vs_single={base / coal:.2f}x "
         f"coalesced_batches={rep['coalesced_batches']}")
    rows.append({"name": "serve/gateway-coalesced", "us_per_query": coal,
                 "speedup_vs_single": base / coal,
                 "batches": rep["batches"],
                 "coalesced_batches": rep["coalesced_batches"],
                 "coalesced_requests": rep["coalesced_requests"]})
    emit("serve/gateway-cache-hot", hot,
         f"speedup_vs_single={base / hot:.2f}x cache_hit_queries={hits}")
    rows.append({"name": "serve/gateway-cache-hot", "us_per_query": hot,
                 "speedup_vs_single": base / hot,
                 "cache_hit_queries": int(hits)})
    save_json("serve_gateway", rows)
    return rows
