"""Table IV: ATCS ("auto") vs uniform ("fixed") training-eps selection,
across estimators and datasets; MAE/MSE on random and uniform testing eps."""
from __future__ import annotations

import functools

import numpy as np

from benchmarks.common import EPOCHS, emit, get_data, save_json
from repro.core import JoinEngine, atcs
from repro.data.groundtruth import cardinality_table, eps_grid_for_metric
from repro.models import make_estimator

DATASETS = ("glove", "word2vec", "gist", "nuswide")
MODELS = ("nn", "rmi", "selnet", "linear")
S_SAMPLES = 6
M_GRID = 100


def _eval(est, S, grid, sub, idx):
    X = np.concatenate([S, grid[idx]], axis=1)
    y = np.take_along_axis(sub, idx, axis=1)[:, 0]
    pred = est.predict(X)
    return float(np.mean(np.abs(pred - y))), float(np.mean((pred - y) ** 2))


def run(datasets=DATASETS, models=MODELS) -> list:
    rows = []
    for ds in datasets:
        R, S, spec = get_data(ds)
        grid = eps_grid_for_metric(spec.metric, M_GRID)
        # one lazily-built engine serves both ground-truth sweeps over the
        # same R — padding + device upload happen at most once, and not at
        # all when both tables come back from the disk cache
        eng = functools.cache(
            lambda: JoinEngine(R, spec.metric, backend="jnp"))
        table = cardinality_table(R, R, grid, spec.metric, backend="jnp",
                                  exclude_self=True, engine=eng,
                                  cache_key=("bench-atcs-R", ds, len(R)))
        sub = cardinality_table(S, R, grid, spec.metric, backend="jnp",
                                engine=eng,
                                cache_key=("bench-atcs-S", ds, len(S)))
        rng = np.random.default_rng(1)
        rand_idx = rng.integers(0, M_GRID, size=(len(S), 1))
        unif_idx = np.linspace(0, M_GRID - 1, 7).round().astype(np.int64)
        unif_idx = np.tile(unif_idx[None, :1], (len(S), 1))  # one uniform col

        for model in models:
            for strat, select in (("fixed", atcs.uniform_select),
                                  ("auto", atcs.atcs_select)):
                idx = select(table, S_SAMPLES, seed=0)
                X, y = atcs.build_training_tuples(R, grid, table, idx)
                est = make_estimator(model, X.shape[1], **(
                    {"epochs": EPOCHS} if model != "linear" else {}))
                import time
                t0 = time.perf_counter()
                est.fit(X, y)
                fit_s = time.perf_counter() - t0
                r_mae, r_mse = _eval(est, S, grid, sub, rand_idx)
                u_mae, u_mse = _eval(est, S, grid, sub, unif_idx)
                rows.append({"dataset": ds, "model": model, "strategy": strat,
                             "rand_mae": r_mae, "rand_mse": r_mse,
                             "unif_mae": u_mae, "unif_mse": u_mse,
                             "fit_s": fit_s})
                emit(f"atcs/{ds}/{model}/{strat}", fit_s * 1e6,
                     f"mae={r_mae:.3f}")
    save_json("table4_atcs", rows)
    # headline: per (dataset, model), did auto beat fixed?
    wins = sum(1 for i in range(0, len(rows), 2)
               if rows[i + 1]["rand_mae"] <= rows[i]["rand_mae"])
    emit("atcs/auto_wins", 0.0, f"{wins}/{len(rows)//2}")
    return rows


if __name__ == "__main__":
    run()
