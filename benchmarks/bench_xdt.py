"""Table V: XDT selection (mean vs FPR) x target computation (interpolated
vs exact): FPR/FNR of the filter + time to compute targets + the XDT."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, get_filter, save_json, true_counts
from repro.core.xdt import filter_rates

DATASETS = ("glove", "nuswide")
EPS_LIST = (0.4, 0.45, 0.5)


def run(datasets=DATASETS) -> list:
    rows = []
    for ds in datasets:
        filt, R, S, spec = get_filter(ds)
        for eps in EPS_LIST:
            truth = true_counts(R, S, eps, spec.metric)
            filt._train_predictions(eps)   # cache estimator preds: the timed
                                           # section isolates TARGET computation
            for target_mode in ("interp", "exact"):
                filt.cfg.target_mode = target_mode
                for mode in ("mean", "fpr"):
                    filt._xdt_cache.clear()
                    t0 = time.perf_counter()
                    thr = filt.xdt(eps, 0, mode=mode)
                    t_target = time.perf_counter() - t0
                    pos, _ = filt.query(S, eps, 0, mode=mode)
                    r = filter_rates(pos, truth, 0)
                    rows.append({"dataset": ds, "eps": eps, "mode": mode,
                                 "targets": target_mode, "xdt": thr,
                                 "fpr": r["fpr"], "fnr": r["fnr"],
                                 "t_target_s": t_target})
                    emit(f"xdt/{ds}/eps{eps}/{mode}/{target_mode}",
                         t_target * 1e6,
                         f"fpr={r['fpr']:.3f};fnr={r['fnr']:.3f}")
            filt.cfg.target_mode = "interp"
    save_json("table5_xdt", rows)

    # headline claims from the paper:
    #  (1) interp ~ exact quality, (2) interp targets are much faster,
    #  (3) fpr-mode XDT > mean-mode XDT
    by = {(r["dataset"], r["eps"], r["mode"], r["targets"]): r for r in rows}
    speedups = []
    for ds in datasets:
        for eps in EPS_LIST:
            a = by[(ds, eps, "fpr", "interp")]
            b = by[(ds, eps, "fpr", "exact")]
            if a["t_target_s"] > 0:
                speedups.append(b["t_target_s"] / max(a["t_target_s"], 1e-9))
    emit("xdt/interp_speedup_median", 0.0,
         f"{np.median(speedups):.0f}x")
    return rows


if __name__ == "__main__":
    run()
