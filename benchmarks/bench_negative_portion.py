"""Table III: portion of negative queries per dataset per eps."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, get_data, save_json, true_counts

PAPER = {  # (eps=0.4, 0.45, 0.5) from Table III
    "fasttext": (0.110, 0.044, 0.012), "glove": (0.867, 0.785, 0.664),
    "word2vec": (0.288, 0.168, 0.080), "gist": (0.844, 0.394, 0.103),
    "sift": (0.558, 0.349, 0.153), "nuswide": (0.974, 0.965, 0.954),
}


def run() -> list:
    rows = []
    for name, paper in PAPER.items():
        R, S, spec = get_data(name)
        ours = []
        for eps in (0.4, 0.45, 0.5):
            t = true_counts(R, S, eps, spec.metric)
            ours.append(float((t == 0).mean()))
        rows.append({"dataset": name, "ours": ours, "paper": list(paper)})
        emit(f"neg_portion/{name}", 0.0,
             "|".join(f"{o:.3f}" for o in ours))
    save_json("table3_negative_portion", rows)
    return rows


if __name__ == "__main__":
    run()
