"""Benchmark runner — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV lines; details land in
experiments/bench/*.json.

  PYTHONPATH=src python -m benchmarks.run [--only tab3,tab4,...]
  REPRO_BENCH_SCALE=small|medium|full  (default small)

``--snapshot`` additionally writes a top-level ``BENCH_<n>.json``
(suite -> {row name -> us_per_call}, next free n) so the perf trajectory
is tracked across PRs; ``--snapshot-out PATH`` pins an explicit path
instead (the CI smoke run writes to a temp file).

``--compare BENCH_<n>.json`` diffs this run against a committed
snapshot: per-row deltas for every row present in BOTH (rows only on
one side are listed as informational), and a non-zero exit if any
previously-present row regressed by more than ``REGRESSION_PCT`` —
the CI perf gate (scripts/ci.sh runs the kernels smoke against the
latest committed snapshot).
"""
from __future__ import annotations

import argparse
import io
import json
import re
import sys
import time
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent

#: --compare fails on any common row slower than baseline by more than
#: this (smoke-scale timings are noisy; 25% is well past jitter for the
#: kernel rows the CI gate compares)
REGRESSION_PCT = 25.0

#: rows whose baseline AND current timings are both under this floor are
#: exempt from the regression gate: at single-digit microseconds per call
#: (the ring/* rows sit at ~9 us) a >25% delta is scheduler jitter, not a
#: regression — they still print, flagged informational
MIN_GATE_US = 50.0


class _Tee(io.TextIOBase):
    """stdout tee: forward everything, keep a copy for CSV parsing."""

    def __init__(self, sink):
        self.sink = sink
        self.parts: list[str] = []

    def write(self, s: str) -> int:
        self.parts.append(s)
        return self.sink.write(s)

    def flush(self) -> None:
        self.sink.flush()

    def text(self) -> str:
        return "".join(self.parts)


def parse_rows(text: str) -> dict[str, float]:
    """{row name: us_per_call} from the emitted CSV lines (non-CSV lines —
    headers, comments, tracebacks — are ignored)."""
    rows: dict[str, float] = {}
    for line in text.splitlines():
        parts = line.split(",")
        if len(parts) < 2 or parts[0].startswith("#") or not parts[0]:
            continue
        try:
            rows[parts[0]] = float(parts[1])
        except ValueError:
            continue
    return rows


def compare_snapshots(baseline: dict, current: dict[str, dict[str, float]],
                      *, threshold_pct: float = REGRESSION_PCT,
                      min_gate_us: float = MIN_GATE_US,
                      out=None) -> list[str]:
    """Diff `current` (suite -> {row: us}) against a loaded `baseline`
    snapshot payload.  Prints one line per common row (old, new, delta%)
    and informational lines for rows present on only one side; returns
    the rows regressed past `threshold_pct` (empty == gate passes).
    Rows under `min_gate_us` on both sides are jitter-exempt: printed
    and flagged, never returned as regressions."""
    out = sys.stdout if out is None else out
    base_suites = baseline.get("suites", baseline)
    regressed: list[str] = []
    for suite in sorted(set(base_suites) & set(current)):
        for row in sorted(set(base_suites[suite]) & set(current[suite])):
            old, new = base_suites[suite][row], current[suite][row]
            delta = (new - old) / old * 100.0 if old else float("inf")
            flag = ""
            if delta > threshold_pct:
                if old < min_gate_us and new < min_gate_us:
                    flag = (f"  jitter-exempt (< {min_gate_us:.0f} us "
                            "floor)")
                else:
                    regressed.append(row)
                    flag = f"  REGRESSION (> {threshold_pct:.0f}%)"
            print(f"# compare {row}: {old:.1f} -> {new:.1f} us "
                  f"({delta:+.1f}%){flag}", file=out, flush=True)
        for row in sorted(set(base_suites[suite]) - set(current[suite])):
            print(f"# compare {row}: in baseline only (not run)", file=out)
        for row in sorted(set(current[suite]) - set(base_suites[suite])):
            print(f"# compare {row}: new row ({current[suite][row]:.1f} us)",
                  file=out)
    for suite in sorted(set(current) - set(base_suites)):
        print(f"# compare suite {suite}: not in baseline", file=out)
    return regressed


def next_snapshot_path(root: Path) -> Path:
    """BENCH_<n>.json with the next n after the largest existing one."""
    ns = [int(m.group(1)) for p in root.glob("BENCH_*.json")
          if (m := re.fullmatch(r"BENCH_(\d+)\.json", p.name))]
    return root / f"BENCH_{max(ns, default=0) + 1}.json"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all",
                    help="comma list: tab3,tab4,tab5,tab6,fig2,fig3,fig45,"
                         "kernels,perf,xjoin,ring,delta,serve,planner")
    ap.add_argument("--snapshot", action="store_true",
                    help="write suite->us_per_call to the next free "
                         "top-level BENCH_<n>.json (perf trajectory "
                         "across PRs)")
    ap.add_argument("--snapshot-out", default=None,
                    help="explicit snapshot path (implies --snapshot)")
    ap.add_argument("--compare", default=None, metavar="BENCH_N.json",
                    help="diff this run's rows against a committed "
                         "snapshot; exit 1 on any common row regressing "
                         f"by more than {REGRESSION_PCT:.0f}%%")
    args = ap.parse_args()
    want = set(args.only.split(",")) if args.only != "all" else None
    snapshot = args.snapshot or args.snapshot_out is not None
    capture = snapshot or args.compare is not None

    from benchmarks import (bench_atcs, bench_delta, bench_e2e,
                            bench_filter, bench_generalization,
                            bench_kernels, bench_negative_portion,
                            bench_perf_xjoin, bench_planner, bench_probe,
                            bench_ring, bench_serve, bench_tradeoff,
                            bench_xdt)
    from benchmarks.common import SCALE
    suites = [
        ("tab3", "Table III negative-query portions", bench_negative_portion.run),
        ("tab4", "Table IV ATCS vs fixed eps selection", bench_atcs.run),
        ("tab5", "Table V XDT selection x target mode", bench_xdt.run),
        ("tab6", "Table VI Xling vs LSBF effectiveness", bench_filter.run),
        ("fig2", "Figure 2 end-to-end join", bench_e2e.run),
        ("fig3", "Figure 3 speed-quality trade-off", bench_tradeoff.run),
        ("fig45", "Figures 4/5 generalization", bench_generalization.run),
        ("kernels", "Kernel micro-benchmarks", bench_kernels.run),
        ("perf", "Perf: XJoin paper-faithful vs optimized", bench_perf_xjoin.run),
        ("xjoin", "XJoin probe placement: host vs device, per topology",
         bench_probe.run),
        ("ring", "Ring sweep schedule: overlapped vs serial, per r_shards",
         bench_ring.run),
        ("delta", "Dynamic R: query cost vs delta occupancy",
         bench_delta.run),
        ("serve", "Serving gateway: coalesced vs single-stream",
         bench_serve.run),
        ("planner", "Cost-based auto-planner: planned vs grid vs defaults",
         bench_planner.run),
    ]
    print("name,us_per_call,derived")
    captured: dict[str, dict[str, float]] = {}
    for key, title, fn in suites:
        if want is not None and key not in want:
            continue
        print(f"# === {key}: {title} ===", flush=True)
        tee = _Tee(sys.stdout) if capture else None
        t0 = time.time()
        try:
            if tee is not None:
                old, sys.stdout = sys.stdout, tee
                try:
                    fn()
                finally:
                    sys.stdout = old
                captured[key] = parse_rows(tee.text())
            else:
                fn()
            print(f"# {key} done in {time.time()-t0:.1f}s", flush=True)
        except Exception as e:
            import traceback
            traceback.print_exc()
            print(f"# {key} FAILED: {e}", file=sys.stderr, flush=True)

    if snapshot:
        path = (Path(args.snapshot_out) if args.snapshot_out
                else next_snapshot_path(REPO_ROOT))
        payload = {"scale": SCALE, "suites": captured}
        path.write_text(json.dumps(payload, indent=1, sort_keys=True) + "\n")
        print(f"# snapshot -> {path}", flush=True)

    if args.compare is not None:
        baseline = json.loads(Path(args.compare).read_text())
        print(f"# compare vs {args.compare} "
              f"(baseline scale={baseline.get('scale', '?')})", flush=True)
        regressed = compare_snapshots(baseline, captured)
        if regressed:
            print(f"# compare FAILED: {len(regressed)} row(s) regressed "
                  f"> {REGRESSION_PCT:.0f}%: {', '.join(regressed)}",
                  file=sys.stderr, flush=True)
            sys.exit(1)
        print("# compare OK", flush=True)


if __name__ == '__main__':
    main()
