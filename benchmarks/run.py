"""Benchmark runner — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV lines; details land in
experiments/bench/*.json.

  PYTHONPATH=src python -m benchmarks.run [--only tab3,tab4,...]
  REPRO_BENCH_SCALE=small|medium|full  (default small)
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all",
                    help="comma list: tab3,tab4,tab5,tab6,fig2,fig3,fig45,kernels,perf")
    args = ap.parse_args()
    want = set(args.only.split(",")) if args.only != "all" else None

    from benchmarks import (bench_atcs, bench_e2e, bench_filter,
                            bench_generalization, bench_kernels,
                            bench_negative_portion, bench_perf_xjoin,
                            bench_tradeoff, bench_xdt)
    suites = [
        ("tab3", "Table III negative-query portions", bench_negative_portion.run),
        ("tab4", "Table IV ATCS vs fixed eps selection", bench_atcs.run),
        ("tab5", "Table V XDT selection x target mode", bench_xdt.run),
        ("tab6", "Table VI Xling vs LSBF effectiveness", bench_filter.run),
        ("fig2", "Figure 2 end-to-end join", bench_e2e.run),
        ("fig3", "Figure 3 speed-quality trade-off", bench_tradeoff.run),
        ("fig45", "Figures 4/5 generalization", bench_generalization.run),
        ("kernels", "Kernel micro-benchmarks", bench_kernels.run),
        ("perf", "Perf: XJoin paper-faithful vs optimized", bench_perf_xjoin.run),
    ]
    print("name,us_per_call,derived")
    for key, title, fn in suites:
        if want is not None and key not in want:
            continue
        print(f"# === {key}: {title} ===", flush=True)
        t0 = time.time()
        try:
            fn()
            print(f"# {key} done in {time.time()-t0:.1f}s", flush=True)
        except Exception as e:
            import traceback
            traceback.print_exc()
            print(f"# {key} FAILED: {e}", file=sys.stderr, flush=True)


if __name__ == '__main__':
    main()
