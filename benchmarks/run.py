"""Benchmark runner — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV lines; details land in
experiments/bench/*.json.

  PYTHONPATH=src python -m benchmarks.run [--only tab3,tab4,...]
  REPRO_BENCH_SCALE=small|medium|full  (default small)

``--snapshot`` additionally writes a top-level ``BENCH_<n>.json``
(suite -> {row name -> us_per_call}, next free n) so the perf trajectory
is tracked across PRs; ``--snapshot-out PATH`` pins an explicit path
instead (the CI smoke run writes to a temp file).
"""
from __future__ import annotations

import argparse
import io
import json
import re
import sys
import time
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent


class _Tee(io.TextIOBase):
    """stdout tee: forward everything, keep a copy for CSV parsing."""

    def __init__(self, sink):
        self.sink = sink
        self.parts: list[str] = []

    def write(self, s: str) -> int:
        self.parts.append(s)
        return self.sink.write(s)

    def flush(self) -> None:
        self.sink.flush()

    def text(self) -> str:
        return "".join(self.parts)


def parse_rows(text: str) -> dict[str, float]:
    """{row name: us_per_call} from the emitted CSV lines (non-CSV lines —
    headers, comments, tracebacks — are ignored)."""
    rows: dict[str, float] = {}
    for line in text.splitlines():
        parts = line.split(",")
        if len(parts) < 2 or parts[0].startswith("#") or not parts[0]:
            continue
        try:
            rows[parts[0]] = float(parts[1])
        except ValueError:
            continue
    return rows


def next_snapshot_path(root: Path) -> Path:
    """BENCH_<n>.json with the next n after the largest existing one."""
    ns = [int(m.group(1)) for p in root.glob("BENCH_*.json")
          if (m := re.fullmatch(r"BENCH_(\d+)\.json", p.name))]
    return root / f"BENCH_{max(ns, default=0) + 1}.json"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all",
                    help="comma list: tab3,tab4,tab5,tab6,fig2,fig3,fig45,"
                         "kernels,perf,xjoin,delta,serve")
    ap.add_argument("--snapshot", action="store_true",
                    help="write suite->us_per_call to the next free "
                         "top-level BENCH_<n>.json (perf trajectory "
                         "across PRs)")
    ap.add_argument("--snapshot-out", default=None,
                    help="explicit snapshot path (implies --snapshot)")
    args = ap.parse_args()
    want = set(args.only.split(",")) if args.only != "all" else None
    snapshot = args.snapshot or args.snapshot_out is not None

    from benchmarks import (bench_atcs, bench_delta, bench_e2e,
                            bench_filter, bench_generalization,
                            bench_kernels, bench_negative_portion,
                            bench_perf_xjoin, bench_probe, bench_serve,
                            bench_tradeoff, bench_xdt)
    from benchmarks.common import SCALE
    suites = [
        ("tab3", "Table III negative-query portions", bench_negative_portion.run),
        ("tab4", "Table IV ATCS vs fixed eps selection", bench_atcs.run),
        ("tab5", "Table V XDT selection x target mode", bench_xdt.run),
        ("tab6", "Table VI Xling vs LSBF effectiveness", bench_filter.run),
        ("fig2", "Figure 2 end-to-end join", bench_e2e.run),
        ("fig3", "Figure 3 speed-quality trade-off", bench_tradeoff.run),
        ("fig45", "Figures 4/5 generalization", bench_generalization.run),
        ("kernels", "Kernel micro-benchmarks", bench_kernels.run),
        ("perf", "Perf: XJoin paper-faithful vs optimized", bench_perf_xjoin.run),
        ("xjoin", "XJoin probe placement: host vs device, per topology",
         bench_probe.run),
        ("delta", "Dynamic R: query cost vs delta occupancy",
         bench_delta.run),
        ("serve", "Serving gateway: coalesced vs single-stream",
         bench_serve.run),
    ]
    print("name,us_per_call,derived")
    captured: dict[str, dict[str, float]] = {}
    for key, title, fn in suites:
        if want is not None and key not in want:
            continue
        print(f"# === {key}: {title} ===", flush=True)
        tee = _Tee(sys.stdout) if snapshot else None
        t0 = time.time()
        try:
            if tee is not None:
                old, sys.stdout = sys.stdout, tee
                try:
                    fn()
                finally:
                    sys.stdout = old
                captured[key] = parse_rows(tee.text())
            else:
                fn()
            print(f"# {key} done in {time.time()-t0:.1f}s", flush=True)
        except Exception as e:
            import traceback
            traceback.print_exc()
            print(f"# {key} FAILED: {e}", file=sys.stderr, flush=True)

    if snapshot:
        path = (Path(args.snapshot_out) if args.snapshot_out
                else next_snapshot_path(REPO_ROOT))
        payload = {"scale": SCALE, "suites": captured}
        path.write_text(json.dumps(payload, indent=1, sort_keys=True) + "\n")
        print(f"# snapshot -> {path}", flush=True)


if __name__ == '__main__':
    main()
