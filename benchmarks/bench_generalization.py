"""Figures 4/5: generalization — the filter trained on the first sample is
applied, WITHOUT retraining, to a disjoint second sample; we compare the
acceleration and recall loss Xling brings on both samples."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, get_data, get_filter, save_json
from repro.core import JoinPlan, make_join
from repro.kernels import ops

DATASET = "glove"
EPS = 0.45


def _run_pair(base_fn, enh_fn, truth):
    base_fn(); enh_fn()   # warm both paths (jit shapes)
    t0 = time.perf_counter(); c0 = np.asarray(base_fn()); t_base = time.perf_counter() - t0
    t0 = time.perf_counter(); c1 = np.asarray(enh_fn()); t_enh = time.perf_counter() - t0
    r0 = float(np.minimum(c0, truth).sum() / max(truth.sum(), 1))
    r1 = float(np.minimum(c1, truth).sum() / max(truth.sum(), 1))
    return {"t_base": t_base, "t_xling": t_enh, "recall_base": r0,
            "recall_xling": r1,
            "recall_loss_pct": 100 * (r0 - r1) / max(r0, 1e-9)}


def run(dataset=DATASET) -> list:
    from benchmarks.common import N
    n = max(N, 20000)
    filt, R, S1, spec = get_filter(dataset, n=n)
    # second disjoint sample, same distribution; R stays the indexed set
    _, S2, _ = get_data(dataset, n=n, sample=2)
    rows = []
    for tag, S in (("1st", S1), ("2nd", S2)):
        truth = np.asarray(ops.range_count(S, R, EPS, metric=spec.metric,
                                           backend="jnp"))
        naive = make_join("naive", R, spec.metric, backend="jnp")
        naive.query_counts(S[:32], EPS)
        lsh = make_join("lsh", R, spec.metric, k=14, l=10, n_probes=4, W=2.5)
        km = make_join("kmeanstree", R, spec.metric, branching=3, rho=0.02)
        for method, base in (("naive", naive), ("lsh", lsh), ("kmeanstree", km)):
            tau, xdt = (50, "fpr") if method == "naive" else (0, "mean")
            enh = (JoinPlan(R, spec.metric).filter(filt, tau=tau, xdt=xdt)
                   .search(base)
                   .on(backend="jnp", engine=naive.engine)
                   .build())
            r = _run_pair(lambda b=base: b.query_counts(S, EPS),
                          lambda e=enh: e.run(S, EPS).counts, truth)
            rows.append({"sample": tag, "method": method, **r})
            emit(f"gen/{tag}/{method}", r["t_xling"] * 1e6 / len(S),
                 f"speedup={r['t_base']/max(r['t_xling'],1e-9):.2f}x;"
                 f"recall_loss={r['recall_loss_pct']:.1f}%")
    save_json("fig45_generalization", rows)
    return rows


if __name__ == "__main__":
    run()
