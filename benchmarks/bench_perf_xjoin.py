"""§Perf — XJoin: paper-faithful baseline vs beyond-paper optimized.

Three implementations of the same join (glove, eps=0.45, tau=50):
  A. naive          — no filter (the pre-paper baseline).
  B. xjoin-masked   — paper-faithful semantics mechanically ported to
                      accelerator-style static shapes: the filter runs, but
                      negative queries are only MASKED (every query is still
                      ranged). This is what a direct port of the paper's
                      loop gives you on XLA: no actual work saved.
  C. xjoin-compacted— the TPU-native realization (DESIGN.md §3): positives
                      are compacted into power-of-two-bucketed blocks;
                      skipped queries cost nothing.
  D. xjoin-streamed — C served as batches through the asynchronous
                      double-buffered pipeline (DESIGN.md §5): batch k+1
                      dispatches while batch k's results transfer back;
                      compared against the same batches run synchronously.
Plus the verification-backend matrix (exact vs lsh vs ivfpq — time and
recall vs the exact oracle), a `<method>-Xling` plugin matrix (the same
filter composed with a NON-naive base through the `JoinPlan` candidate
route, DESIGN.md §9), and a block-size sweep of the verification kernel
(the CPU analogue of the BlockSpec tile sweep on TPU).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, get_filter, save_json, true_counts
from repro.core import JoinPlan, make_join
from repro.kernels import ops

EPS = 0.45
TAU = 50


def run(n: int = 20000) -> dict:
    filt, R, S, spec = get_filter("glove", n=n)
    truth = true_counts(R, S, EPS, spec.metric)
    naive = make_join("naive", R, spec.metric, backend="jnp")

    # ---- A: naive -----------------------------------------------------------
    naive.query_counts(S, EPS)
    t0 = time.perf_counter()
    c_naive = naive.query_counts(S, EPS)
    t_naive = time.perf_counter() - t0

    # ---- B: masked (paper-faithful port) ------------------------------------
    pos, _ = filt.query(S, EPS, TAU, mode="fpr")       # warm filter
    def masked():
        p, _ = filt.query(S, EPS, TAU, mode="fpr")
        counts = naive.query_counts(S, EPS)            # all queries ranged
        return np.where(p, counts, 0)
    masked()
    t0 = time.perf_counter()
    c_masked = masked()
    t_masked = time.perf_counter() - t0

    # ---- C: compacted, fused on-device via the plan (beyond-paper) ----------
    xplan = (JoinPlan(R, spec.metric)
             .filter(filt, tau=TAU, xdt="fpr")
             .search(naive).on(engine=naive.engine, backend="jnp").build())
    xplan.run(S, EPS)
    t0 = time.perf_counter()
    res = xplan.run(S, EPS)
    t_comp = time.perf_counter() - t0

    def rec(c):
        return float(np.minimum(c, truth).sum() / max(truth.sum(), 1))

    # ---- D: async double-buffered stream vs synchronous batches -------------
    bs = 512
    batches = [S[i:i + bs] for i in range(0, len(S), bs)]
    list(xplan.stream(batches, EPS, depth=2))       # warm all bucket shapes
    t0 = time.perf_counter()
    sync_res = [xplan.run(b, EPS) for b in batches]  # per-batch synchronous
    t_sync = time.perf_counter() - t0
    t0 = time.perf_counter()
    stream_res = list(xplan.stream(batches, EPS, depth=2))
    t_stream = time.perf_counter() - t0
    c_stream = np.concatenate([r.counts for r in stream_res])
    assert np.array_equal(
        c_stream, np.concatenate([r.counts for r in sync_res]))

    # ---- verification-backend matrix (DESIGN.md §5) -------------------------
    verify_rows = {}
    for vb in ("lsh", "ivfpq"):
        xp_v = (JoinPlan(R, spec.metric)
                .filter(filt, tau=TAU, xdt="fpr")
                .search(naive).verify(vb)
                .on(engine=naive.engine, backend="jnp").build())
        xp_v.run(S, EPS)                            # warm
        t0 = time.perf_counter()
        res_v = xp_v.run(S, EPS)
        t_v = time.perf_counter() - t0
        verify_rows[vb] = {"t": t_v, "recall": rec(res_v.counts),
                           "speedup_vs_exact": t_comp / max(t_v, 1e-9)}
        emit(f"perf_xjoin/verify_{vb}", t_v * 1e6 / len(S),
             f"recall={verify_rows[vb]['recall']:.3f}")

    # ---- <method>-Xling plugin matrix (DESIGN.md §9) ------------------------
    # the SAME filter gating non-naive bases: positives route through the
    # base's candidates() + the engine's device candidate verification
    plugin_rows = {}
    for name, params in (("lsh", dict(k=14, l=10, n_probes=4,
                                      W=2.5 if spec.kind == "text" else 2.0)),
                         ("kmeanstree", dict(branching=3, rho=0.02))):
        base = make_join(name, R, spec.metric, **params)
        base.query_counts(S[:256], EPS)             # warm the base
        t0 = time.perf_counter()
        c_base = base.query_counts(S, EPS)
        t_base = time.perf_counter() - t0
        plug = (JoinPlan(R, spec.metric)
                .filter(filt, tau=0, xdt="mean")
                .search(base).on(backend="jnp", engine=naive.engine).build())
        plug.run(S, EPS)                            # warm
        t0 = time.perf_counter()
        res_p = plug.run(S, EPS)
        t_p = time.perf_counter() - t0
        plugin_rows[name] = {
            "t_base": t_base, "t_plugin": t_p,
            "recall_base": rec(np.asarray(c_base)),
            "recall_plugin": rec(res_p.counts),
            "searched_frac": res_p.n_searched / len(S),
            "speedup_vs_base": t_base / max(t_p, 1e-9),
            "plan": plug.describe(),
        }
        emit(f"perf_xjoin/plugin_{name}", t_p * 1e6 / len(S),
             f"recall={plugin_rows[name]['recall_plugin']:.3f};"
             f"speedup={plugin_rows[name]['speedup_vs_base']:.2f}x")

    out = {
        "n_queries": len(S), "searched_frac": res.n_searched / len(S),
        "naive": {"t": t_naive, "recall": rec(c_naive)},
        "masked": {"t": t_masked, "recall": rec(c_masked)},
        "compacted": {"t": t_comp, "recall": rec(res.counts)},
        "streamed": {"t": t_stream, "t_sync_batches": t_sync,
                     "recall": rec(c_stream), "batch_size": bs,
                     "speedup_vs_sync_batches": t_sync / max(t_stream, 1e-9)},
        "verify_backends": verify_rows,
        "plugin_matrix": plugin_rows,
        "speedup_masked": t_naive / t_masked,
        "speedup_compacted": t_naive / t_comp,
    }
    emit("perf_xjoin/naive", t_naive * 1e6 / len(S), f"recall={rec(c_naive):.3f}")
    emit("perf_xjoin/masked", t_masked * 1e6 / len(S),
         f"recall={rec(c_masked):.3f};speedup={out['speedup_masked']:.2f}x")
    emit("perf_xjoin/compacted", t_comp * 1e6 / len(S),
         f"recall={rec(res.counts):.3f};speedup={out['speedup_compacted']:.2f}x")
    emit("perf_xjoin/streamed", t_stream * 1e6 / len(S),
         f"speedup_vs_sync={out['streamed']['speedup_vs_sync_batches']:.2f}x")

    # ---- verification-kernel block sweep ------------------------------------
    sweeps = []
    for block_r in (512, 2048, 8192):
        ops.range_count(S[:512], R, EPS, metric=spec.metric, backend="jnp",
                        block_r=block_r)
        t0 = time.perf_counter()
        ops.range_count(S[:512], R, EPS, metric=spec.metric, backend="jnp",
                        block_r=block_r)
        dt = time.perf_counter() - t0
        sweeps.append({"block_r": block_r, "t_s": dt})
        emit(f"perf_xjoin/block_r{block_r}", dt * 1e6 / 512, "")
    out["block_sweep"] = sweeps
    save_json("perf_xjoin", out)
    return out


if __name__ == "__main__":
    run()
