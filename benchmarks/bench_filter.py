"""Table VI: filter effectiveness — Xling (mean/FPR XDT) vs LSBF:
FPR, FNR, #Nbrs found by the gated join, #PPQ, #ANPQ."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, get_filter, save_json, true_counts
from repro.core.joins.lsbf import LSBF
from repro.core.xdt import filter_rates

DATASETS = ("fasttext", "word2vec", "sift", "nuswide")
EPS_LIST = (0.4, 0.45, 0.5)


def _stats(verdicts, truth):
    r = filter_rates(verdicts, truth, 0)
    n_ppq = int(verdicts.sum())
    n_nbrs = int(truth[verdicts].sum())     # neighbors found by gated search
    return {"fpr": r["fpr"], "fnr": r["fnr"], "n_nbrs": n_nbrs,
            "n_ppq": n_ppq, "anpq": n_nbrs / max(n_ppq, 1)}


def run(datasets=DATASETS) -> list:
    rows = []
    for ds in datasets:
        filt, R, S, spec = get_filter(ds)
        lsbf = LSBF(R, spec.metric, k=18, l=10, theta=0.7,
                    W=2.5 if spec.kind == "text" else 2.0)
        for eps in EPS_LIST:
            truth = true_counts(R, S, eps, spec.metric)
            entries = {
                "lsbf": lsbf.query(S),
                "xling_mean": filt.query(S, eps, 0, mode="mean")[0],
                "xling_fpr": filt.query(S, eps, 0, mode="fpr")[0],
            }
            for name, v in entries.items():
                st = _stats(v, truth)
                rows.append({"dataset": ds, "eps": eps, "filter": name, **st})
                emit(f"filter/{ds}/eps{eps}/{name}", 0.0,
                     f"fpr={st['fpr']:.3f};fnr={st['fnr']:.3f};anpq={st['anpq']:.1f}")
    save_json("table6_filter_effectiveness", rows)
    return rows


if __name__ == "__main__":
    run()
