"""Train a reduced LM arch with the full distributed runtime: sharded train
step, gradient compression, checkpointing, a simulated failure at step 60
and automatic restore — a few hundred steps on CPU.

    PYTHONPATH=src python examples/train_lm.py [arch] [steps]
"""
import sys, os, shutil
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config
from repro.runtime.loop import TrainLoopConfig, run_training

arch = sys.argv[1] if len(sys.argv) > 1 else "tinyllama_1_1b"
steps = int(sys.argv[2]) if len(sys.argv) > 2 else 200

ckpt = "/tmp/repro_example_ckpt"
shutil.rmtree(ckpt, ignore_errors=True)
cfg = get_config(arch, smoke=True)
print(f"training reduced {cfg.name} for {steps} steps "
      f"(failure injected at step 60)...")
hist = run_training(cfg, TrainLoopConfig(
    total_steps=steps, batch=8, seq=128, ckpt_dir=ckpt, ckpt_every=25,
    compression="int8", fail_at_step=min(60, steps - 1), log_every=25))
print(f"done: final loss {hist['final_loss']:.4f}, "
      f"restarts {hist['restarts']}, steps run {len(hist['loss'])}")
