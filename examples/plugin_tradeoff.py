"""Xling as a generic plugin: accelerate LSH and k-means-tree joins and
print the speed/quality trade-off (paper Fig. 3 in miniature).

Each `<method>-xling` row is one `JoinPlan`: the base method's
`candidates()` (the Searcher protocol, DESIGN.md §9) routes the filter's
predicted-positive queries through the engine's device-resident candidate
verification — the same machinery for every base, not just naive.

    PYTHONPATH=src python examples/plugin_tradeoff.py
"""
import sys, os, time
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
from benchmarks.common import get_filter
from repro.core import JoinPlan, make_join

# filter cost is O(1)/query while index probing is O(index): the plugin pays
# off from ~20k points up (disk-cached from the benchmark run)
EPS, N = 0.45, 20000
filt, R, S, spec = get_filter("glove", n=N)
naive = make_join("naive", R, spec.metric, backend="jnp")
truth = naive.query_counts(S, EPS)

print(f"{'method':24s} {'time ms':>9s} {'recall':>8s}")
for name, params in (("lsh", dict(k=14, l=10, n_probes=4, W=2.5)),
                     ("kmeanstree", dict(branching=3, rho=0.02))):
    base = make_join(name, R, spec.metric, **params)
    plan = (JoinPlan(R, spec.metric)
            .filter(filt, tau=0, xdt="mean")
            .search(base).on(backend="jnp").build())
    for tag, runner in ((name, lambda: base.query_counts(S, EPS)),
                        (f"{name}-xling",
                         lambda: plan.run(S, EPS).counts)):
        runner()  # warm
        t0 = time.time(); counts = np.asarray(runner()); dt = time.time() - t0
        rec = np.minimum(counts, truth).sum() / max(truth.sum(), 1)
        print(f"{tag:24s} {dt*1e3:9.1f} {rec:8.3f}")
