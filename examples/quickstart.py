"""Quickstart: declare an XJoin with JoinPlan, run it, compare vs naive.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os, time
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
from repro.core import JoinPlan, make_join
from repro.data import load_dataset

EPS, TAU, N = 0.45, 5, 8000

print(f"== loading glove-like corpus (n={N}) ==")
R, S, spec = load_dataset("glove", n=N)
print(f"R (indexed) = {R.shape}, S (queries) = {S.shape}, metric = {spec.metric}")

print("\n== building the plan (fits Xling; RMI takes minutes, NN here) ==")
t0 = time.time()
plan = (JoinPlan(R, spec.metric)
        .filter("xling", tau=TAU, xdt="fpr", estimator="nn", epochs=12)
        .search("naive")
        .on(backend="jnp", cache_key=("quickstart", N))
        .build())
print(f"offline build: {time.time()-t0:.1f}s "
      f"(ground-truth targets + ATCS + estimator training)")

naive = make_join("naive", R, spec.metric, backend="jnp")
naive.query_counts(S, EPS)                       # warm the jit
t0 = time.time(); truth = naive.query_counts(S, EPS); t_naive = time.time() - t0

plan.run(S, EPS)                                 # warm
res = plan.run(S, EPS)
print(f"\n== XJoin vs naive @ eps={EPS}, tau={TAU} ==")
print(f"negative-query portion: {(truth == 0).mean():.2%}")
print(f"queries searched:       {res.n_searched}/{res.n_queries} "
      f"({1 - res.n_searched/res.n_queries:.1%} skipped)")
print(f"naive:  {t_naive*1e3:7.1f} ms   recall 1.000")
print(f"xjoin:  {res.t_total*1e3:7.1f} ms   recall {res.recall_vs(truth):.3f} "
      f"  -> {t_naive/res.t_total:.2f}x speedup")
