"""End-to-end serving driver (the paper's production scenario): batched
similarity-join requests against an indexed corpus, gated by Xling.

    PYTHONPATH=src python examples/serve_join.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import serve

sys.argv = ["serve_join", "--dataset", "glove", "--n", "8000",
            "--eps", "0.45", "--tau", "5", "--batches", "6",
            "--batch-size", "256", "--epochs", "12"]
serve.main()
