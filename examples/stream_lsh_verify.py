"""Worked example: async double-buffered serving with an LSH verifier,
declared as one `JoinPlan` (DESIGN.md §9).

End-to-end walkthrough of the DESIGN.md §5 pipeline, in three acts:

  1. Declare + build the plan: `.filter("xling", ...)` fits the filter on
     the corpus R, `.search("naive")` makes the exact sweep the base,
     `.verify("lsh", ...)` builds the engine's LSH verifier index with
     tuned parameters, and `.build()` validates the whole combination and
     pins R on device once.
  2. Serve a query stream: `plan.stream(batches, eps, depth=2)` stages
     batch k+1's device programs while batch k's verification results
     transfer back — the bounded in-flight queue keeps at most `depth`
     committed batches outstanding and the generator drains as a flush
     barrier.
  3. Measure quality: per-batch skip rate (filter effectiveness) and
     recall of LSH verification against the engine's exact sweep.

    PYTHONPATH=src python examples/stream_lsh_verify.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import JoinPlan
from repro.data import load_dataset

EPS, TAU = 0.45, 5
BATCH = 256

# ---- 1. declare + build the plan ------------------------------------------
R, S, spec = load_dataset("glove", n=4000)
print(f"corpus R={R.shape}, queries S={S.shape}, metric={spec.metric}")

plan = (JoinPlan(R, spec.metric)
        .filter("xling", tau=TAU, xdt="fpr", fpr_tolerance=0.05,
                estimator="nn", epochs=8)
        .search("naive")
        .verify("lsh", k=14, l=12, n_probes=6)   # tuned verifier index
        .on(backend="jnp")
        .build())                                # validate + fit + pin R
print("plan:", plan.describe()["verify"])

# the engine's exact sweep doubles as the recall oracle
engine = plan.engine

# ---- 2. stream query batches through the async pipeline -------------------
batches = [S[i:i + BATCH] for i in range(0, len(S), BATCH)]
results = list(plan.stream(batches, EPS, depth=2))

# ---- 3. per-batch report: skip rate + recall vs the exact sweep -----------
total_true = total_found = 0
for b, res in enumerate(results):
    true = engine.range_count(batches[b], EPS)          # exact oracle
    found = np.minimum(res.counts, true).sum()
    total_true += true.sum()
    total_found += found
    recall = found / max(true.sum(), 1)
    print(f"batch {b}: queries={len(batches[b])} "
          f"searched={res.n_searched} "
          f"skipped={1 - res.n_searched / len(batches[b]):.2%} "
          f"recall={recall:.3f} "
          f"t_filter={res.t_filter * 1e3:.1f}ms "
          f"t_search={res.t_search * 1e3:.1f}ms")

print(f"stream recall vs exact sweep: "
      f"{total_found / max(total_true, 1):.3f} "
      f"({len(results)} batches, verify=lsh, depth=2)")
