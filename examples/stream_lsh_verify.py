"""Worked example: async double-buffered serving with an LSH verifier.

End-to-end walkthrough of the DESIGN.md §5 pipeline, in three acts:

  1. Build the filter + engine: an Xling filter is fitted on the corpus R,
     a `JoinEngine` pins R on device, and the engine's LSH verifier index
     is pre-built with tuned parameters via `engine.verifier("lsh", ...)`.
  2. Serve a query stream: `JoinEngine.stream(batches, eps, ...,
     verify="lsh", depth=2)` stages batch k+1's device programs while
     batch k's verification results transfer back — the bounded in-flight
     queue keeps at most `depth` committed batches outstanding and the
     generator drains as a flush barrier.
  3. Measure quality: per-batch skip rate (filter effectiveness) and
     recall of LSH verification against the engine's exact sweep.

    PYTHONPATH=src python examples/stream_lsh_verify.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import XlingConfig, XlingFilter
from repro.core.engine import JoinEngine
from repro.data import load_dataset

EPS, TAU = 0.45, 5
BATCH = 256

# ---- 1. corpus, filter, engine, verifier ----------------------------------
R, S, spec = load_dataset("glove", n=4000)
print(f"corpus R={R.shape}, queries S={S.shape}, metric={spec.metric}")

filt = XlingFilter(XlingConfig(estimator="nn", metric=spec.metric,
                               epochs=8, backend="jnp")).fit(R)
engine = JoinEngine(R, spec.metric, backend="jnp")

# pre-build the LSH verifier with tuned parameters (first call builds the
# index over the engine's R; later `verify="lsh"` calls reuse it)
engine.verifier("lsh", k=14, l=12, n_probes=6)

# the device inference fn + a threshold calibrated through that same fn
predict = filt.estimator.device_predict_fn()
threshold = filt.xdt(EPS, TAU, mode="fpr", fpr_tolerance=0.05,
                     predict=predict)

# ---- 2. stream query batches through the async pipeline -------------------
batches = [S[i:i + BATCH] for i in range(0, len(S), BATCH)]
results = list(engine.stream(batches, EPS, predict=predict,
                             threshold=threshold, verify="lsh", depth=2))

# ---- 3. per-batch report: skip rate + recall vs the exact sweep -----------
total_true = total_found = 0
for b, res in enumerate(results):
    true = engine.range_count(batches[b], EPS)          # exact oracle
    found = np.minimum(res.counts, true).sum()
    total_true += true.sum()
    total_found += found
    recall = found / max(true.sum(), 1)
    print(f"batch {b}: queries={len(batches[b])} "
          f"searched={res.n_searched} "
          f"skipped={1 - res.n_searched / len(batches[b]):.2%} "
          f"recall={recall:.3f} "
          f"t_filter={res.t_filter * 1e3:.1f}ms "
          f"t_search={res.t_search * 1e3:.1f}ms")

print(f"stream recall vs exact sweep: "
      f"{total_found / max(total_true, 1):.3f} "
      f"({len(results)} batches, verify=lsh, depth=2)")
