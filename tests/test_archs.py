"""Per-arch smoke tests (assignment: reduced config, one forward/train step
on CPU, output shapes + no NaNs) + prefill/decode consistency + flash
attention vs oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.archs import build_model
from repro.archs.frontends import make_batch
from repro.archs.layers import attention, chunked_attention, flash_attention
from repro.configs import ARCH_IDS, get_config


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.slow
def test_arch_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, "train", 2, 64)

    def loss_fn(p):
        loss, m = model.train_loss(p, batch)
        return loss
    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss))
    # sane CE at init: ~ln(vocab)
    assert abs(float(loss) - np.log(cfg.vocab)) < 1.5
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.slow
def test_arch_prefill_decode_consistency(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 48
    batch = make_batch(cfg, "train", B, S)
    toks = batch["tokens"]
    b_pre = dict(batch)
    b_pre["tokens"] = toks[:, :-1]
    _, cache = jax.jit(model.prefill)(params, b_pre)

    def grow(c, pad=16):
        def f(x):
            if x.ndim == 6 and x.shape[2] == 1 and cfg.window == 0:
                G, Bb, NS, Sc, K, D = x.shape
                z = jnp.zeros((G, Bb, 1, pad, K, D), x.dtype)
                return jnp.concatenate([x, z], axis=3)
            return x
        return jax.tree.map(f, c)

    cache = grow(cache)
    n_prefix = cfg.n_patches if cfg.frontend == "vision_stub" else 0
    pos = jnp.asarray(n_prefix + toks.shape[1] - 1, jnp.int32)
    logits_dec, _ = jax.jit(model.decode_step)(params, cache, toks[:, -1:], pos)
    logits_full, _ = jax.jit(model.prefill)(params, batch)
    rel = (float(jnp.max(jnp.abs(logits_dec - logits_full)))
           / (float(jnp.max(jnp.abs(logits_full))) + 1e-9))
    assert rel < 2e-2, rel


def test_arch_output_shapes():
    cfg = get_config("tinyllama_1_1b", smoke=True)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 3, 32
    batch = make_batch(cfg, "train", B, S)
    logits, cache = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (B, cfg.vocab)
    k = cache["b0"]["k"]
    assert k.shape[0] == cfg.n_layers and k.shape[1] == B


@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 9)])
@pytest.mark.slow
def test_flash_attention_grads_vs_oracle(causal, window):
    rng = np.random.default_rng(0)
    B, S, H, K, D, T = 2, 20, 6, 2, 8, 20
    q = jnp.asarray(rng.normal(size=(B, S, H, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, T, K, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, T, K, D)).astype(np.float32))

    def f_ref(q, k, v):
        return jnp.sum(jnp.tanh(chunked_attention(q, k, v, causal=causal,
                                                  window=window, chunk=5)))

    def f_new(q, k, v):
        return jnp.sum(jnp.tanh(attention(q, k, v, causal=causal,
                                          window=window, chunk=5)))

    np.testing.assert_allclose(float(f_ref(q, k, v)), float(f_new(q, k, v)),
                               rtol=1e-5)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    gn = jax.grad(f_new, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gr, gn):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-4, atol=5e-5)


@pytest.mark.slow
def test_mamba2_chunked_equals_sequential():
    from repro.archs import mamba2
    from repro.archs.spec import init_params
    d, N, hd = 32, 8, 8
    specs = mamba2.mamba2_specs(d, d_state=N, head_dim=hd, expand=2,
                                dtype=jnp.float32)
    p = init_params(jax.random.key(0), specs)
    B, S = 2, 16
    u = jax.random.normal(jax.random.key(1), (B, S, d)) * 0.3
    y_chunk, st = mamba2.mamba2_forward(p, u, d_state=N, head_dim=hd,
                                        chunk=4, with_state=True)
    # sequential decode from zero state must reproduce the chunked output
    d_inner = 2 * d
    cache = {"ssm": jnp.zeros((B, d_inner // hd, hd, N)),
             "conv": jnp.zeros((B, mamba2.CONV_K - 1, d_inner + 2 * N))}
    outs = []
    for t in range(S):
        y, cache = mamba2.mamba2_decode(p, u[:, t:t + 1], cache,
                                        d_state=N, head_dim=hd)
        outs.append(y)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               rtol=2e-4, atol=2e-5)
    # and the handed-off state matches the final sequential state
    np.testing.assert_allclose(np.asarray(st["ssm"]),
                               np.asarray(cache["ssm"]), rtol=2e-4, atol=2e-5)


@pytest.mark.slow
def test_moe_routes_and_mixes():
    from repro.archs import moe
    from repro.archs.spec import init_params
    d, f, E = 16, 32, 4
    specs = moe.moe_specs(d, f, E, jnp.float32)
    p = init_params(jax.random.key(0), specs)
    x = jax.random.normal(jax.random.key(1), (2, 8, d))
    y = moe.moe_apply(p, x, top_k=2, capacity_factor=8.0, group_size=16)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    # MoE must actually change the input (residual + expert outputs)
    assert float(jnp.max(jnp.abs(y - x))) > 1e-6
