"""Estimator registry: every model fits a learnable cardinality surface and
predicts with sane error; SelNet stays monotone in eps by construction."""
import numpy as np
import pytest

from repro.models import ESTIMATORS, make_estimator


def _toy_problem(n=600, d=8, seed=0):
    """Synthetic CR problem: cardinality grows smoothly with eps and depends
    on the point's first coordinate (denser region near +1)."""
    rng = np.random.default_rng(seed)
    pts = rng.normal(size=(n, d)).astype(np.float32)
    pts /= np.linalg.norm(pts, axis=1, keepdims=True)
    eps = rng.uniform(0.2, 1.0, size=(n, 1)).astype(np.float32)
    X = np.concatenate([pts, eps], axis=1)
    y = (200 * eps[:, 0] ** 2 * (1.5 + pts[:, 0])).astype(np.float32)
    return X, y


@pytest.mark.parametrize("name", sorted(ESTIMATORS))
@pytest.mark.slow
def test_estimator_fit_predict(name):
    X, y = _toy_problem()
    est = make_estimator(name, X.shape[1], **(
        {"epochs": 25} if name != "linear" else {}))
    est.fit(X, y)
    pred = est.predict(X)
    assert pred.shape == y.shape
    assert np.isfinite(pred).all()
    # explains most of the variance on train (it is a smooth surface)
    mse = np.mean((pred - y) ** 2)
    var = np.var(y)
    assert mse < 0.7 * var, (name, mse, var)


@pytest.mark.parametrize("name", ["nn", "rmi", "selnet"])
@pytest.mark.slow
def test_estimator_state_dict_roundtrip(name):
    X, y = _toy_problem(n=200)
    est = make_estimator(name, X.shape[1], epochs=4)
    est.fit(X, y)
    state = est.state_dict()
    est2 = make_estimator(name, X.shape[1])
    est2.load_state_dict(state)
    np.testing.assert_allclose(est.predict(X[:32]), est2.predict(X[:32]),
                               rtol=1e-4, atol=1e-4)


def test_selnet_monotone_in_eps():
    X, y = _toy_problem(n=300)
    est = make_estimator("selnet", X.shape[1], epochs=10)
    est.fit(X, y)
    pts = X[:16, :-1]
    grid = np.linspace(0.1, 1.2, 12, dtype=np.float32)
    preds = np.stack([
        est.predict(np.concatenate([pts, np.full((16, 1), e, np.float32)], 1))
        for e in grid], axis=1)
    assert (np.diff(preds, axis=1) >= -1e-3 * np.abs(preds[:, :-1]) - 1e-4).all()


@pytest.mark.slow
def test_atcs_improves_training_on_uneven_data():
    """Qualitative check of the paper's Table IV claim at miniature scale:
    on an unevenly-distributed corpus (glove-like), ATCS training-eps
    selection beats uniform sampling (measured: MAE 4.5 vs 6.1 here; the
    full sweep lives in benchmarks/bench_atcs.py)."""
    from repro.core import atcs
    from repro.data import load_dataset
    from repro.data.groundtruth import cardinality_table, eps_grid_for_metric

    R, S, spec = load_dataset("glove", n=1500, seed=0)
    grid = eps_grid_for_metric(spec.metric, 60)
    table = cardinality_table(R, R, grid, spec.metric, backend="jnp",
                              exclude_self=True,
                              cache_key=("test-atcs-R", 1500))
    sub = cardinality_table(S, R, grid, spec.metric, backend="jnp",
                            cache_key=("test-atcs-S", 1500))
    rng = np.random.default_rng(1)
    test_idx = rng.integers(0, len(grid), size=(len(S), 1))
    Xt = np.concatenate([S, grid[test_idx]], axis=1)
    yt = np.take_along_axis(sub, test_idx, axis=1)[:, 0]
    results = {}
    for strat, select in (("fixed", atcs.uniform_select),
                          ("auto", atcs.atcs_select)):
        idx = select(table, 6, seed=0)
        X, y = atcs.build_training_tuples(R, grid, table, idx)
        est = make_estimator("nn", X.shape[1], epochs=12, seed=0)
        est.fit(X, y)
        results[strat] = float(np.mean(np.abs(est.predict(Xt) - yt)))
    assert results["auto"] <= results["fixed"] * 1.1, results
