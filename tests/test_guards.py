"""Runtime transfer-guard lane (DESIGN.md §12): the two-sync claim, enforced.

The static host-sync rule (scripts/xlint) proves no UNANNOTATED sync
exists in the hot path; this lane proves the annotated ones are the ONLY
syncs at runtime.  The streamed exact and device-probe routes re-run
inside `engine.host_sync_guard("n_pos", "result")` — which stacks the
hook-level check (any instrumented sync with an undeclared kind raises
`HostSyncError`, on every backend) on a scoped
`jax.transfer_guard_device_to_host("disallow")` (uninstrumented
device→host transfers raise at the XLA layer on accelerator backends;
the two declared sync points open their own `"allow"` windows via
`_allowed_transfer`) — and must stay bit-identical to the unguarded
reference.  The host-probe route, whose verdict readback is deliberately
a plain `_note_host_sync`, must trip the guard: that failure is what
proves the lane is not vacuous (on CPU, where zero-copy transfers never
reach the XLA guard, the hook layer is the tripwire).  Programs are
warmed on the same shape buckets first so compilation noise cannot mask
(or cause) a violation.  CPU-cheap; runs in the fast lane under
`-m guard`.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.engine import HostSyncError, JoinEngine, host_sync_guard

pytestmark = pytest.mark.guard

EPS = 0.4
LSH_PARAMS = dict(k=10, l=8, n_probes=4, W=2.5)


@pytest.fixture(scope="module")
def data():
    """Small clustered corpus/queries (enough positives to probe)."""
    rng = np.random.default_rng(11)
    d, nc, spread = 16, 4, 0.05
    c = rng.normal(size=(nc, d))
    c /= np.linalg.norm(c, axis=1, keepdims=True)

    def draw(per):
        pts = (np.repeat(c, per, axis=0)
               + rng.normal(size=(nc * per, d)) * spread)
        pts /= np.linalg.norm(pts, axis=1, keepdims=True)
        return pts.astype(np.float32)

    return draw(60), draw(20)


def _trivial_predict():
    """A fused (params, fn) filter passing everything — the cheapest way
    to put the verdicts ON DEVICE so `_stage_probe` must read
    `n_pos_dev` through its declared `_allowed_transfer("n_pos")`
    window (host verdicts would precompute the count and skip it)."""
    params = jnp.zeros((1,), jnp.float32)

    def fn(params, X):
        del params
        return jnp.ones((X.shape[0],), jnp.float32)

    return params, fn


def _stream_counts(eng, batches, **kw):
    """Run a stream and materialize counts (the result readbacks happen
    inside the calling context — i.e. under the guard when scoped)."""
    return [np.asarray(r.counts)
            for r in eng.stream(batches, EPS, depth=2, **kw)]


@pytest.mark.parametrize("route", ["exact", "device"])
def test_streamed_routes_pass_under_disallow(data, route):
    """Exact and device-probe streams run to completion — bit-identical
    to the unguarded reference — with host syncs disallowed outside the
    two declared per-batch points (count read + result readback)."""
    R, Q = data
    eng = JoinEngine(R, "l2", backend="jnp")
    kw = dict(predict=_trivial_predict(), threshold=0.5)
    if route == "device":
        eng.verifier("lsh", **LSH_PARAMS)
        kw.update(verify="lsh", probe="device")
    batches = [Q[:30], Q[30:31], Q[31:]]    # ragged: distinct shape buckets
    want = _stream_counts(eng, batches, **kw)        # warm the programs
    with host_sync_guard("n_pos", "result"):
        got = _stream_counts(eng, batches, **kw)
    assert len(got) == len(batches)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(g, w)


def test_host_probe_route_trips_guard(data):
    """Non-vacuity: the host-probe route's verdict readback is a plain
    `_note_host_sync("verdicts")`, NOT a declared window — under the
    same guard it must raise, proving the scope actually intercepts."""
    R, Q = data
    eng = JoinEngine(R, "l2", backend="jnp")
    eng.verifier("lsh", **LSH_PARAMS)
    batches = [Q[:30], Q[30:]]
    _stream_counts(eng, batches, verify="lsh", probe="host")     # warm
    with pytest.raises(HostSyncError, match=r"(?i)disallowed.*verdicts"):
        with host_sync_guard("n_pos", "result"):
            _stream_counts(eng, batches, verify="lsh", probe="host")


def test_guard_scope_does_not_leak(data):
    """After a guarded stream — even one that raised — the guard stack
    and ambient transfer policy are restored."""
    from repro.core import engine
    R, Q = data
    eng = JoinEngine(R, "l2", backend="jnp")
    eng.verifier("lsh", **LSH_PARAMS)
    with host_sync_guard("n_pos", "result"):
        _stream_counts(eng, [Q], predict=_trivial_predict(), threshold=0.5)
    with pytest.raises(HostSyncError):
        with host_sync_guard("n_pos", "result"):
            _stream_counts(eng, [Q], verify="lsh", probe="host")
    assert engine._SYNC_GUARDS == []
    engine._note_host_sync("verdicts")      # no guard: a no-op again
    assert int(jnp.asarray(3) + 1) == 4     # ambient policy restored
