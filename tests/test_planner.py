"""Cost-based auto-planner (core/planner.py, DESIGN.md §16).

Covers: the Hoeffding sample-bound closed form and the sample sources;
skew-aware LSH re-bucketing (verified-count bit-parity with the plain
index, overflow_frac strictly non-increasing, cap reduction, no-op on
uniform data, and `split_hot_buckets`'s candidate-set-preservation
invariant); the satellite-2 hot-bucket overflow trigger replacing plain
LSH in the candidate grid; the randomized-stats property that `choose`
and `JoinPlan.auto()` never emit a configuration `build()` would
reject; byte-determinism of `explain()` for a fixed seed + sample;
pinned-knob and error paths of `auto()` / `.on(plan="auto")`; the
gateway's planned-tenant parity, report rationale, and
mutation-triggered re-planning; the `--compare` minimum-gate floor
(satellite 1); and — in a forced-8-device subprocess — ring-pinned
planning parity plus explain determinism on both topologies.
"""
import json
import os
import subprocess
import sys
import warnings

import numpy as np
import pytest

from repro.core import JoinPlan, make_join, planner
from repro.core.planner import (OVERFLOW_TRIGGER, REBUCKET_HOT, Candidate,
                                choose, draw_sample, enumerate_candidates,
                                estimate_cost, measure_skew, sample_bound)
from repro.core.probe import split_hot_buckets

LSH_PARAMS = dict(k=10, l=8, n_probes=4, W=2.5)
EPS = 0.4


def _unit(rng, n, d=32):
    x = rng.normal(size=(n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def _skewed(rng, n=1200, d=32, hot_frac=0.25):
    """Corpus with one dense cluster — a deliberately hot LSH bucket."""
    n_hot = int(n * hot_frac)
    bg = rng.normal(size=(n - n_hot, d))
    hot = rng.normal(size=(1, d)) + 0.03 * rng.normal(size=(n_hot, d))
    R = np.concatenate([bg, hot]).astype(np.float32)
    return R / np.linalg.norm(R, axis=1, keepdims=True)


# ============================================================= sampling
def test_sample_bound_closed_form():
    import math
    for err, conf in ((0.1, 0.95), (0.05, 0.99), (0.2, 0.9)):
        want = math.ceil(math.log(2.0 / (1.0 - conf)) / (2.0 * err * err))
        assert sample_bound(err, conf) == want
    # tighter error or higher confidence can only cost more samples
    assert sample_bound(0.05, 0.95) > sample_bound(0.1, 0.95)
    assert sample_bound(0.1, 0.99) > sample_bound(0.1, 0.95)


@pytest.mark.parametrize("err,conf", [(0.0, 0.95), (1.0, 0.95),
                                      (0.1, 0.0), (0.1, 1.0), (-0.1, 0.5)])
def test_sample_bound_validates(err, conf):
    with pytest.raises(ValueError):
        sample_bound(err, conf)


def test_draw_sample_sources():
    rng = np.random.default_rng(0)
    R, Q = _unit(rng, 500), _unit(rng, 400)
    s, meta = draw_sample(Q, R, err=0.1, confidence=0.95, seed=1)
    assert meta["source"] == "queries" and len(s) == meta["bound"]
    assert all(any(np.array_equal(row, q) for q in Q) for row in s[:3])
    s2, meta2 = draw_sample(None, R, err=0.1, confidence=0.95, seed=1)
    assert meta2["source"] == "index-self"
    # fewer rows than the bound: take them all
    s3, meta3 = draw_sample(Q[:7], R, err=0.1, confidence=0.95, seed=1)
    assert len(s3) == 7 and meta3["bound"] > 7


# ======================================================== re-bucketing
@pytest.fixture(scope="module")
def skewed_data():
    rng = np.random.default_rng(7)
    return _skewed(rng), _unit(rng, 40)


def _counts(plan, Q, eps=EPS):
    return np.asarray(plan.run(Q, eps).counts)


def test_rebucket_count_parity_and_overflow(skewed_data):
    """Re-bucketing preserves verified counts bit-exactly (probing
    expands every probed bucket to ALL children) while overflow — the
    silent membership loss — strictly recovers on the hot corpus."""
    R, Q = skewed_data
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        plain = make_join("lsh", R, "cosine", **LSH_PARAMS)
        reb = make_join("lsh", R, "cosine", rebucket_hot=REBUCKET_HOT,
                        **LSH_PARAMS)
    assert reb.rebucket_info is not None and reb.expand is not None
    assert reb.overflow_frac < plain.overflow_frac
    assert (reb.rebucket_info["max_occ_after"]
            < reb.rebucket_info["max_occ_before"])
    # bit-parity holds when capacity binds on NEITHER side (a non-binding
    # explicit cap): re-bucketing is then a pure relabeling and probing
    # recovers every original candidate.  Under the auto-cap the counts
    # legitimately differ — plain LSH silently drops memberships (19%+
    # here) that the split recovers, which is the recall win above.
    cap = int(len(R))
    for probe in ("host", "device"):
        p1 = (JoinPlan(R, "cosine").filter("none").search("naive")
              .verify("lsh", cap=cap, **LSH_PARAMS)
              .on(backend="jnp", probe=probe).build())
        p2 = (JoinPlan(R, "cosine").filter("none").search("naive")
              .verify("lsh", cap=cap, rebucket_hot=REBUCKET_HOT,
                      **LSH_PARAMS)
              .on(backend="jnp", probe=probe).build())
        np.testing.assert_array_equal(_counts(p2, Q), _counts(p1, Q))


def test_split_noop_on_flat_occupancy():
    """Nothing hot -> split_hot_buckets declines (returns None)."""
    rng = np.random.default_rng(3)
    n, l, n_buckets = 512, 4, 128
    buckets = np.stack([rng.permutation(n) % n_buckets
                        for _ in range(l)], axis=1)     # occ exactly 4
    X = rng.normal(size=(n, 8)).astype(np.float32)
    assert split_hot_buckets(buckets, X, n_buckets=n_buckets,
                             hot_factor=REBUCKET_HOT) is None


def test_rebucket_candidate_sets_on_uniform():
    """On an already-uniform corpus the split (if any fires at the
    sparse-occupancy floor) changes nothing observable: per-query
    candidate SETS are identical under a non-binding cap."""
    rng = np.random.default_rng(3)
    R, Q = _unit(rng, 400), _unit(rng, 10)
    cap = len(R)
    plain = make_join("lsh", R, "cosine", cap=cap, **LSH_PARAMS)
    reb = make_join("lsh", R, "cosine", cap=cap, rebucket_hot=REBUCKET_HOT,
                    **LSH_PARAMS)
    c1, c2 = plain.candidates(Q), reb.candidates(Q)
    for i in range(len(Q)):
        assert (set(c1[i].tolist()) - {-1}) == (set(c2[i].tolist()) - {-1})


def test_split_hot_buckets_preserves_row_sets(skewed_data):
    """The invariant behind count parity: the union of a bucket's
    children holds exactly the original bucket's rows."""
    R, _ = skewed_data
    join = make_join("lsh", R, "cosine", **LSH_PARAMS)
    codes = join._hash_codes(R)
    buckets = join._combine(codes)
    out = split_hot_buckets(buckets, R, n_buckets=join.n_buckets,
                            hot_factor=REBUCKET_HOT)
    assert out is not None
    buckets2, expand, n_total, info = out
    assert info["n_hot"] >= 1 and info["fanout"] >= 2
    l = buckets.shape[1]
    for t in range(l):
        for b in np.unique(buckets[:, t]):
            rows = set(np.nonzero(buckets[:, t] == b)[0].tolist())
            kids = expand[t, b]
            rows2 = set(np.nonzero(np.isin(buckets2[:, t], kids))[0].tolist())
            assert rows2 == rows


# ============================================== satellite 2: the trigger
def test_hot_bucket_trips_overflow_trigger(skewed_data):
    R, _ = skewed_data
    skew = measure_skew(R, "cosine", seed=0, verify_params=LSH_PARAMS)
    assert skew["overflow_est"] > OVERFLOW_TRIGGER
    cands, rejected = enumerate_candidates(skew, recall=0.9, n_devices=1,
                                           pinned={})
    verifies = {c.verify for c in cands}
    assert "lsh+rebucket" in verifies and "lsh" not in verifies
    reasons = [r["reason"] for r in rejected if r.get("verify") == "lsh"]
    assert any("re-bucketing" in r for r in reasons)


def test_uniform_keeps_plain_lsh():
    rng = np.random.default_rng(11)
    R = _unit(rng, 800)
    skew = measure_skew(R, "cosine", seed=0, verify_params=LSH_PARAMS)
    assert skew["overflow_est"] <= OVERFLOW_TRIGGER
    cands, rejected = enumerate_candidates(skew, recall=0.9, n_devices=1,
                                           pinned={})
    verifies = {c.verify for c in cands}
    assert "lsh" in verifies and "lsh+rebucket" not in verifies


# ================================== property: never an invalid config
def test_choose_never_returns_invalid_config():
    """Randomized measured stats: whatever the numbers say, the chosen
    candidate is a buildable configuration (the acceptance property)."""
    rng = np.random.default_rng(42)
    for trial in range(40):
        workload = {"pos_rate": float(rng.uniform(0, 1)),
                    "exact_us_per_query": float(rng.uniform(1, 5000)),
                    "delta_frac": float(rng.uniform(0, 0.5)),
                    "selectivity": float(rng.uniform(0, 0.01))}
        cap = float(rng.uniform(2, 200))
        skew = {"overflow_est": float(rng.uniform(0, 0.3)),
                "hot_factor": float(rng.uniform(1, 40)),
                "max_occ": int(rng.uniform(2, 2000)),
                "cap_est": cap,
                "sb_occ": float(rng.uniform(1, cap)),
                "sb_occ_rebucket": float(rng.uniform(1, cap))}
        consts = dict(planner.DEFAULT_CONSTANTS,
                      machine_scale=float(rng.uniform(0.2, 5)))
        recall = float(rng.choice([0.8, 0.9, 0.95, 0.99, 1.0]))
        n_devices = int(rng.choice([1, 2, 8]))
        best, scored, rejected = choose(
            workload, skew, consts, recall=recall, n_devices=n_devices,
            n=int(rng.uniform(100, 1_000_000)), pinned={})
        assert best.verify in ("exact", "lsh", "lsh+rebucket", "ivfpq")
        if recall >= 1.0:
            assert best.verify == "exact"
        elif recall >= 0.95:
            assert best.verify in ("exact", "ivfpq")
        assert best.probe == "-" if best.verify == "exact" \
            else best.probe in ("device", "host")
        assert best.block in (256, 512) and best.depth in (2, 4)
        if best.topology == "ring":
            assert best.r_shards >= 2 and n_devices >= 2
        else:
            assert best.r_shards == 1
        assert all(e["us_per_query"] >= 0 for _, e in scored)


def test_auto_always_builds_and_runs():
    rng = np.random.default_rng(5)
    for R, recall in ((_unit(rng, 300), 0.9), (_skewed(rng, 600), 0.85),
                      (_unit(rng, 300), 1.0)):
        Q = _unit(rng, 16)
        plan = JoinPlan(R, "cosine").filter("none").auto(
            EPS, Q, recall=recall, seed=0)
        counts = _counts(plan, Q)
        assert counts.shape == (16,)
        ex = plan.explain()
        assert ex["chosen"]["verify"] in ("exact", "lsh", "lsh+rebucket",
                                          "ivfpq")
        if recall >= 1.0:
            assert ex["chosen"]["verify"] == "exact"
            np.testing.assert_array_equal(
                counts, _counts(JoinPlan(R, "cosine").verify("exact")
                                .on(backend="jnp").build(), Q))


# ========================================================== determinism
def test_explain_byte_deterministic():
    rng = np.random.default_rng(9)
    R, Q = _skewed(rng, 500), _unit(rng, 32)

    def dump():
        plan = JoinPlan(R, "cosine").filter("none").auto(
            EPS, Q, recall=0.9, seed=3)
        return json.dumps(plan.explain(), sort_keys=True)

    d1, d2 = dump(), dump()
    assert d1 == d2


def test_auto_respects_pins_and_errors():
    rng = np.random.default_rng(13)
    R, Q = _unit(rng, 300), _unit(rng, 16)
    base = JoinPlan(R, "cosine").filter("none")
    # by-name verify pins the verify axis
    plan = base.verify("lsh", **LSH_PARAMS).auto(EPS, Q, recall=0.9, seed=0)
    assert plan.explain()["chosen"]["verify"].startswith("lsh")
    # explicit probe pins placement
    plan = base.verify("auto").on(probe="host").auto(EPS, Q, recall=0.9,
                                                     seed=0)
    ch = plan.explain()["chosen"]
    assert ch["verify"] == "exact" or ch["probe"] == "host"
    with pytest.raises(ValueError, match="recall"):
        base.auto(EPS, Q, recall=1.5)
    with pytest.raises(ValueError, match="search"):
        base.search(make_join("lsh", R, "cosine", **LSH_PARAMS)).auto(EPS, Q)


def test_on_plan_auto_lazy_delegate():
    rng = np.random.default_rng(17)
    R, Q = _unit(rng, 300), _unit(rng, 16)
    lazy = (JoinPlan(R, "cosine").filter("none").search("naive")
            .verify("auto").on(plan="auto"))
    explicit = JoinPlan(R, "cosine").filter("none").auto(EPS, Q, seed=0)
    np.testing.assert_array_equal(_counts(lazy, Q), _counts(explicit, Q))
    assert lazy.explain()["chosen"] == explicit.explain()["chosen"]
    with pytest.raises(ValueError, match="plan"):
        JoinPlan(R, "cosine").on(plan="lsh")
    with pytest.raises(RuntimeError, match="mutable"):
        (JoinPlan(R, "cosine").mutable().on(plan="auto")).run(Q, EPS)


def test_auto_mutable_plan_stays_correct():
    rng = np.random.default_rng(19)
    R, Q = _unit(rng, 300), _unit(rng, 16)
    plan = (JoinPlan(R, "cosine").filter("none").mutable()
            .auto(EPS, Q, recall=1.0, seed=0))
    new = _unit(rng, 40)
    plan.insert(new)
    plan.delete(np.arange(10))
    from repro.kernels import ref
    world = np.concatenate([R[10:], new])
    np.testing.assert_array_equal(
        _counts(plan, Q),
        np.asarray(ref.range_count(Q, world, EPS, metric="cosine")))


# ============================================================== gateway
def test_gateway_planner_parity_and_report():
    from repro.serve import Gateway, TenantClass
    rng = np.random.default_rng(21)
    R = _skewed(rng, 500)
    classes = [TenantClass("bulk", eps=EPS, recall_target=0.9),
               TenantClass("gold", eps=EPS, verify="exact")]
    gw = Gateway(R, classes, backend="jnp")
    q = _unit(rng, 9)
    t = gw.join("bulk", q)
    np.testing.assert_array_equal(
        t.counts, np.asarray(gw.plan("bulk").run(q, EPS).counts))
    rep = gw.report()
    assert rep["tenants"]["bulk"]["planner"] is not None
    assert rep["tenants"]["bulk"]["planner"]["replans"] == 0
    assert rep["tenants"]["gold"]["planner"] is None  # explicit verify
    # planner="off" restores the static recall table
    gw_off = Gateway(R, classes, backend="jnp", planner="off")
    assert gw_off.report()["tenants"]["bulk"]["planner"] is None


def test_gateway_replans_after_mutation():
    from repro.serve import Gateway, TenantClass
    rng = np.random.default_rng(23)
    R = _unit(rng, 400)
    cls = TenantClass("bulk", eps=EPS, recall_target=0.9)
    gw = Gateway(R, [cls], backend="jnp", mutable=True, replan_at=0.05)
    q = _unit(rng, 8)
    gw.join("bulk", q)
    gw.insert(_unit(rng, 60))                 # delta_frac 60/460 > 0.05
    t = gw.join("bulk", q)
    rep = gw.report()["tenants"]["bulk"]["planner"]
    assert rep["replans"] == 1
    np.testing.assert_array_equal(
        t.counts, np.asarray(gw.plan("bulk").run(q, EPS).counts))
    gw.join("bulk", q)                        # no second bump -> no replan
    assert gw.report()["tenants"]["bulk"]["planner"]["replans"] == 1


# ================================== satellite 1: the --compare floor
def test_compare_floor_exempts_fast_rows(capsys):
    from benchmarks.run import compare_snapshots
    baseline = {"suites": {"ring": {"ring/r1": 9.0, "ring/r2": 10.0},
                           "kernels": {"kernels/big": 100.0}}}
    current = {"ring": {"ring/r1": 18.0, "ring/r2": 10.5},
               "kernels": {"kernels/big": 200.0}}
    regressed = compare_snapshots(baseline, current)
    out = capsys.readouterr().out
    assert regressed == ["kernels/big"]       # past the floor: gated
    assert "jitter-exempt" in out             # under the floor: flagged only


# =========================================== forced-8-device subprocess
@pytest.mark.slow
def test_planner_subprocess_8dev():
    """Forced 8-host-device subprocess: ring appears in the candidate
    grid, a ring-pinned auto() plan keeps exact-count parity with the
    replicated exact sweep, explain() is byte-deterministic under both
    pinned topologies, and re-bucketed LSH keeps verified-count parity
    with the plain index on BOTH topologies (non-binding cap)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    code = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'\n"
        "import json\n"
        "import numpy as np, jax\n"
        "from repro.core import JoinPlan\n"
        "assert len(jax.devices()) == 8\n"
        "rng = np.random.default_rng(6)\n"
        "def unit(n):\n"
        "    x = rng.normal(size=(n, 16)).astype(np.float32)\n"
        "    return x / np.linalg.norm(x, axis=1, keepdims=True)\n"
        "R, Q = unit(400), unit(12)\n"
        "want = np.asarray(JoinPlan(R, 'cosine').verify('exact')\n"
        "                  .on(backend='jnp').build().run(Q, 0.4).counts)\n"
        "for pins in ({}, dict(topology='ring', r_shards=2)):\n"
        "    def plan():\n"
        "        p = JoinPlan(R, 'cosine').filter('none')\n"
        "        if pins: p = p.on(**pins)\n"
        "        return p.auto(0.4, Q, recall=1.0, seed=0)\n"
        "    p1, p2 = plan(), plan()\n"
        "    e1 = json.dumps(p1.explain(), sort_keys=True)\n"
        "    e2 = json.dumps(p2.explain(), sort_keys=True)\n"
        "    assert e1 == e2, pins\n"
        "    if pins:\n"
        "        assert p1.explain()['chosen']['topology'] == 'ring'\n"
        "    np.testing.assert_array_equal(\n"
        "        np.asarray(p1.run(Q, 0.4).counts), want)\n"
        "unpinned = JoinPlan(R, 'cosine').filter('none').auto(\n"
        "    0.4, Q, recall=0.9, seed=0)\n"
        "assert any('ring' in c['config']\n"
        "           for c in unpinned.explain()['candidates'])\n"
        "hot = np.concatenate([R, R[:1] + 0.02 * unit(120)])\n"
        "hot = hot / np.linalg.norm(hot, axis=1, keepdims=True)\n"
        "LSH = dict(k=10, l=8, n_probes=4, W=2.5, cap=len(hot))\n"
        "for pins in ({}, dict(topology='ring', r_shards=2)):\n"
        "    def lsh_plan(**extra):\n"
        "        return (JoinPlan(hot, 'cosine').filter('none')\n"
        "                .search('naive').verify('lsh', **LSH, **extra)\n"
        "                .on(backend='jnp', **pins).build())\n"
        "    c1 = np.asarray(lsh_plan().run(Q, 0.4).counts)\n"
        "    c2 = np.asarray(lsh_plan(rebucket_hot=4.0).run(Q, 0.4).counts)\n"
        "    np.testing.assert_array_equal(c2, c1), pins\n"
        "print('PLANNER_8DEV_OK')\n"
    )
    out = subprocess.run(
        [sys.executable, "-c", code], env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True, text=True, timeout=600)
    assert "PLANNER_8DEV_OK" in out.stdout, out.stderr[-3000:]
