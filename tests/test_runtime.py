"""Runtime: checkpoint atomicity/keep-k/restore, failure detection,
straggler mitigation, elastic re-mesh planning, fault-tolerant loop with
induced failure, optimizer + gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.optim import adam, adamw, adafactor, make_compressor, sgd
from repro.optim.compression import CompressionState
from repro.runtime.elastic import best_mesh_shape, rescale_plan
from repro.runtime.failure import FailureDetector, StragglerMonitor


# ----------------------------------------------------------- checkpointing
def test_checkpoint_roundtrip_and_keep(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    state = {"w": jnp.arange(6.0).reshape(2, 3), "step": jnp.asarray(3)}
    for s in (0, 10, 20, 30):
        mgr.save(s, state, meta={"loss": float(s)})
    assert mgr.all_steps() == [20, 30]           # keep-k GC
    restored, meta = mgr.restore(state)
    np.testing.assert_allclose(np.asarray(restored["w"]),
                               np.asarray(state["w"]))
    assert meta["step"] == 30


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_write=False)
    mgr.save(1, {"x": jnp.ones(4)})
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_write=True)
    mgr.save(5, {"x": jnp.ones(3)}, blocking=False)
    mgr.wait()
    import time
    for _ in range(100):
        if mgr.all_steps() == [5]:
            break
        time.sleep(0.02)
    assert mgr.all_steps() == [5]


# ------------------------------------------------------------- failure det
def test_failure_detector():
    t = [0.0]
    det = FailureDetector(["a", "b", "c"], timeout_s=10, clock=lambda: t[0])
    t[0] = 5.0
    det.heartbeat("a")
    det.heartbeat("b")
    t[0] = 12.0
    assert det.dead() == ["c"]
    assert det.alive() == ["a", "b"]


def test_straggler_monitor_and_rebalance():
    mon = StragglerMonitor(["w0", "w1", "w2", "w3"], threshold=1.5)
    for _ in range(8):
        for w in ("w0", "w1", "w2"):
            mon.record(w, 1.0)
        mon.record("w3", 3.0)
    assert mon.stragglers() == ["w3"]
    plan = mon.rebalance_plan()
    assert abs(sum(plan.shares.values()) - 1.0) < 1e-6
    assert plan.shares["w3"] < plan.shares["w0"]  # straggler gets less work


# ----------------------------------------------------------------- elastic
def test_best_mesh_shape():
    assert best_mesh_shape(256, prefer_model=16) == (16, 16)
    d, m = best_mesh_shape(255, prefer_model=16)
    assert d * m <= 255 and m <= 16
    assert best_mesh_shape(3, prefer_model=16)[0] * \
        best_mesh_shape(3, prefer_model=16)[1] <= 3


def test_rescale_plan_single_device():
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    plan = rescale_plan(mesh, set())
    assert plan.n_lost == 0
    assert plan.new_shape[0] * plan.new_shape[1] == 1


# ------------------------------------------------------------------- optim
def _quadratic_losses(opt, steps=60):
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        def loss_fn(p):
            return jnp.sum((p["w"] - target) ** 2)
        loss, g = jax.value_and_grad(loss_fn)(params)
        params, state = opt.apply(params, state, g)
        return params, state, loss

    losses = []
    for _ in range(steps):
        params, state, l = step(params, state)
        losses.append(float(l))
    return losses


@pytest.mark.parametrize("opt_fn", [
    lambda: adam(lr=0.1), lambda: adamw(lr=0.1, weight_decay=0.0),
    lambda: sgd(lr=0.05), lambda: adafactor(lr=0.3, min_dim_factored=2)])
def test_optimizers_converge(opt_fn):
    losses = _quadratic_losses(opt_fn())
    assert losses[-1] < losses[0] * 0.05


def test_adam_bf16_moments_still_converges():
    losses = _quadratic_losses(adam(lr=0.1, moment_dtype=jnp.bfloat16))
    assert losses[-1] < losses[0] * 0.1


def test_adafactor_factored_state_is_small():
    opt = adafactor(min_dim_factored=4)
    params = {"w": jnp.zeros((64, 32))}
    st = opt.init(params)
    row, col = st.nu["w"]
    assert row.shape == (64,) and col.shape == (32,)


@pytest.mark.parametrize("mode", ["topk", "int8"])
def test_compression_error_feedback_converges(mode):
    """Compressed-gradient descent with error feedback still converges on a
    quadratic (the whole point of error feedback)."""
    comp = make_compressor(mode, topk_frac=0.34)
    target = jnp.asarray([1.0, -2.0, 3.0])
    w = jnp.zeros(3)
    state = CompressionState(error={"w": jnp.zeros(3)})
    for i in range(150):
        g = {"w": 2 * (w - target)}
        g, state = comp(g, state, jax.random.key(i))
        w = w - 0.05 * g["w"]
    assert float(jnp.sum((w - target) ** 2)) < 1e-2


def test_int8_roundtrip_accuracy():
    from repro.optim.compression import int8_compress, int8_decompress
    x = jax.random.normal(jax.random.key(0), (256,)) * 3
    q, scale = int8_compress(x, jax.random.key(1))
    err = jnp.abs(int8_decompress(q, scale) - x)
    assert float(jnp.max(err)) <= float(scale) + 1e-6


# ---------------------------------------------------- fault-tolerant loop
@pytest.mark.slow
def test_training_loop_survives_failure(tmp_path):
    from repro.configs import get_config
    from repro.runtime.loop import TrainLoopConfig, run_training
    cfg = get_config("tinyllama_1_1b", smoke=True)
    loop = TrainLoopConfig(total_steps=8, batch=2, seq=16,
                           ckpt_dir=str(tmp_path), ckpt_every=2,
                           fail_at_step=5, lose_devices=0)
    hist = run_training(cfg, loop)
    assert hist["restarts"] == 1
    assert len(hist["loss"]) >= 8
    assert all(np.isfinite(hist["loss"]))


@pytest.mark.slow
def test_training_loop_with_compression(tmp_path):
    from repro.configs import get_config
    from repro.runtime.loop import TrainLoopConfig, run_training
    cfg = get_config("tinyllama_1_1b", smoke=True)
    loop = TrainLoopConfig(total_steps=4, batch=2, seq=16,
                           ckpt_dir=str(tmp_path), ckpt_every=0,
                           compression="topk", topk_frac=0.1)
    hist = run_training(cfg, loop)
    assert all(np.isfinite(hist["loss"]))
