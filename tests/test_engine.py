"""The sharded join engine (core/engine.py) + topology + mesh-compat.

Covers: single-device engine vs the ref oracle, FilteredJoin compaction
parity for every verdict pattern, the streaming API (including the async
double-buffered pipeline vs the synchronous path, and the StreamSession
submit/flush invariants), the pluggable verification backends (lsh/ivfpq
recall floors vs the exact oracle, verify_candidates backend parity), the
topology layer (DESIGN.md §10: ring == ref on a degenerate 1-device ring,
build-time validation, program-cache eviction, ground-truth engine
reuse), the exact-mode target clamp regression, and — in forced-8-device
subprocesses, mirroring test_system — bit-for-bit equality of the sharded
sweep with the ref backend while the query axis is genuinely distributed,
for BOTH the replicated and the ring (r x data ppermute ring) topologies.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import XlingConfig, XlingFilter, make_join
from repro.core.engine import JoinEngine, _bucket_size, sharded_range_count_hist
from repro.core.xjoin import FilteredJoin
from repro.kernels import ops, ref


def _unit(rng, n, d):
    x = rng.normal(size=(n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


@pytest.fixture(scope="module")
def world():
    rng = np.random.default_rng(0)
    R = _unit(rng, 900, 24)
    Q = _unit(rng, 157, 24)
    eps = np.linspace(0.2, 1.8, 23).astype(np.float32)
    return R, Q, eps


# -------------------------------------------------------------- single device
def test_engine_hist_matches_ref(world):
    R, Q, eps = world
    import jax.numpy as jnp
    want = np.asarray(ref.range_count_hist(jnp.asarray(Q), jnp.asarray(R),
                                           jnp.asarray(eps), "l2"))
    for backend in ("jnp", "ref"):
        eng = JoinEngine(R, "l2", backend=backend)
        np.testing.assert_array_equal(eng.range_count_hist(Q, eps), want)
    np.testing.assert_array_equal(
        sharded_range_count_hist(Q, R, eps, metric="l2", backend="jnp"), want)


def test_naive_join_routes_through_engine(world):
    R, Q, _ = world
    j = make_join("naive", R, "l2", backend="jnp")
    assert isinstance(j.engine, JoinEngine)
    want = np.asarray(ops.range_count(Q, R, 0.8, metric="l2", backend="jnp"))
    np.testing.assert_array_equal(j.query_counts(Q, 0.8), want)


@pytest.mark.parametrize("pattern", ["all_positive", "all_negative", "mixed"])
def test_filtered_join_compaction_patterns(world, pattern):
    """Engine compaction must return counts identical to the host-compaction
    path for every verdict shape."""
    R, Q, _ = world
    rng = np.random.default_rng(3)
    verdicts = {"all_positive": np.ones(len(Q), bool),
                "all_negative": np.zeros(len(Q), bool),
                "mixed": rng.random(len(Q)) > 0.5}[pattern]
    base = make_join("naive", R, "l2", backend="jnp")
    filt = lambda Q_, eps_: verdicts  # noqa: E731
    host = FilteredJoin(base, filter=filt).run(Q, 0.8)
    eng = FilteredJoin(base, filter=filt, engine=base.engine).run(Q, 0.8)
    assert eng.meta.get("engine") is True
    assert eng.n_searched == host.n_searched == int(verdicts.sum())
    np.testing.assert_array_equal(eng.counts, host.counts)
    true = np.asarray(ops.range_count(Q, R, 0.8, metric="l2", backend="jnp"))
    np.testing.assert_array_equal(eng.counts, np.where(verdicts, true, 0))


def test_engine_fused_estimator_path_matches_host(world):
    R, Q, _ = world
    cfg = XlingConfig(estimator="nn", metric="l2", epochs=3, backend="jnp", m=12)
    filt = XlingFilter(cfg).fit(R)
    base = make_join("naive", R, "l2", backend="jnp")
    eng = FilteredJoin(base, filter=filt, tau=0, xdt_mode="fpr",
                       engine=base.engine)
    host = FilteredJoin(base, filter=filt, tau=0, xdt_mode="fpr")
    r_eng, r_host = eng.run(Q, 0.8), host.run(Q, 0.8)
    assert r_eng.meta.get("engine") is True
    # same estimator math on both paths -> same verdicts -> same counts
    np.testing.assert_array_equal(r_eng.counts, r_host.counts)
    assert r_eng.n_searched == r_host.n_searched


def test_engine_streaming_matches_oneshot(world):
    R, Q, _ = world
    cfg = XlingConfig(estimator="nn", metric="l2", epochs=3, backend="jnp", m=12)
    filt = XlingFilter(cfg).fit(R)
    base = make_join("naive", R, "l2", backend="jnp")
    fj = FilteredJoin(base, filter=filt, tau=0, xdt_mode="fpr",
                      engine=base.engine)
    one = fj.run(Q, 0.8)
    batches = [Q[:64], Q[64:128], Q[128:]]
    results = list(fj.run_stream(batches, 0.8))
    assert len(results) == 3
    np.testing.assert_array_equal(
        np.concatenate([r.counts for r in results]), one.counts)
    assert sum(r.n_searched for r in results) == one.n_searched
    # the engine-level stream (predict + threshold) agrees with the join-level
    predict = filt.estimator.device_predict_fn()
    thr = filt.xdt(0.8, 0, mode="fpr", predict=predict)
    eng_results = list(base.engine.stream(batches, 0.8, predict=predict,
                                          threshold=thr))
    np.testing.assert_array_equal(
        np.concatenate([r.counts for r in eng_results]), one.counts)


def test_async_stream_bit_identical_to_sync(world):
    """The async double-buffered pipeline must return results bit-identical
    to per-batch synchronous `filtered_join` calls (ordering-insensitive:
    compared as the concatenated multiset AND per-batch)."""
    R, Q, _ = world
    cfg = XlingConfig(estimator="nn", metric="l2", epochs=3, backend="jnp", m=12)
    filt = XlingFilter(cfg).fit(R)
    base = make_join("naive", R, "l2", backend="jnp")
    fj = FilteredJoin(base, filter=filt, tau=0, xdt_mode="fpr",
                      engine=base.engine)
    # deliberately ragged batch sizes to exercise distinct shape buckets
    batches = [Q[:50], Q[50:51], Q[51:120], Q[120:]]
    sync = [fj.run(b, 0.8) for b in batches]
    for depth in (0, 1, 3, 10):
        stream = list(fj.run_stream(batches, 0.8, depth=depth))
        assert len(stream) == len(batches)
        for s, a in zip(sync, stream):
            np.testing.assert_array_equal(a.counts, s.counts)
            assert a.n_searched == s.n_searched
        np.testing.assert_array_equal(
            np.sort(np.concatenate([r.counts for r in stream])),
            np.sort(np.concatenate([r.counts for r in sync])))


def test_stream_session_submit_flush_invariants(world):
    """StreamSession: the in-flight queue stays bounded by `depth`, results
    come back FIFO, flush() drains everything and is idempotent."""
    R, Q, _ = world
    eng = JoinEngine(R, "l2", backend="jnp")
    rng = np.random.default_rng(9)
    verdicts = [rng.random(40) > 0.5 for _ in range(6)]
    sess = eng.stream_session(0.8, depth=2)
    got = []
    for i in range(6):
        out = sess.submit(Q[i * 20:i * 20 + 40], verdicts=verdicts[i])
        got.extend(out)
        # bounded: at most depth committed + 1 staged in flight
        assert len(sess._inflight) <= 2
    rest = sess.flush()
    assert len(sess._inflight) == 0 and sess._staged is None
    assert sess.flush() == []            # idempotent barrier
    got.extend(rest)
    assert len(got) == 6                 # every submitted batch came back
    for i, res in enumerate(got):        # FIFO + correct per-batch counts
        want = eng.filtered_join(Q[i * 20:i * 20 + 40], 0.8,
                                 verdicts=verdicts[i])
        np.testing.assert_array_equal(res.counts, want.counts)


# ------------------------------------------------- verification backends
@pytest.fixture(scope="module")
def clustered_world():
    """Clustered corpus/queries sharing centers — enough true pairs that
    approximate-verifier recall is a meaningful, stable number."""
    rng = np.random.default_rng(5)
    d, nc, spread = 32, 6, 0.03
    c = rng.normal(size=(nc, d))
    c /= np.linalg.norm(c, axis=1, keepdims=True)

    def draw(per):
        pts = (np.repeat(c, per, axis=0)
               + rng.normal(size=(nc * per, d)) * spread)
        pts /= np.linalg.norm(pts, axis=1, keepdims=True)
        return pts.astype(np.float32)

    return draw(150), draw(25)


@pytest.mark.parametrize("backend,floor,params", [
    ("lsh", 0.90, dict(k=10, l=8, n_probes=4, W=2.5)),
    ("ivfpq", 0.95, dict(C=24, m=8, n_probe=8, n_candidates=600)),
])
def test_verify_backend_recall_floor(clustered_world, backend, floor, params):
    """Approximate verification: counts never exceed the exact sweep (the
    verification itself is exact over candidates, so precision is 1) and
    recall vs the exact oracle stays above the configured floor."""
    R, Q = clustered_world
    eng = JoinEngine(R, "l2", backend="jnp")
    eng.verifier(backend, **params)      # pre-build with tuned params
    true = eng.range_count(Q, 0.4)
    assert true.sum() > 1000             # the workload is meaningful
    res = eng.filtered_join(Q, 0.4, verdicts=np.ones(len(Q), bool),
                            verify=backend)
    assert res.verify == backend
    assert res.n_searched == len(Q)
    assert (res.counts <= true).all()    # no false pairs
    recall = float(np.minimum(res.counts, true).sum() / true.sum())
    assert recall >= floor, f"{backend} recall {recall:.3f} < {floor}"
    # the streamed form of the same verify backend is bit-identical
    streamed = list(eng.stream([Q[:70], Q[70:]], 0.4, verify=backend,
                               depth=2))
    np.testing.assert_array_equal(
        np.concatenate([r.counts for r in streamed]), res.counts)


def test_verifier_registry(world):
    R, Q, _ = world
    eng = JoinEngine(R, "l2", backend="jnp")
    with pytest.raises(ValueError):
        eng.filtered_join(Q, 0.8, verdicts=np.ones(len(Q), bool),
                          verify="annoy")
    v1 = eng.verifier("lsh", k=6, l=4)
    assert eng.verifier("lsh") is v1     # cached per name


def test_verify_candidates_backend_parity(world):
    """verify_candidates counts are backend-invariant (§2): the blocked
    path and the unpadded ref oracle agree, with host or device R."""
    import jax.numpy as jnp
    from repro.core.joins.common import verify_candidates
    R, Q, _ = world
    rng = np.random.default_rng(4)
    cand = rng.integers(-1, len(R), size=(len(Q), 37)).astype(np.int32)
    want = verify_candidates(R, Q, cand, 0.8, "l2", backend="jnp")
    got_ref = verify_candidates(R, Q, cand, 0.8, "l2", backend="ref")
    got_dev = verify_candidates(jnp.asarray(R), Q, cand, 0.8, "l2")
    np.testing.assert_array_equal(got_ref, want)
    np.testing.assert_array_equal(got_dev, want)


@pytest.mark.parametrize("metric", ["cosine", "l2"])
def test_live_chunked_verify_oracle_parity(world, metric):
    """The live-chunked verify (_verify_block_live, DESIGN.md §15) is
    bit-identical to the oracle form on the shapes that stress its
    schedule: all-pad rows (zero trip count), dup-heavy rows, full-width
    rows (every chunk live), a candidate width that does not divide the
    chunk, and a tombstone mask riding along."""
    import jax.numpy as jnp
    from repro.core.joins.common import (_LIVE_CHUNK, _verify_block_impl,
                                         _verify_block_live)
    R, Q, _ = world
    Rj = jnp.asarray(R)
    rng = np.random.default_rng(11)
    tomb = jnp.asarray((rng.random(len(R)) < 0.15).astype(np.int32))
    cases = []
    for C in (_LIVE_CHUNK * 3, _LIVE_CHUNK - 9, 1):
        sparse = rng.integers(-1, len(R), size=(32, C)).astype(np.int32)
        sparse[rng.random(size=sparse.shape) > 0.15] = -1
        sparse[0] = -1                          # an all-pad row
        dense = rng.integers(0, len(R), size=(32, C)).astype(np.int32)
        dense[:, : C // 2] = dense[:, C // 2:][:, : C // 2] \
            if C > 1 else dense[:, :1]          # heavy duplication
        cases += [sparse, dense, np.full((32, C), -1, np.int32)]
    q = jnp.asarray(Q[:32])
    for cand in cases:
        for tb in (None, tomb):
            want = np.asarray(_verify_block_impl(
                Rj, q, jnp.asarray(cand), np.float32(0.9), metric=metric,
                tomb=tb))
            got = np.asarray(_verify_block_live(
                Rj, q, jnp.asarray(cand), np.float32(0.9), metric=metric,
                tomb=tb))
            np.testing.assert_array_equal(got, want)


def test_stream_staging_constant_caches(world):
    """Unfiltered streams re-stage the same radius scalar and all-positive
    mask every batch; the engine uploads each once per (value, shape
    bucket) and reuses the device arrays (DESIGN.md §5) — and the cached
    route stays bit-identical to the one-shot join."""
    R, Q, _ = world
    j = make_join("naive", R, "l2", backend="jnp")
    eng = j.engine
    want = j.query_counts(Q, 0.8)
    batches = [Q[:64], Q[64:128], Q[128:]]
    got = np.concatenate([r.counts for r in eng.stream(batches, 0.8)])
    np.testing.assert_array_equal(got, want)
    assert len(eng._eps_scalar_cache) == 1      # one radius staged once
    keys = set(eng._allpos_cache)
    assert len(keys) == 2                       # 64-row + 29-row buckets
    st = eng._stage_filter(Q[:64], 0.8)
    assert st.eps_dev is eng._eps_scalar_cache[0.8]
    assert st.pos_dev is eng._allpos_cache[(st.qdev.shape[0], 64)][0]
    assert set(eng._allpos_cache) == keys       # no new upload


def test_engine_filter_program_cache_stable(world):
    """device_predict_fn must hand back a memoized fn so the engine's
    program cache hits across run() calls — one compiled filter program per
    estimator, not one per batch (the serving steady-state guarantee)."""
    R, Q, _ = world
    cfg = XlingConfig(estimator="nn", metric="l2", epochs=2, backend="jnp", m=12)
    filt = XlingFilter(cfg).fit(R)
    base = make_join("naive", R, "l2", backend="jnp")
    fj = FilteredJoin(base, filter=filt, tau=0, xdt_mode="fpr",
                      engine=base.engine)
    for _ in range(3):
        fj.run(Q, 0.8)
    assert len(base.engine._filter_progs) == 1


def test_bucket_size_reexport():
    # _bucket_size moved to engine; xjoin re-exports it (test_property uses it)
    from repro.core.xjoin import _bucket_size as xb
    assert xb is _bucket_size
    assert _bucket_size(513, 512) == 1024


# ----------------------------------------------------- topology layer (§10)
def test_ring_topology_single_device_parity(world):
    """The ring topology on a degenerate 1x1 (r, data) mesh must stay
    bit-identical to the ref oracle — this exercises the full ring code
    path (ppermute ring, psum, zero-pad-row correction: R=900 pads to
    1024 rows, and l2 eps up to 1.8 > sqrt(2) means uncorrected padding
    rows WOULD count) without needing forced devices."""
    from repro.launch.mesh import make_join_mesh
    R, Q, eps = world
    mesh = make_join_mesh(data=1, r=1)
    assert mesh.axis_names == ("r", "data")
    eng = JoinEngine(R, "l2", mesh=mesh, backend="jnp", topology="ring")
    ref_eng = JoinEngine(R, "l2", backend="ref")
    np.testing.assert_array_equal(eng.range_count_hist(Q, eps),
                                  ref_eng.range_count_hist(Q, eps))
    want = np.asarray(ref_eng.range_count(Q, 0.8))
    v = np.random.default_rng(11).random(len(Q)) > 0.5
    res = eng.filtered_join(Q, 0.8, verdicts=v)
    np.testing.assert_array_equal(res.counts, np.where(v, want, 0))
    # StreamSession parity + invariants under topology="ring"
    sess = eng.stream_session(0.8, depth=1)
    got = []
    verdicts = [np.random.default_rng(s).random(50) > 0.5 for s in range(4)]
    for i in range(4):
        got.extend(sess.submit(Q[i * 25:i * 25 + 50], verdicts=verdicts[i]))
        assert len(sess._inflight) <= 1
    got.extend(sess.flush())
    assert len(got) == 4
    for i, r in enumerate(got):
        w = eng.filtered_join(Q[i * 25:i * 25 + 50], 0.8,
                              verdicts=verdicts[i])
        np.testing.assert_array_equal(r.counts, w.counts)


def test_topology_validation():
    """Placement misconfiguration must fail at build/construction time
    with actionable messages, never data-dependently mid-stream."""
    from repro.core import JoinPlan, resolve_topology
    from repro.core.topology import RingSharded
    R = np.eye(8, dtype=np.float32)
    with pytest.raises(ValueError, match="topology"):
        resolve_topology("bogus")
    with pytest.raises(ValueError, match="ring"):
        JoinEngine(R, "l2", topology="ring")        # no mesh
    with pytest.raises(ValueError, match="r_shards"):
        JoinPlan(R, "l2").on(r_shards=2).build()    # replicated + r_shards
    with pytest.raises(ValueError, match="r_shards"):
        JoinPlan(R, "l2").on(topology="ring").build()
    with pytest.raises(ValueError):                 # more shards than devices
        JoinPlan(R, "l2").on(topology="ring", r_shards=64).build()
    eng = JoinEngine(R, "l2", backend="jnp")        # replicated engine
    with pytest.raises(ValueError, match="placement"):
        JoinPlan(R, "l2").on(engine=eng, topology="ring",
                             r_shards=1).build()
    assert isinstance(resolve_topology("ring"), RingSharded)
    assert resolve_topology(None).name == "replicated"


def test_clear_program_cache(world):
    """clear_program_cache() must evict the module-level compiled-program
    caches (long-lived serve processes / test suites would otherwise pin
    executables for discarded meshes) and the engine must transparently
    rebuild afterwards."""
    from repro.core import engine as engine_mod
    R, Q, _ = world
    eng = JoinEngine(R, "l2", backend="jnp")
    want = eng.range_count(Q, 0.8)
    assert engine_mod._hist_program.cache_info().currsize > 0
    engine_mod.clear_program_cache()
    assert engine_mod._hist_program.cache_info().currsize == 0
    assert engine_mod._compact_program.cache_info().currsize == 0
    np.testing.assert_array_equal(eng.range_count(Q, 0.8), want)


def test_groundtruth_engine_reuse(world):
    """cardinality_table(engine=...) must reuse the prebuilt engine's
    device-resident R (identical counts) and reject an engine built over
    a different index set instead of silently sweeping the wrong R."""
    from repro.data.groundtruth import cardinality_table
    R, Q, eps = world
    eng = JoinEngine(R, "l2", backend="jnp")
    want = cardinality_table(Q, R, eps, "l2", backend="jnp")
    np.testing.assert_array_equal(
        cardinality_table(Q, R, eps, "l2", engine=eng), want)
    with pytest.raises(ValueError, match="different"):
        cardinality_table(Q, R[:100], eps, "l2", engine=eng)
    with pytest.raises(ValueError, match="different"):
        cardinality_table(Q, R, eps, "cosine", engine=eng)


# ------------------------------------------------- exact-target clamp (bugfix)
def test_exact_targets_clamped_on_outliers():
    """An isolated point has range-count 1 (itself); after the self-match
    subtraction its exact-mode target must clamp to 0, matching the interp
    targets built from cardinality_table — not go to -1 and bias XDT."""
    rng = np.random.default_rng(7)
    # tight cluster around e1 ...
    core = _unit(rng, 120, 8) * 0.05
    core[:, 0] += 1.0
    core /= np.linalg.norm(core, axis=1, keepdims=True)
    # ... plus 6 mutually-orthogonal isolated points. At norm 0.5 they do
    # not even self-match on the cosine grid (d_self = 1 - 0.25 = 0.75 >
    # 0.4), so their raw exact count is 0 and the unclamped target is -1.
    outliers = 0.5 * np.eye(8, dtype=np.float32)[2:]
    R = np.concatenate([core, outliers]).astype(np.float32)
    cfg = XlingConfig(estimator="linear", metric="cosine", m=10,
                      backend="jnp", target_mode="exact")
    filt = XlingFilter(cfg).fit(R)
    eps = float(filt.eps_grid[0])
    exact = filt._targets_at(eps)
    assert (exact >= 0).all(), exact.min()
    interp = np.asarray(
        __import__("repro.core.xdt", fromlist=["interp_targets"]).interp_targets(
            filt.eps_grid, filt.target_table, eps))
    # both conventions agree on the isolated points: target exactly 0
    iso = exact[len(core):]
    np.testing.assert_array_equal(iso, np.zeros_like(iso))
    np.testing.assert_allclose(exact, interp, atol=1e-6)


# ------------------------------------------------------- multi-device (mesh)
@pytest.mark.slow
def test_sharded_engine_subprocess_8dev():
    """Forced 8-host-device subprocess (mirrors test_system): the sharded
    sweep must distribute the query axis over all devices and stay
    bit-for-bit equal to the ref backend, for the raw engine AND for
    cardinality_table; the compact/verify program must agree too."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    code = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'\n"
        "import numpy as np, jax\n"
        "from repro.launch.mesh import make_data_mesh\n"
        "from repro.core.engine import JoinEngine\n"
        "from repro.data.groundtruth import cardinality_table\n"
        "assert len(jax.devices()) == 8\n"
        "rng = np.random.default_rng(1)\n"
        "def unit(n, d):\n"
        "    x = rng.normal(size=(n, d)).astype(np.float32)\n"
        "    return x / np.linalg.norm(x, axis=1, keepdims=True)\n"
        "R, Q = unit(700, 16), unit(357, 16)\n"
        "eps = np.linspace(0.2, 1.8, 19).astype(np.float32)\n"
        "mesh = make_data_mesh()\n"
        "eng = JoinEngine(R, 'l2', mesh=mesh, backend='jnp')\n"
        "out = eng.device_range_count_hist(Q, eps)\n"
        "assert len({s.device for s in out.addressable_shards}) == 8\n"
        "ref_eng = JoinEngine(R, 'l2', backend='ref')\n"
        "want = ref_eng.range_count_hist(Q, eps)\n"
        "np.testing.assert_array_equal(eng.range_count_hist(Q, eps), want)\n"
        "t_mesh = cardinality_table(Q, R, eps, 'l2', backend='jnp', mesh=mesh)\n"
        "t_ref = cardinality_table(Q, R, eps, 'l2', backend='ref')\n"
        "np.testing.assert_array_equal(t_mesh, t_ref)\n"
        "v = rng.random(len(Q)) > 0.4\n"
        "res = eng.filtered_join(Q, float(eps[9]), verdicts=v)\n"
        "np.testing.assert_array_equal(res.counts, np.where(v, want[:, 9], 0))\n"
        "print('ENGINE_SHARDED_OK')\n"
    )
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                         capture_output=True, text=True, timeout=300)
    assert "ENGINE_SHARDED_OK" in out.stdout, out.stderr[-2000:]


@pytest.mark.slow
def test_ring_topology_subprocess_8dev():
    """Forced 8-host-device subprocess: the ring topology (R row-sharded
    over the r axis, ppermute ring sweep) must stay bit-for-bit equal to
    the ref oracle on a 2x4 (r, data) mesh — raw sweep, compaction,
    sharded candidate verification, and the async stream — and on a 4x2
    mesh `JoinPlan.describe()` must report per-device R bytes reduced 4x
    vs the replicated placement."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    code = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'\n"
        "import numpy as np, jax\n"
        "from repro.launch.mesh import make_join_mesh\n"
        "from repro.core.engine import JoinEngine\n"
        "from repro.core.api import JoinPlan\n"
        "from repro.core.joins.common import verify_candidates\n"
        "assert len(jax.devices()) == 8\n"
        "rng = np.random.default_rng(2)\n"
        "def unit(n, d):\n"
        "    x = rng.normal(size=(n, d)).astype(np.float32)\n"
        "    return x / np.linalg.norm(x, axis=1, keepdims=True)\n"
        "R, Q = unit(700, 16), unit(357, 16)\n"
        "eps = np.linspace(0.2, 1.8, 19).astype(np.float32)\n"
        "ref_eng = JoinEngine(R, 'l2', backend='ref')\n"
        "want = ref_eng.range_count_hist(Q, eps)\n"
        "mesh = make_join_mesh(data=4, r=2)\n"
        "assert dict(zip(mesh.axis_names, mesh.devices.shape)) == "
        "{'r': 2, 'data': 4}\n"
        "eng = JoinEngine(R, 'l2', mesh=mesh, backend='jnp', "
        "topology='ring')\n"
        "out = eng.device_range_count_hist(Q, eps)\n"
        "assert len({s.device for s in out.addressable_shards}) == 8\n"
        "assert len({s.device for s in eng._Rdev.addressable_shards}) == 8\n"
        "np.testing.assert_array_equal(eng.range_count_hist(Q, eps), want)\n"
        "for seed in (0, 1):\n"
        "    v = np.random.default_rng(seed).random(len(Q)) > 0.4\n"
        "    res = eng.filtered_join(Q, float(eps[9]), verdicts=v)\n"
        "    np.testing.assert_array_equal(res.counts, "
        "np.where(v, want[:, 9], 0))\n"
        "cand = rng.integers(-1, len(R), size=(len(Q), 33)).astype(np.int32)\n"
        "want_vc = verify_candidates(R, Q, cand, 0.8, 'l2', backend='jnp')\n"
        "got_vc = verify_candidates(eng._Rdev, Q, cand, 0.8, 'l2', "
        "backend='jnp', mesh=mesh, r_axis='r', "
        "shard_rows=eng.nr_padded // eng.r_shards)\n"
        "np.testing.assert_array_equal(got_vc, want_vc)\n"
        "batches = [Q[:50], Q[50:51], Q[51:200], Q[200:]]\n"
        "sync = [eng.filtered_join(b, 0.8, verdicts=np.ones(len(b), bool)) "
        "for b in batches]\n"
        "stream = list(eng.stream(batches, 0.8, depth=2))\n"
        "for s, a in zip(sync, stream):\n"
        "    np.testing.assert_array_equal(a.counts, s.counts)\n"
        # JoinPlan on a 4x2 mesh: counts identical to replicated/ref AND
        # per-device R bytes down 4x (|R|=4096 divides 4*block_r evenly)
        "R2, Q2 = unit(4096, 16), unit(193, 16)\n"
        "mesh4 = make_join_mesh(data=2, r=4)\n"
        "ring_plan = JoinPlan(R2, 'l2').filter('none').on(mesh=mesh4, "
        "backend='jnp', topology='ring')\n"
        "rep_plan = JoinPlan(R2, 'l2').filter('none').on(backend='jnp')\n"
        "want2 = JoinEngine(R2, 'l2', backend='ref').range_count(Q2, 0.8)\n"
        "a, b = ring_plan.run(Q2, 0.8), rep_plan.run(Q2, 0.8)\n"
        "np.testing.assert_array_equal(a.counts, want2)\n"
        "np.testing.assert_array_equal(b.counts, want2)\n"
        "sc = np.concatenate([r.counts for r in "
        "ring_plan.stream([Q2[:100], Q2[100:]], 0.8)])\n"
        "np.testing.assert_array_equal(sc, want2)\n"
        "tr = ring_plan.describe()['exec']['topology']\n"
        "tp = rep_plan.describe()['exec']['topology']\n"
        "assert tr['name'] == 'ring' and tr['r_shards'] == 4, tr\n"
        "assert tp['per_device_r_bytes'] == 4 * tr['per_device_r_bytes'], "
        "(tp, tr)\n"
        "print('RING_TOPOLOGY_OK')\n"
    )
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                         capture_output=True, text=True, timeout=300)
    assert "RING_TOPOLOGY_OK" in out.stdout, out.stderr[-2000:]


# ------------------------------------------------------------- mesh compat
def test_make_mesh_no_axistype_dependency():
    """The compat helper must build meshes on JAX versions without
    jax.sharding.AxisType (the installed 0.4.x) and with explicit devices."""
    import jax
    from repro.launch.mesh import (make_cpu_mesh, make_data_mesh,
                                   make_join_mesh, make_mesh)
    m = make_mesh((1, 1), ("data", "model"))
    assert m.axis_names == ("data", "model")
    m2 = make_mesh((1,), ("data",), devices=jax.devices()[:1])
    assert m2.devices.shape == (1,)
    assert make_cpu_mesh().axis_names == ("data", "model")
    assert make_data_mesh().axis_names == ("data",)
    assert make_join_mesh(data=1, r=1).axis_names == ("r", "data")
    with pytest.raises(ValueError):
        make_join_mesh(r=0)
    with pytest.raises(ValueError):
        make_join_mesh(r=len(jax.devices()) + 1)
