"""xlint suite tests: every rule fires, the repo lints clean, and the
program-cache registry is complete (DESIGN.md §12).

Three layers: (1) each rule is proven NON-VACUOUS — it fires on a
synthetic fixture violation at the exact line with the exact rule id,
and stays quiet on the clean fixture; (2) the CLI contract (`python
scripts/xlint` exit codes, `--rule` filtering, `--list-rules`) and the
acceptance gate that the repo itself lints clean; (3) the runtime side
of the cache-registry rule — all eleven program caches (the dynamic-R
delta/tombstone builders included) are registered in
`engine._PROGRAM_CACHES` and `clear_program_cache()` evicts through the
registry, not a hand-maintained list.
"""
import functools
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
FIXTURES = REPO / "tests" / "fixtures" / "xlint"

sys.path.insert(0, str(REPO / "scripts"))

from xlint import RULES, lint_paths, rules_for  # noqa: E402


def _lint(name, rule_ids=None):
    vs = lint_paths([FIXTURES / name], rules_for(rule_ids), root=REPO)
    return vs, {(v.rule, v.line) for v in vs}


# fixture -> the EXACT (rule-id, line) findings a full-rule lint yields
EXPECTED = {
    "bad_mesh.py": {("mesh-policy", 7)},
    "bad_host_sync.py": {("host-sync", 7)},
    # invalid kind: the host-sync finding is unsuppressible AND the
    # annotation goes unconsumed, so hygiene flags it stale too
    "bad_sync_kind.py": {("host-sync", 9), ("annotation-hygiene", 8)},
    "bad_cache.py": {("cache-registry", 7)},
    # the *_program naming-convention direction: no lru_cache at all
    "bad_program_builder.py": {("cache-registry", 6)},
    "bad_cache_key.py": {("jit-cache-key", 7)},
    "bad_docstring.py": {("docstring-gate", 5)},
    "bad_annotation.py": {("annotation-hygiene", 4),
                          ("annotation-hygiene", 5),
                          ("annotation-hygiene", 6)},
}


@pytest.mark.parametrize("fixture", sorted(EXPECTED))
def test_rule_fires_on_fixture(fixture):
    """Each fixture violation is caught at the right line by the right
    rule — and by NOTHING else (no cross-rule false positives)."""
    _, got = _lint(fixture)
    assert got == EXPECTED[fixture]


@pytest.mark.parametrize("fixture,rule_id", sorted(
    {(f, r) for f, pairs in EXPECTED.items() for r, _ in pairs}))
def test_rule_fires_in_isolation(fixture, rule_id):
    """`--rule <id>` alone still catches its fixture's violation."""
    _, got = _lint(fixture, [rule_id])
    assert any(r == rule_id for r, _ in got)


def test_clean_fixture_passes():
    """The clean fixture opts into every rule and yields zero findings —
    including annotation-hygiene on its consumed allow-host-sync."""
    vs, _ = _lint("clean.py")
    assert vs == []


def test_bad_kind_is_unsuppressible():
    """An allow-host-sync naming an undeclared kind cannot silence the
    finding — the violation it 'covers' survives with suppressible=False."""
    vs, _ = _lint("bad_sync_kind.py", ["host-sync"])
    (v,) = vs
    assert v.rule == "host-sync" and not v.suppressible


def test_registry_table_complete():
    """All six rules are registered with a DESIGN.md section mapping."""
    assert set(RULES) == {"mesh-policy", "host-sync", "cache-registry",
                          "jit-cache-key", "docstring-gate",
                          "annotation-hygiene"}
    for rule in RULES.values():
        assert rule.design_ref.startswith("§"), rule.id
        assert rule.description, rule.id
    with pytest.raises(KeyError):
        rules_for(["no-such-rule"])


# ---------------------------------------------------------------- CLI


def _cli(*args):
    return subprocess.run([sys.executable, "scripts/xlint", *args],
                          cwd=REPO, capture_output=True, text=True)


def test_repo_lints_clean():
    """The acceptance gate: `python scripts/xlint` exits 0 on the repo."""
    out = _cli()
    assert out.returncode == 0, out.stdout + out.stderr


def test_cli_reports_violations():
    out = _cli(str(FIXTURES / "bad_mesh.py"))
    assert out.returncode == 1
    assert "[mesh-policy]" in out.stdout and "bad_mesh.py:7" in out.stdout


def test_cli_rule_filter():
    """--rule narrows the run: bad_mesh is clean under docstring-gate."""
    out = _cli("--rule", "docstring-gate", str(FIXTURES / "bad_mesh.py"))
    assert out.returncode == 0, out.stdout + out.stderr


def test_cli_list_rules():
    out = _cli("--list-rules")
    assert out.returncode == 0
    for rule_id in RULES:
        assert rule_id in out.stdout


# ------------------------------------------------- runtime registry


def test_program_cache_registry_complete():
    """Every lru_cache program builder in core/ is in _PROGRAM_CACHES —
    the runtime fact the cache-registry static rule guarantees."""
    from repro.core import engine, probe
    from repro.core.joins import common
    expected = {
        engine._hist_program, engine._compact_program,
        engine._delete_program, engine._delta_count_program,
        engine._delta_hist_program,
        common._sharded_verify_program,
        probe._gather_program, probe._lsh_probe_program,
        probe._lsh_ring_probe_program, probe._probe_verify_program,
        probe._ring_probe_verify_program,
    }
    registered = set(engine._PROGRAM_CACHES)
    assert expected <= registered
    for cache in registered:            # registry holds evictable caches
        assert hasattr(cache, "cache_clear") and hasattr(cache, "cache_info")


def test_clear_program_cache_iterates_registry():
    """clear_program_cache() evicts through the registry, so a builder
    registered AFTER engine import is still cleared."""
    from repro.core import engine

    @engine.register_program_cache
    @functools.lru_cache(maxsize=8)
    def _dummy_program(n):
        return n * 2

    try:
        _dummy_program(3)
        assert _dummy_program.cache_info().currsize == 1
        engine.clear_program_cache()
        assert _dummy_program.cache_info().currsize == 0
    finally:
        engine._PROGRAM_CACHES.remove(_dummy_program)
