"""Serving gateway (DESIGN.md §14): scatter-back bit-parity, the
eps-aware result cache under mutation, coalescing, adaptive depth, and
the tenant-class contract.

The headline contracts:

* scatter-back parity — every ticket's counts are bit-identical to the
  tenant's own `JoinPlan.run` on just that request's rows (per-row
  counts are independent of batch composition), on both topologies and
  in a forced-8-device subprocess;
* cache soundness — hits are bit-identical, never cross eps buckets or
  tenant classes, and NEVER survive a world-version bump: a randomized
  insert/delete/compact sequence interleaved with REPEATED queries
  stays pointwise bit-identical to a fresh `ShadowOracle` while the
  cache demonstrably serves hits between mutations (non-vacuity);
* one engine — all tenant plans share the gateway's pinned engine.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.kernels import ref
from repro.serve import (Coalescer, DepthController, Gateway, PendingRows,
                         ResultCache, TenantClass, fingerprint_rows)

EPS = 0.45
DIM = 16


def _unit(rng, n, d=DIM):
    x = rng.normal(size=(n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def _shadow_counts(live: dict, Q, eps, metric="cosine"):
    world = np.stack(list(live.values()))
    return np.asarray(ref.range_count(Q, world, eps, metric=metric))


CLASSES = [
    TenantClass("gold", eps=EPS, verify="exact"),
    TenantClass("silver", eps=0.5, recall_target=0.9, verify="lsh",
                verify_params=dict(k=10, l=8, n_probes=4, W=2.5),
                slo_ms=10_000.0),
]


def _gateway(rng, n=240, classes=CLASSES, **kw):
    R = _unit(rng, n)
    return R, Gateway(R, classes, metric="cosine", backend="jnp", **kw)


# ------------------------------------------------- scatter-back parity
def test_scatter_parity_replicated():
    """Interleaved sub-bucket requests from two classes coalesce into
    shared batches, and each ticket's counts are bit-identical to the
    tenant's own plan run alone on its rows."""
    rng = np.random.default_rng(0)
    R, gw = _gateway(rng)
    assert gw.plan("gold").engine is gw.engine
    assert gw.plan("silver").engine is gw.engine

    reqs = [(CLASSES[i % 2].name, _unit(rng, int(rng.integers(3, 20))))
            for i in range(10)]
    tickets = [gw.submit(name, q) for name, q in reqs]
    gw.flush()
    for (name, q), t in zip(reqs, tickets):
        assert t.done
        want = np.asarray(gw.plan(name).run(q, t.eps).counts)
        np.testing.assert_array_equal(t.counts, want, err_msg=name)

    rep = gw.report()
    m = rep["tenants"]["gold"]["metrics"]
    assert m["admitted_requests"] == 5
    assert m["coalesced_requests"] >= 2   # sub-bucket requests DID share
    assert m["coalesced_batches"] >= 1


def test_scatter_parity_ring():
    from repro.launch.mesh import make_join_mesh
    rng = np.random.default_rng(1)
    R, gw = _gateway(rng, mesh=make_join_mesh(data=1, r=1),
                     topology="ring")
    reqs = [(CLASSES[i % 2].name, _unit(rng, 7)) for i in range(6)]
    tickets = [gw.submit(name, q) for name, q in reqs]
    gw.flush()
    for (name, q), t in zip(reqs, tickets):
        np.testing.assert_array_equal(
            t.counts, np.asarray(gw.plan(name).run(q, t.eps).counts),
            err_msg=name)


def test_scatter_parity_learned_tenant():
    """A frozen gateway serves the learned (RMI) route as a tenant
    class; its scattered counts match its plan run."""
    rng = np.random.default_rng(2)
    classes = CLASSES + [TenantClass("rmi", eps=EPS, verify="learned",
                                     verify_params=dict(epochs=8))]
    R, gw = _gateway(rng, classes=classes)
    q = _unit(rng, 9)
    t = gw.join("rmi", q)
    np.testing.assert_array_equal(
        t.counts, np.asarray(gw.plan("rmi").run(q, EPS).counts))


@pytest.mark.slow
def test_gateway_subprocess_8dev():
    """Forced 8-host-device subprocess: gateway scatter-back parity on
    a replicated data mesh and a 4x2 ring mesh."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    code = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'\n"
        "import numpy as np, jax\n"
        "from repro.launch.mesh import make_data_mesh, make_join_mesh\n"
        "from repro.serve import Gateway, TenantClass\n"
        "assert len(jax.devices()) == 8\n"
        "rng = np.random.default_rng(6)\n"
        "def unit(n):\n"
        "    x = rng.normal(size=(n, 16)).astype(np.float32)\n"
        "    return x / np.linalg.norm(x, axis=1, keepdims=True)\n"
        "R = unit(300)\n"
        "classes = [TenantClass('gold', eps=0.45, verify='exact'),\n"
        "           TenantClass('silver', eps=0.5, recall_target=0.9,\n"
        "                       verify='lsh',\n"
        "                       verify_params=dict(k=10, l=8, n_probes=4,\n"
        "                                          W=2.5))]\n"
        "for mesh, topo in ((make_data_mesh(), None),\n"
        "                   (make_join_mesh(data=4, r=2), 'ring')):\n"
        "    gw = Gateway(R, classes, backend='jnp', mesh=mesh,\n"
        "                 topology=topo)\n"
        "    reqs = [(classes[i % 2].name, unit(7)) for i in range(6)]\n"
        "    tickets = [gw.submit(n, q) for n, q in reqs]\n"
        "    gw.flush()\n"
        "    for (n, q), t in zip(reqs, tickets):\n"
        "        want = np.asarray(gw.plan(n).run(q, t.eps).counts)\n"
        "        np.testing.assert_array_equal(t.counts, want)\n"
        "print('GATEWAY_MESH_OK')\n"
    )
    out = subprocess.run(
        [sys.executable, "-c", code], env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True, text=True, timeout=600)
    assert "GATEWAY_MESH_OK" in out.stdout, out.stderr[-3000:]


# ------------------------------------------------------ eps-aware cache
def test_cache_hits_are_bit_identical():
    rng = np.random.default_rng(3)
    R, gw = _gateway(rng)
    q = _unit(rng, 8)
    t1 = gw.join("gold", q)
    t2 = gw.join("gold", q)
    assert t2.meta["cache_hits"] == len(q)
    np.testing.assert_array_equal(t2.counts, t1.counts)
    # partial overlap: only the repeated rows hit
    q2 = np.concatenate([q[:3], _unit(rng, 4)])
    t3 = gw.join("gold", q2)
    assert t3.meta["cache_hits"] == 3
    np.testing.assert_array_equal(t3.counts[:3], t1.counts[:3])


def test_cache_is_eps_and_tenant_aware():
    """Same rows at a different eps — or from a different class — must
    not hit the other bucket's entries."""
    rng = np.random.default_rng(4)
    R, gw = _gateway(rng)
    q = _unit(rng, 6)
    gw.join("gold", q)
    assert gw.join("gold", q, eps=0.6).meta["cache_hits"] == 0
    assert gw.join("silver", q, eps=EPS).meta["cache_hits"] == 0
    assert gw.join("gold", q).meta["cache_hits"] == len(q)


def test_eps_quantum_snaps_buckets():
    """Explicit radii snap to the quantum grid: nearby radii share one
    bucket (and its cache entries), and the ticket reports the EXECUTED
    eps."""
    rng = np.random.default_rng(5)
    R, gw = _gateway(rng, eps_quantum=0.05)
    q = _unit(rng, 5)
    t1 = gw.join("gold", q, eps=0.4501)
    t2 = gw.join("gold", q, eps=0.4499)
    assert t1.eps == t2.eps == 0.45
    assert t2.meta["cache_hits"] == len(q)
    np.testing.assert_array_equal(t2.counts, t1.counts)


def test_cache_never_survives_world_bump():
    """Randomized mutation sequence interleaved with REPEATED queries:
    pointwise bit-identity vs a fresh shadow oracle, cache hits between
    mutations (non-vacuity), zero hits on the first post-bump replay."""
    rng = np.random.default_rng(6)
    classes = [TenantClass("gold", eps=EPS, verify="exact"),
               TenantClass("bulk", eps=EPS, recall_target=0.9,
                           verify="lsh",
                           verify_params=dict(k=10, l=8, n_probes=4,
                                              W=2.5))]
    R = _unit(rng, 240)
    gw = Gateway(R, classes, backend="jnp", mutable=True,
                 auto_compact_at=None)
    live = {i: R[i] for i in range(len(R))}
    q = _unit(rng, 10)

    def check(post_bump):
        t = gw.join("gold", q)
        if post_bump:
            assert t.meta["cache_hits"] == 0      # bump invalidated all
        np.testing.assert_array_equal(t.counts, _shadow_counts(live, q, EPS))
        t2 = gw.join("gold", q)                   # replay: all hits now
        assert t2.meta["cache_hits"] == len(q)
        np.testing.assert_array_equal(t2.counts, t.counts)
        gw.join("bulk", q)                        # approx route stays live

    check(post_bump=False)
    wv = gw.world_version
    ops = rng.choice(np.array(["insert", "delete", "compact"]),
                     size=8, p=[0.5, 0.35, 0.15])
    for op in ops:
        if op == "insert":
            rows = _unit(rng, int(rng.integers(1, 16)))
            live.update(zip(map(int, gw.insert(rows)), rows))
        elif op == "delete":
            pool = np.fromiter(live, np.int64)
            ids = rng.choice(pool, size=4, replace=False)
            gw.delete(ids)
            for i in ids:
                live.pop(int(i))
        else:
            gw.compact()
        assert gw.world_version == wv + 1
        wv = gw.world_version
        check(post_bump=True)
    assert wv == len(ops)


def test_mutation_flushes_pending_requests():
    """A request admitted before a mutation completes against the
    pre-mutation world: insert() flushes it first, and its counts match
    the shadow oracle at SUBMIT time."""
    rng = np.random.default_rng(7)
    R, gw = _gateway(rng, classes=[TenantClass("gold", eps=EPS)],
                     mutable=True, auto_compact_at=None)
    live = {i: R[i] for i in range(len(R))}
    q = _unit(rng, 6)
    t = gw.submit("gold", q)               # sub-bucket: stays pending
    assert not t.done
    want = _shadow_counts(live, q, EPS)
    rows = _unit(rng, 8)
    live.update(zip(map(int, gw.insert(rows)), rows))
    assert t.done                           # the mutation drained it
    np.testing.assert_array_equal(t.counts, want)


def test_result_cache_unit():
    c = ResultCache(capacity=3)
    c.note_world(0)
    c.put(("t", b"a", 0.45, 0), 3)
    assert c.get(("t", b"a", 0.45, 0)) == 3 and c.hits == 1
    assert c.get(("t", b"b", 0.45, 0)) is None and c.misses == 1
    for k in (b"b", b"c", b"d"):
        c.put(("t", k, 0.45, 0), 1)
    assert len(c) == 3                      # LRU bound
    c.note_world(1)
    assert len(c) == 0                      # generation cleared
    h1, h2 = fingerprint_rows(np.eye(2, 4, dtype=np.float32))
    assert h1 != h2 and isinstance(h1, bytes)


# --------------------------------------------------- coalescer contract
def test_coalescer_never_splits_requests():
    co = Coalescer()
    g = ("t", 0.45)
    for n in (5, 4, 4):
        rows = np.zeros((n, 3), np.float32)
        co.add(g, PendingRows(ticket=None, rows=rows,
                              positions=np.arange(n), hashes=[b""] * n))
    Q, segs = co.take(g, max_rows=8)
    assert len(Q) == 5 and len(segs) == 1   # 5+4 would split the budget
    Q, segs = co.take(g, max_rows=8)
    assert len(Q) == 8 and len(segs) == 2   # both 4s fit whole
    assert (segs[0].start, segs[0].stop, segs[1].start) == (0, 4, 4)
    assert co.take(g, max_rows=8) == (None, [])


# ------------------------------------------------------- adaptive depth
def test_depth_controller_aimd():
    dc = DepthController(depth=2, max_depth=4, slo_ms=100.0)
    assert dc.update(150.0) == 1            # miss: shed immediately
    assert dc.update(150.0) == 0
    assert dc.update(150.0) == 0            # floor
    for _ in range(DepthController.GROW_AFTER):
        d = dc.update(10.0)
    assert d == 1                           # sustained headroom: +1
    assert dc.update(60.0) == 1             # in-band resets the streak
    dc2 = DepthController(depth=2, max_depth=4, slo_ms=None)
    assert dc2.update(1e9) == 2             # no SLO: pinned


def test_gateway_depth_adapts_to_slo():
    rng = np.random.default_rng(8)
    tight = [TenantClass("t", eps=EPS, slo_ms=1e-6, depth=2, max_depth=4)]
    R, gw = _gateway(rng, classes=tight)
    for _ in range(3):
        gw.join("t", _unit(rng, 5))
    rep = gw.report()["tenants"]["t"]
    assert rep["groups"][str(EPS)]["depth"] == 0      # shed to floor
    assert rep["metrics"]["slo_misses"] >= 1

    loose = [TenantClass("t", eps=EPS, slo_ms=1e9, depth=0, max_depth=3)]
    R, gw = _gateway(rng, classes=loose)
    for _ in range(3 * DepthController.GROW_AFTER + 1):
        gw.join("t", _unit(rng, 5))
    assert gw.report()["tenants"]["t"]["groups"][str(EPS)]["depth"] == 3


# --------------------------------------------------- contract/validation
def test_validation_errors():
    rng = np.random.default_rng(9)
    R = _unit(rng, 64)
    with pytest.raises(ValueError, match="at least one"):
        Gateway(R, [])
    with pytest.raises(ValueError, match="duplicate"):
        Gateway(R, [TenantClass("a", eps=EPS), TenantClass("a", eps=0.5)])
    with pytest.raises(ValueError, match="mutable"):
        Gateway(R, [TenantClass("a", eps=EPS, verify="learned")],
                mutable=True)
    with pytest.raises(ValueError, match="share its params"):
        Gateway(R, [TenantClass("a", eps=EPS, verify="lsh",
                                verify_params=dict(k=8, l=4)),
                    TenantClass("b", eps=EPS, verify="lsh",
                                verify_params=dict(k=10, l=4))],
                mutable=True)
    gw = Gateway(R, [TenantClass("a", eps=EPS)])
    with pytest.raises(ValueError, match="unknown tenant"):
        gw.submit("nope", _unit(rng, 2))
    with pytest.raises(ValueError, match="expected"):
        gw.submit("a", np.zeros((2, DIM + 1), np.float32))
    with pytest.raises(ValueError, match="must be > 0"):
        gw.submit("a", _unit(rng, 2), eps=-0.1)
    with pytest.raises(RuntimeError, match="frozen"):
        gw.insert(_unit(rng, 2))
    t = gw.submit("a", _unit(rng, 2))
    with pytest.raises(RuntimeError, match="flush"):
        t.counts
    gw.flush()
    assert t.counts.shape == (2,)
    with pytest.raises(ValueError, match="recall_target"):
        TenantClass("x", eps=EPS, recall_target=1.5)
    with pytest.raises(ValueError, match="max_depth"):
        TenantClass("x", eps=EPS, depth=3, max_depth=1)


def test_tenant_class_auto_verify_resolution():
    assert TenantClass("a", eps=1.0).resolved_verify() == "exact"
    assert TenantClass("a", eps=1.0,
                       recall_target=0.97).resolved_verify() == "ivfpq"
    assert TenantClass("a", eps=1.0,
                       recall_target=0.8).resolved_verify() == "lsh"
    assert TenantClass("a", eps=1.0, recall_target=0.8,
                       verify="exact").resolved_verify() == "exact"


def test_report_shape():
    rng = np.random.default_rng(10)
    R, gw = _gateway(rng)
    gw.join("gold", _unit(rng, 4))
    rep = gw.report()
    assert set(rep) == {"world_version", "mutable", "eps_quantum",
                        "max_batch_rows", "n_index", "cache", "tenants"}
    assert set(rep["tenants"]) == {"gold", "silver"}
    trow = rep["tenants"]["gold"]
    assert trow["verify"] == "exact"
    m = trow["metrics"]
    for key in ("admitted_requests", "admitted_queries", "served_requests",
                "cache_hit_queries", "cache_miss_queries", "batches",
                "coalesced_batches", "coalesced_requests", "slo_misses",
                "p50_ms", "p95_ms"):
        assert key in m
    assert m["p50_ms"] is not None
    import json
    json.dumps(rep)                         # report is serializable
