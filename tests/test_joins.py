"""Join-method correctness: exact methods match naive exactly; approximate
methods reach reasonable recall; the Xling plugin accelerates without
destroying quality."""
import numpy as np
import pytest

from repro.core import XlingConfig, XlingFilter, build_xjoin, enhance_with_xling, make_join
from repro.core.joins.lsbf import LSBF
from repro.core.xjoin import FilteredJoin


@pytest.fixture(scope="module")
def data():
    from repro.data import load_dataset
    R, S, spec = load_dataset("sift", n=2000, seed=0)
    return R, S[:150], spec


@pytest.fixture(scope="module")
def truth(data):
    R, S, spec = data
    naive = make_join("naive", R, spec.metric, backend="jnp")
    return naive.query_counts(S, 0.45)


def test_grid_join_exact(data, truth):
    R, S, spec = data
    g = make_join("grid", R, spec.metric)
    np.testing.assert_array_equal(g.query_counts(S, 0.45), truth)


def test_grid_join_exact_other_eps(data):
    R, S, spec = data
    naive = make_join("naive", R, spec.metric, backend="jnp")
    g = make_join("grid", R, spec.metric)
    for eps in (0.3, 0.6):
        np.testing.assert_array_equal(g.query_counts(S, eps),
                                      naive.query_counts(S, eps))


def test_lsh_join_recall(data, truth):
    R, S, spec = data
    j = make_join("lsh", R, spec.metric, k=12, l=10, n_probes=4, W=2.0)
    cnt = j.query_counts(S, 0.45)
    assert (cnt <= truth).all()          # never finds a false pair
    rec = np.minimum(cnt, truth).sum() / max(truth.sum(), 1)
    assert rec > 0.4, rec


def test_kmeans_tree_recall(data, truth):
    R, S, spec = data
    j = make_join("kmeanstree", R, spec.metric, branching=3, rho=0.05)
    cnt = j.query_counts(S, 0.45)
    assert (cnt <= truth).all()
    rec = np.minimum(cnt, truth).sum() / max(truth.sum(), 1)
    assert rec > 0.7, rec


def test_learned_join_recall(data, truth):
    R, S, spec = data
    j = make_join("learned", R, spec.metric, epochs=16)
    cnt = j.query_counts(S, 0.45)
    assert (cnt <= truth).all()          # verified candidates: no false pair
    rec = np.minimum(cnt, truth).sum() / max(truth.sum(), 1)
    assert rec > 0.95, rec


def test_learned_join_selective_on_clustered_data():
    """On data whose distance-to-pivot actually varies — unit-sphere
    clusters at distinct ANGLES to the shared axis, so the centroid
    sits off-center and every cluster lands in its own key band — the
    window must PRUNE (mean candidate width well under |R|) while
    keeping the recall floor. This is the non-vacuity check the
    isotropic fixtures can't provide: there every key collapses to ~1
    and the window spans all of R."""
    rng = np.random.default_rng(3)
    theta = 0.1 + 0.22 * np.arange(6)    # angles to the shared axis
    axis = np.zeros(32)
    axis[0] = 1.0
    perp = rng.normal(size=(6, 32))
    perp[:, 0] = 0.0
    perp /= np.linalg.norm(perp, axis=1, keepdims=True)
    c = np.cos(theta)[:, None] * axis + np.sin(theta)[:, None] * perp

    def draw(per):
        p = np.repeat(c, per, axis=0) + rng.normal(size=(6 * per, 32)) * 0.005
        return (p / np.linalg.norm(p, axis=1, keepdims=True)
                ).astype(np.float32)

    R, S = draw(300), draw(20)
    naive = make_join("naive", R, "cosine", backend="jnp")
    truth = naive.query_counts(S, 0.002)
    assert truth.sum() > 0               # clusters make real neighbors
    j = make_join("learned", R, "cosine", epochs=16)
    cnt = j.query_counts(S, 0.002)
    assert (cnt <= truth).all()
    rec = np.minimum(cnt, truth).sum() / max(truth.sum(), 1)
    assert rec > 0.95, rec
    cand = j.candidates(S, eps=0.002)
    width = (cand >= 0).sum(axis=1).mean()
    assert width < 0.5 * len(R), width   # the window actually prunes


def test_ivfpq_recall(data, truth):
    R, S, spec = data
    j = make_join("ivfpq", R, spec.metric, C=32, n_probe=6, n_candidates=400)
    cnt = j.query_counts(S, 0.45)
    assert (cnt <= truth).all()
    rec = np.minimum(cnt, truth).sum() / max(truth.sum(), 1)
    assert rec > 0.6, rec


def test_lsbf_is_a_filter(data, truth):
    R, S, spec = data
    f = LSBF(R, spec.metric, k=10, l=6, W=2.0)
    v = f.query(S)
    assert v.dtype == bool and v.shape == (len(S),)
    # it must do better than accepting everything on negatives while keeping
    # some positives (the paper's LSBF has high FNR — we just need sanity)
    gt_pos = truth > 0
    assert v[gt_pos].mean() > 0.1


@pytest.mark.slow
def test_xjoin_end_to_end(data, truth):
    R, S, spec = data
    xcfg = XlingConfig(estimator="nn", metric=spec.metric, epochs=6,
                       backend="jnp", m=40)
    xj = build_xjoin(R, spec.metric, xling_cfg=xcfg, tau=0, backend="jnp")
    res = xj.run(S, 0.45)
    assert res.n_searched <= res.n_queries
    assert res.recall_vs(truth) > 0.5
    # tau=50 filters more, recall may drop but search volume must shrink
    xj50 = FilteredJoin(xj.base, filter=xj.filter, tau=50, xdt_mode="fpr")
    res50 = xj50.run(S, 0.45)
    assert res50.n_searched <= res.n_searched


@pytest.mark.slow
def test_xling_plugin_on_lsh(data, truth):
    R, S, spec = data
    xcfg = XlingConfig(estimator="nn", metric=spec.metric, epochs=6,
                       backend="jnp", m=40)
    filt = XlingFilter(xcfg).fit(R)
    base = make_join("lsh", R, spec.metric, k=12, l=10, n_probes=4, W=2.0)
    plain = base.query_counts(S, 0.45)
    enhanced = enhance_with_xling(base, filt, tau=0)
    res = enhanced.run(S, 0.45)
    # enhanced method searches fewer queries...
    assert res.n_searched <= len(S)
    # ...and loses little of the base method's recall
    base_rec = np.minimum(plain, truth).sum() / max(truth.sum(), 1)
    enh_rec = res.recall_vs(truth)
    assert enh_rec >= base_rec - 0.25


def test_filtered_join_all_negative_short_circuit(data):
    R, S, spec = data
    fj = FilteredJoin(make_join("naive", R, spec.metric, backend="jnp"),
                      filter=lambda Q, eps: np.zeros(len(Q), bool))
    res = fj.run(S, 0.45)
    assert res.n_searched == 0
    assert (res.counts == 0).all()
