"""The protocol-first public API (core/api.py, DESIGN.md §9).

Covers: the full `<method> x filter` plugin matrix — every registered join
method composed with the Xling filter AND the LSBF baseline through
`JoinPlan` (count parity vs the unfiltered base on predicted-positive
queries, zeros on skipped queries, skip-rate sanity); engine-vs-host
verification parity for non-naive bases; the acceptance invariant that
`plan.stream` is bit-identical to per-batch `plan.run` on the engine path
with a NON-naive base; build-time validation of every invalid
filter/search/verify combination (including the legacy `FilteredJoin`
shim inheriting the construction-time check); protocol conformance of the
registered joins and filter adapters; and `describe()` serializability.
"""
import json

import numpy as np
import pytest

from repro.core import (Filter, JoinPlan, Searcher, XlingConfig, XlingFilter,
                        as_filter, make_join)
from repro.core.api import CallableAdapter, LSBFAdapter, XlingAdapter
from repro.core.engine import JoinEngine
from repro.core.joins import JOINS
from repro.core.joins.lsbf import LSBF
from repro.core.xjoin import FilteredJoin

EPS = 0.45

#: Small-but-meaningful per-method constructor params for the matrix.
METHOD_PARAMS = {
    "naive": {},
    "grid": {},
    "lsh": dict(k=12, l=10, n_probes=4, W=2.0),
    "kmeanstree": dict(branching=3, rho=0.05),
    "ivfpq": dict(C=32, n_probe=6, n_candidates=400),
    "learned": dict(epochs=8),
}


@pytest.fixture(scope="module")
def data():
    from repro.data import load_dataset
    R, S, spec = load_dataset("sift", n=1500, seed=0)
    return R, S[:120], spec


@pytest.fixture(scope="module")
def bases(data):
    R, _, spec = data
    return {name: make_join(name, R, spec.metric, backend="jnp",
                            **METHOD_PARAMS[name])
            for name in JOINS}


@pytest.fixture(scope="module")
def xling(data):
    R, _, spec = data
    cfg = XlingConfig(estimator="nn", metric=spec.metric, epochs=3,
                      backend="jnp", m=12)
    return XlingFilter(cfg).fit(R)


@pytest.fixture(scope="module")
def lsbf(data):
    R, _, spec = data
    return LSBF(R, spec.metric, k=10, l=6, W=2.0)


# ------------------------------------------------------- protocol conformance
def test_joins_satisfy_searcher_protocol(bases):
    for name, j in bases.items():
        assert isinstance(j, Searcher), name
        assert isinstance(j.name, str) and isinstance(j.exact, bool)
    # every non-naive method exposes the probe half of the split
    for name in set(JOINS) - {"naive"}:
        assert hasattr(bases[name], "candidates"), name


def test_filter_adapters_satisfy_protocol(xling, lsbf):
    ax = as_filter(xling, tau=3, xdt_mode="fpr")
    al = as_filter(lsbf)
    ac = as_filter(lambda Q, eps: np.zeros(len(Q), bool))
    assert isinstance(ax, XlingAdapter) and ax.tau == 3
    assert isinstance(al, LSBFAdapter)
    assert isinstance(ac, CallableAdapter)
    for a in (ax, al, ac):
        assert isinstance(a, Filter)
    assert as_filter(ax) is ax           # protocol objects pass through
    assert as_filter(None) is None
    with pytest.raises(TypeError):
        as_filter(object())
    # the fused device form exists exactly where advertised
    assert ax.device_filter(EPS) is not None
    assert not hasattr(al, "device_filter")


# ---------------------------------------------------- the <method>-Xling matrix
@pytest.mark.parametrize("method", sorted(JOINS))
@pytest.mark.parametrize("fname", ["xling", "lsbf"])
def test_method_filter_matrix(data, bases, xling, lsbf, method, fname):
    """Every registered join method composed with both filters through the
    protocol: the filtered plan returns EXACTLY the base method's counts on
    predicted-positive queries and 0 on skipped ones (count parity), with
    n_searched equal to the verdict mass (skip-rate sanity)."""
    R, S, spec = data
    base = bases[method]
    filt, kw = {"xling": (xling, dict(tau=0, xdt="mean")),
                "lsbf": (lsbf, {})}[fname]
    plan = (JoinPlan(R, spec.metric).filter(filt, **kw)
            .search(base).on(backend="jnp").build())
    res = plan.run(S, EPS)
    mask = np.asarray(as_filter(
        filt, tau=kw.get("tau", 0), xdt_mode=kw.get("xdt")).verdicts(S, EPS),
        bool)
    assert res.n_searched == int(mask.sum())
    assert 0 <= res.n_searched <= len(S)
    base_counts = np.asarray(base.query_counts(S, EPS))
    np.testing.assert_array_equal(res.counts[mask], base_counts[mask])
    assert (res.counts[~mask] == 0).all()
    assert res.meta["base"] == method
    assert res.meta["engine"] is True


def test_engine_vs_host_parity_nonnaive(data, bases):
    """Engine-vs-host verification parity for a non-naive base: routing the
    positives through the engine's device-resident (padded) R must count
    exactly what the base's own host-side query_counts path counts."""
    R, S, spec = data
    rng = np.random.default_rng(11)
    verdicts = rng.random(len(S)) > 0.4
    for method in ("lsh", "kmeanstree"):
        base = bases[method]
        plan = (JoinPlan(R, spec.metric)
                .filter(lambda Q, eps, v=verdicts: v)
                .search(base).on(backend="jnp").build())
        res = plan.run(S, EPS)
        want = np.where(verdicts,
                        np.asarray(base.query_counts(S, EPS)), 0)
        np.testing.assert_array_equal(res.counts, want)
        assert res.n_searched == int(verdicts.sum())


# --------------------------------------------- acceptance: non-naive streaming
def test_stream_bit_identical_to_run_nonnaive(data, bases, xling):
    """The acceptance invariant: a plan with a NON-naive base runs its
    positive queries through JoinEngine device candidate verification, and
    plan.stream stays bit-identical to per-batch plan.run on that path."""
    R, S, spec = data
    for method in ("lsh", "grid"):
        plan = (JoinPlan(R, spec.metric).filter(xling, tau=0, xdt="mean")
                .search(bases[method]).on(backend="jnp").build())
        # deliberately ragged batch sizes to exercise distinct shape buckets
        batches = [S[:50], S[50:51], S[51:]]
        sync = [plan.run(b, EPS) for b in batches]
        for depth in (0, 2):
            stream = list(plan.stream(batches, EPS, depth=depth))
            assert len(stream) == len(batches)
            for s, a in zip(sync, stream):
                np.testing.assert_array_equal(a.counts, s.counts)
                assert a.n_searched == s.n_searched
                assert a.meta["verify"] == method  # the base's candidates


def test_verify_backend_swap_on_naive(data, xling):
    """verify("lsh") on the naive base swaps the exact sweep for candidate
    probing: counts never exceed the exact path's (precision 1)."""
    R, S, spec = data
    exact = (JoinPlan(R, spec.metric).filter(xling, tau=0, xdt="mean")
             .search("naive").on(backend="jnp").build())
    approx = (JoinPlan(R, spec.metric).filter(xling, tau=0, xdt="mean")
              .search("naive").verify("lsh", k=10, l=8, n_probes=4, W=2.0)
              .on(engine=exact.engine, backend="jnp").build())
    r_exact, r_approx = exact.run(S, EPS), approx.run(S, EPS)
    assert r_approx.meta["verify"] == "lsh"
    assert r_approx.n_searched == r_exact.n_searched
    assert (r_approx.counts <= r_exact.counts).all()


class _LoopJoin:
    """Minimal Searcher: query_counts only — the paper's generic
    'any loop-based join method' plug-in, with no candidates() probe."""
    name = "loop"
    exact = True

    def __init__(self, R, metric):
        self.R, self.metric = np.asarray(R, np.float32), metric
        self._naive = make_join("naive", self.R, metric, backend="jnp")

    def query_counts(self, Q, eps):
        return self._naive.query_counts(Q, eps)


def test_query_counts_only_base_supported(data):
    """A base exposing ONLY query_counts (no candidates) must still compose
    with a filter — through JoinPlan's auto route (host verification of the
    compacted positives) and through the legacy FilteredJoin shim."""
    R, S, spec = data
    rng = np.random.default_rng(3)
    verdicts = rng.random(len(S)) > 0.5
    base = _LoopJoin(R, spec.metric)
    want = np.where(verdicts, np.asarray(base.query_counts(S, EPS)), 0)
    plan = (JoinPlan(R, spec.metric).filter(lambda Q, eps: verdicts)
            .search(base).on(backend="jnp").build())
    res = plan.run(S, EPS)
    np.testing.assert_array_equal(res.counts, want)
    assert res.meta["verify"] == "loop"
    fj = FilteredJoin(base, filter=lambda Q, eps: verdicts)
    np.testing.assert_array_equal(fj.run(S, EPS).counts, want)


def test_tuned_verifier_pinned_per_plan(data):
    """verify(name, **params) pins the built index to the plan: a second
    plan sharing the engine with different params must not clobber it
    (verify(name) with no params keeps the name — the live retune hook)."""
    R, S, spec = data
    shared = (JoinPlan(R, spec.metric).search("naive")
              .verify("lsh", k=10, l=16).on(backend="jnp").build())
    engine = shared.engine
    other = (JoinPlan(R, spec.metric).search("naive")
             .verify("lsh", k=10, l=4).on(engine=engine,
                                          backend="jnp").build())
    assert shared._built.verify_route.l == 16      # pinned, not clobbered
    assert other._built.verify_route.l == 4
    untuned = (JoinPlan(R, spec.metric).search("naive").verify("lsh")
               .on(engine=engine, backend="jnp").build())
    assert untuned._built.verify_route == "lsh"    # name: retune-able


# ----------------------------------------------------- build-time validation
def test_build_time_validation(data, bases, xling):
    R, S, spec = data
    with pytest.raises(ValueError, match="unknown join method"):
        JoinPlan(R, spec.metric).search("annoy").build()
    with pytest.raises(ValueError, match="unknown filter"):
        JoinPlan(R, spec.metric).filter("bloomier").build()
    with pytest.raises(ValueError, match="unknown backend"):
        JoinPlan(R, spec.metric).verify("naive").build()
    with pytest.raises(ValueError, match="only composes with"):
        JoinPlan(R, spec.metric).search("lsh", **METHOD_PARAMS["lsh"]) \
            .verify("exact").build()
    with pytest.raises(ValueError, match="tau must be"):
        JoinPlan(R, spec.metric).filter(xling, tau=-1).build()
    with pytest.raises(ValueError, match="expected 'fpr' or 'mean'"):
        JoinPlan(R, spec.metric).filter(xling, xdt="median").build()
    with pytest.raises(ValueError, match="fpr_tolerance"):
        JoinPlan(R, spec.metric).filter(xling, fpr_tolerance=1.5).build()
    with pytest.raises(ValueError, match="unknown option"):
        JoinPlan(R, spec.metric).on(mesg=None)
    with pytest.raises(ValueError, match="expected 'cosine' or 'l2'"):
        JoinPlan(R, "hamming").build()
    # engine over a different (R, metric) is rejected up front
    other = JoinEngine(np.ascontiguousarray(R[:500]), spec.metric,
                       backend="jnp")
    with pytest.raises(ValueError, match="different"):
        JoinPlan(R, spec.metric).on(engine=other).build()
    # an instance base over a different R is rejected up front
    foreign = make_join("lsh", R[:500].copy(), spec.metric,
                        **METHOD_PARAMS["lsh"])
    with pytest.raises(ValueError, match="different R"):
        JoinPlan(R, spec.metric).search(foreign).build()
    # ... including same-shape R differing only in INTERIOR rows (the
    # silent wrong-index-set hazard)
    R_mut = R.copy()
    R_mut[len(R) // 2] += 0.25
    with pytest.raises(ValueError, match="different R"):
        JoinPlan(R, spec.metric).search(
            make_join("lsh", R_mut, spec.metric,
                      **METHOD_PARAMS["lsh"])).build()
    # an instance base built for a different metric is rejected up front
    other_metric = "cosine" if spec.metric == "l2" else "l2"
    with pytest.raises(ValueError, match="metric"):
        JoinPlan(R, spec.metric).search(
            make_join("lsh", R, other_metric,
                      **METHOD_PARAMS["lsh"])).build()
    # tau/XDT knobs only parameterize Xling — rejected elsewhere
    with pytest.raises(ValueError, match="tau/xdt"):
        JoinPlan(R, spec.metric).filter("lsbf", tau=5).build()
    with pytest.raises(ValueError, match="tau/xdt"):
        JoinPlan(R, spec.metric).filter(lambda Q, eps: None, tau=5).build()


def test_describe_reports_bypassed_base(data, xling):
    """An explicit verify backend bypasses a non-naive base's own probe;
    describe() must say so instead of reporting the base as what runs."""
    R, S, spec = data
    plan = (JoinPlan(R, spec.metric).filter(xling, tau=0, xdt="mean")
            .search("kmeanstree", **METHOD_PARAMS["kmeanstree"])
            .verify("lsh", k=10, l=8, n_probes=4, W=2.0)
            .on(backend="jnp"))
    d = plan.describe()
    assert d["search"]["resolved"] == "kmeanstree"
    assert d["search"]["active"] is False
    assert d["verify"]["resolved"] == "lsh"
    # whereas the auto route keeps the base active
    auto = (JoinPlan(R, spec.metric)
            .search("kmeanstree", **METHOD_PARAMS["kmeanstree"])
            .on(backend="jnp"))
    assert auto.describe()["search"]["active"] is True


def test_legacy_shim_inherits_construction_check(data, bases):
    """The legacy FilteredJoin shim must reject an approximate verify
    backend without a usable engine AT CONSTRUCTION, not on first run()."""
    R, S, spec = data
    with pytest.raises(ValueError, match="engine path"):
        FilteredJoin(bases["lsh"], verify="lsh")
    with pytest.raises(ValueError, match="engine path"):
        # naive base but a foreign engine (not the base's own): unusable
        FilteredJoin(bases["naive"], verify="ivfpq",
                     engine=JoinEngine(np.ascontiguousarray(R[:500]),
                                       spec.metric, backend="jnp"))


# ------------------------------------------------------------------ describe
def test_describe_serializable_and_faithful(data, xling):
    R, S, spec = data
    plan = (JoinPlan(R, spec.metric).filter(xling, tau=7, xdt="fpr")
            .search("lsh", **METHOD_PARAMS["lsh"]).on(backend="jnp"))
    d = plan.describe()
    json.dumps(d)                        # serializable as-is
    assert d["metric"] == spec.metric and d["n_index"] == len(R)
    assert d["filter"]["resolved"] == "XlingFilter"
    assert d["filter"]["tau"] == 7
    assert d["search"]["resolved"] == "lsh"
    assert d["verify"]["resolved"] == "lsh"   # auto -> the base's candidates
    assert d["exec"]["backend"] == "jnp"
    # rebuilding after a spec change is reflected
    assert plan.verify("ivfpq").describe()["verify"]["resolved"] == "ivfpq"
