"""The fused probe kernels + overlapped ring sweep (DESIGN.md §15).

Covers: interpret-mode Pallas parity with the jnp path for the LSH
bucket-gather and ADC-rank kernels — EXACT array equality (the
bit-identity-by-construction claim), candidate-set equality vs the
pre-dedup gather, and count parity through the engine on both metrics;
non-divisible shapes; empty-bucket / all-tombstoned(-1) candidate edge
cases; `clear_program_cache()` evicting the backend-keyed probe
programs; the platform-derived `interpret=` default; and — in forced
multi-device subprocesses (r=2 and r=3, the latter exercising the
reduce-scatter carry's ring wraparound) — the overlapped ring sweep's
bit-identity with the serial schedule plus a guard lane proving overlap
adds no host syncs beyond the two declared per-batch points.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.engine import JoinEngine
from repro.core import probe as probe_mod
from repro.kernels import ops
from repro.kernels.adc_rank import adc_rank_chain, adc_rank_jnp
from repro.kernels.lsh_gather import (lsh_bucket_gather_jnp,
                                      lsh_probe_dup_mask)
from repro.kernels.range_count import default_interpret

EPS = 0.4
LSH_PARAMS = dict(k=10, l=8, n_probes=4, W=2.5)
IVFPQ_PARAMS = dict(C=24, m=8, n_probe=8, n_candidates=200)


def _unit(rng, n, d):
    x = rng.normal(size=(n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


@pytest.fixture(scope="module")
def clustered():
    rng = np.random.default_rng(5)
    d, nc, spread = 32, 6, 0.03
    c = rng.normal(size=(nc, d))
    c /= np.linalg.norm(c, axis=1, keepdims=True)

    def draw(per):
        pts = (np.repeat(c, per, axis=0)
               + rng.normal(size=(nc * per, d)) * spread)
        pts /= np.linalg.norm(pts, axis=1, keepdims=True)
        return pts.astype(np.float32)

    return draw(150), draw(25)


# ------------------------------------------------ lsh_gather: ops-level
@pytest.mark.parametrize("shape", [
    (64, 4, 64, 8, 3),        # aligned rows
    (37, 5, 48, 7, 4),        # nothing divides the 128-row kernel tile
    (1, 1, 8, 1, 1),          # degenerate single-everything
])
def test_lsh_gather_pallas_matches_jnp_exactly(shape):
    """Pallas (interpret) and jnp outputs are bit-identical — including
    the dedup blanks — and the candidate set matches the raw pre-dedup
    gather."""
    q, l, B, cap, n_probes = shape
    rng = np.random.default_rng(0)
    tables = rng.integers(-1, 900, size=(l, B, cap)).astype(np.int32)
    pb = rng.integers(0, B, size=(q, l, n_probes)).astype(np.int32)
    pb[..., -1] = pb[..., 0]          # the pad schedule repeats probe 0
    a = np.asarray(ops.lsh_bucket_gather(jnp.asarray(tables),
                                         jnp.asarray(pb), backend="jnp"))
    b = np.asarray(ops.lsh_bucket_gather(jnp.asarray(tables),
                                         jnp.asarray(pb), backend="pallas"))
    np.testing.assert_array_equal(a, b)
    raw = tables[np.arange(l)[None, :, None], pb].reshape(q, -1)
    for i in range(q):
        assert (set(a[i][a[i] >= 0].tolist())
                == set(raw[i][raw[i] >= 0].tolist()))


def test_lsh_gather_large_ids_exact():
    """The 16-bit-split one-hot gather is exact for ids far past the f32
    24-bit integer window (the failure a naive f32 gather would hit)."""
    ids = np.array([2**30 - 1, 2**24 + 1, 16_777_217, -1],
                   np.int32).reshape(1, 1, 4)
    tables = np.broadcast_to(ids, (2, 8, 4)).copy()
    rng = np.random.default_rng(9)
    pb = rng.integers(0, 8, size=(5, 2, 3)).astype(np.int32)
    a = np.asarray(ops.lsh_bucket_gather(jnp.asarray(tables),
                                         jnp.asarray(pb), backend="jnp"))
    b = np.asarray(ops.lsh_bucket_gather(jnp.asarray(tables),
                                         jnp.asarray(pb), backend="pallas"))
    np.testing.assert_array_equal(a, b)
    for v in (2**30 - 1, 2**24 + 1, 16_777_217):
        assert v in set(b.ravel().tolist())


def test_lsh_gather_empty_buckets_and_full_dup():
    """All-empty tables emit all -1; a fully duplicated probe schedule
    keeps exactly the first probe's block."""
    l, B, cap, q, n_probes = 3, 16, 5, 9, 4
    empty = np.full((l, B, cap), -1, np.int32)
    rng = np.random.default_rng(1)
    pb = rng.integers(0, B, size=(q, l, n_probes)).astype(np.int32)
    for be in ("jnp", "pallas"):
        out = np.asarray(ops.lsh_bucket_gather(
            jnp.asarray(empty), jnp.asarray(pb), backend=be))
        assert (out == -1).all(), be
    # every probe identical -> dup mask true for all but probe 0
    pb_dup = np.repeat(pb[:, :, :1], n_probes, axis=2)
    dup = np.asarray(lsh_probe_dup_mask(jnp.asarray(pb_dup)))
    assert not dup[..., 0].any() and dup[..., 1:].all()
    tables = rng.integers(-1, 100, size=(l, B, cap)).astype(np.int32)
    out = np.asarray(ops.lsh_bucket_gather(
        jnp.asarray(tables), jnp.asarray(pb_dup),
        backend="pallas")).reshape(q, l, n_probes, cap)
    assert (out[:, :, 1:] == -1).all()
    np.testing.assert_array_equal(
        out[:, :, 0], tables[np.arange(l)[None, :], pb_dup[:, :, 0]])


# -------------------------------------------------- adc_rank: ops-level
@pytest.mark.parametrize("b,C,n_cand", [(16, 64, 32), (21, 48, 20),
                                        (3, 10, 10)])
def test_adc_rank_pallas_matches_jnp_exactly(b, C, n_cand):
    """Pallas (interpret) and jnp ADC ranking are bit-identical — same
    ids in the same order, ties included — and value-identical to the
    pre-kernel chain (same id multiset per row)."""
    rng = np.random.default_rng(2)
    m, seg, n = 4, 8, 300
    q = rng.normal(size=(b, m * seg)).astype(np.float32)
    cbs = rng.normal(size=(m, 256, seg)).astype(np.float32)
    codes = rng.integers(0, 256, size=(n, m)).astype(np.uint8)
    cand = rng.integers(-1, n, size=(b, C)).astype(np.int32)
    if C > 2:
        cand[:, 2] = cand[:, 1]       # duplicate ids (overlapping lists)
    args = (jnp.asarray(q), jnp.asarray(cbs), jnp.asarray(cand),
            jnp.asarray(codes))
    a = np.asarray(ops.adc_rank(*args, n_cand=n_cand, backend="jnp"))
    p = np.asarray(ops.adc_rank(*args, n_cand=n_cand, backend="pallas"))
    np.testing.assert_array_equal(a, p)
    c = np.asarray(ops.adc_rank(*args, n_cand=n_cand, backend="ref"))
    for i in range(b):
        assert sorted(a[i].tolist()) == sorted(c[i].tolist())


def test_adc_rank_all_tombstoned_candidates():
    """A fully -1 candidate row (empty probed lists / everything
    tombstoned) ranks to all -1 on every backend, bit-identically."""
    rng = np.random.default_rng(3)
    b, C, m, seg, n = 8, 24, 4, 8, 50
    q = rng.normal(size=(b, m * seg)).astype(np.float32)
    cbs = rng.normal(size=(m, 256, seg)).astype(np.float32)
    codes = rng.integers(0, 256, size=(n, m)).astype(np.uint8)
    cand = np.full((b, C), -1, np.int32)
    cand[0, :3] = [4, 4, 7]           # one row keeps a few live ids
    args = (jnp.asarray(q), jnp.asarray(cbs), jnp.asarray(cand),
            jnp.asarray(codes))
    outs = [np.asarray(ops.adc_rank(*args, n_cand=12, backend=be))
            for be in ("jnp", "pallas")]
    np.testing.assert_array_equal(outs[0], outs[1])
    assert (outs[0][1:] == -1).all()
    assert set(outs[0][0][outs[0][0] >= 0].tolist()) == {4, 7}


def test_adc_rank_formulations_share_values():
    """The flat-LUT path computes the same ADC sums as the chain (the
    per-segment accumulation is a reordering of the same addends) —
    checked through the id sets of unambiguous (untied) rankings."""
    rng = np.random.default_rng(4)
    b, C, m, seg, n = 6, 32, 8, 4, 200
    q = rng.normal(size=(b, m * seg)).astype(np.float32)
    cbs = rng.normal(size=(m, 256, seg)).astype(np.float32)
    codes = rng.integers(0, 256, size=(n, m)).astype(np.uint8)
    cand = rng.permutation(n)[:C].astype(np.int32)[None].repeat(b, 0)
    a = np.asarray(adc_rank_jnp(jnp.asarray(q), jnp.asarray(cbs),
                                jnp.asarray(cand), jnp.asarray(codes),
                                n_cand=C))
    c = np.asarray(adc_rank_chain(jnp.asarray(q), jnp.asarray(cbs),
                                  jnp.asarray(cand), jnp.asarray(codes),
                                  n_cand=C))
    np.testing.assert_array_equal(np.sort(a, 1), np.sort(c, 1))


# ------------------------------------- engine-level parity, both metrics
@pytest.mark.parametrize("metric", ["cosine", "l2"])
@pytest.mark.parametrize("verify,params", [
    ("lsh", LSH_PARAMS), ("ivfpq", IVFPQ_PARAMS)])
def test_device_probe_pallas_backend_parity(clustered, metric, verify,
                                            params):
    """Through the engine, the pallas-backed probe programs produce
    candidates bit-identical to the jnp-backed ones (same placed-probe
    geometry) and counts bit-identical to the host probe."""
    R, Q = clustered
    eng_j = JoinEngine(R, metric, backend="jnp")
    eng_p = JoinEngine(R, metric, backend="pallas")
    cands = {}
    for eng in (eng_j, eng_p):
        eng.verifier(verify, **params)
        placed = eng.device_probe_for(verify, "device")
        qp = np.zeros((256, Q.shape[1]), np.float32)
        qp[:len(Q)] = Q
        cands[eng.backend] = np.asarray(placed.probe(jnp.asarray(qp)))
    np.testing.assert_array_equal(cands["jnp"], cands["pallas"])
    host = eng_p.filtered_join(Q, EPS, verify=verify, probe="host")
    dev = eng_p.filtered_join(Q, EPS, verify=verify, probe="device")
    np.testing.assert_array_equal(dev.counts, host.counts)


def test_lsh_dedup_preserves_candidate_sets(clustered):
    """Device candidates (dedup'd) cover exactly the host candidate id
    sets — dedup drops repeats, never members."""
    R, Q = clustered
    eng = JoinEngine(R, "l2", backend="pallas")
    searcher = eng.verifier("lsh", **LSH_PARAMS)
    placed = eng.device_probe_for("lsh", "device")
    qp = np.zeros((256, Q.shape[1]), np.float32)
    qp[:len(Q)] = Q
    dev = np.asarray(placed.probe(jnp.asarray(qp)))[:len(Q)]
    host = searcher.candidates(Q)
    for h, d in zip(host, dev):
        assert (set(d[d >= 0].tolist())
                == set(h[h >= 0].tolist()))


# ------------------------------------------------------------ interpret
def test_interpret_default_derives_from_platform(monkeypatch):
    """`interpret=None` resolves via default_interpret(): interpret off
    TPU, compiled on TPU — a TPU run can never silently interpret."""
    assert default_interpret() is (jax.default_backend() != "tpu")
    monkeypatch.setattr(jax, "default_backend", lambda: "tpu")
    assert default_interpret() is False
    monkeypatch.setattr(jax, "default_backend", lambda: "cpu")
    assert default_interpret() is True
    # the kernel entries default to the derived policy, not a hard True
    import inspect
    from repro.kernels import adc_rank, fused_mlp, lsh_gather, range_count
    for fn in (range_count.range_count_hist_pallas,
               fused_mlp.mlp_forward_pallas,
               lsh_gather.lsh_bucket_gather_pallas,
               adc_rank.adc_rank_pallas):
        assert inspect.signature(fn).parameters["interpret"].default is None


# ------------------------------------------------------- cache eviction
def test_clear_program_cache_evicts_backend_keyed_probe_programs(clustered):
    """The backend-keyed probe programs (pallas + jnp entries coexist in
    one cache) are evicted by engine.clear_program_cache() and rebuild
    bit-identically."""
    from repro.core import engine as engine_mod
    R, Q = clustered
    want = {}
    for backend in ("jnp", "pallas"):
        eng = JoinEngine(R, "l2", backend=backend)
        eng.verifier("lsh", **LSH_PARAMS)
        want[backend] = eng.filtered_join(Q, EPS, verify="lsh",
                                          probe="device").counts
    assert probe_mod._lsh_probe_program.cache_info().currsize >= 2
    engine_mod.clear_program_cache()
    assert probe_mod._lsh_probe_program.cache_info().currsize == 0
    for backend in ("jnp", "pallas"):
        eng = JoinEngine(R, "l2", backend=backend)
        eng.verifier("lsh", **LSH_PARAMS)
        np.testing.assert_array_equal(
            eng.filtered_join(Q, EPS, verify="lsh", probe="device").counts,
            want[backend])
    np.testing.assert_array_equal(want["jnp"], want["pallas"])


# --------------------------------------- overlapped ring (subprocesses)
def _run_forced_devices(code: str, n: int = 2) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    prelude = (
        "import os\n"
        "from repro.launch.xla_flags import apply_xla_flags, "
        "host_device_count_flag\n"
        f"apply_xla_flags(host_device_count_flag({n}))\n")
    out = subprocess.run(
        [sys.executable, "-c", prelude + code], env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
@pytest.mark.parametrize("r_shards", [2, 3])
def test_overlapped_ring_bit_identical_to_serial(r_shards):
    """Forced r-device subprocess: RingSharded(overlap=True) counts are
    bit-identical to overlap=False and to the replicated ref oracle, on
    the jnp AND pallas backends.  r=3 exercises the reduce-scatter
    carry's ring wraparound, which r=2 cannot distinguish from a plain
    exchange (a carry-index bug is invisible at two shards)."""
    code = (
        "import numpy as np\n"
        "from repro.core.engine import JoinEngine\n"
        "from repro.core.topology import RingSharded\n"
        "from repro.launch.mesh import make_join_mesh\n"
        "rng = np.random.default_rng(7)\n"
        "def unit(n, d=24):\n"
        "    x = rng.normal(size=(n, d)).astype(np.float32)\n"
        "    return x / np.linalg.norm(x, axis=1, keepdims=True)\n"
        "R, Q = unit(700), unit(130)\n"
        "base = np.asarray(JoinEngine(R, 'cosine', backend='ref')"
        ".range_count(Q, 0.7))\n"
        f"mesh = make_join_mesh(data=1, r={r_shards})\n"
        "for overlap in (True, False):\n"
        "    for backend in ('jnp', 'pallas'):\n"
        "        eng = JoinEngine(R, 'cosine', backend=backend, mesh=mesh,\n"
        "                         topology=RingSharded(overlap=overlap))\n"
        "        np.testing.assert_array_equal(\n"
        "            np.asarray(eng.range_count(Q, 0.7)), base)\n"
        "print('RING_OVERLAP_PARITY_OK')\n")
    assert "RING_OVERLAP_PARITY_OK" in _run_forced_devices(code, n=r_shards)


@pytest.mark.slow
@pytest.mark.guard
def test_overlapped_ring_adds_no_host_syncs_2dev():
    """Forced 2-device subprocess, guard lane: a streamed device-probe
    run over the OVERLAPPED ring topology completes under
    host_sync_guard('n_pos', 'result') — the extra ppermutes introduce
    no new host syncs — and stays bit-identical to the unguarded run."""
    code = (
        "import numpy as np\n"
        "import jax.numpy as jnp\n"
        "from repro.core.engine import JoinEngine, host_sync_guard\n"
        "from repro.core.topology import RingSharded\n"
        "from repro.launch.mesh import make_join_mesh\n"
        "rng = np.random.default_rng(5)\n"
        "c = rng.normal(size=(6, 32))\n"
        "c /= np.linalg.norm(c, axis=1, keepdims=True)\n"
        "def draw(per):\n"
        "    p = (np.repeat(c, per, axis=0)\n"
        "         + rng.normal(size=(6 * per, 32)) * 0.03)\n"
        "    return (p / np.linalg.norm(p, axis=1, keepdims=True))"
        ".astype(np.float32)\n"
        "R, Q = draw(150), draw(25)\n"
        "params = dict(k=10, l=8, n_probes=4, W=2.5)\n"
        "def trivial():\n"
        "    p = jnp.zeros((1,), jnp.float32)\n"
        "    return p, (lambda p, X: jnp.ones((X.shape[0],), jnp.float32))\n"
        "mesh = make_join_mesh(data=1, r=2)\n"
        "eng = JoinEngine(R, 'l2', backend='jnp', mesh=mesh,\n"
        "                 topology=RingSharded(overlap=True))\n"
        "eng.verifier('lsh', **params)\n"
        "kw = dict(verify='lsh', probe='device', predict=trivial(),\n"
        "          threshold=0.5)\n"
        "batches = [Q[:10], Q[10:]]\n"
        "ref = [np.asarray(r.counts)\n"
        "       for r in eng.stream(batches, 0.4, depth=2, **kw)]\n"
        "import repro.core.engine as em\n"
        "events, orig = [], em._note_host_sync\n"
        "em._note_host_sync = events.append\n"
        "list(eng.stream(batches, 0.4, depth=2, **kw))\n"
        "em._note_host_sync = orig\n"
        "assert set(events) <= {'n_pos', 'result'}, events\n"
        "with host_sync_guard('n_pos', 'result'):\n"
        "    got = [np.asarray(r.counts)\n"
        "           for r in eng.stream(batches, 0.4, depth=2, **kw)]\n"
        "for a, b in zip(ref, got):\n"
        "    np.testing.assert_array_equal(a, b)\n"
        "print('RING_GUARD_OK')\n")
    assert "RING_GUARD_OK" in _run_forced_devices(code)
