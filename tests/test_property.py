"""Property-based tests on system invariants.

Runs under real `hypothesis` when installed; otherwise `hypo_compat`
substitutes a deterministic seeded-rng driver over the same strategies,
so this lane is NEVER vacuous (scripts/ci.sh fails a skip-only run)."""
import numpy as np

from hypo_compat import given, settings, st

from repro.core import atcs, xdt
from repro.core.engine import JoinEngine
from repro.core.xjoin import _bucket_size
from repro.kernels import ops, ref
from repro.launch import roofline


def _unit(seed, n, d):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 40), st.integers(2, 60), st.integers(2, 32),
       st.integers(1, 12), st.integers(0, 10**6))
def test_range_count_hist_invariants(nq, nr, d, m, seed):
    q, r = _unit(seed, nq, d), _unit(seed + 1, nr, d)
    eps = np.sort(np.random.default_rng(seed).uniform(0.01, 1.99, m)).astype(np.float32)
    cnt = np.asarray(ops.range_count_hist(q, r, eps, metric="l2", backend="jnp",
                                          block_r=16))
    # monotone non-decreasing in eps (the premise of Eq. 2 interpolation)
    assert (np.diff(cnt, axis=1) >= 0).all()
    # bounded by |R|
    assert (cnt >= 0).all() and (cnt <= nr).all()
    # eps >= 2 on the unit sphere finds everything
    full = np.asarray(ops.range_count(q, r, 2.0 + 1e-3, metric="l2",
                                      backend="jnp", block_r=16))
    assert (full == nr).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 30), st.integers(2, 50), st.integers(1, 12),
       st.integers(0, 10**6))
def test_atcs_selection_invariants(n, m, s, seed):
    rng = np.random.default_rng(seed)
    targets = rng.integers(0, 500, size=(n, m)).astype(np.float64)
    s_eff = min(s, m)
    idx = atcs.atcs_select(targets, s_eff, seed=seed)
    assert idx.shape == (n, s_eff)
    # all valid, all distinct per row (exactly s samples, Alg. 1 line 12-13)
    assert (idx >= 0).all() and (idx < m).all()
    for row in idx:
        assert len(np.unique(row)) == s_eff


@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(0.01, 1.99), min_size=2, max_size=12, unique=True),
       st.integers(0, 10**6), st.floats(0.011, 1.989))
def test_interpolation_between_bracketing_values(grid, seed, eps_q):
    grid = np.sort(np.asarray(grid, np.float32))
    rng = np.random.default_rng(seed)
    base = np.sort(rng.integers(0, 100, size=(4, len(grid))), axis=1).astype(np.float64)
    t = xdt.interp_targets(grid, base, float(eps_q))
    # interpolation of a monotone curve stays within [min, max] per row
    assert (t >= base.min(axis=1) - 1e-9).all()
    assert (t <= base.max(axis=1) + 1e-9).all()


@settings(max_examples=30, deadline=None)
@given(st.floats(0.001, 0.5), st.integers(10, 2000), st.integers(0, 10**6))
def test_fpr_xdt_never_exceeds_tolerance_on_train(tol, n, seed):
    rng = np.random.default_rng(seed)
    preds = rng.normal(size=n)
    targets = np.zeros(n)
    thr = xdt.select_xdt(preds, targets, tau=0, mode="fpr", fpr_tolerance=tol)
    assert (preds > thr).mean() <= tol + 1.0 / n


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 10**5), st.integers(16, 2048))
def test_bucket_size_properties(n, block):
    b = _bucket_size(n, block)
    assert b >= n and b % block == 0
    # power-of-two growth: at most 2x overshoot beyond one block
    assert b < 2 * max(n, block)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 64), st.integers(1, 64), st.integers(1, 64))
def test_hlo_dot_flops_parser(m, n, k):
    txt = f"""
ENTRY %main (p0: f32[{m},{k}], p1: f32[{k},{n}]) -> f32[{m},{n}] {{
  %p0 = f32[{m},{k}]{{1,0}} parameter(0)
  %p1 = f32[{k},{n}]{{1,0}} parameter(1)
  ROOT %dot.1 = f32[{m},{n}]{{1,0}} dot(%p0, %p1), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}
}}
"""
    total = roofline.analyze_hlo(txt)
    assert total["flops"] == 2.0 * m * n * k


# -------------------------- mutation-sequence invariants (DESIGN.md §13)
@settings(max_examples=8, deadline=None)
@given(st.integers(20, 80), st.integers(1, 10), st.integers(0, 10**6))
def test_insert_delete_roundtrip_identity(n, k, seed):
    """Inserting rows and deleting those same rows restores the original
    counts bit-exactly — the delta slots are dead and no tombstones were
    taken on the main set."""
    R, Q = _unit(seed, n, 8), _unit(seed + 1, 16, 8)
    eng = JoinEngine(R, "cosine", backend="jnp")
    base = np.asarray(eng.filtered_join(Q, 0.5).counts)
    ids = eng.insert(_unit(seed + 2, k, 8))
    eng.delete(ids)
    assert np.array_equal(base, np.asarray(eng.filtered_join(Q, 0.5).counts))
    assert eng.n_delta == 0 and eng.n_tombstones == 0


@settings(max_examples=6, deadline=None)
@given(st.integers(30, 90), st.integers(1, 12), st.integers(0, 4),
       st.integers(0, 10**6))
def test_compaction_noop_on_results(n, k, ndel, seed):
    """compact() changes the physical layout (delta merged, tombstones
    dropped, programs rebuilt) but NOT the logical set: counts before and
    after are bit-identical."""
    R, Q = _unit(seed, n, 8), _unit(seed + 1, 12, 8)
    eng = JoinEngine(R, "cosine", backend="jnp")
    eng.insert(_unit(seed + 2, k, 8))
    if ndel:
        dead = np.random.default_rng(seed).choice(
            n, size=min(ndel, n - 1), replace=False)
        eng.delete(dead)
    before = np.asarray(eng.filtered_join(Q, 0.5).counts)
    stats = eng.compact()
    assert stats["compacted"]
    assert np.array_equal(before,
                          np.asarray(eng.filtered_join(Q, 0.5).counts))


@settings(max_examples=8, deadline=None)
@given(st.integers(20, 60), st.integers(1, 5), st.integers(0, 10**6))
def test_tombstoned_rows_never_in_verified_pairs(n, ndel, seed):
    """Queries placed exactly AT tombstoned rows (distance 0 — the
    strongest possible match) never count the deleted row, on the exact
    sweep (bit-equal to the survivors-only oracle) nor through a
    candidate-probing route (bounded by it)."""
    R = _unit(seed, n, 8)
    eng = JoinEngine(R, "cosine", backend="jnp")
    dead = np.random.default_rng(seed + 7).choice(
        n, size=min(ndel, n - 1), replace=False)
    eng.delete(dead)
    Q = R[dead]
    keep = np.ones(n, bool)
    keep[dead] = False
    oracle = np.asarray(ref.range_count(Q, R[keep], 0.3, metric="cosine"))
    counts = np.asarray(eng.filtered_join(Q, 0.3).counts)
    assert np.array_equal(counts, oracle)
    lsh = np.asarray(
        eng.filtered_join(Q, 0.3, verify=eng.verifier("lsh")).counts)
    assert (lsh <= oracle).all()
