"""Pallas kernels vs pure-jnp oracles: shape x dtype sweeps (assignment
requirement: per kernel, sweep shapes/dtypes, assert_allclose vs ref.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _unit(rng, n, d, dtype):
    x = rng.normal(size=(n, d)).astype(np.float32)
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    return x.astype(dtype)


@pytest.mark.parametrize("metric", ["cosine", "l2"])
@pytest.mark.parametrize("nq,nr,d,m", [
    (16, 64, 8, 4),        # tiny
    (37, 301, 65, 13),     # unaligned everything
    (128, 512, 128, 16),   # exactly tile-aligned
    (200, 700, 300, 100),  # realistic (fasttext dims, paper m=100)
])
@pytest.mark.slow
def test_range_count_pallas_vs_ref(metric, nq, nr, d, m):
    rng = np.random.default_rng(nq * 7 + nr)
    q = _unit(rng, nq, d, np.float32)
    r = _unit(rng, nr, d, np.float32)
    eps = np.sort(rng.uniform(0.05, 1.9, size=m)).astype(np.float32)
    want = np.asarray(ref.range_count_hist(jnp.asarray(q), jnp.asarray(r),
                                           jnp.asarray(eps), metric))
    got = np.asarray(ops.range_count_hist(q, r, eps, metric=metric,
                                          backend="pallas", block_q=32,
                                          block_r=64, eps_chunk=4))
    np.testing.assert_array_equal(want, got)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.slow
def test_range_count_dtypes(dtype):
    rng = np.random.default_rng(5)
    q = _unit(rng, 24, 32, np.float32).astype(dtype)
    r = _unit(rng, 96, 32, np.float32).astype(dtype)
    eps = np.linspace(0.2, 1.8, 8).astype(np.float32)
    want = np.asarray(ref.range_count_hist(jnp.asarray(q, jnp.float32),
                                           jnp.asarray(r, jnp.float32),
                                           jnp.asarray(eps), "l2"))
    got = np.asarray(ops.range_count_hist(q, r, eps, metric="l2",
                                          backend="pallas", block_q=8,
                                          block_r=32, eps_chunk=4))
    # bf16 rounding may flip counts for distances exactly at a boundary
    assert np.mean(np.abs(want - got)) < 1.0


def test_range_count_jnp_backend_matches():
    rng = np.random.default_rng(7)
    q, r = _unit(rng, 50, 40, np.float32), _unit(rng, 333, 40, np.float32)
    eps = np.linspace(0.1, 1.9, 25).astype(np.float32)
    for metric in ("cosine", "l2"):
        want = np.asarray(ref.range_count_hist(jnp.asarray(q), jnp.asarray(r),
                                               jnp.asarray(eps), metric))
        got = np.asarray(ops.range_count_hist(q, r, eps, metric=metric,
                                              backend="jnp", block_r=64))
        np.testing.assert_array_equal(want, got)


@pytest.mark.slow
def test_range_count_monotone_in_eps():
    rng = np.random.default_rng(9)
    q, r = _unit(rng, 20, 16, np.float32), _unit(rng, 100, 16, np.float32)
    eps = np.linspace(0.05, 1.95, 32).astype(np.float32)
    cnt = np.asarray(ops.range_count_hist(q, r, eps, metric="cosine",
                                          backend="pallas", block_q=8,
                                          block_r=32, eps_chunk=8))
    assert (np.diff(cnt, axis=1) >= 0).all()


@pytest.mark.parametrize("widths", [(32,), (64, 32), (128, 64, 32)])
@pytest.mark.parametrize("din,n", [(17, 40), (301, 100), (66, 256)])
@pytest.mark.slow
def test_fused_mlp_vs_ref(widths, din, n):
    rng = np.random.default_rng(din + n)
    dims = (din,) + widths + (1,)
    params = [(rng.normal(size=(a, b)).astype(np.float32) * 0.2,
               rng.normal(size=(1, b)).astype(np.float32))
              for a, b in zip(dims[:-1], dims[1:])]
    x = rng.normal(size=(n, din)).astype(np.float32)
    want = np.asarray(ref.mlp_forward(
        [(jnp.asarray(w), jnp.asarray(b)) for w, b in params], jnp.asarray(x)))
    got = np.asarray(ops.mlp_forward(params, x, backend="pallas", block_n=16))
    np.testing.assert_allclose(want, got, rtol=1e-5, atol=1e-5)


@pytest.mark.slow
def test_fused_mlp_bf16():
    rng = np.random.default_rng(1)
    params = [(rng.normal(size=(20, 16)).astype(np.float32) * 0.2,
               np.zeros((1, 16), np.float32)),
              (rng.normal(size=(16, 1)).astype(np.float32) * 0.2,
               np.zeros((1, 1), np.float32))]
    x = rng.normal(size=(32, 20)).astype(np.float32)
    pb = [(jnp.asarray(w, jnp.bfloat16), jnp.asarray(b, jnp.bfloat16))
          for w, b in params]
    want = np.asarray(ref.mlp_forward(pb, jnp.asarray(x, jnp.bfloat16)))
    got = np.asarray(ops.mlp_forward(pb, jnp.asarray(x, jnp.bfloat16),
                                     backend="pallas", block_n=16))
    np.testing.assert_allclose(want, got, rtol=3e-2, atol=3e-2)


# ---------------------------------------------------- pallas flash attention
@pytest.mark.parametrize("B,S,T,H,K,Dk,Dv,causal", [
    (2, 128, 128, 8, 2, 32, 32, True),
    (1, 64, 256, 4, 1, 16, 24, False),    # cross-attention shape (MQA-ish)
    (2, 128, 128, 6, 6, 64, 64, True),    # MHA
    (1, 64, 64, 40, 1, 96, 64, True),     # MLA-materialized-ish dims
])
@pytest.mark.slow
def test_flash_attention_pallas_vs_oracle(B, S, T, H, K, Dk, Dv, causal):
    from repro.archs.layers import chunked_attention
    from repro.kernels.flash_attention import flash_attention_pallas
    rng = np.random.default_rng(S * 3 + T)
    q = jnp.asarray(rng.normal(size=(B, S, H, Dk)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, T, K, Dk)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, T, K, Dv)).astype(np.float32))
    want = chunked_attention(q, k, v, causal=causal, chunk=64)
    got = flash_attention_pallas(q, k, v, causal=causal, block_q=32,
                                 block_kv=64)
    np.testing.assert_allclose(np.asarray(want), np.asarray(got),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.slow
def test_flash_attention_pallas_bf16():
    from repro.archs.layers import chunked_attention
    from repro.kernels.flash_attention import flash_attention_pallas
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 64, 4, 32)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.bfloat16)
    want = chunked_attention(q, k, v, causal=True, chunk=32)
    got = flash_attention_pallas(q, k, v, causal=True, block_q=32, block_kv=32)
    np.testing.assert_allclose(np.asarray(want, np.float32),
                               np.asarray(got, np.float32), rtol=3e-2, atol=3e-2)


@pytest.mark.slow
def test_flash_attention_pallas_kv_valid():
    from repro.archs.layers import chunked_attention
    from repro.kernels.flash_attention import flash_attention_pallas
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 32, 4, 16)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 64, 2, 16)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 64, 2, 16)).astype(np.float32))
    want = chunked_attention(q, k, v, causal=False, kv_valid=40, chunk=16)
    got = flash_attention_pallas(q, k, v, causal=False, block_q=16,
                                 block_kv=16, kv_valid=40)
    np.testing.assert_allclose(np.asarray(want), np.asarray(got),
                               rtol=2e-5, atol=2e-5)
