"""Data layer: synthetic corpora shape/normalization/negative portions,
ground-truth pipeline caching, batch pipeline determinism."""
import numpy as np

from repro.data import DATASETS, ShardedBatcher, load_dataset, token_batches
from repro.data.groundtruth import cardinality_table, eps_grid_for_metric
from repro.kernels import ops


def test_all_datasets_generate_and_normalize():
    for name, spec in DATASETS.items():
        R, S, sp = load_dataset(name, n=600, seed=0)
        assert R.shape[1] == spec.dim and S.shape[1] == spec.dim
        assert len(R) == 480 and len(S) == 120      # 8:2 split
        norms = np.linalg.norm(np.concatenate([R, S]), axis=1)
        np.testing.assert_allclose(norms, 1.0, atol=1e-5)


def test_second_sample_disjoint_same_distribution():
    x1, _ = load_dataset("glove", n=500, seed=0, split=False)
    x2, _ = load_dataset("glove", n=500, seed=0, sample=2, split=False)
    assert not np.allclose(x1[:50], x2[:50])
    # same distribution: mean cosine-to-centroid similar
    c1, c2 = x1.mean(0), x2.mean(0)
    assert abs(np.linalg.norm(c1) - np.linalg.norm(c2)) < 0.12


def test_negative_portion_ordering():
    """Table III structure: nuswide is the sparsest, fasttext the densest."""
    portions = {}
    for name in ("fasttext", "nuswide", "glove"):
        R, S, spec = load_dataset(name, n=1200, seed=0)
        cnt = np.asarray(ops.range_count(S, R, 0.45, metric=spec.metric,
                                         backend="jnp"))
        portions[name] = (cnt == 0).mean()
    assert portions["fasttext"] < portions["glove"] < portions["nuswide"]


def test_cardinality_table_cache(tmp_path, monkeypatch):
    import repro.utils as U
    monkeypatch.setattr(U, "CACHE_DIR", str(tmp_path))
    R, _, spec = load_dataset("sift", n=400, seed=0)
    grid = eps_grid_for_metric(spec.metric, 10)
    t1 = cardinality_table(R, R, grid, spec.metric, backend="jnp",
                           cache_key=("t",), exclude_self=True)
    t2 = cardinality_table(R, R, grid, spec.metric, backend="jnp",
                           cache_key=("t",), exclude_self=True)
    np.testing.assert_array_equal(t1, t2)
    assert (t1 >= 0).all()


def test_sharded_batcher():
    X = np.arange(100, dtype=np.float32).reshape(50, 2)
    y = np.arange(50, dtype=np.float32)
    b = ShardedBatcher((X, y), batch_size=16, seed=0)
    seen = []
    for xb, yb in b.epoch():
        assert xb.shape == (16, 2) and yb.shape == (16,)
        seen.extend(np.asarray(yb).tolist())
    assert len(seen) == 48 and len(set(seen)) == 48   # drop-remainder, no dup


def test_token_batches_deterministic():
    it1 = token_batches(100, 4, 8, seed=3)
    it2 = token_batches(100, 4, 8, seed=3)
    a, b = next(it1), next(it2)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = next(it1)
    assert not np.array_equal(np.asarray(a), np.asarray(c))
