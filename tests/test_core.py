"""Unit tests for the paper's core: ATCS (Alg. 1), XDT selection (§V-B),
Eq.-2 interpolation, and the Xling filter itself."""
import numpy as np
import pytest

from repro.core import atcs, xdt
from repro.core.xling import XlingConfig, XlingFilter


# ----------------------------------------------------------------- ATCS
def test_atcs_returns_s_distinct_indices():
    rng = np.random.default_rng(0)
    targets = rng.integers(0, 1000, size=(50, 100)).astype(np.float64)
    idx = atcs.atcs_select(targets, s=6, seed=1)
    assert idx.shape == (50, 6)
    for row in idx:
        assert len(set(row.tolist())) == 6
        assert (row >= 0).all() and (row < 100).all()


def test_atcs_density_bias():
    """Alg. 1 samples proportionally to target-bin density: a distribution
    with 90% of targets in one bin should mostly sample that bin."""
    rng = np.random.default_rng(1)
    n, m, s = 200, 100, 5
    targets = np.where(rng.random((n, m)) < 0.9, 10.0, 1000.0)
    targets[:, 0] = 0.0       # pin t_min
    targets[:, 1] = 1000.0    # pin t_max
    idx = atcs.atcs_select(targets, s=s, seed=2)
    picked = np.take_along_axis(targets, idx, axis=1)
    dense_frac = (picked < 500).mean()
    assert dense_frac > 0.6, dense_frac


def test_uniform_select_matches_paper_fixed_strategy():
    targets = np.zeros((3, 100))
    idx = atcs.uniform_select(targets, s=6)
    assert idx.shape == (3, 6)
    assert (idx[0] == idx[1]).all()            # same grid for every point
    assert idx[0][0] == 0 and idx[0][-1] == 99


def test_build_training_tuples():
    points = np.eye(4, 3, dtype=np.float32)
    grid = np.linspace(0.0, 1.0, 10).astype(np.float32)
    targets = np.arange(40).reshape(4, 10).astype(np.float32)
    idx = np.tile(np.array([[1, 5]]), (4, 1))
    X, y = atcs.build_training_tuples(points, grid, targets, idx)
    assert X.shape == (8, 4) and y.shape == (8,)
    np.testing.assert_allclose(X[0, :3], points[0])
    np.testing.assert_allclose(X[0, 3], grid[1])
    assert y[0] == targets[0, 1] and y[1] == targets[0, 5]


# ------------------------------------------------------------------ XDT
def test_interp_targets_eq2():
    grid = np.array([0.1, 0.2, 0.4], np.float32)
    table = np.array([[0, 10, 30], [5, 5, 5]], np.float32)
    t = xdt.interp_targets(grid, table, 0.3)      # halfway 0.2 -> 0.4
    np.testing.assert_allclose(t, [20.0, 5.0])
    # clamping outside the grid
    np.testing.assert_allclose(xdt.interp_targets(grid, table, 0.05), [0, 5])
    np.testing.assert_allclose(xdt.interp_targets(grid, table, 0.9), [30, 5])


def test_xdt_fpr_mode_controls_train_fpr():
    rng = np.random.default_rng(3)
    preds = rng.normal(size=2000)
    targets = np.zeros(2000)                      # all negatives (tau=0)
    thr = xdt.select_xdt(preds, targets, tau=0, mode="fpr", fpr_tolerance=0.05)
    fpr = (preds > thr).mean()
    assert fpr <= 0.055


def test_xdt_mean_mode_lower_than_fpr_mode():
    """§V-B: FPR-based XDT is usually higher than mean-based."""
    rng = np.random.default_rng(4)
    preds = rng.normal(size=500)
    targets = np.zeros(500)
    t_mean = xdt.select_xdt(preds, targets, tau=0, mode="mean")
    t_fpr = xdt.select_xdt(preds, targets, tau=0, mode="fpr", fpr_tolerance=0.05)
    assert t_fpr > t_mean


def test_xdt_increases_with_tau():
    """§V-B: larger tau -> more samples counted negative -> higher XDT."""
    rng = np.random.default_rng(5)
    true_counts = rng.integers(0, 100, size=1000)
    preds = true_counts + rng.normal(scale=2.0, size=1000)
    t0 = xdt.select_xdt(preds, true_counts, tau=0, mode="mean")
    t50 = xdt.select_xdt(preds, true_counts, tau=50, mode="mean")
    assert t50 > t0


def test_filter_rates():
    verdicts = np.array([True, True, False, False])
    true_counts = np.array([5, 0, 7, 0])
    r = xdt.filter_rates(verdicts, true_counts, tau=0)
    assert r["fpr"] == 0.5 and r["fnr"] == 0.5


# ---------------------------------------------------------------- Xling
@pytest.fixture(scope="module")
def fitted_filter(small_dataset_mod):
    R, S, spec = small_dataset_mod
    cfg = XlingConfig(estimator="nn", metric=spec.metric, epochs=6,
                      backend="jnp", m=40)
    return XlingFilter(cfg).fit(R), R, S, spec


@pytest.fixture(scope="module")
def small_dataset_mod():
    from repro.data import load_dataset
    R, S, spec = load_dataset("sift", n=2000, seed=0)
    return R, S[:200], spec


@pytest.mark.slow
def test_xling_filter_quality(fitted_filter):
    from repro.kernels import ops
    filt, R, S, spec = fitted_filter
    eps = 0.45
    true = np.asarray(ops.range_count(S, R, eps, metric=spec.metric,
                                      backend="jnp"))
    # FPR mode: the 5%-tolerance calibration must hold (paper Table V/VI
    # reports FPR ~0.05 with FNR up to ~0.68 on Sift — high FNR is expected)
    pos_f, _ = filt.query(S, eps, tau=0, mode="fpr")
    rf = xdt.filter_rates(pos_f, true, 0)
    assert rf["fpr"] <= 0.25, rf
    assert rf["fnr"] <= 0.75, rf
    # mean mode trades FPR for lower FNR (paper §V-B)
    pos_m, _ = filt.query(S, eps, tau=0, mode="mean")
    rm = xdt.filter_rates(pos_m, true, 0)
    assert rm["fnr"] <= rf["fnr"] + 0.05, (rm, rf)
    assert rm["fpr"] + rm["fnr"] < 1.0, rm


@pytest.mark.slow
def test_xling_interp_vs_exact_targets_similar(fitted_filter):
    filt, R, S, spec = fitted_filter
    eps = 0.43  # out-of-domain (not on the grid)
    x_interp = filt.xdt(eps, 0, mode="mean")
    filt.cfg.target_mode = "exact"
    filt._xdt_cache.clear()
    x_exact = filt.xdt(eps, 0, mode="mean")
    filt.cfg.target_mode = "interp"
    filt._xdt_cache.clear()
    # thresholds computed from approx vs exact targets should be close
    denom = max(abs(x_exact), 1e-6)
    assert abs(x_interp - x_exact) / denom < 0.5, (x_interp, x_exact)


@pytest.mark.slow
def test_xling_save_load_roundtrip(tmp_path, fitted_filter):
    filt, R, S, spec = fitted_filter
    p = str(tmp_path / "xling.npz")
    filt.save(p)
    loaded = XlingFilter.load(p, XlingConfig(estimator="nn",
                                             metric=spec.metric,
                                             backend="jnp"))
    a = filt.predict_counts(S[:32], 0.45)
    b = loaded.predict_counts(S[:32], 0.45)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
