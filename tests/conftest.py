# NOTE: deliberately NO XLA_FLAGS here — smoke tests and benches must see
# the real device count (1 on CI). Only launch/dryrun.py forces 512 host
# devices, in its own process.
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture(scope="session")
def unit_rng():
    return np.random.default_rng(0)


def unit_vectors(rng, n, d):
    x = rng.normal(size=(n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


@pytest.fixture(scope="session")
def small_dataset():
    """A cached small corpus used across join/filter tests."""
    from repro.data import load_dataset
    R, S, spec = load_dataset("sift", n=2000, seed=0)
    return R, S[:200], spec
