"""End-to-end behaviour tests for the paper's system.

The paper's headline claims, validated at CI scale:
  1. XJoin >> fewer range searches than naive at high pair-recall.
  2. Xling filters beat LSBF on FPR/FNR trade-off (data-awareness).
  3. The trained filter transfers to a disjoint second sample (Fig. 4/5).
  4. The multi-pod dry-run machinery works (tiny mesh, subprocess).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import XlingConfig, XlingFilter, build_xjoin, make_join
from repro.core.joins.lsbf import LSBF
from repro.core.xdt import filter_rates
from repro.data import load_dataset
from repro.kernels import ops

N = 3000
EPS = 0.45

# every test here either fits a filter end-to-end or spawns a compile
# subprocess — all slow-lane (DESIGN.md §8)
pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def world():
    R, S, spec = load_dataset("glove", n=N, seed=0)
    S = S[:400]
    xcfg = XlingConfig(estimator="nn", metric=spec.metric, epochs=10,
                       backend="jnp", m=60)
    filt = XlingFilter(xcfg).fit(R, cache_key=("system-glove", N))
    naive = make_join("naive", R, spec.metric, backend="jnp")
    true = naive.query_counts(S, EPS)
    return R, S, spec, filt, true


def test_xjoin_skips_and_recalls(world):
    R, S, spec, filt, true = world
    xj = build_xjoin(R, spec.metric,
                     xling_cfg=XlingConfig(estimator="nn", metric=spec.metric,
                                           epochs=10, backend="jnp", m=60),
                     tau=0, cache_key=("system-glove", N), backend="jnp")
    res = xj.run(S, EPS)
    neg_portion = (true == 0).mean()
    # glove is sparse (paper: ~78% negatives at eps=0.45): XJoin must skip a
    # large share of queries and keep recall high
    assert neg_portion > 0.4
    assert res.n_searched < 0.75 * len(S), (res.n_searched, len(S))
    assert res.recall_vs(true) > 0.8, res.recall_vs(true)


def test_xling_beats_lsbf(world):
    R, S, spec, filt, true = world
    pos, _ = filt.query(S, EPS, tau=0, mode="mean")
    x = filter_rates(pos, true, 0)
    lsbf = LSBF(R, spec.metric, k=12, l=8, W=2.5)
    l = filter_rates(lsbf.query(S), true, 0)
    # data-awareness: Xling's balanced error must beat LSBF's decisively
    assert x["fpr"] + x["fnr"] < l["fpr"] + l["fnr"], (x, l)


def test_generalization_second_sample(world):
    """Fig. 4/5: the filter trained on sample 1 transfers to the disjoint
    second sample without retraining."""
    R, S, spec, filt, true = world
    R2, S2, _ = load_dataset("glove", n=N, seed=0, sample=2)
    S2 = S2[:300]
    true2 = np.asarray(ops.range_count(S2, R, EPS, metric=spec.metric,
                                       backend="jnp"))
    pos2, _ = filt.query(S2, EPS, tau=0, mode="mean")
    r2 = filter_rates(pos2, true2, 0)
    pos1, _ = filt.query(S, EPS, tau=0, mode="mean")
    r1 = filter_rates(pos1, true, 0)
    # error on the fresh sample within a modest margin of the original
    assert r2["fpr"] + r2["fnr"] <= r1["fpr"] + r1["fnr"] + 0.25, (r1, r2)


def test_filtering_by_counting_tau(world):
    """tau > 0 ('enough neighbors') must shrink the predicted-positive set
    monotonically."""
    R, S, spec, filt, true = world
    sizes = []
    for tau in (0, 5, 50):
        pos, _ = filt.query(S, EPS, tau=tau, mode="fpr")
        sizes.append(int(pos.sum()))
    assert sizes[0] >= sizes[1] >= sizes[2]


def test_dryrun_subprocess_tiny():
    """The dry-run entry point must lower+compile on a forced-device mesh in
    a fresh process (CI-scale stand-in for the 512-chip run)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    code = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'\n"
        "import jax, jax.numpy as jnp\n"
        "from repro.launch.dryrun import _sds\n"
        "from repro.configs import get_config\n"
        "from repro.archs import build_model\n"
        "from repro.parallel.sharding import param_shardings, batch_shardings\n"
        "cfg = get_config('tinyllama_1_1b', smoke=True)\n"
        "from repro.launch.mesh import make_mesh\n"
        "mesh = make_mesh((4, 2), ('data', 'model'))\n"
        "model = build_model(cfg)\n"
        "params = _sds(model.abstract_params(), param_shardings(model.param_specs(), mesh))\n"
        "batch = {'tokens': jax.ShapeDtypeStruct((8, 64), jnp.int32)}\n"
        "batch = _sds(batch, batch_shardings(mesh, batch))\n"
        "def loss(p, b):\n"
        "    l, m = model.train_loss(p, b)\n"
        "    return l\n"
        "c = jax.jit(loss).lower(params, batch).compile()\n"
        "from repro.utils import cost_analysis_dict\n"
        "assert cost_analysis_dict(c).get('flops', 0) > 0\n"
        "print('DRYRUN_OK')\n"
    )
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                         capture_output=True, text=True, timeout=300)
    assert "DRYRUN_OK" in out.stdout, out.stderr[-2000:]
