"""Fixture: raw mesh construction — mesh-policy must fire on line 7."""
import jax


def build(devs):
    """Build a mesh the forbidden way (bypassing make_mesh)."""
    return jax.sharding.Mesh(devs, ("x",))
