"""Fixture: clean module — every rule selects it, none fires.

The allow-host-sync below is CONSUMED (its kind is declared by the stub
`_note_host_sync` call), so annotation-hygiene stays quiet too.
"""
# xlint: scope(host-sync)
# xlint: scope(cache-registry)
# xlint: scope(jit-cache-key)
# xlint: scope(docstring-gate)


def _note_host_sync(kind):
    del kind


def drain(counts_dev):
    """One declared, properly annotated readback."""
    _note_host_sync("count")
    # xlint: allow-host-sync(count: declared readback)
    return int(counts_dev)
