"""Fixture: unannotated hot-path host sync — host-sync fires on line 7."""
# xlint: scope(host-sync)


def drain(counts_dev):
    """Read a device counter without declaring the sync."""
    return int(counts_dev)
