"""Fixture: malformed + stale annotations — annotation-hygiene fires on
lines 4 (unknown directive), 5 (stale allow), and 6 (empty reason)."""

# xlint: frobnicate(whatever)
X = 1  # xlint: allow-mesh-policy(there is no raw mesh here)
Y = 2  # xlint: allow-host-sync()
