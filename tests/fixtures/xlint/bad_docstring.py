"""Fixture: naked public def — docstring-gate fires on line 5."""
# xlint: scope(docstring-gate)


def naked():
    pass
