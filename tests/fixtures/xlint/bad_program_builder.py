"""Fixture: `_program`-named builder with no cache/registry stack —
cache-registry fires on line 6 (the naming-convention direction)."""
# xlint: scope(cache-registry)


def _delta_count_program(mesh, metric):
    """A delta builder that recompiles per call and dodges the registry."""
    return mesh, metric
