"""Fixture: unhashable cache-key params — jit-cache-key fires on line 7."""
# xlint: scope(jit-cache-key)
import functools


@functools.lru_cache
def build_program(shape: dict, opts=[]):
    """Builder keyed on a dict and a fresh list — defeats the cache."""
    return shape
