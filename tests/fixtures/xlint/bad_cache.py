"""Fixture: unregistered program cache — cache-registry fires on line 7."""
# xlint: scope(cache-registry)
import functools


@functools.lru_cache(maxsize=None)
def build_program(n):
    """A program builder that clear_program_cache() would miss."""
    return n
