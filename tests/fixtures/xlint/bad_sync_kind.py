"""Fixture: allow-host-sync naming an UNDECLARED kind — host-sync fires
(unsuppressibly) on line 9: no `_note_host_sync("bogus")` exists here."""
# xlint: scope(host-sync)


def drain(counts_dev):
    """Annotated, but with a kind no instrumentation declares."""
    # xlint: allow-host-sync(bogus: not a declared kind)
    return int(counts_dev)
