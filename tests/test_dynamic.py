"""Dynamic R (DESIGN.md §13): the oracle-driven mutation harness.

The correctness contract under mutation is bit-identity: at EVERY point
in an arbitrary insert/delete/query/compact sequence, the engine's
counts must equal a brute-force `ref` oracle built fresh on the logical
(R ∪ delta − tombstones) set. `ShadowOracle` is that oracle — a host
dict of live rows mutated in lockstep with the engine — and
`run_sequence` drives randomized sequences against it (hypothesis
strategies when installed, the seeded-rng `hypo_compat` driver
otherwise, so the lane is never vacuous).

Covers: sequence parity on replicated and ring topologies, sync and
streamed (each streamed batch vs the oracle at ITS submit time, not
result time); ref/pallas backend parity under mutation; candidate
routes (lsh / ivfpq) with host-vs-device probe count equality and
tombstone masking; the recall floors on (R ∪ delta) before and after
compact() under both probe placements; mid-stream compact() draining
and re-binding live sessions; the JoinPlan.mutable() surface incl. the
auto-compaction policy; every mutation error path; the host-sync guard
lane with mutations inside the scope; and a forced-8-device subprocess
replaying a sequence on a 4x2 ring mesh.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from hypo_compat import given, settings, st

from repro.core.api import JoinPlan
from repro.core.engine import JoinEngine, host_sync_guard
from repro.kernels import ref

EPS = 0.45       # cosine parity worlds
EPS_L2 = 0.4     # the clustered l2 probe-layer world (test_probe.py)
DIM = 16

LSH_PARAMS = dict(k=10, l=8, n_probes=4, W=2.5)
IVFPQ_PARAMS = dict(C=24, m=8, n_probe=8, n_candidates=600)


def _unit(rng, n, d=DIM):
    x = rng.normal(size=(n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


def _cluster_world(seed, d=32):
    """The probe-layer test world (test_probe.py): 6 tight SHARED
    clusters so approximate indices have real recall to lose — every
    `draw(per)` samples around the same centers."""
    rng = np.random.default_rng(seed)
    c = rng.normal(size=(6, d))
    c /= np.linalg.norm(c, axis=1, keepdims=True)

    def draw(per):
        p = (np.repeat(c, per, axis=0)
             + rng.normal(size=(6 * per, d)) * 0.03)
        return (p / np.linalg.norm(p, axis=1, keepdims=True)
                ).astype(np.float32)
    return draw


class ShadowOracle:
    """Brute-force shadow of the logical set: id -> row, counts via the
    unpadded `ref` kernel — the same oracle `compact()` must preserve."""

    def __init__(self, R, metric="cosine"):
        self.metric = metric
        self.live = {i: np.asarray(R[i], np.float32) for i in range(len(R))}

    def insert(self, ids, rows):
        self.live.update(zip(map(int, ids), np.asarray(rows, np.float32)))

    def delete(self, ids):
        for i in ids:
            self.live.pop(int(i))

    def world(self):
        return np.stack(list(self.live.values()))

    def counts(self, Q, eps):
        return np.asarray(
            ref.range_count(Q, self.world(), eps, metric=self.metric))


def _mutate_once(eng, shadow, rng, op):
    """Apply one op to engine + shadow in lockstep."""
    if op == "insert":
        rows = _unit(rng, int(rng.integers(1, 16)))
        shadow.insert(eng.insert(rows), rows)
    elif op == "delete":
        pool = np.fromiter(shadow.live, np.int64)
        if len(pool) > 8:       # never drain the logical set
            k = int(rng.integers(1, 7))
            ids = rng.choice(pool, size=k, replace=False)
            eng.delete(ids)
            shadow.delete(ids)
    elif op == "compact":
        eng.compact()


def run_sequence(eng, shadow, rng, Q, eps, n_ops=12):
    """Randomized mutation sequence with a bit-parity check after EVERY
    op — the §13 contract is pointwise, not just final-state."""
    ops = rng.choice(np.array(["insert", "delete", "compact"]),
                     size=n_ops, p=[0.5, 0.35, 0.15])
    for op in ops:
        _mutate_once(eng, shadow, rng, op)
        got = np.asarray(eng.filtered_join(Q, eps).counts)
        np.testing.assert_array_equal(got, shadow.counts(Q, eps),
                                      err_msg=f"after {op}")
    return ops


# ------------------------------------------------ sequence parity (sync)
@settings(max_examples=3, deadline=None)
@given(st.integers(0, 10**6))
def test_mutation_sequence_parity_replicated(seed):
    rng = np.random.default_rng(seed)
    R = _unit(rng, 240)
    eng = JoinEngine(R, "cosine", backend="jnp")
    run_sequence(eng, ShadowOracle(R), rng, _unit(rng, 24), EPS)


@settings(max_examples=2, deadline=None)
@given(st.integers(0, 10**6))
def test_mutation_sequence_parity_ring(seed):
    from repro.launch.mesh import make_join_mesh
    rng = np.random.default_rng(seed)
    R = _unit(rng, 240)
    eng = JoinEngine(R, "cosine", mesh=make_join_mesh(data=1, r=1),
                     backend="jnp", topology="ring")
    run_sequence(eng, ShadowOracle(R), rng, _unit(rng, 24), EPS)


def test_mutation_parity_ref_backend(unit_rng):
    """The ref backend (unpadded host oracle path) takes the same delta
    and tombstone adjustments — parity is backend-independent."""
    rng = np.random.default_rng(77)
    R = _unit(rng, 150)
    eng = JoinEngine(R, "cosine", backend="ref")
    run_sequence(eng, ShadowOracle(R), rng, _unit(rng, 16), EPS, n_ops=8)


@pytest.mark.slow
def test_mutation_parity_pallas_backend():
    """Interpret-mode Pallas exact sweep under mutation."""
    rng = np.random.default_rng(78)
    R = _unit(rng, 150)
    eng = JoinEngine(R, "cosine", backend="pallas")
    run_sequence(eng, ShadowOracle(R), rng, _unit(rng, 16), EPS, n_ops=6)


def test_range_count_hist_under_mutation(unit_rng):
    """The histogram program (ground-truth table builds) sees the delta
    and tombstones too — monotone, bounded by the LIVE set size, and
    bit-equal to the ref histogram on the logical set."""
    rng = np.random.default_rng(9)
    R = _unit(rng, 120)
    eng = JoinEngine(R, "cosine", backend="jnp")
    shadow = ShadowOracle(R)
    rows = _unit(rng, 30)
    shadow.insert(eng.insert(rows), rows)
    eng.delete([0, 5, 9])
    shadow.delete([0, 5, 9])
    Q = _unit(rng, 10)
    grid = np.asarray([0.2, 0.45, 0.8, 1.4], np.float32)
    got = np.asarray(eng.range_count_hist(Q, grid))
    want = np.asarray(ref.range_count_hist(Q, shadow.world(), grid,
                                           metric="cosine"))
    np.testing.assert_array_equal(got, want)
    assert (np.diff(got, axis=1) >= 0).all()
    assert (got <= len(shadow.live)).all()


# --------------------------------------------------- streamed snapshots
def test_stream_snapshot_consistency(unit_rng):
    """Each streamed batch's counts reflect the logical set at ITS
    submit time — a mutation between submits must not leak backward into
    in-flight batches nor get lost for later ones."""
    rng = np.random.default_rng(3)
    R = _unit(rng, 200)
    eng = JoinEngine(R, "cosine", backend="jnp")
    shadow = ShadowOracle(R)
    batches = [_unit(rng, 12) for _ in range(6)]
    truths = []

    def feed():
        for k, q in enumerate(batches):
            if k == 1:
                rows = _unit(rng, 25)
                shadow.insert(eng.insert(rows), rows)
            if k == 3:
                eng.delete([2, 11, 200])
                shadow.delete([2, 11, 200])
            truths.append(shadow.counts(q, EPS))
            yield q

    res = list(eng.stream(feed(), EPS, depth=2))
    assert len(res) == len(batches)
    for k, r in enumerate(res):
        np.testing.assert_array_equal(np.asarray(r.counts), truths[k],
                                      err_msg=f"batch {k}")


def test_stream_compact_drains_and_rebinds(unit_rng):
    """compact() mid-stream drains in-flight batches (their snapshot
    worlds stay valid) and re-binds the session's device probe to the
    rebuilt tables; FIFO order and per-batch parity survive."""
    draw = _cluster_world(4)
    R = draw(150)
    eng = JoinEngine(R, "l2", backend="jnp")
    eng.verifier("lsh", **LSH_PARAMS)
    shadow = ShadowOracle(R, "l2")
    batches = [draw(3) for _ in range(6)]
    truths = []

    def feed():
        for k, q in enumerate(batches):
            if k == 2:
                rows = draw(6)
                shadow.insert(eng.insert(rows), rows)
            if k == 4:
                stats = eng.compact()
                assert stats["compacted"] and stats["n_merged"] == 36
            truths.append(shadow.counts(q, EPS_L2))
            yield q

    res = list(eng.stream(feed(), EPS_L2, verify="lsh", probe="device",
                          depth=2))
    assert len(res) == len(batches)
    for k, r in enumerate(res):
        got = np.asarray(r.counts)
        assert (got <= truths[k]).all(), f"batch {k}: tombstone/delta leak"
        rec = got.sum() / max(truths[k].sum(), 1)
        assert rec >= 0.9, f"batch {k}: recall {rec}"


# ------------------------------------------- candidate routes + recall
@pytest.mark.parametrize("name,params",
                         [("lsh", LSH_PARAMS), ("ivfpq", IVFPQ_PARAMS)])
def test_candidate_routes_under_mutation(name, params):
    """Approximate verify routes under mutation: host and device probe
    placements stay bit-identical to each other, never count a
    tombstoned row, and see every delta row exactly."""
    draw = _cluster_world(11)
    R = draw(150)
    eng = JoinEngine(R, "l2", backend="jnp")
    eng.verifier(name, **params)
    shadow = ShadowOracle(R, "l2")
    rows = draw(8)
    shadow.insert(eng.insert(rows), rows)
    dead = [3, 17, 101, 900]
    eng.delete(dead)
    shadow.delete(dead)
    Q = draw(4)
    true = shadow.counts(Q, EPS_L2)
    host = eng.filtered_join(Q, EPS_L2, verify=name, probe="host")
    dev = eng.filtered_join(Q, EPS_L2, verify=name, probe="device")
    np.testing.assert_array_equal(np.asarray(host.counts),
                                  np.asarray(dev.counts))
    assert (np.asarray(dev.counts) <= true).all()


@pytest.mark.parametrize("name,params,floor",
                         [("lsh", LSH_PARAMS, 0.90),
                          ("ivfpq", IVFPQ_PARAMS, 0.95)])
def test_recall_floors_under_mutation(name, params, floor):
    """The §11 recall floors hold on (R ∪ delta − tombstones) BEFORE and
    AFTER compact(), under both probe placements — the delta is probed
    exactly, so recall can only dip through the pinned-R candidates, and
    compact() folds the delta into rebuilt index tables."""
    draw = _cluster_world(12)
    rng = np.random.default_rng(12)
    R = draw(150)
    eng = JoinEngine(R, "l2", backend="jnp")
    eng.verifier(name, **params)
    shadow = ShadowOracle(R, "l2")
    rows = draw(5)                      # 30 delta rows, in-distribution
    shadow.insert(eng.insert(rows), rows)
    dead = rng.choice(len(R), size=20, replace=False)
    eng.delete(dead)
    shadow.delete(dead)
    Q = draw(4)
    true = shadow.counts(Q, EPS_L2)
    assert true.sum() > 1000            # non-vacuous floor
    for phase in ("pre-compact", "post-compact"):
        for probe in ("host", "device"):
            res = eng.filtered_join(Q, EPS_L2, verify=name, probe=probe)
            counts = np.asarray(res.counts)
            assert (counts <= true).all(), (phase, probe)
            recall = float(np.minimum(counts, true).sum() / true.sum())
            assert recall >= floor, (phase, probe, recall)
        if phase == "pre-compact":
            assert eng.compact()["compacted"]
            np.testing.assert_array_equal(shadow.counts(Q, EPS_L2), true)


# ------------------------------------------------------ plan surface
def test_mutable_plan_roundtrip(unit_rng):
    rng = np.random.default_rng(21)
    R = _unit(rng, 180)
    Q = _unit(rng, 20)
    shadow = ShadowOracle(R)
    plan = JoinPlan(R, "cosine").mutable(auto_compact_at=None)
    rows = _unit(rng, 30)
    shadow.insert(plan.insert(rows), rows)
    plan.delete([7, 40])
    shadow.delete([7, 40])
    np.testing.assert_array_equal(plan.run(Q, EPS).counts,
                                  shadow.counts(Q, EPS))
    d = plan.describe()["mutable"]
    assert d["n_delta"] == 30 and d["n_tombstones"] == 2
    assert d["delta_frac"] == pytest.approx(32 / 180)
    stats = plan.compact()
    assert stats["n_merged"] == 30 and stats["n_dropped"] == 2
    np.testing.assert_array_equal(plan.run(Q, EPS).counts,
                                  shadow.counts(Q, EPS))
    d2 = plan.describe()
    assert d2["n_index"] == 208 and d2["mutable"]["compactions"] == 1


def test_mutable_plan_auto_compact(unit_rng):
    rng = np.random.default_rng(22)
    R = _unit(rng, 180)
    plan = JoinPlan(R, "cosine").mutable(auto_compact_at=0.125)
    plan.insert(_unit(rng, 10))     # 10/180 < 0.125: still delta
    assert plan.describe()["mutable"]["compactions"] == 0
    plan.insert(_unit(rng, 20))     # 30/180 >= 0.125: auto-compacts
    d = plan.describe()["mutable"]
    assert d["compactions"] == 1 and d["n_delta"] == 0
    assert plan.describe()["n_index"] == 210


def test_mutable_plan_rebinds_device_probe(unit_rng):
    """A mutable plan with a device-placed by-name route keeps serving
    from the REBUILT tables after compact() — the placed probe is
    re-resolved, not left pinned to the pre-merge upload."""
    draw = _cluster_world(23)
    R = draw(150)
    plan = (JoinPlan(R, "l2").verify("lsh", **LSH_PARAMS)
            .on(probe="device").mutable(auto_compact_at=None))
    shadow = ShadowOracle(R, "l2")
    Q = draw(4)
    rows = draw(6)
    shadow.insert(plan.insert(rows), rows)
    before = plan.describe()["exec"]["probe"]
    assert before["resolved"] == "device"
    plan.compact()
    res = plan.run(Q, EPS_L2)
    true = shadow.counts(Q, EPS_L2)
    assert plan.describe()["exec"]["probe"]["resolved"] == "device"
    assert (np.asarray(res.counts) <= true).all()
    assert np.asarray(res.counts).sum() >= 0.9 * true.sum()


# -------------------------------------------------------- error paths
def test_frozen_plan_rejects_mutation(unit_rng):
    plan = JoinPlan(_unit(np.random.default_rng(0), 50), "cosine")
    for op in (lambda: plan.insert(np.zeros((1, DIM), np.float32)),
               lambda: plan.delete([0]), lambda: plan.compact()):
        with pytest.raises(RuntimeError, match="frozen"):
            op()


def test_mutable_rejects_non_naive_base(unit_rng):
    R = _unit(np.random.default_rng(0), 50)
    with pytest.raises(ValueError, match="search\\('naive'\\)"):
        JoinPlan(R, "cosine").search("lsh", **LSH_PARAMS).mutable().build()
    with pytest.raises(ValueError, match="by-name"):
        class _V:
            name, exact, metric = "v", False, "cosine"
            def query_counts(self, Q, eps):
                return np.zeros(len(Q), np.int32)
        JoinPlan(R, "cosine").verify(_V()).mutable().build()
    with pytest.raises(ValueError, match="positive"):
        JoinPlan(R, "cosine").mutable(auto_compact_at=-0.5)


def test_mutation_error_paths(unit_rng):
    rng = np.random.default_rng(30)
    R = _unit(rng, 60)
    eng = JoinEngine(R, "cosine", backend="jnp")
    with pytest.raises(ValueError):            # wrong insert shape
        eng.insert(np.zeros((3, DIM + 1), np.float32))
    with pytest.raises(KeyError):              # unknown id
        eng.delete([10_000])
    with pytest.raises(KeyError):              # duplicate in one call
        eng.delete([5, 5])
    eng.delete([5])
    with pytest.raises(KeyError):              # double delete
        eng.delete([5])
    # KeyError resolution happens BEFORE any mutation is applied
    before = eng.n_tombstones
    with pytest.raises(KeyError):
        eng.delete([6, 5])                     # 5 already dead
    assert eng.n_tombstones == before
    assert eng.compact()["compacted"]          # tombstones alone compact
    assert eng.compact() == {"compacted": False, "n_r": 59,
                             "n_merged": 0, "n_dropped": 0}
    with pytest.raises(ValueError, match="empty"):
        eng.delete(eng._main_ids.copy())       # the whole logical set
        eng.compact()


def test_counts_only_plugin_rejects_tombstones(unit_rng):
    """A query_counts-only plug-in searcher computes counts over ITS OWN
    host copy of R — it cannot honor tombstones, so the engine fails
    loudly instead of over-counting."""
    rng = np.random.default_rng(31)
    R = _unit(rng, 60)
    eng = JoinEngine(R, "cosine", backend="jnp")

    class CountsOnly:
        name, exact = "countsonly", True
        def query_counts(self, Q, eps):
            return np.asarray(ref.range_count(Q, R, eps, metric="cosine"))

    Q = _unit(rng, 8)
    shadow = ShadowOracle(R)
    rows = _unit(rng, 10)
    shadow.insert(eng.insert(rows), rows)
    # inserts alone are fine: the delta adjustment is route-independent
    np.testing.assert_array_equal(
        np.asarray(eng.filtered_join(Q, EPS, verify=CountsOnly()).counts),
        shadow.counts(Q, EPS))
    eng.delete([0])
    with pytest.raises(RuntimeError, match="tombstoned"):
        eng.filtered_join(Q, EPS, verify=CountsOnly())
    eng.compact()                              # folds the tombstone away
    shadow.delete([0])
    # note: post-compact the plug-in's captured R is stale by design —
    # the guard exists exactly because the engine can't patch it


# ---------------------------------------------------------- guard lane
@pytest.mark.guard
def test_mutation_paths_respect_host_sync_budget(unit_rng):
    """Exact and device-probe joins under mutation keep the §12 transfer
    budget: n_pos + result reads only, even with a delete inside the
    guarded scope (mutation uploads are host->device, not syncs)."""
    draw = _cluster_world(40)
    R = draw(40)
    eng = JoinEngine(R, "l2", backend="jnp")
    eng.verifier("lsh", **LSH_PARAMS)
    Q = draw(3)
    eng.insert(draw(4))
    with host_sync_guard("n_pos", "result"):
        eng.filtered_join(Q, EPS_L2)
        eng.filtered_join(Q, EPS_L2, verify="lsh", probe="device")
        eng.delete([1, 2])
        eng.filtered_join(Q, EPS_L2)
        list(eng.stream([Q[:2], Q[2:]], EPS_L2, verify="lsh",
                        probe="device", depth=2))


# ------------------------------------------------- multi-device (mesh)
@pytest.mark.slow
def test_dynamic_subprocess_8dev():
    """Forced 8-host-device subprocess: the full mutation-sequence
    parity contract on a 4x2 ring mesh and a replicated data mesh —
    the delta is replicated (topology.delta_spec) so the ring sweep
    schedule is unchanged while shards mutate."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    code = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'\n"
        "import numpy as np, jax\n"
        "from repro.launch.mesh import make_data_mesh, make_join_mesh\n"
        "from repro.core.engine import JoinEngine\n"
        "from repro.kernels import ref\n"
        "assert len(jax.devices()) == 8\n"
        "rng = np.random.default_rng(6)\n"
        "def unit(n):\n"
        "    x = rng.normal(size=(n, 16)).astype(np.float32)\n"
        "    return x / np.linalg.norm(x, axis=1, keepdims=True)\n"
        "R, Q = unit(300), unit(20)\n"
        "for mesh, topo in ((make_data_mesh(), 'replicated'),\n"
        "                   (make_join_mesh(data=4, r=2), 'ring')):\n"
        "    eng = JoinEngine(R, 'cosine', mesh=mesh, backend='jnp',\n"
        "                     topology=topo)\n"
        "    live = {i: R[i] for i in range(len(R))}\n"
        "    for t in range(8):\n"
        "        op = ['insert', 'delete', 'insert', 'delete',\n"
        "              'compact', 'insert', 'delete', 'compact'][t]\n"
        "        if op == 'insert':\n"
        "            rows = unit(int(rng.integers(1, 24)))\n"
        "            live.update(zip(map(int, eng.insert(rows)), rows))\n"
        "        elif op == 'delete':\n"
        "            pool = np.fromiter(live, np.int64)\n"
        "            ids = rng.choice(pool, size=5, replace=False)\n"
        "            eng.delete(ids)\n"
        "            [live.pop(int(i)) for i in ids]\n"
        "        else:\n"
        "            eng.compact()\n"
        "        world = np.stack(list(live.values()))\n"
        "        want = np.asarray(ref.range_count(Q, world, 0.45,\n"
        "                                          metric='cosine'))\n"
        "        got = np.asarray(eng.filtered_join(Q, 0.45).counts)\n"
        "        np.testing.assert_array_equal(got, want, err_msg=\n"
        "            f'{topo} step {t} ({op})')\n"
        "        sres = list(eng.stream([Q[:7], Q[7:]], 0.45, depth=2))\n"
        "        np.testing.assert_array_equal(\n"
        "            np.concatenate([np.asarray(r.counts) for r in sres]),\n"
        "            want)\n"
        "print('DYNAMIC_RING_OK')\n"
    )
    out = subprocess.run(
        [sys.executable, "-c", code], env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True, text=True, timeout=600)
    assert "DYNAMIC_RING_OK" in out.stdout, out.stderr[-3000:]
