"""`hypothesis` when installed, a seeded-rng fallback otherwise.

The property lane (`test_property.py`) and the mutation harness
(`test_dynamic.py`) express invariants as `@given(...)` functions. With
`hypothesis` available (requirements-dev.txt) they get real shrinking
search; without it this module substitutes a deterministic seeded-rng
driver over the same strategy surface, so THE LANE IS NEVER VACUOUS —
every test still runs `max_examples` drawn cases instead of silently
skipping (the failure mode scripts/ci.sh now also guards against).

The fallback implements only the strategy subset the suite uses
(`st.integers`, `st.floats`, `st.lists(..., unique=)`); each test's
draw stream is seeded from its qualname, so failures reproduce exactly
across runs without a shared global seed ordering hazard.
"""
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
    import zlib

    import numpy as np

    class _Strategy:
        """A draw rule: `example(rng)` produces one value."""

        def __init__(self, sample):
            self._sample = sample

        def example(self, rng):
            return self._sample(rng)

    class _St:
        """The `strategies` subset the suite uses."""

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def lists(elements, *, min_size=0, max_size=10, unique=False):
            def sample(rng):
                n = int(rng.integers(min_size, max_size + 1))
                out, seen, tries = [], set(), 0
                while len(out) < n and tries < 100 * max(n, 1):
                    v = elements.example(rng)
                    tries += 1
                    if unique:
                        if v in seen:
                            continue
                        seen.add(v)
                    out.append(v)
                return out
            return _Strategy(sample)

    st = _St()

    def settings(max_examples=20, deadline=None, **_):
        """Record the example budget; `deadline` etc. are no-ops here."""
        def deco(fn):
            fn._hc_max_examples = int(max_examples)
            return fn
        return deco

    def given(*strategies):
        """Run the test once per drawn example, rng seeded per-test."""
        def deco(fn):
            def wrapper(*args, **kwargs):
                # the attr lands on `wrapper` when @settings is applied
                # above @given (the usual order) and on `fn` otherwise
                n = getattr(wrapper, "_hc_max_examples",
                            getattr(fn, "_hc_max_examples", 20))
                rng = np.random.default_rng(
                    zlib.crc32(fn.__qualname__.encode()))
                for _ in range(n):
                    fn(*args, *(s.example(rng) for s in strategies),
                       **kwargs)
            # metadata copied by hand: functools.wraps would set
            # __wrapped__, making pytest unwrap to fn's signature and
            # demand its strategy params as fixtures
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__dict__.update(fn.__dict__)
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco
