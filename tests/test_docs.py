"""Documentation gates: the docs-check tooling and the top-level docs.

Keeps the repo's documented surface from regressing: the docstring checker
must pass on the serving-surface modules (core/engine.py, core/xjoin.py,
launch/serve.py), must actually detect violations (not vacuously pass),
and README.md / DESIGN.md must keep their load-bearing sections.
"""
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def test_docs_check_passes():
    out = subprocess.run([sys.executable, "scripts/check_docstrings.py"],
                         cwd=REPO, capture_output=True, text=True)
    assert out.returncode == 0, out.stdout + out.stderr


def test_docs_check_detects_violations(tmp_path):
    """The gate must flag an undocumented public def — otherwise a checker
    bug could silently disable the whole docs lane."""
    bad = tmp_path / "bad.py"
    bad.write_text('"""mod."""\ndef documented():\n    """ok."""\n'
                   "def naked():\n    pass\n")
    out = subprocess.run(
        [sys.executable, "scripts/check_docstrings.py", str(bad)],
        cwd=REPO, capture_output=True, text=True)
    assert out.returncode == 1
    assert "naked" in out.stdout and "documented" not in out.stdout


def test_readme_quickstart_present():
    text = (REPO / "README.md").read_text()
    for needle in ("Quickstart", 'pytest -m "not slow"', "DESIGN.md",
                   "verify", "lsh", "ivfpq"):
        assert needle in text, f"README.md lost its {needle!r} section"


def test_design_documents_streaming_protocol():
    text = (REPO / "DESIGN.md").read_text()
    for needle in ("Streaming & verification backends", "flush()",
                   "In-flight queue invariants", "ivfpq"):
        assert needle in text, f"DESIGN.md lost {needle!r}"
