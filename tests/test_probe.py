"""The device-resident probing layer (core/probe.py, DESIGN.md §11).

Covers: bit-parity of device-probe candidates with the host probe (the
shared-math guarantee) for LSH and IVF-PQ; count parity of the
probe="device" route with probe="host" through the engine and JoinPlan
(run AND stream, bit-identical); the acceptance invariant that a
device-probe streamed batch performs no per-batch host transfers beyond
the positive-count read and the result readback (via the
`engine._note_host_sync` instrumentation hook); build-time validation of
probe= misconfiguration; `clear_program_cache` evicting the probe-program
caches; the `LSHJoin.overflow_frac` satellite (exposure, describe(),
warning above 1%); the DeviceSearcher protocol + PROBE_BUILDERS adapter
registry; and — in a forced-8-device subprocess — candidate-subset and
post-verify-count parity plus recall floors under BOTH topologies.
"""
import os
import subprocess
import sys
import warnings

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import DeviceSearcher, JoinPlan, make_join
from repro.core.engine import JoinEngine
from repro.core.joins.lsh import LSHJoin
from repro.core import probe as probe_mod

EPS = 0.4

LSH_PARAMS = dict(k=10, l=8, n_probes=4, W=2.5)
IVFPQ_PARAMS = dict(C=24, m=8, n_probe=8, n_candidates=600)


def _unit(rng, n, d):
    x = rng.normal(size=(n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)


@pytest.fixture(scope="module")
def clustered():
    """Clustered corpus/queries sharing centers — enough true pairs that
    approximate recall is a meaningful, stable number."""
    rng = np.random.default_rng(5)
    d, nc, spread = 32, 6, 0.03
    c = rng.normal(size=(nc, d))
    c /= np.linalg.norm(c, axis=1, keepdims=True)

    def draw(per):
        pts = (np.repeat(c, per, axis=0)
               + rng.normal(size=(nc * per, d)) * spread)
        pts /= np.linalg.norm(pts, axis=1, keepdims=True)
        return pts.astype(np.float32)

    return draw(150), draw(25)


# --------------------------------------------------- candidate-level parity
@pytest.mark.parametrize("backend,params", [
    ("lsh", LSH_PARAMS), ("ivfpq", IVFPQ_PARAMS)])
def test_device_probe_candidates_match_host(clustered, backend, params):
    """The placed probe program must produce, per query, exactly the host
    probe's candidate id set (shared math, shared tables) — the property
    that makes device-probe counts bit-identical to host-probe counts."""
    R, Q = clustered
    eng = JoinEngine(R, "l2", backend="jnp")
    searcher = eng.verifier(backend, **params)
    placed = eng.device_probe_for(backend, "device")
    assert placed is not None and placed.cand_width > 0
    host_cand = searcher.candidates(Q)
    qp = np.zeros((256, Q.shape[1]), np.float32)   # a capacity bucket
    qp[:len(Q)] = Q
    dev_cand = np.asarray(placed.probe(jnp.asarray(qp)))[:len(Q)]
    assert dev_cand.shape[1] == placed.cand_width
    for h, d in zip(host_cand, dev_cand):
        assert set(d[d >= 0].tolist()) == set(h[h >= 0].tolist())


# ------------------------------------------------------- count-level parity
@pytest.mark.parametrize("backend,params", [
    ("lsh", LSH_PARAMS), ("ivfpq", IVFPQ_PARAMS)])
def test_device_probe_counts_match_host(clustered, backend, params):
    """probe="device" and probe="host" must return identical counts for
    every verdict pattern, and never exceed the exact sweep."""
    R, Q = clustered
    eng = JoinEngine(R, "l2", backend="jnp")
    eng.verifier(backend, **params)
    true = eng.range_count(Q, EPS)
    rng = np.random.default_rng(3)
    for verdicts in (np.ones(len(Q), bool), rng.random(len(Q)) > 0.5):
        host = eng.filtered_join(Q, EPS, verdicts=verdicts, verify=backend,
                                 probe="host")
        dev = eng.filtered_join(Q, EPS, verdicts=verdicts, verify=backend,
                                probe="device")
        assert host.probe == "host" and dev.probe == "device"
        np.testing.assert_array_equal(dev.counts, host.counts)
        assert (dev.counts <= np.where(verdicts, true, 0)).all()


def test_stream_bit_identical_to_run_device_probe(clustered):
    """plan.stream with device probing must stay bit-identical to
    per-batch plan.run — the §11 pipeline reshuffle cannot change
    results, only overlap."""
    R, Q = clustered
    plan = (JoinPlan(R, "l2").search("naive").verify("lsh", **LSH_PARAMS)
            .on(backend="jnp", probe="device").build())
    assert plan.describe()["exec"]["probe"]["resolved"] == "device"
    batches = [Q[:50], Q[50:51], Q[51:]]   # ragged: distinct shape buckets
    sync = [plan.run(b, EPS) for b in batches]
    for depth in (0, 2):
        stream = list(plan.stream(batches, EPS, depth=depth))
        assert len(stream) == len(batches)
        for s, a in zip(sync, stream):
            np.testing.assert_array_equal(a.counts, s.counts)
            assert a.meta["probe"] == "device"


def test_auto_selects_device_probe_for_capable_base(clustered):
    """verify('auto') with an LSH base must pick device probing without
    being asked (the searcher advertises DeviceSearcher), while a
    candidates-less plug-in stays on the host route."""
    R, Q = clustered
    plan = (JoinPlan(R, "l2").search("lsh", **LSH_PARAMS)
            .on(backend="jnp").build())
    d = plan.describe()["exec"]["probe"]
    assert d["mode"] == "auto" and d["resolved"] == "device"
    assert d["table_bytes_per_device"] > 0
    res = plan.run(Q, EPS)
    assert res.meta["probe"] == "device"
    np.testing.assert_array_equal(res.counts,
                                  plan.base.query_counts(Q, EPS))


# ----------------------------------------------- host-sync instrumentation
def test_device_probe_route_host_syncs(clustered, monkeypatch):
    """The ISSUE 5 acceptance invariant: with probe="device", a streamed
    batch performs NO per-batch host transfer other than the
    positive-count read and the result readback; the host route performs
    its verdict readback + host probe as before."""
    R, Q = clustered
    eng = JoinEngine(R, "l2", backend="jnp")
    eng.verifier("lsh", **LSH_PARAMS)
    eng.filtered_join(Q, EPS, verify="lsh", probe="device")   # warm programs

    events = []
    monkeypatch.setattr("repro.core.engine._note_host_sync", events.append)
    # the host probe itself must never run on the device route
    monkeypatch.setattr(
        LSHJoin, "candidates",
        lambda *a, **k: pytest.fail("host probe called on device route"))
    batches = [Q[:64], Q[64:128], Q[128:]]
    out = list(eng.stream(batches, EPS, verify="lsh", probe="device",
                          depth=2))
    assert len(out) == 3
    # no filter -> verdicts are host-known, so not even the count read
    # syncs; with a fused filter the only extra event is "n_pos"
    assert set(events) <= {"n_pos", "result"}, events
    assert events.count("result") == len(batches)

    monkeypatch.undo()
    events2 = []
    monkeypatch.setattr("repro.core.engine._note_host_sync", events2.append)
    list(eng.stream(batches, EPS, verify="lsh", probe="host", depth=2))
    assert {"verdicts", "probe"} <= set(events2)


# ----------------------------------------------------- build-time validation
def test_probe_validation(clustered):
    R, Q = clustered
    eng = JoinEngine(R, "l2", backend="jnp")
    with pytest.raises(ValueError, match="probe="):
        eng.filtered_join(Q, EPS, verify="lsh", probe="gpu")
    with pytest.raises(ValueError, match="no probe stage"):
        eng.filtered_join(Q, EPS, verify="exact", probe="device")
    with pytest.raises(ValueError, match="no probe stage"):
        JoinPlan(R, "l2").search("naive").on(backend="jnp",
                                             probe="device").build()
    # a host-only searcher (no device_probe, not registered) under
    # probe="device" fails at build with an actionable message
    grid = make_join("grid", R, "l2")
    with pytest.raises(ValueError, match="no device probe"):
        JoinPlan(R, "l2").search(grid).on(backend="jnp",
                                          probe="device").build()
    # ... but keeps working under the default auto route (host probing)
    plan = JoinPlan(R, "l2").search(grid).on(backend="jnp").build()
    assert plan.describe()["exec"]["probe"]["resolved"] == "host"
    res = plan.run(Q, EPS)
    assert res.meta["probe"] == "host"


# --------------------------------------------------------- protocol/registry
def test_device_searcher_protocol(clustered):
    R, _ = clustered
    assert isinstance(make_join("lsh", R, "l2", **LSH_PARAMS),
                      DeviceSearcher)
    assert isinstance(make_join("ivfpq", R, "l2", **IVFPQ_PARAMS),
                      DeviceSearcher)
    assert not isinstance(make_join("grid", R, "l2"), DeviceSearcher)


def test_probe_builders_registry(clustered):
    """A searcher class that cannot grow device_probe() itself plugs in
    through the PROBE_BUILDERS registry — same counts, device route."""
    R, Q = clustered

    class _Wrapped:
        name = "wrapped"
        exact = False

        def __init__(self, R, metric):
            self._lsh = LSHJoin(R, metric, **LSH_PARAMS)

        def candidates(self, Q):
            return self._lsh.candidates(Q)

        def query_counts(self, Q, eps):
            return self._lsh.query_counts(Q, eps)

    probe_mod.register_probe(_Wrapped,
                             lambda s, eps: probe_mod.LSHProbe(s._lsh))
    try:
        eng = JoinEngine(R, "l2", backend="jnp")
        searcher = _Wrapped(R, "l2")
        dev = eng.filtered_join(Q, EPS, verify=searcher, probe="device")
        host = eng.filtered_join(Q, EPS, verify=searcher, probe="host")
        assert dev.probe == "device"
        np.testing.assert_array_equal(dev.counts, host.counts)
    finally:
        probe_mod.PROBE_BUILDERS.pop(_Wrapped, None)


def test_device_probe_small_block_q(clustered):
    """An engine whose padded batches are shorter than one ADC/verify
    tile (small block_q) must still probe on device with identical
    counts — the tile sizes fall back instead of failing to reshape."""
    R, Q = clustered
    eng = JoinEngine(R, "l2", backend="jnp", block_q=24)
    eng.verifier("ivfpq", **IVFPQ_PARAMS)
    eng.verifier("lsh", **LSH_PARAMS)
    q = Q[:20]                   # pads to 24 rows; capacity 24: % 64 != 0
    for backend in ("ivfpq", "lsh"):
        host = eng.filtered_join(q, EPS, verify=backend, probe="host")
        dev = eng.filtered_join(q, EPS, verify=backend, probe="device")
        np.testing.assert_array_equal(dev.counts, host.counts)


def test_retune_evicts_stale_placed_probe(clustered):
    """engine.verifier(name, **params) retunes replace the index; the
    previous index's placed probe (device-resident tables) must be
    evicted from the engine's probe cache, not pinned forever."""
    R, _ = clustered
    eng = JoinEngine(R, "l2", backend="jnp")
    eng.verifier("lsh", **LSH_PARAMS)
    p1 = eng.device_probe_for("lsh", "device")
    assert len(eng._probes) == 1
    eng.verifier("lsh", k=8, l=4, n_probes=2)
    p2 = eng.device_probe_for("lsh", "device")
    assert p2 is not p1
    assert len(eng._probes) == 1         # stale placement dropped


# ------------------------------------------------------------ cache eviction
def test_clear_program_cache_evicts_probe_programs(clustered):
    """engine.clear_program_cache() must evict the probe-program caches
    too (they key on the mesh and would otherwise pin executables for
    discarded meshes), and the route must transparently rebuild."""
    from repro.core import engine as engine_mod
    R, Q = clustered
    eng = JoinEngine(R, "l2", backend="jnp")
    eng.verifier("lsh", **LSH_PARAMS)
    want = eng.filtered_join(Q, EPS, verify="lsh", probe="device").counts
    assert probe_mod._gather_program.cache_info().currsize > 0
    assert (probe_mod._lsh_probe_program.cache_info().currsize
            + probe_mod._lsh_ring_probe_program.cache_info().currsize) > 0
    assert (probe_mod._probe_verify_program.cache_info().currsize
            + probe_mod._ring_probe_verify_program.cache_info().currsize) > 0
    engine_mod.clear_program_cache()
    for cache in (probe_mod._gather_program, probe_mod._lsh_probe_program,
                  probe_mod._lsh_ring_probe_program,
                  probe_mod._probe_verify_program,
                  probe_mod._ring_probe_verify_program):
        assert cache.cache_info().currsize == 0
    np.testing.assert_array_equal(
        eng.filtered_join(Q, EPS, verify="lsh", probe="device").counts, want)


# ------------------------------------------------------------- overflow_frac
def test_lsh_overflow_frac_exposed_and_warns(clustered):
    R, Q = clustered
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)
        quiet = LSHJoin(R, "l2", k=10, l=4, n_probes=2, cap=len(R))
    assert quiet.overflow_frac == 0.0
    with pytest.warns(RuntimeWarning, match="overflow"):
        lossy = LSHJoin(R, "l2", k=2, l=4, n_probes=2, n_buckets=4, cap=2)
    assert lossy.overflow_frac > 0.01
    # surfaced by describe() and per-result meta
    plan = (JoinPlan(R, "l2").search("naive")
            .verify(lossy).on(backend="jnp").build())
    d = plan.describe()
    assert d["verify"]["overflow_frac"] == pytest.approx(lossy.overflow_frac)
    res = plan.run(Q, EPS)
    assert res.meta["overflow_frac"] == pytest.approx(lossy.overflow_frac)
    # the exact route tracks none
    exact = JoinPlan(R, "l2").search("naive").on(backend="jnp").build()
    assert exact.describe()["verify"]["overflow_frac"] is None


def test_serve_batch_stats_reports_probe_and_overflow(clustered):
    """The serve per-batch report line carries the probe placement and
    the overflow fraction of the verify index."""
    from repro.launch.serve import batch_stats
    R, Q = clustered
    plan = (JoinPlan(R, "l2").search("naive").verify("lsh", **LSH_PARAMS)
            .on(backend="jnp", probe="device").build())
    res = plan.run(Q, EPS)
    line = batch_stats(0, res, np.asarray(plan.engine.range_count(Q, EPS)))
    assert line["probe"] == "device"
    assert line["overflow_frac"] == pytest.approx(
        plan.engine.verifier("lsh").overflow_frac)


# ------------------------------------------------------- multi-device (mesh)
@pytest.mark.slow
def test_device_probe_subprocess_8dev():
    """Forced 8-host-device subprocess: under BOTH topologies
    (replicated data mesh, 2x4 ring mesh) the device probe's candidates
    are a subset of the host probe's with equal post-verify counts,
    plan.stream stays bit-identical to per-batch run with device probing
    on, and the lsh/ivfpq recall floors hold vs the exact oracle."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    code = (
        "import os\n"
        "os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'\n"
        "import numpy as np, jax\n"
        "import jax.numpy as jnp\n"
        "from repro.launch.mesh import make_data_mesh, make_join_mesh\n"
        "from repro.core.engine import JoinEngine\n"
        "from repro.core.api import JoinPlan\n"
        "assert len(jax.devices()) == 8\n"
        "rng = np.random.default_rng(5)\n"
        "c = rng.normal(size=(6, 32))\n"
        "c /= np.linalg.norm(c, axis=1, keepdims=True)\n"
        "def draw(per):\n"
        "    p = (np.repeat(c, per, axis=0)\n"
        "         + rng.normal(size=(6 * per, 32)) * 0.03)\n"
        "    return (p / np.linalg.norm(p, axis=1, keepdims=True))"
        ".astype(np.float32)\n"
        "R, Q = draw(150), draw(25)\n"
        "SPECS = {'lsh': (dict(k=10, l=8, n_probes=4, W=2.5), 0.90),\n"
        "         'ivfpq': (dict(C=24, m=8, n_probe=8, n_candidates=600),"
        " 0.95)}\n"
        "for mesh, topo in ((make_data_mesh(), 'replicated'),\n"
        "                   (make_join_mesh(data=4, r=2), 'ring')):\n"
        "    eng = JoinEngine(R, 'l2', mesh=mesh, backend='jnp',"
        " topology=topo)\n"
        "    true = eng.range_count(Q, 0.4)\n"
        "    assert true.sum() > 1000\n"
        "    for name, (params, floor) in SPECS.items():\n"
        "        searcher = eng.verifier(name, **params)\n"
        "        placed = eng.device_probe_for(name, 'device')\n"
        "        host_cand = searcher.candidates(Q)\n"
        "        qp = np.zeros((256, Q.shape[1]), np.float32)\n"
        "        qp[:len(Q)] = Q\n"
        "        dev_cand = np.asarray(placed.probe(jnp.asarray(qp)))"
        "[:len(Q)]\n"
        "        for h, d in zip(host_cand, dev_cand):\n"
        "            hs, ds = set(h[h >= 0].tolist()), "
        "set(d[d >= 0].tolist())\n"
        "            assert ds <= hs, (topo, name)\n"
        "        v = np.ones(len(Q), bool)\n"
        "        host = eng.filtered_join(Q, 0.4, verdicts=v, verify=name,"
        " probe='host')\n"
        "        dev = eng.filtered_join(Q, 0.4, verdicts=v, verify=name,"
        " probe='device')\n"
        "        np.testing.assert_array_equal(dev.counts, host.counts)\n"
        "        assert (dev.counts <= true).all()\n"
        "        recall = float(np.minimum(dev.counts, true).sum()"
        " / true.sum())\n"
        "        assert recall >= floor, (topo, name, recall)\n"
        "        batches = [Q[:10], Q[10:11], Q[11:]]\n"
        "        stream = list(eng.stream(batches, 0.4, verify=name,"
        " probe='device', depth=2))\n"
        "        sync = [eng.filtered_join(b, 0.4, verify=name,"
        " probe='device') for b in batches]\n"
        "        for s, a in zip(sync, stream):\n"
        "            np.testing.assert_array_equal(a.counts, s.counts)\n"
        "    plan = (JoinPlan(R, 'l2').search('naive')\n"
        "            .verify('lsh', **SPECS['lsh'][0])\n"
        "            .on(engine=eng, backend='jnp', probe='device')"
        ".build())\n"
        "    pd = plan.describe()['exec']['probe']\n"
        "    assert pd['resolved'] == 'device' and "
        "pd['table_bytes_per_device'] > 0, pd\n"
        "print('DEVICE_PROBE_8DEV_OK')\n"
    )
    out = subprocess.run(
        [sys.executable, "-c", code], env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True, text=True, timeout=600)
    assert "DEVICE_PROBE_8DEV_OK" in out.stdout, out.stderr[-3000:]
