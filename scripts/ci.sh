#!/usr/bin/env bash
# Single CI entry point (DESIGN.md §8 test lanes):
#   scripts/ci.sh          — docs gate + fast lane (default; target < 90 s)
#   scripts/ci.sh full     — docs gate + tier-1 full suite (includes slow)
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== repo hygiene =="
if git ls-files | grep -q '^\.cache/'; then
    echo "FAIL: experiment caches tracked in git:" >&2
    git ls-files | grep '^\.cache/' >&2
    exit 1
fi
big=$(git ls-files | while IFS= read -r f; do
    [ -f "$f" ] && [ "$(wc -c < "$f")" -gt 1048576 ] && echo "$f"
done || true)
if [ -n "$big" ]; then
    echo "FAIL: tracked files exceed 1 MB:" >&2
    echo "$big" >&2
    exit 1
fi
echo "hygiene OK"

echo "== docs-check =="
python scripts/check_docstrings.py

echo "== pytest (${1:-fast} lane) =="
if [ "${1:-fast}" = "full" ]; then
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q
else
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -q -m "not slow"
fi
