#!/usr/bin/env bash
# Single CI entry point (DESIGN.md §8 test lanes):
#   scripts/ci.sh          — hygiene + xlint gate (incl. the docs gate,
#                            DESIGN.md §12) + fast lane (incl. the runtime
#                            transfer-guard lane) + bench smoke snapshot
#                            (default; target < 2 min)
#   scripts/ci.sh full     — same, but tier-1 full suite (includes slow)
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== repo hygiene =="
if git ls-files | grep -q '^\.cache/'; then
    echo "FAIL: experiment caches tracked in git:" >&2
    git ls-files | grep '^\.cache/' >&2
    exit 1
fi
big=$(git ls-files | while IFS= read -r f; do
    [ -f "$f" ] && [ "$(wc -c < "$f")" -gt 1048576 ] && echo "$f"
done || true)
if [ -n "$big" ]; then
    echo "FAIL: tracked files exceed 1 MB:" >&2
    echo "$big" >&2
    exit 1
fi
echo "hygiene OK"

# xlint folds the old standalone docs gate in as its docstring-gate rule;
# it runs BEFORE the test lanes so invariant violations fail in seconds
echo "== xlint (static analysis, DESIGN.md §12) =="
python scripts/xlint

echo "== pytest (${1:-fast} lane) =="
if [ "${1:-fast}" = "full" ]; then
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q
else
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -q -m "not slow"
fi

# property-lane non-vacuity gate: the lane once silently skipped
# wholesale when `hypothesis` was missing; hypo_compat now substitutes a
# seeded-rng driver, and this gate fails CI if the lane ever reports
# zero passes again (skip-only = vacuous = red)
echo "== property lane non-vacuity =="
prop_out=$(PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -q tests/test_property.py | tail -n 2)
echo "$prop_out"
if ! echo "$prop_out" | grep -Eq '[1-9][0-9]* passed'; then
    echo "FAIL: tests/test_property.py reported no passing tests — the" >&2
    echo "property lane is vacuous (hypothesis missing AND hypo_compat" >&2
    echo "fallback broken?)" >&2
    exit 1
fi

# device-probe smoke (DESIGN.md §11): single-device parity of the
# probe="device" route with host probing, under the jnp backend AND the
# pallas backend (interpret mode off-TPU) — the new layer cannot regress
# silently on hosts without accelerators
echo "== device-probe smoke (jnp + pallas-interpret) =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'EOF'
import numpy as np
from repro.core import JoinPlan

rng = np.random.default_rng(0)
def unit(n, d):
    x = rng.normal(size=(n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)

R, Q = unit(600, 16), unit(96, 16)
for backend in ("jnp", "pallas"):
    dev = (JoinPlan(R, "l2").search("naive")
           .verify("lsh", k=8, l=6, n_probes=4)
           .on(backend=backend, probe="device").build())
    host = (JoinPlan(R, "l2").search("naive")
            .verify("lsh", k=8, l=6, n_probes=4)
            .on(engine=dev.engine, backend=backend, probe="host").build())
    assert dev.describe()["exec"]["probe"]["resolved"] == "device"
    a, b = dev.run(Q, 0.8), host.run(Q, 0.8)
    np.testing.assert_array_equal(a.counts, b.counts)
    exact = np.asarray(dev.engine.range_count(Q, 0.8))   # engine sweep on
    assert (a.counts <= exact).all()                     # this backend
    sc = np.concatenate(
        [r.counts for r in dev.stream([Q[:48], Q[48:]], 0.8)])
    np.testing.assert_array_equal(sc, a.counts)
    print(f"device-probe smoke OK (backend={backend})")
EOF

# smoke-scale perf snapshot: proves the BENCH_<n>.json trajectory pipeline
# (benchmarks/run.py --snapshot) end-to-end without touching the tracked
# top-level snapshots — the real per-PR snapshot is written deliberately
echo "== bench snapshot (smoke) =="
snap_dir=$(mktemp -d)
trap 'rm -rf "$snap_dir"' EXIT
REPRO_BENCH_SCALE=small REPRO_BENCH_OUT="$snap_dir" \
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run --only kernels \
    --snapshot-out "$snap_dir/BENCH_smoke.json" > "$snap_dir/bench.log"
python - "$snap_dir/BENCH_smoke.json" <<'EOF'
import json, sys
snap = json.load(open(sys.argv[1]))
rows = snap["suites"].get("kernels", {})
assert rows, f"smoke snapshot captured no kernel rows: {snap}"
print(f"snapshot OK ({len(rows)} rows, scale={snap['scale']})")
EOF
