#!/usr/bin/env bash
# Single CI entry point (DESIGN.md §8 test lanes):
#   scripts/ci.sh          — docs gate + fast lane (default; target < 90 s)
#   scripts/ci.sh full     — docs gate + tier-1 full suite (includes slow)
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== docs-check =="
python scripts/check_docstrings.py

echo "== pytest (${1:-fast} lane) =="
if [ "${1:-fast}" = "full" ]; then
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q
else
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -q -m "not slow"
fi
