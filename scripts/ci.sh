#!/usr/bin/env bash
# Single CI entry point (DESIGN.md §8 test lanes):
#   scripts/ci.sh          — hygiene + xlint gate (incl. the docs gate,
#                            DESIGN.md §12) + fast lane (incl. the runtime
#                            transfer-guard lane) + bench smoke snapshot
#                            (default; target < 2 min)
#   scripts/ci.sh full     — same, but tier-1 full suite (includes slow)
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== repo hygiene =="
if git ls-files | grep -q '^\.cache/'; then
    echo "FAIL: experiment caches tracked in git:" >&2
    git ls-files | grep '^\.cache/' >&2
    exit 1
fi
big=$(git ls-files | while IFS= read -r f; do
    [ -f "$f" ] && [ "$(wc -c < "$f")" -gt 1048576 ] && echo "$f"
done || true)
if [ -n "$big" ]; then
    echo "FAIL: tracked files exceed 1 MB:" >&2
    echo "$big" >&2
    exit 1
fi
echo "hygiene OK"

# xlint folds the old standalone docs gate in as its docstring-gate rule;
# it runs BEFORE the test lanes so invariant violations fail in seconds
echo "== xlint (static analysis, DESIGN.md §12) =="
python scripts/xlint

echo "== pytest (${1:-fast} lane) =="
if [ "${1:-fast}" = "full" ]; then
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q
else
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -q -m "not slow"
fi

# property-lane non-vacuity gate: the lane once silently skipped
# wholesale when `hypothesis` was missing; hypo_compat now substitutes a
# seeded-rng driver, and this gate fails CI if the lane ever reports
# zero passes again (skip-only = vacuous = red)
echo "== property lane non-vacuity =="
prop_out=$(PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -q tests/test_property.py | tail -n 2)
echo "$prop_out"
if ! echo "$prop_out" | grep -Eq '[1-9][0-9]* passed'; then
    echo "FAIL: tests/test_property.py reported no passing tests — the" >&2
    echo "property lane is vacuous (hypothesis missing AND hypo_compat" >&2
    echo "fallback broken?)" >&2
    exit 1
fi

# device-probe smoke (DESIGN.md §11): single-device parity of the
# probe="device" route with host probing, under the jnp backend AND the
# pallas backend (interpret mode off-TPU) — the new layer cannot regress
# silently on hosts without accelerators
echo "== device-probe smoke (jnp + pallas-interpret) =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'EOF'
import numpy as np
from repro.core import JoinPlan

rng = np.random.default_rng(0)
def unit(n, d):
    x = rng.normal(size=(n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)

R, Q = unit(600, 16), unit(96, 16)
for backend in ("jnp", "pallas"):
    dev = (JoinPlan(R, "l2").search("naive")
           .verify("lsh", k=8, l=6, n_probes=4)
           .on(backend=backend, probe="device").build())
    host = (JoinPlan(R, "l2").search("naive")
            .verify("lsh", k=8, l=6, n_probes=4)
            .on(engine=dev.engine, backend=backend, probe="host").build())
    assert dev.describe()["exec"]["probe"]["resolved"] == "device"
    a, b = dev.run(Q, 0.8), host.run(Q, 0.8)
    np.testing.assert_array_equal(a.counts, b.counts)
    exact = np.asarray(dev.engine.range_count(Q, 0.8))   # engine sweep on
    assert (a.counts <= exact).all()                     # this backend
    sc = np.concatenate(
        [r.counts for r in dev.stream([Q[:48], Q[48:]], 0.8)])
    np.testing.assert_array_equal(sc, a.counts)
    print(f"device-probe smoke OK (backend={backend})")
EOF

# gateway smoke (DESIGN.md §14): two tenant classes with mixed radii
# through one pinned engine — scatter-back parity per request, the
# coalescing counters actually fire, a mutation invalidates the cache,
# and the SLO report is well-formed (serializable, all counter keys)
echo "== serving gateway smoke (two tenants, mixed eps) =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'EOF'
import json
import numpy as np
from repro.serve import Gateway, TenantClass

rng = np.random.default_rng(0)
def unit(n, d=16):
    x = rng.normal(size=(n, d)).astype(np.float32)
    return x / np.linalg.norm(x, axis=1, keepdims=True)

R = unit(400)
gw = Gateway(R, [TenantClass("gold", eps=0.45, verify="exact",
                             slo_ms=10_000.0),
                 TenantClass("bulk", eps=0.5, recall_target=0.9,
                             verify="lsh",
                             verify_params=dict(k=10, l=8, n_probes=4,
                                                W=2.5))],
             backend="jnp", mutable=True, auto_compact_at=None)
reqs = [(("gold", "bulk")[i % 2], unit(9),
         (0.45, 0.5)[i % 2]) for i in range(8)]
tickets = [gw.submit(t, q, eps=e) for t, q, e in reqs]
gw.flush()
for (t, q, e), tk in zip(reqs, tickets):
    np.testing.assert_array_equal(
        tk.counts, np.asarray(gw.plan(t).run(q, e).counts))
rep = gw.report()
assert rep["tenants"]["gold"]["metrics"]["coalesced_batches"] >= 1
assert rep["tenants"]["bulk"]["metrics"]["coalesced_requests"] >= 2
assert gw.join("gold", reqs[0][1]).meta["cache_hits"] == 9  # replay hits
gw.insert(unit(8))                                # bumps world_version
assert gw.join("gold", reqs[0][1]).meta["cache_hits"] == 0  # none survive
rep = json.loads(json.dumps(gw.report()))         # well-formed SLO report
for name in ("gold", "bulk"):
    m = rep["tenants"][name]["metrics"]
    missing = {"admitted_requests", "served_requests", "slo_misses",
               "coalesced_batches", "cache_hit_queries", "p50_ms",
               "p95_ms"} - set(m)
    assert not missing, missing
    assert m["admitted_requests"] == m["served_requests"]
assert rep["world_version"] == 1
print(f"gateway smoke OK (world_version={rep['world_version']}, "
      f"gold p50={rep['tenants']['gold']['metrics']['p50_ms']:.1f}ms)")
EOF

# auto-planner smoke (DESIGN.md §16): plan a deliberately skewed smoke
# dataset, assert the explain() rationale is well-formed and the chosen
# configuration runs bit-identically to an identically-configured twin
# on the `ref` kernel backend — whatever the planner picks, the result
# is still the oracle's
echo "== auto-planner smoke (skewed corpus, ref-twin parity) =="
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python - <<'EOF'
import json
import numpy as np
from repro.core import JoinPlan
from repro.core.planner import REBUCKET_HOT

rng = np.random.default_rng(0)
def unit(x):
    return (x / np.linalg.norm(x, axis=1, keepdims=True)).astype(np.float32)

bg = rng.normal(size=(450, 24))
hot = rng.normal(size=(1, 24)) + 0.03 * rng.normal(size=(150, 24))
R = unit(np.concatenate([bg, hot]))
Q = unit(rng.normal(size=(32, 24)))

plan = JoinPlan(R, "cosine").filter("none").auto(0.4, Q, recall=0.9, seed=0)
ex = json.loads(json.dumps(plan.explain()))       # machine-readable
ch = ex["chosen"]
assert ex["candidates"] and ex["skew"]["hashed_rows"] == len(R)
assert ch["verify"] in ("exact", "lsh", "lsh+rebucket", "ivfpq")

# identically-configured twin on the ref kernel backend
twin = JoinPlan(R, "cosine").filter("none").search("naive")
if ch["verify"] == "exact":
    twin = twin.verify("exact")
elif ch["verify"].startswith("lsh"):
    params = {} if ch["verify"] == "lsh" else dict(rebucket_hot=REBUCKET_HOT)
    twin = twin.verify("lsh", **params)
else:
    twin = twin.verify("ivfpq")
twin = twin.on(backend="ref", block=int(ch["block"])).build()
a = np.asarray(plan.run(Q, 0.4).counts)
np.testing.assert_array_equal(a, np.asarray(twin.run(Q, 0.4).counts))
print(f"planner smoke OK (chosen={ch['verify']}/{ch['probe']}"
      f"/{ch['topology']}{ch['r_shards']}, est={ch['est_us']}us/q)")
EOF

# smoke-scale perf snapshot: proves the BENCH_<n>.json trajectory pipeline
# (benchmarks/run.py --snapshot) end-to-end without touching the tracked
# top-level snapshots — the real per-PR snapshot is written deliberately
echo "== bench snapshot (smoke) =="
snap_dir=$(mktemp -d)
trap 'rm -rf "$snap_dir"' EXIT
REPRO_BENCH_SCALE=small REPRO_BENCH_OUT="$snap_dir" \
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run --only kernels \
    --snapshot-out "$snap_dir/BENCH_smoke.json" > "$snap_dir/bench.log"
python - "$snap_dir/BENCH_smoke.json" <<'EOF'
import json, sys
snap = json.load(open(sys.argv[1]))
rows = snap["suites"].get("kernels", {})
assert rows, f"smoke snapshot captured no kernel rows: {snap}"
print(f"snapshot OK ({len(rows)} rows, scale={snap['scale']})")
EOF

# perf gate: diff the smoke kernel rows against the latest committed
# BENCH_<n>.json (kernel shapes are scale-independent, so smoke-vs-
# committed is apples-to-apples); >25% regression on any common row
# fails the build (benchmarks/run.py --compare)
echo "== bench compare (perf gate) =="
latest=$(ls BENCH_*.json 2>/dev/null | sort -V | tail -1)
if [ -n "$latest" ]; then
    REPRO_BENCH_SCALE=small REPRO_BENCH_OUT="$snap_dir" \
        PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        python -m benchmarks.run --only kernels --compare "$latest" \
        | grep "^# compare" || { echo "bench compare FAILED"; exit 1; }
    echo "bench compare OK (vs $latest)"
else
    echo "no committed BENCH_*.json yet - compare skipped"
fi
