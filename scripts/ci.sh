#!/usr/bin/env bash
# Single CI entry point (DESIGN.md §8 test lanes):
#   scripts/ci.sh          — hygiene + docs gate + fast lane + bench smoke
#                            snapshot (default; target < 2 min)
#   scripts/ci.sh full     — same, but tier-1 full suite (includes slow)
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== repo hygiene =="
if git ls-files | grep -q '^\.cache/'; then
    echo "FAIL: experiment caches tracked in git:" >&2
    git ls-files | grep '^\.cache/' >&2
    exit 1
fi
big=$(git ls-files | while IFS= read -r f; do
    [ -f "$f" ] && [ "$(wc -c < "$f")" -gt 1048576 ] && echo "$f"
done || true)
if [ -n "$big" ]; then
    echo "FAIL: tracked files exceed 1 MB:" >&2
    echo "$big" >&2
    exit 1
fi
echo "hygiene OK"

echo "== docs-check =="
python scripts/check_docstrings.py

echo "== pytest (${1:-fast} lane) =="
if [ "${1:-fast}" = "full" ]; then
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q
else
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -q -m "not slow"
fi

# smoke-scale perf snapshot: proves the BENCH_<n>.json trajectory pipeline
# (benchmarks/run.py --snapshot) end-to-end without touching the tracked
# top-level snapshots — the real per-PR snapshot is written deliberately
echo "== bench snapshot (smoke) =="
snap_dir=$(mktemp -d)
trap 'rm -rf "$snap_dir"' EXIT
REPRO_BENCH_SCALE=small REPRO_BENCH_OUT="$snap_dir" \
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run --only kernels \
    --snapshot-out "$snap_dir/BENCH_smoke.json" > "$snap_dir/bench.log"
python - "$snap_dir/BENCH_smoke.json" <<'EOF'
import json, sys
snap = json.load(open(sys.argv[1]))
rows = snap["suites"].get("kernels", {})
assert rows, f"smoke snapshot captured no kernel rows: {snap}"
print(f"snapshot OK ({len(rows)} rows, scale={snap['scale']})")
EOF
