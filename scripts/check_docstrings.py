#!/usr/bin/env python
"""Docs gate: every public function/class/method in the serving-surface
modules must carry a docstring (the `make docs-check` target, wired into
CI via scripts/ci.sh and tests/test_docs.py).

Since xlint landed (DESIGN.md §12) the check itself lives in
`scripts/xlint/rules/docstrings.py` as the `docstring-gate` rule — this
script is a thin shim kept so the historical entry point, its CLI
contract (exit 1 + `file:line qualname` offender lines, explicit paths
override the default module set), and `make docs-check` keep working.
The default set is the serving surface (core/api.py, core/engine.py,
core/probe.py, core/topology.py, core/xjoin.py, launch/serve.py) plus
the xlint package itself.
"""
from __future__ import annotations

import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "scripts"))

from xlint.rules.docstrings import (  # noqa: E402  (path bootstrap first)
    CHECKED, default_targets, missing_docstrings)

__all__ = ["CHECKED", "missing_docstrings", "main"]


def main(argv: list[str]) -> int:
    """Check the serving-surface modules (or explicit paths in argv)."""
    paths = [Path(a) for a in argv] or default_targets(REPO)
    offenders: list[str] = []
    for p in paths:
        try:
            rel = p.resolve().relative_to(REPO)
        except ValueError:              # explicit path outside the repo
            rel = p
        offenders += [f"{rel}:{line} {qual}"
                      for line, qual in missing_docstrings(p, REPO)]
    if offenders:
        print("public definitions missing docstrings:")
        for o in offenders:
            print(f"  {o}")
        return 1
    print(f"docs-check OK ({len(paths)} modules)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
