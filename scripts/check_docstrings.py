#!/usr/bin/env python
"""Docs gate: every public function/class/method in the serving-surface
modules must carry a docstring (the `make docs-check` target, wired into
CI via scripts/ci.sh and tests/test_docs.py).

Checked modules: core/api.py (the JoinPlan + Filter/Searcher protocol
surface), core/engine.py, core/topology.py (the placement layer),
core/probe.py (the device-resident probing layer), core/xjoin.py,
launch/serve.py — the public API a user touches to serve a join stream. "Public" = module-level
defs, classes, and methods of public classes whose names don't start with
an underscore (dunder methods other than __init__ are exempt; __init__ is
exempt when the owning class documents construction in its own docstring).
Exits 1 listing offenders as file:line so editors can jump to them.
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
CHECKED = (
    "src/repro/core/api.py",
    "src/repro/core/engine.py",
    "src/repro/core/probe.py",
    "src/repro/core/topology.py",
    "src/repro/core/xjoin.py",
    "src/repro/launch/serve.py",
)


def _is_public(name: str) -> bool:
    return not name.startswith("_")


def missing_docstrings(path: Path) -> list[str]:
    """[f"{path}:{line} <qualname>"] for every undocumented public def."""
    tree = ast.parse(path.read_text(), filename=str(path))
    offenders: list[str] = []
    try:
        rel = path.relative_to(REPO)
    except ValueError:                      # explicit path outside the repo
        rel = path

    if ast.get_docstring(tree) is None:
        offenders.append(f"{rel}:1 <module>")

    def visit(node, prefix: str) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if _is_public(child.name):
                    if ast.get_docstring(child) is None:
                        offenders.append(
                            f"{rel}:{child.lineno} {prefix}{child.name}")
            elif isinstance(child, ast.ClassDef):
                if _is_public(child.name):
                    if ast.get_docstring(child) is None:
                        offenders.append(
                            f"{rel}:{child.lineno} {prefix}{child.name}")
                    visit(child, prefix=f"{prefix}{child.name}.")
    visit(tree, prefix="")
    return offenders


def main(argv: list[str]) -> int:
    """Check the serving-surface modules (or explicit paths in argv)."""
    paths = [Path(a) for a in argv] or [REPO / p for p in CHECKED]
    offenders: list[str] = []
    for p in paths:
        offenders += missing_docstrings(p)
    if offenders:
        print("public definitions missing docstrings:")
        for o in offenders:
            print(f"  {o}")
        return 1
    print(f"docs-check OK ({len(paths)} modules)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
