"""xlint — the repo-native static-analysis suite (DESIGN.md §12).

PRs 1–5 built a device-resident join pipeline whose correctness rests on
conventions: every mesh is constructed through `launch/mesh.py::make_mesh`
(§7), the streamed hot path performs exactly two per-batch host transfers
(§11), and every compiled-program `lru_cache` in `core/` is evictable by
`engine.clear_program_cache()` (§4/§12).  xlint turns those conventions
into machine-checked rules: each rule is a small AST-walking plugin in
`xlint/rules/`, registered in `xlint.registry.RULES` and mapped to the
DESIGN.md section it enforces.

Run it as `python scripts/xlint` (the `make lint` target and the first
gate in `scripts/ci.sh`); `tests/test_lint.py` proves every rule fires on
a fixture violation and that the repo itself lints clean.  The companion
RUNTIME layer — `jax.transfer_guard` around the streamed hot path — lives
in `core/engine.py::_allowed_transfer` + `tests/test_guards.py`.

Deliberate deviations are annotated in-line, never in a suppression file:

    # xlint: allow-<rule-id>(<reason>)          suppress on this/next line
    # xlint: allow-host-sync(<kind>: <reason>)  host-sync needs a declared
                                                _note_host_sync kind
    # xlint: scope(<rule-id>)                   opt a file into a rule
                                                (test fixtures)

Stale or malformed annotations are themselves violations (the
annotation-hygiene rule), so suppressions cannot rot.
"""
from xlint.core import Annotation, LintFile, Rule, Violation, lint_paths
from xlint.registry import RULES, rules_for

__all__ = ["Annotation", "LintFile", "Rule", "Violation", "lint_paths",
           "RULES", "rules_for"]
