"""xlint command line: `python scripts/xlint [paths...] [--rule ID]`.

With no paths, lints the whole repository (every `.py` outside
`EXCLUDED_DIRS`).  Exit status 0 = clean, 1 = violations (one
`path:line: [rule-id] message` line each).  `--rule` narrows to a
subset of rules (`make docs-check` is `--rule docstring-gate`);
`--list-rules` prints the registry table.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from xlint.core import iter_py_files, lint_paths
from xlint.registry import RULES, rules_for

#: scripts/xlint/cli.py -> the repository root
REPO_ROOT = Path(__file__).resolve().parents[2]


def main(argv=None) -> int:
    """Parse arguments, run the selected rules, print findings."""
    parser = argparse.ArgumentParser(
        prog="xlint",
        description="repo-native static analysis for the DESIGN.md "
                    "invariants")
    parser.add_argument("paths", nargs="*", type=Path,
                        help="files/directories to lint (default: the "
                             "whole repository)")
    parser.add_argument("--rule", action="append", dest="rule_ids",
                        metavar="ID", choices=sorted(RULES),
                        help="run only this rule (repeatable)")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule table and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule in RULES.values():
            print(f"{rule.id:20s} {rule.design_ref:5s} {rule.description}")
        return 0

    rules = rules_for(args.rule_ids)
    if args.paths:
        files = []
        for p in args.paths:
            files.extend(iter_py_files(p) if p.is_dir() else [p])
    else:
        files = iter_py_files(REPO_ROOT)

    violations = lint_paths(files, rules, root=REPO_ROOT)
    for v in violations:
        print(v.render())
    if violations:
        print(f"xlint: {len(violations)} violation(s)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
