"""Entry point so `python scripts/xlint` and `python -m xlint` both work.

Running the package as a *directory* (`python scripts/xlint`) puts the
package dir itself — not its parent — on `sys.path`, so the absolute
`xlint.*` imports used throughout the package would fail; prepending the
parent fixes both invocation styles.
"""
import sys
from pathlib import Path

_parent = str(Path(__file__).resolve().parent.parent)
if _parent not in sys.path:
    sys.path.insert(0, _parent)

from xlint.cli import main  # noqa: E402  (path bootstrap must run first)

sys.exit(main())
