"""xlint framework core: file model, annotation grammar, rule runner.

A `LintFile` is one parsed source file (AST + lines + `# xlint:`
annotations, extracted from real COMMENT tokens only, so grammar examples
inside docstrings never parse as live annotations).  A `Rule` is a plugin
that selects files and emits `Violation`s; the runner applies generic
`allow-<rule-id>` suppression and tracks which annotations earned their
keep — the annotation-hygiene rule flags the rest as stale.
"""
from __future__ import annotations

import ast
import io
import re
import tokenize
from dataclasses import dataclass, field
from pathlib import Path
from typing import Iterable, Optional

#: `# xlint: <directive>(<arg>)` — the whole annotation on ONE comment
#: line.  directive is `allow-<rule-id>` or `scope`; arg is the reason
#: (allow) or the rule id (scope).
ANNOTATION_RE = re.compile(
    r"#\s*xlint:\s*(?P<directive>[A-Za-z][\w-]*)\s*"
    r"(?:\(\s*(?P<arg>[^)]*?)\s*\))?")

#: Directory names never walked by the default repo lint (fixtures are
#: linted explicitly by tests/test_lint.py, one rule at a time).
EXCLUDED_DIRS = {".git", ".cache", "__pycache__", "fixtures",
                 "experiments", "node_modules", ".claude"}


@dataclass(frozen=True)
class Annotation:
    """One parsed `# xlint:` comment: line number, directive, argument."""
    line: int
    directive: str
    arg: str


@dataclass(frozen=True)
class Violation:
    """One rule finding, pointing at `rel`:`line` with a rule id.

    `suppressible=False` marks findings about the annotations themselves
    (bad kind, stale suppression) that an `allow-` comment must not be
    able to silence."""
    rel: str
    line: int
    rule: str
    message: str
    suppressible: bool = True

    def render(self) -> str:
        """`path:line: [rule-id] message` — the CLI output line."""
        return f"{self.rel}:{self.line}: [{self.rule}] {self.message}"


@dataclass
class LintFile:
    """One source file prepared for linting."""
    path: Path
    rel: str
    source: str
    lines: list[str]
    tree: Optional[ast.AST]
    annotations: dict[int, Annotation]
    scoped_rules: set[str] = field(default_factory=set)
    used_annotations: set[int] = field(default_factory=set)

    @classmethod
    def load(cls, path: Path, root: Path) -> "LintFile":
        """Read + parse one file; a syntax error leaves `tree=None` (the
        runner reports it as an unsuppressible parse-error finding)."""
        source = path.read_text(encoding="utf-8", errors="replace")
        try:
            rel = str(path.resolve().relative_to(root.resolve()))
        except ValueError:
            rel = str(path)
        try:
            tree = ast.parse(source, filename=str(path))
        except SyntaxError:
            tree = None
        annotations = _parse_annotations(source)
        scoped = {a.arg for a in annotations.values()
                  if a.directive == "scope" and a.arg}
        return cls(path=path, rel=rel, source=source,
                   lines=source.splitlines(), tree=tree,
                   annotations=annotations, scoped_rules=scoped)

    def allow_at(self, line: int, rule_id: str) -> Optional[Annotation]:
        """The `allow-<rule_id>` annotation governing `line`: on the line
        itself or on the comment line immediately above."""
        for ln in (line, line - 1):
            a = self.annotations.get(ln)
            if a is not None and a.directive == f"allow-{rule_id}":
                return a
        return None

    def mark_used(self, annotation: Annotation) -> None:
        """Record that `annotation` suppressed or legitimized a finding
        (anything still unused afterwards is a stale suppression)."""
        self.used_annotations.add(annotation.line)


def _parse_annotations(source: str) -> dict[int, Annotation]:
    """{line: Annotation} for every `# xlint:` COMMENT token. Tokenizing
    (instead of regex over raw lines) keeps annotation examples inside
    docstrings and string literals inert."""
    out: dict[int, Annotation] = {}
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            m = ANNOTATION_RE.search(tok.string)
            if m and "xlint" in tok.string:
                out[tok.start[0]] = Annotation(
                    line=tok.start[0], directive=m.group("directive"),
                    arg=(m.group("arg") or "").strip())
    except (tokenize.TokenError, IndentationError, SyntaxError):
        pass                    # unparseable file: reported via tree=None
    return out


class Rule:
    """Base class for xlint rules.

    Subclasses set `id` (the annotation/CLI name), `design_ref` (the
    DESIGN.md section the rule enforces), `description`, and implement
    `check(lf)`. `select(lf)` defaults to path-suffix targeting via
    `targets` plus `# xlint: scope(<id>)` opt-in; `targets = None` means
    repo-wide."""

    id: str = ""
    design_ref: str = ""
    description: str = ""
    #: repo-relative path suffixes the rule applies to (None = all files)
    targets: Optional[tuple[str, ...]] = None

    def select(self, lf: LintFile) -> bool:
        """Whether this rule applies to `lf` (targets or scope opt-in)."""
        if self.id in lf.scoped_rules:
            return True
        if self.targets is None:
            return True
        rel = lf.rel.replace("\\", "/")
        return any(rel.endswith(t) for t in self.targets)

    def check(self, lf: LintFile) -> list[Violation]:
        """Return this rule's findings for one file."""
        raise NotImplementedError

    def violation(self, lf: LintFile, line: int, message: str, *,
                  suppressible: bool = True) -> Violation:
        """Build a `Violation` carrying this rule's id."""
        return Violation(rel=lf.rel, line=line, rule=self.id,
                         message=f"{message} (DESIGN.md {self.design_ref})",
                         suppressible=suppressible)


def iter_py_files(root: Path) -> list[Path]:
    """Every lintable `.py` under `root`, skipping `EXCLUDED_DIRS`."""
    out = []
    for p in sorted(root.rglob("*.py")):
        if any(part in EXCLUDED_DIRS for part in p.relative_to(root).parts):
            continue
        out.append(p)
    return out


def lint_paths(paths: Iterable[Path], rules: list[Rule], *,
               root: Path) -> list[Violation]:
    """Run `rules` over `paths` and return surviving violations.

    Per file: every selecting rule runs, then generic suppression drops
    findings covered by an `allow-<rule-id>` annotation on the same or
    previous line (marking the annotation used).  Rules whose findings
    concern annotations themselves emit `suppressible=False` and are
    exempt.  The annotation-hygiene rule (id "annotation-hygiene") is
    always run LAST so it sees which annotations went unused."""
    hygiene = [r for r in rules if r.id == "annotation-hygiene"]
    ordered = [r for r in rules if r.id != "annotation-hygiene"] + hygiene
    out: list[Violation] = []
    for path in paths:
        lf = LintFile.load(path, root)
        if lf.tree is None:
            out.append(Violation(rel=lf.rel, line=1, rule="parse-error",
                                 message="file does not parse",
                                 suppressible=False))
            continue
        for rule in ordered:
            if not rule.select(lf):
                continue
            for v in rule.check(lf):
                if v.suppressible:
                    a = lf.allow_at(v.line, v.rule)
                    if a is not None:
                        lf.mark_used(a)
                        continue
                out.append(v)
    return out
