"""xlint rule registry: the rule table (DESIGN.md §12).

Every rule plugin is instantiated exactly once here; `RULES` maps rule
id → instance and is the single source of truth for the CLI
(`--list-rules`, `--rule`), the annotation-hygiene rule (known ids),
and the DESIGN.md §12 rule table.  To add a rule: drop a module in
`xlint/rules/`, subclass `xlint.core.Rule`, and register it below —
`tests/test_lint.py::test_rule_fires_on_fixture` will demand a fixture
proving it fires.
"""
from __future__ import annotations

from xlint.rules.annotations import AnnotationHygieneRule
from xlint.rules.cache_registry import CacheRegistryRule
from xlint.rules.docstrings import DocstringRule
from xlint.rules.host_sync import HostSyncRule
from xlint.rules.jit_cache_key import JitCacheKeyRule
from xlint.rules.mesh_policy import MeshPolicyRule

_CORE_RULES = (
    MeshPolicyRule(),
    HostSyncRule(),
    CacheRegistryRule(),
    JitCacheKeyRule(),
    DocstringRule(),
)

#: rule id -> rule instance; annotation-hygiene is built last so it can
#: validate directives against every other registered id
RULES = {r.id: r for r in _CORE_RULES}
RULES["annotation-hygiene"] = AnnotationHygieneRule(set(RULES))


def rules_for(ids=None):
    """The rule instances for `ids` (all registered rules when None)."""
    if ids is None:
        return list(RULES.values())
    unknown = [i for i in ids if i not in RULES]
    if unknown:
        raise KeyError(f"unknown rule id(s): {', '.join(unknown)}")
    return [RULES[i] for i in ids]
