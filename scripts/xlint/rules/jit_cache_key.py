"""jit-cache-key rule (DESIGN.md §12): program-cache keys stay static.

The `lru_cache` program builders key compiled XLA executables on their
arguments, so every parameter must be hashable, static geometry (mesh,
axis names, block sizes, frozen topology dataclasses).  An array-typed
or dict/list parameter either raises `unhashable type` at first call or
— worse, via a fresh default object per call — defeats the cache and
recompiles every batch.  This rule rejects, on any module-level
`lru_cache` function in `src/repro/core/`:

  * parameters annotated with an unhashable/array type
    (dict/list/set/ndarray/Array/DeviceArray)
  * mutable or call-expression default values (`{}`, `[]`, `set()`,
    `make_thing()` — a fresh object per definition breaks key equality)
"""
from __future__ import annotations

import ast

from xlint.core import LintFile, Rule, Violation
from xlint.rules.cache_registry import lru_cached_module_functions

#: annotation identifiers that cannot be lru_cache keys
UNHASHABLE = {"dict", "Dict", "list", "List", "set", "Set", "ndarray",
              "Array", "ArrayLike", "DeviceArray"}


def _annotation_ids(node: ast.AST) -> set[str]:
    """All bare identifiers appearing in an annotation expression."""
    ids = set()
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name):
            ids.add(sub.id)
        elif isinstance(sub, ast.Attribute):
            ids.add(sub.attr)
        elif isinstance(sub, ast.Constant) and isinstance(sub.value, str):
            ids.update(p for p in sub.value.replace("[", " ").split()
                       if p.isidentifier())    # string annotations
    return ids


class JitCacheKeyRule(Rule):
    """Reject unhashable params on lru_cache'd program builders."""

    id = "jit-cache-key"
    design_ref = "§12"
    description = ("lru_cache'd program builders may only take hashable "
                   "static args — array/dict params break or defeat the "
                   "program cache")
    targets = None

    def select(self, lf: LintFile) -> bool:
        """Only `src/repro/core/**` (or scope-annotated fixtures)."""
        if self.id in lf.scoped_rules:
            return True
        return "src/repro/core/" in lf.rel.replace("\\", "/")

    def check(self, lf: LintFile) -> list[Violation]:
        """Flag unhashable annotations and mutable/call defaults."""
        out: list[Violation] = []
        for fn in lru_cached_module_functions(lf.tree):
            a = fn.args
            for arg in (a.posonlyargs + a.args + a.kwonlyargs):
                if arg.annotation is not None:
                    bad = _annotation_ids(arg.annotation) & UNHASHABLE
                    if bad:
                        out.append(self.violation(
                            lf, arg.lineno,
                            f"cache key param {arg.arg!r} of {fn.name!r} "
                            f"annotated {sorted(bad)[0]!r} — program-cache "
                            "keys must be hashable static geometry"))
            defaults = a.defaults + [d for d in a.kw_defaults
                                     if d is not None]
            for default in defaults:
                if isinstance(default, (ast.Dict, ast.List, ast.Set,
                                        ast.ListComp, ast.DictComp,
                                        ast.SetComp, ast.Call)):
                    out.append(self.violation(
                        lf, default.lineno,
                        f"mutable/call default in {fn.name!r}'s cache key "
                        "— a fresh object per definition breaks lru_cache "
                        "key equality"))
        return out
