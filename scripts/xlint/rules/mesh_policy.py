"""mesh-policy rule (DESIGN.md §7): one mesh constructor, version-compat.

JAX 0.4.37 lacks `jax.sharding.AxisType`; every mesh in the repo must be
built through `launch/mesh.py::make_mesh`, which feature-detects the
enum.  This rule rejects, everywhere EXCEPT that module:

  * `jax.sharding.Mesh(...)` / bare imported `Mesh(...)` constructor calls
  * `jax.make_mesh(...)` calls
  * any attribute access of `AxisType` (including `getattr` probing is
    left to mesh.py — nobody else should even reference the name)
  * an `axis_types=` keyword in any call
  * `from jax.sharding import Mesh / AxisType` imports

Type annotations (`m: jax.sharding.Mesh`) stay legal — only calls,
keywords, and `AxisType` references are policy violations.
"""
from __future__ import annotations

import ast

from xlint.core import LintFile, Rule, Violation

#: the one module allowed to touch the raw constructors
EXEMPT = ("src/repro/launch/mesh.py",)


def _dotted(node: ast.AST) -> str:
    """Best-effort dotted name of an expression (`jax.sharding.Mesh`)."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


class MeshPolicyRule(Rule):
    """Flag mesh construction that bypasses `make_mesh` (DESIGN.md §7)."""

    id = "mesh-policy"
    design_ref = "§7"
    description = ("all mesh construction goes through "
                   "launch/mesh.py::make_mesh; never touch "
                   "jax.sharding.AxisType or axis_types= directly")
    targets = None              # repo-wide

    def select(self, lf: LintFile) -> bool:
        """Everywhere except the mesh module itself."""
        rel = lf.rel.replace("\\", "/")
        if any(rel.endswith(e) for e in EXEMPT):
            return False
        return super().select(lf)

    def check(self, lf: LintFile) -> list[Violation]:
        """Walk the AST for raw-constructor calls and AxisType refs."""
        out: list[Violation] = []
        for node in ast.walk(lf.tree):
            if isinstance(node, ast.Attribute) and node.attr == "AxisType":
                out.append(self.violation(
                    lf, node.lineno,
                    "jax.sharding.AxisType referenced directly — "
                    "launch/mesh.py::make_mesh owns version compat"))
            elif isinstance(node, ast.Call):
                name = _dotted(node.func)
                if name.endswith("sharding.Mesh") or name == "Mesh":
                    out.append(self.violation(
                        lf, node.lineno,
                        f"raw mesh constructor {name}(...) — build meshes "
                        "via launch/mesh.py::make_mesh"))
                elif name.endswith("jax.make_mesh"):
                    out.append(self.violation(
                        lf, node.lineno,
                        "jax.make_mesh(...) called directly — use "
                        "launch/mesh.py::make_mesh"))
                for kw in node.keywords:
                    if kw.arg == "axis_types":
                        out.append(self.violation(
                            lf, node.lineno,
                            "axis_types= passed directly — only "
                            "launch/mesh.py::make_mesh may feature-detect "
                            "it"))
            elif isinstance(node, ast.ImportFrom):
                if node.module and node.module.startswith("jax.sharding"):
                    for alias in node.names:
                        if alias.name in ("Mesh", "AxisType"):
                            out.append(self.violation(
                                lf, node.lineno,
                                f"importing {alias.name} from jax.sharding "
                                "— construct meshes via "
                                "launch/mesh.py::make_mesh"))
        return out
