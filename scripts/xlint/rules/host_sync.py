"""host-sync rule (DESIGN.md §11/§12): no silent syncs in the hot path.

The streamed join pipeline's performance claim is that the exact and
device-probe routes perform exactly two per-batch host transfers — the
positive-count read and the result readback.  This rule keeps new code
from quietly adding a third: inside the HOT functions of
`core/engine.py` / `core/probe.py` (the three pipeline stages, the
stream/session drivers, and the placed-probe dispatchers, nested
closures included) it flags

  * `np.asarray(...)` / `int(...)` / `float(...)` applied to a
    device-resident value — recognized by the repo-wide `*dev` naming
    convention (`st.n_pos_dev`, `counts_dev`, `qdev`, ...)
  * `.item()` and `.block_until_ready()` anywhere in a hot function

unless the line (or the comment line above it) carries

    # xlint: allow-host-sync(<kind>: <reason>)

where `<kind>` must be a sync kind DECLARED in the same module by a
`_note_host_sync("<kind>")` / `_allowed_transfer("<kind>")` call — the
annotation is only valid adjacent to instrumentation, so the static
suppression and the runtime guard/instrumentation layers can never
drift apart.  A fixture file opts in with `# xlint: scope(host-sync)`,
which makes EVERY function hot.
"""
from __future__ import annotations

import ast
import re

from xlint.core import LintFile, Rule, Violation

#: device-resident values follow the `*dev` suffix convention
DEV_NAME_RE = re.compile(r".*dev$")

#: hot-path functions per target file (qualnames)
HOT_FUNCTIONS = {
    "src/repro/core/engine.py": {
        "JoinEngine._stage_filter", "JoinEngine._stage_probe",
        "JoinEngine._commit_verify", "JoinEngine.stream",
        "PendingJoin.result", "StreamSession.submit", "StreamSession.flush",
        "StreamSession._commit_probed", "StreamSession._advance_staged",
    },
    "src/repro/core/probe.py": {
        "PlacedProbe.probe", "PlacedProbe.verify",
    },
    # the push-interface session (api.py) and the gateway's per-request
    # path sit directly on the stream pipeline — same two-syncs budget
    "src/repro/core/api.py": {
        "PlanSession.submit", "PlanSession.flush",
    },
    # planner measurement programs: one sanctioned histogram readback per
    # auto() (annotated allow-host-sync), nothing on the per-batch path
    "src/repro/core/planner.py": {
        "measure_skew", "measure_workload",
    },
    "src/repro/serve/gateway.py": {
        "Gateway.submit", "Gateway._pump", "Gateway._scatter",
        "Gateway.flush",
    },
}


def _mentions_dev_value(node: ast.AST) -> bool:
    """Whether any identifier under `node` names a device value."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and DEV_NAME_RE.match(sub.id):
            return True
        if isinstance(sub, ast.Attribute) and DEV_NAME_RE.match(sub.attr):
            return True
    return False


def _declared_kinds(tree: ast.AST) -> set[str]:
    """Sync kinds declared by `_note_host_sync("...")` /
    `_allowed_transfer("...")` calls in this module."""
    kinds: set[str] = set()
    for node in ast.walk(tree):
        if (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
                and node.func.id in ("_note_host_sync", "_allowed_transfer")
                and node.args
                and isinstance(node.args[0], ast.Constant)
                and isinstance(node.args[0].value, str)):
            kinds.add(node.args[0].value)
    return kinds


def _sync_calls(fn: ast.AST):
    """(node, label) for every host-sync-shaped call under `fn`."""
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        if isinstance(f, ast.Attribute):
            if f.attr == "block_until_ready":
                yield node, ".block_until_ready()"
            elif f.attr == "item":
                yield node, ".item()"
            elif (f.attr == "asarray" and isinstance(f.value, ast.Name)
                    and f.value.id in ("np", "numpy")
                    and _mentions_dev_value(node)):
                yield node, "np.asarray() on a device value"
        elif isinstance(f, ast.Name) and f.id in ("int", "float"):
            if node.args and _mentions_dev_value(node.args[0]):
                yield node, f"{f.id}() on a device value"


class HostSyncRule(Rule):
    """Flag unannotated host syncs in the pipeline hot path (§11)."""

    id = "host-sync"
    design_ref = "§11"
    description = ("hot-path host syncs (np.asarray/int/float on *dev "
                   "values, .item, block_until_ready) must carry "
                   "allow-host-sync(<kind>: <reason>) with an "
                   "instrumented kind")
    targets = tuple(HOT_FUNCTIONS)

    def _hot_functions(self, lf: LintFile) -> list[ast.AST]:
        rel = lf.rel.replace("\\", "/")
        hot = None
        for path, names in HOT_FUNCTIONS.items():
            if rel.endswith(path):
                hot = names
        out = []

        def visit(node: ast.AST, prefix: str) -> None:
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                    qual = f"{prefix}{child.name}"
                    if hot is None or qual in hot:
                        out.append(child)
                    # nested defs of a hot fn are covered by ast.walk;
                    # only class bodies need descending here
                elif isinstance(child, ast.ClassDef):
                    visit(child, prefix=f"{prefix}{child.name}.")

        visit(lf.tree, "")      # hot=None (scoped fixture): all functions
        return out

    def check(self, lf: LintFile) -> list[Violation]:
        """Flag sync-shaped calls in hot functions, validating the
        `allow-host-sync(<kind>: <reason>)` annotations against the
        module's declared instrumentation kinds."""
        declared = _declared_kinds(lf.tree)
        out: list[Violation] = []
        seen: set[int] = set()
        for fn in self._hot_functions(lf):
            for node, label in _sync_calls(fn):
                if node.lineno in seen:
                    continue
                seen.add(node.lineno)
                ann = lf.allow_at(node.lineno, self.id)
                if ann is None:
                    out.append(self.violation(
                        lf, node.lineno,
                        f"{label} in hot path without an "
                        "allow-host-sync(<kind>: <reason>) annotation"))
                    continue
                kind, _, reason = ann.arg.partition(":")
                kind, reason = kind.strip(), reason.strip()
                if kind not in declared or not reason:
                    out.append(self.violation(
                        lf, node.lineno,
                        f"allow-host-sync kind {kind!r} is not a "
                        "_note_host_sync/_allowed_transfer kind declared "
                        "in this module (or the reason is empty)",
                        suppressible=False))
                else:
                    lf.mark_used(ann)
        return out
