"""annotation-hygiene rule (DESIGN.md §12): suppressions cannot rot.

In-line `# xlint:` annotations are the ONLY suppression mechanism (no
suppression file), so they must stay trustworthy.  This rule runs LAST
— after every other rule has had the chance to `mark_used` the
annotations that legitimized a real finding — and flags

  * unknown directives (must be `allow-<known-rule-id>` or
    `scope(<known-rule-id>)`)
  * `scope(...)` naming a rule id that does not exist
  * `allow-*` annotations with an empty reason
  * STALE `allow-*` annotations: ones that no rule consumed, i.e. the
    code they excused no longer triggers the rule

Every finding is `suppressible=False`: an annotation cannot excuse
another annotation.
"""
from __future__ import annotations

from xlint.core import LintFile, Rule, Violation


class AnnotationHygieneRule(Rule):
    """Flag unknown, malformed, and stale `# xlint:` annotations."""

    id = "annotation-hygiene"
    design_ref = "§12"
    description = ("xlint annotations must name a real rule, carry a "
                   "reason, and still excuse a live finding — stale "
                   "suppressions are violations")
    targets = None              # repo-wide; must run after all other rules

    def __init__(self, known_rule_ids: set[str]):
        """`known_rule_ids`: every registered rule id (from the registry),
        used to validate `allow-<id>` / `scope(<id>)` directives."""
        self.known_rule_ids = set(known_rule_ids) | {self.id}

    def check(self, lf: LintFile) -> list[Violation]:
        """Validate every annotation in the file against the registry and
        the set of annotations other rules marked used."""
        out: list[Violation] = []
        for ann in lf.annotations.values():
            if ann.directive == "scope":
                if ann.arg not in self.known_rule_ids:
                    out.append(self.violation(
                        lf, ann.line,
                        f"scope({ann.arg!r}) names no registered rule",
                        suppressible=False))
                continue
            if not ann.directive.startswith("allow-"):
                out.append(self.violation(
                    lf, ann.line,
                    f"unknown xlint directive {ann.directive!r} — use "
                    "allow-<rule-id>(<reason>) or scope(<rule-id>)",
                    suppressible=False))
                continue
            rule_id = ann.directive[len("allow-"):]
            if rule_id not in self.known_rule_ids:
                out.append(self.violation(
                    lf, ann.line,
                    f"allow-{rule_id} names no registered rule",
                    suppressible=False))
                continue
            if not ann.arg:
                out.append(self.violation(
                    lf, ann.line,
                    f"allow-{rule_id} carries no reason — write "
                    f"allow-{rule_id}(<reason>)", suppressible=False))
                continue
            if ann.line not in lf.used_annotations:
                out.append(self.violation(
                    lf, ann.line,
                    f"stale allow-{rule_id} — no finding on this or the "
                    "next line needed it; delete the annotation",
                    suppressible=False))
        return out
