"""xlint rule plugins — one module per enforced DESIGN.md invariant.

mesh_policy    §7   all mesh construction via launch/mesh.py::make_mesh
host_sync      §11  annotated, instrumented host syncs only in hot paths
cache_registry §12  every core/ lru_cache program builder is registered
jit_cache_key  §12  program-builder cache keys stay hashable/static
docstrings     §8   the docs gate (public serving surface + xlint itself)
annotations    §12  xlint annotations are well-formed and never stale
"""
