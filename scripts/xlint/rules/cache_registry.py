"""cache-registry rule (DESIGN.md §12): no unevictable program caches.

`engine.clear_program_cache()` iterates the `_PROGRAM_CACHES` registry;
a module-level `functools.lru_cache` program builder in `core/` that
never registers would pin XLA executables (and their device buffers)
past mesh teardown and silently survive eviction — the forgotten-cache
failure mode this rule removes.  Every `@functools.lru_cache` decorated
module-level function in `src/repro/core/` or the serving gateway
package `src/repro/serve/` (whose sessions outlive individual batches,
so a pinned program there survives compaction too) must also carry the
`@register_program_cache` decorator (stacked above the cache, engine.py)
or be explicitly waived with `# xlint: allow-cache-registry(<reason>)`.

The naming convention is enforced in BOTH directions: a module-level
function whose name ends in `_program` (the program-builder convention —
the dynamic-R delta/tombstone builders included, DESIGN.md §13) must be
lru_cache'd AND registered even if the author forgot the cache
decorator entirely, so a new builder cannot dodge the registry by
skipping memoization.
"""
from __future__ import annotations

import ast

from xlint.core import LintFile, Rule, Violation


def _decorator_names(fn: ast.FunctionDef) -> list[str]:
    """Dotted name of each decorator (Call decorators unwrapped)."""
    names = []
    for dec in fn.decorator_list:
        node = dec.func if isinstance(dec, ast.Call) else dec
        parts: list[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if isinstance(node, ast.Name):
            parts.append(node.id)
        if parts:
            names.append(".".join(reversed(parts)))
    return names


def _has(fn: ast.FunctionDef, suffix: str) -> bool:
    return any(n == suffix or n.endswith(f".{suffix}")
               for n in _decorator_names(fn))


def lru_cached_module_functions(tree: ast.AST) -> list[ast.FunctionDef]:
    """Module-level `@functools.lru_cache` functions (program builders)."""
    out = []
    for node in ast.iter_child_nodes(tree):
        if (isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                and _has(node, "lru_cache")):
            out.append(node)
    return out


class CacheRegistryRule(Rule):
    """Require `register_program_cache` on every core/ lru_cache."""

    id = "cache-registry"
    design_ref = "§12"
    description = ("every module-level functools.lru_cache program "
                   "builder in core/ or serve/ must be registered in "
                   "engine._PROGRAM_CACHES via @register_program_cache")
    targets = None              # selection is path-prefix based below

    def select(self, lf: LintFile) -> bool:
        """`src/repro/core/**` and `src/repro/serve/**` (or
        scope-annotated fixtures)."""
        if self.id in lf.scoped_rules:
            return True
        rel = lf.rel.replace("\\", "/")
        return ("src/repro/core/" in rel
                or "src/repro/serve/" in rel)

    def check(self, lf: LintFile) -> list[Violation]:
        """Flag lru_cache'd builders missing @register_program_cache."""
        out: list[Violation] = []
        flagged: set[int] = set()
        for fn in lru_cached_module_functions(lf.tree):
            if not _has(fn, "register_program_cache"):
                flagged.add(fn.lineno)
                out.append(self.violation(
                    lf, fn.lineno,
                    f"lru_cache'd program builder {fn.name!r} is not "
                    "registered in engine._PROGRAM_CACHES — "
                    "clear_program_cache() would silently miss it; stack "
                    "@register_program_cache above the lru_cache"))
        # the `_program` naming convention: builders must opt INTO the
        # cache + registry stack, not dodge it by omitting lru_cache
        for node in ast.iter_child_nodes(lf.tree):
            if not (isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and node.name.endswith("_program")):
                continue
            if node.lineno in flagged:
                continue            # already reported by the loop above
            if not (_has(node, "lru_cache")
                    and _has(node, "register_program_cache")):
                out.append(self.violation(
                    lf, node.lineno,
                    f"program builder {node.name!r} (by the *_program "
                    "naming convention) must stack @register_program_cache "
                    "over @functools.lru_cache — an unmemoized or "
                    "unregistered builder either recompiles per call or "
                    "survives clear_program_cache()"))
        return out
