"""docstring-gate rule (DESIGN.md §8): the docs gate as an xlint rule.

Migrated from the standalone `scripts/check_docstrings.py` (which now
delegates here so `make docs-check` and tests keep their entry point):
every public function/class/method in the serving-surface modules — and
in the xlint framework itself — must carry a docstring.  "Public" =
module-level defs, classes, and methods of public classes whose names
don't start with an underscore; dunders other than `__init__` are
exempt, and `__init__` is exempt when the owning class documents
construction in its own docstring.
"""
from __future__ import annotations

import ast
from pathlib import Path

from xlint.core import LintFile, Rule, Violation

#: repo-relative serving-surface modules under the gate, plus the xlint
#: package itself and the gateway package `src/repro/serve/` (both
#: globbed at runtime so new modules are auto-covered)
CHECKED = (
    "src/repro/core/api.py",
    "src/repro/core/engine.py",
    "src/repro/core/planner.py",
    "src/repro/core/probe.py",
    "src/repro/core/topology.py",
    "src/repro/core/xjoin.py",
    "src/repro/kernels/adc_rank.py",
    "src/repro/kernels/lsh_gather.py",
    "src/repro/launch/serve.py",
    "src/repro/launch/xla_flags.py",
)


def default_targets(repo: Path) -> list[Path]:
    """The gated module paths: the serving surface, the gateway package
    (`src/repro/serve/`), and `scripts/xlint/`."""
    paths = [repo / p for p in CHECKED]
    paths += sorted((repo / "src" / "repro" / "serve").rglob("*.py"))
    paths += sorted((repo / "scripts" / "xlint").rglob("*.py"))
    return paths


def _is_public(name: str) -> bool:
    return not name.startswith("_")


def missing_docstrings(path: Path, repo: Path) -> list[tuple[int, str]]:
    """[(line, qualname)] for every undocumented public def in `path`."""
    tree = ast.parse(path.read_text(), filename=str(path))
    offenders: list[tuple[int, str]] = []
    if ast.get_docstring(tree) is None:
        offenders.append((1, "<module>"))

    def visit(node, prefix: str) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if _is_public(child.name) and \
                        ast.get_docstring(child) is None:
                    offenders.append((child.lineno, f"{prefix}{child.name}"))
            elif isinstance(child, ast.ClassDef):
                if _is_public(child.name):
                    if ast.get_docstring(child) is None:
                        offenders.append(
                            (child.lineno, f"{prefix}{child.name}"))
                    visit(child, prefix=f"{prefix}{child.name}.")

    visit(tree, prefix="")
    return offenders


class DocstringRule(Rule):
    """Flag undocumented public defs on the gated modules (§8)."""

    id = "docstring-gate"
    design_ref = "§8"
    description = ("public defs in the serving-surface modules and "
                   "scripts/xlint/ must carry docstrings (the docs gate, "
                   "make docs-check)")
    targets = CHECKED + ("src/repro/serve", "scripts/xlint")

    def select(self, lf: LintFile) -> bool:
        """Gated modules, the gateway package, the xlint package, or
        scoped fixtures."""
        if self.id in lf.scoped_rules:
            return True
        rel = lf.rel.replace("\\", "/")
        return (any(rel.endswith(t) for t in CHECKED)
                or "src/repro/serve/" in rel
                or "scripts/xlint/" in rel)

    def check(self, lf: LintFile) -> list[Violation]:
        """Report one violation per undocumented public definition."""
        out: list[Violation] = []
        if ast.get_docstring(lf.tree) is None:
            out.append(self.violation(
                lf, 1, "module is missing a docstring"))

        def visit(node, prefix: str) -> None:
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                    if _is_public(child.name) and \
                            ast.get_docstring(child) is None:
                        out.append(self.violation(
                            lf, child.lineno,
                            f"public def {prefix}{child.name!s} is missing "
                            "a docstring"))
                elif isinstance(child, ast.ClassDef):
                    if _is_public(child.name):
                        if ast.get_docstring(child) is None:
                            out.append(self.violation(
                                lf, child.lineno,
                                f"public class {prefix}{child.name!s} is "
                                "missing a docstring"))
                        visit(child, prefix=f"{prefix}{child.name}.")

        visit(lf.tree, prefix="")
        return out
