"""repro — Xling/XJoin (learned-filter similarity join) as a multi-pod JAX framework."""

__version__ = "0.1.0"
