from repro.data.synthetic import DATASETS, DatasetSpec, load_dataset
from repro.data.groundtruth import cardinality_table, eps_grid_for_metric
from repro.data.pipeline import ShardedBatcher, token_batches

__all__ = [
    "DATASETS", "DatasetSpec", "load_dataset",
    "cardinality_table", "eps_grid_for_metric",
    "ShardedBatcher", "token_batches",
]
