"""Input pipelines.

Two consumers:
  * estimator training — ShardedBatcher over (point, eps, target) tuples:
    epoch shuffling, drop-remainder static batches, device placement with an
    optional data-axis sharding (so the same code feeds 1-device CPU runs
    and multi-pod meshes).
  * LM-arch training (the end-to-end driver) — token_batches: a synthetic
    token stream with deterministic per-step RNG, sharded over the DP axis.
    Per the assignment the modality frontends are stubs, so [audio]/[vlm]
    archs consume precomputed frame/patch embeddings from input_specs()
    instead of raw media.
"""
from __future__ import annotations

from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


class ShardedBatcher:
    """Epoch-shuffled, drop-remainder batches; optionally device-sharded."""

    def __init__(self, arrays: tuple[np.ndarray, ...], batch_size: int,
                 seed: int = 0, sharding: Optional[jax.sharding.Sharding] = None):
        n = arrays[0].shape[0]
        assert all(a.shape[0] == n for a in arrays)
        self.arrays = arrays
        self.n = n
        self.batch_size = batch_size
        self.rng = np.random.default_rng(seed)
        self.sharding = sharding

    def __len__(self) -> int:
        return self.n // self.batch_size

    def epoch(self) -> Iterator[tuple[jax.Array, ...]]:
        perm = self.rng.permutation(self.n)
        nb = len(self)
        for b in range(nb):
            idx = perm[b * self.batch_size:(b + 1) * self.batch_size]
            batch = tuple(a[idx] for a in self.arrays)
            if self.sharding is not None:
                batch = tuple(jax.device_put(x, self.sharding) for x in batch)
            yield batch


def token_batches(vocab: int, global_batch: int, seq_len: int, *, seed: int = 0,
                  sharding: Optional[jax.sharding.Sharding] = None
                  ) -> Iterator[jax.Array]:
    """Deterministic synthetic token stream for the LM training driver."""
    step = 0
    while True:
        rng = np.random.default_rng((seed, step))
        toks = rng.integers(0, vocab, size=(global_batch, seq_len), dtype=np.int32)
        x = jnp.asarray(toks)
        if sharding is not None:
            x = jax.device_put(x, sharding)
        yield x
        step += 1
