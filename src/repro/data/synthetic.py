"""Offline stand-ins for the paper's six evaluation corpora.

The container has no network, so FastText/Glove/Word2vec/Gist/Sift/NUS-WIDE
cannot be downloaded. Each stand-in reproduces the *shape* of the original:
its dimensionality, its unit-normalization (the paper normalizes all vectors)
and a Gaussian-mixture cluster structure whose spread is tuned so that the
portion of negative queries at the paper's evaluation eps (0.4/0.45/0.5)
falls in the paper's reported 10%-95% range (Table III). Sizes are scaled by
`n` (default 20k vs the paper's 150k) to fit the 1-core CI budget — a config
knob, not a code fork.

Every dataset is split 8:2 into R (train/index side) and S (queries), as in
the paper, and a *second disjoint sample* is available for the
generalization experiments (Fig. 4/5) via ``load_dataset(..., sample=2)``.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.utils import cache_path


@dataclass(frozen=True)
class DatasetSpec:
    name: str
    dim: int
    n_clusters: int
    spread: float          # within-cluster noise scale (always-positive pop.)
    pair_frac: float       # "threshold pairs": NN distance inside the eps band
    pair_band: tuple       # (lo, hi) distance band for pair separation
    outlier_frac: float    # isotropic background points (always negative)
    metric: str            # paper: cosine for text, l2 for image
    kind: str              # "text" | "image"


# Three populations per corpus: dense clusters (positives at any eval eps),
# threshold pairs whose partner sits at a controlled distance inside the
# evaluation band (these flip negative->positive as eps grows — the steep
# Table III decay), and isotropic outliers (pairwise d_cos ~ 1, d_l2 ~ sqrt2
# in high dim: negatives at any eval eps). Fractions tuned so the
# negative-query portions at eps in {0.4,0.45,0.5} track the paper's
# Table III (see benchmarks/bench_negative_portion.py).
DATASETS: dict[str, DatasetSpec] = {
    "fasttext": DatasetSpec("fasttext", 300, 24, 0.40, 0.13, (0.33, 0.52), 0.008, "cosine", "text"),
    "glove":    DatasetSpec("glove",    200, 160, 0.45, 0.24, (0.36, 0.53), 0.63, "cosine", "text"),
    "word2vec": DatasetSpec("word2vec", 300, 64, 0.42, 0.25, (0.34, 0.53), 0.06, "cosine", "text"),
    "gist":     DatasetSpec("gist",     960, 96, 0.25, 0.80, (0.38, 0.52), 0.08, "l2", "image"),
    "sift":     DatasetSpec("sift",     128, 128, 0.25, 0.46, (0.36, 0.53), 0.13, "l2", "image"),
    "nuswide":  DatasetSpec("nuswide",  500, 400, 0.28, 0.03, (0.40, 0.52), 0.945, "l2", "image"),
}


def _pair_points(rng, n_pairs: int, dim: int, band: tuple, metric: str) -> np.ndarray:
    """2*n_pairs unit vectors in isolated pairs at controlled distance."""
    u = rng.normal(size=(n_pairs, dim))
    u /= np.linalg.norm(u, axis=1, keepdims=True)
    w = rng.normal(size=(n_pairs, dim))
    w -= np.sum(w * u, axis=1, keepdims=True) * u
    w /= np.linalg.norm(w, axis=1, keepdims=True)
    dist = np.exp(rng.uniform(np.log(band[0]), np.log(band[1]), size=(n_pairs, 1)))
    if metric == "cosine":
        cos = 1.0 - dist
    else:  # l2 on the unit sphere: d^2 = 2 - 2 cos
        cos = 1.0 - dist ** 2 / 2.0
    cos = np.clip(cos, -1.0, 1.0)
    v = cos * u + np.sqrt(1.0 - cos ** 2) * w
    return np.concatenate([u, v], axis=0)


def _generate(spec: DatasetSpec, n: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    n_out = int(spec.outlier_frac * n)
    n_pair = int(spec.pair_frac * n) // 2 * 2
    n_clu = n - n_out - n_pair

    centers = rng.normal(size=(spec.n_clusters, spec.dim))
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    # zipf-ish cluster weights: real embedding corpora are uneven (the
    # data-unawareness of LSH that the paper attacks shows up exactly here)
    w = 1.0 / np.arange(1, spec.n_clusters + 1) ** 0.8
    w /= w.sum()
    assign = rng.choice(spec.n_clusters, size=n_clu, p=w)
    noise = rng.normal(size=(n_clu, spec.dim)) * (spec.spread / np.sqrt(spec.dim))
    x_clu = centers[assign] + noise

    x_pair = _pair_points(rng, n_pair // 2, spec.dim, spec.pair_band, spec.metric)
    x_out = rng.normal(size=(n_out, spec.dim))
    x = np.concatenate([x_clu, x_pair, x_out], axis=0)
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    rng.shuffle(x)
    return x.astype(np.float32)


def load_dataset(name: str, n: int = 20000, seed: int = 0, sample: int = 1,
                 split: bool = True):
    """Returns (R, S, spec) with |R|:|S| = 8:2, or (X, spec) if split=False.

    sample=2 gives the disjoint "second 150k" used by the generalization
    experiments (same distribution, fresh draw).
    """
    spec = DATASETS[name]
    path = cache_path("synthetic-v1", name, n, seed, sample)
    try:
        with np.load(path) as z:
            x = z["x"]
    except (FileNotFoundError, OSError):
        x = _generate(spec, n, seed + 104729 * (sample - 1))
        np.savez_compressed(path, x=x)
    if not split:
        return x, spec
    n_train = int(0.8 * n)
    return x[:n_train], x[n_train:], spec
