"""Ground-truth cardinality pipeline (training targets for the estimator).

For the indexed set R and a sorted candidate-eps grid (m values — Def. 4's
{c_i1..c_im}), builds the full target table t[i, j] = |{r in R :
d(p_i, r) <= eps_j}| in ONE blocked sweep via the fused range_count kernel.
The table is cached on disk: it is the single most expensive offline
artifact (O(|R|^2 d)) and is reused by ATCS, XDT selection and every
benchmark.
"""
from __future__ import annotations

import numpy as np

from repro.utils import cache_path

# paper §VI-B1: candidate grids per metric, m=100 evenly spaced values
EPS_RANGE = {"cosine": (0.4, 0.9), "l2": (0.5, 2.0)}


def eps_grid_for_metric(metric: str, m: int = 100) -> np.ndarray:
    lo, hi = EPS_RANGE[metric]
    return np.linspace(lo, hi, m).astype(np.float32)


def cardinality_table(points: np.ndarray, index_set: np.ndarray,
                      eps_grid: np.ndarray, metric: str,
                      *, backend: str = "auto", block: int = 4096,
                      cache_key: tuple | None = None,
                      exclude_self: bool = False, mesh=None,
                      engine=None) -> np.ndarray:
    """t[i, j] = #-neighbors of points[i] in index_set within eps_grid[j].

    Runs as ONE sharded device sweep through the engine: the points (query)
    axis distributes over `mesh`'s data axis when a mesh is given; without
    one it is a single-device program with bucketed static shapes (the old
    per-`block` host loop is gone). Counts are identical either way.

    engine: a prebuilt `JoinEngine` over (index_set, metric) — reuses its
    device-resident padded R instead of re-padding and re-uploading
    index_set on every call (the repeated-sweep hot path: estimator
    fitting, benchmarks). Validated against index_set; mismatch raises.
    May also be a zero-arg callable returning the engine: it is invoked
    only on a disk-cache miss, so warm runs build nothing.

    exclude_self: subtract the self-match when points IS index_set (the
    paper counts neighbors of training points within their own set; whether
    self counts is a convention — we exclude it so tau=0 means "has some
    OTHER point nearby", matching the join semantics R x S).
    """
    if cache_key is not None:
        path = cache_path("gt-v1", cache_key, len(points), len(index_set),
                          len(eps_grid), metric, exclude_self)
        try:
            with np.load(path) as z:
                return z["t"]
        except (FileNotFoundError, OSError):
            pass

    # `block` (legacy host-chunk size) now bounds the engine's per-device
    # query tile; the engine scans tiles on device, so values above the
    # 256-row default no longer trade memory for speed
    from repro.core.engine import JoinEngine, sharded_range_count_hist
    if callable(engine) and not isinstance(engine, JoinEngine):
        engine = engine()               # lazy factory: only on cache miss
    t = sharded_range_count_hist(points, index_set, eps_grid, metric=metric,
                                 backend=backend, mesh=mesh,
                                 block_q=min(block, 256), engine=engine)
    if exclude_self:
        t = t - 1  # every point is its own 0-distance neighbor on the grid
        t = np.maximum(t, 0)
    if cache_key is not None:
        np.savez_compressed(path, t=t)
    return t
