"""Elastic scaling: rebuild the mesh from surviving devices and reshard.

On device/pod loss the runtime (a) picks the largest (data, model) grid that
fits the survivors — preferring to keep the model axis intact so TP-sharded
params keep their layout, (b) re-lowers the step for the new mesh, and
(c) restores the latest checkpoint with the NEW shardings
(CheckpointManager.restore(shardings=...) is the reshard).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax

from repro.launch.mesh import make_mesh


def best_mesh_shape(n_devices: int, *, prefer_model: int,
                    max_model: int | None = None) -> tuple[int, int]:
    """Largest (data, model) grid with data*model <= n_devices, model as
    close to prefer_model as possible (keeps TP layouts stable)."""
    best = (1, 1)
    max_model = max_model or prefer_model
    for model in range(min(prefer_model, max_model, n_devices), 0, -1):
        data = n_devices // model
        if data * model > best[0] * best[1] or (
                data * model == best[0] * best[1] and model == prefer_model):
            best = (data, model)
        if model == prefer_model and data * model == n_devices:
            break
    return best


@dataclass
class RescalePlan:
    old_shape: tuple
    new_shape: tuple
    n_lost: int
    devices: list


def rescale_plan(mesh: jax.sharding.Mesh, dead_devices: set) -> RescalePlan:
    """Plan a new (data, model) mesh over surviving devices."""
    shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    survivors = [d for d in mesh.devices.flatten() if d.id not in dead_devices]
    new_shape = best_mesh_shape(len(survivors),
                                prefer_model=shape.get("model", 1))
    n_used = new_shape[0] * new_shape[1]
    return RescalePlan(old_shape=tuple(mesh.devices.shape),
                       new_shape=new_shape,
                       n_lost=mesh.devices.size - len(survivors),
                       devices=survivors[:n_used])


def build_mesh(plan: RescalePlan) -> jax.sharding.Mesh:
    return make_mesh(plan.new_shape, ("data", "model"), devices=plan.devices)
