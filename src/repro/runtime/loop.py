"""Fault-tolerant training loop: the production driver.

Composes: sharded train step (+optional gradient compression), async
atomic checkpointing, heartbeat failure detection, straggler monitoring,
and elastic re-meshing with checkpoint resharding on (simulated) device
loss. The same loop runs on 1 CPU device (smoke) and on the production
mesh — only the mesh/shardings differ.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.archs import build_model
from repro.archs.frontends import make_batch
from repro.checkpoint import CheckpointManager
from repro.launch.mesh import make_mesh
from repro.launch.steps import make_optimizer
from repro.optim.compression import CompressionState, make_compressor
from repro.parallel.sharding import (activation_sharding, _batch_axes,
                                     batch_shardings, param_shardings)
from repro.runtime.elastic import build_mesh, rescale_plan
from repro.runtime.failure import FailureDetector, StragglerMonitor


class SimulatedFailure(RuntimeError):
    def __init__(self, dead_device_ids):
        super().__init__(f"simulated loss of devices {sorted(dead_device_ids)}")
        self.dead_device_ids = set(dead_device_ids)


@dataclass
class TrainLoopConfig:
    total_steps: int = 50
    batch: int = 8
    seq: int = 64
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 10
    keep: int = 3
    log_every: int = 0
    compression: str = "none"      # none | topk | int8
    topk_frac: float = 0.05
    # failure injection (tests / chaos drills)
    fail_at_step: int = -1
    lose_devices: int = 0
    seed: int = 0


def _make_step(model, opt, compressor):
    def step(params, opt_state, comp_state, batch, key):
        def loss_fn(p):
            loss, metrics = model.train_loss(p, batch)
            return loss, metrics
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        # gradient compression round-trip (compress -> DP collective ->
        # decompress); error feedback keeps it convergent
        grads, comp_state = compressor(grads, comp_state, key)
        params, opt_state = opt.apply(params, opt_state, grads)
        return params, opt_state, comp_state, metrics
    return step


def _shard_state(mesh, model, params_like):
    return param_shardings(model.param_specs(), mesh)


def run_training(arch_cfg, loop: TrainLoopConfig, *, mesh=None,
                 batch_iter: Optional[Iterator] = None) -> dict:
    model = build_model(arch_cfg)
    opt = make_optimizer(arch_cfg, 0)
    compressor = make_compressor(loop.compression, loop.topk_frac)
    ckpt = CheckpointManager(loop.ckpt_dir, keep=loop.keep, async_write=False)

    if mesh is None:
        mesh = make_mesh((len(jax.devices()), 1), ("data", "model"))

    workers = [f"dev{d.id}" for d in mesh.devices.flatten()]
    detector = FailureDetector(workers, timeout_s=1e9)
    monitor = StragglerMonitor(workers)

    history = {"loss": [], "restarts": 0, "mesh_shapes": [tuple(mesh.devices.shape)],
               "rebalances": 0}

    def setup(mesh, restore: bool):
        p_shard = _shard_state(mesh, model, None)
        params = model.init(jax.random.key(loop.seed))
        params = jax.device_put(params, p_shard)
        opt_state = opt.init(params)
        comp_state = (CompressionState(
            error=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
            if loop.compression != "none" else CompressionState(error=()))
        state = (params, opt_state, comp_state)
        start = 0
        if restore and ckpt.latest_step() is not None:
            # restore to host, then device_put under the (possibly NEW,
            # post-rescale) shardings — this is the checkpoint reshard
            state, meta = ckpt.restore(state)
            params = jax.device_put(state[0], p_shard)
            opt_state = state[1]
            if opt_state.mu != ():
                opt_state = opt_state._replace(
                    mu=jax.device_put(opt_state.mu, p_shard),
                    nu=jax.device_put(opt_state.nu, p_shard))
            comp_state = state[2]
            if comp_state.error != ():
                comp_state = CompressionState(
                    error=jax.device_put(comp_state.error, p_shard))
            state = (params, opt_state, comp_state)
            start = meta["step"] + 1
        bax = _batch_axes(mesh, loop.batch)
        step_fn = jax.jit(_make_step(model, opt, compressor),
                          donate_argnums=(0, 1, 2))
        return state, step_fn, start, activation_sharding(mesh, bax)

    state, step_fn, start, act_ctx = setup(mesh, restore=False)
    step = start
    rng = np.random.default_rng(loop.seed)

    while step < loop.total_steps:
        try:
            batch = (next(batch_iter) if batch_iter is not None else
                     make_batch(arch_cfg, "train", loop.batch, loop.seq,
                                seed=loop.seed + step))
            if loop.fail_at_step == step and history["restarts"] == 0:
                ids = [d.id for d in mesh.devices.flatten()][-loop.lose_devices:] \
                    if loop.lose_devices else []
                raise SimulatedFailure(ids)
            t0 = time.perf_counter()
            key = jax.random.key(step)
            with act_ctx:
                params, opt_state, comp_state, metrics = step_fn(
                    state[0], state[1], state[2], batch, key)
            state = (params, opt_state, comp_state)
            dt = time.perf_counter() - t0
            loss = float(metrics["loss"])
            history["loss"].append(loss)
            for w in workers:
                detector.heartbeat(w)
                monitor.record(w, dt)
            if monitor.stragglers():
                history["rebalances"] += 1
                monitor.rebalance_plan()  # plan recorded; shares feed the
                                          # data pipeline in deployment
            if loop.ckpt_every and step % loop.ckpt_every == 0:
                ckpt.save(step, state, blocking=True,
                          meta={"loss": loss})
            if loop.log_every and step % loop.log_every == 0:
                print(f"step {step:5d} loss {loss:.4f} ({dt*1e3:.0f} ms)")
            step += 1
        except SimulatedFailure as e:
            # ---- failure path: detect -> remesh -> reshard -> resume ----
            history["restarts"] += 1
            plan = rescale_plan(mesh, e.dead_device_ids)
            if plan.n_lost and plan.new_shape != tuple(mesh.devices.shape):
                mesh = build_mesh(plan)
                history["mesh_shapes"].append(tuple(mesh.devices.shape))
                workers = [f"dev{d.id}" for d in mesh.devices.flatten()]
                detector = FailureDetector(workers, timeout_s=1e9)
                monitor = StragglerMonitor(workers)
            state, step_fn, step, act_ctx = setup(mesh, restore=True)

    ckpt.save(loop.total_steps - 1, state, blocking=True,
              meta={"final": True})
    history["final_loss"] = history["loss"][-1] if history["loss"] else None
    return history
