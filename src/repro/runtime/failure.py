"""Failure detection + straggler mitigation.

FailureDetector — heartbeat registry. In a real deployment every host posts
heartbeats (GCS bucket / etcd / coordinator RPC); here the transport is
pluggable and the tests inject synthetic timestamps. The detector's verdicts
feed the elastic re-mesh path (runtime/elastic.py).

StragglerMonitor — per-worker step-duration statistics. A worker whose
recent median exceeds `threshold` x fleet-median is flagged; the proposed
mitigation is a batch-rebalance plan (shrink the straggler's shard, grow the
fast workers') — the standard mitigation when you cannot evict the host.
"""
from __future__ import annotations

import time
from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Callable, Optional


class FailureDetector:
    def __init__(self, workers: list[str], *, timeout_s: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout_s = timeout_s
        self.clock = clock
        self.last_seen: dict[str, float] = {w: clock() for w in workers}

    def heartbeat(self, worker: str, at: Optional[float] = None) -> None:
        self.last_seen[worker] = self.clock() if at is None else at

    def dead(self) -> list[str]:
        now = self.clock()
        return sorted(w for w, t in self.last_seen.items()
                      if now - t > self.timeout_s)

    def alive(self) -> list[str]:
        now = self.clock()
        return sorted(w for w, t in self.last_seen.items()
                      if now - t <= self.timeout_s)


@dataclass
class RebalancePlan:
    stragglers: list[str]
    shares: dict[str, float]     # fraction of the global batch per worker


class StragglerMonitor:
    def __init__(self, workers: list[str], *, window: int = 16,
                 threshold: float = 1.5):
        self.window = window
        self.threshold = threshold
        self.hist: dict[str, deque] = {w: deque(maxlen=window) for w in workers}

    def record(self, worker: str, step_seconds: float) -> None:
        self.hist[worker].append(step_seconds)

    def _median(self, xs):
        xs = sorted(xs)
        return xs[len(xs) // 2] if xs else 0.0

    def stragglers(self) -> list[str]:
        meds = {w: self._median(h) for w, h in self.hist.items() if h}
        if len(meds) < 2:
            return []
        fleet = self._median(list(meds.values()))
        if fleet <= 0:
            return []
        return sorted(w for w, m in meds.items() if m > self.threshold * fleet)

    def rebalance_plan(self) -> RebalancePlan:
        """Batch shares inversely proportional to each worker's median step
        time — equalizes wall-clock across workers."""
        meds = {w: self._median(h) or 1e-9 for w, h in self.hist.items()}
        inv = {w: 1.0 / m for w, m in meds.items()}
        z = sum(inv.values()) or 1.0
        return RebalancePlan(stragglers=self.stragglers(),
                             shares={w: v / z for w, v in inv.items()})
