from repro.runtime.failure import FailureDetector, StragglerMonitor
from repro.runtime.elastic import best_mesh_shape, rescale_plan
from repro.runtime.loop import TrainLoopConfig, run_training

__all__ = ["FailureDetector", "StragglerMonitor", "best_mesh_shape",
           "rescale_plan", "TrainLoopConfig", "run_training"]
