"""Learning-rate schedules as step -> lr callables."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_warmup(lr: float, warmup_steps: int):
    def fn(step):
        s = step.astype(jnp.float32)
        return lr * jnp.minimum(1.0, s / max(warmup_steps, 1))
    return fn


def cosine_warmup(lr: float, warmup_steps: int, total_steps: int, min_ratio: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(1.0, s / max(warmup_steps, 1))
        frac = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return lr * warm * cos
    return fn
