"""Gradient compression for the DP all-reduce (distributed-optimization trick).

Two schemes, both with error feedback so compression error does not bias the
optimizer (Karimireddy et al. 2019):

  * top-k sparsification — keep the k largest-|g| entries per leaf, feed the
    residual back next step. The all-reduce then moves k values + k indices
    instead of n values.
  * int8 quantization with stochastic rounding — 4x over f32 / 2x over bf16
    on the wire.

They are deliberately written as pure functions over pytrees so they compose
with shard_map'd psum: compress -> collective -> decompress.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    error: Any  # residual feedback pytree (same structure as grads)


def init_state(grads_like: Any) -> CompressionState:
    return CompressionState(error=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def topk_compress(g: jax.Array, frac: float) -> tuple[jax.Array, jax.Array]:
    """Returns (values, flat_indices) of the top ceil(frac * n) entries of |g|."""
    flat = g.reshape(-1).astype(jnp.float32)
    k = max(1, int(frac * flat.shape[0]))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx


def topk_decompress(values: jax.Array, idx: jax.Array, shape) -> jax.Array:
    n = 1
    for s in shape:
        n *= s
    flat = jnp.zeros((n,), jnp.float32).at[idx].set(values)
    return flat.reshape(shape)


def int8_compress(g: jax.Array, key: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric int8 quantization with stochastic rounding. Returns (q, scale)."""
    g = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    x = g / scale
    floor = jnp.floor(x)
    p = x - floor
    rnd = jax.random.uniform(key, g.shape)
    q = (floor + (rnd < p)).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def make_compressor(mode: str = "none", topk_frac: float = 0.01) -> Callable:
    """Returns fn(grads, state, key) -> (compressed_then_restored_grads, new_state).

    The round-trip (compress -> decompress) happens on-device; in the real
    multi-host deployment the collective runs between the two halves. The
    error-feedback residual makes the scheme convergent.
    """
    if mode == "none":
        return lambda grads, state, key: (grads, state)

    def fn(grads, state: CompressionState, key):
        leaves, treedef = jax.tree.flatten(grads)
        errs = treedef.flatten_up_to(state.error)
        keys = jax.random.split(key, len(leaves))
        new_leaves, new_errs = [], []
        for g, e, k in zip(leaves, errs, keys):
            corrected = g.astype(jnp.float32) + e
            if mode == "topk":
                vals, idx = topk_compress(corrected, topk_frac)
                restored = topk_decompress(vals, idx, g.shape)
            elif mode == "int8":
                q, scale = int8_compress(corrected, k)
                restored = int8_decompress(q, scale)
            else:
                raise ValueError(f"unknown compression mode {mode!r}")
            new_errs.append(corrected - restored)
            new_leaves.append(restored.astype(g.dtype))
        return (jax.tree.unflatten(treedef, new_leaves),
                CompressionState(error=jax.tree.unflatten(treedef, new_errs)))

    return fn
