"""Optimizers as (init, update) pairs over pytrees (no external deps).

Production knobs used by the large-arch configs:
  * ``moment_dtype`` — bf16 second/first moments so that Adam state for the
    100B+ architectures fits the 16 GB/chip HBM budget (see DESIGN.md §6).
  * ``adafactor`` — factored second moments for 2-D params (O(n+m) state).
  * global-norm gradient clipping fused into the update.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    mu: Any          # first moment (or None-like empty tuple)
    nu: Any          # second moment (possibly factored: (row, col) tuples)


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], OptState]
    update: Callable[[Any, OptState, Any], tuple[Any, OptState]]
    name: str = "opt"

    def apply(self, params: Any, state: OptState, grads: Any, lr: float | jax.Array = None):
        """Convenience: returns (new_params, new_state)."""
        updates, new_state = self.update(grads, state, params)
        new_params = jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)
        return new_params, new_state


def _global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def _clip_by_global_norm(grads: Any, max_norm: float) -> Any:
    norm = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads)


def _schedule(lr) -> Callable[[jax.Array], jax.Array]:
    if callable(lr):
        return lr
    return lambda step: jnp.asarray(lr, jnp.float32)


def adam(lr=1e-3, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
         clip_norm: Optional[float] = None, moment_dtype=jnp.float32,
         name: str = "adam") -> Optimizer:
    lr_fn = _schedule(lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
        return OptState(step=jnp.zeros((), jnp.int32),
                        mu=jax.tree.map(zeros, params),
                        nu=jax.tree.map(zeros, params))

    def update(grads, state, params):
        if clip_norm is not None:
            grads = _clip_by_global_norm(grads, clip_norm)
        step = state.step + 1
        t = step.astype(jnp.float32)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
            mhat = m_new / bc1
            vhat = v_new / bc2
            u = -lr_fn(step) * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32))
            return u, m_new.astype(moment_dtype), v_new.astype(moment_dtype)

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)
        flat_p = jax.tree.leaves(params)
        outs = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        updates = jax.tree.unflatten(treedef, [o[0] for o in outs])
        mu = jax.tree.unflatten(treedef, [o[1] for o in outs])
        nu = jax.tree.unflatten(treedef, [o[2] for o in outs])
        return updates, OptState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update, name=name)


def adamw(lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          clip_norm: Optional[float] = 1.0, moment_dtype=jnp.float32) -> Optimizer:
    return adam(lr, b1, b2, eps, weight_decay, clip_norm, moment_dtype, name="adamw")


def sgd(lr=1e-2, momentum=0.9, clip_norm: Optional[float] = None) -> Optimizer:
    lr_fn = _schedule(lr)

    def init(params):
        return OptState(step=jnp.zeros((), jnp.int32),
                        mu=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
                        nu=())

    def update(grads, state, params):
        if clip_norm is not None:
            grads = _clip_by_global_norm(grads, clip_norm)
        step = state.step + 1

        def upd(g, m):
            m_new = momentum * m + g.astype(jnp.float32)
            return -lr_fn(step) * m_new, m_new

        flat_g, treedef = jax.tree.flatten(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        outs = [upd(g, m) for g, m in zip(flat_g, flat_m)]
        updates = jax.tree.unflatten(treedef, [o[0] for o in outs])
        mu = jax.tree.unflatten(treedef, [o[1] for o in outs])
        return updates, OptState(step=step, mu=mu, nu=())

    return Optimizer(init=init, update=update, name="sgd")


def adafactor(lr=1e-2, decay=0.8, eps=1e-30, clip_norm: Optional[float] = 1.0,
              min_dim_factored: int = 128) -> Optimizer:
    """Factored second moments for >=2-D params (Shazeer & Stern, 2018 style).

    State for a (n, m) matrix is O(n + m) instead of O(n*m): this is the
    default optimizer for the 398B/480B assigned archs in this repo.
    """
    lr_fn = _schedule(lr)

    def factored(p):
        return p.ndim >= 2 and min(p.shape[-2:]) >= min_dim_factored

    def init(params):
        def nu_init(p):
            if factored(p):
                row = jnp.zeros(p.shape[:-1], jnp.float32)
                col = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                return (row, col)
            return jnp.zeros(p.shape, jnp.float32)
        return OptState(step=jnp.zeros((), jnp.int32), mu=(),
                        nu=jax.tree.map(nu_init, params))

    def update(grads, state, params):
        if clip_norm is not None:
            grads = _clip_by_global_norm(grads, clip_norm)
        step = state.step + 1
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** (-decay)

        def upd(g, v, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if factored(p):
                row, col = v
                row_new = beta * row + (1 - beta) * jnp.mean(g2, axis=-1)
                col_new = beta * col + (1 - beta) * jnp.mean(g2, axis=-2)
                # rank-1 reconstruction of the second moment
                r = row_new / (jnp.mean(row_new, axis=-1, keepdims=True) + eps)
                vhat = r[..., None] * col_new[..., None, :]
                u = -lr_fn(step) * g / (jnp.sqrt(vhat) + 1e-8)
                return u, (row_new, col_new)
            v_new = beta * v + (1 - beta) * g2
            u = -lr_fn(step) * g / (jnp.sqrt(v_new) + 1e-8)
            return u, v_new

        flat_g, treedef = jax.tree.flatten(grads)
        flat_v = treedef.flatten_up_to(state.nu)
        flat_p = jax.tree.leaves(params)
        outs = [upd(g, v, p) for g, v, p in zip(flat_g, flat_v, flat_p)]
        updates = jax.tree.unflatten(treedef, [o[0] for o in outs])
        nu = jax.tree.unflatten(treedef, [o[1] for o in outs])
        return updates, OptState(step=step, mu=(), nu=nu)

    return Optimizer(init=init, update=update, name="adafactor")
