from repro.optim.adam import OptState, Optimizer, adafactor, adam, adamw, sgd
from repro.optim.schedule import constant, cosine_warmup, linear_warmup
from repro.optim.compression import (
    CompressionState,
    int8_compress,
    int8_decompress,
    make_compressor,
    topk_compress,
)

__all__ = [
    "Optimizer", "OptState", "adam", "adamw", "sgd", "adafactor",
    "constant", "cosine_warmup", "linear_warmup",
    "CompressionState", "make_compressor", "topk_compress",
    "int8_compress", "int8_decompress",
]
