from repro.parallel.sharding import (
    FSDP_AXES,
    LOGICAL_RULES,
    batch_shardings,
    cache_shardings,
    param_shardings,
)

__all__ = ["FSDP_AXES", "LOGICAL_RULES", "param_shardings", "batch_shardings",
           "cache_shardings"]
