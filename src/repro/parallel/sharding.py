"""Sharding rules: logical param axes -> mesh axes, batch/cache shardings.

Parallelism map (DESIGN.md §6):
  * FSDP  — params + optimizer state sharded over ("pod","data") via the
            "embed"/"mlp-in" logical dims; XLA all-gathers per scanned layer.
  * TP    — "heads"/"mlp"/"vocab" over the `model` axis.
  * EP    — "experts" over `model` (GShard dispatch einsums -> all-to-all).
  * KV-seq sharding — decode caches shard their NS axis over `model`
            (distributed flash decode) because MQA/GQA kv-heads < TP.

Divisibility fallbacks are automatic: a logical mapping whose mesh-axis size
does not divide the dim is dropped (e.g. kv_heads=8 on model=16 replicates
— the exact involuntary-remat hazard the spike measured is avoided).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.archs.spec import shardings_for

FSDP_AXES = ("pod", "data")

# ---- activation-sharding context -------------------------------------------
# pjit auto-propagation happily batch-REPLICATES activations (measured:
# f32[22,256,4096,128] layer-scan carries on the 4k train cell, 16x memory).
# Model code calls constrain_act()/constrain_logits() at layer boundaries;
# the launcher activates this context while tracing so the constraints bind
# to the production mesh. Without the context they are no-ops (smoke tests).
_ACT_CTX: list = []


class activation_sharding:
    def __init__(self, mesh, batch_axes, model_axis="model"):
        self.state = (mesh, batch_axes, model_axis)

    def __enter__(self):
        _ACT_CTX.append(self.state)
        return self

    def __exit__(self, *exc):
        _ACT_CTX.pop()
        return False


def constrain_act(x):
    """Constrain [B, ...] activations to batch-over-fsdp sharding."""
    if not _ACT_CTX:
        return x
    mesh, baxes, _ = _ACT_CTX[-1]
    spec = P(baxes, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_logits(x):
    """Constrain [B, S, V] logits: batch over fsdp, vocab over model."""
    if not _ACT_CTX:
        return x
    mesh, baxes, maxis = _ACT_CTX[-1]
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    vaxis = maxis if x.shape[-1] % mesh_shape.get(maxis, 1) == 0 else None
    spec = P(baxes, *([None] * (x.ndim - 2)), vaxis)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _fsdp(mesh) -> tuple:
    return tuple(a for a in FSDP_AXES if a in mesh.axis_names)


def LOGICAL_RULES(mesh, mode: str = "train") -> dict:
    fsdp = _fsdp(mesh)
    rules = {
        "vocab": "model",
        "embed": fsdp,
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "experts": "model",
        "expert_in": fsdp,     # expert banks too big to replicate — always fsdp
        "expert_mlp": None,
        "latent": None,
        "head_dim": None,
        "layers": None,
    }
    if mode == "decode":
        # §Perf finding: FSDP-sharded DENSE weights force a per-layer
        # all-gather on every decoded token (granite decode: 0.089 s
        # collective term, dominant). At decode there is no optimizer state,
        # so dense weights replicate across the data axes (TP-only sharding)
        # — inference-mode sharding, the standard training/serving split.
        rules["embed"] = None
    return rules


def param_shardings(specs, mesh, mode: str = "train") -> dict:
    return shardings_for(specs, mesh, LOGICAL_RULES(mesh, mode))


def _batch_axes(mesh, batch: int):
    """Largest prefix of the fsdp axes whose product divides `batch`."""
    fsdp = _fsdp(mesh)
    shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    chosen = []
    prod = 1
    for a in sorted(fsdp, key=lambda a: -shape[a]):  # prefer the bigger axis
        if batch % (prod * shape[a]) == 0:
            chosen.append(a)
            prod *= shape[a]
    return tuple(chosen) or None


def batch_shardings(mesh, batch_tree) -> dict:
    """tokens/frames/patches [B, ...] -> shard B over fsdp (divisible part)."""
    def one(x):
        if x.ndim == 0:
            return NamedSharding(mesh, P())
        bax = _batch_axes(mesh, x.shape[0])
        return NamedSharding(mesh, P(bax, *([None] * (x.ndim - 1))))
    return jax.tree.map(one, batch_tree)


def cache_shardings(cfg, mesh, cache_tree):
    """Decode caches: batch over fsdp, NS (KV-seq shards) over model, mamba
    heads / conv channels over model."""
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp = mesh_shape.get("model", 1)

    def one(path, x):
        key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        bax = _batch_axes(mesh, x.shape[1]) if x.ndim >= 2 else None
        if x.ndim == 6:          # attn k/v [G,B,NS,Sc,K,D]
            ns = x.shape[2]
            spec = [None, bax, "model" if ns % tp == 0 and ns > 1 else None,
                    None, None, None]
            if spec[2] is None and x.shape[4] % tp == 0:
                spec[4] = "model"          # fall back to kv-head sharding
            return NamedSharding(mesh, P(*spec))
        if x.ndim == 5 and key == "ssm":   # [G,B,H,P,N]
            h = x.shape[2]
            return NamedSharding(mesh, P(None, bax,
                                         "model" if h % tp == 0 else None,
                                         None, None))
        if x.ndim == 5:          # cross-attn ek/ev [G,B,S,K,D]
            return NamedSharding(mesh, P(None, bax, None,
                                         "model" if x.shape[3] % tp == 0 else None,
                                         None))
        if x.ndim == 4 and key == "conv":  # [G,B,K-1,conv_dim]
            return NamedSharding(mesh, P(None, bax, None,
                                         "model" if x.shape[3] % tp == 0 else None))
        return NamedSharding(mesh, P(*([None] * x.ndim)))

    return jax.tree_util.tree_map_with_path(one, cache_tree)
