"""Cross-request micro-batching: the gateway's coalescer
(DESIGN.md §14).

Requests land here AFTER the cache pass, as `PendingRows` — the subset
of a ticket's query rows that must actually run, remembering their
positions inside the ticket for scatter-back. The coalescer queues them
per compatibility group (one group = one tenant class at one eps
bucket = one engine session and one compiled-program family) and
`take()` drains whole requests FIFO into a single concatenated batch up
to a row budget — the engine pads every batch to a power-of-two bucket
(`JoinEngine.padded_rows`), so packing several small requests into one
bucket is pure throughput (the padded sweep costs the same whether the
bucket is one request or eight).

A request is never split across batches: its rows stay contiguous in
exactly one engine batch (one `Segment` per request), which keeps
scatter-back a single slice copy and results bit-identical to running
the request alone.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Any

import numpy as np


@dataclass
class PendingRows:
    """One request's uncached remainder, queued for coalescing:
    `rows` ([k, d], the query rows to run), `positions` (their row
    indices inside the originating ticket), `hashes` (their cache
    fingerprints, for storing the computed counts), `ticket` (the
    handle to scatter results back into)."""
    ticket: Any
    rows: np.ndarray
    positions: np.ndarray
    hashes: list


@dataclass
class Segment:
    """One request's slice of a composed batch: rows `[start, stop)` of
    the batch belong to `ticket` at `positions`; `hashes` key the cache
    stores for the computed counts."""
    ticket: Any
    positions: np.ndarray
    hashes: list
    start: int
    stop: int


class Coalescer:
    """Per-group FIFO queues of `PendingRows` + batch composition."""

    def __init__(self):
        self._groups: dict[tuple, deque[PendingRows]] = {}

    def add(self, group: tuple, pending: PendingRows) -> None:
        """Queue one request's uncached rows under its compatibility
        group (tenant class, eps bucket)."""
        self._groups.setdefault(group, deque()).append(pending)

    def pending_rows(self, group: tuple) -> int:
        """Query rows currently queued under `group`."""
        return sum(len(p.rows) for p in self._groups.get(group, ()))

    def groups(self) -> list[tuple]:
        """Groups with at least one queued request (flush iterates)."""
        return [g for g, q in self._groups.items() if q]

    def take(self, group: tuple, max_rows: int) -> tuple:
        """Compose one batch from `group`: drain whole requests FIFO
        until adding the next would exceed `max_rows` (the first request
        is always taken, so an oversized request forms its own batch).
        Returns `(Q [m, d], segments)` — or `(None, [])` when the group
        is empty."""
        queue = self._groups.get(group)
        if not queue:
            return None, []
        parts, segments, row = [], [], 0
        while queue:
            nxt = len(queue[0].rows)
            if parts and row + nxt > max_rows:
                break
            p = queue.popleft()
            parts.append(p.rows)
            segments.append(Segment(ticket=p.ticket, positions=p.positions,
                                    hashes=p.hashes, start=row,
                                    stop=row + nxt))
            row += nxt
        return np.concatenate(parts, axis=0), segments
