"""Serving layer (DESIGN.md §14): the multi-tenant request gateway over
one pinned `JoinEngine`.

    gateway — `Gateway` / `Ticket`: request admission, cross-request
              micro-batching, scatter-back, mutation flushing
    tenants — `TenantClass`: the (eps, recall target, latency SLO)
              contract compiled into a per-class `JoinPlan.fork`
    cache   — `ResultCache`: eps-aware per-query result cache keyed on
              (class, row fingerprint, eps bucket, world version)
    batching — `Coalescer`: per-(class, eps) FIFO batch composition
              into the engine's power-of-two buckets
    metrics — `TenantMetrics` counters/percentiles + the AIMD
              `DepthController` for SLO-driven stream depth
"""
from repro.serve.batching import Coalescer, PendingRows, Segment
from repro.serve.cache import ResultCache, fingerprint_rows
from repro.serve.gateway import Gateway, Ticket
from repro.serve.metrics import DepthController, TenantMetrics
from repro.serve.tenants import TenantClass

__all__ = ["Gateway", "Ticket", "TenantClass", "ResultCache",
           "fingerprint_rows", "Coalescer", "PendingRows", "Segment",
           "TenantMetrics", "DepthController"]
