"""Tenant classes: the (eps, recall target, latency SLO) contract a
serving tenant buys, mapped onto a built `JoinPlan` (DESIGN.md §14).

A `TenantClass` is pure configuration — frozen, validated at
construction — and the `Gateway` compiles each one into a frozen fork of
its base plan (`JoinPlan.fork`): same pinned device-resident
R/estimator, per-class verify backend / probe placement / Xling tau.
`verify="auto"` resolves from the recall target: 1.0 -> the exact sweep,
>= 0.95 -> IVF-PQ, anything looser -> LSH (explicit `verify=` always
wins; `verify_params` tune the chosen index).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Mapping, Optional


@dataclass(frozen=True)
class TenantClass:
    """One tenant's serving contract.

    name: unique tenant id (requests address it; metrics key on it).
    eps: default join radius for this tenant's requests (an explicit
        per-request eps overrides it, snapped to the gateway's eps
        quantum).
    recall_target: the recall the tenant pays for — resolves
        `verify="auto"` to a backend (see module docstring) and is
        reported alongside the measured counters.
    slo_ms: per-request latency SLO (admit -> results scattered back);
        None = best-effort (no SLO accounting, no depth adaptation).
    verify: verification backend ("auto" | "exact" | "lsh" | "ivfpq" |
        any candidate-producing join name, e.g. "learned").
    verify_params: constructor params for the chosen verify index.
    probe: probe placement ("auto" | "device" | "host", DESIGN.md §11).
    tau: per-tenant Xling XDT strictness (None = inherit the gateway
        filter's tau; requires the gateway to be built with a filter).
    depth: initial async stream depth for this tenant's sessions.
    max_depth: ceiling the adaptive-depth controller may grow back to.
    """

    name: str
    eps: float
    recall_target: float = 1.0
    slo_ms: Optional[float] = None
    verify: str = "auto"
    verify_params: Mapping = field(default_factory=dict)
    probe: str = "auto"
    tau: Optional[int] = None
    depth: int = 2
    max_depth: int = 4

    def __post_init__(self):
        """Validate the contract at construction, not at first request."""
        if not self.name or not isinstance(self.name, str):
            raise ValueError(f"TenantClass(name={self.name!r}): expected a "
                             "non-empty string")
        if not self.eps > 0.0:
            raise ValueError(f"TenantClass({self.name!r}): eps={self.eps} "
                             "must be > 0")
        if not 0.0 < self.recall_target <= 1.0:
            raise ValueError(
                f"TenantClass({self.name!r}): recall_target="
                f"{self.recall_target} must be in (0, 1]")
        if self.slo_ms is not None and not self.slo_ms > 0.0:
            raise ValueError(f"TenantClass({self.name!r}): slo_ms="
                             f"{self.slo_ms} must be > 0 (or None)")
        if self.depth < 0 or self.max_depth < self.depth:
            raise ValueError(
                f"TenantClass({self.name!r}): need 0 <= depth "
                f"(={self.depth}) <= max_depth (={self.max_depth})")

    def resolved_verify(self) -> str:
        """The verify backend this class actually runs: the explicit
        `verify=` when named, else the recall target's resolution —
        exact at 1.0, ivfpq at >= 0.95, lsh below."""
        if self.verify != "auto":
            return self.verify
        if self.recall_target >= 1.0:
            return "exact"
        if self.recall_target >= 0.95:
            return "ivfpq"
        return "lsh"
