"""The request-level serving gateway over one pinned `JoinEngine`
(DESIGN.md §14).

`Gateway` turns the engine into a multi-tenant service: it accepts
`(tenant, Q, eps)` requests from any number of concurrent feeds and
returns a `Ticket` per request, then

* answers bit-identical repeated rows from the eps-aware `ResultCache`
  (keyed on tenant class, row fingerprint, executed eps, and the
  engine's `world_version` — a mutation can never serve stale counts);
* coalesces the remaining rows across requests into the engine's
  power-of-two bucketed batches — compatibility group = (tenant class,
  eps bucket), i.e. one compiled-program family — and scatters each
  batch's counts back into the originating tickets per `Segment`
  (results are bit-identical to running each request alone through the
  tenant's own `JoinPlan.run`, because per-row counts are independent
  of batch composition);
* runs every tenant class as a frozen `JoinPlan.fork` of one base plan:
  a single device-resident R/estimator serves every class, the classes
  differing only in verify backend / probe placement / Xling tau;
* adapts each group's async stream depth from observed batch latency
  against the tenant's SLO (`DepthController`), and accounts
  admitted / coalesced / cache-hit / SLO-miss counters with p50/p95
  request latency per tenant (`report()`).

Mutations (`insert`/`delete`/`compact`, gateways built `mutable=True`)
flush every pending request first, then delegate to the mutable base
plan — so a request's results always reflect the logical set at its
dispatch, and the world-version bump makes the whole cache generation
unreachable.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Iterable, Optional

import numpy as np

from repro.core import JoinPlan
from repro.core.engine import VERIFY_BACKENDS
from repro.core.xling import XlingFilter
from repro.serve.batching import Coalescer, PendingRows
from repro.serve.cache import ResultCache, fingerprint_rows
from repro.serve.metrics import DepthController, TenantMetrics
from repro.serve.tenants import TenantClass


class Ticket:
    """Handle for one admitted request: filled progressively (cache hits
    immediately, batched rows at scatter-back) and `done` once every row
    has its count. `counts` raises until then — call `Gateway.flush()`
    (or `join()` instead of `submit()`) to force completion."""

    def __init__(self, tenant: str, eps: float, n: int):
        self.tenant = tenant
        self.eps = float(eps)
        self.n = int(n)
        self.meta: dict = {"cache_hits": 0}
        self._counts = np.zeros((n,), np.int32)
        self._missing = int(n)
        self._t0 = time.perf_counter()
        self.latency_ms: Optional[float] = None

    @property
    def done(self) -> bool:
        """True once every row's count has been scattered back."""
        return self._missing == 0

    @property
    def counts(self) -> np.ndarray:
        """int32 [n] per-query neighbor counts (raises while pending)."""
        if not self.done:
            raise RuntimeError(
                f"Ticket({self.tenant!r}): {self._missing}/{self.n} rows "
                "still pending — call Gateway.flush() to force the "
                "coalescer to dispatch")
        return self._counts

    def _fill(self, positions: np.ndarray, counts: np.ndarray) -> None:
        if len(positions):
            self._counts[positions] = counts
            self._missing -= len(positions)

    def _finish(self) -> float:
        self.latency_ms = (time.perf_counter() - self._t0) * 1e3
        self.meta["latency_ms"] = self.latency_ms
        return self.latency_ms


@dataclass
class _BatchRecord:
    """One dispatched engine batch awaiting scatter-back (FIFO per
    group): its request segments, the world version and wall-clock at
    dispatch, and its row count."""
    segments: list
    world_version: int
    t_submit: float
    n_rows: int


@dataclass
class _GroupState:
    """Live state of one compatibility group (tenant class x eps
    bucket): the plan session batches run through, the FIFO of
    dispatched batch records, and the group's depth controller."""
    cls: TenantClass
    eps: float
    session: object
    controller: DepthController
    records: deque = field(default_factory=deque)


class Gateway:
    """Multi-tenant serving gateway over one pinned engine (see module
    docstring). Construct with the index set and the tenant classes;
    `submit()` admits a request and returns its `Ticket`, `flush()`
    drains, `join()` is the synchronous convenience, `report()` the
    per-tenant metrics snapshot.

    R, metric: the shared index set (one device upload for ALL tenants).
    classes: the `TenantClass` contracts (unique names).
    filter / filter_opts: optional shared gating filter ("xling" fits
        once; per-class `tau` re-calibrates thresholds on the shared
        estimator without refitting).
    mesh / backend / block / topology / r_shards / cache_key: engine
        placement, as `JoinPlan.on` (DESIGN.md §10).
    eps_quantum: grid explicit request radii snap to (None = exact-eps
        buckets only). Snapping changes the EXECUTED radius — the bucket
        is the semantics, and the ticket's `eps` reports it.
    max_batch_rows: coalescing budget per dispatched batch; default =
        the engine's minimum padded bucket (`padded_rows(1)`), i.e.
        "fill one bucket before dispatching early".
    cache_capacity: LRU bound of the per-query result cache.
    mutable / auto_compact_at: unlock `insert`/`delete`/`compact`
        (DESIGN.md §13) on the shared set. Mutable gateways restrict
        classes to engine-rebuildable verify backends (exact/lsh/ivfpq)
        and require classes naming the same backend to agree on its
        params (one engine-cached index per backend name).
    planner / replan_at: "auto" (default) plans every `verify="auto"`
        class with `recall_target < 1.0` and no `verify_params` through
        the cost-based planner (core/planner.py, DESIGN.md §16) instead
        of the static recall table — the planner measures R (query-free
        index-self sample) and picks verify backend / probe placement /
        block / initial depth, splitting hot LSH buckets when the skew
        measurement trips the overflow trigger; "off" restores the
        static resolution. Planned classes RE-plan when the engine's
        `world_version` has advanced past their plan and `delta_frac`
        has reached `replan_at` — the class's pending requests flush
        first, its groups rebuild on the new plan, and `report()`
        counts the re-plans.
    """

    def __init__(self, R, classes: Iterable[TenantClass], *,
                 metric: str = "cosine", filter=None, filter_opts=None,
                 mesh=None, backend: str = "auto", block: int = 512,
                 topology=None, r_shards=None, cache_key=None,
                 eps_quantum: Optional[float] = None,
                 max_batch_rows: Optional[int] = None,
                 cache_capacity: int = 65536, mutable: bool = False,
                 auto_compact_at: Optional[float] = 0.5,
                 planner: str = "auto", replan_at: float = 0.25):
        classes = list(classes)
        if not classes:
            raise ValueError("Gateway: at least one TenantClass is required")
        names = [c.name for c in classes]
        if len(set(names)) != len(names):
            raise ValueError(f"Gateway: duplicate tenant class names in "
                             f"{names}")
        if eps_quantum is not None and not eps_quantum > 0.0:
            raise ValueError(f"Gateway(eps_quantum={eps_quantum}): must be "
                             "> 0 (or None for exact-eps buckets)")
        if planner not in ("auto", "off"):
            raise ValueError(f"Gateway(planner={planner!r}): expected "
                             "'auto' or 'off'")
        if not replan_at > 0.0:
            raise ValueError(f"Gateway(replan_at={replan_at}): must be > 0")
        self.mutable = bool(mutable)
        self.eps_quantum = eps_quantum
        self.planner = planner
        self.replan_at = float(replan_at)
        self._classes = {c.name: c for c in classes}

        base = JoinPlan(R, metric).search("naive").on(
            mesh=mesh, backend=backend, block=block, topology=topology,
            r_shards=r_shards, cache_key=cache_key)
        if filter is not None:
            base = base.filter(filter, **dict(filter_opts or {}))
        if self.mutable:
            base = base.mutable(auto_compact_at)
        self._base = base.build()
        self._engine = self._base.engine
        self.max_batch_rows = (int(max_batch_rows) if max_batch_rows
                               else self._engine.padded_rows(1))
        if self.max_batch_rows < 1:
            raise ValueError(f"Gateway(max_batch_rows={max_batch_rows}): "
                             "must be >= 1")

        self._plans: dict[str, JoinPlan] = {}
        self._metrics = {c.name: TenantMetrics() for c in classes}
        self._verify_name_params: dict[str, dict] = {}
        self._class_depth: dict[str, int] = {}
        self._planned_world: dict[str, int] = {}
        self._replans: dict[str, int] = {}
        for cls in classes:
            self._plans[cls.name] = self._build_tenant_plan(cls)
        self._cache = ResultCache(cache_capacity)
        self._coalescer = Coalescer()
        self._groups: dict[tuple, _GroupState] = {}

    # -------------------------------------------------------- construction
    def _use_planner(self, cls: TenantClass) -> bool:
        """Whether a class's configuration comes from the cost-based
        planner: planner="auto" and the class left everything to
        resolve — `verify="auto"`, no `verify_params`, a recall target
        below 1.0 (1.0 contractually pins the exact sweep; the static
        table already answers it and planning would just burn a
        measurement pass)."""
        return (self.planner == "auto" and cls.verify == "auto"
                and not cls.verify_params and cls.recall_target < 1.0)

    def _build_tenant_plan(self, cls: TenantClass) -> JoinPlan:
        """Fork the base plan for one tenant class: shared engine (and
        fitted filter), per-class verify/probe/tau — the verify backend,
        probe placement, block, and initial depth coming from the
        cost-based planner when `_use_planner` says so (the plan's
        `describe()["planner"]` carries the rationale)."""
        plan = self._base.fork()
        verify = cls.resolved_verify()
        params = dict(cls.verify_params)
        probe = cls.probe
        explain = None
        self._class_depth[cls.name] = cls.depth
        if self._use_planner(cls):
            # tau must land BEFORE planning so the measured skip rate is
            # this class's, not the base plan's
            self._apply_class_tau(plan, cls)
            from repro.core import planner as planner_mod
            _, explain = planner_mod.plan_auto(
                plan, None, float(cls.eps), recall=cls.recall_target,
                seed=0)
            ch = explain["chosen"]
            if ch["verify"] == "lsh+rebucket":
                verify = "lsh"
                params = {"rebucket_hot": planner_mod.REBUCKET_HOT}
            else:
                verify, params = ch["verify"], {}
            if probe == "auto":
                probe = "auto" if ch["probe"] == "-" else ch["probe"]
            plan.on(block=int(ch["block"]))
            self._class_depth[cls.name] = min(
                max(cls.depth, int(ch["depth"])), cls.max_depth)
            self._planned_world[cls.name] = self._engine.world_version
        if self.mutable:
            if verify not in VERIFY_BACKENDS:
                raise ValueError(
                    f"TenantClass({cls.name!r}): verify={verify!r} on a "
                    "mutable gateway — compact() can only rebuild the "
                    f"engine-cached backends {VERIFY_BACKENDS}; freeze the "
                    "gateway (mutable=False) to serve instance-indexed "
                    "backends like 'learned'")
            if params and verify != "exact":
                prev = self._verify_name_params.get(verify)
                if prev is not None and prev != params:
                    raise ValueError(
                        f"TenantClass({cls.name!r}): verify={verify!r} "
                        f"params {params} conflict with another class's "
                        f"{prev} — a mutable gateway keeps ONE engine-"
                        "cached index per backend name (rebuilt on "
                        "compact), so classes naming the same backend "
                        "must share its params")
                self._verify_name_params[verify] = params
                # build (and record for post-compact rebuild) the shared
                # index now; the plan routes by NAME so the rebuilt index
                # takes effect after every compaction
                self._engine.verifier(verify, **params)
                plan.verify(verify)
            else:
                plan.verify(verify, **params)
        else:
            plan.verify(verify, **params)
        self._apply_class_tau(plan, cls)
        plan.on(probe=probe)
        plan.build()
        if explain is not None:
            plan._planner_explain = explain
        assert plan.engine is self._engine  # fork shares the pinned R
        return plan

    def _apply_class_tau(self, plan: JoinPlan, cls: TenantClass) -> None:
        """Swap the class's tau onto the shared fitted Xling estimator
        (no refit — only the XDT threshold re-calibrates)."""
        if cls.tau is None:
            return
        adapter = self._base.build()._built.filter
        filt = getattr(adapter, "filt", None)
        if not isinstance(filt, XlingFilter):
            raise ValueError(
                f"TenantClass({cls.name!r}): tau={cls.tau} needs the "
                "gateway built with filter='xling' (tau is the Xling "
                "XDT strictness)")
        plan.filter(filt, tau=int(cls.tau), xdt=adapter.xdt_mode,
                    fpr_tolerance=adapter.fpr_tolerance)

    # ------------------------------------------------------------- serving
    def _resolve_eps(self, cls: TenantClass, eps) -> float:
        """The EXECUTED radius for a request: the class default when
        unspecified; an explicit eps snapped to the `eps_quantum` grid
        (the snapped value is both the cache bucket and what the engine
        runs — deterministic, reported on the ticket)."""
        if eps is None:
            return float(cls.eps)
        eps = float(eps)
        if not eps > 0.0:
            raise ValueError(f"submit(eps={eps}): radius must be > 0")
        if self.eps_quantum:
            eps = round(self.eps_quantum * round(eps / self.eps_quantum), 9)
            if not eps > 0.0:
                eps = self.eps_quantum
        return eps

    def _group_state(self, gkey: tuple) -> _GroupState:
        name, eps_key = gkey
        gs = self._groups.get(gkey)
        if gs is None:
            cls = self._classes[name]
            depth0 = self._class_depth[name]    # planner-chosen when planned
            gs = _GroupState(
                cls=cls, eps=float(eps_key),
                session=self._plans[name].session(float(eps_key),
                                                  depth=depth0),
                controller=DepthController(depth0, cls.max_depth,
                                           cls.slo_ms))
            self._groups[gkey] = gs
        return gs

    def submit(self, tenant: str, Q, eps: Optional[float] = None) -> Ticket:
        """Admit one request: cache-hit rows are answered immediately;
        the rest queue in the request's compatibility group, which is
        dispatched whenever `max_batch_rows` are pending (and at
        `flush()`). Returns the request's `Ticket`."""
        cls = self._classes.get(tenant)
        if cls is None:
            raise ValueError(f"submit({tenant!r}): unknown tenant class; "
                             f"registered: {sorted(self._classes)}")
        Q = np.atleast_2d(np.asarray(Q, np.float32))
        if Q.ndim != 2 or Q.shape[1] != self._engine.dim or not len(Q):
            raise ValueError(
                f"submit({tenant!r}): queries have shape {Q.shape}; "
                f"expected (k >= 1, {self._engine.dim})")
        self._maybe_replan(cls)
        eps_exec = self._resolve_eps(cls, eps)
        eps_key = round(eps_exec, 9)
        ticket = Ticket(tenant, eps_exec, len(Q))
        m = self._metrics[tenant]
        m.admitted_requests += 1
        m.admitted_queries += len(Q)

        wv = self._engine.world_version
        self._cache.note_world(wv)
        hashes = fingerprint_rows(Q)
        hit_pos, hit_counts, miss_pos = [], [], []
        for i, h in enumerate(hashes):
            c = self._cache.get((tenant, h, eps_key, wv))
            if c is None:
                miss_pos.append(i)
            else:
                hit_pos.append(i)
                hit_counts.append(c)
        ticket.meta["cache_hits"] = len(hit_pos)
        m.cache_hit_queries += len(hit_pos)
        m.cache_miss_queries += len(miss_pos)
        if hit_pos:
            ticket._fill(np.asarray(hit_pos, np.int64),
                         np.asarray(hit_counts, np.int32))
        if miss_pos:
            pos = np.asarray(miss_pos, np.int64)
            gkey = (tenant, eps_key)
            self._coalescer.add(gkey, PendingRows(
                ticket=ticket, rows=Q[pos], positions=pos,
                hashes=[hashes[i] for i in miss_pos]))
            while self._coalescer.pending_rows(gkey) >= self.max_batch_rows:
                self._pump(gkey)
        else:
            m.observe_request(ticket._finish(), cls.slo_ms)
        return ticket

    def _pump(self, gkey: tuple) -> None:
        """Dispatch one coalesced batch from a group's pending queue."""
        Q, segments = self._coalescer.take(gkey, self.max_batch_rows)
        if Q is None:
            return
        gs = self._group_state(gkey)
        m = self._metrics[gs.cls.name]
        m.batches += 1
        if len(segments) > 1:
            m.coalesced_batches += 1
            m.coalesced_requests += len(segments)
        gs.records.append(_BatchRecord(
            segments=segments, world_version=self._engine.world_version,
            t_submit=time.perf_counter(), n_rows=len(Q)))
        self._scatter(gs, gs.session.submit(Q))

    def _scatter(self, gs: _GroupState, results) -> None:
        """Scatter completed batches' counts back into their tickets
        (FIFO against the group's batch records), populate the cache
        under the dispatch-time world version, finish tickets, and feed
        the depth controller."""
        if not results:
            return
        m = self._metrics[gs.cls.name]
        eps_key = round(gs.eps, 9)
        now = time.perf_counter()
        for res in results:
            rec = gs.records.popleft()
            counts = np.asarray(res.counts)
            for seg in rec.segments:
                c = counts[seg.start:seg.stop]
                seg.ticket._fill(seg.positions, c)
                for h, cnt in zip(seg.hashes, c):
                    self._cache.put(
                        (gs.cls.name, h, eps_key, rec.world_version),
                        int(cnt))
                if seg.ticket.done:
                    m.observe_request(seg.ticket._finish(), gs.cls.slo_ms)
            new_depth = gs.controller.update((now - rec.t_submit) * 1e3)
            if new_depth != gs.session.depth:
                gs.session.set_depth(new_depth)

    def flush(self, tenant: Optional[str] = None) -> None:
        """Dispatch everything pending (regardless of batch fill) and
        drain the sessions: on return, every admitted ticket (of
        `tenant`, or of all tenants) is `done`."""
        gkeys = set(self._coalescer.groups()) | set(self._groups)
        for gkey in sorted(gkeys):
            if tenant is not None and gkey[0] != tenant:
                continue
            while self._coalescer.pending_rows(gkey) > 0:
                self._pump(gkey)
            gs = self._groups.get(gkey)
            if gs is not None:
                self._scatter(gs, gs.session.flush())

    def _maybe_replan(self, cls: TenantClass) -> None:
        """Re-plan one planned class when the world has moved past its
        plan: the engine's `world_version` advanced AND the pending
        delta reached `replan_at` (the measured stats the plan was
        priced on — selectivity, delta occupancy — are stale enough to
        re-measure). The class's pending requests flush first and its
        groups rebuild on the new plan, so no in-flight batch ever
        crosses plans; results stay exact either way — re-planning
        moves cost, not counts."""
        if not self._use_planner(cls):
            return
        if (self._engine.world_version == self._planned_world.get(cls.name)
                or self._engine.delta_frac < self.replan_at):
            return
        self.flush(cls.name)
        for gkey in [k for k in self._groups if k[0] == cls.name]:
            del self._groups[gkey]
        self._plans[cls.name] = self._build_tenant_plan(cls)
        self._replans[cls.name] = self._replans.get(cls.name, 0) + 1

    def join(self, tenant: str, Q, eps: Optional[float] = None) -> Ticket:
        """Synchronous convenience: `submit` + flush the request's
        group; the returned ticket is always `done`."""
        ticket = self.submit(tenant, Q, eps)
        if not ticket.done:
            self.flush(tenant)
        return ticket

    # ------------------------------------------------------------ mutation
    def _require_mutable(self, op: str) -> None:
        if not self.mutable:
            raise RuntimeError(
                f"{op}: this gateway is frozen — construct it with "
                "mutable=True to serve a dynamic R (DESIGN.md §13/§14)")

    def insert(self, rows) -> np.ndarray:
        """Insert rows into the shared logical set (all tenants observe
        them): flushes every pending request first, so in-flight results
        reflect the pre-mutation world, then bumps the world version —
        no cached count survives."""
        self._require_mutable("insert()")
        self.flush()
        return self._base.insert(rows)

    def delete(self, ids) -> None:
        """Delete rows by id from the shared logical set (flushes
        pending requests first; bumps the world version)."""
        self._require_mutable("delete()")
        self.flush()
        self._base.delete(ids)

    def compact(self) -> dict:
        """Merge the delta / drop tombstones on the shared engine
        (flushes pending requests first; bumps the world version).
        Returns the engine's compaction stats."""
        self._require_mutable("compact()")
        self.flush()
        return self._base.compact()

    # ---------------------------------------------------------- inspection
    @property
    def world_version(self) -> int:
        """The engine's logical-set version (cache-key component)."""
        return self._engine.world_version

    @property
    def engine(self):
        """The shared `JoinEngine` every tenant plan runs on."""
        return self._engine

    def plan(self, tenant: str) -> JoinPlan:
        """The built `JoinPlan` serving a tenant class (shares the
        gateway engine; its `run` is the per-request parity oracle)."""
        return self._plans[tenant]

    def report(self) -> dict:
        """Serializable serving snapshot: world version, cache counters,
        and per-tenant class config + resolved routes + metrics
        (admitted/coalesced/cache-hit/SLO-miss counters, p50/p95) + live
        group depths — the `describe()` of the serving layer."""
        tenants = {}
        for name, cls in self._classes.items():
            desc = self._plans[name].describe()
            groups = {
                str(gkey[1]): {"depth": int(gs.session.depth),
                               "pending_rows":
                                   self._coalescer.pending_rows(gkey),
                               "in_flight_batches": len(gs.records)}
                for gkey, gs in self._groups.items() if gkey[0] == name}
            tenants[name] = {
                "eps": cls.eps, "recall_target": cls.recall_target,
                "slo_ms": cls.slo_ms,
                "verify": desc["verify"]["resolved"],
                "probe": desc["exec"]["probe"]["resolved"],
                "tau": desc["filter"]["tau"],
                # the auto-planner's rationale + re-plan counter
                # (DESIGN.md §16): None for statically-resolved classes
                "planner": (None if desc["planner"] is None else dict(
                    desc["planner"],
                    replans=self._replans.get(name, 0),
                    planned_world=self._planned_world.get(name))),
                "metrics": self._metrics[name].report(),
                "groups": groups,
            }
        return {
            "world_version": self.world_version,
            "mutable": self.mutable,
            "eps_quantum": self.eps_quantum,
            "max_batch_rows": self.max_batch_rows,
            "n_index": int(self._engine.nr),
            "cache": self._cache.report(),
            "tenants": tenants,
        }
