"""Per-tenant serving metrics + the adaptive-depth controller
(DESIGN.md §14).

`TenantMetrics` carries the counters the gateway report surfaces per
tenant — admitted / coalesced / cache-hit / SLO-miss counts plus a
bounded window of request latencies for p50/p95 — and `DepthController`
turns observed per-batch latency into a stream-depth target (AIMD
against the tenant's SLO: a miss sheds one level of pipelining
immediately; sustained headroom grows it back one level at a time up to
the class ceiling).
"""
from __future__ import annotations

from collections import deque
from typing import Optional

import numpy as np

#: request latencies kept per tenant for the p50/p95 columns
LATENCY_WINDOW = 512


class TenantMetrics:
    """Counters + latency window for one tenant (gateway report rows)."""

    def __init__(self):
        self.admitted_requests = 0      # submit() calls accepted
        self.admitted_queries = 0       # query rows across them
        self.served_requests = 0        # tickets fully scattered back
        self.cache_hit_queries = 0      # rows answered from the cache
        self.cache_miss_queries = 0     # rows that joined a batch
        self.batches = 0                # engine batches dispatched
        self.coalesced_batches = 0      # batches carrying > 1 request
        self.coalesced_requests = 0     # requests that shared a batch
        self.slo_misses = 0             # requests finishing past slo_ms
        self._lat_ms: deque[float] = deque(maxlen=LATENCY_WINDOW)

    def observe_request(self, latency_ms: float,
                        slo_ms: Optional[float]) -> None:
        """Record one finished request's admit->done latency and its SLO
        outcome (no-op SLO accounting when the class has no SLO)."""
        self.served_requests += 1
        self._lat_ms.append(float(latency_ms))
        if slo_ms is not None and latency_ms > slo_ms:
            self.slo_misses += 1

    def report(self) -> dict:
        """Serializable counter snapshot with p50/p95 request latency."""
        lat = np.asarray(self._lat_ms, np.float64)
        return {
            "admitted_requests": self.admitted_requests,
            "admitted_queries": self.admitted_queries,
            "served_requests": self.served_requests,
            "cache_hit_queries": self.cache_hit_queries,
            "cache_miss_queries": self.cache_miss_queries,
            "batches": self.batches,
            "coalesced_batches": self.coalesced_batches,
            "coalesced_requests": self.coalesced_requests,
            "slo_misses": self.slo_misses,
            "p50_ms": float(np.percentile(lat, 50)) if len(lat) else None,
            "p95_ms": float(np.percentile(lat, 95)) if len(lat) else None,
        }


class DepthController:
    """AIMD stream-depth target against a latency SLO.

    `update(lat_ms)` ingests one batch's submit->readback latency:
    above the SLO, depth drops one level immediately (each queued batch
    adds a full batch of latency, so shedding pipelining is the lever);
    under half the SLO for three consecutive batches, depth grows one
    level back, up to `max_depth`. Without an SLO the depth is pinned
    at its initial value."""

    #: consecutive well-under-SLO batches required before growing depth
    GROW_AFTER = 3

    def __init__(self, depth: int, max_depth: int,
                 slo_ms: Optional[float]):
        self.depth = max(int(depth), 0)
        self.max_depth = max(int(max_depth), self.depth)
        self.slo_ms = slo_ms
        self._ok_streak = 0

    def update(self, lat_ms: float) -> int:
        """Feed one observed batch latency; returns the new target
        depth."""
        if self.slo_ms is None:
            return self.depth
        if lat_ms > self.slo_ms:
            self._ok_streak = 0
            self.depth = max(self.depth - 1, 0)
        elif lat_ms < 0.5 * self.slo_ms:
            self._ok_streak += 1
            if self._ok_streak >= self.GROW_AFTER \
                    and self.depth < self.max_depth:
                self.depth += 1
                self._ok_streak = 0
        else:
            self._ok_streak = 0
        return self.depth
