"""Eps-aware result cache for the serving gateway (DESIGN.md §14).

Caches PER-QUERY results (the exact-at-candidates neighbor count), not
per-request blobs, so a repeated query row hits regardless of how
requests batch it. An entry is keyed on the full serving identity:

    (plan signature, query fingerprint, eps bucket, world version)

* plan signature — the tenant class name: two classes may run different
  verify routes over the same engine, so their results never cross.
* query fingerprint — blake2b over the query row's float32 bytes:
  bit-identical rows hit, anything else misses (no tolerance radius —
  a "near-duplicate" hits only through the eps bucket it shares).
* eps bucket — the EXECUTED radius (the gateway snaps request eps to
  its `eps_quantum` grid before both execution and lookup, so the
  bucket is also the semantics — a cached count is exactly the count
  the engine would recompute).
* world version — `JoinEngine.world_version`, bumped by every
  insert/delete/compact. Lookups always use the current version, so a
  result computed against an older logical set can never answer a new
  request; `note_world` additionally drops the stale generation
  eagerly instead of waiting for LRU eviction.

Bounded LRU (`capacity` entries); hit/miss counters feed the per-tenant
metrics reports.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict

import numpy as np


def fingerprint_rows(Q: np.ndarray) -> list[bytes]:
    """16-byte blake2b digest per query row (float32 bytes) — the query
    half of the cache key."""
    Q = np.ascontiguousarray(np.asarray(Q, np.float32))
    return [hashlib.blake2b(row.tobytes(), digest_size=16).digest()
            for row in Q]


class ResultCache:
    """Bounded-LRU per-query result cache (see module docstring)."""

    def __init__(self, capacity: int = 65536):
        if capacity < 1:
            raise ValueError(f"ResultCache(capacity={capacity}): must be "
                             ">= 1")
        self.capacity = int(capacity)
        self._entries: OrderedDict[tuple, int] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self._world: int | None = None

    def __len__(self) -> int:
        """Number of live entries."""
        return len(self._entries)

    def note_world(self, version: int) -> None:
        """Observe the engine's current world version: on a bump, drop
        every entry eagerly — they are unreachable anyway (the version
        is part of the key) but holding a dead generation would evict
        live entries first."""
        if self._world != version:
            self._world = version
            self._entries.clear()

    def get(self, key: tuple) -> int | None:
        """The cached count for `key`, or None on a miss; hits refresh
        LRU recency and both outcomes feed the counters."""
        count = self._entries.get(key)
        if count is None:
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        return count

    def put(self, key: tuple, count: int) -> None:
        """Store one per-query count, evicting the LRU entry past
        capacity."""
        self._entries[key] = int(count)
        self._entries.move_to_end(key)
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)

    def report(self) -> dict:
        """Serializable counters for the gateway report."""
        total = self.hits + self.misses
        return {"entries": len(self._entries), "capacity": self.capacity,
                "hits": self.hits, "misses": self.misses,
                "hit_rate": self.hits / total if total else 0.0}
