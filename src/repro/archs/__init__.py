from repro.archs.transformer import Model, build_model, layer_pattern, param_specs
from repro.archs.encdec import EncDecModel
from repro.archs.frontends import input_specs, make_batch

__all__ = ["Model", "EncDecModel", "build_model", "layer_pattern",
           "param_specs", "input_specs", "make_batch"]
