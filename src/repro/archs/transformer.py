"""Decoder-only composable model covering the dense / moe / ssm / hybrid /
vlm assigned architectures.

Layer-pattern machinery: each arch reduces to a repeating GROUP of
sub-blocks (jamba: 8 layers = 7 mamba + 1 attn, MoE on every 2nd FFN;
dense: group of 1). Parameters are stacked over groups and the forward is a
lax.scan over the stacked pytree — HLO size stays O(group), which is what
keeps 512-partition compiles at seconds per cell (spike measurement).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.archs import layers as L
from repro.archs import mamba2, moe
from repro.archs.spec import ParamSpec, init_params, abstract_params, is_spec
from repro.configs.base import ArchConfig
from repro.parallel.sharding import constrain_act, constrain_logits


class BlockDesc(NamedTuple):
    kind: str   # "attn" | "mamba"
    ffn: str    # "dense" | "moe" | "none"


def layer_pattern(cfg: ArchConfig) -> tuple[list[BlockDesc], int]:
    period = 1
    if cfg.hybrid_period:
        period = cfg.hybrid_period
    if cfg.n_experts:
        period = int(period * cfg.moe_every // math.gcd(period, cfg.moe_every))
    assert cfg.n_layers % period == 0, (cfg.name, cfg.n_layers, period)
    descs = []
    for j in range(period):
        if cfg.attn_kind == "none":
            kind = "mamba"
        elif cfg.hybrid_period:
            kind = "attn" if j % cfg.hybrid_period == cfg.attn_position else "mamba"
        else:
            kind = "attn"
        if cfg.d_ff == 0 and not cfg.n_experts:
            ffn = "none"
        elif cfg.n_experts and (j % cfg.moe_every == cfg.moe_every - 1):
            ffn = "moe"
        else:
            ffn = "dense"
        descs.append(BlockDesc(kind, ffn))
    return descs, cfg.n_layers // period


# ------------------------------------------------------------------- params
def _block_specs(cfg: ArchConfig, desc: BlockDesc) -> dict:
    d, dt = cfg.d_model, cfg.dtype
    out = {}
    if desc.kind == "attn":
        if cfg.attn_kind == "mla":
            out["attn"] = L.mla_specs(d, cfg.n_heads, q_lora=cfg.q_lora,
                                      kv_lora=cfg.kv_lora, d_nope=cfg.d_nope,
                                      d_rope=cfg.d_rope, d_v=cfg.d_v, dtype=dt)
        else:
            out["attn"] = L.gqa_specs(d, cfg.n_heads, cfg.n_kv_heads,
                                      cfg.head_dim, dt)
    else:
        out["mamba"] = mamba2.mamba2_specs(d, d_state=cfg.ssm_state,
                                           head_dim=cfg.ssm_head_dim,
                                           expand=cfg.ssm_expand, dtype=dt)
    if desc.ffn == "dense":
        out["mlp"] = L.mlp_specs(d, cfg.d_ff, cfg.mlp_kind, dt)
    elif desc.ffn == "moe":
        out["moe"] = moe.moe_specs(d, cfg.d_ff, cfg.n_experts, dt)
        if cfg.dense_residual_ff:
            out["mlp"] = L.mlp_specs(d, cfg.dense_residual_ff, cfg.mlp_kind, dt)
    return out


def _stack_specs(specs, n: int):
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.logical, s.dtype,
                            s.init, s.scale),
        specs, is_leaf=is_spec)


def param_specs(cfg: ArchConfig) -> dict:
    descs, n_groups = layer_pattern(cfg)
    d, dt = cfg.d_model, cfg.dtype
    group = {f"b{j}": _block_specs(cfg, desc) for j, desc in enumerate(descs)}
    out = {
        "emb": ParamSpec((cfg.vocab, d), ("vocab", "embed"), dt),
        "final_norm": L.rmsnorm_spec(d),
        "layers": _stack_specs(group, n_groups),
    }
    if not cfg.tie_embeddings:
        out["head"] = ParamSpec((d, cfg.vocab), ("embed", "vocab"), dt)
    if cfg.frontend == "vision_stub":
        out["projector"] = ParamSpec((d, d), ("embed", "mlp"), dt)
    return out


# ------------------------------------------------------------------ forward
def _block_forward(cfg: ArchConfig, desc: BlockDesc, p: dict, x, positions,
                   with_cache: bool):
    cache = {}
    if desc.kind == "attn":
        if cfg.attn_kind == "mla":
            x, c = L.mla_prefill(p["attn"], x, positions=positions,
                                 d_nope=cfg.d_nope, d_rope=cfg.d_rope,
                                 rope_theta=cfg.rope_theta, norm_eps=cfg.norm_eps,
                                 chunk=cfg.attn_chunk, with_cache=with_cache)
            if with_cache:
                cache["k"] = c[0]
        else:
            x, c = L.gqa_prefill(p["attn"], x, positions=positions,
                                 window=cfg.window, rope_theta=cfg.rope_theta,
                                 norm_eps=cfg.norm_eps, chunk=cfg.attn_chunk,
                                 with_cache=with_cache)
            if with_cache:
                cache["k"], cache["v"] = c
    else:
        x, st = mamba2.mamba2_forward(p["mamba"], x, d_state=cfg.ssm_state,
                                      head_dim=cfg.ssm_head_dim,
                                      norm_eps=cfg.norm_eps,
                                      with_state=with_cache)
        if with_cache:
            cache.update(st)
    if desc.ffn == "moe":
        y = moe.moe_apply(p["moe"], x, top_k=cfg.top_k,
                          capacity_factor=cfg.capacity_factor,
                          group_size=cfg.moe_group, norm_eps=cfg.norm_eps)
        if cfg.dense_residual_ff:
            y = y + (L.mlp_apply(p["mlp"], x, cfg.mlp_kind, cfg.norm_eps) - x)
        x = y
    elif desc.ffn == "dense":
        x = L.mlp_apply(p["mlp"], x, cfg.mlp_kind, cfg.norm_eps)
    return x, cache


def _stack_forward(cfg: ArchConfig, params_layers, x, positions,
                   with_cache: bool):
    descs, n_groups = layer_pattern(cfg)

    def group_fn(h, gparams):
        caches = {}
        h = constrain_act(h)
        for j, desc in enumerate(descs):
            h, c = _block_forward(cfg, desc, gparams[f"b{j}"], h, positions,
                                  with_cache)
            h = constrain_act(h)
            if with_cache:
                caches[f"b{j}"] = c
        return h, caches

    fn = jax.checkpoint(group_fn) if cfg.remat else group_fn
    if cfg.scan_layers:
        return jax.lax.scan(fn, x, params_layers)
    # unrolled path (useful for body-cost analysis and small smokes)
    caches = []
    for g in range(n_groups):
        gp = jax.tree.map(lambda a: a[g], params_layers)
        x, c = fn(x, gp)
        caches.append(c)
    stacked = (jax.tree.map(lambda *cs: jnp.stack(cs), *caches)
               if with_cache else None)
    return x, stacked


def _embed(cfg: ArchConfig, params, batch: dict):
    tok = batch["tokens"]
    x = params["emb"][tok].astype(cfg.dtype)
    if cfg.frontend == "vision_stub":
        patches = batch["patches"].astype(cfg.dtype)
        patches = jnp.einsum("bpd,de->bpe", patches, params["projector"])
        x = jnp.concatenate([patches, x], axis=1)
    return x


def _logits(cfg: ArchConfig, params, x):
    head = params["emb"].T if cfg.tie_embeddings else params["head"]
    return constrain_logits(jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype)))


# -------------------------------------------------------------------- model
@dataclass
class Model:
    cfg: ArchConfig

    # .... parameters ....
    def param_specs(self):
        return param_specs(self.cfg)

    def init(self, key, dtype_override=None):
        return init_params(key, self.param_specs(), dtype_override)

    def abstract_params(self, dtype_override=None):
        return abstract_params(self.param_specs(), dtype_override)

    # .... training ....
    def train_loss(self, params, batch: dict):
        cfg = self.cfg
        x = constrain_act(_embed(cfg, params, batch))
        S_total = x.shape[1]
        positions = jnp.arange(S_total)
        x, _ = _stack_forward(cfg, params["layers"], x, positions, False)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = _logits(cfg, params, x)
        tok = batch["tokens"]
        n_prefix = S_total - tok.shape[1]          # vlm: patch positions
        pred = logits[:, n_prefix:-1].astype(jnp.float32)
        labels = tok[:, 1:]
        lse = jax.nn.logsumexp(pred, axis=-1)
        ll = jnp.take_along_axis(pred, labels[..., None], axis=-1)[..., 0]
        loss = jnp.mean(lse - ll)
        return loss, {"loss": loss, "ppl_proxy": jnp.exp(jnp.minimum(loss, 20.0))}

    # .... serving ....
    def prefill(self, params, batch: dict):
        """Returns (last_logits [B,V], cache). Cache layout = decode layout."""
        cfg = self.cfg
        x = constrain_act(_embed(cfg, params, batch))
        S_total = x.shape[1]
        positions = jnp.arange(S_total)
        x, raw = _stack_forward(cfg, params["layers"], x, positions, True)
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = _logits(cfg, params, x[:, -1:])[:, 0]
        cache = self._cache_from_prefill(raw, S_total)
        return logits, cache

    def _cache_from_prefill(self, raw, S: int):
        """Reshape scan-stacked prefill K/V into the decode cache layout."""
        cfg = self.cfg
        descs, _ = layer_pattern(cfg)

        def reshape_kv(x):
            G, B, S_, K, D = x.shape       # [G,B,S,K,D] from scan ys
            if cfg.window:
                W = cfg.window
                if S_ >= W:
                    # ring buffer: slot(p) = p % W for the last W positions
                    last = x[:, :, S_ - W:]
                    return jnp.roll(last, shift=(S_ - W) % W, axis=2)[:, :, None]
                pad = jnp.zeros((G, B, W - S_, K, D), x.dtype)
                return jnp.concatenate([x, pad], axis=2)[:, :, None]
            ns = cfg.kv_shards if S_ % max(cfg.kv_shards, 1) == 0 else 1
            return x.reshape(G, B, ns, S_ // ns, K, D)

        out = {}
        for j, desc in enumerate(descs):
            c = raw[f"b{j}"]
            if desc.kind == "attn":
                out[f"b{j}"] = {k: reshape_kv(v) for k, v in c.items()}
            else:
                out[f"b{j}"] = c          # mamba ssm/conv states are decode-ready
        return out

    def decode_step(self, params, cache, token, pos):
        """token [B,1] int32, pos scalar int32. Returns (logits [B,V], cache)."""
        cfg = self.cfg
        descs, _ = layer_pattern(cfg)
        x = params["emb"][token].astype(cfg.dtype)

        def group_fn(h, xs):
            gparams, gcache = xs
            new_cache = {}
            for j, desc in enumerate(descs):
                p, c = gparams[f"b{j}"], gcache[f"b{j}"]
                if desc.kind == "attn":
                    if cfg.attn_kind == "mla":
                        h, nc = L.mla_decode(p["attn"], h, c, pos,
                                             d_nope=cfg.d_nope, d_rope=cfg.d_rope,
                                             rope_theta=cfg.rope_theta,
                                             norm_eps=cfg.norm_eps)
                    else:
                        h, nc = L.gqa_decode(p["attn"], h, c, pos,
                                             window=cfg.window,
                                             rope_theta=cfg.rope_theta,
                                             norm_eps=cfg.norm_eps)
                else:
                    h, nc = mamba2.mamba2_decode(p["mamba"], h, c,
                                                 d_state=cfg.ssm_state,
                                                 head_dim=cfg.ssm_head_dim,
                                                 norm_eps=cfg.norm_eps)
                if desc.ffn == "moe":
                    y = moe.moe_apply(p["moe"], h, top_k=cfg.top_k,
                                      capacity_factor=cfg.capacity_factor,
                                      group_size=cfg.moe_group,
                                      norm_eps=cfg.norm_eps)
                    if cfg.dense_residual_ff:
                        y = y + (L.mlp_apply(p["mlp"], h, cfg.mlp_kind,
                                             cfg.norm_eps) - h)
                    h = y
                elif desc.ffn == "dense":
                    h = L.mlp_apply(p["mlp"], h, cfg.mlp_kind, cfg.norm_eps)
                new_cache[f"b{j}"] = nc
            return h, new_cache

        x, new_cache = jax.lax.scan(group_fn, x, (params["layers"], cache))
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = _logits(cfg, params, x)[:, 0]
        return logits, new_cache

    # .... cache construction ....
    def init_cache(self, batch_size: int, max_len: int, abstract: bool = False):
        cfg = self.cfg
        descs, n_groups = layer_pattern(cfg)
        dt = cfg.dtype

        def one(desc: BlockDesc):
            c = {}
            if desc.kind == "attn":
                ns = cfg.kv_shards if max_len % max(cfg.kv_shards, 1) == 0 else 1
                if cfg.window:
                    shape_k = (n_groups, batch_size, 1, cfg.window,
                               cfg.n_kv_heads, cfg.head_dim)
                    c["k"] = (shape_k, dt)
                    c["v"] = (shape_k, dt)
                elif cfg.attn_kind == "mla":
                    c["k"] = ((n_groups, batch_size, ns, max_len // ns, 1,
                               cfg.kv_lora + cfg.d_rope), dt)
                else:
                    shape_k = (n_groups, batch_size, ns, max_len // ns,
                               cfg.n_kv_heads, cfg.head_dim)
                    c["k"] = (shape_k, dt)
                    c["v"] = (shape_k, dt)
            else:
                d_inner = cfg.ssm_expand * cfg.d_model
                h = d_inner // cfg.ssm_head_dim
                c["ssm"] = ((n_groups, batch_size, h, cfg.ssm_head_dim,
                             cfg.ssm_state), jnp.float32)
                c["conv"] = ((n_groups, batch_size, mamba2.CONV_K - 1,
                              d_inner + 2 * cfg.ssm_state), dt)
            return c

        tree = {f"b{j}": one(d) for j, d in enumerate(descs)}
        make = (lambda sd: jax.ShapeDtypeStruct(*sd)) if abstract else \
               (lambda sd: jnp.zeros(*sd))
        return jax.tree.map(make, tree,
                            is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
                            and isinstance(x[0], tuple))


def build_model(cfg: ArchConfig):
    if cfg.family == "audio":
        from repro.archs.encdec import EncDecModel
        return EncDecModel(cfg)
    return Model(cfg)
