"""Parameter specs: shapes + logical sharding axes, materializable either as
real arrays (smoke tests, the training driver) or as ShapeDtypeStructs with
NamedShardings (the multi-pod dry-run). Models define their parameters once
as a ParamSpec pytree; everything else (init, sharding, optimizer-state
sharding, checkpoint layout) derives from it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    logical: tuple                 # logical axis name per dim (None = replicated)
    dtype: jnp.dtype = jnp.float32
    init: str = "normal"           # "normal" | "zeros" | "ones" | "scaled"
    scale: float = 0.02


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(key: jax.Array, specs, dtype_override=None):
    """Materialize a ParamSpec pytree into real arrays."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, s in zip(keys, leaves):
        dt = dtype_override or s.dtype
        if s.init == "zeros":
            out.append(jnp.zeros(s.shape, dt))
        elif s.init == "ones":
            out.append(jnp.ones(s.shape, dt))
        else:
            scale = s.scale
            if s.init == "scaled":           # 1/sqrt(fan_in) output-proj style
                scale = 1.0 / np.sqrt(max(int(np.prod(s.shape[:-1])), 1))
            out.append((jax.random.normal(k, s.shape, jnp.float32) * scale).astype(dt))
    return jax.tree.unflatten(treedef, out)


def abstract_params(specs, dtype_override=None):
    """ParamSpec pytree -> ShapeDtypeStruct pytree (no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype_override or s.dtype),
        specs, is_leaf=is_spec)


# Logical-axis -> mesh-axis rules (MaxText-style). "fsdp" is the combined
# (pod, data) axis group; "model" is tensor/expert parallelism.
def logical_to_mesh_axes(logical: tuple, mesh: jax.sharding.Mesh,
                         rules: dict) -> jax.sharding.PartitionSpec:
    from jax.sharding import PartitionSpec as P
    axes = []
    for name in logical:
        mapped = rules.get(name)
        if mapped is None:
            axes.append(None)
            continue
        axes.append(mapped)
    return P(*axes)


def shardings_for(specs, mesh: jax.sharding.Mesh, rules: dict):
    from jax.sharding import NamedSharding

    def one(s: ParamSpec):
        # drop mappings whose mesh axis size does not divide the dim, and
        # dedupe mesh axes within one spec (first dim wins — e.g. MoE
        # [experts, embed, mlp]: experts takes `model`, mlp replicates)
        axes = []
        used: set = set()
        mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
        for dim, name in zip(s.shape, s.logical):
            mapped = rules.get(name)
            if mapped is None:
                axes.append(None)
                continue
            flat = mapped if isinstance(mapped, tuple) else (mapped,)
            if any(a in used for a in flat):
                axes.append(None)
                continue
            size = int(np.prod([mesh_shape[a] for a in flat]))
            if dim % size == 0:
                axes.append(mapped)
                used.update(flat)
            else:
                axes.append(None)
        from jax.sharding import PartitionSpec as P
        return NamedSharding(mesh, P(*axes))

    return jax.tree.map(one, specs, is_leaf=is_spec)
