"""Whisper-style encoder-decoder (whisper-base).

The conv/audio frontend is a STUB per the assignment: `frames` arrive as
precomputed frame embeddings [B, S_enc, d_model]. Encoder: bidirectional
attention. Decoder: causal self-attention (KV cache) + cross-attention over
the encoder states (cross K/V precomputed at prefill). Positions are
sinusoidal (added) — whisper does not use RoPE, so rope_theta=0 disables it.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.archs import layers as L
from repro.archs.spec import ParamSpec, init_params, abstract_params
from repro.configs.base import ArchConfig
from repro.parallel.sharding import constrain_act, constrain_logits


def sinusoidal(positions: jax.Array, d: int) -> jax.Array:
    half = d // 2
    freqs = np.exp(-np.log(10000.0) * np.arange(half) / max(half - 1, 1))
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _enc_layer_specs(cfg: ArchConfig) -> dict:
    return {
        "attn": L.gqa_specs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                            cfg.head_dim, cfg.dtype),
        "mlp": L.mlp_specs(cfg.d_model, cfg.d_ff, cfg.mlp_kind, cfg.dtype),
    }


def _dec_layer_specs(cfg: ArchConfig) -> dict:
    return {
        "attn": L.gqa_specs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                            cfg.head_dim, cfg.dtype),
        "cross": L.gqa_specs(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                             cfg.head_dim, cfg.dtype),
        "mlp": L.mlp_specs(cfg.d_model, cfg.d_ff, cfg.mlp_kind, cfg.dtype),
    }


def _stack(specs: dict, n: int):
    from repro.archs.spec import is_spec
    return jax.tree.map(
        lambda s: ParamSpec((n,) + s.shape, ("layers",) + s.logical, s.dtype,
                            s.init, s.scale),
        specs, is_leaf=is_spec)


def _cross_attend(p, x, enc_k, enc_v, norm_eps, chunk):
    h = L.rmsnorm(p["norm"], x, norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    o = L.attention(q, enc_k, enc_v, causal=False, chunk=chunk)
    return x + jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def _cross_kv(p, enc_out):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    return k, v


@dataclass
class EncDecModel:
    cfg: ArchConfig

    def param_specs(self) -> dict:
        cfg = self.cfg
        return {
            "emb": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"), cfg.dtype),
            "head": ParamSpec((cfg.d_model, cfg.vocab), ("embed", "vocab"), cfg.dtype),
            "enc_norm": L.rmsnorm_spec(cfg.d_model),
            "final_norm": L.rmsnorm_spec(cfg.d_model),
            "enc_layers": _stack(_enc_layer_specs(cfg), cfg.enc_layers),
            "dec_layers": _stack(_dec_layer_specs(cfg), cfg.n_layers),
        }

    def init(self, key, dtype_override=None):
        return init_params(key, self.param_specs(), dtype_override)

    def abstract_params(self, dtype_override=None):
        return abstract_params(self.param_specs(), dtype_override)

    # ------------------------------------------------------------- encoder
    def encode(self, params, frames):
        cfg = self.cfg
        S = frames.shape[1]
        x = frames.astype(cfg.dtype) + sinusoidal(jnp.arange(S), cfg.d_model
                                                  ).astype(cfg.dtype)[None]
        positions = jnp.arange(S)

        def layer(h, p):
            h = constrain_act(h)
            h, _ = L.gqa_prefill(p["attn"], h, positions=positions,
                                 causal=False, rope_theta=0.0,
                                 norm_eps=cfg.norm_eps, chunk=cfg.attn_chunk)
            h = L.mlp_apply(p["mlp"], h, cfg.mlp_kind, cfg.norm_eps)
            return constrain_act(h), None

        fn = jax.checkpoint(layer) if cfg.remat else layer
        x, _ = jax.lax.scan(fn, x, params["enc_layers"])
        return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)

    # ------------------------------------------------------------- decoder
    def _decoder(self, params, tokens, enc_out, with_cache: bool):
        cfg = self.cfg
        S = tokens.shape[1]
        x = params["emb"][tokens].astype(cfg.dtype)
        x = x + sinusoidal(jnp.arange(S), cfg.d_model).astype(cfg.dtype)[None]
        positions = jnp.arange(S)

        def layer(h, p):
            h = constrain_act(h)
            h, c = L.gqa_prefill(p["attn"], h, positions=positions,
                                 causal=True, rope_theta=0.0,
                                 norm_eps=cfg.norm_eps, chunk=cfg.attn_chunk,
                                 with_cache=with_cache)
            ek, ev = _cross_kv(p["cross"], enc_out)
            h = _cross_attend(p["cross"], h, ek, ev, cfg.norm_eps, cfg.attn_chunk)
            h = L.mlp_apply(p["mlp"], h, cfg.mlp_kind, cfg.norm_eps)
            ys = {}
            if with_cache:
                ys = {"k": c[0], "v": c[1], "ek": ek, "ev": ev}
            return h, ys

        fn = jax.checkpoint(layer) if cfg.remat else layer
        x, ys = jax.lax.scan(fn, x, params["dec_layers"])
        return L.rmsnorm(params["final_norm"], x, cfg.norm_eps), ys

    # ------------------------------------------------------------ training
    def train_loss(self, params, batch: dict):
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        x, _ = self._decoder(params, batch["tokens"], enc_out, False)
        logits = constrain_logits(
            jnp.einsum("bsd,dv->bsv", x, params["head"].astype(x.dtype)))
        pred = logits[:, :-1].astype(jnp.float32)
        labels = batch["tokens"][:, 1:]
        lse = jax.nn.logsumexp(pred, axis=-1)
        ll = jnp.take_along_axis(pred, labels[..., None], axis=-1)[..., 0]
        loss = jnp.mean(lse - ll)
        return loss, {"loss": loss, "ppl_proxy": jnp.exp(jnp.minimum(loss, 20.0))}

    # ------------------------------------------------------------- serving
    def prefill(self, params, batch: dict):
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        x, ys = self._decoder(params, batch["tokens"], enc_out, True)
        logits = jnp.einsum("bsd,dv->bsv", x[:, -1:],
                            params["head"].astype(x.dtype))[:, 0]
        S = batch["tokens"].shape[1]
        ns = cfg.kv_shards if S % max(cfg.kv_shards, 1) == 0 else 1

        def reshape_kv(v):
            G, B, S_, K, D = v.shape
            return v.reshape(G, B, ns, S_ // ns, K, D)

        cache = {"k": reshape_kv(ys["k"]), "v": reshape_kv(ys["v"]),
                 "ek": ys["ek"], "ev": ys["ev"]}
        return logits, cache

    def decode_step(self, params, cache, token, pos):
        cfg = self.cfg
        x = params["emb"][token].astype(cfg.dtype)
        pe = sinusoidal(pos[None], cfg.d_model).astype(cfg.dtype)
        x = x + pe[None]

        def layer(h, xs):
            p, c = xs
            h, nc_self = L.gqa_decode(p["attn"], h, {"k": c["k"], "v": c["v"]},
                                      pos, rope_theta=0.0, norm_eps=cfg.norm_eps)
            hq = L.rmsnorm(p["cross"]["norm"], h, cfg.norm_eps)
            q = jnp.einsum("bsd,dhk->bshk", hq, p["cross"]["wq"])
            valid = jnp.ones((c["ek"].shape[1],), bool)
            o = L._masked_decode(q, c["ek"], c["ev"], valid)
            h = h + jnp.einsum("bshk,hkd->bsd", o, p["cross"]["wo"])
            h = L.mlp_apply(p["mlp"], h, cfg.mlp_kind, cfg.norm_eps)
            return h, {"k": nc_self["k"], "v": nc_self["v"],
                       "ek": c["ek"], "ev": c["ev"]}

        x, new_cache = jax.lax.scan(layer, x, (params["dec_layers"], cache))
        x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = jnp.einsum("bsd,dv->bsv", x, params["head"].astype(x.dtype))[:, 0]
        return logits, new_cache

    def init_cache(self, batch_size: int, max_len: int, abstract: bool = False):
        cfg = self.cfg
        G = cfg.n_layers
        ns = cfg.kv_shards if max_len % max(cfg.kv_shards, 1) == 0 else 1
        K, D = cfg.n_kv_heads, cfg.head_dim
        shapes = {
            "k": ((G, batch_size, ns, max_len // ns, K, D), cfg.dtype),
            "v": ((G, batch_size, ns, max_len // ns, K, D), cfg.dtype),
            "ek": ((G, batch_size, cfg.cross_len, K, D), cfg.dtype),
            "ev": ((G, batch_size, cfg.cross_len, K, D), cfg.dtype),
        }
        make = (lambda sd: jax.ShapeDtypeStruct(*sd)) if abstract else \
               (lambda sd: jnp.zeros(*sd))
        return {k: make(v) for k, v in shapes.items()}
