"""GShard-style top-k routed Mixture of Experts (arctic / dbrx / jamba).

TPU-native dispatch: capacity-bounded one-hot einsums (dispatch/combine
tensors), the canonical pjit/XLA pattern — expert-dim shardings on the
`model` mesh axis make XLA insert the all-to-alls. No torch-style dynamic
token lists: shapes stay static, overflow tokens are dropped (tracked by an
aux metric) and the residual path carries them.

Arctic's "dense residual": a small dense FFN runs in parallel with the MoE
and is summed — configured via dense_residual in the arch config.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.archs.layers import rmsnorm, rmsnorm_spec
from repro.archs.spec import ParamSpec


def moe_specs(d: int, f: int, n_experts: int, dtype) -> dict:
    # expert inner dims get their own logical axes ("expert_in") so they stay
    # fsdp-sharded even under the decode sharding rules (a 398B expert bank
    # cannot replicate across the data axis; dense weights can).
    return {
        "norm": rmsnorm_spec(d),
        "router": ParamSpec((d, n_experts), ("embed", None), jnp.float32),
        "w_gate": ParamSpec((n_experts, d, f), ("experts", "expert_in", "expert_mlp"), dtype),
        "w_up": ParamSpec((n_experts, d, f), ("experts", "expert_in", "expert_mlp"), dtype),
        "w_down": ParamSpec((n_experts, f, d), ("experts", "expert_mlp", "expert_in"),
                            dtype, init="scaled"),
    }


def moe_apply(p: dict, x: jax.Array, *, top_k: int, capacity_factor: float = 1.25,
              group_size: int = 1024, norm_eps: float = 1e-5) -> jax.Array:
    """x [B,S,D] -> [B,S,D]. Tokens are processed in groups; per group the
    per-expert capacity is C = ceil(g * top_k * cf / E)."""
    B, S, D = x.shape
    E = p["router"].shape[1]
    h = rmsnorm(p["norm"], x, norm_eps)
    T = B * S
    g = min(group_size, T)
    while T % g != 0:
        g //= 2
    G = T // g
    ht = h.reshape(G, g, D)

    logits = jnp.einsum("gtd,de->gte", ht.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                    # [G,g,E]
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)        # [G,g,k]
    # renormalize the selected gates (standard for top-k routing)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    C = max(1, int(round(g * top_k * capacity_factor / E)))
    sel = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)     # [G,g,k,E]
    # position of each (token, choice) within its expert queue
    pos_in_expert = (jnp.cumsum(sel.reshape(G, g * top_k, E), axis=1)
                     .reshape(G, g, top_k, E) - sel)
    keep = sel * (pos_in_expert < C)                           # overflow drops
    pos = jnp.einsum("gtke,gtke->gtk", pos_in_expert, keep).astype(jnp.int32)
    pos_oh = jax.nn.one_hot(pos, C, dtype=jnp.float32)         # [G,g,k,C]

    # dispatch/combine in the activation dtype (bf16): these G*g*E*C one-hot
    # tensors dominated the MoE-train memory term at f32 (§Perf cell 4b)
    dispatch = jnp.einsum("gtke,gtkc->gtec", keep, pos_oh).astype(ht.dtype)
    combine = jnp.einsum("gtk,gtke,gtkc->gtec", gate_vals, keep,
                         pos_oh).astype(ht.dtype)

    xin = jnp.einsum("gtec,gtd->gecd", dispatch, ht)           # [G,E,C,D]
    gate = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xin, p["w_gate"]))
    up = jnp.einsum("gecd,edf->gecf", xin, p["w_up"])
    xout = jnp.einsum("gecf,efd->gecd", gate * up, p["w_down"])  # [G,E,C,D]
    out = jnp.einsum("gtec,gecd->gtd", combine.astype(xout.dtype), xout)
    return x + out.reshape(B, S, D)
