"""Mamba2 / SSD block (state-space duality, Dao & Gu 2024) — mamba2-780m and
the mamba layers of jamba.

Chunked SSD: the sequence is split into chunks; within a chunk the quadratic
(attention-like) form runs on the MXU, across chunks a tiny recurrent state
[B,H,P,N] is carried by lax.scan — O(L) time, O(L * chunk) memory, exactly
the TPU-friendly formulation of the paper's algorithm. Decode is the O(1)
recurrence on the same state, which is the whole reason the long_500k cell
runs for SSM/hybrid archs only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.archs.layers import rmsnorm, rmsnorm_spec
from repro.archs.spec import ParamSpec

CONV_K = 4  # depthwise causal conv width


def mamba2_specs(d: int, *, d_state: int, head_dim: int = 64, expand: int = 2,
                 dtype=jnp.bfloat16) -> dict:
    d_inner = expand * d
    n_heads = d_inner // head_dim
    conv_dim = d_inner + 2 * d_state          # x, B, C go through the conv
    return {
        "norm": rmsnorm_spec(d),
        # in_proj emits [z, x, B, C, dt]
        "w_in": ParamSpec((d, 2 * d_inner + 2 * d_state + n_heads),
                          ("embed", "mlp"), dtype),
        "conv_w": ParamSpec((CONV_K, conv_dim), (None, "mlp"), dtype),
        "conv_b": ParamSpec((conv_dim,), ("mlp",), dtype, init="zeros"),
        "A_log": ParamSpec((n_heads,), ("heads",), jnp.float32, init="zeros"),
        "dt_bias": ParamSpec((n_heads,), ("heads",), jnp.float32, init="zeros"),
        "D": ParamSpec((n_heads,), ("heads",), jnp.float32, init="ones"),
        "out_norm": ParamSpec((d_inner,), ("mlp",), init="ones"),
        "w_out": ParamSpec((d_inner, d), ("mlp", "embed"), dtype, init="scaled"),
    }


def _split_in(proj, d_inner, d_state, n_heads):
    z = proj[..., :d_inner]
    x = proj[..., d_inner:2 * d_inner]
    B = proj[..., 2 * d_inner:2 * d_inner + d_state]
    C = proj[..., 2 * d_inner + d_state:2 * d_inner + 2 * d_state]
    dt = proj[..., 2 * d_inner + 2 * d_state:]
    return z, x, B, C, dt


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over [B,S,C] with kernel [K,C]."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    return out + b[None, None, :]


def mamba2_forward(p: dict, u: jax.Array, *, d_state: int, head_dim: int = 64,
                   chunk: int = 256, norm_eps: float = 1e-5,
                   with_state: bool = False):
    """u [B,S,D] -> [B,S,D]. Chunked SSD scan."""
    Bsz, S, D = u.shape
    d_inner = p["w_out"].shape[0]
    n_heads = d_inner // head_dim

    h = rmsnorm(p["norm"], u, norm_eps)
    proj = jnp.einsum("bsd,de->bse", h, p["w_in"])
    z, x, Bm, Cm, dt = _split_in(proj, d_inner, d_state, n_heads)
    xbc_raw = jnp.concatenate([x, Bm, Cm], axis=-1)     # pre-conv (cached)
    xbc = jax.nn.silu(_causal_conv(xbc_raw, p["conv_w"], p["conv_b"]))
    x, Bm, Cm = (xbc[..., :d_inner], xbc[..., d_inner:d_inner + d_state],
                 xbc[..., d_inner + d_state:])

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])      # [B,S,H]
    A = -jnp.exp(p["A_log"])                                          # [H] < 0
    xh = x.reshape(Bsz, S, n_heads, head_dim).astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)                                       # [B,S,N]
    Cf = Cm.astype(jnp.float32)

    chunk = min(chunk, S)
    while S % chunk != 0:
        chunk //= 2
    nc = S // chunk
    a = (dt * A[None, None, :]).reshape(Bsz, nc, chunk, n_heads)      # <= 0
    xc = xh.reshape(Bsz, nc, chunk, n_heads, head_dim)
    bc = Bf.reshape(Bsz, nc, chunk, d_state)
    cc = Cf.reshape(Bsz, nc, chunk, d_state)
    dtc = dt.reshape(Bsz, nc, chunk, n_heads)

    cum_a = jnp.cumsum(a, axis=2)                                     # [B,nc,c,H]

    def body(state, xs):
        a_c, cum_c, x_c, b_c, c_c, dt_c = xs
        # state: [B,H,P,N]
        # inter-chunk contribution: y_inter = C_t * exp(cum_a_t) @ state
        decay_in = jnp.exp(cum_c)                                     # [B,c,H]
        y_inter = jnp.einsum("bcn,bhpn,bch->bchp", c_c, state, decay_in)
        # intra-chunk (quadratic) term with decay matrix L
        seg = cum_c[:, :, None, :] - cum_c[:, None, :, :]             # [B,c,c,H]
        causal = jnp.tril(jnp.ones((seg.shape[1], seg.shape[1]), bool))
        L = jnp.where(causal[None, :, :, None], jnp.exp(seg), 0.0)
        scores = jnp.einsum("bcn,bsn->bcs", c_c, b_c)                 # [B,c,c]
        y_intra = jnp.einsum("bcs,bcsh,bsh,bshp->bchp",
                             scores, L, dt_c, x_c)
        # state update: S' = exp(sum a) S + sum_t exp(cum_end - cum_t) dt_t B_t x_t^T
        decay_out = jnp.exp(cum_c[:, -1:, :] - cum_c)                 # [B,c,H]
        new_state = (jnp.exp(cum_c[:, -1, :])[:, :, None, None] * state
                     + jnp.einsum("bch,bch,bchp,bcn->bhpn",
                                  decay_out, dt_c, x_c, b_c))
        return new_state, y_inter + y_intra

    state0 = jnp.zeros((Bsz, n_heads, head_dim, d_state), jnp.float32)
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (a, cum_a, xc, bc, cc, dtc))
    # checkpoint the chunk body: autodiff-of-scan would otherwise store the
    # O(chunk^2) intra-chunk decay/score tensors for every chunk
    final_state, ys = jax.lax.scan(jax.checkpoint(body), state0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, S, n_heads, head_dim)
    y = y + p["D"][None, None, :, None] * xh                          # skip
    y = y.reshape(Bsz, S, d_inner).astype(u.dtype)

    # gated output norm (mamba2: RMSNorm(y * silu(z)))
    y = rmsnorm(p["out_norm"], y * jax.nn.silu(z), norm_eps)
    out = u + jnp.einsum("bse,ed->bsd", y, p["w_out"])
    if with_state:
        # decode needs the last K-1 PRE-conv inputs
        conv_state = xbc_raw[:, -(CONV_K - 1):, :].astype(u.dtype)
        if S < CONV_K - 1:
            pad = jnp.zeros((Bsz, CONV_K - 1 - S, conv_state.shape[-1]), u.dtype)
            conv_state = jnp.concatenate([pad, conv_state], axis=1)
        return out, {"ssm": final_state, "conv": conv_state}
    return out, None


def mamba2_decode(p: dict, u: jax.Array, cache: dict, *, d_state: int,
                  head_dim: int = 64, norm_eps: float = 1e-5):
    """One-token recurrent step. u [B,1,D]; cache {"ssm":[B,H,P,N],
    "conv":[B,K-1,conv_dim]}. O(1) in context length."""
    Bsz, _, D = u.shape
    d_inner = p["w_out"].shape[0]
    n_heads = d_inner // head_dim

    h = rmsnorm(p["norm"], u, norm_eps)
    proj = jnp.einsum("bsd,de->bse", h, p["w_in"])
    z, x, Bm, Cm, dt = _split_in(proj, d_inner, d_state, n_heads)
    xbc = jnp.concatenate([x, Bm, Cm], axis=-1)                   # [B,1,conv]
    hist = jnp.concatenate([cache["conv"].astype(xbc.dtype), xbc], axis=1)
    w = p["conv_w"]
    conv_out = sum(hist[:, i, :] * w[i][None, :] for i in range(CONV_K))
    xbc1 = jax.nn.silu(conv_out + p["conv_b"][None, :])           # [B,conv]
    x1 = xbc1[:, :d_inner]
    B1 = xbc1[:, d_inner:d_inner + d_state].astype(jnp.float32)
    C1 = xbc1[:, d_inner + d_state:].astype(jnp.float32)

    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # [B,H]
    A = -jnp.exp(p["A_log"])
    da = jnp.exp(dt1 * A[None, :])                                # [B,H]
    xh1 = x1.reshape(Bsz, n_heads, head_dim).astype(jnp.float32)
    new_state = (da[:, :, None, None] * cache["ssm"]
                 + jnp.einsum("bh,bhp,bn->bhpn", dt1, xh1, B1))
    y = jnp.einsum("bn,bhpn->bhp", C1, new_state)
    y = y + p["D"][None, :, None] * xh1
    y = y.reshape(Bsz, 1, d_inner).astype(u.dtype)
    y = rmsnorm(p["out_norm"], y * jax.nn.silu(z), norm_eps)
    out = u + jnp.einsum("bse,ed->bsd", y, p["w_out"])
    new_conv = hist[:, 1:, :]
    return out, {"ssm": new_state, "conv": new_conv}
