"""Modality-frontend STUBS + input_specs for every (arch x shape) cell.

Per the assignment, [audio]/[vlm] entries specify the transformer BACKBONE
only: input_specs() provides precomputed frame/patch embeddings as
ShapeDtypeStructs (dry-run) or synthetic arrays (smoke tests / driver).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeCell


def input_specs(cfg: ArchConfig, cell: ShapeCell) -> dict:
    """ShapeDtypeStruct stand-ins for one step's inputs (no allocation)."""
    B, S = cell.global_batch, cell.seq_len
    i32 = jnp.int32
    if cell.kind in ("train", "prefill"):
        if cfg.family == "audio":
            return {"frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.dtype),
                    "tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.frontend == "vision_stub":
            n_tok = S - cfg.n_patches
            return {"tokens": jax.ShapeDtypeStruct((B, n_tok), i32),
                    "patches": jax.ShapeDtypeStruct((B, cfg.n_patches, cfg.d_model),
                                                    cfg.dtype)}
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    # decode: one new token against a cache of seq_len
    return {"token": jax.ShapeDtypeStruct((B, 1), i32),
            "pos": jax.ShapeDtypeStruct((), i32)}


def make_batch(cfg: ArchConfig, cell_kind: str, batch: int, seq: int,
               seed: int = 0) -> dict:
    """Concrete synthetic inputs (smoke tests, the training driver)."""
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(batch, seq), dtype=np.int32))
    if cell_kind in ("train", "prefill"):
        if cfg.family == "audio":
            frames = jnp.asarray(rng.normal(size=(batch, seq, cfg.d_model))
                                 .astype(np.float32) * 0.02, cfg.dtype)
            return {"frames": frames, "tokens": toks}
        if cfg.frontend == "vision_stub":
            n_tok = max(seq - cfg.n_patches, 8)
            patches = jnp.asarray(rng.normal(size=(batch, cfg.n_patches, cfg.d_model))
                                  .astype(np.float32) * 0.02, cfg.dtype)
            return {"tokens": toks[:, :n_tok], "patches": patches}
        return {"tokens": toks}
    return {"token": toks[:, :1], "pos": jnp.asarray(seq // 2, jnp.int32)}
