"""Composable transformer layers: norms, RoPE, chunked (flash-style)
attention, GQA/MQA/MLA attention blocks with KV caches, SWA ring buffers,
sharded flash-decode, and MLPs.

Memory discipline (the spike showed naive S x S attention costs 273 GB/dev
temp at 4k): prefill/train attention is *chunked* — an online-softmax scan
over KV chunks, so live intermediates are O(S * chunk) not O(S^2).

Decode attention uses the "sharded flash decode" layout: the KV cache is
stored as [B, NS, Sc, K, Dh] with the NS axis sharded over the `model` mesh
axis; each shard computes a partial (m, l, acc) and the combine is an
elementwise log-sum-exp merge over NS (tiny tensors). This is how MQA/GQA
archs with n_kv < TP (granite kv=1!) scale decode across the model axis —
head-sharding is impossible there.

MLA (MiniCPM3) uses the DeepSeek-V2 absorption trick: attention runs as MQA
over the latent c_kv (+ shared rope key); per-head projections are absorbed
into the query / applied after attention. The cache holds only the latent.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.archs.spec import ParamSpec

_NEG = -1e30


# --------------------------------------------------------------------- norms
def rmsnorm_spec(d: int) -> ParamSpec:
    return ParamSpec((d,), ("embed",), init="ones")


def rmsnorm(scale: jax.Array, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------- rope
def rope(x: jax.Array, positions: jax.Array, theta: float = 1e4) -> jax.Array:
    """x [..., S, H, D] (D even), positions [..., S] or [S].
    theta == 0 disables RoPE (archs with absolute/sinusoidal positions)."""
    if theta == 0:
        return x
    d = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = positions.astype(jnp.float32)[..., None] * freqs          # [.., S, D/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]                                          # head axis
    sin = sin[..., None, :]
    x1, x2 = x[..., 0::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x1 * sin + x2 * cos
    out = jnp.stack([y1, y2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------- flash attention
def _attn_mask(key_pos, q_pos, kv_valid, causal, window):
    mask = key_pos[None, :] < kv_valid
    if causal:
        mask = mask & (key_pos[None, :] <= q_pos[:, None])
    if window > 0:
        mask = mask & (key_pos[None, :] > q_pos[:, None] - window)
    return mask  # [S, chunk]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    q_offset: int = 0, kv_valid: int = -1, chunk: int = 512):
    """Memory-linear attention with a hand-written VJP.

    Autodiff of an online-softmax scan stores O(n_chunks * S * D) carries per
    layer (measured: 142 GB/dev on the 4k train cell) — the custom backward
    recomputes each chunk's probabilities from the saved logsumexp instead,
    keeping residuals at O(S * D): q,k,v,out,lse. This is the standard
    flash-attention backward, expressed in jnp (the Pallas TPU kernel for it
    lives in future work; XLA fuses this form well).

    q [B,S,H,Dk]; k [B,T,K,Dk]; v [B,T,K,Dv]; T % chunk == 0; kv_valid < 0
    means all T keys are valid.
    """
    out, _ = _flash_fwd_impl(q, k, v, causal, window, q_offset, kv_valid, chunk)
    return out


def _flash_fwd_impl(q, k, v, causal, window, q_offset, kv_valid, chunk):
    B, S, H, Dk = q.shape
    T, K = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // K
    nc = T // chunk
    scale = 1.0 / np.sqrt(Dk)
    valid = T if kv_valid < 0 else kv_valid

    qg = q.reshape(B, S, K, G, Dk)
    ks = jnp.moveaxis(k.reshape(B, nc, chunk, K, Dk), 1, 0)
    vs = jnp.moveaxis(v.reshape(B, nc, chunk, K, Dv), 1, 0)
    q_pos = q_offset + jnp.arange(S)

    def body(carry, xs):
        m, l, acc = carry
        kc, vc, ci = xs
        # native-dtype (bf16) operands with f32 accumulation: an .astype(f32)
        # on the KV operand materializes a full f32 copy in HBM (measured in
        # the dry-run HLO) — preferred_element_type avoids it.
        s = jnp.einsum("bskgd,btkd->bkgst", qg, kc,
                       preferred_element_type=jnp.float32) * scale
        key_pos = ci * chunk + jnp.arange(chunk)
        mask = _attn_mask(key_pos, q_pos, valid, causal, window)
        s = jnp.where(mask[None, None, None], s, _NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.where(mask[None, None, None], jnp.exp(s - m_new[..., None]), 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p.astype(v.dtype), vc,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, K, G, S), _NEG, jnp.float32)
    l0 = jnp.zeros((B, K, G, S), jnp.float32)
    a0 = jnp.zeros((B, K, G, S, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (ks, vs, jnp.arange(nc)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    lse = m + jnp.log(jnp.maximum(l, 1e-30))                  # [B,K,G,S]
    out = jnp.moveaxis(out, 3, 1).reshape(B, S, H, Dv).astype(q.dtype)
    return out, lse


def _flash_fwd(q, k, v, causal, window, q_offset, kv_valid, chunk):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, q_offset, kv_valid, chunk)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, window, q_offset, kv_valid, chunk, res, dout):
    q, k, v, out, lse = res
    B, S, H, Dk = q.shape
    T, K = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // K
    nc = T // chunk
    scale = 1.0 / np.sqrt(Dk)
    valid = T if kv_valid < 0 else kv_valid

    qg = q.reshape(B, S, K, G, Dk)
    dog = jnp.moveaxis(dout.reshape(B, S, K, G, Dv), 1, 3)    # [B,K,G,S,Dv]
    outg = jnp.moveaxis(out.reshape(B, S, K, G, Dv), 1, 3)
    delta = jnp.einsum("bkgsd,bkgsd->bkgs", dog, outg,
                       preferred_element_type=jnp.float32)    # [B,K,G,S]
    q_pos = q_offset + jnp.arange(S)
    ks = jnp.moveaxis(k.reshape(B, nc, chunk, K, Dk), 1, 0)
    vs = jnp.moveaxis(v.reshape(B, nc, chunk, K, Dv), 1, 0)

    def body(dq_acc, xs):
        kc, vc, ci = xs
        s = jnp.einsum("bskgd,btkd->bkgst", qg, kc,
                       preferred_element_type=jnp.float32) * scale
        key_pos = ci * chunk + jnp.arange(chunk)
        mask = _attn_mask(key_pos, q_pos, valid, causal, window)
        p = jnp.where(mask[None, None, None],
                      jnp.exp(s - lse[..., None]), 0.0)       # [B,K,G,S,c]
        pb = p.astype(v.dtype)
        dv_c = jnp.einsum("bkgst,bkgsd->btkd", pb, dog,
                          preferred_element_type=jnp.float32)
        dp = jnp.einsum("bkgsd,btkd->bkgst", dog, vc,
                        preferred_element_type=jnp.float32)
        ds = (p * (dp - delta[..., None]) * scale)
        dsb = ds.astype(k.dtype)
        dq_acc = dq_acc + jnp.einsum("bkgst,btkd->bskgd", dsb, kc,
                                     preferred_element_type=jnp.float32)
        dk_c = jnp.einsum("bkgst,bskgd->btkd", dsb, qg,
                          preferred_element_type=jnp.float32)
        return dq_acc, (dk_c, dv_c)

    dq0 = jnp.zeros((B, S, K, G, Dk), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(body, dq0, (ks, vs, jnp.arange(nc)))
    dq = dq.reshape(B, S, H, Dk).astype(q.dtype)
    dk = jnp.moveaxis(dks, 0, 1).reshape(B, T, K, Dk).astype(k.dtype)
    dv = jnp.moveaxis(dvs, 0, 1).reshape(B, T, K, Dv).astype(v.dtype)
    return dq, dk, dv


flash_attention.defvjp(_flash_fwd, _flash_bwd)


# ------------------------------------------------------- chunked attention
def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: int = 0, q_offset=0,
                      kv_valid: Optional[jax.Array] = None,
                      chunk: int = 512) -> jax.Array:
    """Online-softmax attention. q [B,S,H,Dk], k [B,T,K,Dk], v [B,T,K,Dv],
    H % K == 0. Returns [B,S,H,Dv]. T % chunk must be 0 (pad + kv_valid)."""
    B, S, H, Dk = q.shape
    T, K = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // K
    chunk = min(chunk, T)
    if T % chunk:  # pad keys to a chunk multiple; kv_valid masks the tail
        pad = chunk - T % chunk
        k = jnp.concatenate([k, jnp.zeros((B, pad, K, Dk), k.dtype)], axis=1)
        v = jnp.concatenate([v, jnp.zeros((B, pad, K, Dv), v.dtype)], axis=1)
        kv_valid = jnp.minimum(jnp.asarray(T if kv_valid is None else kv_valid), T)
        T = T + pad
    nc = T // chunk
    scale = 1.0 / np.sqrt(Dk)

    qg = q.reshape(B, S, K, G, Dk)
    ks = jnp.moveaxis(k.reshape(B, nc, chunk, K, Dk), 1, 0)   # [nc,B,c,K,Dk]
    vs = jnp.moveaxis(v.reshape(B, nc, chunk, K, Dv), 1, 0)
    q_pos = q_offset + jnp.arange(S)
    kv_valid = jnp.asarray(T if kv_valid is None else kv_valid)

    def body(carry, xs):
        m, l, acc = carry
        kc, vc, ci = xs
        s = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                       kc.astype(jnp.float32)) * scale        # [B,K,G,S,c]
        key_pos = ci * chunk + jnp.arange(chunk)
        mask = key_pos[None, :] < kv_valid
        if causal:
            mask = mask & (key_pos[None, :] <= q_pos[:, None])
        if window > 0:
            mask = mask & (key_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(mask[None, None, None], s, _NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.where(mask[None, None, None], jnp.exp(s - m_new[..., None]), 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgst,btkd->bkgsd", p, vc.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, K, G, S), _NEG, jnp.float32)
    l0 = jnp.zeros((B, K, G, S), jnp.float32)
    a0 = jnp.zeros((B, K, G, S, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                  (ks, vs, jnp.arange(nc)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]              # [B,K,G,S,Dv]
    out = jnp.moveaxis(out, 3, 1).reshape(B, S, H, Dv)
    return out.astype(q.dtype)


def sharded_flash_decode(q: jax.Array, kc: jax.Array, vc: jax.Array,
                         valid_len: jax.Array) -> jax.Array:
    """Single-token decode over a seq-sharded cache.

    q [B,1,H,Dk]; kc [B,NS,Sc,K,Dk]; vc [B,NS,Sc,K,Dv] (NS sharded over
    `model`). Returns [B,1,H,Dv]. Partial softmax per shard + LSE combine.
    """
    B, _, H, Dk = q.shape
    _, NS, Sc, K, _ = kc.shape
    Dv = vc.shape[-1]
    G = H // K
    scale = 1.0 / np.sqrt(Dk)
    qg = q.reshape(B, K, G, Dk)

    s = jnp.einsum("bkgd,bnskd->bnkgs", qg, kc,
                   preferred_element_type=jnp.float32) * scale
    key_pos = (jnp.arange(NS)[:, None] * Sc + jnp.arange(Sc)[None, :])
    mask = (key_pos < valid_len)[None, :, None, None, :]       # [1,NS,1,1,Sc]
    s = jnp.where(mask, s, _NEG)
    m = jnp.max(s, axis=-1)                                    # [B,NS,K,G]
    p = jnp.where(mask, jnp.exp(s - m[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)                                    # [B,NS,K,G]
    acc = jnp.einsum("bnkgs,bnskd->bnkgd", p.astype(vc.dtype), vc,
                     preferred_element_type=jnp.float32)

    # combine partials across shards (tiny tensors -> cheap collective)
    M = jnp.max(m, axis=1, keepdims=True)                      # [B,1,K,G]
    w = jnp.exp(m - M)                                         # [B,NS,K,G]
    l_tot = jnp.sum(l * w, axis=1)                             # [B,K,G]
    acc_tot = jnp.sum(acc * w[..., None], axis=1)              # [B,K,G,Dv]
    out = acc_tot / jnp.maximum(l_tot, 1e-30)[..., None]
    return out.reshape(B, 1, H, Dv).astype(q.dtype)


def cache_update(cache_k: jax.Array, cache_v: jax.Array, k1: jax.Array,
                 v1: jax.Array, pos: jax.Array):
    """Insert one token's K/V into the sharded [B,NS,Sc,K,D] cache."""
    B, NS, Sc, K, Dk = cache_k.shape
    shard = pos // Sc
    off = pos % Sc
    zero = jnp.zeros((), pos.dtype)
    ck = jax.lax.dynamic_update_slice(
        cache_k, k1[:, None, None].astype(cache_k.dtype),
        (zero, shard, off, zero, zero))
    cv = jax.lax.dynamic_update_slice(
        cache_v, v1[:, None, None].astype(cache_v.dtype),
        (zero, shard, off, zero, zero))
    return ck, cv


def attention(q, k, v, *, causal=True, window=0, q_offset=0, chunk=512,
              impl: str = "xla"):
    """Training/prefill attention entry point: pads KV to a chunk multiple
    and dispatches to flash_attention (custom-VJP, memory-linear XLA path)
    or the fused Pallas TPU kernel (impl="pallas": serving/prefill forward;
    keeps score tiles in VMEM and skips causal-masked kv blocks — see
    kernels/flash_attention.py and EXPERIMENTS.md §Perf)."""
    B, T, K, Dk = k.shape[0], k.shape[1], k.shape[2], k.shape[3]
    Dv = v.shape[-1]
    chunk = min(chunk, T)
    pad = (-T) % chunk
    if pad:
        k = jnp.concatenate([k, jnp.zeros((B, pad, K, Dk), k.dtype)], axis=1)
        v = jnp.concatenate([v, jnp.zeros((B, pad, K, Dv), v.dtype)], axis=1)
    if impl == "pallas" and window == 0 and q_offset == 0:
        from repro.kernels.flash_attention import flash_attention_pallas
        return flash_attention_pallas(
            q, k, v, causal=causal, block_q=min(64, q.shape[1]),
            block_kv=chunk, kv_valid=T if pad else -1,
            interpret=jax.default_backend() != "tpu")
    return flash_attention(q, k, v, causal, window, q_offset,
                           T if pad else -1, chunk)


# ------------------------------------------------------------ GQA attention
def gqa_specs(d: int, n_heads: int, n_kv: int, d_head: int, dtype) -> dict:
    return {
        "norm": rmsnorm_spec(d),
        "wq": ParamSpec((d, n_heads, d_head), ("embed", "heads", "head_dim"), dtype),
        "wk": ParamSpec((d, n_kv, d_head), ("embed", "kv_heads", "head_dim"), dtype),
        "wv": ParamSpec((d, n_kv, d_head), ("embed", "kv_heads", "head_dim"), dtype),
        "wo": ParamSpec((n_heads, d_head, d), ("heads", "head_dim", "embed"), dtype,
                        init="scaled"),
    }


def gqa_prefill(p: dict, x: jax.Array, *, positions, causal=True, window=0,
                rope_theta=1e4, norm_eps=1e-5, chunk=512, kv_valid=None,
                with_cache=False):
    """Full-sequence attention block. Returns (y, (k, v) or None)."""
    h = rmsnorm(p["norm"], x, norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
    q = rope(q, positions, rope_theta)
    k = rope(k, positions, rope_theta)
    o = attention(q, k, v, causal=causal, window=window, chunk=chunk)
    y = x + jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return (y, (k, v)) if with_cache else (y, None)


def gqa_decode(p: dict, x: jax.Array, cache: dict, pos: jax.Array, *,
               window=0, rope_theta=1e4, norm_eps=1e-5):
    """One-token decode. x [B,1,D]. cache {"k","v"}: [B,NS,Sc,K,Dh] (or ring
    [B,1,W,K,Dh] when window>0). Returns (y, new_cache)."""
    h = rmsnorm(p["norm"], x, norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    k1 = jnp.einsum("bsd,dhk->bshk", h, p["wk"])[:, 0]
    v1 = jnp.einsum("bsd,dhk->bshk", h, p["wv"])[:, 0]
    q = rope(q, pos[None], rope_theta)
    k1 = rope(k1[:, None], pos[None], rope_theta)[:, 0]

    if window > 0:
        # ring buffer: slot = pos % W; key positions are reconstructable
        W = cache["k"].shape[2]
        slot = pos % W
        ck, cv = cache_update(cache["k"], cache["v"], k1, v1,
                              jnp.asarray(slot))
        # slot i holds position p_i = pos - ((pos - i) mod W), valid if >= 0
        idx = jnp.arange(W)
        key_pos = pos - ((pos - idx) % W)
        # map to "valid length" semantics via masked flash decode: treat the
        # ring as a single shard and mask invalid slots by key position.
        o = _masked_decode(q, ck[:, 0], cv[:, 0], key_pos >= 0)
    else:
        ck, cv = cache_update(cache["k"], cache["v"], k1, v1, pos)
        o = sharded_flash_decode(q, ck, cv, pos + 1)
    y = x + jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return y, {"k": ck, "v": cv}


def _masked_decode(q, k, v, valid_mask):
    """q [B,1,H,Dk], k/v [B,T,K,D*], valid_mask [T] bool."""
    B, _, H, Dk = q.shape
    K = k.shape[2]
    G = H // K
    scale = 1.0 / np.sqrt(Dk)
    qg = q.reshape(B, K, G, Dk)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(valid_mask[None, None, None, :], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(valid_mask[None, None, None, :], p, 0.0)
    o = jnp.einsum("bkgt,btkd->bkgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, H, v.shape[-1]).astype(q.dtype)


# ------------------------------------------------------------ MLA attention
def mla_specs(d: int, n_heads: int, *, q_lora: int, kv_lora: int,
              d_nope: int, d_rope: int, d_v: int, dtype) -> dict:
    return {
        "norm": rmsnorm_spec(d),
        "w_dq": ParamSpec((d, q_lora), ("embed", "latent"), dtype),
        "w_uq": ParamSpec((q_lora, n_heads, d_nope + d_rope),
                          ("latent", "heads", "head_dim"), dtype),
        "w_dkv": ParamSpec((d, kv_lora), ("embed", "latent"), dtype),
        "w_kr": ParamSpec((d, d_rope), ("embed", "head_dim"), dtype),
        "w_uk": ParamSpec((kv_lora, n_heads, d_nope),
                          ("latent", "heads", "head_dim"), dtype),
        "w_uv": ParamSpec((kv_lora, n_heads, d_v),
                          ("latent", "heads", "head_dim"), dtype),
        "wo": ParamSpec((n_heads, d_v, d), ("heads", "head_dim", "embed"),
                        dtype, init="scaled"),
    }


def _mla_absorbed_q(p, h, positions, rope_theta, d_nope, d_rope):
    """Queries in the latent space: q_abs [B,S,H, kv_lora + d_rope]."""
    q = jnp.einsum("bsd,dr->bsr", h, p["w_dq"])
    q = jnp.einsum("bsr,rhk->bshk", q, p["w_uq"])          # [B,S,H,dn+dr]
    q_nope, q_rope = q[..., :d_nope], q[..., d_nope:]
    q_rope = rope(q_rope, positions, rope_theta)
    # absorb w_uk: q_abs = q_nope @ w_uk^T  -> latent dims
    q_abs = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"])
    return jnp.concatenate([q_abs, q_rope], axis=-1)        # [B,S,H,r+dr]


def mla_prefill(p: dict, x: jax.Array, *, positions, d_nope: int, d_rope: int,
                rope_theta=1e4, norm_eps=1e-5, chunk=512, with_cache=False,
                absorb: bool = False):
    """MLA prefill.

    absorb=False (default, §Perf iteration 1 on minicpm3): materialize
    per-head K [B,S,H,d_nope+d_rope] / V [B,S,H,d_v] — score dims 96 vs the
    absorbed form's kv_lora+d_rope=288 and value dims 64 vs 256, a ~3.4x
    attention-FLOP reduction at prefill. The absorbed (MQA-over-latent) form
    only pays off at decode, where it shrinks the CACHE; the prefill cache
    returned here is the latent either way.
    """
    h = rmsnorm(p["norm"], x, norm_eps)
    c_kv = jnp.einsum("bsd,dr->bsr", h, p["w_dkv"])         # [B,S,r]
    k_rope = rope(jnp.einsum("bsd,dk->bsk", h, p["w_kr"])[:, :, None, :],
                  positions, rope_theta)[:, :, 0]           # [B,S,dr]
    if absorb:
        q_abs = _mla_absorbed_q(p, h, positions, rope_theta, d_nope, d_rope)
        k = jnp.concatenate([c_kv, k_rope], axis=-1)[:, :, None, :]
        v = c_kv[:, :, None, :]                             # [B,S,1,r]
        o_lat = attention(q_abs, k, v, causal=True, chunk=chunk)
        o = jnp.einsum("bshr,rhk->bshk", o_lat, p["w_uv"])  # [B,S,H,d_v]
    else:
        q = jnp.einsum("bsd,dr->bsr", h, p["w_dq"])
        q = jnp.einsum("bsr,rhk->bshk", q, p["w_uq"])       # [B,S,H,dn+dr]
        q_rope = rope(q[..., d_nope:], positions, rope_theta)
        q = jnp.concatenate([q[..., :d_nope], q_rope], axis=-1)
        k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"])
        H = k_nope.shape[2]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      k_rope.shape[:2] + (H, d_rope))], axis=-1)
        v = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uv"])    # [B,S,H,d_v]
        o = attention(q, k, v, causal=True, chunk=chunk)
    y = x + jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    if with_cache:
        lat = jnp.concatenate([c_kv, k_rope], axis=-1)[:, :, None, :]
        return y, (lat,)
    return y, None


def mla_decode(p: dict, x: jax.Array, cache: dict, pos: jax.Array, *,
               d_nope: int, d_rope: int, rope_theta=1e4, norm_eps=1e-5):
    """cache {"k": [B,NS,Sc,1,r+dr]} — latent-only cache (the MLA win)."""
    r = p["w_dkv"].shape[1]
    h = rmsnorm(p["norm"], x, norm_eps)
    c_kv = jnp.einsum("bsd,dr->bsr", h, p["w_dkv"])[:, 0]
    k_rope = rope(jnp.einsum("bsd,dk->bsk", h, p["w_kr"]),
                  pos[None], rope_theta)[:, 0]
    k1 = jnp.concatenate([c_kv, k_rope], axis=-1)[:, None, :]    # [B,1,r+dr]
    q_abs = _mla_absorbed_q(p, h, pos[None], rope_theta, d_nope, d_rope)
    ck = cache["k"]
    zero = jnp.zeros((), pos.dtype)
    Sc = ck.shape[2]
    ck = jax.lax.dynamic_update_slice(
        ck, k1[:, None, None].astype(ck.dtype),
        (zero, pos // Sc, pos % Sc, zero, zero))
    vcache = ck[..., :r]
    o_lat = sharded_flash_decode(q_abs, ck, vcache, pos + 1)
    o = jnp.einsum("bshr,rhk->bshk", o_lat, p["w_uv"])
    y = x + jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return y, {"k": ck}


# ----------------------------------------------------------------------- MLP
def mlp_specs(d: int, f: int, kind: str, dtype) -> dict:
    if kind == "swiglu":
        return {
            "norm": rmsnorm_spec(d),
            "w_gate": ParamSpec((d, f), ("embed", "mlp"), dtype),
            "w_up": ParamSpec((d, f), ("embed", "mlp"), dtype),
            "w_down": ParamSpec((f, d), ("mlp", "embed"), dtype, init="scaled"),
        }
    return {
        "norm": rmsnorm_spec(d),
        "w_in": ParamSpec((d, f), ("embed", "mlp"), dtype),
        "w_out": ParamSpec((f, d), ("mlp", "embed"), dtype, init="scaled"),
    }


def mlp_apply(p: dict, x: jax.Array, kind: str, norm_eps=1e-5) -> jax.Array:
    h = rmsnorm(p["norm"], x, norm_eps)
    if kind == "swiglu":
        g = jax.nn.silu(jnp.einsum("bsd,df->bsf", h, p["w_gate"]))
        u = jnp.einsum("bsd,df->bsf", h, p["w_up"])
        return x + jnp.einsum("bsf,fd->bsd", g * u, p["w_down"])
    hh = jax.nn.gelu(jnp.einsum("bsd,df->bsf", h, p["w_in"]))
    return x + jnp.einsum("bsf,fd->bsd", hh, p["w_out"])
