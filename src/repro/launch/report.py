"""Render EXPERIMENTS.md sections from dry-run JSON records.

  PYTHONPATH=src python -m repro.launch.report --dryrun experiments/dryrun
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dirpath: str) -> list[dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        if p.endswith("summary.json"):
            continue
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def fmt_e(x) -> str:
    return f"{x:.2e}" if x else "0"


def dryrun_table(recs: list[dict], mesh: str) -> str:
    lines = [
        "| arch | shape | status | compile s | peak GB/dev | fits 16GB | "
        "HLO flops/dev | HLO bytes/dev | coll bytes/dev | collectives |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | SKIP ({r['reason'][:40]}…) "
                         "| | | | | | | |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR | | | | | | | |")
            continue
        c = r["corrected"]
        coll = ", ".join(f"{k}:{fmt_e(v)}" for k, v in sorted(
            c.get("coll", {}).items()))
        lines.append(
            f"| {r['arch']} | {r['shape']} | ok | {r.get('compile_s','-')} "
            f"| {r['memory']['peak_gb']:.2f} | {'Y' if r.get('fits_16gb') else 'N'} "
            f"| {fmt_e(c['flops'])} | {fmt_e(c['mem_bytes'])} "
            f"| {fmt_e(c['coll_bytes'])} | {coll or '-'} |")
    return "\n".join(lines)


def roofline_table(recs: list[dict]) -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "bound step ms | MODEL_FLOPS/dev | useful ratio |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r.get("mesh") != "single" or r["status"] != "ok" or "terms" not in r:
            continue
        t = r["terms"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.4g} "
            f"| {t['memory_s']:.4g} | {t['collective_s']:.4g} "
            f"| {t['dominant'].replace('_s','')} "
            f"| {t['step_s_lower_bound']*1e3:.3g} "
            f"| {fmt_e(r.get('model_flops', 0))} "
            f"| {r.get('useful_ratio', 0):.3f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="experiments/dryrun")
    args = ap.parse_args()
    recs = load(args.dryrun)
    print("## Dry-run — single-pod mesh (16, 16)\n")
    print(dryrun_table(recs, "single"))
    print("\n## Dry-run — multi-pod mesh (2, 16, 16)\n")
    print(dryrun_table(recs, "multi"))
    print("\n## Roofline (single-pod, per device)\n")
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
