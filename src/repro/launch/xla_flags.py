"""XLA flag helpers: async-collective overlap + forced host devices.

The overlapped ring sweep (core/topology.py `RingSharded(overlap=True)`,
DESIGN.md §15) issues the next query block's `ppermute` BEFORE the
current histogram step and combines partial counts with a ring
reduce-scatter, so the hop transfers while the MXU sweeps.  On
TPU the compiler overlaps async collectives with independent compute by
default; on GPU the equivalent behavior sits behind XLA flags
(`--xla_gpu_enable_async_collectives`, the latency-hiding scheduler,
and the high-priority async stream).  This module centralizes those
flags so launch scripts and benchmark subprocesses compose them instead
of hand-rolling `XLA_FLAGS` strings.

Functions, not import-time mutation — importing this module touches
neither the environment nor jax device state (the mesh-module rule,
DESIGN.md §7).  `apply_xla_flags` must run BEFORE the first jax import
in the target process: XLA parses the variable once at backend
initialization, which is why the benchmark harness passes these through
subprocess environments rather than calling `apply_xla_flags` in an
already-initialized process.
"""
from __future__ import annotations

import os

#: GPU overlap flags (SNIPPETS.md launch idiom): async collectives +
#: latency-hiding scheduler so a started `ppermute` transfers behind
#: independent compute, plus the high-priority async stream so the
#: collective is not queued behind the sweep kernels it should overlap.
GPU_OVERLAP_FLAGS: tuple[str, ...] = (
    "--xla_gpu_enable_async_collectives=true",
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
)


def overlap_flags(platform: str | None = None) -> tuple[str, ...]:
    """Flags enabling collective/compute overlap for `platform` (default:
    probe the environment variable-free way — `platform=None` returns the
    GPU set, the only platform that needs explicit flags; TPU overlaps by
    default and CPU ignores them)."""
    if platform in (None, "gpu", "cuda", "rocm"):
        return GPU_OVERLAP_FLAGS
    return ()


def host_device_count_flag(n: int) -> str:
    """`--xla_force_host_platform_device_count=<n>`: fake n host devices
    so CPU subprocesses can host multi-shard meshes (the ring-topology
    tests/benches drive `make_join_mesh(r=...)` through this)."""
    if n < 1:
        raise ValueError(f"host_device_count_flag({n}): need n >= 1")
    return f"--xla_force_host_platform_device_count={n}"


def compose_xla_flags(*flags: str, env: dict | None = None) -> str:
    """The XLA_FLAGS value combining `env`'s existing flags with `flags`
    (existing first, duplicates dropped, order preserved)."""
    env = os.environ if env is None else env
    parts = [p for p in env.get("XLA_FLAGS", "").split() if p]
    for f in flags:
        if f not in parts:
            parts.append(f)
    return " ".join(parts)


def apply_xla_flags(*flags: str, env: dict | None = None) -> str:
    """Merge `flags` into `env['XLA_FLAGS']` (default `os.environ`) and
    return the new value.  Call BEFORE the process first imports jax —
    XLA reads the variable once at backend init; an already-initialized
    process will not pick the flags up (pass them to a subprocess env
    instead, see benchmarks/bench_ring.py)."""
    env = os.environ if env is None else env
    merged = compose_xla_flags(*flags, env=env)
    env["XLA_FLAGS"] = merged
    return merged
