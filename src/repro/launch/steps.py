"""Step functions shared by the dry-run, the training driver and serve."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim import adamw, cosine_warmup
from repro.utils import tree_size


def make_optimizer(cfg, n_params: int):
    """bf16 moments for >=30B params so optimizer state fits 16 GB/chip
    (DESIGN.md §6); full-f32 moments below that."""
    moment_dtype = jnp.bfloat16 if n_params >= 30e9 else jnp.float32
    return adamw(lr=cosine_warmup(3e-4, 200, 10000), b1=0.9, b2=0.95,
                 weight_decay=0.1, clip_norm=1.0, moment_dtype=moment_dtype)


def make_train_step(model, opt):
    accum = getattr(model.cfg, "grad_accum", 1)

    def grad_of(params, batch):
        def loss_fn(p):
            loss, metrics = model.train_loss(p, batch)
            return loss, metrics
        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def step(params, opt_state, batch):
        if accum <= 1:
            (loss, metrics), grads = grad_of(params, batch)
        else:
            # gradient accumulation: microbatch scan divides the activation
            # peak by ~accum (XLA overlaps each microbatch's reduce with the
            # next microbatch's compute)
            micro = jax.tree.map(
                lambda x: x.reshape((accum, x.shape[0] // accum) + x.shape[1:]),
                batch)

            def body(acc, mb):
                (loss, metrics), grads = grad_of(params, mb)
                acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32) / accum,
                                   acc, grads)
                return acc, metrics

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, metrics_all = jax.lax.scan(body, zeros, micro)
            metrics = jax.tree.map(lambda m: m[-1], metrics_all)
        new_params, new_state = opt.apply(params, opt_state, grads)
        return new_params, new_state, metrics
    return step


def make_prefill_step(model):
    def step(params, batch):
        return model.prefill(params, batch)
    return step


def make_decode_step(model):
    def step(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)
    return step
