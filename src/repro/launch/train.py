"""Training driver CLI.

Smoke-scale on CPU by default (reduced config); pass --full to use the
assigned config (only sensible on a real TPU fleet, but the code path is
identical — mesh + shardings scale, the loop does not change).

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama_1_1b \
      --steps 100 --batch 8 --seq 128 --ckpt /tmp/ckpt \
      [--fail-at 50] [--compression topk]
"""
from __future__ import annotations

import argparse
import json

from repro.configs import get_config
from repro.runtime.loop import TrainLoopConfig, run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--compression", default="none",
                    choices=["none", "topk", "int8"])
    ap.add_argument("--fail-at", type=int, default=-1)
    ap.add_argument("--lose-devices", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=not args.full)
    loop = TrainLoopConfig(total_steps=args.steps, batch=args.batch,
                           seq=args.seq, ckpt_dir=args.ckpt,
                           ckpt_every=args.ckpt_every,
                           compression=args.compression,
                           fail_at_step=args.fail_at,
                           lose_devices=args.lose_devices,
                           log_every=args.log_every)
    hist = run_training(cfg, loop)
    print(json.dumps({"final_loss": hist["final_loss"],
                      "restarts": hist["restarts"],
                      "mesh_shapes": [list(s) for s in hist["mesh_shapes"]],
                      "steps": len(hist["loss"])}))


if __name__ == "__main__":
    main()
