"""Mesh construction — the single version-compatible entry point.

Every mesh in the codebase (production, CPU smoke, elastic rebuilds, the
engine's data mesh, tests) is built through `make_mesh` here.  JAX moved the
`axis_types=` kwarg / `jax.sharding.AxisType` enum in post-0.4.x releases;
`make_mesh` feature-detects them and falls back cleanly, so no module may
touch `jax.sharding.AxisType` or pass `axis_types=` directly (DESIGN.md §7).
The policy is enforced mechanically: xlint's mesh-policy rule (DESIGN.md
§12, `make lint`) rejects raw `jax.sharding.Mesh(...)` / `jax.make_mesh`
calls, `AxisType` access, and `axis_types=` kwargs everywhere but here.

Functions, not module constants — importing this module never touches jax
device state.
"""
from __future__ import annotations

from typing import Sequence

import jax
import numpy as np


def _axis_types_kw(n_axes: int) -> dict:
    """`{"axis_types": (Auto,) * n}` on JAX versions that have the enum."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_mesh(shape: Sequence[int], axes: Sequence[str], *,
              devices=None) -> jax.sharding.Mesh:
    """Version-compatible mesh builder.

    shape/axes as for `jax.make_mesh`.  Pass `devices` (flat sequence, length
    prod(shape)) to pin an explicit device order (elastic rebuilds); otherwise
    jax picks a performant order over all local devices.
    """
    kw = _axis_types_kw(len(axes))
    if devices is not None:
        devs = np.asarray(devices).reshape(tuple(shape))
        try:
            return jax.sharding.Mesh(devs, tuple(axes), **kw)
        except TypeError:       # enum exists but ctor predates the kwarg
            return jax.sharding.Mesh(devs, tuple(axes))
    try:
        return jax.make_mesh(tuple(shape), tuple(axes), **kw)
    except TypeError:
        return jax.make_mesh(tuple(shape), tuple(axes))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_cpu_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever host devices exist (tests / driver)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(n // data, 1))
    return make_mesh((data, model), ("data", "model"))


def make_data_mesh(n: int | None = None) -> jax.sharding.Mesh:
    """1-D `("data",)` mesh over the first n local devices (join engine,
    replicated topology)."""
    devs = jax.devices()
    n = len(devs) if n is None else min(n, len(devs))
    return make_mesh((n,), ("data",), devices=devs[:n])


def make_join_mesh(data: int | None = None, r: int = 1) -> jax.sharding.Mesh:
    """2-D `("r", "data")` join-engine mesh (DESIGN.md §10).

    `r` is the index-sharding axis (R row-shards over it under
    `topology="ring"`; per-device R memory drops by this factor), `data`
    the query-sharding axis; `data=None` spreads the remaining devices
    (len(devices) // r). Built through the mandatory `make_mesh` compat
    path. Raises ValueError when the local device count cannot host the
    requested shape — at build time, not inside a sweep."""
    devs = jax.devices()
    if r < 1:
        raise ValueError(f"make_join_mesh(r={r}): r must be >= 1")
    if len(devs) < r:
        raise ValueError(
            f"make_join_mesh(r={r}): only {len(devs)} local device(s); the "
            "r axis cannot exceed the device count")
    if data is None:
        data = len(devs) // r
    n = r * data
    if data < 1 or n > len(devs):
        raise ValueError(
            f"make_join_mesh(data={data}, r={r}): needs {n} devices, have "
            f"{len(devs)}")
    return make_mesh((r, data), ("r", "data"), devices=devs[:n])
