"""Production mesh builders. Functions, not module constants — importing
this module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_cpu_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever host devices exist (tests / driver)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(n // data, 1))
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
