import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
ShapeDtypeStruct inputs (zero allocation), prove the sharding is coherent,
record memory_analysis / cost_analysis / corrected roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out experiments/dryrun
  (add --paper-workload to also dry-run the Xling join step cells)
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.archs import build_model
from repro.archs.frontends import input_specs
from repro.archs.spec import is_spec
from repro.configs import ARCH_IDS, get_config
from repro.configs.base import SHAPES, supports_cell
from repro.launch import roofline
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_decode_step, make_optimizer, make_train_step
from repro.parallel.sharding import (activation_sharding, batch_shardings,
                                     cache_shardings, param_shardings,
                                     _batch_axes)
from repro.optim.adam import OptState
from repro.utils import cost_analysis_dict


def _sds(tree, shardings):
    """Attach shardings onto ShapeDtypeStructs."""
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree, shardings)


def _param_counts(specs, cfg) -> tuple[int, int]:
    total = expert = 0
    for s in jax.tree.leaves(specs, is_leaf=is_spec):
        n = int(np.prod(s.shape))
        total += n
        if "experts" in s.logical:
            expert += n
    active = total - expert
    if cfg_experts := getattr(cfg, "n_experts", 0):
        active += expert * getattr(cfg, "top_k", 1) // cfg_experts
    return total, active


def run_cell(arch: str, shape: str, mesh_kind: str) -> dict:
    cfg = get_config(arch)
    cell = SHAPES[shape]
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind}
    ok, why = supports_cell(cfg, cell)
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.devices.size
    model = build_model(cfg)
    specs = model.param_specs()
    shard_mode = "decode" if cell.kind == "decode" else "train"
    p_shard = param_shardings(specs, mesh, mode=shard_mode)
    params = _sds(model.abstract_params(), p_shard)
    n_total, n_active = _param_counts(specs, cfg)
    rec["params_total"] = n_total
    rec["params_active"] = n_active

    t0 = time.time()
    # activations see the MICRObatch at train time (grad accumulation)
    act_batch = cell.global_batch
    if cell.kind == "train":
        act_batch = max(cell.global_batch // max(cfg.grad_accum, 1), 1)
    act_ctx = activation_sharding(mesh, _batch_axes(mesh, act_batch))
    try:
        if cell.kind == "train":
            opt = make_optimizer(cfg, n_total)
            opt_shapes = jax.eval_shape(opt.init, params)
            mu = _sds(opt_shapes.mu, p_shard)
            nu = _sds(opt_shapes.nu, p_shard)
            opt_state = OptState(step=jax.ShapeDtypeStruct((), jnp.int32),
                                 mu=mu, nu=nu)
            batch = input_specs(cfg, cell)
            b_shard = batch_shardings(mesh, batch)
            batch = _sds(batch, b_shard)
            step = make_train_step(model, opt)
            with act_ctx:
                lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
                    params, opt_state, batch)
        elif cell.kind == "prefill":
            batch = input_specs(cfg, cell)
            batch = _sds(batch, batch_shardings(mesh, batch))
            with act_ctx:
                lowered = jax.jit(model.prefill).lower(params, batch)
        else:  # decode
            cache = model.init_cache(cell.global_batch, cell.seq_len,
                                     abstract=True)
            cache = _sds(cache, cache_shardings(cfg, mesh, cache))
            io = input_specs(cfg, cell)
            token = jax.ShapeDtypeStruct(io["token"].shape, io["token"].dtype,
                                         sharding=batch_shardings(mesh, io)["token"])
            pos = jax.ShapeDtypeStruct((), jnp.int32)
            step = make_decode_step(model)
            with act_ctx:
                lowered = jax.jit(step, donate_argnums=(1,)).lower(
                    params, cache, token, pos)
        rec["lower_s"] = round(time.time() - t0, 2)

        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

        ma = compiled.memory_analysis()
        rec["memory"] = {
            "arg_gb": ma.argument_size_in_bytes / 2**30,
            "out_gb": ma.output_size_in_bytes / 2**30,
            "temp_gb": ma.temp_size_in_bytes / 2**30,
            "alias_gb": ma.alias_size_in_bytes / 2**30,
            # live working set per device: args + outputs + temps - aliased
            "peak_gb": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                        + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30,
        }
        rec["fits_16gb"] = rec["memory"]["peak_gb"] <= 16.0

        ca = cost_analysis_dict(compiled)
        rec["raw_cost"] = {"flops": ca.get("flops", 0.0),
                           "bytes": ca.get("bytes accessed", 0.0)}

        hlo = analyze_text = compiled.as_text()
        parsed = roofline.analyze_hlo(hlo)
        rec["corrected"] = {"flops": parsed["flops"],
                            "mem_bytes": parsed["mem_bytes"],
                            "coll_bytes": parsed["coll_bytes"],
                            "coll": parsed["coll"]}
        rec["terms"] = roofline.roofline_terms(parsed["flops"],
                                               parsed["mem_bytes"],
                                               parsed["coll_bytes"])
        mf = roofline.model_flops(cfg, n_total, n_active, cell, n_dev)
        rec["model_flops"] = mf
        rec["useful_ratio"] = mf / parsed["flops"] if parsed["flops"] else 0.0
        rec["status"] = "ok"
    except Exception as e:  # a failure here is a sharding bug in the system
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def paper_workload_cells(mesh_kind: str) -> list:
    """Dry-run the paper's own workload: the Xling filter step and the
    brute-force verification step on the production mesh (R sharded over
    `model`, queries over the data axes)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.xling_paper import CONFIG as W
    from repro.kernels import ref as kref
    from repro.models.mlp import init_mlp, apply_mlp

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    fsdp = ("pod", "data") if mesh_kind == "multi" else ("data",)
    recs = []

    # filter step: fused estimator inference over a global query batch
    widths = W.estimator_widths
    dims = (W.dim + 1,) + widths + (1,)
    mlp_params = tuple(
        (jax.ShapeDtypeStruct((a, b), jnp.float32,
                              sharding=NamedSharding(mesh, P(None, None))),
         jax.ShapeDtypeStruct((1, b), jnp.float32,
                              sharding=NamedSharding(mesh, P(None, None))))
        for a, b in zip(dims[:-1], dims[1:]))
    q = jax.ShapeDtypeStruct((W.query_batch, W.dim + 1), jnp.float32,
                             sharding=NamedSharding(mesh, P(fsdp, None)))

    def filter_step(params, x):
        return apply_mlp(params, x)

    rec = {"arch": "xling-paper", "shape": "filter_step", "mesh": mesh_kind}
    try:
        t0 = time.time()
        compiled = jax.jit(filter_step).lower(mlp_params, q).compile()
        parsed = roofline.analyze_hlo(compiled.as_text())
        ma = compiled.memory_analysis()
        rec.update(status="ok", compile_s=round(time.time() - t0, 2),
                   corrected={"flops": parsed["flops"],
                              "mem_bytes": parsed["mem_bytes"],
                              "coll_bytes": parsed["coll_bytes"]},
                   terms=roofline.roofline_terms(parsed["flops"],
                                                 parsed["mem_bytes"],
                                                 parsed["coll_bytes"]),
                   memory={"peak_gb": (ma.argument_size_in_bytes +
                                       ma.output_size_in_bytes +
                                       ma.temp_size_in_bytes) / 2**30})
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}")
    recs.append(rec)

    # join (verification) step: R sharded over model, queries over data —
    # each device counts its R shard's neighbors, psum over model.
    nR = W.n_index
    R = jax.ShapeDtypeStruct((nR, W.dim), jnp.float32,
                             sharding=NamedSharding(mesh, P("model", None)))
    Q = jax.ShapeDtypeStruct((W.query_batch, W.dim), jnp.float32,
                             sharding=NamedSharding(mesh, P(fsdp, None)))

    def join_step(r, qq):
        d = 1.0 - qq @ r.T                      # cosine on unit vectors
        return jnp.sum(d <= 0.45, axis=1, dtype=jnp.int32)

    rec = {"arch": "xling-paper", "shape": "join_step", "mesh": mesh_kind}
    try:
        t0 = time.time()
        compiled = jax.jit(join_step).lower(R, Q).compile()
        parsed = roofline.analyze_hlo(compiled.as_text())
        ma = compiled.memory_analysis()
        rec.update(status="ok", compile_s=round(time.time() - t0, 2),
                   corrected={"flops": parsed["flops"],
                              "mem_bytes": parsed["mem_bytes"],
                              "coll_bytes": parsed["coll_bytes"]},
                   terms=roofline.roofline_terms(parsed["flops"],
                                                 parsed["mem_bytes"],
                                                 parsed["coll_bytes"]),
                   memory={"peak_gb": (ma.argument_size_in_bytes +
                                       ma.output_size_in_bytes +
                                       ma.temp_size_in_bytes) / 2**30})
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}")
    recs.append(rec)
    return recs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--paper-workload", action="store_true")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    os.makedirs(args.out, exist_ok=True)
    results = []
    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                rec = run_cell(arch, shape, mesh_kind)
                results.append(rec)
                tag = f"{arch} x {shape} x {mesh_kind}"
                if rec["status"] == "ok":
                    t = rec["terms"]
                    print(f"[ok]   {tag:55s} compile={rec['compile_s']:6.1f}s "
                          f"peak={rec['memory']['peak_gb']:6.2f}GB/dev "
                          f"dominant={t['dominant']} "
                          f"useful={rec['useful_ratio']:.2f}", flush=True)
                elif rec["status"] == "skipped":
                    print(f"[skip] {tag:55s} {rec['reason']}", flush=True)
                else:
                    print(f"[ERR]  {tag:55s} {rec['error']}", flush=True)
                with open(os.path.join(args.out,
                                       f"{arch}_{shape}_{mesh_kind}.json"),
                          "w") as f:
                    json.dump(rec, f, indent=1, default=float)
        if args.paper_workload:
            for rec in paper_workload_cells(mesh_kind):
                results.append(rec)
                print(f"[{rec['status']:4s}] {rec['arch']} x {rec['shape']} x "
                      f"{mesh_kind}", flush=True)

    with open(os.path.join(args.out, "summary.json"), "w") as f:
        json.dump(results, f, indent=1, default=float)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\n{n_ok} ok / {n_skip} skipped / {n_err} errors "
          f"out of {len(results)} cells")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
