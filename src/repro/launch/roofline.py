"""Roofline analysis from compiled HLO (CPU container, TPU v5e targets).

Why a custom HLO walker: compiled.cost_analysis() on this jax/XLA build
reports PER-DEVICE numbers with `while` (scan) bodies counted ONCE (verified
in the spike). Every model here scans over layer groups, so raw
cost_analysis underestimates by ~n_layers. This module parses
compiled.as_text() post-SPMD, computes per-computation FLOPs (dots),
HBM-traffic proxies and collective bytes, then expands the call graph with
while-loop trip counts (XLA's backend_config "known_trip_count", falling
back to config-supplied trips).

HBM-traffic proxy: per top-level op, result bytes (write) + operand result
bytes (reads); fusion internals are invisible (correct — they stay in
registers/VMEM); dynamic-slice/gather/dynamic-update-slice are special-cased
to touch only the sliced/updated bytes (XLA updates in place).

Hardware constants (TPU v5e, from the assignment):
  197 TFLOP/s bf16 per chip | 819 GB/s HBM | ~50 GB/s/link ICI.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
                "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
                "s32": 4, "u32": 4, "f32": 4,
                "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16, "token": 0,
                "s4": 1, "u4": 1}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+) = (.*?) ([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')


def _shape_bytes_elems(type_str: str) -> tuple[int, int]:
    """Total (bytes, elements) across possibly-tuple type strings."""
    total_b = total_e = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        elems = 1
        if dims:
            for d in dims.split(","):
                elems *= int(d)
        total_e += elems
        total_b += elems * _DTYPE_BYTES[dt]
    return total_b, total_e


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclass
class Comp:
    flops: float = 0.0
    mem_bytes: float = 0.0
    coll: dict = field(default_factory=dict)
    calls: list = field(default_factory=list)    # (callee, trip)


_SKIP_OPS = {"get-tuple-element", "tuple", "parameter", "constant", "bitcast",
             "after-all", "partition-id", "replica-id", "iota"}


def parse_module(txt: str) -> tuple[dict, str]:
    comps: dict[str, Comp] = {}
    shapes: dict[str, str] = {}
    entry = None
    cur = None
    lines = txt.splitlines()

    # pass 1: result types for operand lookups + root opcode / DUS presence
    # per computation
    roots: dict[str, str] = {}
    has_dus: set[str] = set()
    _cur = None
    for ln in lines:
        stripped = ln.strip()
        if stripped.endswith("{") and "->" in stripped:
            mm = _COMP_RE.match(stripped)
            if mm:
                _cur = mm.group(1)
            continue
        m = _INSTR_RE.match(ln)
        if m:
            shapes[m.group(1)] = m.group(2)
            if stripped.startswith("ROOT") and _cur:
                roots[_cur] = m.group(3)
            if m.group(3) == "dynamic-update-slice" and _cur:
                has_dus.add(_cur)

    for ln in lines:
        stripped = ln.strip()
        # computation headers end with the body-opening brace and contain
        # the "-> result_type" arrow (instruction lines never end with "{")
        if stripped.endswith("{") and "->" in stripped:
            m = _COMP_RE.match(stripped)
            if m:
                cur = m.group(1)
                comps[cur] = Comp()
                if stripped.startswith("ENTRY"):
                    entry = cur
                continue
        if cur is None:
            continue
        m = _INSTR_RE.match(ln)
        if not m:
            continue
        name, rtype, opcode, rest = m.groups()
        comp = comps[cur]

        # operands: %names inside the first paren group
        depth, i0, ops_str = 1, 0, ""
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    ops_str = rest[:i]
                    break
        operands = re.findall(r"%([\w\.\-]+)", ops_str)
        attrs = rest[len(ops_str):]

        rbytes, relems = _shape_bytes_elems(rtype)

        if opcode == "while":
            trip = 1
            tm = _TRIP_RE.search(attrs)
            if tm:
                trip = int(tm.group(1))
            bm = re.search(r"body=%?([\w\.\-]+)", attrs)
            cm = re.search(r"condition=%?([\w\.\-]+)", attrs)
            if bm:
                comp.calls.append((bm.group(1), trip, True))
            if cm:
                comp.calls.append((cm.group(1), trip, True))
            continue
        # fusion bodies execute as ONE fused HBM op: recurse for flops
        # (dots inside fusions are real compute) but NOT for memory —
        # fusion internals live in registers/VMEM.
        for kind in ("calls", "to_apply"):
            km = re.search(kind + r"=%?([\w\.\-]+)", attrs)
            if km:
                comp.calls.append((km.group(1), 1, False))

        base = opcode.replace("-start", "")
        if base in COLLECTIVES:
            obytes = sum(_shape_bytes_elems(shapes.get(o, ""))[0]
                         for o in operands)
            comp.coll[base] = comp.coll.get(base, 0.0) + max(rbytes, obytes)
            comp.mem_bytes += max(rbytes, obytes)
            continue

        if opcode in _SKIP_OPS or opcode.endswith("-done"):
            continue

        if opcode == "dot":
            out_dims = _shape_dims(rtype)
            lm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", attrs)
            lhs_dims = _shape_dims(shapes.get(operands[0], "")) if operands else []
            contr = 1
            if lm and lm.group(1):
                for d in lm.group(1).split(","):
                    di = int(d)
                    if di < len(lhs_dims):
                        contr *= lhs_dims[di]
            out_elems = 1
            for d in out_dims:
                out_elems *= d
            comp.flops += 2.0 * out_elems * contr

        fusion_root = ""
        fusion_dus = False
        if opcode == "fusion":
            km = re.search(r"calls=%?([\w\.\-]+)", attrs)
            if km:
                fusion_root = roots.get(km.group(1), "")
                fusion_dus = km.group(1) in has_dus

        if opcode in ("dynamic-slice", "gather") or fusion_root in (
                "dynamic-slice", "gather"):
            comp.mem_bytes += 2.0 * rbytes
        elif opcode == "dynamic-update-slice" or fusion_dus:
            # in-place update: traffic = the updated slab, not the buffer.
            # For DUS fusions the aliased buffer operand matches the result
            # size — count only the small operands, twice (read + write).
            small = [_shape_bytes_elems(shapes.get(o, ""))[0] for o in operands]
            small = [b for b in small if 2 * b <= rbytes]
            comp.mem_bytes += 2.0 * (sum(small) if small else rbytes)
        elif opcode == "dot":
            obytes = sum(_shape_bytes_elems(shapes.get(o, ""))[0]
                         for o in operands)
            comp.mem_bytes += rbytes + obytes
        else:
            # elementwise/fusion/copy ops: write + read of result-sized data
            # plus genuinely-smaller side inputs. Counting full same-size
            # operands here double-counts XLA:CPU's bf16->f32 convert copies
            # (which do not exist on the TPU target) and aliased buffers.
            small = sum(b for b in (_shape_bytes_elems(shapes.get(o, ""))[0]
                                    for o in operands) if 2 * b <= rbytes)
            comp.mem_bytes += 2.0 * rbytes + small

    return comps, entry


def expand(comps: dict, name: str, memo: dict | None = None) -> dict:
    """Recursively expand call graph: returns {flops, mem_bytes, coll:{..}}."""
    memo = {} if memo is None else memo
    if name in memo:
        return memo[name]
    c = comps.get(name)
    if c is None:
        return {"flops": 0.0, "mem_bytes": 0.0, "coll": {}}
    memo[name] = {"flops": 0.0, "mem_bytes": 0.0, "coll": {}}  # cycle guard
    total = {"flops": c.flops, "mem_bytes": c.mem_bytes, "coll": dict(c.coll)}
    for callee, trip, with_mem in c.calls:
        sub = expand(comps, callee, memo)
        total["flops"] += trip * sub["flops"]
        if with_mem:
            total["mem_bytes"] += trip * sub["mem_bytes"]
        for k, v in sub["coll"].items():
            total["coll"][k] = total["coll"].get(k, 0.0) + trip * v
    memo[name] = total
    return total


def analyze_hlo(txt: str) -> dict:
    comps, entry = parse_module(txt)
    total = expand(comps, entry)
    total["coll_bytes"] = sum(total["coll"].values())
    return total


def roofline_terms(flops: float, mem_bytes: float, coll_bytes: float) -> dict:
    """Per-device seconds for each roofline term + the dominant one."""
    t = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": mem_bytes / HBM_BW,
        "collective_s": coll_bytes / ICI_BW,
    }
    t["dominant"] = max(("compute_s", "memory_s", "collective_s"),
                        key=lambda k: t[k])
    t["step_s_lower_bound"] = max(t["compute_s"], t["memory_s"], t["collective_s"])
    return t


def model_flops(cfg, n_params_total: int, n_params_active: int, cell,
                n_devices: int) -> float:
    """Analytic MODEL_FLOPS per device (6ND train / 2ND inference)."""
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_params_active * tokens / n_devices
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_params_active * tokens / n_devices
    # decode: one token per sequence
    return 2.0 * n_params_active * cell.global_batch / n_devices
