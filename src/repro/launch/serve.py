"""Serving driver: embedding model + Xling-filtered similarity join.

This is the paper's production story end-to-end: a backbone produces
embeddings for incoming requests; XJoin finds their eps-neighbors in the
indexed corpus R, with the Xling filter skipping negative queries.

  PYTHONPATH=src python -m repro.launch.serve --dataset glove --n 4000 \
      --eps 0.45 --tau 5 --batches 4 --batch-size 256
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.configs.xling_paper import SMOKE as WORKLOAD
from repro.core import XlingConfig, build_xjoin
from repro.data import load_dataset


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="glove")
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--eps", type=float, default=0.45)
    ap.add_argument("--tau", type=int, default=5)
    ap.add_argument("--batches", type=int, default=4)
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--estimator", default="nn")
    ap.add_argument("--epochs", type=int, default=10)
    args = ap.parse_args()

    R, S, spec = load_dataset(args.dataset, n=args.n)
    xcfg = XlingConfig(estimator=args.estimator, metric=spec.metric,
                       epochs=args.epochs, backend="jnp")
    t0 = time.time()
    xj = build_xjoin(R, spec.metric, xling_cfg=xcfg, tau=args.tau,
                     cache_key=(args.dataset, args.n), backend="jnp")
    build_s = time.time() - t0
    naive = xj.base       # shares the xjoin engine's device-resident R

    batches = [q for b in range(args.batches)
               if len(q := S[b * args.batch_size:(b + 1) * args.batch_size])]
    stats = []
    # the engine streaming path: R + estimator stay device-resident across
    # batches, compiled programs are reused (bucketed shapes)
    for b, res in enumerate(xj.run_stream(batches, args.eps)):
        q = batches[b]
        true = naive.query_counts(q, args.eps)
        stats.append({
            "batch": b, "queries": int(res.n_queries),
            "searched": int(res.n_searched),
            "skipped_frac": 1.0 - res.n_searched / max(res.n_queries, 1),
            "t_filter_ms": res.t_filter * 1e3,
            "t_search_ms": res.t_search * 1e3,
            "recall": res.recall_vs(true),
        })
        print(json.dumps(stats[-1]))

    agg = {
        "build_s": build_s,
        "mean_recall": float(np.mean([s["recall"] for s in stats])),
        "mean_skipped": float(np.mean([s["skipped_frac"] for s in stats])),
        "mean_t_ms": float(np.mean([s["t_filter_ms"] + s["t_search_ms"]
                                    for s in stats])),
    }
    print(json.dumps({"summary": agg}))


if __name__ == "__main__":
    main()
