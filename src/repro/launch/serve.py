"""Serving driver: the multi-tenant gateway CLI (DESIGN.md §14).

The paper's production story end-to-end: the CLI flags compile into a
`repro.serve.Gateway` — one pinned device-resident R/estimator behind
per-tenant `(eps, recall target, latency SLO)` classes — and the query
stream is replayed as per-tenant REQUESTS through the gateway's
admission path: eps-aware result cache, cross-request micro-batching
into the engine's bucketed static shapes, asynchronous pipelined
dispatch with SLO-driven adaptive depth, and per-request scatter-back.

The base flags (--eps/--tau/--verify/--probe/--depth/--slo-ms) define
the "default" tenant class; each repeatable `--tenant` flag adds
another, e.g.

  --tenant "name=gold,eps=0.4,verify=exact,slo_ms=50" \
  --tenant "name=bulk,eps=0.5,verify=lsh,recall=0.9,tau=20"

Requests round-robin over the classes within every input batch. The
first output line is the gateway configuration; each request line
reports result quality (recall vs the exact oracle over the logical
set), cache hits, and latency; the summary aggregates them and the
final line is the full `Gateway.report()` (admitted/coalesced/
cache-hit/SLO-miss counters, p50/p95, per-group stream depths).

  PYTHONPATH=src python -m repro.launch.serve --dataset glove --n 4000 \
      --eps 0.45 --tau 5 --batches 4 --batch-size 256 --verify lsh \
      --tenant "name=strict,eps=0.4,verify=exact,slo_ms=100"
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.data import load_dataset
from repro.serve import Gateway, TenantClass


def batch_stats(b: int, res, true_counts: np.ndarray,
                delta_frac: float | None = None) -> dict:
    """One report line for a `JoinResult`-shaped batch (the single-plan
    serving form, kept for plan-level debugging and the probe tests):
    filter skip rate, verification recall vs the exact oracle, probe
    placement + the verify index's build-time candidate-loss budget
    (DESIGN.md §11), the delta occupancy at submit time when a mutation
    trace is being replayed (DESIGN.md §13), and the filter/search
    timing split."""
    out = {
        "batch": b,
        "queries": int(res.n_queries),
        "searched": int(res.n_searched),
        "skipped_frac": 1.0 - res.n_searched / max(res.n_queries, 1),
        "recall": res.recall_vs(true_counts),
        "verify": res.meta.get("verify", "exact"),
        "probe": res.meta.get("probe"),
        "overflow_frac": res.meta.get("overflow_frac"),
        "t_filter_ms": res.t_filter * 1e3,
        "t_search_ms": res.t_search * 1e3,
    }
    if delta_frac is not None:
        out["delta_frac"] = float(delta_frac)
    return out


def summarize(stats: list[dict], build_s: float) -> dict:
    """Aggregate the per-request lines: mean recall / cache-hit
    fraction, p50/p95 request latency, and the SET of verify backends
    seen across the run — a multi-tenant run mixes routes, so reporting
    one request's backend would misdescribe every other tenant."""
    if not stats:
        return {"build_s": build_s, "requests": 0}
    lat = np.asarray([s["latency_ms"] for s in stats])
    return {
        "build_s": build_s,
        "requests": len(stats),
        "mean_recall": float(np.mean([s["recall"] for s in stats])),
        "mean_cache_hit_frac": float(np.mean(
            [s["cache_hits"] / max(s["queries"], 1) for s in stats])),
        "mean_latency_ms": float(lat.mean()),
        "p50_latency_ms": float(np.percentile(lat, 50)),
        "p95_latency_ms": float(np.percentile(lat, 95)),
        "verify": sorted({s["verify"] for s in stats}),
    }


def load_trace(path: str) -> dict[int, list[dict]]:
    """Parse a JSONL mutation trace into {batch index: [ops]}: each line is
    `{"before_batch": k, "op": "insert"|"delete"|"compact", "n": ...,
    "seed": ...}` — the ops run right before batch k is submitted."""
    by_batch: dict[int, list[dict]] = {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            op = json.loads(line)
            by_batch.setdefault(int(op.get("before_batch", 0)), []).append(op)
    return by_batch


def apply_ops(target, ops, live: dict, dim: int) -> None:
    """Replay trace ops against a mutable target (a `Gateway` or a
    mutable `JoinPlan` — anything exposing insert/delete/compact),
    mirroring them into `live` (id -> row), the host shadow of the
    logical set that the recall oracle is computed from. Inserts draw
    seeded unit rows; deletes draw seeded ids from the live set (never
    the last row)."""
    for op in ops:
        kind = op["op"]
        rng = np.random.default_rng(int(op.get("seed", 0)))
        if kind == "insert":
            rows = rng.normal(size=(int(op["n"]), dim)).astype(np.float32)
            rows /= np.maximum(
                np.linalg.norm(rows, axis=1, keepdims=True), 1e-12)
            ids = target.insert(rows)
            live.update(zip(map(int, ids), rows))
        elif kind == "delete":
            pool = np.fromiter(live, dtype=np.int64)
            ids = rng.choice(pool, size=min(int(op["n"]), len(pool) - 1),
                             replace=False)
            target.delete(ids)
            for i in ids:
                live.pop(int(i))
        elif kind == "compact":
            target.compact()
        else:
            raise ValueError(f"mutate-trace: unknown op {kind!r}; expected "
                             "'insert', 'delete', or 'compact'")


#: --tenant spec fields -> parser (everything else is an error)
_TENANT_FIELDS = {
    "name": str, "eps": float, "recall": float, "slo_ms": float,
    "verify": str, "probe": str, "tau": int, "depth": int, "max_depth": int,
}


def parse_tenant(spec: str) -> TenantClass:
    """Compile one `--tenant "k=v,k=v,..."` spec into a `TenantClass`
    (fields: name, eps, recall, slo_ms, verify, probe, tau, depth,
    max_depth)."""
    kw: dict = {}
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        key, _, val = part.partition("=")
        key = key.strip()
        if key not in _TENANT_FIELDS:
            raise ValueError(f"--tenant {spec!r}: unknown field {key!r}; "
                             f"expected {sorted(_TENANT_FIELDS)}")
        kw[key] = _TENANT_FIELDS[key](val.strip())
    if "name" not in kw or "eps" not in kw:
        raise ValueError(f"--tenant {spec!r}: name= and eps= are required")
    if "recall" in kw:
        kw["recall_target"] = kw.pop("recall")
    return TenantClass(**kw)


def build_gateway(args, R, metric: str) -> Gateway:
    """Compile the CLI flags into a built `Gateway`: the base flags make
    the "default" tenant class, each `--tenant` spec adds one, and the
    shared Xling filter is fitted once at build (so its one-time cost
    lands in build_s, not in request 0's reported latency)."""
    classes = [TenantClass("default", eps=args.eps, verify=args.verify,
                           probe=args.probe, slo_ms=args.slo_ms,
                           depth=args.depth)]
    classes += [parse_tenant(s) for s in args.tenant]
    return Gateway(
        R, classes, metric=metric, filter="xling",
        filter_opts=dict(tau=args.tau, xdt="fpr", estimator=args.estimator,
                         epochs=args.epochs),
        backend="jnp", topology=args.topology, r_shards=args.r_shards,
        cache_key=(args.dataset, args.n), eps_quantum=args.eps_quantum,
        max_batch_rows=args.max_batch_rows,
        mutable=args.mutate_trace is not None)


def main():
    """CLI entry point: compile the flags into a Gateway, replay the
    query stream as round-robin per-tenant requests, and print the
    per-request lines, aggregate summary, and the gateway report."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="glove")
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--eps", type=float, default=0.45)
    ap.add_argument("--tau", type=int, default=5)
    ap.add_argument("--batches", type=int, default=4)
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--estimator", default="nn")
    ap.add_argument("--epochs", type=int, default=10)
    ap.add_argument("--verify", default="exact",
                    choices=("auto", "exact", "lsh", "ivfpq", "learned"),
                    help="default tenant's verification backend "
                         "(DESIGN.md §5; 'learned' is the RMI index)")
    ap.add_argument("--depth", type=int, default=2,
                    help="default tenant's initial async in-flight bound "
                         "(0 ~= synchronous; adapts under --slo-ms)")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="default tenant's per-request latency SLO — "
                         "drives SLO-miss accounting and adaptive depth")
    ap.add_argument("--tenant", action="append", default=[],
                    metavar="SPEC",
                    help="add a tenant class: 'name=gold,eps=0.4,"
                         "verify=lsh,recall=0.9,slo_ms=50,tau=5' "
                         "(repeatable; requests round-robin over classes)")
    ap.add_argument("--eps-quantum", type=float, default=None,
                    help="snap explicit request radii to this grid "
                         "(coalescing/caching buckets, DESIGN.md §14)")
    ap.add_argument("--max-batch-rows", type=int, default=None,
                    help="coalescing budget per dispatched batch "
                         "(default: the engine's minimum padded bucket)")
    ap.add_argument("--topology", default=None,
                    choices=("replicated", "ring"),
                    help="where R lives on the mesh (DESIGN.md §10): "
                         "replicated (default) or ring (R sharded over "
                         "--r-shards devices)")
    ap.add_argument("--r-shards", type=int, default=None,
                    help="ring topology: number of R shards (the mesh's "
                         "r-axis size)")
    ap.add_argument("--probe", default="auto",
                    choices=("auto", "device", "host"),
                    help="where the approximate verify route's index "
                         "probe runs (DESIGN.md §11): auto = on device "
                         "whenever the searcher supports it")
    ap.add_argument("--mutate-trace", default=None, metavar="PATH",
                    help="JSONL mutation trace replayed against the "
                         "stream (DESIGN.md §13): each line "
                         "{'before_batch': k, 'op': 'insert'|'delete'|"
                         "'compact', 'n': ..., 'seed': ...}; makes the "
                         "gateway mutable and computes each request's "
                         "recall oracle against the logical set at "
                         "submit time")
    args = ap.parse_args()

    R, S, spec = load_dataset(args.dataset, n=args.n)
    t0 = time.time()
    gw = build_gateway(args, R, spec.metric)
    build_s = time.time() - t0
    rep0 = gw.report()
    print(json.dumps({"gateway": {k: rep0[k] for k in
                                  ("mutable", "eps_quantum",
                                   "max_batch_rows", "n_index", "tenants")}},
                     default=str))
    names = sorted(rep0["tenants"])
    trace = load_trace(args.mutate_trace) if args.mutate_trace else {}
    live = {i: R[i] for i in range(len(R))}

    def oracle(q: np.ndarray, eps: float) -> np.ndarray:
        # brute force over the logical set at submit time — under a
        # mutation trace `live` tracks inserts/deletes, otherwise it is
        # just R (DESIGN.md §13)
        from repro.kernels import ref
        world = np.stack(list(live.values()))
        return np.asarray(ref.range_count(q, world, eps,
                                          metric=spec.metric))

    batches = [q for b in range(args.batches)
               if len(q := S[b * args.batch_size:(b + 1) * args.batch_size])]
    stats = []
    for b, batch in enumerate(batches):
        apply_ops(gw, trace.get(b, ()), live, R.shape[1])
        # one request per tenant class per input batch (round-robin
        # split); the gateway coalesces compatible ones back together
        parts = [p for p in np.array_split(batch, len(names)) if len(p)]
        tickets = [(name, q, gw.submit(name, q))
                   for name, q in zip(names, parts)]
        gw.flush()
        for name, q, t in tickets:
            stats.append({
                "batch": b, "tenant": name, "queries": int(t.n),
                "eps": t.eps, "cache_hits": int(t.meta["cache_hits"]),
                "recall": float(np.minimum(t.counts, tr := oracle(q, t.eps))
                                .sum() / max(tr.sum(), 1)),
                "latency_ms": float(t.latency_ms),
                "verify": rep0["tenants"][name]["verify"],
            })
            print(json.dumps(stats[-1]))

    print(json.dumps({"summary": summarize(stats, build_s)}))
    print(json.dumps({"report": gw.report()}, default=str))


if __name__ == "__main__":
    main()
