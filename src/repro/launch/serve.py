"""Serving driver: embedding model + Xling-filtered similarity join.

This is the paper's production story end-to-end, on the declarative
`JoinPlan` API (DESIGN.md §9): the CLI flags compile into one plan —
filter("xling") -> search("naive") -> verify(--verify) — which is
validated and built once (filter fit, engine construction, verifier
index, probe-table placement) and then serves query batches through the
engine's asynchronous pipelined stream (DESIGN.md §5, §11): batch k+1
dispatches while batch k's results transfer back, with `--depth`
bounding the in-flight queue, `--verify` picking the verification
backend (exact sweep, or LSH / IVF-PQ candidate probing with on-device
verification), and `--probe` picking where the index probe runs
(`device` keeps the whole probe→verify path on the mesh).

The first output line is the serialized plan (`plan.describe()`). Each
batch line reports filter effectiveness (skip rate) and result quality
(recall vs the exact oracle) alongside the timing split; the summary adds
aggregate skip/recall plus p50/p95 per-batch latency.

  PYTHONPATH=src python -m repro.launch.serve --dataset glove --n 4000 \
      --eps 0.45 --tau 5 --batches 4 --batch-size 256 --verify lsh
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import JoinPlan
from repro.data import load_dataset


def batch_stats(b: int, res, true_counts: np.ndarray,
                delta_frac: float | None = None) -> dict:
    """One report line for a served batch: filter skip rate, verification
    recall vs the exact oracle, probe placement + the verify index's
    build-time candidate-loss budget (LSH bucket-capacity overflow,
    DESIGN.md §11), the delta occupancy at submit time when a mutation
    trace is being replayed (DESIGN.md §13), and the filter/search
    timing split."""
    out = {
        "batch": b,
        "queries": int(res.n_queries),
        "searched": int(res.n_searched),
        "skipped_frac": 1.0 - res.n_searched / max(res.n_queries, 1),
        "recall": res.recall_vs(true_counts),
        "verify": res.meta.get("verify", "exact"),
        "probe": res.meta.get("probe"),
        "overflow_frac": res.meta.get("overflow_frac"),
        "t_filter_ms": res.t_filter * 1e3,
        "t_search_ms": res.t_search * 1e3,
    }
    if delta_frac is not None:
        out["delta_frac"] = float(delta_frac)
    return out


def summarize(stats: list[dict], build_s: float) -> dict:
    """Aggregate the per-batch lines: mean skip rate / recall, served-query
    throughput proxy, and p50/p95 per-batch latency."""
    if not stats:
        return {"build_s": build_s, "batches": 0}
    lat = np.asarray([s["t_filter_ms"] + s["t_search_ms"] for s in stats])
    return {
        "build_s": build_s,
        "batches": len(stats),
        "mean_skipped": float(np.mean([s["skipped_frac"] for s in stats])),
        "mean_recall": float(np.mean([s["recall"] for s in stats])),
        "mean_t_ms": float(lat.mean()),
        "p50_t_ms": float(np.percentile(lat, 50)),
        "p95_t_ms": float(np.percentile(lat, 95)),
        "verify": stats[0]["verify"],
    }


def load_trace(path: str) -> dict[int, list[dict]]:
    """Parse a JSONL mutation trace into {batch index: [ops]}: each line is
    `{"before_batch": k, "op": "insert"|"delete"|"compact", "n": ...,
    "seed": ...}` — the ops run right before batch k is submitted."""
    by_batch: dict[int, list[dict]] = {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            op = json.loads(line)
            by_batch.setdefault(int(op.get("before_batch", 0)), []).append(op)
    return by_batch


def apply_ops(plan: JoinPlan, ops, live: dict, dim: int) -> None:
    """Replay trace ops against a mutable plan, mirroring them into `live`
    (id -> row), the host shadow of the logical set that the recall
    oracle is computed from. Inserts draw seeded unit rows; deletes draw
    seeded ids from the live set (never the last row)."""
    for op in ops:
        kind = op["op"]
        rng = np.random.default_rng(int(op.get("seed", 0)))
        if kind == "insert":
            rows = rng.normal(size=(int(op["n"]), dim)).astype(np.float32)
            rows /= np.maximum(
                np.linalg.norm(rows, axis=1, keepdims=True), 1e-12)
            ids = plan.insert(rows)
            live.update(zip(map(int, ids), rows))
        elif kind == "delete":
            pool = np.fromiter(live, dtype=np.int64)
            ids = rng.choice(pool, size=min(int(op["n"]), len(pool) - 1),
                             replace=False)
            plan.delete(ids)
            for i in ids:
                live.pop(int(i))
        elif kind == "compact":
            plan.compact()
        else:
            raise ValueError(f"mutate-trace: unknown op {kind!r}; expected "
                             "'insert', 'delete', or 'compact'")


def build_plan(args, R, metric: str) -> JoinPlan:
    """Compile the CLI flags into a built `JoinPlan` (filter fit + engine +
    verifier index + probe tables all constructed here, so their one-time
    cost lands in build_s, not in batch 0's reported latency). `--topology
    ring` shards R over `--r-shards` devices (DESIGN.md §10); `--probe
    device` pins the verify index's probe tables on the mesh too
    (DESIGN.md §11) — the resolved placement, including per-device R and
    probe-table bytes, lands in the printed plan line."""
    plan = (JoinPlan(R, metric)
            .filter("xling", tau=args.tau, xdt="fpr",
                    estimator=args.estimator, epochs=args.epochs)
            .search("naive")
            .verify(args.verify)
            .on(backend="jnp", cache_key=(args.dataset, args.n),
                topology=args.topology, r_shards=args.r_shards,
                probe=args.probe))
    if args.mutate_trace:
        plan = plan.mutable()   # unlock insert/delete/compact (§13)
    return plan.build()


def main():
    """CLI entry point: compile the flags into a JoinPlan, stream query
    batches through the async engine pipeline, and print the plan summary,
    per-batch lines, and aggregate JSON."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="glove")
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--eps", type=float, default=0.45)
    ap.add_argument("--tau", type=int, default=5)
    ap.add_argument("--batches", type=int, default=4)
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--estimator", default="nn")
    ap.add_argument("--epochs", type=int, default=10)
    ap.add_argument("--verify", default="exact",
                    choices=("exact", "lsh", "ivfpq"),
                    help="verification backend (DESIGN.md §5)")
    ap.add_argument("--depth", type=int, default=2,
                    help="async in-flight queue bound (0 ~= synchronous)")
    ap.add_argument("--topology", default=None,
                    choices=("replicated", "ring"),
                    help="where R lives on the mesh (DESIGN.md §10): "
                         "replicated (default) or ring (R sharded over "
                         "--r-shards devices)")
    ap.add_argument("--r-shards", type=int, default=None,
                    help="ring topology: number of R shards (the mesh's "
                         "r-axis size)")
    ap.add_argument("--probe", default="auto",
                    choices=("auto", "device", "host"),
                    help="where the approximate verify route's index "
                         "probe runs (DESIGN.md §11): auto = on device "
                         "whenever the searcher supports it")
    ap.add_argument("--mutate-trace", default=None, metavar="PATH",
                    help="JSONL mutation trace replayed against the "
                         "stream (DESIGN.md §13): each line "
                         "{'before_batch': k, 'op': 'insert'|'delete'|"
                         "'compact', 'n': ..., 'seed': ...}; makes the "
                         "plan mutable and computes each batch's recall "
                         "oracle against the logical set at submit time")
    args = ap.parse_args()

    R, S, spec = load_dataset(args.dataset, n=args.n)
    t0 = time.time()
    plan = build_plan(args, R, spec.metric)
    build_s = time.time() - t0
    print(json.dumps({"plan": plan.describe()}, default=str))
    naive = plan.base     # shares the plan engine's device-resident R

    batches = [q for b in range(args.batches)
               if len(q := S[b * args.batch_size:(b + 1) * args.batch_size])]
    stats = []
    if args.mutate_trace is None:
        # exact-oracle counts for the recall column, computed BEFORE
        # streaming so the measurement doesn't interleave device programs
        # with the pipeline and pollute the reported p50/p95 latencies
        truths = [naive.query_counts(q, args.eps) for q in batches]
        dfracs: list[float | None] = [None] * len(batches)
        feed = iter(batches)
    else:
        # under a mutation trace the oracle is per-batch: ops run right
        # before a batch is submitted, and its truth is the brute-force
        # count over the logical set AT THAT MOMENT (the engine snapshots
        # the same world per batch — DESIGN.md §13)
        from repro.kernels import ref
        trace = load_trace(args.mutate_trace)
        live = {i: R[i] for i in range(len(R))}
        truths, dfracs = [], []

        def mutating_feed():
            for k, q in enumerate(batches):
                apply_ops(plan, trace.get(k, ()), live, R.shape[1])
                world = np.stack(list(live.values()))
                truths.append(np.asarray(
                    ref.range_count(q, world, args.eps, metric=spec.metric)))
                dfracs.append(float(plan.engine.delta_frac))
                yield q
        feed = mutating_feed()
    # the async engine streaming path: R + estimator stay device-resident,
    # compiled programs are reused (bucketed shapes), and batch k+1
    # dispatches while batch k's verification results transfer back
    for b, res in enumerate(plan.stream(feed, args.eps,
                                        depth=args.depth)):
        stats.append(batch_stats(b, res, truths[b], dfracs[b]))
        print(json.dumps(stats[-1]))

    print(json.dumps({"summary": summarize(stats, build_s)}))


if __name__ == "__main__":
    main()
