"""ArchConfig: one dataclass describing every assigned architecture, plus
the shape cells (train_4k / prefill_32k / decode_32k / long_500k)."""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional

import jax.numpy as jnp


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                # 0 -> d_model // n_heads
    # attention
    attn_kind: str = "gqa"         # gqa | mla | none
    window: int = 0                # >0 -> sliding-window attention
    rope_theta: float = 1e4
    # hybrid (jamba): within each block of `hybrid_period` layers, the layer
    # at index `attn_position` is attention, the rest are mamba.
    hybrid_period: int = 0
    attn_position: int = 0
    # moe
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1             # MoE replaces FFN every k-th layer
    dense_residual_ff: int = 0     # arctic: parallel dense FFN width
    capacity_factor: float = 1.25
    # mla
    q_lora: int = 0
    kv_lora: int = 0
    d_nope: int = 0
    d_rope: int = 0
    d_v: int = 0
    # ssm
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    # enc-dec / frontends
    enc_layers: int = 0
    frontend: str = "none"         # none | audio_stub | vision_stub
    n_patches: int = 0             # vlm: stub patch embeddings prepended
    cross_len: int = 0             # encdec decode: encoder context length
    # numerics / structure
    mlp_kind: str = "swiglu"       # swiglu | gelu
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    param_dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    attn_chunk: int = 512
    attn_impl: str = "xla"         # "pallas" = fused TPU kernel (serving fwd)
    moe_group: int = 1024
    # train-time gradient-accumulation microbatches (activation peak ~ 1/k)
    grad_accum: int = 1
    # decode-time KV sequence sharding factor (model-axis shards)
    kv_shards: int = 1

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // max(self.n_heads, 1))

    @property
    def dtype(self):
        return jnp.bfloat16 if self.param_dtype == "bfloat16" else jnp.float32

    def scaled(self, **kw) -> "ArchConfig":
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

# long_500k is decode with a 500k-token context: run only for sub-quadratic
# context handling (SSM state / hybrid / bounded-window SWA). Pure
# full-attention archs are skipped per the assignment (see DESIGN.md §4).
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def supports_cell(cfg: ArchConfig, cell: ShapeCell) -> tuple[bool, str]:
    if cell.name == "long_500k":
        if cfg.family in SUBQUADRATIC_FAMILIES or cfg.window > 0:
            return True, ""
        return False, "full-attention arch: 500k dense KV cache is the quadratic regime (skip per assignment)"
    return True, ""
