# llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
# vocab=64000; anyres tiling -> patch-embedding STUB (input_specs provides
# precomputed patch embeddings). [hf:llava-hf/llava-v1.6; unverified]
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=20480,
    vocab=64000, frontend="vision_stub", n_patches=576, rope_theta=5e6,
    kv_shards=16, grad_accum=16,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=128, vocab=256, n_patches=8,
                      param_dtype="float32", kv_shards=1, attn_chunk=32)
