# tinyllama-1.1b [dense]: 22L d_model=2048 32H (GQA kv=4) d_ff=5632
# vocab=32000; llama2-arch small. [arXiv:2401.02385; hf]
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="tinyllama-1.1b", family="dense",
    n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4, d_ff=5632,
    vocab=32000, kv_shards=16, grad_accum=2,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=128, vocab=256, param_dtype="float32",
                      kv_shards=1, attn_chunk=32)
