# h2o-danube-3-4b [dense]: 24L d_model=3840 32H (GQA kv=8) d_ff=10240
# vocab=32000; llama+mistral mix with sliding-window attention.
# [arXiv:2401.16818; unverified]
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8, d_head=120,
    d_ff=10240, vocab=32000, window=4096,
    kv_shards=1,  # SWA ring cache is window-bounded: replicate, shard heads
    grad_accum=4,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_head=16, d_ff=128, vocab=256, window=32,
                      param_dtype="float32", attn_chunk=16)
