# whisper-base [audio]: 6L d_model=512 8H (kv=8) d_ff=2048 vocab=51865
# enc-dec; conv frontend is a STUB (input_specs provides precomputed frame
# embeddings per the assignment). [arXiv:2212.04356; unverified]
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", family="audio",
    n_layers=6, enc_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab=51865, mlp_kind="gelu", attn_kind="gqa",
    frontend="audio_stub", cross_len=1500, rope_theta=1e4,
    kv_shards=16, grad_accum=4,
)

SMOKE = CONFIG.scaled(n_layers=2, enc_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=4, d_ff=128, vocab=256, cross_len=32,
                      param_dtype="float32", kv_shards=1, attn_chunk=32)
