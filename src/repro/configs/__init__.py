"""Config registry: --arch <id> resolves here. Each module has CONFIG (the
exact assigned configuration) and SMOKE (a reduced same-family config for
CPU smoke tests)."""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "whisper_base",
    "jamba_1_5_large_398b",
    "llava_next_34b",
    "h2o_danube_3_4b",
    "tinyllama_1_1b",
    "minicpm3_4b",
    "granite_34b",
    "mamba2_780m",
    "arctic_480b",
    "dbrx_132b",
]

_ALIASES = {m.replace("_", "-"): m for m in ARCH_IDS}


def _module(arch: str):
    key = arch.replace("-", "_")
    if key not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; have {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{key}")


def get_config(arch: str, smoke: bool = False):
    mod = _module(arch)
    return mod.SMOKE if smoke else mod.CONFIG


def all_configs(smoke: bool = False):
    return {a: get_config(a, smoke) for a in ARCH_IDS}
