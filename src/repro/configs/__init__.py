"""Config registry: --arch <id> resolves here. Each module has CONFIG (the
exact assigned configuration) and SMOKE (a reduced same-family config for
CPU smoke tests).

The registry once carried ten seed-noise LLM configs unrelated to the
Xling join stack; they were pruned — what remains is the embedding
backbone used by the serving/runtime tests (`tinyllama_1_1b`), the shared
`base.py` dataclasses, and the paper workload (`xling_paper.py`)."""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "tinyllama_1_1b",
]

_ALIASES = {m.replace("_", "-"): m for m in ARCH_IDS}


def _module(arch: str):
    key = arch.replace("-", "_")
    if key not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; have {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{key}")


def get_config(arch: str, smoke: bool = False):
    mod = _module(arch)
    return mod.SMOKE if smoke else mod.CONFIG


def all_configs(smoke: bool = False):
    return {a: get_config(a, smoke) for a in ARCH_IDS}
