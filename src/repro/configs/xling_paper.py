# The paper's OWN workload: Xling-filtered similarity join over an
# embedding corpus. Used by launch/serve.py and the paper-workload dry-run
# cells (filter_step / join_step on the production mesh).
from dataclasses import dataclass


@dataclass(frozen=True)
class JoinWorkload:
    name: str = "xling-join"
    dim: int = 300                    # embedding dimensionality (FastText-like)
    n_index: int = 1_000_000          # |R| at production scale
    query_batch: int = 65536          # queries per join step (global)
    m: int = 100                      # eps-grid size for target building
    metric: str = "cosine"
    estimator_widths: tuple = (512, 512, 256, 128)


CONFIG = JoinWorkload()
SMOKE = JoinWorkload(n_index=4096, query_batch=512, m=16)
