# minicpm3-4b [dense]: 62L d_model=2560 40H (kv=40) d_ff=6400 vocab=73448;
# MLA (multi-head latent attention): q_lora=768, kv_lora=256, rope dim 32,
# nope dim 64, v dim 64 — the cache holds only the latent + rope key.
# [hf:openbmb/MiniCPM3-4B; hf]
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm3-4b", family="dense",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40, d_ff=6400,
    vocab=73448, attn_kind="mla", q_lora=768, kv_lora=256,
    d_nope=64, d_rope=32, d_v=64, d_head=96, kv_shards=16, grad_accum=8,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                      d_ff=128, vocab=256, q_lora=32, kv_lora=16,
                      d_nope=16, d_rope=8, d_v=16, d_head=24,
                      param_dtype="float32", kv_shards=1, attn_chunk=32)
