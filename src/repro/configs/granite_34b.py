# granite-34b [dense]: 88L d_model=6144 48H (MQA kv=1) d_ff=24576
# vocab=49152; llama-arch code model, gpt-bigcode-style plain-GELU MLP
# (SwiGLU at ff=24576 would overshoot 34B params). [arXiv:2405.04324; hf]
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1, d_head=128,
    d_ff=24576, vocab=49152, mlp_kind="gelu",
    kv_shards=16,  # MQA: kv heads cannot shard -> shard the cache seq dim
    grad_accum=16,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
                      d_head=16, d_ff=128, vocab=256, param_dtype="float32",
                      kv_shards=1, attn_chunk=32)
