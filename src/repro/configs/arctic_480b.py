# arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864 (per expert)
# vocab=32000, MoE 128e top-2 PLUS a parallel dense residual FFN.
# [hf:Snowflake/snowflake-arctic-base; hf]
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=4864,
    vocab=32000, n_experts=128, top_k=2, moe_every=1,
    dense_residual_ff=9728, kv_shards=16, grad_accum=16,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=32, vocab=256, n_experts=8, top_k=2,
                      dense_residual_ff=64, param_dtype="float32",
                      kv_shards=1, attn_chunk=32, moe_group=64,
                      capacity_factor=8.0)
