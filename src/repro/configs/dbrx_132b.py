# dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352,
# MoE 16e top-4 (fine-grained). [hf:databricks/dbrx-base; unverified]
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=10752,
    vocab=100352, n_experts=16, top_k=4, moe_every=1, kv_shards=16, grad_accum=8,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                      d_ff=64, vocab=256, n_experts=4, top_k=2,
                      param_dtype="float32", kv_shards=1, attn_chunk=32,
                      moe_group=64, capacity_factor=8.0)
