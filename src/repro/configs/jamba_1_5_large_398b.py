# jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8) d_ff=24576
# vocab=65536, MoE 16e top-2; Mamba+attn 1:7 interleave (1 attention layer
# per 8-layer block), MoE every 2nd layer. [arXiv:2403.19887; hf]
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=24576,
    vocab=65536, n_experts=16, top_k=2, moe_every=2,
    hybrid_period=8, attn_position=3, ssm_state=128, ssm_head_dim=64,
    kv_shards=16, grad_accum=16,
)

SMOKE = CONFIG.scaled(n_layers=8, d_model=128, n_heads=8, n_kv_heads=2,
                      d_ff=256, vocab=512, n_experts=4, top_k=2,
                      ssm_state=32, param_dtype="float32", kv_shards=1,
                      attn_chunk=32, moe_group=64, capacity_factor=8.0)
