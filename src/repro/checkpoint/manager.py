"""Fault-tolerant checkpointing: atomic, keep-k, optional async writer.

Layout: <dir>/step_<N>/state.npz + meta.json, written to a tmp dir and
renamed (atomic on POSIX) so a crash mid-write never corrupts the latest
checkpoint. `save(..., blocking=False)` hands the (host-copied) state to a
background writer thread so the train loop overlaps checkpoint I/O with the
next steps — the standard multi-thousand-node pattern (per-host shards +
async write); on one host the shard set is just 1.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten_state(state) -> tuple[dict, Any]:
    leaves, treedef = jax.tree.flatten(state)
    blob = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    return blob, treedef


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._q: queue.Queue = queue.Queue()
        self._err: Optional[Exception] = None
        self._thread = None
        if async_write:
            self._thread = threading.Thread(target=self._writer, daemon=True)
            self._thread.start()

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Any, *, blocking: bool = True,
             meta: Optional[dict] = None) -> None:
        if self._err:
            err, self._err = self._err, None
            raise RuntimeError(f"async checkpoint writer failed: {err}")
        # device -> host copy happens here (so the caller can donate buffers)
        blob, _ = _flatten_state(state)
        item = (step, blob, dict(meta or {}))
        if blocking or self._thread is None:
            self._write(*item)
        else:
            self._q.put(item)

    def _writer(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            try:
                self._write(*item)
            except Exception as e:  # surfaced on the next save()
                self._err = e

    def _write(self, step: int, blob: dict, meta: dict):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "state.npz"), **blob)
        meta = {"step": step, "time": time.time(), **meta}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, state_like: Any, step: Optional[int] = None,
                *, shardings: Any = None) -> tuple[Any, dict]:
        """Restore into the structure of `state_like`. If `shardings` is
        given, leaves are device_put with the (possibly NEW, post-elastic-
        rescale) shardings — this IS the checkpoint resharding path."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        with np.load(os.path.join(path, "state.npz")) as z:
            leaves_like, treedef = jax.tree.flatten(state_like)
            leaves = [z[f"leaf_{i}"] for i in range(len(leaves_like))]
        if shardings is not None:
            sh_leaves = jax.tree.flatten(shardings)[0]
            leaves = [jax.device_put(x, s) for x, s in zip(leaves, sh_leaves)]
        else:
            leaves = [jax.numpy.asarray(x) for x in leaves]
        state = jax.tree.unflatten(treedef, leaves)
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        return state, meta

    def wait(self):
        """Drain pending async writes (call before shutdown)."""
        if self._thread is not None:
            self._q.join() if False else None
            while not self._q.empty():
                time.sleep(0.01)
            # one more grace period for the in-flight item
            time.sleep(0.05)
        if self._err:
            err, self._err = self._err, None
            raise RuntimeError(f"async checkpoint writer failed: {err}")
