"""Closed-form ridge regression baseline (stands in for the paper's non-deep
XGB/LGBM/SVR baselines, which have no faithful JAX equivalent — recorded as
an assumption change in DESIGN.md §3). Features: [point, eps, eps^2, eps^3].
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.utils import memoize_device_fn


class LinearEstimator:
    name = "linear"

    def __init__(self, din: int, *, l2: float = 1e-3, log_target: bool = True, **_):
        self.l2 = l2
        self.log_target = log_target
        self.w = None

    def _featurize(self, X, xp=np):
        """[point, eps, eps^2, eps^3, 1] features; xp=jnp makes it traceable
        (single source for the host AND device predict paths)."""
        eps = X[:, -1:]
        return xp.concatenate([X, eps ** 2, eps ** 3,
                               xp.ones((X.shape[0], 1), np.float32)], axis=1)

    def _transform(self, y):
        return np.log1p(y.astype(np.float32)) if self.log_target else y.astype(np.float32)

    def fit(self, X: np.ndarray, y: np.ndarray, weights=None):
        F = self._featurize(X).astype(np.float64)
        t = self._transform(y).astype(np.float64)
        if weights is not None:
            F = F * weights[:, None]
            t = t * weights
        A = F.T @ F + self.l2 * np.eye(F.shape[1])
        self.w = np.linalg.solve(A, F.T @ t).astype(np.float32)
        resid = F.astype(np.float32) @ self.w - t.astype(np.float32)
        return float(np.mean(resid ** 2))

    def predict(self, X, *, backend: str = "auto") -> np.ndarray:
        raw = self._featurize(np.asarray(X, np.float32)) @ self.w
        return np.asarray(jnp.expm1(raw) if self.log_target else raw, np.float32)

    def device_predict_fn(self):
        """(params, fn) for the engine's fused filter program (fn memoized
        per estimator so the engine's program cache hits across calls)."""
        def build():
            log = self.log_target

            def fn(w, X):
                raw = self._featurize(X, xp=jnp) @ w
                return jnp.expm1(raw) if log else raw
            return fn
        return jnp.asarray(self.w), memoize_device_fn(self, self.log_target, build)

    def state_dict(self) -> dict:
        return {"kind": np.asarray("linear"), "w": self.w,
                "log_target": np.asarray(self.log_target)}

    def load_state_dict(self, d: dict):
        self.w = np.asarray(d["w"])
        self.log_target = bool(d["log_target"])
