"""Minibatch trainer shared by all JAX regressors (no optax dependency)."""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adam


def fit_regressor(params, apply_fn: Callable, X: np.ndarray, y: np.ndarray,
                  *, weights: Optional[np.ndarray] = None, lr: float = 1e-3,
                  epochs: int = 30, batch_size: int = 512, seed: int = 0,
                  log_every: int = 0) -> tuple:
    """MSE fit of apply_fn(params, X) -> y. Returns (params, last_loss).

    `weights` (0/1 or soft) implements the masked-subset training the RMI
    stages need without ragged batches.
    """
    n = X.shape[0]
    batch_size = min(batch_size, n)
    if weights is None:
        weights = np.ones((n,), np.float32)
    opt = adam(lr=lr)
    state = opt.init(params)

    @jax.jit
    def step(params, state, xb, yb, wb):
        def loss_fn(p):
            pred = apply_fn(p, xb)
            return jnp.sum(wb * (pred - yb) ** 2) / jnp.maximum(jnp.sum(wb), 1.0)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, state = opt.apply(params, state, grads)
        return params, state, loss

    rng = np.random.default_rng(seed)
    nb = max(1, n // batch_size)
    loss = np.inf
    Xj, yj, wj = jnp.asarray(X), jnp.asarray(y), jnp.asarray(weights)
    for ep in range(epochs):
        perm = jnp.asarray(rng.permutation(n))
        for b in range(nb):
            idx = jax.lax.dynamic_slice_in_dim(perm, b * batch_size, batch_size)
            params, state, loss = step(params, state, Xj[idx], yj[idx], wj[idx])
        if log_every and (ep + 1) % log_every == 0:
            print(f"  epoch {ep+1}/{epochs} loss={float(loss):.5f}")
    return params, float(loss)
