"""Recursive Model Index estimator (Kraska et al.) — the paper's main model.

§VI-A configuration: three stages of 1 / 2 / 4 fully-connected networks,
each sub-model the 512/512/256/128 MLP. Training is the classic greedy
stage-by-stage procedure: stage k's prediction routes each tuple to a stage
k+1 child; children train on their routed subset (implemented as masked
losses so batches stay static for XLA).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.mlp import PAPER_WIDTHS, apply_mlp, init_mlp
from repro.models.train import fit_regressor
from repro.utils import memoize_device_fn


class RMIEstimator:
    name = "rmi"

    def __init__(self, din: int, stage_sizes=(1, 2, 4), widths=PAPER_WIDTHS, *,
                 lr=1e-3, epochs=30, batch_size=512, seed=0, log_target=True):
        self.din = din
        self.stage_sizes = tuple(stage_sizes)
        self.widths = tuple(widths)
        self.lr, self.epochs, self.batch_size = lr, epochs, batch_size
        self.seed, self.log_target = seed, log_target
        key = jax.random.key(seed)
        self.stages = []
        for si, n_models in enumerate(self.stage_sizes):
            key, sub = jax.random.split(key)
            ks = jax.random.split(sub, n_models)
            self.stages.append([init_mlp(k, din, widths) for k in ks])
        self._ylo, self._yhi = 0.0, 1.0
        self._jit_route = jax.jit(self._routed_predict)

    # -- routing ------------------------------------------------------------
    def _route_ids(self, preds: jax.Array, n_children: int) -> jax.Array:
        """Map a (transformed) prediction to a child index by target range."""
        z = (preds - self._ylo) / max(self._yhi - self._ylo, 1e-9)
        return jnp.clip((z * n_children).astype(jnp.int32), 0, n_children - 1)

    def _routed_predict(self, stages_params, X):
        pred = apply_mlp(stages_params[0][0], X)
        for si in range(1, len(self.stage_sizes)):
            kids = stages_params[si]
            route = self._route_ids(pred, len(kids))
            all_preds = jnp.stack([apply_mlp(p, X) for p in kids], axis=1)
            pred = jnp.take_along_axis(all_preds, route[:, None], axis=1)[:, 0]
        return pred

    # -- fit/predict ----------------------------------------------------------
    def _transform(self, y):
        return np.log1p(y.astype(np.float32)) if self.log_target else y.astype(np.float32)

    def fit(self, X: np.ndarray, y: np.ndarray, weights=None):
        yt = self._transform(y)
        self._ylo, self._yhi = float(yt.min()), float(yt.max())
        base_w = np.ones((len(X),), np.float32) if weights is None else weights

        # stage 0: single root model on everything
        self.stages[0][0], loss = fit_regressor(
            self.stages[0][0], apply_mlp, X, yt, weights=base_w, lr=self.lr,
            epochs=self.epochs, batch_size=self.batch_size, seed=self.seed)

        pred = np.asarray(apply_mlp(self.stages[0][0], jnp.asarray(X)))
        for si in range(1, len(self.stage_sizes)):
            kids = self.stages[si]
            route = np.asarray(self._route_ids(jnp.asarray(pred), len(kids)))
            new_pred = np.zeros_like(pred)
            for ci, child in enumerate(kids):
                mask = (route == ci).astype(np.float32) * base_w
                if mask.sum() < 2:  # child got (almost) nothing routed
                    continue
                kids[ci], _ = fit_regressor(
                    child, apply_mlp, X, yt, weights=mask, lr=self.lr,
                    epochs=self.epochs, batch_size=self.batch_size,
                    seed=self.seed + 17 * si + ci)
                cp = np.asarray(apply_mlp(kids[ci], jnp.asarray(X)))
                new_pred = np.where(route == ci, cp, new_pred)
            pred = new_pred
        return loss

    def predict(self, X, *, backend: str = "auto") -> np.ndarray:
        stages_params = [list(s) for s in self.stages]
        raw = self._jit_route(stages_params, jnp.asarray(X))
        out = jnp.expm1(raw) if self.log_target else raw
        return np.asarray(out, np.float32)

    def device_predict_fn(self):
        """(params, fn) for the engine's fused filter program; the routing
        bounds (_ylo/_yhi) are baked in at trace time (post-fit), so fn is
        memoized per (log_target, ylo, yhi) — a refit invalidates it."""
        def build():
            log = self.log_target

            def fn(params, X):
                raw = self._routed_predict(params, X)
                return jnp.expm1(raw) if log else raw
            return fn
        key = (self.log_target, self._ylo, self._yhi)
        return [list(s) for s in self.stages], memoize_device_fn(self, key, build)

    # -- persistence ----------------------------------------------------------
    def state_dict(self) -> dict:
        out = {"kind": np.asarray("rmi"), "din": np.asarray(self.din),
               "stage_sizes": np.asarray(self.stage_sizes),
               "ylo": np.asarray(self._ylo), "yhi": np.asarray(self._yhi),
               "log_target": np.asarray(self.log_target)}
        for si, stage in enumerate(self.stages):
            for ci, params in enumerate(stage):
                for li, (w, b) in enumerate(params):
                    out[f"s{si}c{ci}w{li}"] = np.asarray(w)
                    out[f"s{si}c{ci}b{li}"] = np.asarray(b)
        return out

    def load_state_dict(self, d: dict):
        self._ylo, self._yhi = float(d["ylo"]), float(d["yhi"])
        self.log_target = bool(d["log_target"])
        n_layers = len(self.widths) + 1
        for si, stage in enumerate(self.stages):
            for ci in range(len(stage)):
                stage[ci] = tuple(
                    (jnp.asarray(d[f"s{si}c{ci}w{li}"]), jnp.asarray(d[f"s{si}c{ci}b{li}"]))
                    for li in range(n_layers))
