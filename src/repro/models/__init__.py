"""Estimator registry — Xling is generic over anything satisfying:

    fit(X [n, d+1], y [n]) -> loss
    predict(X [n, d+1]) -> counts [n] (float)
    state_dict() / load_state_dict(d)

where X rows are (point ++ eps). Register new estimators here and every
Xling feature (ATCS, XDT, XJoin, plugins) works with them unchanged — this
is the paper's "any regression model can be encapsulated" claim, enforced
by construction.
"""
from __future__ import annotations

from repro.models.linear import LinearEstimator
from repro.models.mlp import MLPEstimator
from repro.models.rmi import RMIEstimator
from repro.models.selnet import SelNetEstimator

ESTIMATORS = {
    "nn": MLPEstimator,
    "rmi": RMIEstimator,
    "selnet": SelNetEstimator,
    "linear": LinearEstimator,
}


def make_estimator(name: str, din: int, **kwargs):
    try:
        cls = ESTIMATORS[name]
    except KeyError:
        raise KeyError(f"unknown estimator {name!r}; have {sorted(ESTIMATORS)}") from None
    return cls(din, **kwargs)


__all__ = ["ESTIMATORS", "make_estimator", "MLPEstimator", "RMIEstimator",
           "SelNetEstimator", "LinearEstimator"]
