"""The paper's "NN" estimator: a plain ReLU MLP regressor.

Configuration follows §VI-A: 4 hidden layers of width 512/512/256/128 (one
RMI sub-model). Inference can run through the fused Pallas kernel
(kernels/fused_mlp.py) — `predict` selects backend automatically.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.models.train import fit_regressor
from repro.utils import memoize_device_fn

PAPER_WIDTHS = (512, 512, 256, 128)


def init_mlp(key, din: int, widths=PAPER_WIDTHS, dtype=jnp.float32):
    params = []
    dims = (din,) + tuple(widths) + (1,)
    keys = jax.random.split(key, len(dims) - 1)
    for k, (a, b) in zip(keys, zip(dims[:-1], dims[1:])):
        w = jax.random.normal(k, (a, b), dtype) * jnp.sqrt(2.0 / a)
        params.append((w, jnp.zeros((1, b), dtype)))
    return tuple(params)


def apply_mlp(params, x: jax.Array) -> jax.Array:
    h = x.astype(jnp.float32)
    for i, (w, b) in enumerate(params):
        h = h @ w + b
        if i < len(params) - 1:
            h = jnp.maximum(h, 0.0)
    return h[:, 0]


class MLPEstimator:
    """Estimator protocol: fit(X, y) / predict(X) in *count* space.

    Internally regresses log1p(count) — counts span 5 orders of magnitude
    and raw-scale MSE lets dense queries dominate. (Deviation from the
    paper, which does not specify target scaling; toggle log_target=False
    for the raw behavior.)
    """

    name = "nn"

    def __init__(self, din: int, widths=PAPER_WIDTHS, *, lr=1e-3, epochs=30,
                 batch_size=512, seed=0, log_target=True):
        self.din, self.widths = din, tuple(widths)
        self.lr, self.epochs, self.batch_size = lr, epochs, batch_size
        self.seed, self.log_target = seed, log_target
        self.params = init_mlp(jax.random.key(seed), din, widths)
        self._jit_apply = jax.jit(apply_mlp)

    def _transform(self, y):
        return np.log1p(y.astype(np.float32)) if self.log_target else y.astype(np.float32)

    def _untransform(self, p):
        return jnp.expm1(p) if self.log_target else p

    def fit(self, X: np.ndarray, y: np.ndarray, weights=None):
        self.params, loss = fit_regressor(
            self.params, apply_mlp, X, self._transform(y), weights=weights,
            lr=self.lr, epochs=self.epochs, batch_size=self.batch_size,
            seed=self.seed)
        return loss

    def predict(self, X, *, backend: str = "auto") -> np.ndarray:
        if backend in ("pallas",):
            raw = ops.mlp_forward(self.params, jnp.asarray(X), backend=backend)
        else:
            raw = self._jit_apply(self.params, jnp.asarray(X))
        return np.asarray(self._untransform(raw), np.float32)

    def device_predict_fn(self):
        """(params, fn) for the engine's fused filter program: fn(params, X)
        is traceable and returns predicted counts (count space, f32 [n]).
        fn is memoized per estimator so the engine's program cache (keyed by
        fn identity) hits across calls — params stay a call-time argument."""
        def build():
            log = self.log_target

            def fn(params, X):
                raw = apply_mlp(params, X)
                return jnp.expm1(raw) if log else raw
            return fn
        return self.params, memoize_device_fn(self, self.log_target, build)

    # persistence -----------------------------------------------------------
    def state_dict(self) -> dict:
        out = {"kind": np.asarray("nn"), "din": np.asarray(self.din),
               "widths": np.asarray(self.widths), "log_target": np.asarray(self.log_target)}
        for i, (w, b) in enumerate(self.params):
            out[f"w{i}"], out[f"b{i}"] = np.asarray(w), np.asarray(b)
        return out

    def load_state_dict(self, d: dict):
        import re
        n = len([k for k in d if re.fullmatch(r"w\d+", k)])
        self.params = tuple((jnp.asarray(d[f"w{i}"]), jnp.asarray(d[f"b{i}"]))
                            for i in range(n))
        self.log_target = bool(d["log_target"])
