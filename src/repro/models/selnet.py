"""SelNet-lite: query-dependent piecewise-linear selectivity curve.

Faithful to the *mechanism* of SelNet (Wang et al. 2021): the network maps
the query point to a monotone piecewise-linear eps->cardinality curve
(softplus increments cumsum'd over fixed knots); the prediction interpolates
that curve at the queried eps. Monotonicity in eps holds by construction —
a property the test-suite checks (the true cardinality curve is monotone,
Eq. 2's interpolation argument relies on it).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.train import fit_regressor
from repro.utils import memoize_device_fn


def _apply_trunk(params, x):
    h = x.astype(jnp.float32)
    for i, (w, b) in enumerate(params):
        h = h @ w + b
        if i < len(params) - 1:
            h = jnp.maximum(h, 0.0)
    return h  # [n, K+1]


class SelNetEstimator:
    name = "selnet"

    def __init__(self, din: int, *, knots: int = 16, widths=(256, 256), lr=1e-3,
                 epochs=30, batch_size=512, seed=0, log_target=True,
                 eps_lo: float = 0.0, eps_hi: float = 2.0):
        # input is the POINT only (din includes the appended eps column which
        # we strip); curve knots cover the metric's eps range.
        self.d_point = din - 1
        self.knots = knots
        self.eps_knots = jnp.linspace(eps_lo, eps_hi, knots)
        self.lr, self.epochs, self.batch_size, self.seed = lr, epochs, batch_size, seed
        self.log_target = log_target
        key = jax.random.key(seed)
        # trunk outputs K values: base + K-1 softplus increments
        dims = (self.d_point,) + tuple(widths) + (knots,)
        keys = jax.random.split(key, len(dims) - 1)
        self.params = tuple(
            (jax.random.normal(k, (a, b)) * jnp.sqrt(2.0 / a), jnp.zeros((1, b)))
            for k, (a, b) in zip(keys, zip(dims[:-1], dims[1:])))
        self._jit_apply = jax.jit(self._apply)

    def _apply(self, params, X):
        pts, eps = X[:, :-1], X[:, -1]
        raw = _apply_trunk(params, pts)                     # [n, K]
        base = raw[:, 0]
        incs = jax.nn.softplus(raw[:, 1:])                  # >= 0
        curve = jnp.concatenate([base[:, None],
                                 base[:, None] + jnp.cumsum(incs, axis=1)], axis=1)
        # linear interp of the monotone curve at each row's eps
        return jax.vmap(lambda c, e: jnp.interp(e, self.eps_knots, c))(curve, eps)

    def _transform(self, y):
        return np.log1p(y.astype(np.float32)) if self.log_target else y.astype(np.float32)

    def fit(self, X: np.ndarray, y: np.ndarray, weights=None):
        self.params, loss = fit_regressor(
            self.params, self._apply, X, self._transform(y), weights=weights,
            lr=self.lr, epochs=self.epochs, batch_size=self.batch_size, seed=self.seed)
        return loss

    def predict(self, X, *, backend: str = "auto") -> np.ndarray:
        raw = self._jit_apply(self.params, jnp.asarray(X))
        out = jnp.expm1(raw) if self.log_target else raw
        return np.asarray(out, np.float32)

    def device_predict_fn(self):
        """(params, fn) for the engine's fused filter program (fn memoized
        per estimator so the engine's program cache hits across calls)."""
        def build():
            log = self.log_target

            def fn(params, X):
                raw = self._apply(params, X)
                return jnp.expm1(raw) if log else raw
            return fn
        return self.params, memoize_device_fn(self, self.log_target, build)

    def state_dict(self) -> dict:
        out = {"kind": np.asarray("selnet"), "knots": np.asarray(self.knots),
               "log_target": np.asarray(self.log_target),
               "eps_knots": np.asarray(self.eps_knots)}
        for i, (w, b) in enumerate(self.params):
            out[f"w{i}"], out[f"b{i}"] = np.asarray(w), np.asarray(b)
        return out

    def load_state_dict(self, d: dict):
        import re
        n = len([k for k in d if re.fullmatch(r"w\d+", k)])
        self.params = tuple((jnp.asarray(d[f"w{i}"]), jnp.asarray(d[f"b{i}"]))
                            for i in range(n))
        self.eps_knots = jnp.asarray(d["eps_knots"])
        self.log_target = bool(d["log_target"])
