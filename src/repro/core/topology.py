"""Topology layer: where R lives on the mesh (DESIGN.md §10).

The engine (core/engine.py) used to bake one placement decision into every
device program: queries shard over the mesh's ``data`` axis, the index set
R replicates onto every device.  That caps |R| at a single device's HBM —
the opposite of the multi-host north star.  This module makes placement a
first-class, swappable layer.  A `Topology` answers four questions:

  1. how are the padded query rows sharded (`q_spec` / `q_row_quantum`),
  2. how are the padded R rows sharded (`r_spec` / `r_row_quantum`),
  3. how does the range-count sweep run over that placement
     (`hist_program`), and
  4. how does the fused compact -> verify -> scatter program run
     (`compact_program`).

Two implementations:

  * `Replicated` — the original placement.  Q shards over ``data``; every
    device sweeps its query slice against the full replicated R.  Zero
    communication per sweep; per-device R memory is all of R.
  * `RingSharded` — R row-shards over a second mesh axis (``r`` by
    default, built by `launch.mesh.make_join_mesh(data=, r=)`), so peak
    per-device R bytes drop by the r-axis size.  Q shards over BOTH axes.
    The sweep runs as a `jax.lax.ppermute` ring: at each of the
    ``r_shards`` steps every device histograms its resident R shard
    against the query block currently rotating through it and records the
    partial counts under that block's home position; after the rotation
    the partial counts are `psum`'d over ``r`` and each device keeps its
    own block's total.  The compact/verify path gathers only the
    predicted-positive candidates across R shards (replicating the small
    compacted block, or sharding it over ``data`` when it divides evenly)
    and `psum`s the per-shard counts.

Padding convention: R rows are padded to a multiple of
``r_row_quantum(block_r)`` so every shard is block-aligned with the SAME
static shape.  Padding rows are all-zero vectors, which sit at a known
distance from any unit query (cosine: exactly 1.0; l2: exactly sqrt(2)),
so instead of threading a static per-shard valid count into the kernels
(impossible — shards differ, programs are shared), the ring programs
count padded rows too and subtract the closed-form zero-row contribution
using the traced per-shard valid count (`nr_valid_shards`).  Counts stay
bit-identical to the unpadded oracle.

Topologies are tiny frozen dataclasses: hashable, so they key the
engine's module-level `lru_cache` of compiled programs (every one
registered in `engine._PROGRAM_CACHES` — xlint's jit-cache-key rule
rejects unhashable program-builder params, DESIGN.md §12), and
stateless, so one instance can serve any number of engines.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

try:                                    # moved to the stable namespace in
    from jax import shard_map           # newer JAX; experimental on 0.4.x
except ImportError:
    from jax.experimental.shard_map import shard_map

from repro.kernels import ops, ref
from repro.kernels.range_count import range_count_hist_pallas


def _shard_mapped(f, mesh, in_specs, out_specs):
    try:
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)
    except TypeError:                   # newer API dropped check_rep
        return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


def _data_size(mesh, data_axis: str) -> int:
    return int(mesh.shape.get(data_axis, 1)) if mesh is not None else 1


def _q_blocked_hist(q, r, eps, *, metric, block_q, block_r, nr_valid):
    """[n, m] histogram, scanning q in block_q tiles so the fused
    compare tensor stays O(block_q * block_r * m). q rows % block_q == 0."""
    nblk = q.shape[0] // block_q
    qb = q.reshape(nblk, block_q, q.shape[1])
    out = jax.lax.map(
        lambda x: ops.blocked_hist(x, r, eps, metric=metric,
                                   block_r=block_r, nr_valid=nr_valid), qb)
    return out.reshape(nblk * block_q, eps.shape[0])


def _per_shard_hist(backend, metric, block_q, block_r, eps_chunk, nr_valid):
    """(q, r, eps) -> int32 [q, m] per-shard sweep for one backend.

    `nr_valid` masks R rows past that global index; None means "count
    every row" (the ring topology masks via the zero-row correction
    instead, because its per-shard valid counts are traced values)."""
    if backend == "pallas":
        interpret = jax.default_backend() != "tpu"

        def shard_fn(q, r, eps):
            return range_count_hist_pallas(
                q, r, eps, metric=metric, nr_valid=nr_valid, block_q=block_q,
                block_r=block_r, eps_chunk=eps_chunk, interpret=interpret)
    elif backend == "ref":
        def shard_fn(q, r, eps):
            return ref.range_count_hist(q, r, eps, metric)
    else:
        def shard_fn(q, r, eps):
            return _q_blocked_hist(
                q, r, eps, metric=metric, block_q=block_q, block_r=block_r,
                nr_valid=r.shape[0] if nr_valid is None else nr_valid)
    return shard_fn


def _zero_row_distance(metric: str) -> jax.Array:
    """Distance of an all-zero padding row from any unit query, computed
    with the same f32 ops as the sweep kernels (bit-exact correction)."""
    if metric == "cosine":
        return jnp.asarray(1.0, jnp.float32)          # 1 - q.0
    return jnp.sqrt(jnp.asarray(2.0, jnp.float32))    # sqrt(2 - 2 q.0)


def _subtract_pad_rows(counts, eps, n_pad, metric):
    """Remove the padding rows' contribution from a per-shard histogram.

    All padding rows are identical zero vectors at `_zero_row_distance`,
    so each contributes 1 to every eps bin at or above that distance;
    `n_pad` is traced (per-shard), making this the masking mechanism that
    works under shared static-shape programs."""
    hit = (_zero_row_distance(metric)
           <= eps.astype(jnp.float32)).astype(jnp.int32)
    return counts - n_pad.astype(jnp.int32) * hit[None, :]


# ============================================================== the contract
@dataclass(frozen=True)
class Topology:
    """Placement contract for the join engine (DESIGN.md §10).

    Subclasses are stateless frozen dataclasses (hashable — they key the
    engine's module-level compiled-program caches) answering: how Q and R
    shard over the mesh, what row quanta their paddings must honor, and
    how the sweep / compact programs execute over that placement."""

    name = "abstract"

    def r_shards(self, mesh) -> int:
        """Number of R row-shards on this mesh (1 = fully replicated)."""
        return 1

    def validate(self, mesh, data_axis: str) -> None:
        """Raise ValueError when `mesh` cannot host this placement."""

    def q_spec(self, data_axis: str) -> P:
        """PartitionSpec of the padded query row axis."""
        raise NotImplementedError

    def r_spec(self) -> P:
        """PartitionSpec of the device-resident padded R rows."""
        raise NotImplementedError

    def r_row_quantum(self, block_r: int, mesh) -> int:
        """R rows are padded to a multiple of this before upload."""
        return block_r

    def q_row_quantum(self, block_q: int, mesh, data_axis: str) -> int:
        """Query rows are bucketed to a multiple of this (one full mesh
        sweep: block-aligned per-device shapes on every device)."""
        raise NotImplementedError

    def nr_valid_shards(self, nr: int, nr_padded: int, mesh):
        """int32 [r_shards] valid-row count per R shard, or None when the
        placement needs no per-shard masking (replicated)."""
        return None

    def probe_shards(self, mesh) -> int:
        """Number of ways device-probe tables shard on this mesh
        (DESIGN.md §11): 1 = replicated on every device."""
        return 1

    def probe_spec(self) -> P:
        """PartitionSpec of a probe table's leading shard axis (only
        meaningful when `probe_shards` > 1)."""
        return P()

    def delta_spec(self) -> P:
        """PartitionSpec of the dynamic-R delta shard (DESIGN.md §13):
        replicated under EVERY placement.  The delta is small by policy
        (auto-compaction bounds it at a fraction of |R|), so replicating
        it keeps the ring sweep schedule untouched — no extra ppermute
        steps; the delta adjustment is a purely local dense op on each
        device, psum-free under both topologies."""
        return P()

    def per_device_r_bytes(self, nr_padded: int, dim: int, mesh) -> int:
        """Bytes of R resident on EACH device under this placement."""
        raise NotImplementedError

    def sweep_collectives(self, r_shards: int) -> int:
        """Cross-device collectives issued by ONE sweep/probe dispatch
        under this placement at `r_shards` R shards (the planner's
        communication cost hook, DESIGN.md §16): 0 for a replicated R,
        the ring-schedule hop count for sharded placements.  Takes the
        shard count, not a mesh — the planner prices candidate
        configurations before any mesh exists."""
        return 0

    def verify_collectives(self, r_shards: int) -> int:
        """Collectives per candidate-verify dispatch at `r_shards` R
        shards: 0 when counts are device-local, 1 for the sharded
        placements' combining `psum`."""
        return 0

    def hist_program(self, mesh, data_axis, backend, metric, block_q,
                     block_r, eps_chunk, nr_valid):
        """Compiled sweep `(q, r, eps, nrv) -> int32 [n, m]` over this
        placement (cached by the engine per argument tuple)."""
        raise NotImplementedError

    def compact_program(self, mesh, data_axis, backend, metric, block_q,
                        block_r, nr_valid):
        """Compiled fused compact -> verify -> scatter program
        `(q, pos, n_pos, r, eps, nrv, *, capacity) -> int32 [n]`."""
        raise NotImplementedError

    def _compact_scaffold(self, sweep):
        """Shared compact -> verify -> scatter shell around a placement's
        `sweep(qpos, r, eps1, nrv, capacity) -> int32 [capacity]` hook:
        gather the positives into the bucketed static shape, sweep them,
        and scatter the counts back (padding lanes all add 0 onto row 0).
        One place owns the compaction/donation conventions so the
        topologies cannot diverge."""

        def prog(q, pos, n_pos, r, eps, nrv, *, capacity: int):
            idx = jnp.nonzero(pos, size=capacity, fill_value=0)[0]
            valid = jnp.arange(capacity) < n_pos
            qpos = jnp.take(q, idx, axis=0)
            eps1 = jnp.reshape(eps, (1,)).astype(jnp.float32)
            found = sweep(qpos, r, eps1, nrv, capacity)
            contrib = jnp.where(valid, found, 0).astype(jnp.int32)
            return jnp.zeros((q.shape[0],), jnp.int32).at[idx].add(contrib)

        # the padded query buffer is dead after this program — donate it on
        # TPU so the compact output can reuse its HBM (CPU donation warns)
        donate = (0,) if jax.default_backend() == "tpu" else ()
        return jax.jit(prog, static_argnames=("capacity",),
                       donate_argnums=donate)


# ================================================================ replicated
@dataclass(frozen=True)
class Replicated(Topology):
    """R replicated on every device; Q sharded over the ``data`` axis.

    The original engine placement: zero communication per sweep, every
    device holds all of (padded) R.  Right whenever R fits in one
    device's memory — it is the fastest placement at that scale."""

    name = "replicated"

    def q_spec(self, data_axis: str) -> P:
        """Queries shard over the data axis only."""
        return P(data_axis)

    def r_spec(self) -> P:
        """R is fully replicated."""
        return P()

    def q_row_quantum(self, block_q: int, mesh, data_axis: str) -> int:
        """block_q rows per data-axis device."""
        return block_q * _data_size(mesh, data_axis)

    def per_device_r_bytes(self, nr_padded: int, dim: int, mesh) -> int:
        """Every device holds the full padded R."""
        return int(nr_padded) * int(dim) * 4

    def hist_program(self, mesh, data_axis, backend, metric, block_q,
                     block_r, eps_chunk, nr_valid):
        """Per-device sweep of the local query slice vs all of R,
        shard_map'ped over ``data`` when the mesh has >1 data device."""
        shard_fn = _per_shard_hist(backend, metric, block_q, block_r,
                                   eps_chunk, nr_valid)
        if _data_size(mesh, data_axis) > 1:
            shard_fn = _shard_mapped(shard_fn, mesh,
                                     in_specs=(P(data_axis), P(), P()),
                                     out_specs=P(data_axis))
        jitted = jax.jit(shard_fn)
        return lambda q, r, eps, nrv=None: jitted(q, r, eps)

    def compact_program(self, mesh, data_axis, backend, metric, block_q,
                        block_r, nr_valid):
        """Gather positives -> single-eps sweep vs replicated R -> scatter.
        `capacity` is the bucketed static shape; `n_pos` rides along as a
        device scalar so one executable serves every bucket occupancy."""
        from jax.sharding import NamedSharding

        def sweep(qpos, r, eps1, nrv, capacity):
            if _data_size(mesh, data_axis) > 1:
                qpos = jax.lax.with_sharding_constraint(
                    qpos, NamedSharding(mesh, P(data_axis)))
            if backend == "ref":
                return ref.range_count_hist(qpos, r, eps1, metric)[:, 0]
            if capacity > block_q and capacity % block_q == 0:
                # large buckets get the same query tiling as the main sweep
                # so the compare temporaries stay O(block_q * block_r)
                return _q_blocked_hist(qpos, r, eps1, metric=metric,
                                       block_q=block_q, block_r=block_r,
                                       nr_valid=nr_valid)[:, 0]
            return ops.blocked_hist(qpos, r, eps1, metric=metric,
                                    block_r=block_r, nr_valid=nr_valid)[:, 0]

        return self._compact_scaffold(sweep)


# =============================================================== ring-sharded
@dataclass(frozen=True)
class RingSharded(Topology):
    """R row-sharded over the mesh's ``r`` axis; ppermute ring sweep.

    Per-device R memory drops by the r-axis size, so |R| scales past one
    device's HBM.  Q shards over BOTH mesh axes; each sweep runs
    ``r_shards`` ring steps (rotate the query block over ``r`` with
    `jax.lax.ppermute`, histogram it against the resident R shard) and a
    final `psum` over ``r`` combines the per-shard partial counts.  Use
    `launch.mesh.make_join_mesh(data=, r=)` to build the 2-D mesh."""

    name = "ring"
    r_axis: str = "r"
    #: overlapped sweep schedule (DESIGN.md §15): the next query block's
    #: `ppermute` is issued BEFORE the current histogram step so the hop
    #: hides behind compute, and the partial counts combine via a ring
    #: reduce-scatter (r_size - 1 hops of one [q_local, m] int32 row)
    #: instead of a [r_size, q_local, m] buffer + full psum + take.
    #: int32 addition is associative, so counts stay bit-identical to
    #: the serial formulation (`overlap=False`, kept for benchmarking).
    overlap: bool = True

    def r_shards(self, mesh) -> int:
        """Size of the mesh's ``r`` axis."""
        return int(mesh.shape[self.r_axis]) if mesh is not None else 1

    def validate(self, mesh, data_axis: str) -> None:
        """Ring placement needs a mesh carrying both the ``r`` axis and
        the data axis (`launch.mesh.make_join_mesh`)."""
        if mesh is None:
            raise ValueError(
                f"topology='ring' needs a mesh with an {self.r_axis!r} "
                "axis — build one with launch.mesh.make_join_mesh(data=, "
                "r=) or let JoinPlan.on(topology='ring', r_shards=...) "
                "build it")
        missing = {self.r_axis, data_axis} - set(mesh.axis_names)
        if missing:
            raise ValueError(
                f"topology='ring': mesh axes {mesh.axis_names} lack "
                f"{sorted(missing)} (expected a make_join_mesh(data=, r=) "
                "mesh)")

    def q_spec(self, data_axis: str) -> P:
        """Queries shard over (r, data) jointly — every device owns a
        block, so Q memory also drops by the r-axis size."""
        return P((self.r_axis, data_axis))

    def r_spec(self) -> P:
        """R rows shard over the ``r`` axis (replicated over ``data``)."""
        return P(self.r_axis)

    def r_row_quantum(self, block_r: int, mesh) -> int:
        """Shards must be equal-sized AND block_r-aligned."""
        return block_r * self.r_shards(mesh)

    def q_row_quantum(self, block_q: int, mesh, data_axis: str) -> int:
        """block_q rows per device over both axes."""
        return block_q * _data_size(mesh, data_axis) * self.r_shards(mesh)

    def nr_valid_shards(self, nr: int, nr_padded: int, mesh) -> np.ndarray:
        """Valid (non-padding) rows in each equal-sized R shard."""
        r = self.r_shards(mesh)
        rows = nr_padded // r
        return np.clip(nr - np.arange(r) * rows, 0, rows).astype(np.int32)

    def probe_shards(self, mesh) -> int:
        """Probe tables shard `r_shards` ways: each device probes only
        the member table of its own R shard (DESIGN.md §11), so
        candidate ids stay local and per-device table bytes drop by the
        r-axis size alongside R itself."""
        return self.r_shards(mesh)

    def probe_spec(self) -> P:
        """Probe tables shard their leading axis over the ``r`` axis."""
        return P(self.r_axis)

    def per_device_r_bytes(self, nr_padded: int, dim: int, mesh) -> int:
        """Each device holds one R shard: padded rows / r_shards."""
        return int(nr_padded) // self.r_shards(mesh) * int(dim) * 4

    def sweep_collectives(self, r_shards: int) -> int:
        """PR 9 ring schedule (DESIGN.md §15): the overlapped sweep
        issues ``r - 1`` query-rotation ppermutes plus ``r - 1``
        reduce-scatter hops = ``2 (r - 1)``; the serial sweep issues
        ``r - 1`` rotations plus one combining psum = ``r``."""
        r = int(r_shards)
        return 2 * (r - 1) if self.overlap else r

    def verify_collectives(self, r_shards: int) -> int:
        """Sharded candidate verification combines per-shard counts with
        one `psum` over ``r``."""
        return 1

    def hist_program(self, mesh, data_axis, backend, metric, block_q,
                     block_r, eps_chunk, nr_valid):
        """The ring sweep (DESIGN.md §10).

        shard_map'd over the full mesh: at step k each device histograms
        its resident R shard against the query block that has rotated k
        hops along the ``r`` ring, storing the partial counts under the
        block's home position; the per-position partials are then
        `psum`'d over ``r`` and each device keeps its own block's total.
        Padding rows are counted and subtracted in closed form
        (`_subtract_pad_rows`) using the traced per-shard valid count, so
        one static-shape program serves every shard.

        Two schedules (DESIGN.md §15):

        * `overlap=True` (default) — the next block's `ppermute` is
          issued BEFORE the current `inner(...)` histogram and consumed
          after it, so the hop transfers while the MXU sweeps (XLA's
          latency-hiding scheduler overlaps an async collective with
          independent compute; `launch.xla_flags` enables the same on
          GPU).  Partial counts combine via a ring reduce-scatter:
          each block's running sum rides the ring absorbing one
          device's contribution per hop, r_size - 1 hops of a single
          [q_local, m] int32 row — no [r_size, q_local, m] buffer, no
          full-buffer `psum`, no final `take`, and 2(r_size - 1) total
          collectives vs the serial schedule's r_size.
        * `overlap=False` — the original serial formulation (histogram,
          park the partial in a per-position buffer, rotate, `psum` at
          the end), kept as the benchmark baseline.

        Both accumulate the same int32 partials (addition over ints is
        associative + commutative), so counts are bit-identical."""
        self.validate(mesh, data_axis)
        r_size = self.r_shards(mesh)
        inner = _per_shard_hist(backend, metric, block_q, block_r,
                                eps_chunk, None)
        perm = [(i, (i + 1) % r_size) for i in range(r_size)]

        def sweep_overlap(q, r_shard, eps, nrv):
            n_pad = r_shard.shape[0] - nrv[0]
            qc = q
            parts = []
            for k in range(r_size):
                qn = (jax.lax.ppermute(qc, self.r_axis, perm)
                      if k < r_size - 1 else None)     # start the hop...
                # the block in hand is k hops from home: parts[k] is this
                # shard's contribution to block (me - k)
                parts.append(_subtract_pad_rows(inner(qc, r_shard, eps),
                                                eps, n_pad, metric))
                if qn is not None:
                    qc = qn                            # ...consume it here
            # ring reduce-scatter: block b's running sum starts one hop
            # past home (device b+1, = this device's parts[1]) and rides
            # the ring absorbing each host device's contribution; after
            # r_size - 1 hops of one [q_local, m] row each, the carry on
            # every device is its own block's total.  r_size == 1
            # compiles to zero collectives.
            carry = parts[1 % r_size]
            for j in range(1, r_size):
                carry = jax.lax.ppermute(carry, self.r_axis, perm)
                carry = carry + parts[(j + 1) % r_size]
            return carry

        def sweep_serial(q, r_shard, eps, nrv):
            n_pad = r_shard.shape[0] - nrv[0]
            me = jax.lax.axis_index(self.r_axis)
            buf = jnp.zeros((r_size, q.shape[0], eps.shape[0]), jnp.int32)
            qc = q
            for k in range(r_size):
                part = _subtract_pad_rows(inner(qc, r_shard, eps), eps,
                                          n_pad, metric)
                # the block in hand is k hops from home along the ring
                buf = buf.at[jnp.mod(me - k, r_size)].set(part)
                if k < r_size - 1:
                    qc = jax.lax.ppermute(qc, self.r_axis, perm)
            buf = jax.lax.psum(buf, self.r_axis)
            return jnp.take(buf, me, axis=0)

        sweep = sweep_overlap if self.overlap else sweep_serial
        mapped = _shard_mapped(
            sweep, mesh,
            in_specs=(P((self.r_axis, data_axis)), P(self.r_axis), P(),
                      P(self.r_axis)),
            out_specs=P((self.r_axis, data_axis)))
        return jax.jit(mapped)

    def compact_program(self, mesh, data_axis, backend, metric, block_q,
                        block_r, nr_valid):
        """Compact -> sharded verify -> scatter for ring placement.

        Only the predicted-positive candidates travel: the compacted
        block (bucketed `capacity` rows) is gathered across R shards —
        sharded over ``data`` when capacity divides evenly, replicated
        otherwise — each device sweeps it against its resident R shard,
        and the per-shard counts are `psum`'d over ``r``."""
        self.validate(mesh, data_axis)
        ndata = _data_size(mesh, data_axis)

        def sweep(qpos, r, eps1, nrv, capacity):
            shard_data = ndata > 1 and capacity % ndata == 0
            qspec = P(data_axis) if shard_data else P()
            rows_local = capacity // ndata if shard_data else capacity

            def shard_fn(qp, rs, e, nv):
                if backend == "ref":
                    found = ref.range_count_hist(qp, rs, e, metric)
                elif rows_local > block_q and rows_local % block_q == 0:
                    found = _q_blocked_hist(qp, rs, e, metric=metric,
                                            block_q=block_q, block_r=block_r,
                                            nr_valid=rs.shape[0])
                else:
                    found = ops.blocked_hist(qp, rs, e, metric=metric,
                                             block_r=block_r,
                                             nr_valid=rs.shape[0])
                found = _subtract_pad_rows(found, e, rs.shape[0] - nv[0],
                                           metric)
                return jax.lax.psum(found, self.r_axis)

            mapped = _shard_mapped(
                shard_fn, mesh,
                in_specs=(qspec, P(self.r_axis), P(), P(self.r_axis)),
                out_specs=qspec)
            return mapped(qpos, r, eps1, nrv)[:, 0]

        return self._compact_scaffold(sweep)


#: Registered topology names -> classes (the `JoinPlan.on(topology=...)`
#: and `JoinEngine(topology=...)` vocabulary).
TOPOLOGIES = {"replicated": Replicated, "ring": RingSharded}


def resolve_topology(spec, *, r_axis: str = "r") -> Topology:
    """Coerce a topology spec onto the Topology contract.

    Accepts a `Topology` instance (returned as-is), None / "replicated"
    (the default placement), or "ring" (R sharded over `r_axis`).  Raises
    ValueError for anything else — at construction time, not
    data-dependently inside a device program."""
    if isinstance(spec, Topology):
        return spec
    if spec is None or spec == "replicated":
        return Replicated()
    if spec == "ring":
        return RingSharded(r_axis=r_axis)
    raise ValueError(f"topology={spec!r}: expected one of "
                     f"{sorted(TOPOLOGIES)} or a Topology instance")
