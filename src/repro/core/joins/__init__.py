"""Join-method registry. Every method implements:

    __init__(R, metric, **params)   # build the index on R
    query_counts(Q, eps) -> int32 [q]   # found-neighbor counts per query

plus `.exact` (bool) and `.name`. Counting (not pair materialization) is the
framework-wide result representation: with an exact searcher, pair-level
recall equals count-level recall (found ⊆ true and per-query exactness), and
counts keep every shape static for XLA.
"""
from repro.core.joins.grid import GridJoin
from repro.core.joins.ivfpq import IVFPQJoin
from repro.core.joins.kmeans_tree import KmeansTreeJoin
from repro.core.joins.learned import LearnedJoin
from repro.core.joins.lsbf import LSBF
from repro.core.joins.lsh import LSHJoin
from repro.core.joins.naive import NaiveJoin

JOINS = {
    "naive": NaiveJoin,
    "grid": GridJoin,
    "lsh": LSHJoin,
    "kmeanstree": KmeansTreeJoin,
    "ivfpq": IVFPQJoin,
    "learned": LearnedJoin,
}


def make_join(name: str, R, metric: str, **params):
    return JOINS[name](R, metric, **params)


__all__ = ["JOINS", "make_join", "NaiveJoin", "GridJoin", "LSHJoin",
           "KmeansTreeJoin", "IVFPQJoin", "LearnedJoin", "LSBF"]
