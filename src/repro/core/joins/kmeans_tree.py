"""K-means-tree approximate join (paper baseline "KmeansTree", FLANN-style).

A hierarchical k-means partition (branching factor bf) down to bounded-size
leaves; a query ranks leaves by centroid distance and brute-force-verifies
the best rho-fraction of them.
"""
from __future__ import annotations

import numpy as np

from repro.core.joins.common import assign_nearest, kmeans, verify_candidates


class KmeansTreeJoin:
    name = "kmeanstree"
    exact = False

    def __init__(self, R: np.ndarray, metric: str, *, branching: int = 3,
                 leaf_size: int = 128, rho: float = 0.02, seed: int = 0, **_):
        self.R = np.asarray(R, np.float32)
        self.metric = metric
        self.rho = rho
        leaves: list[np.ndarray] = []

        def split(ids: np.ndarray, depth: int):
            if len(ids) <= leaf_size or depth > 12:
                leaves.append(ids)
                return
            cent = kmeans(self.R[ids], branching, iters=5,
                          seed=seed + depth, sample=4096)
            a = assign_nearest(self.R[ids], cent)
            for b in range(branching):
                sub = ids[a == b]
                if len(sub) == 0:
                    continue
                if len(sub) == len(ids):   # degenerate split: stop here
                    leaves.append(sub)
                    return
                split(sub, depth + 1)

        split(np.arange(len(self.R), dtype=np.int32), 0)
        cap = max(len(v) for v in leaves)
        self.leaf_members = np.full((len(leaves), cap), -1, np.int32)
        for i, v in enumerate(leaves):
            self.leaf_members[i, :len(v)] = v
        self.leaf_centroids = np.stack(
            [self.R[v].mean(axis=0) for v in leaves]).astype(np.float32)

    def candidates(self, Q: np.ndarray) -> np.ndarray:
        """Members of the best rho-fraction of leaves by centroid distance,
        int32 [q, C] (-1 padded) — the probing half of the Searcher
        protocol (DESIGN.md §9); radius-independent."""
        Q = np.asarray(Q, np.float32)
        n_leaves = len(self.leaf_centroids)
        n_inspect = max(1, int(np.ceil(self.rho * n_leaves)))
        d = (np.sum(Q * Q, 1)[:, None] - 2 * Q @ self.leaf_centroids.T
             + np.sum(self.leaf_centroids ** 2, 1)[None, :])
        top = np.argpartition(d, n_inspect - 1, axis=1)[:, :n_inspect]
        return self.leaf_members[top].reshape(len(Q), -1)

    def query_counts(self, Q: np.ndarray, eps: float) -> np.ndarray:
        """Exact eps-counts over the probed leaves (device verify)."""
        Q = np.asarray(Q, np.float32)
        return verify_candidates(self.R, Q, self.candidates(Q), float(eps),
                                 self.metric)
