"""IVF-PQ approximate join (paper baseline "IVFPQ", FAISS-style).

IVF: coarse k-means into C lists; the query probes the p nearest lists.
PQ:  vectors split into m segments, each quantized to 256 codes; candidate
     distances are approximated by ADC table lookups, the best
     `n_candidates` (paper: 1000) are verified exactly against eps.

The coarse probe + ADC ranking math lives in `core/probe.py` (DESIGN.md
§11), shared bit-for-bit between this host path and the engine's device
probe programs; `device_probe()` advertises the DeviceSearcher
capability so a plan with `probe="device"` quantizes and ranks on the
mesh with candidates never leaving the device.
"""
from __future__ import annotations

import numpy as np

from repro.core.joins.common import assign_nearest, build_capacity_table, kmeans, verify_candidates
from repro.core.probe import IVFPQProbe, ivfpq_candidates


class IVFPQJoin:
    name = "ivfpq"
    exact = False

    def __init__(self, R: np.ndarray, metric: str, *, C: int = 300, m: int = 25,
                 n_probe: int = 50, n_candidates: int = 1000, seed: int = 0, **_):
        self.R = np.asarray(R, np.float32)
        self.metric = metric
        n, d = self.R.shape
        while d % m != 0:    # paper: m=32, or 25 when dim not a multiple of 32
            m -= 1
        self.m, self.C = m, C
        self.n_probe = min(n_probe, C)
        self.n_candidates = n_candidates
        self.seg = d // m

        self.centroids = kmeans(self.R, C, iters=8, seed=seed)
        assign = assign_nearest(self.R, self.centroids)
        self.lists = build_capacity_table(assign, C)          # [C, cap]

        # PQ codebooks on residual-free raw vectors (classic ADC)
        rng = np.random.default_rng(seed + 1)
        sample = self.R[rng.choice(n, min(8192, n), replace=False)]
        self.codebooks = np.stack([
            kmeans(sample[:, s * self.seg:(s + 1) * self.seg], 256, iters=6,
                   seed=seed + 2 + s)
            for s in range(m)])                               # [m, 256, seg]
        self.codes = self._encode(self.R)                     # [n, m] uint8

    def _encode(self, X: np.ndarray) -> np.ndarray:
        codes = np.empty((len(X), self.m), np.uint8)
        for s in range(self.m):
            seg = X[:, s * self.seg:(s + 1) * self.seg]
            cb = self.codebooks[s]
            d = (np.sum(seg * seg, 1)[:, None] - 2 * seg @ cb.T
                 + np.sum(cb * cb, 1)[None, :])
            codes[:, s] = np.argmin(d, axis=1).astype(np.uint8)
        return codes

    def candidates(self, Q: np.ndarray) -> np.ndarray:
        """ADC-ranked candidate ids, int32 [q, k] (-1 padded), k =
        min(n_candidates, probed pool). Host probing half of the
        host-probe / device-verify split (common.py); the engine's
        `verify="ivfpq"` backend consumes this directly. Runs the same
        compiled coarse-probe + ADC math as `device_probe()`."""
        return ivfpq_candidates(
            Q, self.centroids, self.lists, self.codes, self.codebooks,
            n_probe=self.n_probe,
            n_cand=min(self.n_candidates,
                       self.n_probe * self.lists.shape[1]))

    def device_probe(self, eps: float | None = None):
        """DeviceSearcher capability (DESIGN.md §11): the probe spec the
        engine places on its mesh (quantizer state replicated — ADC
        ranking is a global top-k). Radius-free; one memoized spec per
        index."""
        spec = self.__dict__.get("_probe_spec")
        if spec is None:
            spec = self._probe_spec = IVFPQProbe(self)
        return spec

    def query_counts(self, Q: np.ndarray, eps: float) -> np.ndarray:
        """Exact eps-counts over the ADC-ranked candidates (device verify)."""
        Q = np.asarray(Q, np.float32)
        return verify_candidates(self.R, Q, self.candidates(Q), float(eps),
                                 self.metric, block=32)
