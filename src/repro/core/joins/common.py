"""Shared machinery for index-based joins: k-means, candidate verification.

The joins follow a host/device split that mirrors a production FAISS-on-TPU
style serving stack: index *probing* (data-dependent, pointer-heavy) runs on
host; candidate *verification* (dense distance math) runs on device in
static-shape blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def kmeans(X: np.ndarray, k: int, *, iters: int = 10, seed: int = 0,
           sample: int | None = 8192) -> np.ndarray:
    """Lloyd's k-means (jit'd distance steps). Returns centroids [k, d]."""
    rng = np.random.default_rng(seed)
    data = X[rng.choice(len(X), min(sample or len(X), len(X)), replace=False)]
    cent = data[rng.choice(len(data), k, replace=False)].copy()

    @jax.jit
    def assign(c, x):
        d = (jnp.sum(x * x, 1)[:, None] - 2 * x @ c.T + jnp.sum(c * c, 1)[None, :])
        return jnp.argmin(d, axis=1)

    for _ in range(iters):
        a = np.asarray(assign(jnp.asarray(cent), jnp.asarray(data)))
        for ci in range(k):
            mask = a == ci
            if mask.any():
                cent[ci] = data[mask].mean(axis=0)
            else:  # empty cluster: reseed on a random point
                cent[ci] = data[rng.integers(len(data))]
    return cent.astype(np.float32)


def assign_nearest(X: np.ndarray, centroids: np.ndarray, block: int = 4096) -> np.ndarray:
    @jax.jit
    def go(c, x):
        d = jnp.sum(x * x, 1)[:, None] - 2 * x @ c.T + jnp.sum(c * c, 1)[None, :]
        return jnp.argmin(d, axis=1)
    out = [np.asarray(go(jnp.asarray(centroids), jnp.asarray(X[i:i + block])))
           for i in range(0, len(X), block)]
    return np.concatenate(out)


def build_capacity_table(assignments: np.ndarray, n_buckets: int,
                         cap: int | None = None) -> np.ndarray:
    """Dense [n_buckets, cap] member table (-1 padded) from bucket ids."""
    order = np.argsort(assignments, kind="stable")
    sorted_b = assignments[order]
    counts = np.bincount(assignments, minlength=n_buckets)
    if cap is None:
        cap = max(int(counts.max()), 1)
    table = np.full((n_buckets, cap), -1, np.int32)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    for b in np.nonzero(counts)[0]:
        c = min(counts[b], cap)
        table[b, :c] = order[starts[b]:starts[b] + c]
    return table


@functools.partial(jax.jit, static_argnames=("metric",))
def _verify_block(R, q, cand, eps, *, metric):
    """counts of unique candidates within eps. q [bq,d], cand [bq,C] (-1 pad)."""
    cand_sorted = jnp.sort(cand, axis=1)
    dup = jnp.concatenate([jnp.zeros((cand.shape[0], 1), bool),
                           cand_sorted[:, 1:] == cand_sorted[:, :-1]], axis=1)
    valid = (cand_sorted >= 0) & ~dup
    x = R[jnp.maximum(cand_sorted, 0)]                   # [bq, C, d]
    dots = jnp.einsum("qcd,qd->qc", x.astype(jnp.float32), q.astype(jnp.float32))
    if metric == "cosine":
        d = 1.0 - dots
    else:
        d = jnp.sqrt(jnp.maximum(2.0 - 2.0 * dots, 0.0))
    return jnp.sum(valid & (d <= eps), axis=1, dtype=jnp.int32)


def verify_candidates(R: np.ndarray, Q: np.ndarray, cand_ids: np.ndarray,
                      eps: float, metric: str, *, block: int = 32) -> np.ndarray:
    """Exact verification of candidate lists. cand_ids [q, C] int32 (-1 pad).
    Returns int32 [q] counts of unique true neighbors among candidates."""
    Rj = jnp.asarray(R)
    out = np.empty((len(Q),), np.int32)
    for i in range(0, len(Q), block):
        j = min(i + block, len(Q))
        qb = jnp.asarray(Q[i:j])
        cb = jnp.asarray(cand_ids[i:j])
        # pad the final partial block to keep shapes static
        if j - i < block:
            qb = jnp.concatenate([qb, jnp.zeros((block - (j - i),) + qb.shape[1:], qb.dtype)])
            cb = jnp.concatenate([cb, jnp.full((block - (j - i),) + cb.shape[1:], -1, cb.dtype)])
        cnt = _verify_block(Rj, qb, cb, jnp.float32(eps), metric=metric)
        out[i:j] = np.asarray(cnt)[:j - i]
    return out
