"""Shared machinery for index-based joins: k-means, candidate verification.

The joins follow a host/device split that mirrors a production FAISS-on-TPU
style serving stack: index *probing* (data-dependent, pointer-heavy) runs on
host; candidate *verification* (dense distance math) runs on device in
static-shape blocks.

`verify_candidates` is also the engine's approximate-verification backend
(DESIGN.md §5): `JoinEngine` hands it a *device-resident* R (its padded
replica — candidate ids only ever index valid rows, so padding is inert)
and uses the non-blocking `dispatch_verify_candidates` form so candidate
verification overlaps the next batch's dispatch. The `backend` arg mirrors
the kernel matrix (DESIGN.md §2): "ref" verifies each chunk unpadded with
the oracle semantics; "jnp"/"auto"/"pallas" use the bucketed blocked path
(counts are identical — integer comparisons on the same f32 distances).
"""
from __future__ import annotations

import functools
import inspect

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import register_program_cache


def searcher_candidates(searcher, Q: np.ndarray, eps: float) -> np.ndarray:
    """Probe a Searcher for candidate ids, passing `eps` only when the
    probe is eps-aware (the protocol's `candidates(Q[, eps])` form,
    DESIGN.md §9). Grid needs the radius to size its cells; LSH / IVF-PQ /
    k-means-tree probes are radius-independent."""
    try:
        eps_aware = "eps" in inspect.signature(searcher.candidates).parameters
    except (TypeError, ValueError):         # builtins / C callables
        eps_aware = False
    if eps_aware:
        return searcher.candidates(Q, eps=float(eps))
    return searcher.candidates(Q)


def kmeans(X: np.ndarray, k: int, *, iters: int = 10, seed: int = 0,
           sample: int | None = 8192) -> np.ndarray:
    """Lloyd's k-means (jit'd distance steps). Returns centroids [k, d]."""
    rng = np.random.default_rng(seed)
    data = X[rng.choice(len(X), min(sample or len(X), len(X)), replace=False)]
    cent = data[rng.choice(len(data), k, replace=False)].copy()

    @jax.jit
    def assign(c, x):
        d = (jnp.sum(x * x, 1)[:, None] - 2 * x @ c.T + jnp.sum(c * c, 1)[None, :])
        return jnp.argmin(d, axis=1)

    for _ in range(iters):
        a = np.asarray(assign(jnp.asarray(cent), jnp.asarray(data)))
        for ci in range(k):
            mask = a == ci
            if mask.any():
                cent[ci] = data[mask].mean(axis=0)
            else:  # empty cluster: reseed on a random point
                cent[ci] = data[rng.integers(len(data))]
    return cent.astype(np.float32)


def assign_nearest(X: np.ndarray, centroids: np.ndarray, block: int = 4096) -> np.ndarray:
    @jax.jit
    def go(c, x):
        d = jnp.sum(x * x, 1)[:, None] - 2 * x @ c.T + jnp.sum(c * c, 1)[None, :]
        return jnp.argmin(d, axis=1)
    out = [np.asarray(go(jnp.asarray(centroids), jnp.asarray(X[i:i + block])))
           for i in range(0, len(X), block)]
    return np.concatenate(out)


def build_capacity_table(assignments: np.ndarray, n_buckets: int,
                         cap: int | None = None) -> np.ndarray:
    """Dense [n_buckets, cap] member table (-1 padded) from bucket ids."""
    order = np.argsort(assignments, kind="stable")
    sorted_b = assignments[order]
    counts = np.bincount(assignments, minlength=n_buckets)
    if cap is None:
        cap = max(int(counts.max()), 1)
    table = np.full((n_buckets, cap), -1, np.int32)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    for b in np.nonzero(counts)[0]:
        c = min(counts[b], cap)
        table[b, :c] = order[starts[b]:starts[b] + c]
    return table


def _verify_block_impl(R, q, cand, eps, *, metric, tomb=None):
    """counts of unique candidates within eps. q [bq,d], cand [bq,C] (-1 pad).
    Traceable — composes under the blocked scan below. `tomb` is the
    optional int32 tombstone mask over R's rows (DESIGN.md §13): a
    candidate whose row is tombstoned never counts, on every backend."""
    cand_sorted = jnp.sort(cand, axis=1)
    dup = jnp.concatenate([jnp.zeros((cand.shape[0], 1), bool),
                           cand_sorted[:, 1:] == cand_sorted[:, :-1]], axis=1)
    valid = (cand_sorted >= 0) & ~dup
    if tomb is not None:
        valid &= tomb[jnp.maximum(cand_sorted, 0)] == 0
    x = R[jnp.maximum(cand_sorted, 0)]                   # [bq, C, d]
    dots = jnp.einsum("qcd,qd->qc", x.astype(jnp.float32), q.astype(jnp.float32))
    if metric == "cosine":
        d = 1.0 - dots
    else:
        d = jnp.sqrt(jnp.maximum(2.0 - 2.0 * dots, 0.0))
    return jnp.sum(valid & (d <= eps), axis=1, dtype=jnp.int32)


#: candidate-axis tile of the live-chunked verify below: the lcm of the
#: probe capacity quantum (engine.py `_stage_probe`) and the q block, so
#: typical LSH capacities (l * n_probes * cap) pad by < one tile
_LIVE_CHUNK = 64


def _verify_block_live(R, q, cand, eps, *, metric, tomb=None,
                       chunk=_LIVE_CHUNK):
    """`_verify_block_impl` with cost scaled to LIVE candidates, not probe
    capacity (DESIGN.md §15): multiprobe candidate lists are mostly -1
    padding (empty buckets, dedup blanks), yet the R-row gather — the
    verify's dominant cost — runs over the full width in the oracle form.
    Sorting each row DESCENDING packs live ids to the front, so a
    fori_loop with a traced trip count of ceil(max_live / chunk) gathers
    only chunks that contain a live id.  Counts stay bit-identical to the
    oracle: skipped chunks are all-pad (exactly zero contribution), each
    surviving (q, id) pair's distance is the same f32 dot reduced over the
    same axis, and the int32 partial sums add associatively."""
    bq, C = cand.shape
    cs = jnp.sort(cand, axis=1)[:, ::-1]
    dup = jnp.concatenate([jnp.zeros((bq, 1), bool),
                           cs[:, 1:] == cs[:, :-1]], axis=1)
    valid = (cs >= 0) & ~dup
    if tomb is not None:
        valid &= tomb[jnp.maximum(cs, 0)] == 0
    pad = (-C) % chunk
    if pad:
        cs = jnp.pad(cs, ((0, 0), (0, pad)), constant_values=-1)
        valid = jnp.pad(valid, ((0, 0), (0, pad)))
    n_live = jnp.max(jnp.sum(cs >= 0, axis=1))      # traced scalar bound

    def body(i, acc):
        c_sl = jax.lax.dynamic_slice_in_dim(cs, i * chunk, chunk, 1)
        v_sl = jax.lax.dynamic_slice_in_dim(valid, i * chunk, chunk, 1)
        x = R[jnp.maximum(c_sl, 0)]                  # [bq, chunk, d]
        dots = jnp.einsum("qcd,qd->qc", x.astype(jnp.float32),
                          q.astype(jnp.float32))
        if metric == "cosine":
            d = 1.0 - dots
        else:
            d = jnp.sqrt(jnp.maximum(2.0 - 2.0 * dots, 0.0))
        return acc + jnp.sum(v_sl & (d <= eps), axis=1, dtype=jnp.int32)

    return jax.lax.fori_loop(0, (n_live + chunk - 1) // chunk, body,
                             jnp.zeros((bq,), jnp.int32))


@functools.partial(jax.jit, static_argnames=("metric", "block"))
def _verify_blocks(R, q, cand, eps, tomb=None, *, metric, block):
    """lax.map over q blocks — ONE device program for the whole candidate
    set (q rows % block == 0), peak memory still O(block * C * d); each
    block runs the live-chunked form above (its max-live bound is per q
    block, so dense rows never widen a sparse block's gather)."""
    nb = q.shape[0] // block
    qb = q.reshape(nb, block, q.shape[1])
    cb = cand.reshape(nb, block, cand.shape[1])
    out = jax.lax.map(
        lambda xc: _verify_block_live(R, xc[0], xc[1], eps, metric=metric,
                                      tomb=tomb),
        (qb, cb))
    return out.reshape(-1)


@functools.partial(jax.jit, static_argnames=("metric",))
def _verify_ref(R, q, cand, eps, tomb=None, *, metric):
    """Unblocked oracle form — no padding, one program per chunk shape
    (mirrors the "ref" row of the DESIGN.md §2 matrix)."""
    return _verify_block_impl(R, q, cand, eps, metric=metric, tomb=tomb)


def localized_shard_verify(r_axis, shard_rows, metric, block, backend):
    """Per-shard candidate verification against an R row-sharded over
    `r_axis`: `shard_fn(rs, qb, cb, e, tb=None)` localizes the global
    candidate ids to this device's row range ([me*shard_rows,
    (me+1)*shard_rows) -> masked to -1 outside), verifies them against
    the resident shard, and `psum`s the counts over `r_axis`. A candidate
    id maps to exactly one shard, so the per-shard sort/dedup of
    `_verify_block_impl` stays correct and R's padding rows (never
    referenced by valid ids) stay inert. `tb` is the local slice of the
    tombstone mask (sharded exactly like R, so the localized ids index
    it directly — DESIGN.md §13). The SINGLE implementation behind
    `_sharded_verify_program` (host probing) and `probe.py`'s ring
    verify programs (device probing, DESIGN.md §11) — the two routes
    cannot diverge."""
    def shard_fn(rs, qb, cb, e, tb=None):
        lo = jax.lax.axis_index(r_axis) * shard_rows
        local = cb - lo
        keep = (cb >= 0) & (local >= 0) & (local < shard_rows)
        cl = jnp.where(keep, local, -1).astype(jnp.int32)
        if backend == "ref" or qb.shape[0] % block != 0:
            cnt = _verify_block_impl(rs, qb, cl, e, metric=metric, tomb=tb)
        else:
            cnt = _verify_blocks(rs, qb, cl, e, tb, metric=metric,
                                 block=block)
        return jax.lax.psum(cnt, r_axis)

    return shard_fn


@register_program_cache
@functools.lru_cache(maxsize=64)
def _sharded_verify_program(mesh, r_axis, data_axis, shard_rows, metric,
                            block, backend, has_tomb=False):
    """Candidate verification against an R row-sharded over `r_axis`
    (the ring topology, DESIGN.md §10): `localized_shard_verify` mapped
    over the mesh. The query/candidate chunk additionally shards over
    `data_axis` whenever its (block-bucketed) row count divides evenly —
    the data columns split the work instead of repeating it. `has_tomb`
    keys the program on whether a tombstone mask rides along (shard_map
    in_specs are fixed-arity — DESIGN.md §13). Cached per (mesh,
    geometry); evicted by `engine.clear_program_cache`."""
    from repro.core.topology import _data_size, _shard_mapped
    from jax.sharding import PartitionSpec as P

    ndata = _data_size(mesh, data_axis)
    shard_fn = localized_shard_verify(r_axis, shard_rows, metric, block,
                                      backend)

    def run(rs, qb, cb, e, tb=None):
        # rows are static at trace time, so the placement choice is too;
        # jit caches one executable per chunk-shape bucket either way
        qspec = P(data_axis) if (ndata > 1 and qb.shape[0] % ndata == 0
                                 and (backend == "ref"
                                      or (qb.shape[0] // ndata) % block == 0)
                                 ) else P()
        in_specs = (P(r_axis), qspec, qspec, P())
        if has_tomb:
            in_specs += (P(r_axis),)        # tomb shards exactly like R
            mapped = _shard_mapped(shard_fn, mesh, in_specs=in_specs,
                                   out_specs=qspec)
            return mapped(rs, qb, cb, e, tb)
        mapped = _shard_mapped(shard_fn, mesh, in_specs=in_specs,
                               out_specs=qspec)
        return mapped(rs, qb, cb, e)

    return jax.jit(run)


class PendingCounts:
    """In-flight candidate verification: per-chunk device arrays with their
    host copies already started. `result()` is the only blocking point."""

    def __init__(self, parts: list, n: int):
        self._parts = parts                 # [(device_counts, lo, hi)]
        self._n = n

    def result(self) -> np.ndarray:
        """Materialize the int32 [q] counts (blocking if still computing)."""
        out = np.zeros((self._n,), np.int32)
        for cnt, lo, hi in self._parts:
            out[lo:hi] = np.asarray(cnt)[: hi - lo]
        return out


def dispatch_verify_candidates(R, Q: np.ndarray, cand_ids: np.ndarray,
                               eps: float, metric: str, *, block: int = 32,
                               chunk: int = 8192, backend: str = "auto",
                               mesh=None, r_axis: str | None = None,
                               data_axis: str = "data",
                               shard_rows: int = 0, tomb=None) -> PendingCounts:
    """Non-blocking form of `verify_candidates`: dispatches every chunk's
    device program, kicks off async device→host copies, and returns a
    `PendingCounts` handle. `R` may be a host array or an already
    device-resident replica (e.g. `JoinEngine`'s padded R — candidate ids
    never reference padding rows, so the extra rows are inert). `tomb`
    optionally masks tombstoned R rows out of the counts (DESIGN.md §13;
    sharded like R on ring placements).

    When `R` is row-sharded over a mesh axis (the ring topology), pass
    `mesh`, `r_axis`, and `shard_rows` (rows per shard): each device then
    verifies only the ids landing in its own shard and the counts are
    `psum`'d over `r_axis` — R is never gathered."""
    from repro.core.engine import _bucket_size, _start_host_copy
    from repro.kernels import ops
    backend = ops._resolve(backend)
    n = len(Q)
    Rj = R if isinstance(R, jax.Array) else jnp.asarray(R)
    sharded = mesh is not None and r_axis is not None
    if sharded:
        prog = _sharded_verify_program(mesh, r_axis, data_axis,
                                       int(shard_rows), metric, block,
                                       backend, tomb is not None)
    parts = []
    for i in range(0, n, chunk):
        j = min(i + chunk, n)
        if backend == "ref":
            qb = jnp.asarray(Q[i:j], jnp.float32)
            cb = jnp.asarray(cand_ids[i:j], jnp.int32)
        else:
            n_pad = _bucket_size(j - i, block)
            qh = np.zeros((n_pad,) + Q.shape[1:], np.float32)
            qh[:j - i] = Q[i:j]
            ch = np.full((n_pad,) + cand_ids.shape[1:], -1, np.int32)
            ch[:j - i] = cand_ids[i:j]
            qb, cb = jnp.asarray(qh), jnp.asarray(ch)
        if sharded:
            cnt = prog(Rj, qb, cb, jnp.float32(eps), tomb)
        elif backend == "ref":
            cnt = _verify_ref(Rj, qb, cb, jnp.float32(eps), tomb,
                              metric=metric)
        else:
            cnt = _verify_blocks(Rj, qb, cb, jnp.float32(eps), tomb,
                                 metric=metric, block=block)
        _start_host_copy(cnt)
        parts.append((cnt, i, j))
    return PendingCounts(parts, n)


def verify_candidates(R, Q: np.ndarray, cand_ids: np.ndarray,
                      eps: float, metric: str, *, block: int = 32,
                      chunk: int = 8192, backend: str = "auto",
                      mesh=None, r_axis: str | None = None,
                      data_axis: str = "data",
                      shard_rows: int = 0, tomb=None) -> np.ndarray:
    """Exact verification of candidate lists. cand_ids [q, C] int32 (-1 pad).
    Returns int32 [q] counts of unique true neighbors among candidates.
    Queries are padded to a bucketed multiple of `block` (bounded
    recompiles) and verified in one device call per `chunk` — the chunk
    bounds device residency of the [q, C] candidate matrix; typical query
    sets fit in a single call. `backend` selects the §2 compute path
    ("ref" = unpadded oracle); counts are backend-invariant. Pass
    `mesh`/`r_axis`/`shard_rows` to verify against a row-sharded R
    (see `dispatch_verify_candidates`).
    """
    return dispatch_verify_candidates(R, Q, cand_ids, eps, metric,
                                      block=block, chunk=chunk,
                                      backend=backend, mesh=mesh,
                                      r_axis=r_axis, data_axis=data_axis,
                                      shard_rows=shard_rows,
                                      tomb=tomb).result()
