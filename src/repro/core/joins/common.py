"""Shared machinery for index-based joins: k-means, candidate verification.

The joins follow a host/device split that mirrors a production FAISS-on-TPU
style serving stack: index *probing* (data-dependent, pointer-heavy) runs on
host; candidate *verification* (dense distance math) runs on device in
static-shape blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def kmeans(X: np.ndarray, k: int, *, iters: int = 10, seed: int = 0,
           sample: int | None = 8192) -> np.ndarray:
    """Lloyd's k-means (jit'd distance steps). Returns centroids [k, d]."""
    rng = np.random.default_rng(seed)
    data = X[rng.choice(len(X), min(sample or len(X), len(X)), replace=False)]
    cent = data[rng.choice(len(data), k, replace=False)].copy()

    @jax.jit
    def assign(c, x):
        d = (jnp.sum(x * x, 1)[:, None] - 2 * x @ c.T + jnp.sum(c * c, 1)[None, :])
        return jnp.argmin(d, axis=1)

    for _ in range(iters):
        a = np.asarray(assign(jnp.asarray(cent), jnp.asarray(data)))
        for ci in range(k):
            mask = a == ci
            if mask.any():
                cent[ci] = data[mask].mean(axis=0)
            else:  # empty cluster: reseed on a random point
                cent[ci] = data[rng.integers(len(data))]
    return cent.astype(np.float32)


def assign_nearest(X: np.ndarray, centroids: np.ndarray, block: int = 4096) -> np.ndarray:
    @jax.jit
    def go(c, x):
        d = jnp.sum(x * x, 1)[:, None] - 2 * x @ c.T + jnp.sum(c * c, 1)[None, :]
        return jnp.argmin(d, axis=1)
    out = [np.asarray(go(jnp.asarray(centroids), jnp.asarray(X[i:i + block])))
           for i in range(0, len(X), block)]
    return np.concatenate(out)


def build_capacity_table(assignments: np.ndarray, n_buckets: int,
                         cap: int | None = None) -> np.ndarray:
    """Dense [n_buckets, cap] member table (-1 padded) from bucket ids."""
    order = np.argsort(assignments, kind="stable")
    sorted_b = assignments[order]
    counts = np.bincount(assignments, minlength=n_buckets)
    if cap is None:
        cap = max(int(counts.max()), 1)
    table = np.full((n_buckets, cap), -1, np.int32)
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    for b in np.nonzero(counts)[0]:
        c = min(counts[b], cap)
        table[b, :c] = order[starts[b]:starts[b] + c]
    return table


def _verify_block_impl(R, q, cand, eps, *, metric):
    """counts of unique candidates within eps. q [bq,d], cand [bq,C] (-1 pad).
    Traceable — composes under the blocked scan below."""
    cand_sorted = jnp.sort(cand, axis=1)
    dup = jnp.concatenate([jnp.zeros((cand.shape[0], 1), bool),
                           cand_sorted[:, 1:] == cand_sorted[:, :-1]], axis=1)
    valid = (cand_sorted >= 0) & ~dup
    x = R[jnp.maximum(cand_sorted, 0)]                   # [bq, C, d]
    dots = jnp.einsum("qcd,qd->qc", x.astype(jnp.float32), q.astype(jnp.float32))
    if metric == "cosine":
        d = 1.0 - dots
    else:
        d = jnp.sqrt(jnp.maximum(2.0 - 2.0 * dots, 0.0))
    return jnp.sum(valid & (d <= eps), axis=1, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("metric", "block"))
def _verify_blocks(R, q, cand, eps, *, metric, block):
    """lax.map over q blocks — ONE device program for the whole candidate
    set (q rows % block == 0), peak memory still O(block * C * d)."""
    nb = q.shape[0] // block
    qb = q.reshape(nb, block, q.shape[1])
    cb = cand.reshape(nb, block, cand.shape[1])
    out = jax.lax.map(
        lambda xc: _verify_block_impl(R, xc[0], xc[1], eps, metric=metric),
        (qb, cb))
    return out.reshape(-1)


def verify_candidates(R: np.ndarray, Q: np.ndarray, cand_ids: np.ndarray,
                      eps: float, metric: str, *, block: int = 32,
                      chunk: int = 8192) -> np.ndarray:
    """Exact verification of candidate lists. cand_ids [q, C] int32 (-1 pad).
    Returns int32 [q] counts of unique true neighbors among candidates.
    Queries are padded to a bucketed multiple of `block` (bounded
    recompiles) and verified in one device call per `chunk` — the chunk
    bounds device residency of the [q, C] candidate matrix; typical query
    sets fit in a single call.
    """
    from repro.core.engine import _bucket_size
    n = len(Q)
    Rj = jnp.asarray(R)
    out = np.empty((n,), np.int32)
    for i in range(0, n, chunk):
        j = min(i + chunk, n)
        n_pad = _bucket_size(j - i, block)
        qb = np.zeros((n_pad,) + Q.shape[1:], np.float32)
        qb[:j - i] = Q[i:j]
        cb = np.full((n_pad,) + cand_ids.shape[1:], -1, np.int32)
        cb[:j - i] = cand_ids[i:j]
        cnt = _verify_blocks(Rj, jnp.asarray(qb), jnp.asarray(cb),
                             jnp.float32(eps), metric=metric, block=block)
        out[i:j] = np.asarray(cnt)[:j - i]
    return out
