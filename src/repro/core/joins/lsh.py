"""LSH-based approximate join (paper baseline "LSH", FALCONN-style).

Cosine: k random-hyperplane bits per table -> bucket code.
L2:     k p-stable (Gaussian) quantized projections, combined by a random
        integer hash -> bucket id.
Multiprobe: perturb one hash coordinate at a time (bit-flip / +-1) and take
the first n_p probe buckets per table — structured multiprobe in the spirit
of FALCONN/E2LSH.

All hash/probe math lives in `core/probe.py` (DESIGN.md §11) and is shared
bit-for-bit between this host path and the engine's device probe programs:
`device_probe()` advertises the DeviceSearcher capability, so a plan with
`probe="device"` runs the multiprobe on the mesh with candidates never
leaving the device.
"""
from __future__ import annotations

import warnings

import numpy as np

from repro.core.joins.common import build_capacity_table, verify_candidates
from repro.core.probe import (LSHProbe, lsh_bucket_ids, lsh_hash_codes,
                              lsh_probe_buckets, split_hot_buckets)

_PRIMES = (73856093, 19349663, 83492791, 32452843, 67867967, 86028121,
           49979687, 29996224275833, 982451653, 15485863, 2038074743,
           472882027, 533000389, 613651349, 694847533, 756065159,
           824633720831, 899809343, 961748927, 633910099)


class LSHJoin:
    name = "lsh"
    exact = False

    def __init__(self, R: np.ndarray, metric: str, *, k: int = 18, l: int = 10,
                 n_probes: int = 4, W: float = 2.5, n_buckets: int | None = None,
                 cap: int | None = None, seed: int = 0,
                 rebucket_hot: float | None = None, max_fanout: int = 8,
                 **_):
        self.R = np.asarray(R, np.float32)
        self.metric = metric
        self.k, self.l, self.n_probes, self.W = k, l, n_probes, W
        n = len(self.R)
        self.n_buckets = n_buckets or max(256, 2 ** int(np.ceil(np.log2(n))))
        rng = np.random.default_rng(seed)
        d = self.R.shape[1]
        self.proj = rng.normal(size=(l, k, d)).astype(np.float32)
        self.bias = rng.uniform(0, W, size=(l, k)).astype(np.float32)
        self.salt = rng.integers(1, 2 ** 31, size=(l, k)).astype(np.int64)
        codes = self._hash_codes(self.R)                     # [n, l, k] int
        buckets = self._combine(codes)                       # [n, l]
        #: skew-aware re-bucketing (DESIGN.md §16, `rebucket_hot=`):
        #: buckets hotter than rebucket_hot x the mean occupancy split on
        #: extra median-thresholded hyperplanes; `expand` maps each
        #: original bucket to its children and probing expands through it
        #: (candidate sets — hence verified counts — unchanged).
        self.expand = None
        self.rebucket_info = None
        n_total = self.n_buckets
        if rebucket_hot is not None:
            split = split_hot_buckets(buckets, self.R,
                                      n_buckets=self.n_buckets,
                                      hot_factor=float(rebucket_hot),
                                      max_fanout=int(max_fanout), seed=seed)
            if split is not None:
                buckets, self.expand, n_total, self.rebucket_info = split
        self.n_total_buckets = n_total
        occ = np.stack([np.bincount(buckets[:, t], minlength=n_total)
                        for t in range(l)])                  # [l, B]
        if cap is None:
            # size the bucket capacity at the p99.9 occupancy so the table
            # stays dense; overflow drops rows — counted below, no longer
            # silently (the overflow_frac satellite of ISSUE 5).
            cap = int(max(2, np.quantile(occ.reshape(-1), 0.999)))
        if self.expand is not None:
            # post-split occupancy is the binding width: an explicit cap=
            # is an upper bound, never a reason to pad every child bucket
            # back out to the pre-split hot-tail width
            cap = int(max(2, min(cap, occ.max())))
        self.cap = cap
        #: fraction of (row, table) memberships dropped by bucket-capacity
        #: overflow at build time — the index's silent-candidate-loss
        #: budget, surfaced by `JoinPlan.describe()` and the serve report.
        self.overflow_frac = float(np.maximum(occ - cap, 0).sum()
                                   / max(n * l, 1))
        if self.overflow_frac > 0.01:
            warnings.warn(
                f"LSHJoin: bucket-capacity overflow drops "
                f"{self.overflow_frac:.1%} of row memberships (cap={cap}, "
                f"n_buckets={self.n_buckets}); recall degrades — raise "
                "cap= or n_buckets=", RuntimeWarning, stacklevel=2)
        self.tables = np.stack([
            build_capacity_table(buckets[:, t], n_total, cap)
            for t in range(l)])                              # [l, B, cap]

    # -- hashing -------------------------------------------------------------
    def _hash_codes(self, X: np.ndarray) -> np.ndarray:
        return lsh_hash_codes(X, self.proj, self.bias, metric=self.metric,
                              W=self.W)

    def _combine(self, codes: np.ndarray) -> np.ndarray:
        return lsh_bucket_ids(codes, self.salt, self.n_buckets)

    def _probe_buckets(self, X: np.ndarray) -> np.ndarray:
        """[q, l, n_probes] bucket ids: identity probe + single-coord
        perturbs (the shared `core/probe.py` schedule)."""
        return lsh_probe_buckets(X, self.proj, self.bias, self.salt,
                                 metric=self.metric, W=self.W,
                                 n_probes=self.n_probes,
                                 n_buckets=self.n_buckets)

    # -- query ----------------------------------------------------------------
    def candidates(self, Q: np.ndarray) -> np.ndarray:
        """Multiprobe candidate ids, int32 [q, l*n_probes*cap] (-1 padded).
        Host probing half of the host-probe / device-verify split
        (common.py); the engine's `verify="lsh"` backend consumes this
        directly. Runs the same compiled math as `device_probe()`."""
        pb = self._probe_buckets(Q)                          # [q, l, p]
        q = len(Q)
        if self.expand is not None:
            # re-bucketed index: expand every probed bucket to all of its
            # children (same expansion the device programs apply)
            pb = self.expand[np.arange(self.l)[None, :, None], pb] \
                     .reshape(q, self.l, -1)                 # [q, l, p*F]
        cand = self.tables[np.arange(self.l)[None, :, None], pb]  # [q, l, p, cap]
        return cand.reshape(q, -1)

    def device_probe(self, eps: float | None = None):
        """DeviceSearcher capability (DESIGN.md §11): the probe spec the
        engine places on its mesh. Radius-free (eps is ignored); one
        memoized spec per index."""
        spec = self.__dict__.get("_probe_spec")
        if spec is None:
            spec = self._probe_spec = LSHProbe(self)
        return spec

    def query_counts(self, Q: np.ndarray, eps: float) -> np.ndarray:
        """Exact eps-counts over the probed candidates (device verify)."""
        cand = self.candidates(np.asarray(Q, np.float32))
        return verify_candidates(self.R, Q, cand, float(eps), self.metric)
