"""LSH-based approximate join (paper baseline "LSH", FALCONN-style).

Cosine: k random-hyperplane bits per table -> bucket code.
L2:     k p-stable (Gaussian) quantized projections, combined by a random
        integer hash -> bucket id.
Multiprobe: perturb one hash coordinate at a time (bit-flip / +-1) and take
the first n_p probe buckets per table — structured multiprobe in the spirit
of FALCONN/E2LSH.
"""
from __future__ import annotations

import numpy as np

from repro.core.joins.common import build_capacity_table, verify_candidates

_PRIMES = (73856093, 19349663, 83492791, 32452843, 67867967, 86028121,
           49979687, 29996224275833, 982451653, 15485863, 2038074743,
           472882027, 533000389, 613651349, 694847533, 756065159,
           824633720831, 899809343, 961748927, 633910099)


class LSHJoin:
    name = "lsh"
    exact = False

    def __init__(self, R: np.ndarray, metric: str, *, k: int = 18, l: int = 10,
                 n_probes: int = 4, W: float = 2.5, n_buckets: int | None = None,
                 cap: int | None = None, seed: int = 0, **_):
        self.R = np.asarray(R, np.float32)
        self.metric = metric
        self.k, self.l, self.n_probes, self.W = k, l, n_probes, W
        n = len(self.R)
        self.n_buckets = n_buckets or max(256, 2 ** int(np.ceil(np.log2(n))))
        rng = np.random.default_rng(seed)
        d = self.R.shape[1]
        self.proj = rng.normal(size=(l, k, d)).astype(np.float32)
        self.bias = rng.uniform(0, W, size=(l, k)).astype(np.float32)
        self.salt = rng.integers(1, 2 ** 31, size=(l, k)).astype(np.int64)
        codes = self._hash_codes(self.R)                     # [n, l, k] int
        buckets = self._combine(codes)                       # [n, l]
        if cap is None:
            # size the bucket capacity at the p99.9 occupancy so the table
            # stays dense; overflow silently drops (approximate method).
            occ = [np.bincount(buckets[:, t], minlength=self.n_buckets)
                   for t in range(l)]
            cap = int(max(2, np.quantile(np.concatenate(occ), 0.999)))
        self.tables = np.stack([
            build_capacity_table(buckets[:, t], self.n_buckets, cap)
            for t in range(l)])                              # [l, B, cap]

    # -- hashing -------------------------------------------------------------
    def _hash_codes(self, X: np.ndarray) -> np.ndarray:
        h = np.einsum("nd,lkd->nlk", X.astype(np.float32), self.proj)
        if self.metric == "cosine":
            return (h > 0).astype(np.int64)
        return np.floor((h + self.bias[None]) / self.W).astype(np.int64)

    def _combine(self, codes: np.ndarray) -> np.ndarray:
        mixed = (codes * self.salt[None]).sum(axis=2)
        return (mixed % self.n_buckets).astype(np.int64)

    def _probe_buckets(self, X: np.ndarray) -> np.ndarray:
        """[q, l, n_probes] bucket ids: identity probe + single-coord perturbs."""
        codes = self._hash_codes(X)                          # [q, l, k]
        probes = [self._combine(codes)]
        for j in range(self.k):
            if len(probes) >= self.n_probes:
                break
            pert = codes.copy()
            if self.metric == "cosine":
                pert[:, :, j] = 1 - pert[:, :, j]
            else:
                pert[:, :, j] += np.where((j % 2) == 0, 1, -1)
            probes.append(self._combine(pert))
        while len(probes) < self.n_probes:
            probes.append(probes[0])
        return np.stack(probes[: self.n_probes], axis=2)

    # -- query ----------------------------------------------------------------
    def candidates(self, Q: np.ndarray) -> np.ndarray:
        """Multiprobe candidate ids, int32 [q, l*n_probes*cap] (-1 padded).
        Host probing half of the host-probe / device-verify split
        (common.py); the engine's `verify="lsh"` backend consumes this
        directly."""
        pb = self._probe_buckets(Q)                          # [q, l, p]
        q = len(Q)
        cand = self.tables[np.arange(self.l)[None, :, None], pb]  # [q, l, p, cap]
        return cand.reshape(q, -1)

    def query_counts(self, Q: np.ndarray, eps: float) -> np.ndarray:
        """Exact eps-counts over the probed candidates (device verify)."""
        cand = self.candidates(np.asarray(Q, np.float32))
        return verify_candidates(self.R, Q, cand, float(eps), self.metric)
