"""Epsilon-Grid-Order join (exact; adapted SuperEGO).

True EGO orders points along an eps-grid in full dimension — useless at
d=300+ (curse of dimensionality, §I). The adaptation: grid over the top-3
PCA directions. Projection onto orthonormal directions is contractive
(|P(x) - P(q)| <= |x - q|), so any eps-neighbor of q lies within +-1 cell
of q's cell in every projected dim — checking the 27 neighboring cells and
verifying in full dimension keeps the join EXACT while pruning far pairs.
"""
from __future__ import annotations

import numpy as np

from repro.core.joins.common import verify_candidates


class GridJoin:
    name = "grid"
    exact = True

    def __init__(self, R: np.ndarray, metric: str, *, dims: int = 3,
                 cell_eps: float = 0.5, seed: int = 0, **_):
        self.R = np.asarray(R, np.float32)
        self.metric = metric
        self.dims = dims
        # l2 cell width must bound the *euclidean* eps; for cosine eps we
        # verify with the cosine metric but grid in euclidean space
        # (d_l2 = sqrt(2*d_cos) on unit vectors).
        self.cell_eps = cell_eps
        rng = np.random.default_rng(seed)
        sample = self.R[rng.choice(len(self.R), min(4096, len(self.R)), replace=False)]
        mu = sample.mean(axis=0)
        _, _, vt = np.linalg.svd(sample - mu, full_matrices=False)
        self.mu, self.basis = mu, vt[:dims].T.astype(np.float32)  # [d, dims]
        self.proj = (self.R - mu) @ self.basis                    # [n, dims]
        self._build(self.cell_eps)

    def _l2_eps(self, eps: float) -> float:
        return float(np.sqrt(2.0 * eps)) if self.metric == "cosine" else float(eps)

    def _build(self, width: float):
        self.width = max(width, 1e-6)
        cells = np.floor(self.proj / self.width).astype(np.int64)
        key = self._cell_key(cells)
        order = np.argsort(key, kind="stable")
        self.sorted_key = key[order]
        self.sorted_ids = order.astype(np.int32)

    def _cell_key(self, cells: np.ndarray) -> np.ndarray:
        # pack 3 signed ints into one key (21 bits each)
        off = cells + (1 << 20)
        key = np.zeros(len(cells), np.int64)
        for d in range(self.dims):
            key = (key << 21) | (off[:, d] & ((1 << 21) - 1))
        return key

    def candidates(self, Q: np.ndarray, eps: float | None = None) -> np.ndarray:
        """Neighbor-cell candidate ids, int32 [q, C] (-1 padded) — the
        eps-aware probing half of the Searcher protocol (DESIGN.md §9).
        `eps` widens the grid when the current cells are too fine for the
        radius (exactness needs cell width >= the projected eps); callers
        that omit it probe at the current width."""
        Q = np.asarray(Q, np.float32)
        if eps is not None:
            width_needed = self._l2_eps(eps)
            if width_needed > self.width:   # grid too fine: rebuild coarser
                self._build(width_needed)
        qproj = (Q - self.mu) @ self.basis
        qcells = np.floor(qproj / self.width).astype(np.int64)

        # 27 neighbor cells
        offs = np.array(np.meshgrid(*([[-1, 0, 1]] * self.dims))).reshape(self.dims, -1).T
        # collect candidate ranges per query via searchsorted on sorted keys
        cand_lists = [[] for _ in range(len(Q))]
        max_c = 1
        for o in offs:
            keys = self._cell_key(qcells + o[None, :])
            lo = np.searchsorted(self.sorted_key, keys, side="left")
            hi = np.searchsorted(self.sorted_key, keys, side="right")
            for qi in range(len(Q)):
                if hi[qi] > lo[qi]:
                    cand_lists[qi].append(self.sorted_ids[lo[qi]:hi[qi]])
        for qi in range(len(Q)):
            if cand_lists[qi]:
                cand_lists[qi] = np.concatenate(cand_lists[qi])
                max_c = max(max_c, len(cand_lists[qi]))
            else:
                cand_lists[qi] = np.empty((0,), np.int32)
        cand = np.full((len(Q), max_c), -1, np.int32)
        for qi, c in enumerate(cand_lists):
            cand[qi, :len(c)] = c
        return cand

    def query_counts(self, Q: np.ndarray, eps: float) -> np.ndarray:
        """Exact eps-counts: probe the +-1 cell neighborhood, verify the
        candidates in full dimension on device."""
        Q = np.asarray(Q, np.float32)
        cand = self.candidates(Q, eps=float(eps))
        return verify_candidates(self.R, Q, cand, float(eps), self.metric)
