"""Learned-index join: an RMI over a pivot-distance projection of R.

The style of "A Learned Index for Exact Similarity Search in Metric
Spaces" (PAPERS.md): project every row of R onto a one-dimensional key —
its L2 distance to a pivot (the data centroid) — sort R by key, and train
the paper's `RMIEstimator` (models/rmi.py, otherwise dormant on the query
path) to map key -> rank in the sorted order. A range query with radius
eps can only match rows whose key falls in `[k(q) - r, k(q) + r]` (the
triangle inequality makes the projection contractive), so the candidate
set is one contiguous slice of the sorted order. Lookup is the classic
learned-index two-step: the RMI predicts each endpoint's rank, and a
LAST-MILE binary search pins the exact boundary — the model's measured
worst-case rank error (`max_err`) sizes the slab that search must
cover, and boundaries the slab fails to contain (the MLP is not
monotone between training keys, so an off-sample boundary key falling
in a key gap can be predicted far from its true rank) escalate to a
full binary search and are counted in `fallback_frac`, the per-query
quality metric of the learned bound. On this host numpy path both
searches are the same vectorized `np.searchsorted`; the slab-vs-full
distinction is the accounting that matters at serving scale, where the
slab is what keeps the search in cache.

Candidates are verified exactly (`common.verify_candidates`), so
precision is always 1, and the boundary search makes the key-space
window itself exact; what stays heuristic is the cosine -> key-radius
conversion (`sqrt(2 * eps)` assumes unit-normalized rows), so
`exact=False` and the recall floor is enforced in tests next to
lsh/ivfpq.

Host-probe only: `candidates(Q, eps)` / `query_counts(Q, eps)` — the
probe is eps-aware (`joins.common.searcher_candidates` passes the radius
through), and the engine's device verification consumes the candidate
slab like every other probing searcher.
"""
from __future__ import annotations

import numpy as np

from repro.core.joins.common import verify_candidates
from repro.models.rmi import RMIEstimator


class LearnedJoin:
    name = "learned"
    exact = False

    def __init__(self, R: np.ndarray, metric: str, *, stage_sizes=(1, 2),
                 widths=(64, 64), epochs: int = 24, lr: float = 1e-3,
                 batch_size: int = 256, seed: int = 0, **_):
        self.R = np.asarray(R, np.float32)
        self.metric = metric
        n = len(self.R)
        # pivot-distance projection: key(x) = ||x - centroid||_2
        self.pivot = self.R.mean(axis=0)
        keys = np.linalg.norm(self.R - self.pivot[None, :], axis=1)
        order = np.argsort(keys, kind="stable")
        self.sorted_ids = order.astype(np.int32)
        self.sorted_keys = keys[order].astype(np.float32)
        # normalize keys AND ranks to [0, 1] for the MLP (ranks rescale
        # back through self._n); raw 0..n ranks sit outside the net's
        # useful output range and fit to a useless all-of-R error bound
        self._klo = float(self.sorted_keys[0])
        self._kspan = max(float(self.sorted_keys[-1]) - self._klo, 1e-9)
        self._n = n
        X = ((self.sorted_keys - self._klo) / self._kspan)[:, None]
        ranks = np.arange(n, dtype=np.float32)
        self.rmi = RMIEstimator(1, stage_sizes, widths, lr=lr, epochs=epochs,
                                batch_size=batch_size, seed=seed,
                                log_target=False)
        self.rmi.fit(X, ranks / max(n - 1, 1))
        #: worst-case |predicted rank - true rank| over the index keys —
        #: the learned-index error bound that widens every query window
        pred = self.rmi.predict(X) * max(n - 1, 1)
        self.max_err = int(np.ceil(np.max(np.abs(pred - ranks)))) + 1
        #: fraction of the last query's window boundaries the RMI slab
        #: failed to contain (escalated to a full binary search)
        self.fallback_frac = 0.0

    def _key_radius(self, eps: float) -> float:
        """The query radius mapped into key (L2 pivot-distance) space:
        identity for l2; `sqrt(2 * eps)` for cosine distance on
        unit-normalized rows (d_l2^2 = 2 * d_cos) — same convention as
        the grid join."""
        if self.metric == "cosine":
            return float(np.sqrt(max(2.0 * eps, 0.0)))
        return float(eps)

    def _rank_of(self, keys: np.ndarray) -> np.ndarray:
        """RMI-predicted (float) rank of each key in the sorted order."""
        x = ((np.asarray(keys, np.float32) - self._klo) / self._kspan)[:, None]
        return self.rmi.predict(x) * max(self._n - 1, 1)

    def candidates(self, Q: np.ndarray, eps: float | None = None) -> np.ndarray:
        """int32 [q, C] candidate ids (-1 padded): for each query, the
        sorted-order slice whose keys can lie within `eps` of the query's
        pivot distance — endpoint ranks predicted by the RMI, pinned
        exactly by the last-mile binary search (see module docstring),
        with slab misses accounted in `fallback_frac`. `eps=None`
        degenerates to the point window (ids sharing the query's key)."""
        Q = np.asarray(Q, np.float32)
        n = len(self.sorted_ids)
        kq = np.linalg.norm(Q - self.pivot[None, :], axis=1)
        r = 0.0 if eps is None else self._key_radius(float(eps))
        # last-mile boundary search (exact), then check the model slab
        # would have contained each boundary
        lo = np.searchsorted(self.sorted_keys, kq - r, side="left")
        hi = np.searchsorted(self.sorted_keys, kq + r, side="right")
        contained = ((np.abs(self._rank_of(kq - r) - lo) <= self.max_err)
                     & (np.abs(self._rank_of(kq + r) - hi) <= self.max_err))
        self.fallback_frac = (float(1.0 - contained.mean())
                              if len(kq) else 0.0)
        width = max(int((hi - lo).max()), 1)
        idx = lo[:, None] + np.arange(width, dtype=np.int64)[None, :]
        valid = idx < hi[:, None]
        cand = np.where(valid, self.sorted_ids[np.minimum(idx, n - 1)],
                        np.int32(-1))
        return cand.astype(np.int32)

    def query_counts(self, Q: np.ndarray, eps: float) -> np.ndarray:
        """Exact eps-counts over the predicted slice (device verify)."""
        cand = self.candidates(np.asarray(Q, np.float32), float(eps))
        return verify_candidates(self.R, Q, cand, float(eps), self.metric)
