"""Locality-Sensitive Bloom Filter (Hua et al. 2012) — the MSBF baseline.

Mirrors a Bloom filter with LSH functions: an item sets l bits (one per
hash group of k LSH functions, each group's values hashed to a position in
the bit array). A query is POSITIVE iff at least `theta` fraction of its l
probe bits are set. This is the filter the paper's Naive-LSBF baseline
gates the nested-loop join with — and the structure whose data-unawareness
(problems 1-3 in §I) Xling is designed to fix.
"""
from __future__ import annotations

import numpy as np


class LSBF:
    name = "lsbf"

    def __init__(self, R: np.ndarray, metric: str, *, k: int = 18, l: int = 10,
                 n_bits: int | None = None, W: float = 2.5, theta: float = 1.0,
                 seed: int = 0, **_):
        R = np.asarray(R, np.float32)
        self.metric = metric
        self.k, self.l, self.W, self.theta = k, l, W, theta
        self.n_bits = n_bits or (len(R) * k)     # paper: |R| * k
        rng = np.random.default_rng(seed)
        d = R.shape[1]
        self.proj = rng.normal(size=(l, k, d)).astype(np.float32)
        self.bias = rng.uniform(0, W, size=(l, k)).astype(np.float32)
        self.salt = rng.integers(1, 2 ** 31, size=(l, k)).astype(np.int64)
        self.bits = np.zeros((self.n_bits,), bool)
        self.bits[self._positions(R).reshape(-1)] = True

    def _positions(self, X: np.ndarray) -> np.ndarray:
        """[n, l] bit positions."""
        h = np.einsum("nd,lkd->nlk", X.astype(np.float32), self.proj)
        if self.metric == "cosine":
            codes = (h > 0).astype(np.int64)
        else:
            codes = np.floor((h + self.bias[None]) / self.W).astype(np.int64)
        mixed = (codes * self.salt[None]).sum(axis=2)
        return (mixed % self.n_bits).astype(np.int64)

    def query(self, Q: np.ndarray) -> np.ndarray:
        """bool verdicts [q]: True = predicted to have a neighbor."""
        pos = self._positions(np.asarray(Q, np.float32))      # [q, l]
        frac = self.bits[pos].mean(axis=1)
        return frac >= self.theta
