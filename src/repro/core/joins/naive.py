"""Naive brute-force nested-loop join (the paper's ground-truth method).

Exact: every query is ranged against all of R through the fused
range_count kernel. Results serve as ground truth for recall of every
other method (paper §VI-A).
"""
from __future__ import annotations

import numpy as np

from repro.kernels import ops


class NaiveJoin:
    name = "naive"
    exact = True

    def __init__(self, R: np.ndarray, metric: str, *, backend: str = "auto",
                 block_q: int = 2048, **_):
        self.R = np.asarray(R, np.float32)
        self.metric = metric
        self.backend = backend
        self.block_q = block_q

    def query_counts(self, Q: np.ndarray, eps: float) -> np.ndarray:
        out = []
        for i in range(0, len(Q), self.block_q):
            cnt = ops.range_count(Q[i:i + self.block_q], self.R, float(eps),
                                  metric=self.metric, backend=self.backend)
            out.append(np.asarray(cnt))
        return np.concatenate(out)
