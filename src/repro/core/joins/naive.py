"""Naive brute-force nested-loop join (the paper's ground-truth method).

Exact: every query is ranged against all of R. The sweep runs through the
device-resident JoinEngine — R is transferred once at build time and every
`query_counts` call is a single (optionally mesh-sharded) device program
with bucketed static shapes, not a host loop over NumPy blocks. Results
serve as ground truth for recall of every other method (paper §VI-A).
"""
from __future__ import annotations

import numpy as np

from repro.core.engine import JoinEngine


class NaiveJoin:
    name = "naive"
    exact = True

    def __init__(self, R: np.ndarray, metric: str, *, backend: str = "auto",
                 block_q: int = 256, engine: JoinEngine | None = None,
                 mesh=None, **_):
        self.R = np.asarray(R, np.float32)
        self.metric = metric
        self.backend = backend
        # block_q is the engine's per-device query tile (ignored when an
        # already-built engine is shared in)
        self.engine = engine if engine is not None else JoinEngine(
            self.R, metric, mesh=mesh, backend=backend, block_q=block_q)

    def query_counts(self, Q: np.ndarray, eps: float) -> np.ndarray:
        return self.engine.range_count(Q, float(eps))
