"""The paper's primary contribution:

  xling.py — the learned metric-space Bloom filter (estimator + XDT)
  atcs.py  — adaptive training-condition selection (Algorithm 1)
  xdt.py   — FPR/mean XDT selection + Eq. 2 interpolated targets
  xjoin.py — XJoin and the generic filter-plugin join wrapper
  joins/   — baseline join methods (naive/grid/LSH/LSBF/kmeans-tree/IVFPQ)
"""
from repro.core.xling import XlingConfig, XlingFilter
from repro.core.xjoin import FilteredJoin, JoinResult, build_xjoin, enhance_with_xling
from repro.core.engine import JoinEngine, sharded_range_count_hist
from repro.core import atcs, xdt
from repro.core.joins import JOINS, make_join

__all__ = ["XlingConfig", "XlingFilter", "FilteredJoin", "JoinResult",
           "build_xjoin", "enhance_with_xling", "JoinEngine",
           "sharded_range_count_hist", "atcs", "xdt", "JOINS", "make_join"]
