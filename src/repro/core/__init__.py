"""The paper's primary contribution:

  api.py   — the protocol-first public surface: JoinPlan + Filter/Searcher
  xling.py — the learned metric-space Bloom filter (estimator + XDT)
  atcs.py  — adaptive training-condition selection (Algorithm 1)
  xdt.py   — FPR/mean XDT selection + Eq. 2 interpolated targets
  xjoin.py — legacy XJoin shims (FilteredJoin et al.) over JoinPlan
  joins/   — join methods on the Searcher protocol (naive/grid/LSH/
             LSBF/kmeans-tree/IVFPQ)
  topology.py — engine placement layer (Replicated / RingSharded)
"""
from repro.core.api import (DeviceSearcher, Filter, JoinPlan, JoinResult,
                            Searcher, as_filter)
from repro.core.xling import XlingConfig, XlingFilter
from repro.core.xjoin import FilteredJoin, build_xjoin, enhance_with_xling
from repro.core.engine import (JoinEngine, clear_program_cache,
                               sharded_range_count_hist)
from repro.core.topology import (TOPOLOGIES, Replicated, RingSharded,
                                 Topology, resolve_topology)
from repro.core import atcs, xdt
from repro.core.joins import JOINS, make_join

__all__ = ["Filter", "Searcher", "DeviceSearcher", "JoinPlan", "JoinResult",
           "as_filter",
           "XlingConfig", "XlingFilter", "FilteredJoin",
           "build_xjoin", "enhance_with_xling", "JoinEngine",
           "clear_program_cache", "sharded_range_count_hist",
           "TOPOLOGIES", "Topology", "Replicated", "RingSharded",
           "resolve_topology", "atcs", "xdt", "JOINS", "make_join"]
