"""Adaptive Training Condition Selection (paper Algorithm 1).

Given, for each point p, the uniformly-sampled candidate conditions C_p
(the shared eps grid, |C_p| = m) and their targets T_p (ground-truth
cardinalities), pick s conditions per point:

  1. split [t_min, t_max] into s even bins,
  2. place each (c, t) pair into its bin by target,
  3. draw floor(s*|B_i|/|C_p|) pairs from each bin (density-proportional),
  4. top up to s with random draws from the not-yet-selected pairs.

The output is the per-point index set into the eps grid; the caller builds
the (p, eps, t) training tuples from it. `uniform_select` is the paper's
"fixed" baseline strategy.
"""
from __future__ import annotations

import numpy as np


def uniform_select(targets: np.ndarray, s: int, *, seed: int = 0) -> np.ndarray:
    """Evenly spaced condition indices (same for every point). [n, s] int."""
    n, m = targets.shape
    idx = np.linspace(0, m - 1, s).round().astype(np.int64)
    return np.broadcast_to(idx, (n, s)).copy()


def atcs_select(targets: np.ndarray, s: int, *, seed: int = 0) -> np.ndarray:
    """Algorithm 1 over the full table. targets: [n, m]. Returns [n, s] int
    indices into the condition grid (distinct per row)."""
    n, m = targets.shape
    if s >= m:
        return np.broadcast_to(np.arange(m), (n, m)).copy()
    rng = np.random.default_rng(seed)
    t = targets.astype(np.float64)

    t_min = t.min(axis=1, keepdims=True)                     # line 5
    t_max = t.max(axis=1, keepdims=True)
    span = np.maximum(t_max - t_min, 1e-12)
    # line 6-8: bin of each (c, t): s even bins over [t_min, t_max]
    bin_of = np.minimum((s * (t - t_min) / span).astype(np.int64), s - 1)  # [n, m]

    # line 10-11: per-bin quota floor(s * |B_i| / m); sample that many from
    # each bin. Vectorized: shuffle within rows, sort by (bin, shuffle key),
    # then mark the first quota_i entries of each bin run.
    shuffle_key = rng.random((n, m))
    order = np.lexsort((shuffle_key, bin_of), axis=1)        # [n, m] col indices
    bins_sorted = np.take_along_axis(bin_of, order, axis=1)
    # position of each element within its bin run:
    bin_counts = np.zeros((n, s), np.int64)
    for b in range(s):
        bin_counts[:, b] = (bin_of == b).sum(axis=1)
    quota = (s * bin_counts) // m                            # [n, s]
    # rank within run = index - start of run
    starts = np.concatenate([np.zeros((n, 1), np.int64),
                             np.cumsum(bin_counts, axis=1)[:, :-1]], axis=1)
    pos = np.arange(m)[None, :] - np.take_along_axis(starts, bins_sorted, axis=1)
    chosen = pos < np.take_along_axis(quota, bins_sorted, axis=1)  # [n, m] in sorted order

    # line 12-13: top up to s with random unselected pairs
    deficit = s - chosen.sum(axis=1)                         # [n]
    # random priority for the fill among unchosen
    fill_key = rng.random((n, m))
    fill_key[chosen] = np.inf                                # already selected
    fill_rank = np.argsort(np.argsort(fill_key, axis=1), axis=1)
    chosen |= fill_rank < deficit[:, None]

    sel_sorted_pos = np.argsort(~chosen, axis=1, kind="stable")[:, :s]  # positions in sorted order
    out = np.take_along_axis(order, sel_sorted_pos, axis=1)
    out.sort(axis=1)
    return out


def build_training_tuples(points: np.ndarray, eps_grid: np.ndarray,
                          targets: np.ndarray, select_idx: np.ndarray
                          ) -> tuple[np.ndarray, np.ndarray]:
    """Materialize (p ++ eps) features and targets from selected indices.

    Returns X [n*s, d+1] float32, y [n*s] float32.
    """
    n, s = select_idx.shape
    d = points.shape[1]
    X = np.empty((n * s, d + 1), np.float32)
    X[:, :d] = np.repeat(points, s, axis=0)
    X[:, d] = eps_grid[select_idx].reshape(-1)
    y = np.take_along_axis(targets, select_idx, axis=1).reshape(-1).astype(np.float32)
    return X, y
