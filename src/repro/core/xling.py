"""Xling: the learned metric-space Bloom filter (paper §IV).

Composition (Fig. 1): a learned cardinality estimator (any registry model)
+ the XDT decision threshold, trained offline on the R side of the join:

    fit:    R --(range_count kernel)--> target table over the eps grid
              --(ATCS, Alg. 1)--> s training tuples/point --> estimator
    query:  (q, eps, tau) --> predicted count  vs  XDT(eps, tau) --> +/-

"Filtering-by-counting": tau > 0 asks "more than tau neighbors", not just
"any neighbor"; tau = 0 degrades Xling to a classic MSBF.

XDT is computed offline per (eps, tau, mode) from training-set predictions
and Eq.-2-interpolated targets, and cached — zero online overhead (§V-B).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core import atcs as atcs_mod
from repro.core import xdt as xdt_mod
from repro.data.groundtruth import cardinality_table, eps_grid_for_metric
from repro.models import make_estimator


@dataclass
class XlingConfig:
    estimator: str = "rmi"            # registry key
    metric: str = "cosine"
    m: int = 100                      # candidate-condition grid size
    s: int = 6                        # ATCS sampling number (paper: 6)
    strategy: str = "atcs"            # "atcs" | "uniform"
    xdt_mode: str = "fpr"             # "fpr" | "mean"
    fpr_tolerance: float = 0.05
    target_mode: str = "interp"       # "interp" | "exact"
    epochs: int = 30
    lr: float = 1e-3
    batch_size: int = 512
    seed: int = 0
    backend: str = "auto"             # kernel backend for counting/inference
    estimator_kwargs: dict = field(default_factory=dict)


class XlingFilter:
    """Trained filter. Use `fit(R)` then `query(Q, eps, tau)`."""

    def __init__(self, cfg: XlingConfig):
        self.cfg = cfg
        self.eps_grid = eps_grid_for_metric(cfg.metric, cfg.m)
        self.estimator = None
        self.train_points: Optional[np.ndarray] = None
        self.target_table: Optional[np.ndarray] = None   # [n, m] ground truth
        self._train_preds_cache: dict = {}
        self._xdt_cache: dict = {}
        self.stats: dict = {}

    # ------------------------------------------------------------------ fit
    def fit(self, R: np.ndarray, *, cache_key: tuple | None = None,
            target_table: np.ndarray | None = None, mesh=None,
            engine=None) -> "XlingFilter":
        cfg = self.cfg
        self.train_points = np.asarray(R, np.float32)
        if target_table is None:
            # engine= reuses an already-device-resident R for the
            # ground-truth sweep (JoinPlan passes its own engine in)
            target_table = cardinality_table(
                self.train_points, self.train_points, self.eps_grid, cfg.metric,
                backend=cfg.backend, cache_key=cache_key, exclude_self=True,
                mesh=mesh, engine=engine)
        self.target_table = target_table

        select = (atcs_mod.atcs_select if cfg.strategy == "atcs"
                  else atcs_mod.uniform_select)
        idx = select(self.target_table, cfg.s, seed=cfg.seed)
        X, y = atcs_mod.build_training_tuples(self.train_points, self.eps_grid,
                                              self.target_table, idx)
        din = self.train_points.shape[1] + 1
        self.estimator = make_estimator(
            cfg.estimator, din, epochs=cfg.epochs, lr=cfg.lr,
            batch_size=cfg.batch_size, seed=cfg.seed, **cfg.estimator_kwargs)
        loss = self.estimator.fit(X, y)
        self.stats = {"train_tuples": len(X), "final_loss": loss}
        return self

    # ------------------------------------------------------------ prediction
    def predict_counts(self, Q: np.ndarray, eps: float) -> np.ndarray:
        X = np.concatenate([np.asarray(Q, np.float32),
                            np.full((len(Q), 1), eps, np.float32)], axis=1)
        return self.estimator.predict(X, backend=self.cfg.backend)

    def _train_predictions(self, eps: float, predict=None) -> np.ndarray:
        """Training-set predictions for XDT calibration. `predict` =
        (params, fn) from the estimator's `device_predict_fn()` calibrates
        through the SAME inference implementation the engine serves with
        (host `predict` and the device fn can differ by float-accumulation
        noise, which matters exactly at the threshold)."""
        key = (round(float(eps), 9), "host" if predict is None else "device")
        if key not in self._train_preds_cache:
            if predict is None:
                preds = self.predict_counts(self.train_points, eps)
            else:
                import jax
                import jax.numpy as jnp
                params, fn = predict
                X = np.concatenate(
                    [self.train_points,
                     np.full((len(self.train_points), 1), eps, np.float32)],
                    axis=1)
                # jit: compiled like the engine's serving program (and not
                # per-op eager over all of R); result cached per (eps, impl)
                preds = np.asarray(jax.jit(fn)(params, jnp.asarray(X)),
                                   np.float32)
            self._train_preds_cache[key] = preds
        return self._train_preds_cache[key]

    def _targets_at(self, eps: float) -> np.ndarray:
        if self.cfg.target_mode == "interp":
            return xdt_mod.interp_targets(self.eps_grid, self.target_table, eps)
        # "exact": the naive method — a fresh range count at this eps.
        # Clamp at 0 after the self-match subtraction (mirrors
        # cardinality_table): an isolated point has count 1 (itself) and
        # must target 0, not -1, or it biases XDT selection low.
        from repro.kernels import ops
        cnt = np.asarray(ops.range_count(self.train_points, self.train_points,
                                         float(eps), metric=self.cfg.metric,
                                         backend=self.cfg.backend))
        return np.maximum(cnt - 1, 0)

    def xdt(self, eps: float, tau: int = 0, *, mode: str | None = None,
            fpr_tolerance: float | None = None, predict=None) -> float:
        mode = mode or self.cfg.xdt_mode
        tol = self.cfg.fpr_tolerance if fpr_tolerance is None else fpr_tolerance
        key = (round(float(eps), 9), int(tau), mode, round(tol, 6),
               self.cfg.target_mode, "host" if predict is None else "device")
        if key not in self._xdt_cache:
            preds = self._train_predictions(eps, predict)
            targets = self._targets_at(eps)
            self._xdt_cache[key] = xdt_mod.select_xdt(preds, targets, tau,
                                                      mode=mode, fpr_tolerance=tol)
        return self._xdt_cache[key]

    def query(self, Q: np.ndarray, eps: float, tau: int = 0, *,
              mode: str | None = None, fpr_tolerance: float | None = None
              ) -> tuple[np.ndarray, np.ndarray]:
        """Returns (positive verdicts bool [q], predicted counts float [q])."""
        thr = self.xdt(eps, tau, mode=mode, fpr_tolerance=fpr_tolerance)
        preds = self.predict_counts(Q, eps)
        return preds > thr, preds

    # ---------------------------------------------------------- persistence
    def save(self, path: str) -> None:
        blob = {"eps_grid": self.eps_grid, "target_table": self.target_table,
                "train_points": self.train_points,
                "cfg_estimator": np.asarray(self.cfg.estimator),
                "cfg_metric": np.asarray(self.cfg.metric)}
        for k, v in self.estimator.state_dict().items():
            blob[f"est_{k}"] = v
        np.savez_compressed(path, **blob)

    @classmethod
    def load(cls, path: str, cfg: XlingConfig | None = None) -> "XlingFilter":
        with np.load(path, allow_pickle=False) as z:
            cfg = cfg or XlingConfig(estimator=str(z["cfg_estimator"]),
                                     metric=str(z["cfg_metric"]))
            obj = cls(cfg)
            obj.eps_grid = z["eps_grid"]
            obj.target_table = z["target_table"]
            obj.train_points = z["train_points"]
            est_state = {k[4:]: z[k] for k in z.files if k.startswith("est_")}
        din = obj.train_points.shape[1] + 1
        obj.estimator = make_estimator(cfg.estimator, din, **cfg.estimator_kwargs)
        obj.estimator.load_state_dict(est_state)
        return obj
