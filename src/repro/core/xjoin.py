"""Legacy XJoin surface — thin shims over the protocol-first `JoinPlan`.

`FilteredJoin`, `build_xjoin`, and `enhance_with_xling` predate the
declarative API in `core/api.py` (DESIGN.md §9) and are kept working for
existing callers; new code should build a `JoinPlan` directly:

    from repro.core import JoinPlan
    plan = JoinPlan(R, metric).filter("xling", tau=50, xdt="fpr").search("lsh")
    res = plan.run(Q, eps)

Each shim maps its parameters onto a plan once at construction time — so
configuration errors (e.g. an approximate `verify` backend without the
engine path) surface immediately, not on the first `run()` — and then
delegates `run` / `run_stream` to `JoinPlan.run` / `JoinPlan.stream`.
Filter dispatch goes through the `Filter` protocol adapters (`as_filter`),
not isinstance chains; any base method with `candidates()` routes its
positives through the engine's device candidate verification
(DESIGN.md §9), which supersedes the old host-compaction path.

Paper default configs (§VI-A):
  * XJoin            = Naive base + FPR-based XDT (5% tolerance), tau = 50
  * <method>-Xling   = method base + mean-based XDT, tau = 0
"""
from __future__ import annotations

from typing import Iterable, Iterator, Optional

import numpy as np

# _bucket_size is re-exported for legacy importers (tests/test_property.py)
from repro.core.api import JoinPlan, JoinResult
from repro.core.engine import JoinEngine, _bucket_size  # noqa: F401
from repro.core.joins import make_join
from repro.core.joins.naive import NaiveJoin
from repro.core.xling import XlingConfig, XlingFilter

__all__ = ["FilteredJoin", "JoinResult", "build_xjoin", "enhance_with_xling"]


class FilteredJoin:
    """Filter-then-verify join: any base method gated by any filter
    (legacy shim over `JoinPlan`).

    The shim keeps the historical constructor and attributes but compiles
    its configuration into a `JoinPlan` at construction time: a naive base
    runs the fused engine path (DESIGN.md §4), any other base routes its
    predicted positives through the engine's device candidate verification
    via the base's `candidates()` (DESIGN.md §9). `verify` picks the
    verification backend — "exact" (the base's own route) or "lsh"/"ivfpq"
    (approximate probe + device verification; requires a NaiveJoin base
    sharing this join's engine, enforced here at construction)."""

    def __init__(self, base, *, filter=None, tau: int = 0,
                 xdt_mode: Optional[str] = None,
                 fpr_tolerance: Optional[float] = None, block: int = 512,
                 engine: Optional[JoinEngine] = None, verify: str = "exact"):
        self.base = base
        self.filter = filter
        self.tau = tau
        self.xdt_mode = xdt_mode
        self.fpr_tolerance = fpr_tolerance
        self.block = block
        self.engine = engine
        self.verify = verify
        if verify != "exact" and not self._engine_usable():
            raise ValueError(
                "verify backends other than 'exact' need the engine path "
                "(NaiveJoin base sharing this FilteredJoin's engine); for "
                "plug-in verification on other bases build a JoinPlan and "
                "use plan.verify(...)")
        plan = JoinPlan(base.R, base.metric).search(base)
        if filter is not None:
            plan.filter(filter, tau=tau, xdt=xdt_mode,
                        fpr_tolerance=fpr_tolerance)
        # engine choice: the caller's engine when given (the plan's build
        # validates it is over the base's exact (R, metric) — a foreign
        # index set fails at construction instead of silently verifying
        # against the wrong R), else the naive base's own; other bases
        # without a caller engine get a fresh engine over base.R
        eng = engine if engine is not None else (
            base.engine if isinstance(base, NaiveJoin) else None)
        plan.on(engine=eng, block=block,
                backend=getattr(base, "backend", "auto"))
        plan.verify(verify if verify != "exact" else "auto")
        self._plan = plan.build()   # all validation at construction time

    def _engine_usable(self) -> bool:
        """The fused exact verify is brute-force vs the engine's R — only
        valid when the engine IS the base naive search's engine (identity,
        not just shape: a same-sized engine over a different R would
        silently verify against the wrong index set)."""
        return (self.engine is not None and isinstance(self.base, NaiveJoin)
                and self.engine is self.base.engine)

    def run(self, Q: np.ndarray, eps: float) -> JoinResult:
        """One synchronous join pass over a query batch (delegates to
        `JoinPlan.run`: fused filter -> compact -> verify on the engine)."""
        return self._plan.run(Q, eps)

    def run_stream(self, batches: Iterable[np.ndarray], eps: float, *,
                   depth: int = 2) -> Iterator[JoinResult]:
        """Serving form: yields one JoinResult per query batch, in order,
        through the asynchronous double-buffered pipeline (DESIGN.md §5);
        bit-identical to per-batch `run` calls (delegates to
        `JoinPlan.stream`)."""
        return self._plan.stream(batches, eps, depth=depth)


# ---------------------------------------------------------------- factories
def build_xjoin(R: np.ndarray, metric: str, *, xling_cfg: XlingConfig | None = None,
                tau: int = 50, fpr_tolerance: float = 0.05,
                cache_key: tuple | None = None, block: int = 512,
                backend: str = "auto", mesh=None,
                engine: JoinEngine | None = None,
                verify: str = "exact") -> FilteredJoin:
    """The paper's XJoin: brute-force base + Xling (FPR-XDT, tau=50),
    executed through a (optionally mesh-sharded) JoinEngine. `verify`
    selects the verification backend ("exact" | "lsh" | "ivfpq"); tune the
    approximate index by pre-building it via `engine.verifier(name, ...)`.
    Legacy shim — equivalent to `JoinPlan(R, metric).filter("xling",
    tau=tau, xdt="fpr").search("naive").verify(verify).on(...)`.
    """
    cfg = xling_cfg or XlingConfig(metric=metric, xdt_mode="fpr",
                                   fpr_tolerance=fpr_tolerance, backend=backend)
    filt = XlingFilter(cfg).fit(R, cache_key=cache_key, mesh=mesh)
    if engine is None:
        engine = JoinEngine(R, metric, mesh=mesh, backend=backend, block=block)
    base = make_join("naive", R, metric, backend=backend, engine=engine)
    return FilteredJoin(base, filter=filt, tau=tau, xdt_mode="fpr",
                        fpr_tolerance=fpr_tolerance, block=block,
                        engine=engine, verify=verify)


def enhance_with_xling(base, filt: XlingFilter, *, tau: int = 0,
                       block: int = 512) -> FilteredJoin:
    """<method>-Xling (paper: mean-based XDT, tau=0 to minimize added
    loss). Legacy shim — equivalent to `JoinPlan(base.R,
    base.metric).filter(filt, tau=tau, xdt="mean").search(base)`.

    Note: a non-naive base gets its own device-resident engine per call;
    when building MANY variants over one R (parameter sweeps), prefer the
    plan form with a shared `on(engine=...)` so R is uploaded once — see
    benchmarks/bench_tradeoff.py."""
    return FilteredJoin(base, filter=filt, tau=tau, xdt_mode="mean", block=block)
