"""XJoin and the generic Xling-plugin wrapper (paper §IV-C).

FilteredJoin composes ANY base join method with ANY filter (Xling or the
LSBF baseline): the filter predicts which queries have more than tau
neighbors, and only those are ranged by the base method.

TPU-native skipping (DESIGN.md §3): predicted-positive queries are
*compacted* into static-shape blocks (power-of-two bucketed to bound
recompiles) rather than masked — skipped queries genuinely cost nothing on
device. Negatives are reported with 0 found neighbors.

Execution (DESIGN.md §4): given a `JoinEngine`, the whole hot path —
estimator inference, XDT comparison, positive-query compaction and
verification — runs as fused device programs against the engine's resident
R (sharded over the mesh's data axis when the engine has one). Without an
engine, or for base methods that are not the exact brute-force search, the
original host-side compaction path is used.

Streaming & verification backends (DESIGN.md §5): `run_stream` serves
query batches through the engine's asynchronous double-buffered pipeline
(batch k+1 dispatches while batch k's results transfer back; `depth`
bounds the in-flight queue), and `verify="lsh"` / `"ivfpq"` swap the
exact verification sweep for an approximate index probe + on-device
candidate verification — sub-linear in |R|, recall measured against the
exact oracle.

Paper default configs (§VI-A):
  * XJoin            = Naive base + FPR-based XDT (5% tolerance), tau = 50
  * <method>-Xling   = method base + mean-based XDT, tau = 0
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Iterable, Iterator, Optional

import numpy as np

from repro.core.engine import JoinEngine, _bucket_size
from repro.core.joins import make_join
from repro.core.joins.lsbf import LSBF
from repro.core.joins.naive import NaiveJoin
from repro.core.xling import XlingConfig, XlingFilter


@dataclass
class JoinResult:
    """Per-call join outcome: exact-at-candidates neighbor counts plus the
    filter/search timing split and provenance metadata."""
    counts: np.ndarray
    n_queries: int
    n_searched: int
    t_filter: float
    t_search: float
    meta: dict = field(default_factory=dict)

    @property
    def t_total(self) -> float:
        """Filter + search wall-clock for this call."""
        return self.t_filter + self.t_search

    def recall_vs(self, true_counts: np.ndarray) -> float:
        """Pair-level recall: found pairs over true pairs (count-based —
        exact for exact searchers; an upper-bound-free measure for
        approximate searchers since found <= true per query)."""
        denom = float(np.sum(true_counts))
        if denom == 0:
            return 1.0
        return float(np.sum(np.minimum(self.counts, true_counts)) / denom)


class FilteredJoin:
    """Filter-then-verify join: any base method gated by any filter.

    With an `engine` (and a NaiveJoin base over the same engine) the hot
    path runs fused on device; `verify` then picks the verification
    backend — "exact" (brute-force sweep) or "lsh"/"ivfpq" (approximate
    probe + on-device candidate verification, DESIGN.md §5)."""

    def __init__(self, base, *, filter=None, tau: int = 0,
                 xdt_mode: Optional[str] = None,
                 fpr_tolerance: Optional[float] = None, block: int = 512,
                 engine: Optional[JoinEngine] = None, verify: str = "exact"):
        self.base = base
        self.filter = filter
        self.tau = tau
        self.xdt_mode = xdt_mode
        self.fpr_tolerance = fpr_tolerance
        self.block = block
        self.engine = engine
        self.verify = verify

    def _verdicts(self, Q: np.ndarray, eps: float) -> np.ndarray:
        f = self.filter
        if f is None:
            return np.ones((len(Q),), bool)
        if isinstance(f, XlingFilter):
            pos, _ = f.query(Q, eps, self.tau, mode=self.xdt_mode,
                             fpr_tolerance=self.fpr_tolerance)
            return pos
        if isinstance(f, LSBF):
            return f.query(Q)
        if callable(f):
            return np.asarray(f(Q, eps), bool)
        raise TypeError(f"unsupported filter {type(f)}")

    # ----------------------------------------------------------- engine path
    def _engine_usable(self) -> bool:
        """The fused verify is exact brute-force vs the engine's R — only
        valid when the engine IS the base naive search's engine (identity,
        not just shape: a same-sized engine over a different R would
        silently verify against the wrong index set)."""
        return (self.engine is not None and isinstance(self.base, NaiveJoin)
                and self.engine is self.base.engine)

    def _device_filter_args(self, eps: float):
        """(predict, threshold) for the fused device filter, or (None, None)
        when the filter must run on host (per-batch `verdicts` instead).
        The XDT threshold is calibrated through the same device fn that
        will produce the online predictions (float-parity at the boundary);
        for a serving stream this selection happens once, up front."""
        f = self.filter
        if (isinstance(f, XlingFilter)
                and hasattr(f.estimator, "device_predict_fn")):
            predict = f.estimator.device_predict_fn()
            threshold = f.xdt(eps, self.tau, mode=self.xdt_mode,
                              fpr_tolerance=self.fpr_tolerance,
                              predict=predict)
            return predict, threshold
        return None, None

    def _wrap_engine_result(self, res, n: int, eps: float,
                            t_host: float = 0.0) -> JoinResult:
        f = self.filter
        return JoinResult(
            counts=res.counts, n_queries=n, n_searched=res.n_searched,
            t_filter=res.t_filter + t_host, t_search=res.t_search,
            meta={"eps": eps, "tau": self.tau,
                  "base": getattr(self.base, "name", "?"),
                  "filter": type(f).__name__ if f else None,
                  "engine": True, "verify": res.verify})

    def _run_engine(self, Q: np.ndarray, eps: float) -> JoinResult:
        t0 = time.perf_counter()
        predict, threshold = self._device_filter_args(eps)
        verdicts = None if predict is not None else self._verdicts(Q, eps)
        t_host = time.perf_counter() - t0   # host filter / XDT-selection cost
        res = self.engine.filtered_join(Q, eps, predict=predict,
                                        threshold=threshold, verdicts=verdicts,
                                        block=self.block, verify=self.verify)
        return self._wrap_engine_result(res, len(Q), eps, t_host)

    # -------------------------------------------------------------- host path
    def run(self, Q: np.ndarray, eps: float) -> JoinResult:
        """One synchronous join pass over a query batch (engine-fused when
        `_engine_usable`, host compaction otherwise)."""
        Q = np.asarray(Q, np.float32)
        if self._engine_usable():
            return self._run_engine(Q, eps)
        if self.verify != "exact":
            raise ValueError(
                "verify backends other than 'exact' need the engine path "
                "(NaiveJoin base sharing this FilteredJoin's engine)")
        t0 = time.perf_counter()
        pos = self._verdicts(Q, eps)
        t_filter = time.perf_counter() - t0

        counts = np.zeros((len(Q),), np.int32)
        idx = np.nonzero(pos)[0]
        t1 = time.perf_counter()
        if len(idx):
            # compaction: gather positives, pad to a bucketed static size
            n_pad = _bucket_size(len(idx), self.block)
            qpos = Q[idx]
            if n_pad > len(idx):
                qpos = np.concatenate(
                    [qpos, np.repeat(qpos[:1], n_pad - len(idx), axis=0)])
            found = self.base.query_counts(qpos, eps)[: len(idx)]
            counts[idx] = found
        t_search = time.perf_counter() - t1
        return JoinResult(counts=counts, n_queries=len(Q), n_searched=len(idx),
                          t_filter=t_filter, t_search=t_search,
                          meta={"eps": eps, "tau": self.tau,
                                "base": getattr(self.base, "name", "?"),
                                "filter": type(self.filter).__name__ if self.filter else None})

    def run_stream(self, batches: Iterable[np.ndarray], eps: float, *,
                   depth: int = 2) -> Iterator[JoinResult]:
        """Serving form: yields one JoinResult per query batch, in order.

        On the engine path this is the asynchronous double-buffered
        pipeline (DESIGN.md §5): batch k+1's programs dispatch while batch
        k's results transfer back; `depth` bounds the in-flight queue
        (`depth=0` ≈ synchronous). Results are bit-identical to per-batch
        `run` calls. Off the engine path it degrades to per-batch `run`.
        """
        if not self._engine_usable():
            for Q in batches:
                yield self.run(np.asarray(Q, np.float32), eps)
            return
        t0 = time.perf_counter()
        predict, threshold = self._device_filter_args(eps)
        t_host = time.perf_counter() - t0   # one-time XDT selection cost
        sess = self.engine.stream_session(eps, predict=predict,
                                          threshold=threshold,
                                          verify=self.verify, depth=depth,
                                          block=self.block)
        pending: list[tuple[int, float]] = []   # FIFO of (n, host cost)

        def _emit(results):
            for res in results:
                n, th = pending.pop(0)
                yield self._wrap_engine_result(res, n, eps, th)

        for Q in batches:
            Q = np.asarray(Q, np.float32)
            t1 = time.perf_counter()
            verdicts = None if predict is not None else self._verdicts(Q, eps)
            th = t_host + (time.perf_counter() - t1)
            t_host = 0.0                    # charge XDT selection to batch 0
            pending.append((len(Q), th))
            yield from _emit(sess.submit(Q, verdicts=verdicts))
        yield from _emit(sess.flush())


# ---------------------------------------------------------------- factories
def build_xjoin(R: np.ndarray, metric: str, *, xling_cfg: XlingConfig | None = None,
                tau: int = 50, fpr_tolerance: float = 0.05,
                cache_key: tuple | None = None, block: int = 512,
                backend: str = "auto", mesh=None,
                engine: JoinEngine | None = None,
                verify: str = "exact") -> FilteredJoin:
    """The paper's XJoin: brute-force base + Xling (FPR-XDT, tau=50),
    executed through a (optionally mesh-sharded) JoinEngine. `verify`
    selects the verification backend ("exact" | "lsh" | "ivfpq"); tune the
    approximate index by pre-building it via `engine.verifier(name, ...)`.
    """
    cfg = xling_cfg or XlingConfig(metric=metric, xdt_mode="fpr",
                                   fpr_tolerance=fpr_tolerance, backend=backend)
    filt = XlingFilter(cfg).fit(R, cache_key=cache_key, mesh=mesh)
    if engine is None:
        engine = JoinEngine(R, metric, mesh=mesh, backend=backend, block=block)
    base = make_join("naive", R, metric, backend=backend, engine=engine)
    return FilteredJoin(base, filter=filt, tau=tau, xdt_mode="fpr",
                        fpr_tolerance=fpr_tolerance, block=block,
                        engine=engine, verify=verify)


def enhance_with_xling(base, filt: XlingFilter, *, tau: int = 0,
                       block: int = 512) -> FilteredJoin:
    """<method>-Xling (paper: mean-based XDT, tau=0 to minimize added loss)."""
    return FilteredJoin(base, filter=filt, tau=tau, xdt_mode="mean", block=block)
