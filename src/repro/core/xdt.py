"""Xling Decision Threshold selection (paper §V-B) + Eq. 2 interpolation.

XDT converts the estimator's predicted count into a positive/negative
verdict. Both selectors need the set of ground-truth NEGATIVE training
points (<= tau true neighbors at the queried eps); for an out-of-domain eps
the true cardinalities are approximated by linear interpolation between the
two bracketing grid epsilons (Eq. 2) — the cardinality curve is monotone
non-decreasing in eps, so the approximation error is bounded by the grid
resolution and, empirically (Table V), the resulting FPR/FNR match the
exact targets at 100-2000x lower cost.
"""
from __future__ import annotations

import numpy as np


def interp_targets(eps_grid: np.ndarray, target_table: np.ndarray,
                   eps: float) -> np.ndarray:
    """Eq. 2: per-point linear interpolation of the cardinality curve.

    eps_grid [m] sorted; target_table [n, m]; returns float [n].
    Clamps to the grid edges outside the domain.
    """
    j = int(np.searchsorted(eps_grid, eps))
    if j <= 0:
        return target_table[:, 0].astype(np.float64)
    if j >= len(eps_grid):
        return target_table[:, -1].astype(np.float64)
    e1, e2 = float(eps_grid[j - 1]), float(eps_grid[j])
    t1 = target_table[:, j - 1].astype(np.float64)
    t2 = target_table[:, j].astype(np.float64)
    if e2 <= e1:
        return t1
    return t1 + (t2 - t1) * (eps - e1) / (e2 - e1)


def select_xdt(preds_on_train: np.ndarray, targets_at_eps: np.ndarray,
               tau: int, mode: str = "fpr", fpr_tolerance: float = 0.05) -> float:
    """Compute XDT from training-set predictions + (approx) true targets.

    mode="fpr":  smallest threshold such that the fraction of ground-truth
                 negatives predicted positive is <= fpr_tolerance.
    mode="mean": mean predicted value over the ground-truth negatives
                 (lower threshold -> higher recall, less speedup).
    XDT may be negative (the paper explicitly allows it).
    """
    neg = targets_at_eps <= tau
    if not neg.any():
        # no negatives to calibrate on: nothing can be filtered safely
        return -np.inf
    p = preds_on_train[neg].astype(np.float64)
    if mode == "mean":
        return float(p.mean())
    if mode == "fpr":
        # threshold at the (1 - tol) quantile of negative predictions:
        # only tol of negatives exceed it => train FPR <= tol
        return float(np.quantile(p, 1.0 - fpr_tolerance))
    raise ValueError(f"unknown XDT mode {mode!r}")


def filter_rates(verdicts: np.ndarray, true_counts: np.ndarray, tau: int
                 ) -> dict:
    """FPR/FNR of positive/negative verdicts against ground truth."""
    gt_pos = true_counts > tau
    fp = np.sum(verdicts & ~gt_pos)
    fn = np.sum(~verdicts & gt_pos)
    n_neg = max(int(np.sum(~gt_pos)), 1)
    n_pos = max(int(np.sum(gt_pos)), 1)
    return {"fpr": float(fp / n_neg), "fnr": float(fn / n_pos),
            "n_pos": int(np.sum(gt_pos)), "n_neg": int(np.sum(~gt_pos))}
