"""Device-resident probing layer (DESIGN.md §11).

The engine's approximate verification (DESIGN.md §5) used to split every
batch across the PCIe boundary: candidate *verification* ran on device,
but the index *probe* that produces the candidates ran in NumPy on the
host — a device→host→device round trip inside every streamed batch,
exactly the sync the async pipeline was built to avoid.  This module
moves probing onto the mesh: FALCONN-style LSH multiprobe (hyperplane +
p-stable) and the FAISS-style IVF-PQ coarse quantizer + ADC ranking are
dense einsum + gather workloads, so they compile into the same
bucketed-static-shape device programs as the range-count sweep.

Three layers:

  * **Shared probing math** — `lsh_hash_codes` / `lsh_bucket_ids` /
    `lsh_probe_buckets` / `ivfpq_candidates` are jitted jnp functions
    used by BOTH the host path (`LSHJoin.candidates`,
    `IVFPQJoin.candidates` call them and pull the result back) and the
    device probe programs.  One source of truth means device-probe
    candidates are bit-identical to host-probe candidates — the parity
    the subprocess tests enforce.
  * **Probe specs + the adapter registry** — a Searcher advertises the
    capability with `device_probe(eps)` (the `DeviceSearcher` half of
    the DESIGN.md §9 protocol, analogous to `Filter.device_filter`),
    returning a spec (`LSHProbe` / `IVFPQProbe`) or None.  Third-party
    searchers that cannot grow the method register a builder in
    `PROBE_BUILDERS`; `as_device_probe` resolves either form, and
    host-only searchers (grid, kmeans-tree, plug-ins) simply keep the
    host path.
  * **Placed probes** — `spec.place(engine)` uploads the probe tables
    once, pinned like R, with placement chosen per topology
    (`core/topology.py::Topology.probe_shards`): replicated by default;
    under `"ring"` the LSH member tables are row-partitioned over the
    `r` axis (`_shard_lsh_tables` — each shard's table holds exactly
    the global table's ids that land in its R shard, so candidate ids
    stay local and R is never gathered), while the IVF-PQ tables stay
    replicated because ADC ranking is a global top-k.  The returned
    `PlacedProbe` exposes `probe(qpos)` (candidate generation) and
    `verify(...)` (candidate verification + scatter) as separately
    dispatchable device programs, which is what lets the engine stage
    batch k+1's probing while batch k verifies (DESIGN.md §11 staging).

Compiled programs live in module-level `lru_cache`s keyed ONLY on static
geometry (mesh, metric, probe shape) — table arrays are runtime
arguments — so engines sharing a geometry share executables.  Every one
is registered in `engine._PROGRAM_CACHES` via `register_program_cache`
(enforced by xlint's cache-registry rule, DESIGN.md §12), so
`engine.clear_program_cache()` can never silently miss one.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.engine import register_program_cache
from repro.core.joins.common import (_verify_block_impl, _verify_blocks,
                                     localized_shard_verify)
from repro.core.topology import _data_size, _shard_mapped
from repro.kernels import ops

# ====================================================== shared LSH math
# Bucket combination runs in int32 with two's-complement wraparound on
# host AND device (the sum of salted codes is reduced mod 2**32 before
# the mod-n_buckets): identical residues everywhere, no x64 dependency.


def _lsh_codes(X, proj, bias, *, metric: str, W: float):
    """int32 [n, l, k] hash codes: hyperplane sign bits (cosine) or
    p-stable quantized projections (l2)."""
    h = jnp.einsum("nd,lkd->nlk", X.astype(jnp.float32),
                   proj.astype(jnp.float32))
    if metric == "cosine":
        return (h > 0).astype(jnp.int32)
    return jnp.floor((h + bias[None]) / jnp.float32(W)).astype(jnp.int32)


def _lsh_combine(codes, salt32, n_buckets: int):
    """int32 [n, l] bucket ids from salted-code sums (int32 wraparound;
    `jnp.mod` keeps the result non-negative)."""
    mixed = jnp.sum(codes * salt32[None], axis=2, dtype=jnp.int32)
    return jnp.mod(mixed, jnp.int32(n_buckets))


def _lsh_multiprobe(codes, salt32, *, metric: str, n_probes: int,
                    n_buckets: int):
    """int32 [n, l, n_probes] probe bucket ids: the identity probe plus
    single-coordinate perturbations (bit-flip / ±1), FALCONN-style
    structured multiprobe. The schedule is a trace-time Python loop so
    host and device paths share it exactly."""
    probes = [_lsh_combine(codes, salt32, n_buckets)]
    for j in range(codes.shape[2]):
        if len(probes) >= n_probes:
            break
        if metric == "cosine":
            pert = codes.at[:, :, j].set(1 - codes[:, :, j])
        else:
            pert = codes.at[:, :, j].add(1 if j % 2 == 0 else -1)
        probes.append(_lsh_combine(pert, salt32, n_buckets))
    while len(probes) < n_probes:
        probes.append(probes[0])
    return jnp.stack(probes[:n_probes], axis=2)


@functools.partial(jax.jit, static_argnames=("metric", "W"))
def _lsh_codes_fn(X, proj, bias, *, metric, W):
    return _lsh_codes(X, proj, bias, metric=metric, W=W)


@functools.partial(jax.jit, static_argnames=("n_buckets",))
def _lsh_combine_fn(codes, salt32, *, n_buckets):
    return _lsh_combine(codes, salt32, n_buckets)


@functools.partial(jax.jit,
                   static_argnames=("metric", "W", "n_probes", "n_buckets"))
def _lsh_probe_fn(X, proj, bias, salt32, *, metric, W, n_probes, n_buckets):
    codes = _lsh_codes(X, proj, bias, metric=metric, W=W)
    return _lsh_multiprobe(codes, salt32, metric=metric, n_probes=n_probes,
                           n_buckets=n_buckets)


def lsh_hash_codes(X, proj, bias, *, metric: str, W: float) -> np.ndarray:
    """Host entry: int32 [n, l, k] codes via the shared device math —
    the single implementation behind table build, host probing, and the
    device probe programs (bit parity by construction)."""
    return np.asarray(_lsh_codes_fn(
        jnp.asarray(X, jnp.float32), jnp.asarray(proj, jnp.float32),
        jnp.asarray(bias, jnp.float32), metric=metric, W=float(W)))


def lsh_bucket_ids(codes, salt, n_buckets: int) -> np.ndarray:
    """Host entry: int32 [n, l] bucket ids for table build (same int32
    wraparound combine as probing — build and probe can never skew)."""
    return np.asarray(_lsh_combine_fn(
        jnp.asarray(codes, jnp.int32),
        jnp.asarray(np.asarray(salt, np.int64).astype(np.int32)),
        n_buckets=int(n_buckets)))


def _bucket_rows(X: np.ndarray) -> np.ndarray:
    """Zero-pad query rows to the engine's 64-row bucket quantum so the
    jitted host wrappers compile once per bucket, not once per distinct
    batch size (probing is row-independent; padding rows are sliced off
    by the caller)."""
    from repro.core.engine import _bucket_size, _pad_rows_np
    X = np.asarray(X, np.float32)
    return _pad_rows_np(X, _bucket_size(max(len(X), 1), 64))


def lsh_probe_buckets(X, proj, bias, salt, *, metric: str, W: float,
                      n_probes: int, n_buckets: int) -> np.ndarray:
    """Host entry: int32 [q, l, n_probes] multiprobe bucket ids."""
    n = len(X)
    return np.asarray(_lsh_probe_fn(
        jnp.asarray(_bucket_rows(X)), jnp.asarray(proj, jnp.float32),
        jnp.asarray(bias, jnp.float32),
        jnp.asarray(np.asarray(salt, np.int64).astype(np.int32)),
        metric=metric, W=float(W), n_probes=int(n_probes),
        n_buckets=int(n_buckets)))[:n]


# ==================================== bucket histograms + re-bucketing
# Skew-aware re-bucketing (DESIGN.md §16): when a few LSH buckets are
# far above the mean occupancy (clustered data), the auto capacity —
# sized at the occupancy p99.9 — is gated by that hot tail: every bucket
# slot pays the hot bucket's width, and under the ring topology the one
# shard holding the cluster's rows gates the whole SPMD sweep.  The
# transform splits each hot bucket's ROWS on extra hyperplanes
# (median-thresholded so the split is balanced) into `fanout` child
# buckets appended after the original id space, and records an
# `expand[l, B, fanout]` map; probing keeps the ORIGINAL multiprobe
# schedule and simply expands every probed bucket to all of its children
# (non-hot buckets expand to themselves + an always-empty filler
# bucket).  Because a query probes every child of each probed bucket,
# the candidate id SET per query is exactly the pre-split set whenever
# no bucket overflows its capacity — bit-identical verified counts (the
# parity the tests enforce) — while the per-bucket capacity drops to the
# post-split occupancy and capacity overflow can only shrink (hot
# buckets now own `fanout` slots).


def bucket_occupancy(tables: np.ndarray) -> np.ndarray:
    """Retained-entry occupancy histogram int64 [l, B] of a member table
    [l, B, cap] (-1 padded) — the planner's skew measurement input."""
    return (np.asarray(tables) >= 0).sum(axis=2)


def bucket_skew_stats(occ: np.ndarray) -> dict:
    """Skew summary of an occupancy histogram [l, B] (flattened): Gini
    coefficient, top-16 mass fraction, and the max/mean-nonzero ratio
    (`hot_factor` — the planner's re-bucketing trigger scale)."""
    flat = np.sort(np.asarray(occ, np.float64).reshape(-1))
    total = float(flat.sum())
    n = len(flat)
    if total <= 0 or n == 0:
        return {"gini": 0.0, "top16_mass": 0.0, "hot_factor": 0.0,
                "mean_nonzero": 0.0, "max": 0}
    cum = np.cumsum(flat)
    gini = float(1.0 - 2.0 * np.sum(cum) / (total * n) + 1.0 / n)
    nz = flat[flat > 0]
    return {
        "gini": gini,
        "top16_mass": float(flat[-16:].sum() / total),
        "hot_factor": float(flat[-1] / nz.mean()),
        "mean_nonzero": float(nz.mean()),
        "max": int(flat[-1]),
    }


def split_hot_buckets(buckets: np.ndarray, X: np.ndarray, *,
                      n_buckets: int, hot_factor: float,
                      max_fanout: int = 8, seed: int = 0):
    """Split hot buckets of a raw assignment [n, l] on extra hyperplanes.

    A bucket is HOT when its occupancy exceeds ``max(hot_factor *
    mean-nonzero-occupancy, 4)``.  Each hot bucket's rows are
    partitioned by the sign pattern of ``log2(fanout)`` fresh random
    projections, thresholded at the per-(table, bucket, plane) MEDIAN so
    children come out balanced for any metric.  Children are appended
    after the original ``n_buckets`` ids plus one trailing always-empty
    filler bucket (the expansion slot for non-hot buckets).

    Returns ``None`` when nothing is hot, else ``(buckets2 [n, l],
    expand [l, n_buckets, fanout] int32, n_total_buckets, info)`` where
    ``info`` is the machine-readable summary `JoinPlan.explain()`
    surfaces.  The transform only relabels rows — the union of any
    original bucket's children is exactly that bucket's row set, the
    candidate-set-preservation invariant."""
    buckets = np.asarray(buckets)
    n, l = buckets.shape
    occ = np.stack([np.bincount(buckets[:, t], minlength=n_buckets)
                    for t in range(l)])
    nz = occ[occ > 0]
    mean_nz = float(nz.mean()) if len(nz) else 0.0
    threshold = max(hot_factor * mean_nz, 4.0)
    hot = occ > threshold
    if not hot.any():
        return None
    max_occ = int(occ.max())
    fanout = 2
    while fanout < max_fanout and max_occ / fanout > threshold:
        fanout *= 2
    s = int(math.log2(fanout))
    rng = np.random.default_rng(seed)
    proj2 = rng.normal(size=(l, s, X.shape[1])).astype(np.float32)
    H = np.einsum("nd,lsd->nls", np.asarray(X, np.float32), proj2)
    n_hot_max = int(hot.sum(axis=1).max())
    filler = n_buckets + n_hot_max * fanout
    n_total = filler + 1
    expand = np.full((l, n_buckets, fanout), filler, np.int32)
    expand[:, :, 0] = np.arange(n_buckets, dtype=np.int32)[None, :]
    buckets2 = buckets.copy()
    for t in range(l):
        for i, b in enumerate(np.nonzero(hot[t])[0]):
            base = n_buckets + i * fanout
            expand[t, b] = base + np.arange(fanout, dtype=np.int32)
            rows = np.nonzero(buckets[:, t] == b)[0]
            bits = np.zeros(len(rows), np.int32)
            for j in range(s):
                h = H[rows, t, j]
                bits |= (h > np.median(h)).astype(np.int32) << j
            buckets2[rows, t] = base + bits
    occ2 = np.stack([np.bincount(buckets2[:, t], minlength=n_total)
                     for t in range(l)])
    info = {
        "n_hot": int(hot.sum()),
        "fanout": fanout,
        "threshold": float(threshold),
        "max_occ_before": max_occ,
        "max_occ_after": int(occ2.max()),
        "n_total_buckets": n_total,
    }
    return buckets2, expand, n_total, info


# =================================================== shared IVF-PQ math
_IVFPQ_BLOCK = 64      # query tile of the blocked ADC scan


def _sq_dists(a, b):
    return (jnp.sum(a * a, 1)[:, None] - 2.0 * a @ b.T
            + jnp.sum(b * b, 1)[None, :])


def _ivfpq_block(qb, centroids, lists, codes, codebooks, *, n_probe: int,
                 n_cand: int, backend: str = "jnp"):
    """One query tile: coarse-quantize, gather the probed lists, ADC-rank
    the pool, keep the best n_cand ids. int32 [b, n_cand] (-1 padded).

    The ADC ranking dispatches through `ops.adc_rank`
    (kernels/adc_rank.py): the fused flash-style kernel under
    backend="pallas", the bit-identical flat-LUT jnp formulation for
    every other backend — host probing and "ref"-backend engines take
    the jnp path too, so host/device candidate parity holds across the
    whole backend matrix."""
    b = qb.shape[0]
    dc = _sq_dists(qb, centroids)
    _, probed = jax.lax.top_k(-dc, n_probe)                # [b, P]
    cand = lists[probed].reshape(b, -1)                    # [b, P*cap]
    be = "pallas" if backend == "pallas" else "jnp"
    return ops.adc_rank(qb, codebooks, cand, codes, n_cand=n_cand,
                        backend=be)


@functools.partial(jax.jit, static_argnames=("n_probe", "n_cand", "backend"))
def _ivfpq_probe_fn(q, centroids, lists, codes, codebooks, *, n_probe,
                    n_cand, backend="jnp"):
    # tile size divides the (static) row count exactly: the full ADC tile
    # when rows are a 64-multiple (the host wrapper and the engine's
    # default capacity buckets), its gcd otherwise (small block_q engines
    # whose padded batch is shorter than one tile)
    blk = math.gcd(q.shape[0], _IVFPQ_BLOCK)
    nb = q.shape[0] // blk
    qb = q.reshape(nb, blk, q.shape[1])
    out = jax.lax.map(
        lambda x: _ivfpq_block(x, centroids, lists, codes, codebooks,
                               n_probe=n_probe, n_cand=n_cand,
                               backend=backend), qb)
    return out.reshape(nb * blk, -1)


def ivfpq_candidates(Q, centroids, lists, codes, codebooks, *, n_probe: int,
                     n_cand: int) -> np.ndarray:
    """Host entry: ADC-ranked candidate ids int32 [q, n_cand] (-1 padded)
    via the shared blocked device math (`IVFPQJoin.candidates` delegates
    here; the device probe program runs the identical tiles)."""
    Q = np.asarray(Q, np.float32)
    n = len(Q)
    if n == 0:
        return np.empty((0, n_cand), np.int32)
    qp = _bucket_rows(Q)                   # 64-row buckets: one compile
    out = _ivfpq_probe_fn(jnp.asarray(qp), jnp.asarray(centroids),
                          jnp.asarray(lists), jnp.asarray(codes),
                          jnp.asarray(codebooks), n_probe=int(n_probe),
                          n_cand=int(n_cand))
    return np.asarray(out)[:n]


# ============================================= compiled device programs
@register_program_cache
@functools.lru_cache(maxsize=128)
def _gather_program(mesh, data_axis):
    """Compiled positive-compaction gather `(q, pos, *, capacity) ->
    (qpos [capacity, d], idx [capacity])`, output replicated so the
    probe programs see the whole compacted block. Padding lanes point at
    row 0; the verify scatter masks their contribution to 0."""
    def run(q, pos, *, capacity: int):
        idx = jnp.nonzero(pos, size=capacity, fill_value=0)[0] \
                 .astype(jnp.int32)
        qpos = jnp.take(q, idx, axis=0)
        if mesh is not None:
            rep = NamedSharding(mesh, P())
            qpos = jax.lax.with_sharding_constraint(qpos, rep)
            idx = jax.lax.with_sharding_constraint(idx, rep)
        return qpos, idx

    return jax.jit(run, static_argnames=("capacity",))


@register_program_cache
@functools.lru_cache(maxsize=128)
def _lsh_probe_program(metric, W, n_probes, n_buckets, backend="jnp"):
    """Compiled replicated LSH probe `(qpos, proj, bias, salt, tables) ->
    cand [q, l*p*cap]` — tables are runtime args, so every engine with
    this geometry shares one executable.  The member-table gather +
    multiprobe dedup runs through `ops.lsh_bucket_gather`
    (kernels/lsh_gather.py): the fused Pallas kernel under
    backend="pallas", the bit-identical direct-gather formulation
    otherwise."""
    def run(qpos, proj, bias, salt, tables):
        codes = _lsh_codes(qpos, proj, bias, metric=metric, W=W)
        pb = _lsh_multiprobe(codes, salt, metric=metric, n_probes=n_probes,
                             n_buckets=n_buckets)
        return ops.lsh_bucket_gather(tables, pb, backend=backend)

    return jax.jit(run)


@register_program_cache
@functools.lru_cache(maxsize=128)
def _lsh_ring_probe_program(mesh, r_axis, metric, W, n_probes, n_buckets,
                            backend="jnp"):
    """Compiled ring LSH probe: each device probes its OWN per-shard
    member table (`_shard_lsh_tables` row-partition), producing the
    candidate axis sharded over `r` — ids stay local to the R shard that
    will verify them, and neither tables nor candidates are gathered.
    The per-shard gather dispatches through `ops.lsh_bucket_gather` like
    the replicated program (same kernel, per-shard tables)."""
    def shard_fn(qpos, proj, bias, salt, tables):
        codes = _lsh_codes(qpos, proj, bias, metric=metric, W=W)
        pb = _lsh_multiprobe(codes, salt, metric=metric, n_probes=n_probes,
                             n_buckets=n_buckets)
        # tables[0]: this device's shard table
        return ops.lsh_bucket_gather(tables[0], pb, backend=backend)

    mapped = _shard_mapped(shard_fn, mesh,
                           in_specs=(P(), P(), P(), P(), P(r_axis)),
                           out_specs=P(None, r_axis))
    return jax.jit(mapped)


def _expand_pb(pb, expand):
    """[q, l, p] probed bucket ids -> [q, l, p*fanout] via the re-bucket
    expansion map [l, B, fanout] (trace-safe; shared by both expanded
    programs so replicated and ring candidates agree bit-for-bit)."""
    q, l, p = pb.shape
    pb2 = expand[jnp.arange(l)[None, :, None], pb]     # [q, l, p, F]
    return pb2.reshape(q, l, p * expand.shape[2])


@register_program_cache
@functools.lru_cache(maxsize=128)
def _lsh_expand_probe_program(metric, W, n_probes, n_buckets, backend="jnp"):
    """`_lsh_probe_program` with skew-aware re-bucketing (DESIGN.md
    §16): the multiprobe schedule is unchanged (bucket domain [0, B)),
    then every probed bucket expands to its child buckets through the
    runtime `expand` map before the member-table gather.  The gather's
    dedup blanks the repeated filler slots exactly like repeated identity
    probes, so counts stay bit-identical to the un-rebucketed path."""
    def run(qpos, proj, bias, salt, expand, tables):
        codes = _lsh_codes(qpos, proj, bias, metric=metric, W=W)
        pb = _lsh_multiprobe(codes, salt, metric=metric, n_probes=n_probes,
                             n_buckets=n_buckets)
        return ops.lsh_bucket_gather(tables, _expand_pb(pb, expand),
                                     backend=backend)

    return jax.jit(run)


@register_program_cache
@functools.lru_cache(maxsize=128)
def _lsh_ring_expand_probe_program(mesh, r_axis, metric, W, n_probes,
                                   n_buckets, backend="jnp"):
    """Ring variant of the expanded probe: the expansion map is
    replicated (it indexes the GLOBAL bucket space, identical on every
    shard) while the member tables stay row-partitioned over `r` —
    candidate ids remain local to the R shard that verifies them."""
    def shard_fn(qpos, proj, bias, salt, expand, tables):
        codes = _lsh_codes(qpos, proj, bias, metric=metric, W=W)
        pb = _lsh_multiprobe(codes, salt, metric=metric, n_probes=n_probes,
                             n_buckets=n_buckets)
        return ops.lsh_bucket_gather(tables[0], _expand_pb(pb, expand),
                                     backend=backend)

    mapped = _shard_mapped(shard_fn, mesh,
                           in_specs=(P(), P(), P(), P(), P(), P(r_axis)),
                           out_specs=P(None, r_axis))
    return jax.jit(mapped)


@register_program_cache
@functools.lru_cache(maxsize=128)
def _probe_verify_program(mesh, data_axis, metric, block, backend):
    """Compiled candidate-verify + scatter program for replicated R:
    `(R, qpos, cand, idx, n_pos, eps, tomb, *, out_rows) -> int32
    [out_rows]`. The work shards over `data` when the capacity divides
    evenly. `tomb` (None when R is unmutated) masks tombstoned rows out
    of the counts (DESIGN.md §13)."""
    ndata = _data_size(mesh, data_axis)

    def run(R, qpos, cand, idx, n_pos, eps, tomb=None, *, out_rows: int):
        cap = qpos.shape[0]
        qp, cb = qpos, cand
        if (mesh is not None and ndata > 1 and cap % ndata == 0
                and (backend == "ref" or (cap // ndata) % block == 0)):
            s = NamedSharding(mesh, P(data_axis))
            qp = jax.lax.with_sharding_constraint(qp, s)
            cb = jax.lax.with_sharding_constraint(cb, s)
        if backend == "ref" or cap % block != 0:
            # unblocked fallback also covers small-block_q engines whose
            # capacity is below one verify tile
            cnt = _verify_block_impl(R, qp, cb, eps, metric=metric,
                                     tomb=tomb)
        else:
            cnt = _verify_blocks(R, qp, cb, eps, tomb, metric=metric,
                                 block=block)
        contrib = jnp.where(jnp.arange(cap) < n_pos, cnt, 0) \
                     .astype(jnp.int32)
        return jnp.zeros((out_rows,), jnp.int32).at[idx].add(contrib)

    return jax.jit(run, static_argnames=("out_rows",))


@register_program_cache
@functools.lru_cache(maxsize=128)
def _ring_probe_verify_program(mesh, r_axis, data_axis, shard_rows, metric,
                               block, backend, cand_sharded,
                               has_tomb=False):
    """Compiled candidate-verify + scatter for ring-sharded R: each
    device verifies the candidate ids that land in its own shard's row
    range against its resident R shard and the counts are `psum`'d over
    `r` (`joins.common.localized_shard_verify` — the same shard compute
    as the host-probe route). With `cand_sharded` (per-shard probe
    tables) each device sees only its own candidate slice; otherwise the
    replicated candidate list is localized per shard (ids outside the
    range mask to -1). `has_tomb` keys on whether the tombstone mask
    (sharded like R) rides along — shard_map in_specs are fixed-arity
    (DESIGN.md §13)."""
    cspec = P(None, r_axis) if cand_sharded else P()
    shard_fn = localized_shard_verify(r_axis, shard_rows, metric, block,
                                      backend)
    in_specs = (P(r_axis), P(), cspec, P())
    if has_tomb:
        in_specs += (P(r_axis),)
    mapped = _shard_mapped(shard_fn, mesh, in_specs=in_specs,
                           out_specs=P())

    def run(R, qpos, cand, idx, n_pos, eps, tomb=None, *, out_rows: int):
        if has_tomb:
            cnt = mapped(R, qpos, cand, eps, tomb)
        else:
            cnt = mapped(R, qpos, cand, eps)
        contrib = jnp.where(jnp.arange(qpos.shape[0]) < n_pos, cnt, 0) \
                     .astype(jnp.int32)
        return jnp.zeros((out_rows,), jnp.int32).at[idx].add(contrib)

    return jax.jit(run, static_argnames=("out_rows",))


def clear_probe_program_cache() -> None:
    """Evict this module's compiled probe-program caches only (the caches
    key on the mesh and would otherwise pin executables for meshes a
    long-lived serve process has discarded).  Kept as a targeted hook;
    `engine.clear_program_cache()` now evicts these through the
    `_PROGRAM_CACHES` registry instead of calling here. Programs rebuild
    transparently."""
    for cache in (_gather_program, _lsh_probe_program,
                  _lsh_ring_probe_program, _lsh_expand_probe_program,
                  _lsh_ring_expand_probe_program, _probe_verify_program,
                  _ring_probe_verify_program):
        cache.cache_clear()


# ============================================== table sharding (ring)
def _shard_lsh_tables(tables: np.ndarray, shards: int,
                      rows: int) -> np.ndarray:
    """Partition a global [l, B, cap] LSH member table into per-shard
    tables [shards, l, B, cap_s] (-1 padded), shard s holding EXACTLY
    the global table's ids in row range [s*rows, (s+1)*rows).

    Because the partition is of the *retained* global entries (not a
    rebuild from scratch), the union over shards equals the global
    table bit-for-bit — per-shard probing stays candidate-identical to
    the replicated probe, and per-device table bytes drop by roughly
    the shard count (cap_s ≈ cap / shards on balanced data)."""
    vals = tables.astype(np.int64)
    big = np.int64(1) << 40
    per, caps = [], []
    for s in range(shards):
        lo, hi = s * rows, (s + 1) * rows
        m = (vals >= lo) & (vals < hi)
        per.append(np.sort(np.where(m, vals, big), axis=-1))
        caps.append(int(m.sum(axis=-1).max()))
    cap_s = max(max(caps), 1)
    out = np.stack([p[..., :cap_s] for p in per])
    out[out >= big] = -1
    return out.astype(np.int32)


# ================================================ specs + placed probes
class PlacedProbe:
    """A probe spec bound to one engine: tables uploaded per the
    engine's topology, probe/verify programs resolved. `probe(qpos)`
    and `verify(...)` are separately dispatchable device programs —
    the split that lets `StreamSession` stage batch k+1's probing while
    batch k's verification executes (DESIGN.md §11)."""

    def __init__(self, engine, *, name: str, probe_fn: Callable,
                 state: tuple, cand_sharded: bool, table_bytes: int,
                 cand_width: int):
        self.engine = engine
        self.name = name
        self._probe_fn = probe_fn
        self._state = state
        self.cand_sharded = cand_sharded
        #: probe-table bytes resident on EACH device (reported by
        #: `JoinPlan.describe()["exec"]["probe"]`)
        self.table_bytes_per_device = int(table_bytes)
        #: candidate ids produced per query (global, across shards)
        self.cand_width = int(cand_width)

    def probe(self, qpos) -> jax.Array:
        """Dispatch the probe program: compacted queries [capacity, d]
        -> candidate ids [capacity, cand_width] (-1 padded), all on
        device — no host hop."""
        return self._probe_fn(qpos, *self._state)

    def verify(self, qpos, cand, idx, n_pos, eps, *, out_rows: int,
               block: int = 32, Rdev=None, tomb=None) -> jax.Array:
        """Dispatch candidate verification + scatter against the
        engine's resident R; returns the per-query counts [out_rows]
        (device array — the caller starts the async host copy). `Rdev` /
        `tomb` override the engine's live buffers with a staged batch's
        snapshot of R and its tombstone mask (DESIGN.md §13) so streamed
        batches verify against their submit-time logical set."""
        eng = self.engine
        R = eng._Rdev if Rdev is None else Rdev
        if eng.r_shards > 1:
            prog = _ring_probe_verify_program(
                eng.mesh, eng.topology.r_axis, eng.data_axis,
                eng.nr_padded // eng.r_shards, eng.metric, block,
                eng.backend, self.cand_sharded, tomb is not None)
        else:
            prog = _probe_verify_program(eng.mesh, eng.data_axis,
                                         eng.metric, block, eng.backend)
        return prog(R, qpos, cand, idx, n_pos, eps, tomb,
                    out_rows=out_rows)


def _device_put(arr, mesh, spec=P()):
    if mesh is not None:
        return jax.device_put(arr, NamedSharding(mesh, spec))
    return jnp.asarray(arr)


class LSHProbe:
    """Device-probe spec for `LSHJoin` (DESIGN.md §11): projection /
    bias / salt / member tables uploaded once; under the ring topology
    the member tables are row-partitioned over `r`
    (`_shard_lsh_tables`) so probing AND verification stay local to
    each R shard."""

    name = "lsh"

    def __init__(self, join):
        self.join = join

    def place(self, engine) -> PlacedProbe:
        """Upload the probe tables onto the engine's mesh (placement per
        its topology) and resolve the compiled probe program."""
        j = self.join
        mesh = engine.mesh
        salt32 = np.asarray(j.salt, np.int64).astype(np.int32)
        shards = engine.topology.probe_shards(mesh)
        small = (_device_put(j.proj, mesh), _device_put(j.bias, mesh),
                 _device_put(salt32, mesh))
        expand = getattr(j, "expand", None)
        fanout = 1 if expand is None else int(expand.shape[2])
        if expand is not None:
            # re-bucketed index (DESIGN.md §16): the expansion map rides
            # along replicated; probe width grows by the fanout while the
            # table capacity shrinks to the post-split occupancy
            small = small + (_device_put(np.asarray(expand, np.int32),
                                         mesh),)
        if shards > 1:
            tabs = _shard_lsh_tables(j.tables, shards,
                                     engine.nr_padded // shards)
            tables = _device_put(tabs, mesh, engine.topology.probe_spec())
            if expand is None:
                prog = _lsh_ring_probe_program(
                    mesh, engine.topology.r_axis, j.metric, float(j.W),
                    int(j.n_probes), int(j.n_buckets), engine.backend)
            else:
                prog = _lsh_ring_expand_probe_program(
                    mesh, engine.topology.r_axis, j.metric, float(j.W),
                    int(j.n_probes), int(j.n_buckets), engine.backend)
            table_bytes = (tabs.nbytes // shards + j.proj.nbytes
                           + j.bias.nbytes + salt32.nbytes)
            cand_width = (shards * tabs.shape[1] * j.n_probes * fanout
                          * tabs.shape[3])
            cand_sharded = True
        else:
            tables = _device_put(np.asarray(j.tables, np.int32), mesh)
            if expand is None:
                prog = _lsh_probe_program(j.metric, float(j.W),
                                          int(j.n_probes),
                                          int(j.n_buckets), engine.backend)
            else:
                prog = _lsh_expand_probe_program(
                    j.metric, float(j.W), int(j.n_probes),
                    int(j.n_buckets), engine.backend)
            table_bytes = (j.tables.nbytes + j.proj.nbytes + j.bias.nbytes
                           + salt32.nbytes)
            cand_width = j.l * j.n_probes * fanout * j.tables.shape[2]
            cand_sharded = False
        if expand is not None:
            table_bytes += expand.nbytes
        return PlacedProbe(engine, name=self.name, probe_fn=prog,
                           state=small + (tables,),
                           cand_sharded=cand_sharded,
                           table_bytes=table_bytes, cand_width=cand_width)


class IVFPQProbe:
    """Device-probe spec for `IVFPQJoin`: centroids / inverted lists /
    PQ codes / codebooks uploaded once, replicated on every device
    under EITHER topology — ADC ranking is a global top-k, so the
    candidate list must see the whole pool; under the ring topology the
    replicated candidates are localized per R shard by the verify
    program instead."""

    name = "ivfpq"

    def __init__(self, join):
        self.join = join

    def place(self, engine) -> PlacedProbe:
        """Upload the quantizer state replicated and resolve the blocked
        coarse-probe + ADC-rank program."""
        j = self.join
        mesh = engine.mesh
        n_cand = int(min(j.n_candidates, j.n_probe * j.lists.shape[1]))
        state = (_device_put(j.centroids, mesh),
                 _device_put(np.asarray(j.lists, np.int32), mesh),
                 _device_put(j.codes, mesh),
                 _device_put(j.codebooks, mesh))

        def prog(qpos, centroids, lists, codes, codebooks):
            return _ivfpq_probe_fn(qpos, centroids, lists, codes, codebooks,
                                   n_probe=int(j.n_probe), n_cand=n_cand,
                                   backend=engine.backend)

        table_bytes = (j.centroids.nbytes + j.lists.nbytes + j.codes.nbytes
                       + j.codebooks.nbytes)
        return PlacedProbe(engine, name=self.name, probe_fn=prog,
                           state=state, cand_sharded=False,
                           table_bytes=table_bytes, cand_width=n_cand)


# ============================================== the adapter registry
#: Searcher type -> `builder(searcher, eps) -> spec | None` for searcher
#: classes that cannot grow a `device_probe` method themselves (the
#: DESIGN.md §9 adapter-registry pattern, mirroring FILTER_ADAPTERS).
#: Searchers matching neither route simply keep the host probe path.
PROBE_BUILDERS: dict[type, Callable[[Any, Optional[float]], Any]] = {}


def register_probe(searcher_type: type, builder: Callable) -> None:
    """Register a device-probe builder for a searcher class (the
    extension point for searchers whose source cannot be edited)."""
    PROBE_BUILDERS[searcher_type] = builder


def as_device_probe(searcher, eps: float | None = None):
    """Resolve a searcher's device-probe spec, or None for host-only
    searchers. Resolution order: the searcher's own `device_probe(eps)`
    (the DeviceSearcher protocol), then the `PROBE_BUILDERS` registry
    walked over the class MRO. Returning None is not an error — it
    selects the host probe path. `eps` may be None (plan-build
    validation); the engine caches placement per returned spec, so
    radius-free probes should memoize one spec per index and eps-aware
    probes one spec per distinct eps."""
    fn = getattr(searcher, "device_probe", None)
    if fn is not None:
        return fn(eps)
    for cls in type(searcher).__mro__:
        builder = PROBE_BUILDERS.get(cls)
        if builder is not None:
            return builder(searcher, eps)
    return None
