"""Cost-based auto-planner (DESIGN.md §16): measure, then choose.

`JoinPlan.on()` exposes a real configuration space — topology x
`r_shards` x probe placement x verify backend x block x stream depth —
and before this module the user picked every knob by hand.  The planner
extends the paper's data-awareness thesis from the filter to the whole
execution plan, in the spirit of "Adaptive MapReduce Similarity Joins"
(adapt the join strategy to measured data characteristics) and Wu et
al.'s error-bounded sampling (estimate cost from a small sample whose
count-estimate error is bounded in closed form before committing).

The pipeline, one pass per `JoinPlan.auto()` / `.on(plan="auto")`:

  1. **Sample** — `sample_bound(err, confidence)` gives the Hoeffding
     sample size for a mean-of-bounded-fractions estimate; queries are
     drawn from the caller's Q when available, else from R itself (the
     "index-self" proxy that lets the gateway plan before any traffic).
  2. **Measure** — cheap probe-free programs against the already-pinned
     R: predicted skip rate at the requested eps/tau (the filter's
     verdicts on the sample), selectivity (`engine.range_count`, whose
     wall-clock doubles as the exact-sweep micro-calibration), LSH
     bucket-occupancy skew (Gini / top-k mass / hot-bucket factor over
     a device-histogrammed sample of R — `_bucket_occupancy_program`),
     and the dynamic-R delta occupancy.
  3. **Calibrate** — per-row cost constants come from the committed
     `BENCH_<n>.json` trajectory; a one-shot micro-calibration (the
     timed sweep of step 2) scales them to the current machine, with
     hardcoded defaults when no snapshot exists.  Timings are cached in
     `_CALIBRATION_CACHE` so repeated plans in one process see identical
     constants — the determinism the explain() tests pin down.
  4. **Choose** — a pruned candidate grid is scored by `estimate_cost`;
     infeasible configurations are recorded with rejection reasons
     (recall floor, device count, pinned knobs, hot-bucket overflow).
     When the skew measurement trips the re-bucketing trigger
     (estimated capacity overflow > `OVERFLOW_TRIGGER` or hot factor
     above `REBUCKET_HOT`), plain LSH is replaced by the skew-aware
     re-bucketed variant (`core/probe.py::split_hot_buckets`).

`plan_auto` returns the fully-specified frozen `JoinPlan` plus the
machine-readable explain dict (measured stats, per-candidate cost
estimates, chosen config, rejection reasons).  Every choice goes back
through `JoinPlan.build()` — the planner cannot emit a configuration
the existing validation would reject (the randomized-stats property
test in tests/test_planner.py).
"""
from __future__ import annotations

import functools
import glob
import json
import math
import os
import time
from dataclasses import asdict, dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import (JoinEngine, _allowed_transfer,
                               register_program_cache)
from repro.core.probe import _lsh_codes, _lsh_combine

#: default hot-bucket multiple for skew-aware re-bucketing: a bucket
#: hotter than this multiple of the mean nonzero occupancy gets split
REBUCKET_HOT = 4.0
#: estimated capacity-overflow fraction above which plain LSH is
#: replaced by the re-bucketed variant (the satellite-2 trigger — the
#: same 1% budget `LSHJoin` warns at)
OVERFLOW_TRIGGER = 0.01

#: per-row cost constants when no BENCH_<n>.json snapshot is available
#: (us unless suffixed): derived from the committed smoke-scale
#: trajectory, then scaled to the machine by the micro-calibration
DEFAULT_CONSTANTS = {
    "dispatch_us": 110.0,       # per-batch host glue + dispatch floor
    "exact_pair_ns": 0.9,       # exact sweep, per (query, row) pair
    "lsh_device_us": 18.0,      # LSH verify floor per positive query
    "lsh_host_us": 33.0,
    "lsh_cand_ns": 14.0,        # per live LSH candidate
    "ivfpq_device_us": 150.0,   # ADC rank is n-insensitive at smoke scale
    "ivfpq_host_us": 170.0,
    "coll_us": 0.4,             # per cross-device collective
}

#: process-level calibration memo: {key: constants dict}.  A plain dict
#: on purpose — it caches floats, not compiled programs, so it must NOT
#: look like a program cache to `engine.clear_program_cache()` (and the
#: xlint cache-registry rule).  Caching is what makes two `auto()` calls
#: in one process produce byte-identical explain() dicts.
_CALIBRATION_CACHE: dict = {}


# ============================================================= sampling
def sample_bound(err: float = 0.1, confidence: float = 0.95) -> int:
    """Hoeffding sample size for an error-bounded mean estimate (Wu et
    al., "Improving Distributed Similarity Join in Metric Space with
    Error-bounded Sampling"): the smallest n with
    ``P(|mean_est - mean| > err) <= 1 - confidence`` for means of
    [0, 1]-bounded quantities — ``n >= ln(2 / delta) / (2 err^2)``."""
    if not 0.0 < err < 1.0:
        raise ValueError(f"sample_bound(err={err}): expected a rate in (0,1)")
    if not 0.0 < confidence < 1.0:
        raise ValueError(f"sample_bound(confidence={confidence}): expected "
                         "a probability in (0,1)")
    delta = 1.0 - confidence
    return int(math.ceil(math.log(2.0 / delta) / (2.0 * err * err)))


def draw_sample(Q, R: np.ndarray, *, err: float, confidence: float,
                seed: int) -> tuple[np.ndarray, dict]:
    """Error-bounded measurement sample: `sample_bound` rows drawn
    without replacement from the caller's queries when available, else
    from R itself (the "index-self" proxy — R rows are distributed like
    the corpus, which is the best prior before any traffic arrives)."""
    n = sample_bound(err, confidence)
    src = R if Q is None else np.asarray(Q, np.float32)
    rng = np.random.default_rng(seed)
    if len(src) <= n:
        sample = np.asarray(src, np.float32)
    else:
        sample = np.asarray(
            src[rng.choice(len(src), size=n, replace=False)], np.float32)
    meta = {"bound": n, "rows": int(len(sample)),
            "source": "queries" if Q is not None else "index-self",
            "err": float(err), "confidence": float(confidence)}
    return sample, meta


# ========================================================== measurement
@register_program_cache
@functools.lru_cache(maxsize=32)
def _bucket_occupancy_program(metric, W, n_buckets):
    """Compiled LSH bucket-occupancy histogram `(X, proj, bias, salt) ->
    int32 [l, n_buckets]`: the shared `core/probe.py` hash math (so the
    measured skew is the skew the real index would see) plus a
    scatter-add histogram — the planner's probe-free skew measurement
    program."""
    def run(X, proj, bias, salt):
        codes = _lsh_codes(X, proj, bias, metric=metric, W=W)
        ids = _lsh_combine(codes, salt, n_buckets)       # [n, l]
        l = ids.shape[1]
        occ = jnp.zeros((l, n_buckets), jnp.int32)
        return occ.at[jnp.arange(l)[None, :], ids].add(1)

    return jax.jit(run)


def measure_skew(R: np.ndarray, metric: str, *, seed: int,
                 verify_params: dict | None = None,
                 max_rows: int = 4096) -> dict:
    """LSH bucket-occupancy skew of R, from a hashed row sample.

    Hashes up to `max_rows` seeded-sampled rows of R through the real
    index geometry (`l=4` measurement tables — per-table statistics are
    i.i.d., so four tables bound the estimate at a fraction of the
    build cost), scales the histogram to the full |R|, and summarizes:
    Gini / top-16 mass / hot factor, the p99.9 auto-capacity estimate,
    the capacity-overflow estimate at that capacity, and the post-split
    capacity the re-bucketing transform would reach — the planner's
    inputs for both the re-bucketing trigger and the LSH width term of
    the cost model."""
    from repro.core.probe import bucket_skew_stats
    p = dict(verify_params or {})
    n = len(R)
    k = int(p.get("k", 18))
    l = 4
    W = float(p.get("W", 2.5))
    n_buckets = int(p.get("n_buckets", 0)) or max(
        256, 2 ** int(np.ceil(np.log2(max(n, 2)))))
    rng = np.random.default_rng(seed)
    rows = (np.arange(n) if n <= max_rows
            else rng.choice(n, size=max_rows, replace=False))
    X = np.asarray(R[rows], np.float32)
    proj = rng.normal(size=(l, k, X.shape[1])).astype(np.float32)
    bias = rng.uniform(0, W, size=(l, k)).astype(np.float32)
    salt = rng.integers(1, 2 ** 31, size=(l, k)).astype(np.int32)
    prog = _bucket_occupancy_program(metric, W, n_buckets)
    occ_dev = prog(jnp.asarray(X), jnp.asarray(proj), jnp.asarray(bias),
                   jnp.asarray(salt))
    with _allowed_transfer("measure"):
        # xlint: allow-host-sync(measure: one histogram readback per auto(), off the per-batch serving path)
        occ = np.asarray(occ_dev, np.float64)
    occ *= n / max(len(X), 1)                # scale the sample to |R|
    stats = bucket_skew_stats(occ)
    cap_est = float(max(2.0, np.quantile(occ.reshape(-1), 0.999)))
    overflow_est = float(np.maximum(occ - cap_est, 0).sum()
                         / max(n * occ.shape[0], 1))
    # post-split histogram estimate: buckets above the hot threshold
    # split `fanout` ways (mirrors probe.split_hot_buckets)
    nz = occ[occ > 0]
    mean_nz = float(nz.mean()) if len(nz) else 0.0
    threshold = max(REBUCKET_HOT * mean_nz, 4.0)
    fanout = 2
    while fanout < 8 and stats["max"] / fanout > threshold:
        fanout *= 2
    occ2 = np.where(occ > threshold, occ / fanout, occ)
    cap2_est = float(max(2.0, min(np.quantile(occ2.reshape(-1), 0.999)
                                  * fanout, occ2.max())))
    # size-biased mean occupancy: the expected occupancy of the bucket a
    # random row (hence a distribution-matched query) lands in — the
    # live-candidate scale of the LSH verify cost
    total = occ.sum()
    sb = float((occ ** 2).sum() / total) if total > 0 else 0.0
    sb2 = float((occ2 ** 2).sum() / occ2.sum()) if total > 0 else 0.0
    return {
        "gini": round(stats["gini"], 4),
        "top16_mass": round(stats["top16_mass"], 4),
        "hot_factor": round(stats["hot_factor"], 2),
        "mean_nonzero": round(stats["mean_nonzero"], 2),
        "max_occ": int(stats["max"]),
        "cap_est": round(cap_est, 1),
        "cap_rebucket_est": round(cap2_est, 1),
        "overflow_est": round(overflow_est, 4),
        "sb_occ": round(min(sb, cap_est), 2),
        "sb_occ_rebucket": round(min(sb2, cap2_est), 2),
        "fanout_est": int(fanout),
        "n_buckets": int(n_buckets),
        "hashed_rows": int(len(X)),
    }


def measure_workload(engine: JoinEngine, filt, sample: np.ndarray,
                     eps: float) -> dict:
    """Selectivity + filter skip rate on the sample, against the pinned
    R: `engine.range_count` gives the per-query neighbor counts (its
    wall-clock is the exact-sweep micro-calibration — see
    `calibrated_constants`), the filter's verdicts give the predicted
    positive rate at this eps/tau.  One device sweep, no probing."""
    counts = engine.range_count(sample, float(eps))   # warm: compile once
    t0 = time.perf_counter()
    engine.range_count(sample, float(eps))
    exact_us = (time.perf_counter() - t0) * 1e6 / max(len(sample), 1)
    if filt is not None:
        pos_rate = float(np.mean(np.asarray(
            filt.verdicts(sample, float(eps)), bool)))
    else:
        pos_rate = 1.0
    n = max(engine.nr, 1)
    return {
        "rows": int(len(sample)),
        "eps": float(eps),
        "mean_count": round(float(np.mean(counts)), 3),
        "hit_rate": round(float(np.mean(counts > 0)), 4),
        "selectivity": round(float(np.mean(counts)) / n, 8),
        "pos_rate": round(pos_rate, 4),
        "skip_rate": round(1.0 - pos_rate, 4),
        "exact_us_per_query": round(exact_us, 1),
        "delta_frac": round(float(engine.delta_frac), 4),
        "n_tombstones": int(engine.n_tombstones),
    }


# ========================================================== calibration
def _find_bench_snapshot(root: str | None = None) -> str | None:
    """Path of the newest committed ``BENCH_<n>.json`` (highest n), or
    None when the tree carries no snapshot (fresh clones of the library
    without the benchmark trajectory)."""
    if root is None:
        root = os.path.abspath(
            os.path.join(os.path.dirname(__file__), "..", "..", ".."))
    snaps = glob.glob(os.path.join(root, "BENCH_*.json"))

    def idx(p):
        stem = os.path.splitext(os.path.basename(p))[0]
        try:
            return int(stem.split("_")[1])
        except (IndexError, ValueError):
            return -1

    snaps = [p for p in snaps if idx(p) >= 0]
    return max(snaps, key=idx) if snaps else None


def _constants_from_snapshot(path: str) -> dict:
    """Per-row cost constants from a BENCH snapshot's suites: the xjoin
    probe-placement rows give the LSH/IVF-PQ per-positive-query costs,
    the kernel range_count rows the exact per-pair cost, the ring rows
    the collective increment.  Missing rows fall back to the defaults —
    partial snapshots still calibrate what they can."""
    c = dict(DEFAULT_CONSTANTS)
    try:
        with open(path) as f:
            suites = json.load(f).get("suites", {})
    except (OSError, ValueError):
        return c
    xjoin = suites.get("xjoin", {})

    def row(prefix):
        vals = [v for k, v in xjoin.items() if k.startswith(prefix)]
        return float(np.mean(vals)) if vals else None

    for const, prefix in (("lsh_device_us", "xjoin/lsh-device"),
                          ("lsh_host_us", "xjoin/lsh-host"),
                          ("ivfpq_device_us", "xjoin/ivfpq-device"),
                          ("ivfpq_host_us", "xjoin/ivfpq-host")):
        v = row(prefix)
        if v is not None:
            c[const] = v
    kern = suites.get("kernels", {})
    pairs = []
    for name, us in kern.items():
        parts = name.split("/")
        if len(parts) == 3 and parts[1] == "range_count":
            try:
                q, r, m = (int(x) for x in parts[2].split("x"))
                pairs.append(us * 1e3 / (q * r * m))
            except ValueError:
                continue
    if pairs:
        c["exact_pair_ns"] = float(np.mean(pairs))
    ring = suites.get("ring", {})
    r1 = [v for k, v in ring.items() if k.endswith("r1")]
    r2 = [v for k, v in ring.items() if k.endswith("r2")]
    if r1 and r2:
        c["coll_us"] = max(0.05, float(np.mean(r2) - np.mean(r1)) / 2.0)
    return c


def calibrated_constants(engine: JoinEngine, workload: dict) -> dict:
    """Cost constants for THIS machine: the BENCH snapshot's per-row
    constants (or the defaults), sanity-checked against the one exact
    sweep `measure_workload` already timed — the one-shot
    micro-calibration.  The measured-vs-predicted ratio is clamped to
    [0.2, 5]; while it stays inside the clamp a snapshot's rows are
    trusted verbatim (`approx_scale` 1.0 — they are wall-clock numbers
    from this repo's own harness), and a clamped ratio or the
    arbitrary-unit defaults stretch the approximate-verify constants by
    the ratio and re-anchor the exact per-pair cost on the measured
    sweep.  Memoized in `_CALIBRATION_CACHE` keyed on the engine
    geometry, so every plan in the process prices candidates
    identically."""
    key = (engine.nr, engine.metric, engine.backend, engine.r_shards,
           jax.default_backend())
    cached = _CALIBRATION_CACHE.get(key)
    if cached is not None:
        return dict(cached)
    snap = _find_bench_snapshot()
    c = _constants_from_snapshot(snap) if snap else dict(DEFAULT_CONSTANTS)
    predicted_us = engine.nr * c["exact_pair_ns"] * 1e-3 + 5.0
    measured_us = float(workload.get("exact_us_per_query", predicted_us))
    scale = measured_us / max(predicted_us, 1e-9)
    clamped = min(max(scale, 0.2), 5.0)
    c["machine_scale"] = round(clamped, 3)
    # Snapshot rows are wall-clock us from this repo's own bench harness,
    # so they transfer verbatim while the exact-sweep ratio stays inside
    # the clamp: the ratio is polluted by shape effects (batch size and
    # dimensionality differ between the kernel rows and this workload)
    # that do NOT apply to the end-to-end probe rows.  Only a clamped
    # ratio (snapshot from a very different machine) or the arbitrary-
    # unit defaults get stretched by it.
    c["approx_scale"] = (1.0 if snap is not None and scale == clamped
                         else c["machine_scale"])
    c["calibration"] = (os.path.basename(snap) if snap else "defaults")
    if scale != clamped:
        # the snapshot doesn't match this machine: re-anchor the exact
        # per-pair cost on the measured sweep directly (the clamped scale
        # still stretches the approximate-verify constants)
        c["calibration"] += "+micro"
        c["exact_pair_ns"] = measured_us * 1e3 / max(engine.nr, 1)
    c = {k: (round(v, 4) if isinstance(v, float) else v)
         for k, v in c.items()}
    _CALIBRATION_CACHE[key] = dict(c)
    return c


# ======================================================= candidate grid
@dataclass(frozen=True)
class Candidate:
    """One fully-specified configuration the planner prices: verify
    backend (with the re-bucketed LSH variant spelled "lsh+rebucket"),
    probe placement ("-" for the probe-less exact sweep), topology +
    r_shards, compaction block, stream depth."""
    verify: str
    probe: str
    topology: str
    r_shards: int
    block: int
    depth: int

    @property
    def key(self) -> str:
        """Stable display/sort key of this configuration."""
        return (f"{self.verify}/{self.probe}/{self.topology}"
                f"{self.r_shards}/b{self.block}/d{self.depth}")


def enumerate_candidates(skew: dict, *, recall: float, n_devices: int,
                         pinned: dict) -> tuple[list, list]:
    """The pruned candidate grid plus the rejection record.

    Pruning is by hard feasibility, each recorded with a reason: the
    recall floor gates approximate verifies (1.0 -> exact only, >= 0.95
    -> exact | ivfpq), the ring topology needs >= 2 devices, pinned
    knobs (an explicit on(topology=)/on(probe=)/verify(name) or a
    shared engine) freeze their axis, and the hot-bucket trigger
    (estimated overflow > `OVERFLOW_TRIGGER` or hot factor >
    `REBUCKET_HOT`) replaces plain LSH with the re-bucketed variant."""
    rejected: list[dict] = []
    verifies = []
    # hot when capacity overflow would drop candidates (the satellite
    # trigger, same 1% budget LSHJoin warns at) or the hottest bucket
    # dwarfs the p99.9 capacity the table would be sized to; the second
    # clause is gated on cap_est so sparse-table noise (max occupancy 6
    # vs mean 1 in a mostly-empty table) never trips it
    hot = (skew["overflow_est"] > OVERFLOW_TRIGGER
           or (skew["hot_factor"] > REBUCKET_HOT
               and skew["max_occ"] > REBUCKET_HOT * skew["cap_est"]))
    for v in ("exact", "lsh", "lsh+rebucket", "ivfpq"):
        if recall >= 1.0 and v != "exact":
            rejected.append({"verify": v, "reason":
                             "recall floor 1.0 requires the exact sweep"})
            continue
        if recall >= 0.95 and v in ("lsh", "lsh+rebucket"):
            rejected.append({"verify": v, "reason":
                             f"recall floor {recall} above the LSH floor "
                             "(0.90)"})
            continue
        if v == "lsh" and hot:
            rejected.append({"verify": v, "reason":
                             "hot buckets (overflow_est="
                             f"{skew['overflow_est']}, hot_factor="
                             f"{skew['hot_factor']}) — re-bucketing "
                             "replaces plain LSH"})
            continue
        if v == "lsh+rebucket" and not hot:
            rejected.append({"verify": v, "reason":
                             "no hot buckets — nothing to split"})
            continue
        pv = pinned.get("verify")
        if pv is not None and v.split("+")[0] != pv:
            rejected.append({"verify": v, "reason":
                             f"verify pinned to {pv!r} by the plan"})
            continue
        verifies.append(v)
    topologies = [("replicated", 1)]
    if n_devices >= 2:
        topologies.append(("ring", 2))
    else:
        rejected.append({"topology": "ring", "reason":
                         f"{n_devices} device(s) — the ring sweep needs "
                         ">= 2"})
    pt = pinned.get("topology")
    if pt is not None:
        kept = [(t, r) for t, r in topologies if t == pt]
        for t, r in topologies:
            if t != pt:
                rejected.append({"topology": t, "reason":
                                 f"topology pinned to {pt!r} by the plan "
                                 "(explicit on() or shared engine)"})
        topologies = kept or [(pt, pinned.get("r_shards") or 1)]
        if pinned.get("r_shards"):
            topologies = [(t, int(pinned["r_shards"])) for t, _ in topologies]
    blocks = [pinned["block"]] if pinned.get("block") else [256, 512]
    depths = [2, 4]
    cands = []
    for v in verifies:
        probes = ["-"] if v == "exact" else ["device", "host"]
        pp = pinned.get("probe")
        if pp is not None and v != "exact":
            for p in probes:
                if p != pp:
                    rejected.append({"verify": v, "probe": p, "reason":
                                     f"probe pinned to {pp!r} by the plan"})
            probes = [pp]
        for p in probes:
            for t, r in topologies:
                for b in blocks:
                    for dep in depths:
                        cands.append(Candidate(v, p, t, r, b, dep))
    return cands, rejected


def estimate_cost(cand: Candidate, workload: dict, skew: dict,
                  consts: dict, *, n: int, batch_rows: int = 64) -> dict:
    """Predicted us/query of one candidate at a serving batch size.

    The model: per-batch dispatch glue amortized over the batch and
    hidden by the stream depth, plus the positive-rate-weighted verify
    cost — the measured exact sweep for "exact" (scaled down by the
    ring's compute split on real multi-device backends), the calibrated
    LSH floor plus a per-live-candidate term sized by the measured
    size-biased bucket occupancy for "lsh"/"lsh+rebucket" (re-bucketing
    prices the post-split capacity), the calibrated flat ADC cost for
    "ivfpq" — plus the topology's collective count
    (`Topology.sweep_collectives` / `verify_collectives`) priced per
    batch.  Returns the breakdown `explain()` records."""
    pos = workload["pos_rate"]
    ms = consts.get("approx_scale", consts.get("machine_scale", 1.0))
    dispatch = consts["dispatch_us"] / (batch_rows * max(cand.depth, 1))
    # virtual CPU devices share one socket: the ring splits compute only
    # when shards land on distinct physical devices
    r_speed = cand.r_shards if jax.default_backend() != "cpu" else 1
    if cand.verify == "exact":
        verify = pos * workload["exact_us_per_query"] / max(r_speed, 1)
    elif cand.verify.startswith("lsh"):
        sb = (skew["sb_occ_rebucket"] if cand.verify == "lsh+rebucket"
              else skew["sb_occ"])
        live = 10 * 4 * sb                       # l * n_probes * E[occ]
        base = consts["lsh_device_us" if cand.probe == "device"
                      else "lsh_host_us"]
        verify = pos * (base + consts["lsh_cand_ns"] * live * 1e-3) * ms
    else:
        verify = pos * consts["ivfpq_device_us" if cand.probe == "device"
                              else "ivfpq_host_us"] * ms
    # delta rows are swept exactly; price them off the LIVE measured
    # sweep (delta_frac of the full-table cost), not the snapshot pairs
    delta = workload.get("delta_frac", 0.0)
    verify += pos * delta * workload["exact_us_per_query"]
    from repro.core.topology import resolve_topology
    topo = resolve_topology(cand.topology)
    colls = (topo.sweep_collectives(cand.r_shards)
             + topo.verify_collectives(cand.r_shards))
    coll = consts["coll_us"] * colls / batch_rows
    total = dispatch + verify + coll
    return {"us_per_query": round(total, 2),
            "dispatch_us": round(dispatch, 3),
            "verify_us": round(verify, 2),
            "coll_us": round(coll, 3)}


def choose(workload: dict, skew: dict, consts: dict, *, recall: float,
           n_devices: int, n: int, pinned: dict,
           batch_rows: int = 64) -> tuple[Candidate, list, list]:
    """Price the pruned grid and pick the cheapest candidate.

    Ties break deterministically toward the simpler configuration
    (device probe, default block 512, depth 2, replicated) so the same
    stats always choose the same config — the determinism contract of
    the explain() tests."""
    cands, rejected = enumerate_candidates(skew, recall=recall,
                                           n_devices=n_devices,
                                           pinned=pinned)
    scored = []
    for c in cands:
        est = estimate_cost(c, workload, skew, consts, n=n,
                            batch_rows=batch_rows)
        scored.append((c, est))
    scored.sort(key=lambda ce: (ce[1]["us_per_query"],
                                ce[0].probe != "device",
                                ce[0].block != 512,
                                ce[0].depth != 2,
                                ce[0].topology != "replicated",
                                ce[0].key))
    if not scored:
        raise RuntimeError(
            "auto-planner: every candidate was rejected "
            f"({[r['reason'] for r in rejected]}) — relax the pinned "
            "knobs or the recall floor")
    return scored[0][0], scored, rejected


# ============================================================ the entry
def plan_auto(plan, Q, eps: float, *, recall: float = 0.9,
              err: float = 0.1, confidence: float = 0.95,
              seed: int = 0, batch_rows: int = 64):
    """Measure-then-choose for one `JoinPlan` (DESIGN.md §16).

    Returns ``(chosen_plan, explain)``: a new fully-specified built
    `JoinPlan` sharing the source plan's filter fit (fitted once on the
    measurement engine, carried as an instance like `fork()` does), and
    the machine-readable explain dict.  `Q` may be None — the sample
    then draws from R (the gateway's query-free planning path).  The
    source plan's explicit knobs are respected as pinned constraints:
    an `on(topology=)/on(probe=)/on(engine=)` or a by-name
    `verify(name, ...)` freezes that axis of the grid.  Auto-planning
    requires `search("naive")` — with an instance base the base itself
    is the route and there is nothing left to choose."""
    sspec = plan._search_spec[0]
    if sspec != "naive":
        raise ValueError(
            f"auto(): planning requires search('naive') — with "
            f"search({sspec if isinstance(sspec, str) else type(sspec).__name__!r}) "
            "the base carries its own route; pick verify/topology by hand")
    if not 0.0 < recall <= 1.0:
        raise ValueError(f"auto(recall={recall}): expected a floor in "
                         "(0, 1]")
    vspec = plan._verify_spec[0]
    if not isinstance(vspec, str):
        raise ValueError(
            f"auto(): verify({type(vspec).__name__}) pins a custom "
            "verifier instance — there is nothing left for the planner "
            "to choose; use verify('auto') or a by-name backend")
    engine = plan._exec["engine"]
    if engine is None:
        # measurement is placement-agnostic (range_count values are
        # topology-invariant), so measure on a simple replicated engine;
        # the chosen plan builds its own mesh when the choice is ring
        engine = JoinEngine(plan._R, plan.metric,
                            backend=plan._exec["backend"],
                            block=plan._exec["block"])
    if plan._exec["engine"] is not None:
        p_topo, p_r = engine.topology.name, engine.r_shards
    elif plan._exec["topology"] is not None:
        t = plan._exec["topology"]
        p_topo = t if isinstance(t, str) else t.name
        p_r = plan._exec["r_shards"]
    elif plan._exec["r_shards"] is not None:
        p_r = int(plan._exec["r_shards"])
        p_topo = "ring" if p_r > 1 else "replicated"
    else:
        p_topo, p_r = None, None
    vspec, vparams = plan._verify_spec
    pinned = {
        "topology": p_topo,
        "r_shards": p_r,
        "probe": (plan._exec["probe"]
                  if plan._exec["probe"] != "auto" else None),
        "block": (plan._exec["block"]
                  if plan._exec["block"] != 512 else None),
        "verify": (vspec if vspec in ("exact", "lsh", "ivfpq") else None),
    }
    sample, sample_meta = draw_sample(Q, plan._R, err=err,
                                      confidence=confidence, seed=seed)
    filt = plan._build_filter(engine)
    workload = measure_workload(engine, filt, sample, eps)
    # cache the timing-dependent stats alongside the constants so two
    # identically-seeded plans see identical numbers (determinism)
    wkey = ("workload", engine.nr, engine.metric, engine.backend,
            engine.world_version, round(float(eps), 9), len(sample), seed,
            plan._filter_spec[0] if isinstance(plan._filter_spec[0], str)
            else "instance")
    if wkey in _CALIBRATION_CACHE:
        workload = dict(_CALIBRATION_CACHE[wkey])
    else:
        _CALIBRATION_CACHE[wkey] = dict(workload)
    skew = measure_skew(plan._R, plan.metric, seed=seed,
                        verify_params=vparams)
    consts = calibrated_constants(engine, workload)
    n_devices = jax.device_count()
    best, scored, rejected = choose(workload, skew, consts, recall=recall,
                                    n_devices=n_devices, n=engine.nr,
                                    pinned=pinned, batch_rows=batch_rows)
    chosen = _apply(plan, best, engine, filt)
    explain = {
        "sample": sample_meta,
        "workload": workload,
        "skew": skew,
        "constants": consts,
        "recall_floor": float(recall),
        "seed": int(seed),
        "n_devices": int(n_devices),
        "pinned": {k: v for k, v in pinned.items() if v is not None},
        "candidates": [dict(config=c.key, **est) for c, est in scored],
        "rejected": rejected,
        "chosen": dict(asdict(best), est_us=scored[0][1]["us_per_query"]),
    }
    return chosen, explain


def _apply(plan, cand: Candidate, engine, filt):
    """Materialize the chosen candidate as a new built `JoinPlan`.

    The measurement engine is reused when its placement matches the
    choice (no second R upload); a ring choice on a replicated
    measurement engine builds the ring engine here.  The filter rides
    along as the already-fitted instance (the `fork()` carry), so the
    fit cost is paid exactly once per auto()."""
    from repro.core.api import JoinPlan
    clone = JoinPlan(plan._R, plan.metric)
    fspec, fopts = plan._filter_spec
    if fspec == "xling" and filt is not None:
        knobs = {k: v for k, v in fopts.items()
                 if k in ("tau", "xdt", "xdt_mode", "fpr_tolerance")}
        clone._filter_spec = (filt.filt, knobs)
    else:
        clone._filter_spec = (fspec, dict(fopts))
    clone._search_spec = ("naive", dict(plan._search_spec[1]))
    vparams = dict(plan._verify_spec[1])
    if cand.verify == "exact":
        clone._verify_spec = ("exact", {})
    elif cand.verify == "lsh+rebucket":
        vparams.setdefault("rebucket_hot", REBUCKET_HOT)
        clone._verify_spec = ("lsh", vparams)
    else:
        clone._verify_spec = (cand.verify, vparams)
    clone._exec = dict(plan._exec)
    clone._exec.update(block=int(cand.block),
                       probe=("auto" if cand.probe == "-" else cand.probe))
    if engine.topology.name == cand.topology and (
            cand.topology != "ring" or engine.r_shards == cand.r_shards):
        clone._exec.update(engine=engine, mesh=None, topology=None,
                           r_shards=None)
    else:
        clone._exec.update(engine=None, mesh=None, topology=cand.topology,
                           r_shards=(cand.r_shards
                                     if cand.topology == "ring" else None))
    if plan._mutable:
        clone.mutable(plan._auto_compact_at)
    clone._planned_depth = int(cand.depth)
    return clone.build()
