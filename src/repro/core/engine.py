"""Sharded, device-resident batch-join execution engine (DESIGN.md §4).

The paper reduces similarity join to filter-then-verify, and both halves
bottom out in dense range counting — work that should saturate accelerators.
This module is the execution layer that makes that true:

  * `JoinEngine` pins the index set R on device once (replicated over the
    mesh) and runs every sweep against it with bucketed static shapes.
  * The range-count sweep shards the QUERY axis over the mesh's data axis
    with `shard_map` (each device sweeps its query slice against the full
    replicated R), so ground-truth `cardinality_table` construction and
    naive-join verification scale across devices.
  * `filtered_join` is the fused XJoin hot path: estimator inference + XDT
    thresholding run as one device program; the single host sync reads the
    positive count to pick a power-of-two capacity bucket; compaction +
    verification then run as a second device program (gather the
    positives, count, scatter back) — skipped queries cost nothing.
  * Verification is pluggable (DESIGN.md §5): `verify="exact"` is the
    brute-force sweep above; `verify="lsh"` / `"ivfpq"` replace the sweep
    with an approximate index probe over the same device-resident R —
    candidates are verified on device through
    `joins.common.verify_candidates`, so counts stay exact *per candidate*
    and recall is measured against the exact path.
  * `stream` / `StreamSession` wrap that path for serving as an
    asynchronous double-buffered pipeline (DESIGN.md §5): batch *k+1*'s
    device programs are dispatched while batch *k*'s verification is still
    in flight and its results transfer back via non-blocking host copies;
    a bounded in-flight queue caps memory and `flush()` is the shutdown
    barrier. Compiled programs are reused across batches because every
    shape is bucketed.

Backend matrix (DESIGN.md §2): per-shard compute is the Pallas kernel on
TPU ("pallas"), the blocked-jnp path elsewhere ("jnp"/"auto"), or the
unblocked oracle ("ref" — no padding, used as the bit-for-bit reference).
"""
from __future__ import annotations

import collections
import functools
import time
from dataclasses import dataclass
from typing import Callable, Iterable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

try:                                    # moved to the stable namespace in
    from jax import shard_map           # newer JAX; experimental on 0.4.x
except ImportError:
    from jax.experimental.shard_map import shard_map


def _shard_mapped(f, mesh, in_specs, out_specs):
    try:
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)
    except TypeError:                   # newer API dropped check_rep
        return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)

from repro.kernels import ops, ref
from repro.kernels.range_count import range_count_hist_pallas


def _bucket_size(n: int, block: int) -> int:
    """Round n up to a bucketed multiple of block (recompile bounding).

    Power-of-two growth, refined with quarter steps once those are still
    block multiples — shape count stays logarithmic but padding overshoot
    is capped at 25% (a pure power-of-two bucket wastes up to ~50% of the
    work on padding rows at large n)."""
    if n <= block:
        return block
    b = block
    while b < n:
        b *= 2
    if b >= 8 * block:
        for eighths in (5, 6, 7):
            c = (b // 8) * eighths
            if c >= n:
                return c
    return b


def _pad_rows_np(x: np.ndarray, n: int) -> np.ndarray:
    if x.shape[0] >= n:
        return x
    pad = np.zeros((n - x.shape[0],) + x.shape[1:], x.dtype)
    return np.concatenate([x, pad])


def _q_blocked_hist(q, r, eps, *, metric, block_q, block_r, nr_valid):
    """[n, m] histogram, scanning q in block_q tiles so the fused
    compare tensor stays O(block_q * block_r * m). q rows % block_q == 0."""
    nblk = q.shape[0] // block_q
    qb = q.reshape(nblk, block_q, q.shape[1])
    out = jax.lax.map(
        lambda x: ops.blocked_hist(x, r, eps, metric=metric,
                                   block_r=block_r, nr_valid=nr_valid), qb)
    return out.reshape(nblk * block_q, eps.shape[0])


def _data_size(mesh, data_axis: str) -> int:
    return int(mesh.shape.get(data_axis, 1)) if mesh is not None else 1


@functools.lru_cache(maxsize=128)
def _hist_program(mesh, data_axis, backend, metric, block_q, block_r,
                  eps_chunk, nr_valid):
    """Compiled (optionally shard_map'ped) sweep. Module-level cache so
    engines over the same (mesh, |R|) share one XLA executable."""
    if backend == "pallas":
        interpret = jax.default_backend() != "tpu"

        def shard_fn(q, r, eps):
            return range_count_hist_pallas(
                q, r, eps, metric=metric, nr_valid=nr_valid, block_q=block_q,
                block_r=block_r, eps_chunk=eps_chunk, interpret=interpret)
    elif backend == "ref":
        def shard_fn(q, r, eps):
            return ref.range_count_hist(q, r, eps, metric)
    else:
        def shard_fn(q, r, eps):
            return _q_blocked_hist(q, r, eps, metric=metric, block_q=block_q,
                                   block_r=block_r, nr_valid=nr_valid)

    if _data_size(mesh, data_axis) > 1:
        shard_fn = _shard_mapped(shard_fn, mesh,
                                 in_specs=(P(data_axis), P(), P()),
                                 out_specs=P(data_axis))
    return jax.jit(shard_fn)


@functools.lru_cache(maxsize=128)
def _compact_program(mesh, data_axis, backend, metric, block_q, block_r,
                     nr_valid):
    """Fused compact -> verify -> scatter. `capacity` is the bucketed static
    shape; `n_pos` rides along as a device scalar so the same executable
    serves every occupancy of a bucket."""

    def prog(q, pos, n_pos, r, eps, *, capacity: int):
        idx = jnp.nonzero(pos, size=capacity, fill_value=0)[0]
        valid = jnp.arange(capacity) < n_pos
        qpos = jnp.take(q, idx, axis=0)
        if _data_size(mesh, data_axis) > 1:
            qpos = jax.lax.with_sharding_constraint(
                qpos, NamedSharding(mesh, P(data_axis)))
        eps1 = jnp.reshape(eps, (1,)).astype(jnp.float32)
        if backend == "ref":
            found = ref.range_count_hist(qpos, r, eps1, metric)[:, 0]
        elif capacity > block_q and capacity % block_q == 0:
            # large buckets get the same query tiling as the main sweep so
            # the compare temporaries stay O(block_q * block_r)
            found = _q_blocked_hist(qpos, r, eps1, metric=metric,
                                    block_q=block_q, block_r=block_r,
                                    nr_valid=nr_valid)[:, 0]
        else:
            found = ops.blocked_hist(qpos, r, eps1, metric=metric,
                                     block_r=block_r, nr_valid=nr_valid)[:, 0]
        # invalid (padding) lanes all scatter-add 0 onto row 0
        contrib = jnp.where(valid, found, 0).astype(jnp.int32)
        return jnp.zeros((q.shape[0],), jnp.int32).at[idx].add(contrib)

    # the padded query buffer is dead after this program — donate it on TPU
    # so the compact output can reuse its HBM (CPU donation only warns)
    donate = (0,) if jax.default_backend() == "tpu" else ()
    return jax.jit(prog, static_argnames=("capacity",), donate_argnums=donate)


@dataclass
class EngineJoinResult:
    """Result of one filtered-join batch through the engine."""
    counts: np.ndarray      # int32 [n] neighbor counts (0 for skipped)
    n_searched: int         # queries that reached verification
    t_filter: float
    t_search: float
    verify: str = "exact"   # label of the backend that produced `counts`


#: Verification backends accepted *by name* in `filtered_join(verify=...)` /
#: `stream(verify=...)`. "exact" is the engine's fused brute-force sweep;
#: the others probe an approximate index and verify candidates on device
#: (DESIGN.md §5). Beyond these names, `verify=` also accepts any Searcher
#: object (DESIGN.md §9): one exposing `candidates(Q)` routes its
#: candidates through the on-device verification path; one exposing only
#: `query_counts(Q, eps)` verifies the compacted positives on host.
VERIFY_BACKENDS = ("exact", "lsh", "ivfpq")

#: A verify spec: "exact", a VERIFY_BACKENDS name, or a Searcher object
#: (candidates() for device verification, query_counts() for the host
#: fallback) — validated by `_check_verify`.
VerifySpec = "str | object"


def _check_verify(verify) -> str:
    """Validate a `verify=` spec and return its display label.

    Accepted: "exact", a name from `VERIFY_BACKENDS`, or a plug-in
    searcher object exposing `candidates(Q)` (device candidate
    verification) or `query_counts(Q, eps)` (host verification of the
    compacted positives). Raises ValueError otherwise — at construction
    time, not data-dependently inside the pipeline."""
    if isinstance(verify, str):
        if verify not in VERIFY_BACKENDS:
            raise ValueError(f"verify={verify!r}: expected one of "
                             f"{sorted(VERIFY_BACKENDS)} or a searcher "
                             "object exposing candidates()/query_counts()")
        return verify
    if hasattr(verify, "candidates") or hasattr(verify, "query_counts"):
        return getattr(verify, "name", type(verify).__name__)
    raise ValueError(
        f"verify={type(verify).__name__!r} object: plug-in verification "
        "searchers must expose candidates(Q) -> int32 [q, C] (-1 padded) "
        "or query_counts(Q, eps) -> int32 [q]")


def _start_host_copy(arr) -> None:
    """Kick off a non-blocking device→host transfer so the later
    `np.asarray` materialization finds the bytes already resident."""
    try:
        arr.copy_to_host_async()
    except AttributeError:
        pass                                # backend without async copies


class _StagedBatch:
    """Stage-1 handle: queries resident, filter program dispatched, nothing
    synced. `n_pos` is None until `JoinEngine._commit_verify` reads it."""
    __slots__ = ("Q", "n", "eps", "qdev", "eps_dev", "pos_dev", "n_pos_dev",
                 "n_pos", "t_stage")


class PendingJoin:
    """Stage-2 handle for one in-flight batch.

    Verification is dispatched and the device→host copy is running;
    `result()` is the only blocking point and is idempotent. Async-path
    timing convention: `t_search` = dispatch-side cost + whatever wait
    `result()` actually observed (≈0 when the pipeline hid the readback).
    """

    def __init__(self, finalize: Callable[[], np.ndarray], *, verify: str,
                 n_searched: int, t_filter: float, t_dispatch: float):
        self._finalize = finalize
        self._verify = verify
        self._n_searched = n_searched
        self._t_filter = t_filter
        self._t_dispatch = t_dispatch
        self._res: Optional[EngineJoinResult] = None

    def result(self) -> EngineJoinResult:
        """Materialize (blocking if the device is still busy)."""
        if self._res is None:
            t0 = time.perf_counter()
            counts = self._finalize()
            self._res = EngineJoinResult(
                counts, self._n_searched, self._t_filter,
                self._t_dispatch + (time.perf_counter() - t0), self._verify)
        return self._res


class StreamSession:
    """Asynchronous double-buffered serving session (DESIGN.md §5).

    Push interface under `JoinEngine.stream`: `submit(Q)` stages the new
    batch's device programs, commits the previously staged batch's
    verification, and returns any results forced out by the `depth` bound;
    `flush()` is the shutdown barrier — it commits the staged batch,
    materializes everything outstanding, and returns the remaining results.

    Invariants:
      * results come back in submission order (FIFO), bit-identical to
        per-batch `filtered_join` calls;
      * at most `depth` committed batches plus one staged batch are in
        flight, bounding device memory at (depth + 2) padded batches;
      * on the exact verify route, the only per-batch host sync is the
        staged batch's positive-count read, issued AFTER the next batch's
        programs are enqueued (approximate/plug-in routes additionally
        read back the verdicts and probe on host inside commit — their
        candidate *verification* still overlaps, but probing is
        synchronous);
      * after `flush()` returns, no engine program of this session is
        outstanding.
    """

    def __init__(self, engine: "JoinEngine", eps: float, *, predict=None,
                 threshold=None, verify: VerifySpec = "exact", depth: int = 2,
                 block: int | None = None):
        _check_verify(verify)
        self.engine = engine
        self.eps = float(eps)
        self.predict, self.threshold = predict, threshold
        self.verify, self.depth, self.block = verify, max(int(depth), 0), block
        self._staged: Optional[_StagedBatch] = None
        self._inflight: collections.deque[PendingJoin] = collections.deque()

    def _commit_staged(self) -> None:
        if self._staged is not None:
            self._inflight.append(self.engine._commit_verify(
                self._staged, verify=self.verify, block=self.block))
            self._staged = None

    def submit(self, Q, *, verdicts=None) -> list[EngineJoinResult]:
        """Feed one query batch; returns the (possibly empty) list of OLDER
        batches' results whose readback completed under the depth bound.
        `verdicts` optionally carries precomputed host filter verdicts for
        this batch (plug-in filters without a device predict fn)."""
        st = self.engine._stage_filter(
            Q, self.eps, predict=self.predict, threshold=self.threshold,
            verdicts=verdicts)
        self._commit_staged()               # previous batch enters verify
        self._staged = st
        out = []
        while len(self._inflight) > self.depth:
            out.append(self._inflight.popleft().result())
        return out

    def flush(self) -> list[EngineJoinResult]:
        """Barrier: drain the pipeline, returning all remaining results in
        submission order. Safe to call repeatedly; the session can keep
        submitting afterwards (the pipeline just restarts cold)."""
        self._commit_staged()
        out = []
        while self._inflight:
            out.append(self._inflight.popleft().result())
        return out


class JoinEngine:
    """Device-resident exact join over a fixed index set R.

    mesh: optional `jax.sharding.Mesh` with a `data_axis` axis (use
    `launch.mesh.make_data_mesh()`); queries shard over it, R replicates.
    Without a mesh everything runs single-device through the same programs.
    """

    def __init__(self, R, metric: str = "cosine", *, mesh=None,
                 backend: str = "auto", block_q: int = 256, block_r: int = 512,
                 block: int = 512, eps_chunk: int = 8, data_axis: str = "data"):
        self.metric = metric
        self.backend = ops._resolve(backend)
        self.mesh, self.data_axis = mesh, data_axis
        self.block_q, self.block_r, self.block = block_q, block_r, block
        self.eps_chunk = eps_chunk
        R = np.asarray(R, np.float32)
        self.nr, self.dim = R.shape
        # host-side R backs lazy approximate-verifier construction (§5);
        # np.asarray above is a no-copy view for float32 input
        self._R_host = R
        self._verifiers: dict = {}
        self.ndata = _data_size(mesh, data_axis)
        # "ref" sweeps the raw R (the oracle handles any shape); the blocked
        # backends see an R padded to a block_r multiple and mask via nr_valid
        Rp = R if self.backend == "ref" else _pad_rows_np(
            R, ((self.nr + block_r - 1) // block_r) * block_r)
        if mesh is not None:
            self._q_sharding = NamedSharding(mesh, P(data_axis))
            self._Rdev = jax.device_put(Rp, NamedSharding(mesh, P()))
        else:
            self._q_sharding = None
            self._Rdev = jnp.asarray(Rp)
        self._filter_progs: dict = {}

    # ------------------------------------------------------------- plumbing
    def _pad_q(self, Q) -> np.ndarray:
        """Bucket the query count to a power-of-two multiple of one full
        mesh sweep (block_q rows per device) — bounds recompiles AND keeps
        per-shard shapes block-aligned."""
        Q = np.asarray(Q, np.float32)
        return _pad_rows_np(Q, _bucket_size(len(Q), self.block_q * self.ndata))

    def _put_q(self, qp: np.ndarray) -> jax.Array:
        if self._q_sharding is not None:
            return jax.device_put(qp, self._q_sharding)
        return jnp.asarray(qp)

    def _pad_eps(self, eps_grid) -> np.ndarray:
        e = np.asarray(eps_grid, np.float32).reshape(-1)
        if self.backend == "pallas":
            pad = (-len(e)) % self.eps_chunk
            if pad:
                e = np.concatenate([e, np.full((pad,), np.inf, np.float32)])
        return e

    # ------------------------------------------------------- range counting
    def device_range_count_hist(self, Q, eps_grid) -> jax.Array:
        """Sharded sweep; returns the DEVICE array [n_padded, m_padded]
        (query axis distributed over the data axis). Callers that want the
        exact [n, m] table use `range_count_hist`."""
        qp = self._pad_q(Q)
        ep = self._pad_eps(eps_grid)
        prog = _hist_program(self.mesh, self.data_axis, self.backend,
                             self.metric, self.block_q, self.block_r,
                             self.eps_chunk, self.nr)
        return prog(self._put_q(qp), self._Rdev, jnp.asarray(ep))

    def range_count_hist(self, Q, eps_grid) -> np.ndarray:
        """counts[i, j] = #-neighbors of Q[i] in R within eps_grid[j]."""
        m = np.asarray(eps_grid).reshape(-1).shape[0]
        out = self.device_range_count_hist(Q, eps_grid)
        return np.asarray(out)[: len(Q), :m]

    def range_count(self, Q, eps: float) -> np.ndarray:
        """counts[i] = #-neighbors of Q[i] in R within a single eps."""
        return self.range_count_hist(Q, [float(eps)])[:, 0]

    def cardinality_table(self, points, eps_grid, *,
                          exclude_self: bool = False) -> np.ndarray:
        """Ground-truth target table over the eps grid (optionally with
        each point's self-match removed, for R-vs-R training tables)."""
        t = self.range_count_hist(points, eps_grid)
        if exclude_self:
            t = np.maximum(t - 1, 0)
        return t

    # ------------------------------------------------- fused filtered join
    def _filter_program(self, predict):
        # keyed by the fn object itself (estimators memoize it): survives
        # refits without id-reuse aliasing, and the key pins the fn alive
        _, fn = predict
        prog = self._filter_progs.get(fn)
        if prog is None:
            def program(params, q, eps, thr, n_valid):
                X = jnp.concatenate(
                    [q, jnp.full((q.shape[0], 1), eps, jnp.float32)], axis=1)
                preds = fn(params, X)
                pos = (preds > thr) & (jnp.arange(q.shape[0]) < n_valid)
                return preds, pos, jnp.sum(pos, dtype=jnp.int32)
            prog = jax.jit(program)
            self._filter_progs[fn] = prog
        return prog

    # --------------------------------------------- stage 1: filter dispatch
    def _stage_filter(self, Q, eps: float, *, predict=None, threshold=None,
                      verdicts=None) -> "_StagedBatch":
        """Dispatch the filter program for one batch WITHOUT any host sync.

        Pads + `device_put`s the queries (async H2D), enqueues the fused
        estimator/XDT program (or uploads precomputed host verdicts), and
        returns a `_StagedBatch` handle. Nothing here waits on the device,
        so batch k+1 can be staged while batch k's verification is still
        executing — the double-buffering half of DESIGN.md §5."""
        st = _StagedBatch()
        st.Q = np.asarray(Q, np.float32)
        st.n = len(st.Q)
        st.eps = float(eps)
        t0 = time.perf_counter()
        qp = self._pad_q(st.Q)
        st.qdev = self._put_q(qp)
        st.eps_dev = jnp.asarray(st.eps, jnp.float32)
        if predict is None and verdicts is None:
            verdicts = np.ones((st.n,), bool)   # no filter: verify everything
        if verdicts is not None:
            pos_host = np.zeros((len(qp),), bool)
            pos_host[:st.n] = np.asarray(verdicts, bool)
            st.n_pos = int(pos_host.sum())
            st.pos_dev = (jax.device_put(pos_host, self._q_sharding)
                          if self._q_sharding is not None
                          else jnp.asarray(pos_host))
            st.n_pos_dev = jnp.asarray(st.n_pos, jnp.int32)
        else:
            params, _ = predict
            prog = self._filter_program(predict)
            _, st.pos_dev, st.n_pos_dev = prog(
                params, st.qdev, st.eps_dev,
                jnp.asarray(threshold, jnp.float32),
                jnp.asarray(st.n, jnp.int32))
            st.n_pos = None                 # read at commit time
        st.t_stage = time.perf_counter() - t0
        return st

    # ------------------------------------- stage 2: verify dispatch (commit)
    def _commit_verify(self, st: "_StagedBatch", *, verify: VerifySpec = "exact",
                       block: int | None = None) -> "PendingJoin":
        """Read the staged batch's positive count and dispatch verification.

        The `int(n_pos_dev)` here is the pipeline's only per-batch host
        sync; it waits on this batch's *filter* program only — earlier
        batches' (much deeper) verification programs keep running behind
        it. Returns a `PendingJoin`; device→host copies are started
        non-blocking so `result()` is usually a no-wait.

        `verify` is "exact", a `VERIFY_BACKENDS` name, or a plug-in
        searcher object (see `_check_verify`): any join method's
        `candidates()` can route the compacted positives through the
        device candidate-verification path — the Searcher half of the
        DESIGN.md §9 protocol contract."""
        label = _check_verify(verify)       # fail fast, not data-dependently
        t0 = time.perf_counter()
        if st.n_pos is None:
            st.n_pos = int(st.n_pos_dev)
        t_filter = st.t_stage + (time.perf_counter() - t0)
        n, n_pos = st.n, st.n_pos

        if n_pos == 0:
            return PendingJoin(lambda: np.zeros((n,), np.int32), verify=label,
                               n_searched=0, t_filter=t_filter, t_dispatch=0.0)

        t1 = time.perf_counter()
        if verify == "exact":
            capacity = min(_bucket_size(n_pos, block or self.block),
                           st.qdev.shape[0])
            cprog = _compact_program(self.mesh, self.data_axis, self.backend,
                                     self.metric, self.block_q, self.block_r,
                                     self.nr)
            counts_dev = cprog(st.qdev, st.pos_dev, st.n_pos_dev, self._Rdev,
                               st.eps_dev, capacity=capacity)
            _start_host_copy(counts_dev)
            finalize = lambda: np.asarray(counts_dev)[:n]   # noqa: E731
        else:
            from repro.core.joins.common import (dispatch_verify_candidates,
                                                 searcher_candidates)
            searcher = self.verifier(verify) if isinstance(verify, str) \
                else verify
            # host probing needs the verdicts; the filter program is already
            # complete (n_pos was just read), so this transfer is cheap
            pos_host = np.asarray(st.pos_dev)[:n]
            idx = np.nonzero(pos_host)[0]
            qpos = st.Q[idx]
            if hasattr(searcher, "candidates"):
                cand = searcher_candidates(searcher, qpos, st.eps)
                pend = dispatch_verify_candidates(
                    self._Rdev, qpos, cand, st.eps, self.metric,
                    backend=self.backend)

                def finalize():
                    counts = np.zeros((n,), np.int32)
                    counts[idx] = pend.result()
                    return counts
            else:
                # candidate-less plug-in: the searcher verifies the
                # compacted positives itself (synchronous host hop — the
                # generic "any loop-based method" fallback)
                found = np.asarray(searcher.query_counts(qpos, st.eps),
                                   np.int32)

                def finalize():
                    counts = np.zeros((n,), np.int32)
                    counts[idx] = found
                    return counts
        t_dispatch = time.perf_counter() - t1
        return PendingJoin(finalize, verify=label, n_searched=n_pos,
                           t_filter=t_filter, t_dispatch=t_dispatch)

    # ------------------------------------------------ verification backends
    def verifier(self, name: str, **params):
        """The approximate searcher backing `verify=name` (DESIGN.md §5).

        Built lazily over the engine's host-side R and cached per name, so
        a serving session pays index construction once. Calling with
        `params` always (re)builds the index with those params and replaces
        the cached instance (e.g. `engine.verifier("lsh", l=16,
        n_probes=8)` before streaming is the tuning hook — a silent
        cache hit here would drop the override); calling without params
        returns the cached index, building with defaults on first use.
        The searcher must expose `candidates(Q) -> int32 [q, C]` (-1 pad).
        """
        if name not in VERIFY_BACKENDS or name == "exact":
            raise ValueError(
                f"verifier={name!r}: expected an approximate backend "
                f"({sorted(set(VERIFY_BACKENDS) - {'exact'})}; "
                "'exact' is the fused sweep — it has no index to build)")
        v = None if params else self._verifiers.get(name)
        if v is None:
            from repro.core.joins import make_join   # circular at import time
            v = make_join(name, self._R_host, self.metric, **params)
            if not hasattr(v, "candidates"):
                raise TypeError(f"join {name!r} exposes no candidates()")
            self._verifiers[name] = v
        return v

    # --------------------------------------------------- one-shot join call
    def filtered_join(self, Q, eps: float, *, predict=None, threshold=None,
                      verdicts=None, block: int | None = None,
                      verify: VerifySpec = "exact") -> EngineJoinResult:
        """One synchronous filter -> threshold -> compact -> verify pass.

        Either pass `predict` = (params, fn) from an estimator's
        `device_predict_fn()` plus the XDT `threshold` (fully fused path),
        or a precomputed host bool `verdicts` array (plug-in filters).
        `block` overrides the compaction bucket quantum (default
        self.block); `verify` picks the verification backend ("exact" |
        "lsh" | "ivfpq", DESIGN.md §5 — or any Searcher object whose
        `candidates()` feeds the device verification path, DESIGN.md §9).
        This is the synchronous reference path — `stream` pipelines the
        same two stages."""
        st = self._stage_filter(Q, eps, predict=predict, threshold=threshold,
                                verdicts=verdicts)
        return self._commit_verify(st, verify=verify, block=block).result()

    # ------------------------------------------------------------ streaming
    def stream_session(self, eps: float, *, predict=None, threshold=None,
                       verify: VerifySpec = "exact", depth: int = 2,
                       block: int | None = None) -> "StreamSession":
        """Open an asynchronous `StreamSession` (push interface) over this
        engine; `stream` is the pull/iterator form of the same pipeline."""
        return StreamSession(self, eps, predict=predict, threshold=threshold,
                             verify=verify, depth=depth, block=block)

    def stream(self, batches: Iterable, eps: float, *, predict=None,
               threshold=None, verify: VerifySpec = "exact", depth: int = 2,
               block: int | None = None) -> Iterator[EngineJoinResult]:
        """Serving loop: pipeline query batches through the engine.

        Asynchronous double-buffered (DESIGN.md §5): each incoming batch is
        staged (filter dispatched) before the previous batch's verification
        is committed, and results are materialized only when more than
        `depth` batches are in flight — dispatch of batch k+1 overlaps the
        readback of batch k. Results are yielded in submission order and
        are bit-identical to per-batch `filtered_join` calls. R, the
        estimator, and all compiled programs stay device-resident across
        the whole stream (bucketed shapes). `depth=0` degenerates to
        commit-then-materialize per batch (still one staged batch of
        lookahead)."""
        sess = self.stream_session(eps, predict=predict, threshold=threshold,
                                   verify=verify, depth=depth, block=block)
        for Q in batches:
            yield from sess.submit(Q)
        yield from sess.flush()


def sharded_range_count_hist(Q, R, eps_grid, *, metric: str = "cosine",
                             mesh=None, backend: str = "auto",
                             block_q: int = 256, block_r: int = 512,
                             data_axis: str = "data") -> np.ndarray:
    """One-shot functional form of `JoinEngine.range_count_hist` (used by
    `data.groundtruth.cardinality_table`); prefer a `JoinEngine` when R is
    swept more than once."""
    eng = JoinEngine(R, metric, mesh=mesh, backend=backend, block_q=block_q,
                     block_r=block_r, data_axis=data_axis)
    return eng.range_count_hist(Q, eps_grid)
