"""Sharded, device-resident batch-join execution engine (DESIGN.md §4).

The paper reduces similarity join to filter-then-verify, and both halves
bottom out in dense range counting — work that should saturate accelerators.
This module is the execution layer that makes that true:

  * `JoinEngine` pins the index set R on device once and runs every sweep
    against it with bucketed static shapes.  WHERE R lives is a
    first-class choice (DESIGN.md §10): `topology="replicated"` (the
    default — R on every device, queries sharded over the mesh's data
    axis) or `topology="ring"` (R row-sharded over a second `r` mesh
    axis; the sweep runs as a `jax.lax.ppermute` ring with per-shard
    partial counts `psum`'d over `r`, so |R| scales past one device's
    memory).  The placement logic itself lives in `core/topology.py`;
    this module stays the scheduling/caching layer.
  * The range-count sweep shards the QUERY axis over the mesh
    with `shard_map` (each device sweeps its query slice against its
    topology-resident view of R), so ground-truth `cardinality_table`
    construction and naive-join verification scale across devices.
  * `filtered_join` is the fused XJoin hot path: estimator inference + XDT
    thresholding run as one device program; the single host sync reads the
    positive count to pick a power-of-two capacity bucket; compaction +
    verification then run as a second device program (gather the
    positives, count, scatter back) — skipped queries cost nothing.
  * Verification is pluggable (DESIGN.md §5): `verify="exact"` is the
    brute-force sweep above; `verify="lsh"` / `"ivfpq"` replace the sweep
    with an approximate index probe over the same device-resident R —
    candidates are verified on device through
    `joins.common.verify_candidates`, so counts stay exact *per candidate*
    and recall is measured against the exact path. WHERE the probe runs
    is a placement choice (DESIGN.md §11, `probe="auto"|"device"|"host"`):
    with a device-capable searcher the probe tables live on the mesh
    (`core/probe.py`) and compact → probe → verify is all device
    programs — the positive-count read is the only per-batch host sync.
  * `stream` / `StreamSession` wrap that path for serving as an
    asynchronous pipelined stream (DESIGN.md §5, §11): batches flow
    filter-staged -> probe-staged -> committed, so batch *k+1*'s device
    programs (and, with device probing, batch *k*'s probe) are dispatched
    while batch *k−1*'s verification is still in flight and its results
    transfer back via non-blocking host copies; a bounded in-flight queue
    caps memory and `flush()` is the shutdown barrier. Compiled programs
    are reused across batches because every shape is bucketed.
  * Dynamic R (DESIGN.md §13): `insert` / `delete` mutate the logical
    index set with NO index rebuild — inserts accumulate in a small
    replicated device-resident delta shard (power-of-two bucketed,
    probed exactly and added into every count); deletes zero the
    tombstoned rows inside the pinned R (their closed-form zero-row
    contribution is subtracted, the same mechanism as ring pad-row
    masking) and mask them in candidate verification via an int32
    tombstone mask. `compact()` folds the delta into the pinned R,
    rebuilds the approximate indices, and evicts the compiled programs
    through `clear_program_cache()` — counts stay bit-identical to a
    fresh `ref` oracle over the logical (R ∪ delta − tombstones) set at
    every point in a mutation sequence.

Backend matrix (DESIGN.md §2): per-shard compute is the Pallas kernel on
TPU ("pallas"), the blocked-jnp path elsewhere ("jnp"/"auto"), or the
unblocked oracle ("ref" — no padding, used as the bit-for-bit reference).
The backend also selects the probe-side kernels (DESIGN.md §15):
`engine.backend` threads into the placed probe programs, which dispatch
the LSH bucket gather and IVF-PQ ADC ranking through
`kernels/lsh_gather.py` / `kernels/adc_rank.py` under "pallas" and
their bit-identical jnp formulations otherwise.  Every compiled probe
program is a module-level `lru_cache` registered here via
`register_program_cache`, so `clear_program_cache()` evicts the whole
backend-keyed matrix at once.
"""
from __future__ import annotations

import collections
import contextlib
import functools
import time
import weakref
from dataclasses import dataclass
from typing import Callable, Iterable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.core.topology import (Topology, _data_size, _zero_row_distance,
                                 resolve_topology)
from repro.kernels import ops


def _bucket_size(n: int, block: int) -> int:
    """Round n up to a bucketed multiple of block (recompile bounding).

    Power-of-two growth, refined with quarter steps once those are still
    block multiples — shape count stays logarithmic but padding overshoot
    is capped at 25% (a pure power-of-two bucket wastes up to ~50% of the
    work on padding rows at large n)."""
    if n <= block:
        return block
    b = block
    while b < n:
        b *= 2
    if b >= 8 * block:
        for eighths in (5, 6, 7):
            c = (b // 8) * eighths
            if c >= n:
                return c
    return b


def _pad_rows_np(x: np.ndarray, n: int) -> np.ndarray:
    if x.shape[0] >= n:
        return x
    pad = np.zeros((n - x.shape[0],) + x.shape[1:], x.dtype)
    return np.concatenate([x, pad])


#: Registry of every module-level compiled-program `lru_cache` in
#: `core/` (DESIGN.md §12).  The caches key on the mesh (among others)
#: and thereby pin XLA executables — and through them device buffers —
#: alive for meshes a long-lived process has already discarded, so each
#: one MUST be evictable by `clear_program_cache()`.  Registration is by
#: the `register_program_cache` decorator; xlint's cache-registry rule
#: rejects any `functools.lru_cache` program builder in `core/` that is
#: not registered, so a new cache can never silently escape eviction.
_PROGRAM_CACHES: list = []


def register_program_cache(cache):
    """Register a module-level `functools.lru_cache` program builder in
    `_PROGRAM_CACHES` so `clear_program_cache()` evicts it.

    Stack it ABOVE `@functools.lru_cache` (it returns its argument, so
    the bound name keeps `cache_clear`/`cache_info`).  Mandatory for
    every program cache in `core/` — enforced statically by xlint's
    cache-registry rule (DESIGN.md §12)."""
    _PROGRAM_CACHES.append(cache)
    return cache


@register_program_cache
@functools.lru_cache(maxsize=128)
def _hist_program(mesh, data_axis, backend, metric, block_q, block_r,
                  eps_chunk, nr_valid, topology):
    """Compiled topology-parametrized sweep `(q, r, eps, nrv) -> [n, m]`.
    Module-level cache so engines over the same (mesh, topology, |R|)
    share one XLA executable; evict with `clear_program_cache`."""
    return topology.hist_program(mesh, data_axis, backend, metric, block_q,
                                 block_r, eps_chunk, nr_valid)


@register_program_cache
@functools.lru_cache(maxsize=128)
def _compact_program(mesh, data_axis, backend, metric, block_q, block_r,
                     nr_valid, topology):
    """Compiled topology-parametrized compact -> verify -> scatter program
    `(q, pos, n_pos, r, eps, nrv, *, capacity) -> [n]`. `capacity` is the
    bucketed static shape; `n_pos` rides along as a device scalar so the
    same executable serves every occupancy of a bucket. Cached like
    `_hist_program`; evict with `clear_program_cache`."""
    return topology.compact_program(mesh, data_axis, backend, metric,
                                    block_q, block_r, nr_valid)


@register_program_cache
@functools.lru_cache(maxsize=32)
def _delete_program(mesh, r_spec):
    """Compiled tombstone apply `(R, tomb, rows) -> (R', tomb')`: zero the
    deleted rows in the pinned R and set their tombstone flags, keeping
    the topology's R sharding.  Deliberately NOT donating: staged stream
    batches snapshot the pre-delete buffers (`_WorldView`), so the update
    must be purely functional — old snapshots stay valid until their
    batch commits.  `rows` is bucketed (repeat-padded with rows[0], an
    idempotent re-delete) so one executable serves every delete size."""
    def run(R, tomb, rows):
        R2 = R.at[rows].set(0.0)
        t2 = tomb.at[rows].set(1)
        if mesh is not None:
            s = NamedSharding(mesh, r_spec)
            R2 = jax.lax.with_sharding_constraint(R2, s)
            t2 = jax.lax.with_sharding_constraint(t2, s)
        return R2, t2
    return jax.jit(run)


@register_program_cache
@functools.lru_cache(maxsize=64)
def _delta_count_program(mesh, metric):
    """Compiled per-batch mutation adjustment for single-eps counts
    (DESIGN.md §13): sweep the queries against the replicated delta shard
    with the oracle's own distance math (`ref.pair_distances`, so delta
    verdicts are bit-identical to a fresh oracle over the live rows) and,
    on exact-sweep routes, subtract the tombstoned rows' closed-form
    zero-row contribution (`n_tomb` traced; None on candidate routes,
    where the tombstone mask already removed them in verification).
    `counts=None` returns the bare adjustment (host-probe routes add it
    after their own scatter).  None-ness of counts/n_tomb keys retraces,
    not recompiles per batch — both are fixed per route."""
    from repro.kernels import ref

    def run(counts, q, pos, delta, dvalid, eps, n_tomb):
        d = ref.pair_distances(q, delta, metric)
        dcnt = jnp.sum((d <= eps) & (dvalid[None, :] == 1),
                       axis=1, dtype=jnp.int32)
        if n_tomb is not None:
            hit = (_zero_row_distance(metric) <= eps).astype(jnp.int32)
            dcnt = dcnt - n_tomb * hit
        adj = jnp.where(pos, dcnt, 0).astype(jnp.int32)
        return adj if counts is None else counts + adj
    return jax.jit(run)


@register_program_cache
@functools.lru_cache(maxsize=64)
def _delta_hist_program(mesh, metric):
    """Compiled mutation adjustment for the eps-grid histogram sweep:
    adds the live delta rows' counts and subtracts the tombstoned rows'
    closed-form zero-row contribution per eps bin — the histogram twin of
    `_delta_count_program`, applied by `device_range_count_hist` so the
    ground-truth tables also see the logical (R ∪ delta − tombstones)
    set."""
    from repro.kernels import ref

    def run(counts, q, delta, dvalid, eps_grid, n_tomb):
        d = ref.pair_distances(q, delta, metric)
        dcnt = jnp.sum((d[:, :, None] <= eps_grid[None, None, :])
                       & (dvalid[None, :, None] == 1),
                       axis=1, dtype=jnp.int32)
        zhit = (_zero_row_distance(metric) <= eps_grid).astype(jnp.int32)
        return counts + dcnt - n_tomb * zhit[None, :]
    return jax.jit(run)


def clear_program_cache() -> None:
    """Evict every registered module-level compiled-program cache.

    Iterates the `_PROGRAM_CACHES` registry, so it can never silently
    miss a cache: every `functools.lru_cache` program builder in `core/`
    registers itself via `register_program_cache` at import time (the
    xlint cache-registry rule enforces this, DESIGN.md §12).  Call this
    after tearing down a mesh (tests do) to release the executables it
    pins; programs rebuild transparently on the next engine call."""
    for cache in list(_PROGRAM_CACHES):
        cache.cache_clear()


@dataclass
class EngineJoinResult:
    """Result of one filtered-join batch through the engine."""
    counts: np.ndarray      # int32 [n] neighbor counts (0 for skipped)
    n_searched: int         # queries that reached verification
    t_filter: float
    t_search: float
    verify: str = "exact"   # label of the backend that produced `counts`
    probe: Optional[str] = None   # "device" | "host" | None (exact sweep)


#: Verification backends accepted *by name* in `filtered_join(verify=...)` /
#: `stream(verify=...)`. "exact" is the engine's fused brute-force sweep;
#: the others probe an approximate index and verify candidates on device
#: (DESIGN.md §5). Beyond these names, `verify=` also accepts any Searcher
#: object (DESIGN.md §9): one exposing `candidates(Q)` routes its
#: candidates through the on-device verification path; one exposing only
#: `query_counts(Q, eps)` verifies the compacted positives on host.
VERIFY_BACKENDS = ("exact", "lsh", "ivfpq")

#: A verify spec: "exact", a VERIFY_BACKENDS name, or a Searcher object
#: (candidates() for device verification, query_counts() for the host
#: fallback) — validated by `_check_verify`.
VerifySpec = "str | object"

#: Probe placement modes (DESIGN.md §11): "auto" runs the probe on
#: device whenever the verify route's searcher advertises a device probe
#: (DeviceSearcher / probe.PROBE_BUILDERS), "device" requires it (fails
#: at construction when unavailable), "host" forces the legacy host
#: probe even when a device probe exists.
PROBE_MODES = ("auto", "device", "host")


#: active `host_sync_guard` scopes — a stack of frozensets of allowed
#: sync kinds consulted by `_note_host_sync`
_SYNC_GUARDS: list = []


class HostSyncError(RuntimeError):
    """An UNDECLARED per-batch host sync fired inside a
    `host_sync_guard` scope (DESIGN.md §12)."""


def _note_host_sync(kind: str) -> None:
    """Instrumentation hook invoked at every per-batch host
    synchronization point: "n_pos" (the positive-count read), "verdicts"
    (device->host verdict readback for host probing), "probe" (the host
    index probe itself), "result" (final counts materialization). A
    no-op in production; tests monkeypatch it to assert the device-probe
    route performs no per-batch host transfers beyond the count read and
    the result readback (the ISSUE 5 acceptance invariant). Under an
    active `host_sync_guard`, a kind outside the allowed set raises
    `HostSyncError` — the hook doubles as the runtime guard's tripwire
    on backends whose zero-copy array transfers are invisible to
    `jax.transfer_guard` (the CPU backend)."""
    if _SYNC_GUARDS and kind not in _SYNC_GUARDS[-1]:
        raise HostSyncError(
            f"disallowed host sync {kind!r} inside host_sync_guard scope "
            f"(allowed kinds: {sorted(_SYNC_GUARDS[-1])}) — DESIGN.md §12")


@contextlib.contextmanager
def host_sync_guard(*allowed: str):
    """Runtime guard scope (DESIGN.md §12): every per-batch host sync in
    the scope must be one of `allowed` or `HostSyncError` is raised.

    Two enforcement layers compose here.  The hook layer
    (`_note_host_sync`) catches any instrumented sync with an undeclared
    kind — it works on every backend, including CPU, where JAX's
    zero-copy transfers never reach the XLA transfer guard.  The XLA
    layer (`jax.transfer_guard_device_to_host("disallow")`, entered for
    the whole scope) additionally catches UNinstrumented device→host
    transfers on accelerator backends; the declared sync points open
    their own `"allow"` windows via `_allowed_transfer`, which is why
    `allowed` should normally be exactly `("n_pos", "result")` — the two
    syncs the exact and device-probe streamed routes are specified to
    perform (§11).  tests/test_guards.py runs the parity lanes inside
    this scope."""
    _SYNC_GUARDS.append(frozenset(allowed))
    try:
        with jax.transfer_guard_device_to_host("disallow"):
            yield
    finally:
        _SYNC_GUARDS.pop()


@contextlib.contextmanager
def _allowed_transfer(kind: str):
    """Scope of one DECLARED per-batch device→host sync (DESIGN.md §12).

    The exact and device-probe routes declare exactly two such points —
    the positive-count read ("n_pos") and the final result readback
    ("result").  Entering the scope notes the sync for the test
    instrumentation (`_note_host_sync`) and opens a
    `jax.transfer_guard_device_to_host("allow")` window, so the
    transfer-guard test lane (tests/test_guards.py) can run the whole
    stream under `"disallow"` and any UNdeclared transfer raises — the
    §11 "only two host transfers per batch" claim as an enforced runtime
    property, not just instrumentation.  Host-probe syncs ("verdicts" /
    "probe") deliberately do NOT open an allow window: under the guard
    the host route fails, which is what proves the guard is live."""
    _note_host_sync(kind)
    with jax.transfer_guard_device_to_host("allow"):
        yield


def _check_verify(verify) -> str:
    """Validate a `verify=` spec and return its display label.

    Accepted: "exact", a name from `VERIFY_BACKENDS`, or a plug-in
    searcher object exposing `candidates(Q)` (device candidate
    verification) or `query_counts(Q, eps)` (host verification of the
    compacted positives). Raises ValueError otherwise — at construction
    time, not data-dependently inside the pipeline."""
    if isinstance(verify, str):
        if verify not in VERIFY_BACKENDS:
            raise ValueError(f"verify={verify!r}: expected one of "
                             f"{sorted(VERIFY_BACKENDS)} or a searcher "
                             "object exposing candidates()/query_counts()")
        return verify
    if hasattr(verify, "candidates") or hasattr(verify, "query_counts"):
        return getattr(verify, "name", type(verify).__name__)
    raise ValueError(
        f"verify={type(verify).__name__!r} object: plug-in verification "
        "searchers must expose candidates(Q) -> int32 [q, C] (-1 padded) "
        "or query_counts(Q, eps) -> int32 [q]")


def _start_host_copy(arr) -> None:
    """Kick off a non-blocking device→host transfer so the later
    `np.asarray` materialization finds the bytes already resident.

    This is the asynchronous START of the declared "result" readback
    (the blocking half lives in `PendingJoin.result` under
    `_allowed_transfer("result")`), so it runs inside an explicit
    device→host allow window of its own — one readback, two phases."""
    with jax.transfer_guard_device_to_host("allow"):
        try:
            arr.copy_to_host_async()
        except AttributeError:
            pass                            # backend without async copies


class _WorldView:
    """Immutable snapshot of the engine's logical index state, pinned on
    a `_StagedBatch` at stage time (DESIGN.md §13).

    Mutations (`insert` / `delete`) are purely functional — they swap the
    engine's references to fresh device buffers, never write into the old
    ones — so a batch staged BEFORE a mutation keeps sweeping the exact
    logical set that existed at its submit time, even though its
    verification commits later.  That is the streamed snapshot-consistency
    contract: batch k's counts always equal a fresh oracle over the
    logical set as of batch k's submission."""
    __slots__ = ("Rdev", "nrv", "delta", "dvalid", "tomb", "n_tomb",
                 "n_tomb_dev", "mutated")


class _StagedBatch:
    """Stage-1/2 handle: queries resident, filter program dispatched,
    nothing synced. `n_pos` is None until `JoinEngine._stage_probe` (or
    `_commit_verify` as a fallback) reads it; on a device-probe route
    `_stage_probe` additionally fills `qpos_dev` / `idx_dev` / `cand_dev`
    and sets `probe` to the placed probe that produced them. `world` is
    the submit-time `_WorldView` snapshot (DESIGN.md §13)."""
    __slots__ = ("Q", "n", "eps", "qdev", "eps_dev", "pos_dev", "n_pos_dev",
                 "n_pos", "t_stage", "probe", "qpos_dev", "idx_dev",
                 "cand_dev", "capacity", "world")


class PendingJoin:
    """Stage-2 handle for one in-flight batch.

    Verification is dispatched and the device→host copy is running;
    `result()` is the only blocking point and is idempotent. Async-path
    timing convention: `t_search` = dispatch-side cost + whatever wait
    `result()` actually observed (≈0 when the pipeline hid the readback).
    """

    def __init__(self, finalize: Callable[[], np.ndarray], *, verify: str,
                 n_searched: int, t_filter: float, t_dispatch: float,
                 probe: Optional[str] = None):
        self._finalize = finalize
        self._verify = verify
        self._probe = probe
        self._n_searched = n_searched
        self._t_filter = t_filter
        self._t_dispatch = t_dispatch
        self._res: Optional[EngineJoinResult] = None

    def result(self) -> EngineJoinResult:
        """Materialize (blocking if the device is still busy)."""
        if self._res is None:
            t0 = time.perf_counter()
            with _allowed_transfer("result"):
                counts = self._finalize()
            self._res = EngineJoinResult(
                counts, self._n_searched, self._t_filter,
                self._t_dispatch + (time.perf_counter() - t0), self._verify,
                self._probe)
        return self._res


class StreamSession:
    """Asynchronous pipelined serving session (DESIGN.md §5, §11).

    Push interface under `JoinEngine.stream`. Batches flow through THREE
    stages — filter-staged -> probe-staged -> committed (verifying) —
    so with a device-probe route batch k+1's probing executes on device
    while batch k's verification is still in flight. `submit(Q)` stages
    the new batch's filter programs, commits the probe-staged batch's
    verification, advances the filter-staged batch into the probe stage
    (its positive-count read is the per-batch host sync), and returns
    any results forced out by the `depth` bound; `flush()` is the
    shutdown barrier — it drains all three stages and returns the
    remaining results.

    Invariants:
      * results come back in submission order (FIFO), bit-identical to
        per-batch `filtered_join` calls;
      * at most `depth` committed batches plus one probe-staged and one
        filter-staged batch are in flight, bounding device memory at
        (depth + 3) padded batches;
      * on the exact and device-probe verify routes, the only per-batch
        host syncs are the probe-staged batch's positive-count read —
        issued AFTER the next batch's filter programs and the previous
        batch's verification are enqueued — and the final result
        readback (host-probe routes additionally read back the verdicts
        and probe on host inside commit — their candidate *verification*
        still overlaps, but probing is synchronous);
      * after `flush()` returns, no engine program of this session is
        outstanding.
    """

    def __init__(self, engine: "JoinEngine", eps: float, *, predict=None,
                 threshold=None, verify: VerifySpec = "exact", depth: int = 2,
                 block: int | None = None, probe: str = "auto"):
        _check_verify(verify)
        # resolve the probe route up front: probe="device" without a
        # device-capable searcher fails here, never mid-stream
        self._placed = engine.device_probe_for(verify, probe, eps=eps)
        self._probe_mode = probe
        self.engine = engine
        self.eps = float(eps)
        self.predict, self.threshold = predict, threshold
        self.verify, self.depth, self.block = verify, max(int(depth), 0), block
        self._staged: Optional[_StagedBatch] = None
        self._probed: Optional[_StagedBatch] = None
        self._inflight: collections.deque[PendingJoin] = collections.deque()
        # results forced out by a mid-stream compact() drain (§13): they
        # are re-emitted FIRST by the next submit/flush, preserving FIFO
        self._ready: list[EngineJoinResult] = []
        engine._sessions.add(self)

    def _commit_probed(self) -> None:
        if self._probed is not None:
            self._inflight.append(self.engine._commit_verify(
                self._probed, verify=self.verify, block=self.block))
            self._probed = None

    def _advance_staged(self) -> None:
        if self._staged is not None:
            self._probed = self.engine._stage_probe(
                self._staged, placed=self._placed, block=self.block)
            self._staged = None

    def submit(self, Q, *, verdicts=None) -> list[EngineJoinResult]:
        """Feed one query batch; returns the (possibly empty) list of OLDER
        batches' results whose readback completed under the depth bound.
        `verdicts` optionally carries precomputed host filter verdicts for
        this batch (plug-in filters without a device predict fn)."""
        st = self.engine._stage_filter(
            Q, self.eps, predict=self.predict, threshold=self.threshold,
            verdicts=verdicts)
        self._commit_probed()               # batch k-1 enters verify
        self._advance_staged()              # batch k probes (count read)
        self._staged = st
        out, self._ready = self._ready, []  # compact-drained results first
        while len(self._inflight) > self.depth:
            out.append(self._inflight.popleft().result())
        return out

    def set_depth(self, depth: int) -> None:
        """Retarget the in-flight bound mid-stream (the serve gateway's
        adaptive-depth hook, DESIGN.md §14). A smaller depth takes effect
        on the NEXT submit — already-committed batches drain under the new
        bound; nothing is cancelled, so results stay FIFO and
        bit-identical."""
        self.depth = max(int(depth), 0)

    def flush(self) -> list[EngineJoinResult]:
        """Barrier: drain the pipeline, returning all remaining results in
        submission order. Safe to call repeatedly; the session can keep
        submitting afterwards (the pipeline just restarts cold)."""
        self._commit_probed()
        self._advance_staged()
        self._commit_probed()
        out, self._ready = self._ready, []  # compact-drained results first
        while self._inflight:
            out.append(self._inflight.popleft().result())
        return out

    # -------------------------------------- dynamic-R compaction hooks
    def _drain_for_compact(self) -> None:
        """Flush every in-flight batch into the session's ready buffer so
        `JoinEngine.compact()` can swap geometry with nothing staged
        (DESIGN.md §13).  The results are re-emitted in FIFO order by the
        next `submit`/`flush`, so callers observe the same sequence as an
        uninterrupted stream."""
        drained = self.flush()      # flush() rebinds _ready — extend AFTER
        self._ready.extend(drained)

    def _rebind_after_compact(self) -> None:
        """Re-resolve the placed probe: compaction rebuilt the verify
        indices over the merged R, so the pre-compact probe tables are
        stale."""
        self._placed = self.engine.device_probe_for(
            self.verify, self._probe_mode, eps=self.eps)


class JoinEngine:
    """Device-resident exact join over a fixed index set R.

    mesh: optional `jax.sharding.Mesh` (use `launch.mesh.make_data_mesh()`
    or, for the ring topology, `launch.mesh.make_join_mesh(data=, r=)`).
    topology: "replicated" (default — queries shard over `data_axis`, R
    replicates) or "ring" (R row-sharded over the mesh's `r` axis; the
    sweep runs as a ppermute ring, DESIGN.md §10), or a `Topology`
    instance. Without a mesh everything runs single-device through the
    same programs.
    """

    def __init__(self, R, metric: str = "cosine", *, mesh=None,
                 backend: str = "auto", block_q: int = 256, block_r: int = 512,
                 block: int = 512, eps_chunk: int = 8, data_axis: str = "data",
                 topology: "str | Topology" = "replicated"):
        self.metric = metric
        self.backend = ops._resolve(backend)
        self.mesh, self.data_axis = mesh, data_axis
        self.block_q, self.block_r, self.block = block_q, block_r, block
        self.eps_chunk = eps_chunk
        self.topology = resolve_topology(topology)
        self.topology.validate(mesh, data_axis)
        R = np.asarray(R, np.float32)
        self.dim = R.shape[1]
        self._verifiers: dict = {}
        self._probes: dict = {}     # searcher -> PlacedProbe | None (§11)
        self.ndata = _data_size(mesh, data_axis)
        self.r_shards = self.topology.r_shards(mesh)
        self._q_sharding = None if mesh is None else NamedSharding(
            mesh, self.topology.q_spec(data_axis))
        self._upload_R(R)
        self._filter_progs: dict = {}
        #: per-batch staging constants (DESIGN.md §5): streamed batches
        #: re-stage the same radius scalar and — on unfiltered plans —
        #: the same all-positive mask every submit; both depend only on
        #: (value, shape bucket), so one upload serves the whole stream.
        #: Bounded: distinct radii / shape buckets per engine are few.
        self._eps_scalar_cache: dict = {}
        self._allpos_cache: dict = {}
        # ---- dynamic-R state (DESIGN.md §13) ----------------------------
        #: compact automatically once delta_frac reaches this fraction of
        #: |R| (None = manual compaction only; JoinPlan.mutable sets it)
        self.auto_compact_at: float | None = None
        self.n_compactions = 0
        #: monotone logical-set version: bumped by every insert/delete/
        #: compact, never reset. Cache layers (the serve gateway's
        #: eps-aware result cache, DESIGN.md §14) key entries on it so a
        #: result computed against one world can never answer a query
        #: against another.
        self.world_version = 0
        self._next_id = self.nr             # monotone logical row ids
        self._main_ids = np.arange(self.nr, dtype=np.int64)
        self._delta_rows = np.empty((0, self.dim), np.float32)
        self._delta_ids = np.empty((0,), np.int64)
        self._delta_live = np.empty((0,), bool)
        self._tomb_rows: set[int] = set()   # physical rows tombstoned in R
        self._id_index: dict | None = None  # lazy id -> location map
        self._delta_dev = None              # padded delta rows on device
        self._delta_valid_dev = None        # int32 live mask over the pad
        self._tomb_dev = None               # int32 [nr_padded] tombstones
        self._n_tomb_dev = None             # int32 scalar tombstone count
        self._sessions: weakref.WeakSet = weakref.WeakSet()
        self._verifier_params: dict = {}    # name -> params for rebuilds

    def _upload_R(self, R: np.ndarray) -> None:
        """Pad R to the topology's row quantum and pin it on the mesh —
        shared by `__init__` and `compact()` (which re-uploads the merged
        logical set after evicting the compiled programs)."""
        self.nr = len(R)
        # host-side R backs lazy approximate-verifier construction (§5);
        # np.asarray is a no-copy view for float32 input
        self._R_host = R
        # "ref" on the replicated topology sweeps the raw R (the oracle
        # handles any shape); everything else sees an R padded to the
        # topology's row quantum (equal block-aligned shards) and masks —
        # statically via nr_valid, or via the traced pad-row correction
        # on sharded placements
        if self.backend == "ref" and self.r_shards == 1:
            Rp = R
        else:
            quantum = self.topology.r_row_quantum(self.block_r, self.mesh)
            Rp = _pad_rows_np(R, -(-self.nr // quantum) * quantum)
        self.nr_padded = len(Rp)
        nrv = self.topology.nr_valid_shards(self.nr, self.nr_padded,
                                            self.mesh)
        if self.mesh is not None:
            r_sharding = NamedSharding(self.mesh, self.topology.r_spec())
            self._Rdev = jax.device_put(Rp, r_sharding)
            self._nrv_dev = None if nrv is None else jax.device_put(
                nrv, r_sharding)
        else:
            self._Rdev = jnp.asarray(Rp)
            self._nrv_dev = None if nrv is None else jnp.asarray(nrv)

    @property
    def per_device_r_bytes(self) -> int:
        """Bytes of (padded) R resident on EACH device — the number the
        topology choice moves; reported by `JoinPlan.describe()`."""
        return self.topology.per_device_r_bytes(self.nr_padded, self.dim,
                                                self.mesh)

    # ------------------------------------------- dynamic R (DESIGN.md §13)
    @property
    def n_delta(self) -> int:
        """Live (non-deleted) rows currently in the delta shard."""
        return int(self._delta_live.sum())

    @property
    def n_tombstones(self) -> int:
        """Main-R rows deleted but not yet compacted away."""
        return len(self._tomb_rows)

    @property
    def delta_capacity(self) -> int:
        """Bucketed device rows the delta shard currently occupies."""
        return 0 if self._delta_dev is None else int(self._delta_dev.shape[0])

    @property
    def delta_frac(self) -> float:
        """Pending mutations as a fraction of |R| — the auto-compaction
        trigger metric (`describe()` reports it)."""
        return (len(self._delta_rows) + len(self._tomb_rows)) / max(self.nr, 1)

    def _world(self) -> _WorldView:
        """Snapshot the logical index state for one staged batch."""
        w = _WorldView()
        w.Rdev, w.nrv = self._Rdev, self._nrv_dev
        w.delta, w.dvalid = self._delta_dev, self._delta_valid_dev
        w.tomb = self._tomb_dev
        w.n_tomb, w.n_tomb_dev = len(self._tomb_rows), self._n_tomb_dev
        w.mutated = self._delta_dev is not None
        return w

    def _stable_index(self) -> dict:
        """id -> ("main", physical row) | ("delta", slot); rebuilt lazily
        after compaction invalidates the physical positions."""
        if self._id_index is None:
            self._id_index = {int(i): ("main", r)
                              for r, i in enumerate(self._main_ids)}
            self._id_index.update(
                {int(i): ("delta", s)
                 for s, i in enumerate(self._delta_ids)})
        return self._id_index

    def _put_replicated(self, x: np.ndarray) -> jax.Array:
        if self.mesh is not None:
            return jax.device_put(
                x, NamedSharding(self.mesh, self.topology.delta_spec()))
        return jnp.asarray(x)

    def _upload_delta(self) -> None:
        """Re-pin the delta shard: rows padded to a 64-row power-of-two
        bucket (matching the probe capacity quantum) with an int32 live
        mask, replicated per `topology.delta_spec()` so the ring sweep
        schedule is untouched.  A fresh buffer every time — staged
        batches keep their snapshot of the old one."""
        cap = _bucket_size(max(len(self._delta_rows), 1), 64)
        self._delta_dev = self._put_replicated(
            _pad_rows_np(self._delta_rows, cap))
        valid = np.zeros((cap,), np.int32)
        valid[: len(self._delta_live)] = self._delta_live
        self._delta_valid_dev = self._put_replicated(valid)
        if self._n_tomb_dev is None:
            self._n_tomb_dev = jnp.asarray(0, jnp.int32)

    def _ensure_tomb(self) -> jax.Array:
        """The int32 [nr_padded] tombstone mask, materialized on first
        delete (sharded like R so candidate verification indexes it
        locally on every placement)."""
        if self._tomb_dev is None:
            tomb = np.zeros((self.nr_padded,), np.int32)
            if self.mesh is not None:
                self._tomb_dev = jax.device_put(
                    tomb, NamedSharding(self.mesh, self.topology.r_spec()))
            else:
                self._tomb_dev = jnp.asarray(tomb)
        return self._tomb_dev

    def insert(self, rows) -> np.ndarray:
        """Insert rows into the logical index set; returns their int64 ids.

        The rows land in the device-resident delta shard — probed exactly
        and merged into every subsequent count (`_delta_count_program`) —
        with NO rebuild of R, the learned filter, or the approximate
        verify indices.  `compact()` (or the `auto_compact_at` policy)
        later folds them into the pinned R."""
        rows = np.atleast_2d(np.asarray(rows, np.float32))
        if rows.ndim != 2 or rows.shape[1] != self.dim:
            raise ValueError(
                f"insert: rows have shape {rows.shape}; expected (k, "
                f"{self.dim}) matching the engine's R")
        ids = np.arange(self._next_id, self._next_id + len(rows),
                        dtype=np.int64)
        self._next_id += len(rows)
        base = len(self._delta_rows)
        self._delta_rows = np.concatenate([self._delta_rows, rows])
        self._delta_ids = np.concatenate([self._delta_ids, ids])
        self._delta_live = np.concatenate(
            [self._delta_live, np.ones((len(rows),), bool)])
        if self._id_index is not None:
            for s, i in enumerate(ids):
                self._id_index[int(i)] = ("delta", base + s)
        self._upload_delta()
        self.world_version += 1
        self._maybe_auto_compact()
        return ids

    def delete(self, ids) -> None:
        """Delete rows by id. Main-R rows become tombstones — zeroed in
        the pinned R (their closed-form zero-row contribution is
        subtracted from exact sweeps, the ring pad-row mechanism) and
        masked out of candidate verification; delta rows just drop their
        live flag.  Unknown or already-deleted ids raise KeyError BEFORE
        any state changes, so a failed delete mutates nothing."""
        ids = np.atleast_1d(np.asarray(ids, np.int64))
        index = self._stable_index()
        seen: set[int] = set()
        resolved = []
        for i in ids:
            i = int(i)
            loc = index.get(i)
            dead = (loc is None or i in seen
                    or (loc[0] == "main" and loc[1] in self._tomb_rows)
                    or (loc[0] == "delta" and not self._delta_live[loc[1]]))
            if dead:
                raise KeyError(
                    f"delete: id {i} is unknown or already deleted")
            seen.add(i)
            resolved.append(loc)
        main = [r for kind, r in resolved if kind == "main"]
        slots = [s for kind, s in resolved if kind == "delta"]
        if slots:
            self._delta_live[slots] = False
            self._upload_delta()
        if main:
            self._tomb_rows.update(main)
            rows = np.asarray(main, np.int32)
            # bucket the row list (repeat rows[0]: an idempotent pad) so
            # one compiled delete program serves every delete size
            rp = np.full((_bucket_size(len(rows), 64),), rows[0], np.int32)
            rp[: len(rows)] = rows
            prog = _delete_program(self.mesh, self.topology.r_spec())
            self._Rdev, self._tomb_dev = prog(
                self._Rdev, self._ensure_tomb(), jnp.asarray(rp))
            self._n_tomb_dev = jnp.asarray(len(self._tomb_rows), jnp.int32)
            if self._delta_dev is None:     # mutated: adjust must run even
                self._upload_delta()        # with an empty delta
        self.world_version += 1
        self._maybe_auto_compact()

    def compact(self) -> dict:
        """Fold the delta into the pinned R and drop the tombstones.

        Drains every live stream session (their in-flight results are
        re-emitted FIFO), evicts all compiled programs through
        `clear_program_cache()` (geometry changes: nr/nr_padded key the
        caches), re-uploads the merged (R ∪ delta − tombstones) set, and
        rebuilds the cached approximate verifiers with their recorded
        params so post-compact counts are what a fresh engine over the
        merged set would produce.  Returns a stats dict; a no-op (nothing
        pending) returns `{"compacted": False, ...}` without touching the
        program caches."""
        merged = len(self._tomb_rows) + len(self._delta_rows)
        if merged == 0:
            return {"compacted": False, "n_r": self.nr, "n_merged": 0,
                    "n_dropped": 0}
        for sess in list(self._sessions):
            sess._drain_for_compact()
        keep = np.ones((self.nr,), bool)
        keep[list(self._tomb_rows)] = False
        live = self._delta_live
        newR = np.concatenate([self._R_host[keep],
                               self._delta_rows[live]])
        if len(newR) == 0:
            raise ValueError(
                "compact: the logical index set is empty (every row "
                "deleted) — insert rows before compacting")
        n_merged = int(live.sum())
        n_dropped = len(self._tomb_rows) + int((~live).sum())
        clear_program_cache()
        self._upload_R(newR)
        self._main_ids = np.concatenate(
            [self._main_ids[keep], self._delta_ids[live]])
        self._id_index = None
        self._tomb_rows = set()
        self._delta_rows = np.empty((0, self.dim), np.float32)
        self._delta_ids = np.empty((0,), np.int64)
        self._delta_live = np.empty((0,), bool)
        self._delta_dev = self._delta_valid_dev = None
        self._tomb_dev = self._n_tomb_dev = None
        # rebuild approximate verify indices over the merged set with the
        # params their last build recorded (drop instances + placed probes)
        self._verifiers.clear()
        self._probes.clear()
        for name, params in self._verifier_params.items():
            self.verifier(name, **params)
        self.n_compactions += 1
        self.world_version += 1
        for sess in list(self._sessions):
            sess._rebind_after_compact()
        return {"compacted": True, "n_r": self.nr, "n_merged": n_merged,
                "n_dropped": n_dropped}

    def _maybe_auto_compact(self) -> None:
        if (self.auto_compact_at is not None
                and self.delta_frac >= self.auto_compact_at):
            self.compact()

    # ------------------------------------------------------------- plumbing
    def padded_rows(self, n: int) -> int:
        """Query rows a batch of `n` actually occupies after `_pad_q`'s
        power-of-two bucketing — the batch-composition hook the serve
        gateway's coalescer uses to pack requests up to a bucket boundary
        instead of paying the same padded sweep for half-empty batches
        (DESIGN.md §14)."""
        quantum = self.topology.q_row_quantum(self.block_q, self.mesh,
                                              self.data_axis)
        return _bucket_size(max(int(n), 1), quantum)

    def _pad_q(self, Q) -> np.ndarray:
        """Bucket the query count to a power-of-two multiple of one full
        mesh sweep (block_q rows per device, over every axis the topology
        shards queries on) — bounds recompiles AND keeps per-shard shapes
        block-aligned."""
        Q = np.asarray(Q, np.float32)
        quantum = self.topology.q_row_quantum(self.block_q, self.mesh,
                                              self.data_axis)
        return _pad_rows_np(Q, _bucket_size(len(Q), quantum))

    def _put_q(self, qp: np.ndarray) -> jax.Array:
        if self._q_sharding is not None:
            return jax.device_put(qp, self._q_sharding)
        return jnp.asarray(qp)

    def _pad_eps(self, eps_grid) -> np.ndarray:
        e = np.asarray(eps_grid, np.float32).reshape(-1)
        if self.backend == "pallas":
            pad = (-len(e)) % self.eps_chunk
            if pad:
                e = np.concatenate([e, np.full((pad,), np.inf, np.float32)])
        return e

    # ------------------------------------------------------- range counting
    def device_range_count_hist(self, Q, eps_grid) -> jax.Array:
        """Sharded sweep; returns the DEVICE array [n_padded, m_padded]
        (query axis distributed over the data axis). Callers that want the
        exact [n, m] table use `range_count_hist`."""
        qp = self._pad_q(Q)
        ep = self._pad_eps(eps_grid)
        prog = _hist_program(self.mesh, self.data_axis, self.backend,
                             self.metric, self.block_q, self.block_r,
                             self.eps_chunk, self.nr, self.topology)
        qdev, ep_dev = self._put_q(qp), jnp.asarray(ep)
        out = prog(qdev, self._Rdev, ep_dev, self._nrv_dev)
        w = self._world()
        if w.mutated:
            # logical-set adjustment (§13): add the live delta rows,
            # subtract the tombstones' closed-form contribution (padded
            # query rows / inf eps pad columns are sliced off by callers)
            out = _delta_hist_program(self.mesh, self.metric)(
                out, qdev, w.delta, w.dvalid, ep_dev, w.n_tomb_dev)
        return out

    def range_count_hist(self, Q, eps_grid) -> np.ndarray:
        """counts[i, j] = #-neighbors of Q[i] in R within eps_grid[j]."""
        m = np.asarray(eps_grid).reshape(-1).shape[0]
        out = self.device_range_count_hist(Q, eps_grid)
        return np.asarray(out)[: len(Q), :m]

    def range_count(self, Q, eps: float) -> np.ndarray:
        """counts[i] = #-neighbors of Q[i] in R within a single eps."""
        return self.range_count_hist(Q, [float(eps)])[:, 0]

    def cardinality_table(self, points, eps_grid, *,
                          exclude_self: bool = False) -> np.ndarray:
        """Ground-truth target table over the eps grid (optionally with
        each point's self-match removed, for R-vs-R training tables)."""
        t = self.range_count_hist(points, eps_grid)
        if exclude_self:
            t = np.maximum(t - 1, 0)
        return t

    # ------------------------------------------------- fused filtered join
    def _filter_program(self, predict):
        # keyed by the fn object itself (estimators memoize it): survives
        # refits without id-reuse aliasing, and the key pins the fn alive
        _, fn = predict
        prog = self._filter_progs.get(fn)
        if prog is None:
            def program(params, q, eps, thr, n_valid):
                X = jnp.concatenate(
                    [q, jnp.full((q.shape[0], 1), eps, jnp.float32)], axis=1)
                preds = fn(params, X)
                pos = (preds > thr) & (jnp.arange(q.shape[0]) < n_valid)
                return preds, pos, jnp.sum(pos, dtype=jnp.int32)
            prog = jax.jit(program)
            self._filter_progs[fn] = prog
        return prog

    # --------------------------------------------- stage 1: filter dispatch
    def _stage_filter(self, Q, eps: float, *, predict=None, threshold=None,
                      verdicts=None) -> "_StagedBatch":
        """Dispatch the filter program for one batch WITHOUT any host sync.

        Pads + `device_put`s the queries (async H2D), enqueues the fused
        estimator/XDT program (or uploads precomputed host verdicts), and
        returns a `_StagedBatch` handle. Nothing here waits on the device,
        so batch k+1 can be staged while batch k's verification is still
        executing — the double-buffering half of DESIGN.md §5."""
        st = _StagedBatch()
        st.Q = np.asarray(Q, np.float32)
        st.n = len(st.Q)
        st.eps = float(eps)
        t0 = time.perf_counter()
        qp = self._pad_q(st.Q)
        st.qdev = self._put_q(qp)
        st.eps_dev = self._eps_scalar_cache.get(st.eps)
        if st.eps_dev is None:
            if len(self._eps_scalar_cache) > 64:
                self._eps_scalar_cache.clear()
            st.eps_dev = jnp.asarray(st.eps, jnp.float32)
            self._eps_scalar_cache[st.eps] = st.eps_dev
        if predict is None and verdicts is None:
            # no filter: verify everything — the all-positive mask and its
            # count depend only on (padded rows, batch rows), so the
            # stream reuses one device-resident pair per shape bucket
            cached = self._allpos_cache.get((len(qp), st.n))
            if cached is None:
                if len(self._allpos_cache) > 64:
                    self._allpos_cache.clear()
                pos_host = np.zeros((len(qp),), bool)
                pos_host[:st.n] = True
                cached = ((jax.device_put(pos_host, self._q_sharding)
                           if self._q_sharding is not None
                           else jnp.asarray(pos_host)),
                          jnp.asarray(st.n, jnp.int32))
                self._allpos_cache[(len(qp), st.n)] = cached
            st.pos_dev, st.n_pos_dev = cached
            st.n_pos = st.n
        elif verdicts is not None:
            pos_host = np.zeros((len(qp),), bool)
            pos_host[:st.n] = np.asarray(verdicts, bool)
            st.n_pos = int(pos_host.sum())
            st.pos_dev = (jax.device_put(pos_host, self._q_sharding)
                          if self._q_sharding is not None
                          else jnp.asarray(pos_host))
            st.n_pos_dev = jnp.asarray(st.n_pos, jnp.int32)
        else:
            params, _ = predict
            prog = self._filter_program(predict)
            _, st.pos_dev, st.n_pos_dev = prog(
                params, st.qdev, st.eps_dev,
                jnp.asarray(threshold, jnp.float32),
                jnp.asarray(st.n, jnp.int32))
            st.n_pos = None                 # read at commit time
        st.probe = None                     # set by _stage_probe (§11)
        st.world = self._world()            # submit-time snapshot (§13)
        st.t_stage = time.perf_counter() - t0
        return st

    # ------------------------------------------- stage 2: probe dispatch
    def device_probe_for(self, verify: VerifySpec, mode: str = "auto", *,
                         eps: float | None = None):
        """Resolve the device-probe route for a verify spec (§11).

        mode="host" returns None (legacy host probing); "auto" returns a
        placed probe when the route's searcher advertises one
        (`device_probe(eps)` / `probe.PROBE_BUILDERS`) and None
        otherwise; "device" REQUIRES one and raises ValueError when the
        route has no probe stage (the exact sweep, query_counts-only
        plug-ins) or the searcher is host-only — at construction time,
        not mid-stream. `eps` is forwarded to the searcher's
        `device_probe` (None at plan-build/validation time); placement
        (table upload + program build) is cached per returned SPEC, so
        radius-free probes — which memoize one spec per index — pay the
        upload once, while an eps-aware searcher gets one placement per
        distinct spec it returns."""
        if mode not in PROBE_MODES:
            raise ValueError(f"probe={mode!r}: expected one of "
                             f"{list(PROBE_MODES)}")
        if mode == "host":
            return None
        label = _check_verify(verify)
        searcher = None
        if isinstance(verify, str):
            if verify != "exact":
                searcher = self.verifier(verify)
        elif hasattr(verify, "candidates"):
            searcher = verify
        if searcher is None:
            if mode == "device":
                raise ValueError(
                    f"probe='device': verify={label!r} has no probe stage "
                    "(the exact sweep and query_counts-only plug-ins "
                    "produce no candidates); use probe='auto'|'host' or an "
                    "approximate searcher")
            return None
        from repro.core.probe import as_device_probe
        spec = as_device_probe(searcher, eps)
        if spec is None:
            if mode == "device":
                raise ValueError(
                    f"probe='device': searcher {label!r} exposes no device "
                    "probe — implement device_probe(eps) (DESIGN.md §11) "
                    "or register a builder in probe.PROBE_BUILDERS; "
                    "probe='auto' falls back to host probing")
            return None
        placed = self._probes.get(spec)
        if placed is None:
            placed = spec.place(self)
            self._probes[spec] = placed
        return placed

    def _stage_probe(self, st: "_StagedBatch", *, placed=None,
                     block: int | None = None) -> "_StagedBatch":
        """Stage 2 of the pipeline (§11): read the staged batch's positive
        count (the pipeline's per-batch host sync — it waits on this
        batch's cheap filter program only) and, on a device-probe route,
        dispatch the compact-gather and probe programs, producing the
        candidate ids on device while the PREVIOUS batch's verification
        is still executing. Host-probe routes only perform the count
        read here; the probing itself stays in `_commit_verify`."""
        t0 = time.perf_counter()
        if st.n_pos is None:
            with _allowed_transfer("n_pos"):
                # xlint: allow-host-sync(n_pos: per-batch count read)
                st.n_pos = int(st.n_pos_dev)
        if placed is not None:
            st.probe = placed               # the route, even if this batch
            if st.n_pos > 0:                # stages nothing (all-negative)
                from repro.core.probe import _gather_program
                # probe cost is per-row (unlike the exact sweep, whose
                # program cost is dominated by |R|), so the capacity bucket
                # uses a fine 64-row quantum — the lcm of the IVF-PQ ADC
                # tile and the verify block — instead of the coarse
                # compaction block: small batches probe ~n_pos rows, not a
                # whole padded batch
                st.capacity = min(_bucket_size(st.n_pos, 64),
                                  st.qdev.shape[0])
                gather = _gather_program(self.mesh, self.data_axis)
                st.qpos_dev, st.idx_dev = gather(st.qdev, st.pos_dev,
                                                 capacity=st.capacity)
                st.cand_dev = placed.probe(st.qpos_dev)
        st.t_stage += time.perf_counter() - t0
        return st

    # ------------------------------------- stage 3: verify dispatch (commit)
    def _commit_verify(self, st: "_StagedBatch", *, verify: VerifySpec = "exact",
                       block: int | None = None) -> "PendingJoin":
        """Read the staged batch's positive count and dispatch verification.

        The `int(n_pos_dev)` here is the pipeline's only per-batch host
        sync; it waits on this batch's *filter* program only — earlier
        batches' (much deeper) verification programs keep running behind
        it. Returns a `PendingJoin`; device→host copies are started
        non-blocking so `result()` is usually a no-wait.

        `verify` is "exact", a `VERIFY_BACKENDS` name, or a plug-in
        searcher object (see `_check_verify`): any join method's
        `candidates()` can route the compacted positives through the
        device candidate-verification path — the Searcher half of the
        DESIGN.md §9 protocol contract."""
        label = _check_verify(verify)       # fail fast, not data-dependently
        t0 = time.perf_counter()
        if st.n_pos is None:                # direct callers skipped stage 2
            with _allowed_transfer("n_pos"):
                # xlint: allow-host-sync(n_pos: per-batch count read)
                st.n_pos = int(st.n_pos_dev)
        t_filter = st.t_stage + (time.perf_counter() - t0)
        n, n_pos = st.n, st.n_pos
        w = st.world                        # submit-time logical set (§13)
        probe_label = None if verify == "exact" else \
            ("device" if st.probe is not None else "host")

        if n_pos == 0:
            return PendingJoin(lambda: np.zeros((n,), np.int32), verify=label,
                               n_searched=0, t_filter=t_filter,
                               t_dispatch=0.0, probe=probe_label)

        t1 = time.perf_counter()
        if verify == "exact":
            capacity = min(_bucket_size(n_pos, block or self.block),
                           st.qdev.shape[0])
            cprog = _compact_program(self.mesh, self.data_axis, self.backend,
                                     self.metric, self.block_q, self.block_r,
                                     self.nr, self.topology)
            counts_dev = cprog(st.qdev, st.pos_dev, st.n_pos_dev, w.Rdev,
                               st.eps_dev, w.nrv, capacity=capacity)
            if w.mutated:
                # exact sweep counted tombstones (zeroed rows): subtract
                # their closed-form contribution and add the delta rows
                counts_dev = _delta_count_program(self.mesh, self.metric)(
                    counts_dev, st.qdev, st.pos_dev, w.delta, w.dvalid,
                    st.eps_dev, w.n_tomb_dev)
            _start_host_copy(counts_dev)
            # xlint: allow-host-sync(result: readback in PendingJoin.result)
            finalize = lambda: np.asarray(counts_dev)[:n]   # noqa: E731
        elif st.probe is not None:
            # device-probe route (§11): candidates were produced on device
            # by _stage_probe — verification + scatter dispatch here, with
            # no host transfer of verdicts or candidates at all
            counts_dev = st.probe.verify(
                st.qpos_dev, st.cand_dev, st.idx_dev, st.n_pos_dev,
                st.eps_dev, out_rows=st.qdev.shape[0], Rdev=w.Rdev,
                tomb=w.tomb)
            if w.mutated:
                # tombstones were masked in verification (a deleted row
                # may not even be a candidate), so only the delta is added
                counts_dev = _delta_count_program(self.mesh, self.metric)(
                    counts_dev, st.qdev, st.pos_dev, w.delta, w.dvalid,
                    st.eps_dev, None)
            _start_host_copy(counts_dev)
            # xlint: allow-host-sync(result: readback in PendingJoin.result)
            finalize = lambda: np.asarray(counts_dev)[:n]   # noqa: E731
        else:
            from repro.core.joins.common import (dispatch_verify_candidates,
                                                 searcher_candidates)
            searcher = self.verifier(verify) if isinstance(verify, str) \
                else verify
            # host probing needs the verdicts; the filter program is already
            # complete (n_pos was just read), so this transfer is cheap.
            # NOT an _allowed_transfer: host-probe routes are expected to
            # trip the transfer-guard lane (DESIGN.md §12)
            _note_host_sync("verdicts")
            # xlint: allow-host-sync(verdicts: host probe needs the verdicts)
            pos_host = np.asarray(st.pos_dev)[:n]
            idx = np.nonzero(pos_host)[0]
            qpos = st.Q[idx]
            # under mutations the delta adjustment runs through the SAME
            # device program as the device routes (not host numpy), so
            # host-vs-device probe count parity is preserved bit-for-bit
            adj_dev = None
            if w.mutated:
                adj_dev = _delta_count_program(self.mesh, self.metric)(
                    None, st.qdev, st.pos_dev, w.delta, w.dvalid,
                    st.eps_dev, None)
                _start_host_copy(adj_dev)
            if hasattr(searcher, "candidates"):
                _note_host_sync("probe")
                cand = searcher_candidates(searcher, qpos, st.eps)
                # on sharded placements each device verifies the candidate
                # ids that land in its own R shard (common.py psums them)
                shard = {} if self.r_shards == 1 else dict(
                    mesh=self.mesh, r_axis=self.topology.r_axis,
                    data_axis=self.data_axis,
                    shard_rows=self.nr_padded // self.r_shards)
                pend = dispatch_verify_candidates(
                    w.Rdev, qpos, cand, st.eps, self.metric,
                    backend=self.backend, tomb=w.tomb, **shard)

                def finalize():
                    counts = np.zeros((n,), np.int32)
                    counts[idx] = pend.result()
                    if adj_dev is not None:
                        # xlint: allow-host-sync(result: readback in PendingJoin.result)
                        counts = counts + np.asarray(adj_dev)[:n]
                    return counts
            else:
                # candidate-less plug-in: the searcher verifies the
                # compacted positives itself (synchronous host hop — the
                # generic "any loop-based method" fallback). It sweeps its
                # own copy of R, which cannot honor tombstones — refuse
                # rather than return silently wrong counts
                if w.n_tomb > 0:
                    raise RuntimeError(
                        f"verify={label!r}: query_counts-only plug-in "
                        "searchers cannot honor tombstoned deletes — "
                        "compact() first, or use a candidates() searcher "
                        "(DESIGN.md §13)")
                _note_host_sync("probe")
                found = np.asarray(searcher.query_counts(qpos, st.eps),
                                   np.int32)

                def finalize():
                    counts = np.zeros((n,), np.int32)
                    counts[idx] = found
                    if adj_dev is not None:
                        # xlint: allow-host-sync(result: readback in PendingJoin.result)
                        counts = counts + np.asarray(adj_dev)[:n]
                    return counts
        t_dispatch = time.perf_counter() - t1
        return PendingJoin(finalize, verify=label, n_searched=n_pos,
                           t_filter=t_filter, t_dispatch=t_dispatch,
                           probe=probe_label)

    # ------------------------------------------------ verification backends
    def verifier(self, name: str, **params):
        """The approximate searcher backing `verify=name` (DESIGN.md §5).

        Built lazily over the engine's host-side R and cached per name, so
        a serving session pays index construction once. Calling with
        `params` always (re)builds the index with those params and replaces
        the cached instance (e.g. `engine.verifier("lsh", l=16,
        n_probes=8)` before streaming is the tuning hook — a silent
        cache hit here would drop the override); calling without params
        returns the cached index, building with defaults on first use.
        The searcher must expose `candidates(Q) -> int32 [q, C]` (-1 pad).
        """
        if name not in VERIFY_BACKENDS or name == "exact":
            raise ValueError(
                f"verifier={name!r}: expected an approximate backend "
                f"({sorted(set(VERIFY_BACKENDS) - {'exact'})}; "
                "'exact' is the fused sweep — it has no index to build)")
        v = None if params else self._verifiers.get(name)
        if v is None:
            from repro.core.joins import make_join   # circular at import time
            stale = self._verifiers.get(name)
            if stale is not None:
                # a retune replaces the index: drop the old searcher's
                # placed probe too, or its device-resident tables would
                # stay pinned in self._probes for the engine's lifetime
                self._probes.pop(getattr(stale, "_probe_spec", None), None)
            v = make_join(name, self._R_host, self.metric, **params)
            if not hasattr(v, "candidates"):
                raise TypeError(f"join {name!r} exposes no candidates()")
            self._verifiers[name] = v
            # compact() rebuilds the index over the merged R with the
            # exact params of its last build (DESIGN.md §13)
            self._verifier_params[name] = dict(params)
        return v

    # --------------------------------------------------- one-shot join call
    def filtered_join(self, Q, eps: float, *, predict=None, threshold=None,
                      verdicts=None, block: int | None = None,
                      verify: VerifySpec = "exact",
                      probe: str = "auto") -> EngineJoinResult:
        """One synchronous filter -> threshold -> probe -> verify pass.

        Either pass `predict` = (params, fn) from an estimator's
        `device_predict_fn()` plus the XDT `threshold` (fully fused path),
        or a precomputed host bool `verdicts` array (plug-in filters).
        `block` overrides the compaction bucket quantum (default
        self.block); `verify` picks the verification backend ("exact" |
        "lsh" | "ivfpq", DESIGN.md §5 — or any Searcher object whose
        `candidates()` feeds the device verification path, DESIGN.md §9);
        `probe` ("auto" | "device" | "host", DESIGN.md §11) selects where
        the approximate route's index probe runs. This is the synchronous
        reference path — `stream` pipelines the same three stages."""
        placed = self.device_probe_for(verify, probe, eps=eps)
        st = self._stage_filter(Q, eps, predict=predict, threshold=threshold,
                                verdicts=verdicts)
        self._stage_probe(st, placed=placed, block=block)
        return self._commit_verify(st, verify=verify, block=block).result()

    # ------------------------------------------------------------ streaming
    def stream_session(self, eps: float, *, predict=None, threshold=None,
                       verify: VerifySpec = "exact", depth: int = 2,
                       block: int | None = None,
                       probe: str = "auto") -> "StreamSession":
        """Open an asynchronous `StreamSession` (push interface) over this
        engine; `stream` is the pull/iterator form of the same pipeline."""
        return StreamSession(self, eps, predict=predict, threshold=threshold,
                             verify=verify, depth=depth, block=block,
                             probe=probe)

    def stream(self, batches: Iterable, eps: float, *, predict=None,
               threshold=None, verify: VerifySpec = "exact", depth: int = 2,
               block: int | None = None,
               probe: str = "auto") -> Iterator[EngineJoinResult]:
        """Serving loop: pipeline query batches through the engine.

        Asynchronous double-buffered (DESIGN.md §5): each incoming batch is
        staged (filter dispatched) before the previous batch's verification
        is committed, and results are materialized only when more than
        `depth` batches are in flight — dispatch of batch k+1 overlaps the
        readback of batch k. Results are yielded in submission order and
        are bit-identical to per-batch `filtered_join` calls. R, the
        estimator, and all compiled programs stay device-resident across
        the whole stream (bucketed shapes). `depth=0` degenerates to
        commit-then-materialize per batch (still one staged batch of
        lookahead)."""
        sess = self.stream_session(eps, predict=predict, threshold=threshold,
                                   verify=verify, depth=depth, block=block,
                                   probe=probe)
        for Q in batches:
            yield from sess.submit(Q)
        yield from sess.flush()


def sharded_range_count_hist(Q, R, eps_grid, *, metric: str = "cosine",
                             mesh=None, backend: str = "auto",
                             block_q: int = 256, block_r: int = 512,
                             data_axis: str = "data",
                             topology: "str | Topology" = "replicated",
                             engine: "JoinEngine | None" = None) -> np.ndarray:
    """One-shot functional form of `JoinEngine.range_count_hist` (used by
    `data.groundtruth.cardinality_table`).

    Pass a pre-built `engine=` over the same (R, metric) to reuse its
    device-resident padded R — without it every call re-pads and
    re-uploads R (and that is exactly what repeated ground-truth sweeps
    used to do). The engine is validated against (R, metric): a mismatch
    raises instead of silently sweeping the wrong index set."""
    if engine is not None:
        if (engine.metric != metric or engine.nr != len(R)
                or not (engine._R_host is R
                        or np.array_equal(engine._R_host,
                                          np.asarray(R, np.float32)))):
            raise ValueError(
                "sharded_range_count_hist(engine=...): engine is built over "
                f"a different (R, metric) — engine has |R|={engine.nr}/"
                f"{engine.metric!r}, call has |R|={len(R)}/{metric!r}")
        if mesh is not None and engine.mesh is not mesh:
            raise ValueError(
                "sharded_range_count_hist(engine=..., mesh=...): the engine "
                "carries its own placement; drop mesh= (the engine's mesh "
                "wins) or drop engine= (a fresh engine is built on that "
                "mesh) — silently ignoring the mesh request would change "
                "where the sweep runs")
        return engine.range_count_hist(Q, eps_grid)
    eng = JoinEngine(R, metric, mesh=mesh, backend=backend, block_q=block_q,
                     block_r=block_r, data_axis=data_axis, topology=topology)
    return eng.range_count_hist(Q, eps_grid)
