"""Sharded, device-resident batch-join execution engine (DESIGN.md §4).

The paper reduces similarity join to filter-then-verify, and both halves
bottom out in dense range counting — work that should saturate accelerators.
This module is the execution layer that makes that true:

  * `JoinEngine` pins the index set R on device once (replicated over the
    mesh) and runs every sweep against it with bucketed static shapes.
  * The range-count sweep shards the QUERY axis over the mesh's data axis
    with `shard_map` (each device sweeps its query slice against the full
    replicated R), so ground-truth `cardinality_table` construction and
    naive-join verification scale across devices.
  * `filtered_join` is the fused XJoin hot path: estimator inference + XDT
    thresholding run as one device program; the single host sync reads the
    positive count to pick a power-of-two capacity bucket; compaction +
    exact verification then run as a second device program (gather the
    positives, count, scatter back) — skipped queries cost nothing.
  * `stream` wraps that path for serving: feed query batches, get per-batch
    results; compiled programs are reused across batches because every
    shape is bucketed.

Backend matrix (DESIGN.md §2): per-shard compute is the Pallas kernel on
TPU ("pallas"), the blocked-jnp path elsewhere ("jnp"/"auto"), or the
unblocked oracle ("ref" — no padding, used as the bit-for-bit reference).
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass
from typing import Callable, Iterable, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

try:                                    # moved to the stable namespace in
    from jax import shard_map           # newer JAX; experimental on 0.4.x
except ImportError:
    from jax.experimental.shard_map import shard_map


def _shard_mapped(f, mesh, in_specs, out_specs):
    try:
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)
    except TypeError:                   # newer API dropped check_rep
        return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)

from repro.kernels import ops, ref
from repro.kernels.range_count import range_count_hist_pallas


def _bucket_size(n: int, block: int) -> int:
    """Round n up to a bucketed multiple of block (recompile bounding).

    Power-of-two growth, refined with quarter steps once those are still
    block multiples — shape count stays logarithmic but padding overshoot
    is capped at 25% (a pure power-of-two bucket wastes up to ~50% of the
    work on padding rows at large n)."""
    if n <= block:
        return block
    b = block
    while b < n:
        b *= 2
    if b >= 8 * block:
        for eighths in (5, 6, 7):
            c = (b // 8) * eighths
            if c >= n:
                return c
    return b


def _pad_rows_np(x: np.ndarray, n: int) -> np.ndarray:
    if x.shape[0] >= n:
        return x
    pad = np.zeros((n - x.shape[0],) + x.shape[1:], x.dtype)
    return np.concatenate([x, pad])


def _q_blocked_hist(q, r, eps, *, metric, block_q, block_r, nr_valid):
    """[n, m] histogram, scanning q in block_q tiles so the fused
    compare tensor stays O(block_q * block_r * m). q rows % block_q == 0."""
    nblk = q.shape[0] // block_q
    qb = q.reshape(nblk, block_q, q.shape[1])
    out = jax.lax.map(
        lambda x: ops.blocked_hist(x, r, eps, metric=metric,
                                   block_r=block_r, nr_valid=nr_valid), qb)
    return out.reshape(nblk * block_q, eps.shape[0])


def _data_size(mesh, data_axis: str) -> int:
    return int(mesh.shape.get(data_axis, 1)) if mesh is not None else 1


@functools.lru_cache(maxsize=128)
def _hist_program(mesh, data_axis, backend, metric, block_q, block_r,
                  eps_chunk, nr_valid):
    """Compiled (optionally shard_map'ped) sweep. Module-level cache so
    engines over the same (mesh, |R|) share one XLA executable."""
    if backend == "pallas":
        interpret = jax.default_backend() != "tpu"

        def shard_fn(q, r, eps):
            return range_count_hist_pallas(
                q, r, eps, metric=metric, nr_valid=nr_valid, block_q=block_q,
                block_r=block_r, eps_chunk=eps_chunk, interpret=interpret)
    elif backend == "ref":
        def shard_fn(q, r, eps):
            return ref.range_count_hist(q, r, eps, metric)
    else:
        def shard_fn(q, r, eps):
            return _q_blocked_hist(q, r, eps, metric=metric, block_q=block_q,
                                   block_r=block_r, nr_valid=nr_valid)

    if _data_size(mesh, data_axis) > 1:
        shard_fn = _shard_mapped(shard_fn, mesh,
                                 in_specs=(P(data_axis), P(), P()),
                                 out_specs=P(data_axis))
    return jax.jit(shard_fn)


@functools.lru_cache(maxsize=128)
def _compact_program(mesh, data_axis, backend, metric, block_q, block_r,
                     nr_valid):
    """Fused compact -> verify -> scatter. `capacity` is the bucketed static
    shape; `n_pos` rides along as a device scalar so the same executable
    serves every occupancy of a bucket."""

    def prog(q, pos, n_pos, r, eps, *, capacity: int):
        idx = jnp.nonzero(pos, size=capacity, fill_value=0)[0]
        valid = jnp.arange(capacity) < n_pos
        qpos = jnp.take(q, idx, axis=0)
        if _data_size(mesh, data_axis) > 1:
            qpos = jax.lax.with_sharding_constraint(
                qpos, NamedSharding(mesh, P(data_axis)))
        eps1 = jnp.reshape(eps, (1,)).astype(jnp.float32)
        if backend == "ref":
            found = ref.range_count_hist(qpos, r, eps1, metric)[:, 0]
        elif capacity > block_q and capacity % block_q == 0:
            # large buckets get the same query tiling as the main sweep so
            # the compare temporaries stay O(block_q * block_r)
            found = _q_blocked_hist(qpos, r, eps1, metric=metric,
                                    block_q=block_q, block_r=block_r,
                                    nr_valid=nr_valid)[:, 0]
        else:
            found = ops.blocked_hist(qpos, r, eps1, metric=metric,
                                     block_r=block_r, nr_valid=nr_valid)[:, 0]
        # invalid (padding) lanes all scatter-add 0 onto row 0
        contrib = jnp.where(valid, found, 0).astype(jnp.int32)
        return jnp.zeros((q.shape[0],), jnp.int32).at[idx].add(contrib)

    return jax.jit(prog, static_argnames=("capacity",))


@dataclass
class EngineJoinResult:
    counts: np.ndarray      # int32 [n] exact neighbor counts (0 for skipped)
    n_searched: int         # queries that reached verification
    t_filter: float
    t_search: float


class JoinEngine:
    """Device-resident exact join over a fixed index set R.

    mesh: optional `jax.sharding.Mesh` with a `data_axis` axis (use
    `launch.mesh.make_data_mesh()`); queries shard over it, R replicates.
    Without a mesh everything runs single-device through the same programs.
    """

    def __init__(self, R, metric: str = "cosine", *, mesh=None,
                 backend: str = "auto", block_q: int = 256, block_r: int = 512,
                 block: int = 512, eps_chunk: int = 8, data_axis: str = "data"):
        self.metric = metric
        self.backend = ops._resolve(backend)
        self.mesh, self.data_axis = mesh, data_axis
        self.block_q, self.block_r, self.block = block_q, block_r, block
        self.eps_chunk = eps_chunk
        R = np.asarray(R, np.float32)
        self.nr, self.dim = R.shape
        self.ndata = _data_size(mesh, data_axis)
        # "ref" sweeps the raw R (the oracle handles any shape); the blocked
        # backends see an R padded to a block_r multiple and mask via nr_valid
        Rp = R if self.backend == "ref" else _pad_rows_np(
            R, ((self.nr + block_r - 1) // block_r) * block_r)
        if mesh is not None:
            self._q_sharding = NamedSharding(mesh, P(data_axis))
            self._Rdev = jax.device_put(Rp, NamedSharding(mesh, P()))
        else:
            self._q_sharding = None
            self._Rdev = jnp.asarray(Rp)
        self._filter_progs: dict = {}

    # ------------------------------------------------------------- plumbing
    def _pad_q(self, Q) -> np.ndarray:
        """Bucket the query count to a power-of-two multiple of one full
        mesh sweep (block_q rows per device) — bounds recompiles AND keeps
        per-shard shapes block-aligned."""
        Q = np.asarray(Q, np.float32)
        return _pad_rows_np(Q, _bucket_size(len(Q), self.block_q * self.ndata))

    def _put_q(self, qp: np.ndarray) -> jax.Array:
        if self._q_sharding is not None:
            return jax.device_put(qp, self._q_sharding)
        return jnp.asarray(qp)

    def _pad_eps(self, eps_grid) -> np.ndarray:
        e = np.asarray(eps_grid, np.float32).reshape(-1)
        if self.backend == "pallas":
            pad = (-len(e)) % self.eps_chunk
            if pad:
                e = np.concatenate([e, np.full((pad,), np.inf, np.float32)])
        return e

    # ------------------------------------------------------- range counting
    def device_range_count_hist(self, Q, eps_grid) -> jax.Array:
        """Sharded sweep; returns the DEVICE array [n_padded, m_padded]
        (query axis distributed over the data axis). Callers that want the
        exact [n, m] table use `range_count_hist`."""
        qp = self._pad_q(Q)
        ep = self._pad_eps(eps_grid)
        prog = _hist_program(self.mesh, self.data_axis, self.backend,
                             self.metric, self.block_q, self.block_r,
                             self.eps_chunk, self.nr)
        return prog(self._put_q(qp), self._Rdev, jnp.asarray(ep))

    def range_count_hist(self, Q, eps_grid) -> np.ndarray:
        """counts[i, j] = #-neighbors of Q[i] in R within eps_grid[j]."""
        m = np.asarray(eps_grid).reshape(-1).shape[0]
        out = self.device_range_count_hist(Q, eps_grid)
        return np.asarray(out)[: len(Q), :m]

    def range_count(self, Q, eps: float) -> np.ndarray:
        return self.range_count_hist(Q, [float(eps)])[:, 0]

    def cardinality_table(self, points, eps_grid, *,
                          exclude_self: bool = False) -> np.ndarray:
        t = self.range_count_hist(points, eps_grid)
        if exclude_self:
            t = np.maximum(t - 1, 0)
        return t

    # ------------------------------------------------- fused filtered join
    def _filter_program(self, predict):
        # keyed by the fn object itself (estimators memoize it): survives
        # refits without id-reuse aliasing, and the key pins the fn alive
        _, fn = predict
        prog = self._filter_progs.get(fn)
        if prog is None:
            def program(params, q, eps, thr, n_valid):
                X = jnp.concatenate(
                    [q, jnp.full((q.shape[0], 1), eps, jnp.float32)], axis=1)
                preds = fn(params, X)
                pos = (preds > thr) & (jnp.arange(q.shape[0]) < n_valid)
                return preds, pos, jnp.sum(pos, dtype=jnp.int32)
            prog = jax.jit(program)
            self._filter_progs[fn] = prog
        return prog

    def filtered_join(self, Q, eps: float, *, predict=None, threshold=None,
                      verdicts=None, block: int | None = None
                      ) -> EngineJoinResult:
        """One fused filter -> threshold -> compact -> verify pass.

        Either pass `predict` = (params, fn) from an estimator's
        `device_predict_fn()` plus the XDT `threshold` (fully fused path),
        or a precomputed host bool `verdicts` array (plug-in filters).
        `block` overrides the compaction bucket quantum (default self.block).
        """
        Q = np.asarray(Q, np.float32)
        n = len(Q)
        qp = self._pad_q(Q)
        qdev = self._put_q(qp)
        eps_dev = jnp.asarray(eps, jnp.float32)

        t0 = time.perf_counter()
        if verdicts is not None:
            pos_host = np.zeros((len(qp),), bool)
            pos_host[:n] = np.asarray(verdicts, bool)
            n_pos = int(pos_host.sum())
            pos_dev = (jax.device_put(pos_host, self._q_sharding)
                       if self._q_sharding is not None else jnp.asarray(pos_host))
            n_pos_dev = jnp.asarray(n_pos, jnp.int32)
        else:
            params, _ = predict
            prog = self._filter_program(predict)
            _, pos_dev, n_pos_dev = prog(
                params, qdev, eps_dev, jnp.asarray(threshold, jnp.float32),
                jnp.asarray(n, jnp.int32))
            n_pos = int(n_pos_dev)          # the single host sync
        t_filter = time.perf_counter() - t0

        if n_pos == 0:
            return EngineJoinResult(np.zeros((n,), np.int32), 0, t_filter, 0.0)

        t1 = time.perf_counter()
        capacity = min(_bucket_size(n_pos, block or self.block), len(qp))
        cprog = _compact_program(self.mesh, self.data_axis, self.backend,
                                 self.metric, self.block_q, self.block_r,
                                 self.nr)
        counts = cprog(qdev, pos_dev, n_pos_dev, self._Rdev, eps_dev,
                       capacity=capacity)
        counts = np.asarray(counts)[:n]
        t_search = time.perf_counter() - t1
        return EngineJoinResult(counts, n_pos, t_filter, t_search)

    # ------------------------------------------------------------ streaming
    def stream(self, batches: Iterable, eps: float, *, predict=None,
               threshold=None) -> Iterator[EngineJoinResult]:
        """Serving loop: iterate query batches through `filtered_join`.
        Bucketed shapes mean steady-state batches hit compiled programs;
        R and the estimator stay device-resident across the whole stream."""
        for Q in batches:
            yield self.filtered_join(Q, eps, predict=predict,
                                     threshold=threshold)


def sharded_range_count_hist(Q, R, eps_grid, *, metric: str = "cosine",
                             mesh=None, backend: str = "auto",
                             block_q: int = 256, block_r: int = 512,
                             data_axis: str = "data") -> np.ndarray:
    """One-shot functional form of `JoinEngine.range_count_hist` (used by
    `data.groundtruth.cardinality_table`); prefer a `JoinEngine` when R is
    swept more than once."""
    eng = JoinEngine(R, metric, mesh=mesh, backend=backend, block_q=block_q,
                     block_r=block_r, data_axis=data_axis)
    return eng.range_count_hist(Q, eps_grid)
