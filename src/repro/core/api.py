"""Protocol-first public join API: `JoinPlan` + the Filter/Searcher
contracts (DESIGN.md §9).

The paper's headline claim is that Xling "acts as a flexible plugin that
can be inserted to any loop-based similarity join method" (§IV-C). This
module is the contract that makes the claim structural rather than
special-cased:

  * `Filter` — anything that can veto queries: `verdicts(Q, eps)` is the
    host form; an optional `device_filter(eps) -> (predict, threshold)`
    is the fused form the engine compiles into its filter program.
    Adapters (`as_filter`) lift `XlingFilter`, the `LSBF` baseline, and
    bare callables onto the protocol, replacing the old isinstance
    dispatch in `xjoin.py`.
  * `Searcher` — anything that can find neighbors: `query_counts(Q, eps)`
    is the whole-join form; `candidates(Q[, eps])` is the probing half of
    the host-probe / device-verify split (`joins/common.py`). Every
    registered join method implements the protocol, so ANY base — not
    just the naive sweep — routes its predicted-positive queries through
    `JoinEngine`'s device-resident candidate verification and the
    asynchronous streaming pipeline.
  * `JoinPlan` — the single declarative entry point tying both together:

        plan = (JoinPlan(R, "cosine")
                .filter("xling", tau=50, xdt="fpr")
                .search("lsh", k=14, l=10)
                .on(mesh=mesh, backend="auto"))
        res = plan.run(Q, eps=0.45)
        for r in plan.stream(batches, eps=0.45, depth=2): ...

    The whole configuration is validated once at `build()` (invalid
    filter/search/verify combinations fail there with an actionable
    message, not data-dependently mid-stream), the engine and device
    programs are constructed once and cached across calls, and
    `describe()` returns a serializable summary of the plan (used by the
    serve CLI and the benchmarks).

`FilteredJoin` / `build_xjoin` / `enhance_with_xling` (core/xjoin.py)
remain as thin legacy shims over `JoinPlan`.
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from typing import (Any, Callable, Iterable, Iterator, Optional, Protocol,
                    runtime_checkable)

import numpy as np

from repro.core.engine import VERIFY_BACKENDS, JoinEngine
from repro.core.topology import resolve_topology
from repro.core.joins import JOINS, make_join
from repro.core.joins.lsbf import LSBF
from repro.core.joins.naive import NaiveJoin
from repro.core.xling import XlingConfig, XlingFilter


# =========================================================== the protocols
@runtime_checkable
class Filter(Protocol):
    """A query veto: predicts which queries are worth searching.

    Required: `verdicts(Q, eps) -> bool [q]` (host form). Optional:
    `device_filter(eps) -> (predict, threshold) | None` — the fused form;
    `predict` is an estimator's `(params, fn)` pair and `threshold` the
    calibrated XDT cut, compiled by the engine into one device program."""

    def verdicts(self, Q: np.ndarray, eps: float) -> np.ndarray:
        """bool [q]: True = search this query, False = skip it."""
        ...


@runtime_checkable
class Searcher(Protocol):
    """A join method over a fixed index set R.

    Required: `query_counts(Q, eps) -> int32 [q]` plus `name` / `exact`
    attributes. Optional (the probe/verify split): `candidates(Q[, eps])
    -> int32 [q, C]` (-1 padded) — when present, the engine verifies the
    candidates on device against its resident R; `eps` is passed only to
    eps-aware probes (see `joins.common.searcher_candidates`)."""

    def query_counts(self, Q: np.ndarray, eps: float) -> np.ndarray:
        """int32 [q] found-neighbor counts per query."""
        ...


@runtime_checkable
class DeviceSearcher(Searcher, Protocol):
    """A Searcher whose index probe can run ON the mesh (DESIGN.md §11).

    `device_probe(eps)` is the Searcher analogue of
    `Filter.device_filter`: it returns a probe spec (`core/probe.py` —
    an object exposing `place(engine) -> PlacedProbe`) or None when the
    index cannot probe on device. The engine places each distinct spec
    once (tables uploaded and pinned like R, per the topology) and then
    runs probe -> candidate verification entirely on device, leaving the
    positive-count read as the only per-batch host sync. Contract:
    `eps` may be None (plan-build/validation calls) — return the
    radius-free spec or None; radius-DEPENDENT probes must return one
    (preferably memoized) spec per distinct eps, since placement is
    cached by spec identity. Searchers whose classes cannot grow the
    method register a builder in `probe.PROBE_BUILDERS` instead;
    searchers doing neither simply keep the host probe path."""

    def device_probe(self, eps: float):
        """Probe spec for the engine to place on its mesh, or None."""
        ...


# ======================================================== filter adapters
class XlingAdapter:
    """`XlingFilter` on the Filter protocol: verdicts via the estimator +
    XDT threshold; the fused device form when the estimator exposes
    `device_predict_fn` (all registry estimators do)."""

    def __init__(self, filt: XlingFilter, *, tau: int = 0,
                 xdt_mode: Optional[str] = None,
                 fpr_tolerance: Optional[float] = None):
        self.filt = filt
        self.tau = int(tau)
        self.xdt_mode = xdt_mode
        self.fpr_tolerance = fpr_tolerance

    def verdicts(self, Q: np.ndarray, eps: float) -> np.ndarray:
        """Host-side verdicts: predicted count vs the XDT threshold."""
        pos, _ = self.filt.query(Q, eps, self.tau, mode=self.xdt_mode,
                                 fpr_tolerance=self.fpr_tolerance)
        return pos

    def device_filter(self, eps: float):
        """(predict, threshold) for the engine's fused filter program; the
        XDT threshold is calibrated through the same device fn that will
        produce the online predictions (float parity at the boundary)."""
        est = self.filt.estimator
        if not hasattr(est, "device_predict_fn"):
            return None
        predict = est.device_predict_fn()
        threshold = self.filt.xdt(eps, self.tau, mode=self.xdt_mode,
                                  fpr_tolerance=self.fpr_tolerance,
                                  predict=predict)
        return predict, threshold


class LSBFAdapter:
    """`LSBF` (the MSBF baseline) on the Filter protocol. Its verdict is
    radius-blind (bit-array membership), so `eps` is ignored; there is no
    device form — verdicts are computed on host per batch."""

    def __init__(self, filt: LSBF):
        self.filt = filt
        self.tau = 0                        # LSBF answers "any neighbor"

    def verdicts(self, Q: np.ndarray, eps: float) -> np.ndarray:
        """Host-side verdicts from the locality-sensitive bit array."""
        return self.filt.query(Q)


class CallableAdapter:
    """A bare `fn(Q, eps) -> bool [q]` on the Filter protocol (host-only;
    the escape hatch for experiment-specific filters)."""

    def __init__(self, fn: Callable[[np.ndarray, float], np.ndarray]):
        self.fn = fn
        self.tau = 0

    def verdicts(self, Q: np.ndarray, eps: float) -> np.ndarray:
        """Host-side verdicts from the wrapped callable."""
        return np.asarray(self.fn(Q, eps), bool)


#: Adapter registry: concrete filter type -> adapter factory. `as_filter`
#: walks an object's MRO through this table, so new filter types plug in
#: by registration instead of editing an isinstance chain.
FILTER_ADAPTERS: dict[type, Callable[..., Any]] = {
    XlingFilter: XlingAdapter,
    LSBF: lambda f, **_: LSBFAdapter(f),
}


def as_filter(obj, *, tau: int = 0, xdt_mode: Optional[str] = None,
              fpr_tolerance: Optional[float] = None):
    """Coerce `obj` onto the Filter protocol (None passes through).

    Resolution order: objects already exposing `verdicts` are returned
    as-is; registered concrete types (`FILTER_ADAPTERS`) are wrapped with
    their adapter (Xling adapters receive the tau/XDT knobs); any other
    callable is wrapped as `fn(Q, eps) -> bool [q]`. Raises TypeError for
    everything else, and ValueError when tau/XDT knobs are given for a
    filter that cannot honor them (LSBF, callables, prebuilt protocol
    objects) — silently dropping a declared tau would change semantics."""
    def _reject_knobs(kind: str):
        if tau or xdt_mode is not None or fpr_tolerance is not None:
            raise ValueError(
                f"filter options tau/xdt/fpr_tolerance do not apply to "
                f"{kind}: they parameterize the Xling XDT decision; "
                "configure the object itself instead")

    if obj is None:
        return None
    if isinstance(obj, Filter):             # protocol: has verdicts()
        # a prebuilt adapter carries its own knobs — new ones cannot be
        # grafted on (an XlingAdapter's threshold caches would go stale),
        # so they are rejected rather than silently dropped
        _reject_knobs(f"a prebuilt Filter object ({type(obj).__name__}); "
                      "pass the raw XlingFilter to apply them")
        return obj
    for cls in type(obj).__mro__:
        adapt = FILTER_ADAPTERS.get(cls)
        if adapt is not None:
            if adapt is not XlingAdapter:
                _reject_knobs(type(obj).__name__)
            return adapt(obj, tau=tau, xdt_mode=xdt_mode,
                         fpr_tolerance=fpr_tolerance)
    if callable(obj):
        _reject_knobs("a callable filter")
        return CallableAdapter(obj)
    raise TypeError(
        f"unsupported filter {type(obj).__name__}: expected an object with "
        "verdicts(Q, eps), a registered filter type "
        f"({[c.__name__ for c in FILTER_ADAPTERS]}), or a callable "
        "fn(Q, eps) -> bool [q]")


def _filter_label(f) -> Optional[str]:
    """Human-readable filter name for describe()/meta (the wrapped concrete
    type where the adapter kept it, the adapter type otherwise)."""
    if f is None:
        return None
    for attr in ("filt", "fn"):
        inner = getattr(f, attr, None)
        if inner is not None:
            return type(inner).__name__
    return type(f).__name__


# ============================================================== the plan
@dataclass
class JoinResult:
    """Per-call join outcome: exact-at-candidates neighbor counts plus the
    filter/search timing split and provenance metadata."""
    counts: np.ndarray
    n_queries: int
    n_searched: int
    t_filter: float
    t_search: float
    meta: dict = field(default_factory=dict)

    @property
    def t_total(self) -> float:
        """Filter + search wall-clock for this call."""
        return self.t_filter + self.t_search

    def recall_vs(self, true_counts: np.ndarray) -> float:
        """Pair-level recall: found pairs over true pairs (count-based —
        exact for exact searchers; an upper-bound-free measure for
        approximate searchers since found <= true per query)."""
        denom = float(np.sum(true_counts))
        if denom == 0:
            return 1.0
        return float(np.sum(np.minimum(self.counts, true_counts)) / denom)


@dataclass
class _BuiltPlan:
    """Resolved plan state: constructed engine/base/filter/verify route."""
    engine: JoinEngine
    base: Any
    filter: Optional[Any]
    verify_route: Any                       # "exact" | Searcher object
    verify_label: str
    placed_probe: Any = None                # PlacedProbe | None (§11)


def _spec_name(spec) -> str:
    """Display name of a filter/search/verify spec (string or instance)."""
    return spec if isinstance(spec, str) else type(spec).__name__


class JoinPlan:
    """Declarative, validated join configuration — the single entry point.

    Compose with the fluent builders (`filter` / `search` / `verify` /
    `on`), then `run`, `stream`, or inspect with `describe`. `build()` is
    called implicitly on first use; it validates the WHOLE configuration
    up front (unknown names, impossible filter/search/verify combinations,
    mismatched engines all fail there with actionable messages), fits the
    filter if it was given by name, pins R on device via a `JoinEngine`,
    and caches every compiled program across calls.

    Execution always flows through the engine (DESIGN.md §4–§5): the
    filter runs fused on device when it has a device form (host verdicts
    are uploaded otherwise), positives are compacted into bucketed static
    shapes, and verification is the engine's exact sweep (naive base),
    the verify searcher's `candidates()` checked on device against the
    engine's resident R, or — for candidate-less plug-ins — the
    searcher's own `query_counts()` over the compacted positives. That is
    how EVERY join method, not just the naive sweep, gets the
    fused-skipping and async-streaming machinery."""

    _ON_KEYS = ("mesh", "backend", "block", "engine", "cache_key",
                "topology", "r_shards", "probe", "plan")

    def __init__(self, R: np.ndarray, metric: str = "cosine"):
        self._R = np.asarray(R, np.float32)
        self.metric = str(metric)
        self._filter_spec: tuple[Any, dict] = (None, {})
        self._search_spec: tuple[Any, dict] = ("naive", {})
        self._verify_spec: tuple[Any, dict] = ("auto", {})
        self._exec: dict = {"mesh": None, "backend": "auto", "block": 512,
                            "engine": None, "cache_key": None,
                            "topology": None, "r_shards": None,
                            "probe": "auto", "plan": None}
        self._built: Optional[_BuiltPlan] = None
        self._device_filter_cache: dict = {}
        self._mutable = False
        self._auto_compact_at: Optional[float] = None
        self._seen_compactions = 0
        #: set by the auto-planner (core/planner.py, DESIGN.md §16): the
        #: machine-readable plan rationale on planner-produced plans, the
        #: chosen stream depth, and — on `on(plan="auto")` lazy plans —
        #: the planned delegate built at first run/session
        self._planner_explain: Optional[dict] = None
        self._planned_depth: Optional[int] = None
        self._auto_delegate: Optional["JoinPlan"] = None

    # ------------------------------------------------------------ builders
    def filter(self, filt="xling", **opts) -> "JoinPlan":
        """Select the filter: "xling" (fitted on R at build time; `tau`,
        `xdt`/`xdt_mode`, `fpr_tolerance` plus any `XlingConfig` field as
        keywords), "lsbf" (the MSBF baseline; LSBF constructor params),
        "none", a Filter-protocol object, a concrete `XlingFilter`/`LSBF`
        instance, or a callable `fn(Q, eps) -> bool [q]`."""
        self._filter_spec = (filt, dict(opts))
        self._built = None
        self._auto_delegate = None
        return self

    def search(self, method="naive", **params) -> "JoinPlan":
        """Select the base join method: a registry name (`JOINS` — naive,
        grid, lsh, kmeanstree, ivfpq) with constructor params, or a
        Searcher instance already built over this plan's R."""
        self._search_spec = (method, dict(params))
        self._built = None
        self._auto_delegate = None
        return self

    def verify(self, backend="auto", **params) -> "JoinPlan":
        """Select how predicted-positive queries are verified: "auto"
        (exact sweep for the naive base; otherwise the base verifies its
        own positives — device candidate verification when it exposes
        `candidates()`, its own `query_counts()` when not — the default),
        "exact" (engine brute-force sweep; naive base only), a join name
        (lsh/ivfpq with engine-cached indices — explicit params pin the
        built instance to this plan — or grid/kmeanstree), or a Searcher
        instance (candidates() or query_counts()).

        Naming a backend REPLACES the verification route entirely: with a
        non-naive base the base's own probe is then bypassed (only the
        filter gates which queries reach the named backend) —
        `describe()["search"]["active"]` reports whether the base
        actually participates."""
        self._verify_spec = (backend, dict(params))
        self._built = None
        self._auto_delegate = None
        return self

    def on(self, **opts) -> "JoinPlan":
        """Set execution placement: `mesh` (query-axis sharding via
        `launch.mesh.make_data_mesh` / `make_join_mesh`), `backend`
        (DESIGN.md §2 kernel matrix), `block` (compaction bucket
        quantum), `engine` (share a prebuilt `JoinEngine` over the same
        R), `cache_key` (ground-truth table disk cache for the xling
        fit), `topology` ("replicated" | "ring" | a `Topology` instance
        — where R lives on the mesh, DESIGN.md §10), `r_shards` (ring
        only: size of the R-sharding mesh axis; when no mesh is given the
        plan builds a `make_join_mesh(r=r_shards)` over the local
        devices), `probe` ("auto" | "device" | "host", DESIGN.md §11 —
        where the approximate verify route's index probe runs; "auto"
        picks the device whenever the searcher advertises
        `device_probe`, "device" requires it and fails at build when
        unavailable), `plan` (None | "auto" — "auto" defers to the
        cost-based planner, DESIGN.md §16: the first run/session
        measures the workload and delegates to the planner-chosen
        configuration; explicit knobs set here are respected as pinned
        constraints). `describe()["exec"]["topology"]` /
        `describe()["exec"]["probe"]` report the resolved placement
        including per-device R and probe-table bytes."""
        unknown = set(opts) - set(self._ON_KEYS)
        if unknown:
            raise ValueError(f"on(): unknown option(s) {sorted(unknown)}; "
                             f"expected {list(self._ON_KEYS)}")
        if opts.get("plan") not in (None, "auto"):
            raise ValueError(f"on(plan={opts['plan']!r}): expected None or "
                             "'auto' (the cost-based planner)")
        self._exec.update(opts)
        self._built = None
        self._auto_delegate = None
        return self

    def mutable(self, auto_compact_at: Optional[float] = 0.5) -> "JoinPlan":
        """Opt this plan into dynamic R (DESIGN.md §13): unlock
        `insert` / `delete` / `compact` on the plan and set the engine's
        auto-compaction policy — the delta is merged into the pinned R
        (and any verifier indices are rebuilt) once
        (|delta| + |tombstones|) / |R| reaches `auto_compact_at`; pass
        None to compact only on explicit `compact()` calls.

        Mutable plans require `search("naive")` and a by-name verify
        spec (`"auto"`, `"exact"`, `"lsh"`, `"ivfpq"`): instance
        searchers hold their own host-side copy of R that the engine
        cannot patch, so mutations would silently diverge — build()
        rejects the combination with an actionable error instead."""
        if auto_compact_at is not None and not auto_compact_at > 0.0:
            raise ValueError(
                f"mutable(auto_compact_at={auto_compact_at}): expected a "
                "positive delta fraction, or None to disable auto-compaction")
        self._mutable = True
        self._auto_compact_at = (None if auto_compact_at is None
                                 else float(auto_compact_at))
        self._built = None
        return self

    # ---------------------------------------------------------- validation
    def _same_R(self, other_R) -> bool:
        """Same-index-set check: identity fast path, else full equality —
        a host memcmp, cheap next to the device upload build() performs,
        and the only check that actually closes the wrong-R hazard (a
        corpus differing in interior rows would otherwise be verified
        against silently)."""
        other_R = np.asarray(other_R)
        if other_R is self._R:
            return True
        return (other_R.shape == self._R.shape
                and bool(np.array_equal(other_R, self._R)))

    def _build_base(self, engine: JoinEngine):
        spec, params = self._search_spec
        if isinstance(spec, str):
            if spec not in JOINS:
                raise ValueError(f"search({spec!r}): unknown join method; "
                                 f"registered: {sorted(JOINS)}")
            if spec == "naive":
                return make_join("naive", self._R, self.metric,
                                 backend=self._exec["backend"], engine=engine,
                                 **params)
            return make_join(spec, self._R, self.metric, **params)
        if not isinstance(spec, Searcher):
            raise ValueError(
                f"search({type(spec).__name__}): instance must satisfy the "
                "Searcher protocol (query_counts(Q, eps))")
        if getattr(spec, "metric", self.metric) != self.metric:
            raise ValueError(
                f"search({type(spec).__name__}): instance is built for "
                f"metric {getattr(spec, 'metric')!r}, the plan for "
                f"{self.metric!r} — its probe geometry would not match the "
                "verification distances")
        if not self._same_R(getattr(spec, "R", self._R)):
            raise ValueError(
                f"search({type(spec).__name__}): instance is indexed over a "
                "different R than this plan — rebuild it over the plan's R "
                "or pass that R to JoinPlan()")
        return spec

    def _build_filter(self, engine: JoinEngine):
        spec, opts = self._filter_spec
        if spec is None or spec == "none":
            return None
        opts = dict(opts)
        tau = int(opts.pop("tau", 0))
        xdt_mode = opts.pop("xdt", opts.pop("xdt_mode", None))
        fpr_tolerance = opts.pop("fpr_tolerance", None)
        if tau < 0:
            raise ValueError(f"filter(tau={tau}): tau must be >= 0")
        if xdt_mode not in (None, "fpr", "mean"):
            raise ValueError(f"filter(xdt={xdt_mode!r}): expected 'fpr' or "
                             "'mean'")
        if fpr_tolerance is not None and not 0.0 < fpr_tolerance < 1.0:
            raise ValueError(f"filter(fpr_tolerance={fpr_tolerance}): "
                             "expected a rate in (0, 1)")
        if isinstance(spec, str):
            if spec == "xling":
                cfg = XlingConfig(metric=self.metric,
                                  xdt_mode=xdt_mode or "fpr",
                                  fpr_tolerance=(0.05 if fpr_tolerance is None
                                                 else fpr_tolerance),
                                  backend=self._exec["backend"], **opts)
                # the plan's engine already holds R device-resident —
                # the ground-truth fit sweep reuses it instead of
                # re-uploading (groundtruth.cardinality_table engine=)
                filt = XlingFilter(cfg).fit(
                    self._R, cache_key=self._exec["cache_key"],
                    mesh=self._exec["mesh"], engine=engine)
                return XlingAdapter(filt, tau=tau, xdt_mode=xdt_mode,
                                    fpr_tolerance=fpr_tolerance)
            if spec == "lsbf":
                if tau or xdt_mode is not None or fpr_tolerance is not None:
                    raise ValueError(
                        "filter('lsbf', ...): tau/xdt/fpr_tolerance are "
                        "Xling XDT knobs — LSBF answers the fixed "
                        "'any neighbor' question (theta= is its knob)")
                return LSBFAdapter(LSBF(self._R, self.metric, **opts))
            raise ValueError(f"filter({spec!r}): unknown filter; expected "
                             "'xling', 'lsbf', 'none', a Filter object, or "
                             "a callable")
        if opts:
            raise ValueError(f"filter(<instance>, **{sorted(opts)}): extra "
                             "constructor params only apply to by-name "
                             "filters")
        if isinstance(spec, XlingFilter) and spec.estimator is None:
            spec.fit(self._R, cache_key=self._exec["cache_key"],
                     mesh=self._exec["mesh"], engine=engine)
        return as_filter(spec, tau=tau, xdt_mode=xdt_mode,
                         fpr_tolerance=fpr_tolerance)

    def _build_verify(self, engine: JoinEngine, base):
        spec, params = self._verify_spec
        base_is_naive = isinstance(base, NaiveJoin)
        if spec == "auto":
            if params:
                raise ValueError("verify('auto') takes no params — name the "
                                 "backend to tune it")
            if base_is_naive:
                return "exact", "exact"
            # the base verifies its own positives: through candidates() +
            # device verification when it has the probe split, through its
            # own query_counts() otherwise (the generic "any loop-based
            # method" fallback — a synchronous host hop, engine.py)
            return base, getattr(base, "name", type(base).__name__)
        if spec == "exact":
            if not base_is_naive:
                raise ValueError(
                    "verify('exact') is the engine's brute-force sweep and "
                    "only composes with search('naive'); with "
                    f"search({getattr(base, 'name', '?')!r}) use "
                    "verify('auto') (the base's own candidates) or name an "
                    "approximate backend")
            if params:
                raise ValueError("verify('exact') takes no params — it has "
                                 "no index to tune")
            return "exact", "exact"
        if isinstance(spec, str):
            if spec in VERIFY_BACKENDS:     # lsh / ivfpq: engine-cached
                # build the index now so its construction cost lands at
                # build time. With explicit params the plan PINS the built
                # instance (another plan sharing this engine can't clobber
                # it); without params the NAME stays the route, so a later
                # `engine.verifier(name, **params)` retune takes effect
                v = engine.verifier(spec, **params)
                # mutable plans keep the NAME as the route: compact()
                # rebuilds the engine-cached index over the merged R, and
                # the by-name lookup resolves to the rebuilt instance —
                # a pinned instance would keep probing the pre-merge
                # tables (engine.py rebuilds from _verifier_params)
                return (spec if self._mutable else
                        (v if params else spec)), spec
            if spec in JOINS and hasattr(JOINS[spec], "candidates"):
                return make_join(spec, self._R, self.metric, **params), spec
            raise ValueError(
                f"verify({spec!r}): unknown backend; expected 'auto', "
                f"'exact', one of {sorted(set(VERIFY_BACKENDS) - {'exact'})}"
                ", a candidate-producing join name, or a Searcher instance")
        if not (hasattr(spec, "candidates") or hasattr(spec, "query_counts")):
            raise ValueError(
                f"verify({type(spec).__name__}): instance must expose "
                "candidates(Q) -> int32 [q, C] (device verification) or "
                "query_counts(Q, eps) -> int32 [q] (host verification)")
        if getattr(spec, "metric", self.metric) != self.metric:
            raise ValueError(
                f"verify({type(spec).__name__}): instance is built for "
                f"metric {getattr(spec, 'metric')!r}, the plan for "
                f"{self.metric!r}")
        if not self._same_R(getattr(spec, "R", self._R)):
            raise ValueError(
                f"verify({type(spec).__name__}): instance is indexed over a "
                "different R than this plan")
        return spec, getattr(spec, "name", type(spec).__name__)

    # -------------------------------------------------------------- build
    def build(self) -> "JoinPlan":
        """Validate the whole configuration and construct the execution
        state (engine, base, filter, verify route). Idempotent; called
        implicitly by `run` / `stream` / `describe`. All configuration
        errors surface here, before any query is served."""
        if self._built is not None:
            return self
        if self.metric not in ("cosine", "l2"):
            raise ValueError(f"metric={self.metric!r}: expected 'cosine' or "
                             "'l2'")
        if self._mutable:
            sspec = self._search_spec[0]
            if sspec != "naive":
                raise ValueError(
                    f"mutable() with search({_spec_name(sspec)!r}): mutable "
                    "plans require search('naive') — an instance or "
                    "registry base indexes its own host copy of R, which "
                    "insert/delete cannot patch; route approximate "
                    "verification through verify('lsh'/'ivfpq') instead "
                    "(engine-cached, rebuilt on compact)")
            vspec = self._verify_spec[0]
            if not (isinstance(vspec, str)
                    and vspec in ("auto",) + VERIFY_BACKENDS):
                raise ValueError(
                    f"mutable() with verify({_spec_name(vspec)!r}): mutable "
                    "plans need a by-name verify spec "
                    f"({('auto',) + VERIFY_BACKENDS}) so compact() can "
                    "rebuild the index over the merged R — a pinned "
                    "instance would keep probing the pre-merge tables")
        topo_spec = self._exec["topology"]
        r_shards = self._exec["r_shards"]
        # resolve early: an unknown topology name fails here, not mid-build
        topology = resolve_topology(topo_spec) if topo_spec is not None \
            else None
        engine = self._exec["engine"]
        if r_shards is not None:
            # r_shards targets a ring placement: requested explicitly, or
            # carried by a shared engine (then it is a pure cross-check)
            ring_target = (getattr(topology, "name", None) == "ring"
                           or (topology is None and engine is not None
                               and engine.topology.name == "ring"))
            if not ring_target:
                raise ValueError(
                    f"on(r_shards={r_shards}): r_shards sizes the ring "
                    "topology's R-sharding axis — it needs "
                    "on(topology='ring') or a shared ring engine")
            if int(r_shards) < 1:
                raise ValueError(f"on(r_shards={r_shards}): must be >= 1")
        if engine is not None:
            if engine.metric != self.metric or not self._same_R(engine._R_host):
                raise ValueError(
                    "on(engine=...): engine is built over a different "
                    f"(R, metric) — engine has |R|={engine.nr}/"
                    f"{engine.metric!r}, plan has |R|={len(self._R)}/"
                    f"{self.metric!r}")
            if (self._exec["mesh"] is not None
                    and engine.mesh is not self._exec["mesh"]):
                raise ValueError(
                    "on(engine=..., mesh=...): a shared engine carries its "
                    "own mesh; either drop mesh= (the engine's placement "
                    "wins) or drop engine= (the plan builds an engine on "
                    "that mesh)")
            if topology is not None and engine.topology.name != topology.name:
                raise ValueError(
                    "on(engine=..., topology=...): a shared engine carries "
                    f"its own placement ({engine.topology.name!r}); either "
                    "drop topology= or drop engine=")
            if r_shards is not None and engine.r_shards != int(r_shards):
                raise ValueError(
                    f"on(engine=..., r_shards={r_shards}): the shared "
                    f"engine shards R {engine.r_shards} way(s)")
        else:
            mesh = self._exec["mesh"]
            r_axis = getattr(topology, "r_axis", "r")
            if topology is not None and topology.name == "ring":
                if mesh is None:
                    if r_shards is None:
                        raise ValueError(
                            "on(topology='ring') needs r_shards=... (the "
                            "plan then builds a make_join_mesh(r=r_shards) "
                            "over the local devices) or an explicit 2-D "
                            f"mesh with an {r_axis!r} axis")
                    if r_axis != "r":
                        raise ValueError(
                            f"on(topology=<ring over {r_axis!r}>): "
                            "make_join_mesh only builds ('r', 'data') "
                            "meshes — pass an explicit mesh carrying the "
                            "custom axis")
                    from repro.launch.mesh import make_join_mesh
                    mesh = make_join_mesh(r=int(r_shards))
                elif (r_shards is not None
                        and int(mesh.shape.get(r_axis, 1)) != int(r_shards)):
                    raise ValueError(
                        f"on(topology='ring', r_shards={r_shards}, "
                        f"mesh=...): the mesh's {r_axis!r} axis has size "
                        f"{int(mesh.shape.get(r_axis, 1))}")
            if mesh is None:
                # adopt an instance base's own engine when it provably
                # owns this plan's (R, metric) AND no conflicting
                # placement was requested — a NaiveJoin base already
                # pinned R on device; a second engine would double
                # residency (an explicit on(mesh=...) still forces a
                # fresh engine on that mesh)
                spec = self._search_spec[0]
                cand = getattr(spec, "engine", None) \
                    if not isinstance(spec, str) else None
                if (cand is not None and cand.metric == self.metric
                        and self._same_R(cand._R_host)
                        and (topology is None
                             or cand.topology.name == topology.name)):
                    engine = cand
            if engine is None:
                engine = JoinEngine(self._R, self.metric, mesh=mesh,
                                    backend=self._exec["backend"],
                                    block=self._exec["block"],
                                    topology=topology or "replicated")
        if self._mutable:
            engine.auto_compact_at = self._auto_compact_at
            self._seen_compactions = engine.n_compactions
        base = self._build_base(engine)
        filt = self._build_filter(engine)
        verify_route, verify_label = self._build_verify(engine, base)
        # resolve the probe placement now (DESIGN.md §11): probe='device'
        # with a route that has no device probe fails HERE with an
        # actionable message, and the 'auto' placement cost (probe-table
        # upload + program build) lands at build time, not in batch 0
        placed = engine.device_probe_for(verify_route, self._exec["probe"])
        self._built = _BuiltPlan(engine=engine, base=base, filter=filt,
                                 verify_route=verify_route,
                                 verify_label=verify_label,
                                 placed_probe=placed)
        self._device_filter_cache.clear()
        return self

    # ----------------------------------------------------------- execution
    def _filter_state(self, eps: float):
        """(predict, threshold) for the fused device filter at this eps, or
        (None, None) when the filter is host-only; cached per eps so the
        XDT calibration cost is paid once per radius, not per batch."""
        f = self._built.filter
        if f is None or not hasattr(f, "device_filter"):
            return None, None
        key = round(float(eps), 9)
        if key not in self._device_filter_cache:
            self._device_filter_cache[key] = f.device_filter(eps) or (None,
                                                                      None)
        return self._device_filter_cache[key]

    def _host_verdicts(self, Q: np.ndarray, eps: float):
        f = self._built.filter
        if f is None:
            return None                     # engine treats None as all-pos
        return np.asarray(f.verdicts(Q, eps), bool)

    def _route_searcher(self):
        """The searcher object behind the verify route ("exact" -> None;
        engine-cached instance for by-name routes)."""
        route = self._built.verify_route
        if route == "exact":
            return None
        if isinstance(route, str):
            return self._built.engine.verifier(route)
        return route

    def _overflow_frac(self) -> Optional[float]:
        """The verify route's build-time candidate-loss budget
        (`LSHJoin.overflow_frac`), or None when the route has none."""
        frac = getattr(self._route_searcher(), "overflow_frac", None)
        return None if frac is None else float(frac)

    def _wrap(self, res, n: int, eps: float, t_host: float) -> JoinResult:
        st = self._built
        return JoinResult(
            counts=res.counts, n_queries=n, n_searched=res.n_searched,
            t_filter=res.t_filter + t_host, t_search=res.t_search,
            meta={"eps": eps, "tau": getattr(st.filter, "tau", 0),
                  "base": getattr(st.base, "name", "?"),
                  "filter": _filter_label(st.filter),
                  "engine": True, "verify": res.verify,
                  "probe": res.probe,
                  "overflow_frac": self._overflow_frac()})

    def run(self, Q: np.ndarray, eps: float) -> JoinResult:
        """One synchronous join pass: fused filter (or uploaded host
        verdicts) -> compact -> verify through the engine. Under
        `on(plan="auto")` the first call plans (measure-then-choose,
        DESIGN.md §16) and every call delegates to the chosen plan."""
        if self._exec["plan"] == "auto":
            return self._planned_delegate(Q, eps).run(Q, eps)
        self.build()
        Q = np.asarray(Q, np.float32)
        t0 = time.perf_counter()
        predict, threshold = self._filter_state(eps)
        verdicts = None if predict is not None else self._host_verdicts(Q, eps)
        t_host = time.perf_counter() - t0
        res = self._built.engine.filtered_join(
            Q, float(eps), predict=predict, threshold=threshold,
            verdicts=verdicts, block=self._exec["block"],
            verify=self._built.verify_route, probe=self._exec["probe"])
        return self._wrap(res, len(Q), eps, t_host)

    def stream(self, batches: Iterable[np.ndarray], eps: float, *,
               depth: Optional[int] = None) -> Iterator[JoinResult]:
        """Serving form: yield one JoinResult per query batch, in order,
        through the engine's asynchronous double-buffered pipeline
        (DESIGN.md §5) — batch k+1's programs dispatch while batch k's
        results transfer back; `depth` bounds the in-flight queue
        (`depth=0` ~= synchronous). Bit-identical to per-batch `run`."""
        sess = self.session(eps, depth=depth)
        for Q in batches:
            yield from sess.submit(Q)
        yield from sess.flush()

    def session(self, eps: float, *,
                depth: Optional[int] = None) -> "PlanSession":
        """Open a push-interface serving session at a fixed radius: the
        caller-driven form of `stream` (the serve gateway submits coalesced
        batches as they form rather than pulling from one iterable,
        DESIGN.md §14). Returns a `PlanSession` — `submit(Q)` /
        `flush()` yield `JoinResult`s in FIFO order, bit-identical to
        per-batch `run`; `set_depth()` retargets the in-flight bound
        mid-stream. `depth=None` uses the planner-chosen depth on
        planner-produced plans and 2 otherwise; under `on(plan="auto")`
        the session opens on the planner-chosen delegate."""
        if self._exec["plan"] == "auto":
            return self._planned_delegate(None, eps).session(eps,
                                                             depth=depth)
        if depth is None:
            depth = self._planned_depth or 2
        return PlanSession(self, eps, depth=depth)

    # ------------------------------------------------------ auto-planning
    def _planned_delegate(self, Q, eps: float) -> "JoinPlan":
        """The planner-chosen plan backing `on(plan="auto")` — planned at
        the first run/session against that call's queries and radius,
        then reused for the plan's lifetime (builders reset it)."""
        if self._mutable:
            raise RuntimeError(
                "on(plan='auto') on a mutable plan would leave this handle "
                "mutating a different engine than the one serving queries — "
                "call plan.auto(eps) explicitly and mutate the returned "
                "plan (DESIGN.md §16)")
        if self._auto_delegate is None:
            self._auto_delegate = self.auto(eps, Q)
        return self._auto_delegate

    def auto(self, eps: float, Q: Optional[np.ndarray] = None, *,
             recall: float = 0.9, err: float = 0.1,
             confidence: float = 0.95, seed: int = 0) -> "JoinPlan":
        """Measure-then-choose (DESIGN.md §16): return a new frozen,
        fully-specified `JoinPlan` picked by the cost-based planner for
        this plan's R at radius `eps`.

        The planner draws an error-bounded query sample from `Q` (or
        from R itself when `Q` is None — the serve gateway's query-free
        path), measures selectivity / filter skip rate / LSH bucket
        skew / delta occupancy with cheap probe-free programs, prices a
        pruned candidate grid with BENCH-calibrated constants, and
        applies the winner — splitting hot LSH buckets (skew-aware
        re-bucketing) when the measured occupancy trips the overflow
        trigger. Explicit knobs on THIS plan (`on(topology= ...)`,
        `on(probe=...)`, a by-name `verify(...)`, a shared engine) are
        respected as pinned constraints. `recall` is the acceptance
        floor gating approximate verifies (1.0 forces the exact sweep);
        `err`/`confidence` set the Hoeffding sample bound; `seed` makes
        the whole pass deterministic. The returned plan carries the
        machine-readable rationale in `explain()` and reports it under
        `describe()["planner"]`."""
        from repro.core import planner as _planner
        chosen, explain = _planner.plan_auto(
            self, Q, float(eps), recall=recall, err=err,
            confidence=confidence, seed=seed)
        chosen._exec["plan"] = None         # the choice is final: no
        chosen._planner_explain = explain   # recursive re-planning
        return chosen

    def explain(self) -> dict:
        """The planner's machine-readable rationale for this plan:
        measured workload/skew stats, calibrated cost constants,
        per-candidate cost estimates, rejection reasons, and the chosen
        configuration. Only planner-produced plans carry one — call
        `plan.auto(eps, Q)` (or run once under `on(plan="auto")` and
        take `describe()["planner"]`)."""
        if self._planner_explain is not None:
            return json.loads(json.dumps(self._planner_explain))
        if self._auto_delegate is not None:
            return self._auto_delegate.explain()
        raise RuntimeError(
            "explain(): this plan was not produced by the auto-planner — "
            "call plan.auto(eps, Q) for a planned plan, or run once under "
            "on(plan='auto') (DESIGN.md §16)")

    # ------------------------------------------------------------ sharing
    def fork(self) -> "JoinPlan":
        """A new frozen plan sharing this plan's built engine — the
        multi-tenant form of `on(engine=...)` (DESIGN.md §14): one pinned
        device-resident R/estimator, many plans differing only in
        verify/probe/filter knobs. The fork starts as a copy of this
        plan's filter/search/verify specs and exec placement with
        `engine=` set to the built engine (mesh/topology/r_shards are
        carried BY the engine, so they are cleared on the fork); override
        what differs with the normal builders, then `build()`.

        A by-name `filter("xling", ...)` is carried over as the already-
        FITTED `XlingFilter` instance, so per-tenant tau/xdt retunes
        re-calibrate the threshold without re-fitting the estimator.
        Mutability is NOT inherited: forks are frozen views — mutate
        through the original plan (forks observe inserts/deletes/compacts
        through the shared engine)."""
        self.build()
        clone = JoinPlan(self._R, self.metric)
        fspec, fopts = self._filter_spec
        if fspec == "xling":
            knobs = {k: v for k, v in fopts.items()
                     if k in ("tau", "xdt", "xdt_mode", "fpr_tolerance")}
            clone._filter_spec = (self._built.filter.filt, knobs)
        else:
            clone._filter_spec = (fspec, dict(fopts))
        clone._search_spec = (self._search_spec[0],
                              dict(self._search_spec[1]))
        clone._verify_spec = (self._verify_spec[0],
                              dict(self._verify_spec[1]))
        clone._exec = dict(self._exec)
        clone._exec.update(engine=self._built.engine, mesh=None,
                           topology=None, r_shards=None)
        return clone

    # ------------------------------------------------------------ mutation
    def _require_mutable(self, op: str) -> JoinEngine:
        if not self._mutable:
            raise RuntimeError(
                f"{op}: this plan is frozen — call .mutable() before "
                "insert/delete/compact (DESIGN.md §13)")
        return self.build()._built.engine

    def _sync_after_mutation(self) -> None:
        """Re-sync plan-side state after a mutation that may have
        compacted (explicitly or via the auto_compact_at policy):
        compaction re-uploads R and rebuilds the verifier indices, so the
        plan's host R reference and the resolved probe placement (which
        pins the pre-compact tables) must be refreshed."""
        eng = self._built.engine
        if eng.n_compactions == self._seen_compactions:
            return
        self._seen_compactions = eng.n_compactions
        self._R = eng._R_host
        self._built.placed_probe = eng.device_probe_for(
            self._built.verify_route, self._exec["probe"])

    def insert(self, rows) -> np.ndarray:
        """Insert rows into the logical index set: int64 ids [k] assigned
        to the new rows. They land in the device-resident delta shard and
        participate in every subsequent run/stream batch exactly
        (DESIGN.md §13); `compact()` — or the auto_compact_at policy —
        merges them into the pinned R."""
        eng = self._require_mutable("insert()")
        ids = eng.insert(rows)
        self._sync_after_mutation()
        return ids

    def delete(self, ids) -> None:
        """Delete rows by id (ids from `insert()`, or 0..|R|-1 for the
        original rows). Main-set rows become tombstones — zeroed on
        device and masked out of every verify backend; delta rows are
        dropped in place. Unknown or already-deleted ids raise KeyError
        before any mutation is applied."""
        eng = self._require_mutable("delete()")
        eng.delete(ids)
        self._sync_after_mutation()

    def compact(self) -> dict:
        """Merge the delta into the pinned R and drop tombstones: clears
        the engine's program caches, re-uploads the merged R under the
        plan's topology, rebuilds engine-cached verifier indices, and
        re-resolves the probe placement. Results are unchanged (the
        logical set is the same); per-query cost returns to the pinned
        baseline. Returns the engine's compaction stats."""
        eng = self._require_mutable("compact()")
        stats = eng.compact()
        self._sync_after_mutation()
        return stats

    # ---------------------------------------------------------- inspection
    def describe(self) -> dict:
        """Serializable plan summary (spec + resolved execution state),
        printed by the serve CLI and recorded by the benchmarks. Builds
        the plan if needed (so the summary reflects what will run)."""
        self.build()
        st = self._built

        def scalars(d: dict) -> dict:
            # json-serializable subset (np scalars etc. are coerced or
            # dropped so json.dumps never chokes on a plan summary)
            return {k: (v.item() if isinstance(v, np.generic) else v)
                    for k, v in d.items()
                    if isinstance(v, (int, float, str, bool, np.generic))}

        fspec, fopts = self._filter_spec
        sspec, sparams = self._search_spec
        vspec, vparams = self._verify_spec
        mesh = st.engine.mesh               # the placement that actually runs
        return {
            "metric": self.metric,
            "n_index": int(len(self._R)),
            "dim": int(self._R.shape[1]),
            "filter": {"spec": _spec_name(fspec) if fspec else None,
                       "resolved": _filter_label(st.filter),
                       "tau": getattr(st.filter, "tau", 0),
                       "opts": scalars(fopts)},
            "search": {"spec": _spec_name(sspec),
                       "resolved": getattr(st.base, "name",
                                           type(st.base).__name__),
                       "exact": bool(getattr(st.base, "exact", False)),
                       # False when an explicit verify backend bypasses the
                       # base's own verification route (the filter still
                       # gates which queries reach that backend)
                       "active": (st.verify_route is st.base
                                  or (st.verify_route == "exact"
                                      and isinstance(st.base, NaiveJoin))),
                       "params": scalars(sparams)},
            "verify": {"spec": _spec_name(vspec),
                       "resolved": st.verify_label,
                       "params": scalars(vparams),
                       # the route's build-time candidate-loss budget
                       # (LSH bucket-capacity overflow) — None when the
                       # route tracks none
                       "overflow_frac": self._overflow_frac()},
            "exec": {"backend": st.engine.backend,
                     "block": self._exec["block"],
                     "mesh": (None if mesh is None
                              else dict(zip(mesh.axis_names,
                                            map(int, mesh.devices.shape)))),
                     "engine_shared": self._exec["engine"] is not None,
                     # the placement that actually runs (DESIGN.md §10):
                     # per_device_r_bytes is the number topology moves
                     "topology": {
                         "name": st.engine.topology.name,
                         "r_shards": int(st.engine.r_shards),
                         "per_device_r_bytes":
                             int(st.engine.per_device_r_bytes)},
                     # where the verify route's index probe runs (§11):
                     # "device" with table residency, "host" for probing
                     # routes without a device probe, None for the exact
                     # sweep (it has no probe stage)
                     "probe": {
                         "mode": self._exec["probe"],
                         "resolved": (
                             "device" if st.placed_probe is not None
                             else ("host" if self._route_searcher()
                                   is not None else None)),
                         "table_bytes_per_device": (
                             None if st.placed_probe is None else
                             int(st.placed_probe.table_bytes_per_device)),
                         "cand_width": (
                             None if st.placed_probe is None else
                             int(st.placed_probe.cand_width))}},
            # dynamic-R state (DESIGN.md §13): None for frozen plans
            "mutable": (None if not self._mutable else {
                "auto_compact_at": self._auto_compact_at,
                "n_delta": int(st.engine.n_delta),
                "delta_capacity": int(st.engine.delta_capacity),
                "delta_frac": float(st.engine.delta_frac),
                "n_tombstones": int(st.engine.n_tombstones),
                "compactions": int(st.engine.n_compactions)}),
            # the auto-planner's rationale (DESIGN.md §16): None unless
            # this plan was produced by auto(); the full machine-readable
            # record is plan.explain()
            "planner": (None if self._planner_explain is None else {
                "chosen": dict(self._planner_explain["chosen"]),
                "calibration":
                    self._planner_explain["constants"]["calibration"],
                "sample": dict(self._planner_explain["sample"]),
                "rejected": [dict(r)
                             for r in self._planner_explain["rejected"]],
            }),
        }

    @property
    def engine(self) -> JoinEngine:
        """The plan's `JoinEngine` (builds the plan on first access) —
        the tuning hook for verifier indices lives here
        (`plan.engine.verifier(name, **params)`)."""
        return self.build()._built.engine

    @property
    def base(self):
        """The plan's base Searcher (builds the plan on first access)."""
        return self.build()._built.base


class PlanSession:
    """Caller-driven serving session over a built `JoinPlan` at one radius
    (`JoinPlan.session`): the push form of `stream`, wrapping the engine's
    `StreamSession` with the plan's filter (fused device form, or host
    verdicts computed per submit) and verify route. `submit(Q)` returns
    the (possibly empty) list of OLDER batches' `JoinResult`s released
    under the depth bound; `flush()` is the drain barrier. Results are
    FIFO and bit-identical to per-batch `JoinPlan.run` — the contract the
    serve gateway's scatter-back relies on (DESIGN.md §14)."""

    def __init__(self, plan: JoinPlan, eps: float, *, depth: int = 2):
        plan.build()
        self._plan = plan
        self.eps = float(eps)
        t0 = time.perf_counter()
        self._predict, self._threshold = plan._filter_state(eps)
        self._t_host = time.perf_counter() - t0  # one-time XDT selection
        self._sess = plan._built.engine.stream_session(
            eps, predict=self._predict, threshold=self._threshold,
            verify=plan._built.verify_route, depth=depth,
            block=plan._exec["block"], probe=plan._exec["probe"])
        self._pending: list[tuple[int, float]] = []  # FIFO (n, host cost)

    def _emit(self, results) -> list[JoinResult]:
        out = []
        for res in results:
            n, th = self._pending.pop(0)
            out.append(self._plan._wrap(res, n, self.eps, th))
        return out

    def submit(self, Q: np.ndarray) -> list[JoinResult]:
        """Feed one query batch; returns older batches' results whose
        readback completed under the depth bound (host filter verdicts are
        computed here when the filter has no device form)."""
        Q = np.asarray(Q, np.float32)
        t1 = time.perf_counter()
        verdicts = (None if self._predict is not None
                    else self._plan._host_verdicts(Q, self.eps))
        th = self._t_host + (time.perf_counter() - t1)
        self._t_host = 0.0              # charge XDT selection to batch 0
        self._pending.append((len(Q), th))
        return self._emit(self._sess.submit(Q, verdicts=verdicts))

    def flush(self) -> list[JoinResult]:
        """Drain barrier: all remaining results, in submission order."""
        return self._emit(self._sess.flush())

    def set_depth(self, depth: int) -> None:
        """Retarget the in-flight bound mid-stream (adaptive depth,
        DESIGN.md §14); takes effect on the next submit."""
        self._sess.set_depth(depth)

    @property
    def depth(self) -> int:
        """The current in-flight bound."""
        return self._sess.depth
