"""Shared small utilities: PRNG plumbing, tree math, timing, caching."""
from __future__ import annotations

import contextlib
import hashlib
import json
import os
import time
from dataclasses import asdict, is_dataclass
from typing import Any, Iterator

import jax
import jax.numpy as jnp
import numpy as np

CACHE_DIR = os.environ.get("REPRO_CACHE", os.path.join(os.path.dirname(__file__), "..", "..", ".cache"))


def cache_path(*key: Any, ext: str = "npz") -> str:
    os.makedirs(CACHE_DIR, exist_ok=True)
    blob = json.dumps([repr(k) for k in key], sort_keys=True).encode()
    h = hashlib.sha1(blob).hexdigest()[:16]
    return os.path.join(CACHE_DIR, f"{h}.{ext}")


def tree_size(tree: Any) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree: Any) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_cast(tree: Any, dtype) -> Any:
    return jax.tree.map(lambda x: x.astype(dtype), tree)


def tree_zeros_like(tree: Any, dtype=None) -> Any:
    return jax.tree.map(lambda x: jnp.zeros(x.shape, dtype or x.dtype), tree)


def split_like(key: jax.Array, tree: Any) -> Any:
    """One PRNG key per leaf of `tree`'s structure."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, list(keys))


@contextlib.contextmanager
def timed() -> Iterator[dict]:
    """with timed() as t: ...; t['s'] holds elapsed seconds."""
    box = {}
    t0 = time.perf_counter()
    try:
        yield box
    finally:
        box["s"] = time.perf_counter() - t0


def block_until_ready(x: Any) -> Any:
    jax.tree.map(lambda a: a.block_until_ready() if hasattr(a, "block_until_ready") else a, x)
    return x


def config_dict(cfg: Any) -> dict:
    if is_dataclass(cfg):
        return asdict(cfg)
    return dict(cfg)


def memoize_device_fn(obj, key, build):
    """Per-object memo for traceable device predict fns (estimator
    protocol): the engine's program cache is keyed by fn identity, so the
    SAME fn object must come back across calls until `key` changes."""
    if getattr(obj, "_device_fn", None) is None or obj._device_fn_key != key:
        obj._device_fn, obj._device_fn_key = build(), key
    return obj._device_fn


def cost_analysis_dict(compiled) -> dict:
    """Version-compatible `Compiled.cost_analysis()`: JAX 0.4.x returns a
    one-dict list (per executable), newer versions the dict itself."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def pad_to(arr: np.ndarray, n: int, axis: int = 0, value=0) -> np.ndarray:
    pad = n - arr.shape[axis]
    if pad <= 0:
        return arr
    widths = [(0, 0)] * arr.ndim
    widths[axis] = (0, pad)
    return np.pad(arr, widths, constant_values=value)
