"""Pallas TPU kernel: fused tiled pairwise-distance + eps-histogram.

This is the compute hot-spot of the whole paper: both the ground-truth
target construction for the learned cardinality estimator (one count per
candidate eps in the ATCS grid) and the verification step of every join
method reduce to "count neighbors of each query within each eps".

TPU adaptation (vs the paper's CPU loop / a CUDA candidate-list port):
  * The (Q_blk x R_blk) distance tile is an MXU matmul on unit vectors:
    d_cos = 1 - q.r,  d_l2 = sqrt(2 - 2 q.r), so one bf16 matmul with f32
    accumulation yields the whole tile.
  * The m-bin eps histogram is fused into the same VMEM residency: the
    distance tile is compared against ONE eps at a time (a per-eps masked
    accumulate on the VPU) and the per-eps counts land in an int32
    [Q_blk, m] block, so the m-candidate grid used by ATCS costs a single
    sweep over R instead of m sweeps.  The compare working set is a
    single [Q_blk, R_blk] bool — it used to be a [Q_blk, R_blk,
    eps_chunk] broadcast, which at the default tile was the largest
    temporary in the kernel and capped block_r at 512.
  * Grid is (q_blocks, r_blocks) with the r axis innermost ("arbitrary"
    semantics): the output block for a fixed q block is revisited across r
    steps and accumulated in place — the canonical Pallas reduction layout.

VMEM budget at the widened tile (Bq=256, Br=1024, d<=1024, m<=128):
  q tile 256x1024 f32 = 1 MB, r tile 1024x1024 f32 = 4 MB, distance tile
  256x1024 f32 = 1 MB, out 256x128 i32 = 0.125 MB, per-eps compare
  256x1024 bool = 0.25 MB  =>  ~6.4 MB < 16 MB VMEM (the old eps-chunk
  broadcast was 256x512x8 bool = 1 MB at Br=512 and would have been 2 MB
  at Br=1024 — the per-eps accumulate is what lets block_r grow to 1024
  with headroom).

`eps_chunk` survives only as the eps-grid PADDING quantum (callers pad m
to a multiple of it so one executable serves nearby grid sizes); the
kernel loop itself is per-eps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def default_interpret() -> bool:
    """Platform-derived `interpret=` default for every kernel in this
    package: compiled on TPU, interpret mode elsewhere (the kernel body
    runs as jnp ops for correctness validation).  Callers that pass
    `interpret=None` get this policy, so a TPU run can never silently
    fall into interpret mode (ISSUE 9 satellite)."""
    return jax.default_backend() != "tpu"


def _kernel(q_ref, r_ref, eps_ref, out_ref, *, metric: str, nr_valid: int,
            block_r: int):
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    q = q_ref[...].astype(jnp.float32)            # [Bq, D]
    r = r_ref[...].astype(jnp.float32)            # [Br, D]
    dots = jax.lax.dot_general(q, r, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)  # [Bq, Br]
    if metric == "cosine":
        d = 1.0 - dots
    elif metric == "l2":
        d = jnp.sqrt(jnp.maximum(2.0 - 2.0 * dots, 0.0))
    else:
        raise ValueError(f"unknown metric {metric!r}")

    # Mask out R-padding rows (they must never count as neighbors).
    r_index = j * block_r + jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    d = jnp.where(r_index < nr_valid, d, jnp.inf)

    eps = eps_ref[0, :]                           # [m_padded] f32
    m_padded = eps.shape[0]
    acc = jnp.zeros(out_ref.shape, jnp.int32)     # [Bq, m_padded]

    def body(c, acc):
        # per-eps masked accumulate: the compare temporary is one
        # [Bq, Br] bool, not the old [Bq, Br, eps_chunk] broadcast
        e = jax.lax.dynamic_slice(eps, (c,), (1,))
        cnt = jnp.sum(d <= e[0], axis=1, dtype=jnp.int32)   # [Bq]
        return jax.lax.dynamic_update_slice(acc, cnt[:, None], (0, c))

    acc = jax.lax.fori_loop(0, m_padded, body, acc)
    out_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("metric", "nr_valid", "block_q",
                                             "block_r", "eps_chunk", "interpret"))
def range_count_hist_pallas(q: jax.Array, r: jax.Array, eps_grid: jax.Array,
                            *, metric: str = "cosine", nr_valid: int | None = None,
                            block_q: int = 256, block_r: int = 512,
                            eps_chunk: int = 8,
                            interpret: bool | None = None) -> jax.Array:
    """Padded-shape entry point. q [nq,d], r [nr,d] (nq % block_q == 0,
    nr % block_r == 0, eps_grid [m] with m % eps_chunk == 0, sorted).
    Returns int32 [nq, m]. Padding/unpadding lives in ops.range_count_hist.
    `interpret=None` derives the mode from the runtime platform
    (`default_interpret`): compiled on TPU, interpret elsewhere.
    """
    nq, d = q.shape
    nr = r.shape[0]
    m = eps_grid.shape[0]
    assert nq % block_q == 0 and nr % block_r == 0 and m % eps_chunk == 0
    nr_valid = nr if nr_valid is None else nr_valid
    if interpret is None:
        interpret = default_interpret()
    eps2d = eps_grid.astype(jnp.float32).reshape(1, m)

    grid = (nq // block_q, nr // block_r)
    kernel = functools.partial(_kernel, metric=metric, nr_valid=nr_valid,
                               block_r=block_r)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_r, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, m), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, m), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nq, m), jnp.int32),
        interpret=interpret,
    )(q, r, eps2d)
